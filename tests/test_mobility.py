"""Determinism of the opportunistic world (repro.core.mobility).

The mobility subsystem is parity-critical the same way the derived
minibatch schedule is: both engines must see the SAME world.  These
tests pin down (a) the counter-based kinematics — closed-form in
(seed, round, device), identical under tracing, prefix-stable under
candidate padding; (b) the re-negotiation semantics — top-n_max by
utility, battery-floor releases, arrival undercutting; and (c) fleet
runs being invariant to ``round_chunk`` with mobility enabled.  The
full train-loop churn parity (params/battery/masks, loop vs fleet)
lives in tests/test_fleet_engine.py.
"""

import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mobility
from repro.core.mobility import MobilityConfig


# ---------------------------------------------------------------------------
# kinematics: counter-based, traceable, prefix-stable
# ---------------------------------------------------------------------------


def test_positions_inside_arena_and_deterministic():
    mob = MobilityConfig(arena_m=150.0, leg_rounds=3, seed=11)
    traj = np.asarray(mobility.trajectory(mob, 7, 20))
    assert traj.shape == (20, 2)
    assert (traj >= 0.0).all() and (traj <= 150.0).all()
    again = np.asarray(mobility.trajectory(mob, 7, 20))
    np.testing.assert_array_equal(traj, again)


def test_static_mode_pins_devices():
    mob = MobilityConfig(mode="static", seed=5)
    traj = np.asarray(mobility.trajectory(mob, 3, 12))
    assert (traj == traj[0]).all()


def test_waypoint_interpolation_hits_leg_endpoints():
    """Round k*leg_rounds sits exactly ON waypoint k; in between the
    device moves linearly — the closed-form discretized random-waypoint."""
    mob = MobilityConfig(leg_rounds=4, seed=2)
    traj = np.asarray(mobility.trajectory(mob, 9, 13))
    w0, w4, w8 = traj[0], traj[4], traj[8]
    # interior rounds of a leg interpolate its endpoints
    np.testing.assert_allclose(traj[2], 0.5 * (w0 + w4), rtol=1e-5)
    np.testing.assert_allclose(traj[6], 0.5 * (w4 + w8), rtol=1e-5)
    assert not np.allclose(w0, w4), "waypoints differ"


def test_traced_round_matches_concrete_round():
    """The fleet engine queries positions with a TRACED round number
    inside its compiled loop; the loop engine passes python ints.  Same
    value, same position — the schedule-style parity keystone."""
    mob = MobilityConfig(leg_rounds=3, seed=9)
    for r in (0, 1, 5, 11):
        traced = jax.jit(lambda rr: mobility.device_position(mob, 3, rr))(
            jnp.int32(r))
        host = mobility.device_position(mob, 3, r)
        np.testing.assert_array_equal(np.asarray(traced), np.asarray(host))


def test_positions_prefix_stable_under_device_padding():
    """Each device's trajectory hashes from its own id alone: adding
    candidate lanes (fleet padding) never moves existing devices."""
    mob = MobilityConfig(seed=4)
    small = np.asarray(mobility.device_positions(mob, np.arange(3), 6))
    big = np.asarray(mobility.device_positions(mob, np.arange(8), 6))
    np.testing.assert_array_equal(small, big[:3])


# ---------------------------------------------------------------------------
# re-negotiation semantics
# ---------------------------------------------------------------------------


def _membership(mob, r, ids, level, base_util, n_max, cand_mask=None):
    ids = np.asarray(ids, np.int32)
    cand_mask = np.ones(ids.shape, bool) if cand_mask is None else cand_mask
    member, rank, util = mobility.membership_step(
        mob, r, mob.requester_id, ids, cand_mask,
        np.asarray(base_util, np.float32), np.asarray(level, np.float32),
        n_max)
    return np.asarray(member), np.asarray(rank), np.asarray(util)


def test_membership_caps_at_n_max_by_utility():
    # everyone in range (static world, huge radius), utility ordered 3>1>0>2
    mob = MobilityConfig(mode="static", radio_range_m=1e6, seed=0)
    base = np.array([0.3, 0.5, 0.1, 0.9], np.float32)
    member, rank, _ = _membership(mob, 0, np.arange(4), np.ones(4), base, 2)
    assert member.tolist() == [False, True, False, True]
    assert rank[3] == 0 and rank[1] == 1


def test_membership_releases_below_battery_floor():
    mob = MobilityConfig(mode="static", radio_range_m=1e6, seed=0,
                         battery_floor=0.25)
    base = np.array([0.9, 0.8, 0.7], np.float32)
    level = np.array([0.2, 0.9, 0.9], np.float32)   # best device is flat
    member, _, _ = _membership(mob, 0, np.arange(3), level, base, 3)
    assert member.tolist() == [False, True, True]


def test_membership_undercut_by_higher_utility_arrival():
    """With full slots, an eligible higher-utility device displaces the
    weakest member (contract-theory undercutting)."""
    mob = MobilityConfig(mode="static", radio_range_m=1e6, seed=0)
    base = np.array([0.4, 0.5, 0.95], np.float32)
    # device 2 (best) ineligible -> 0 and 1 fill both slots
    m0, _, _ = _membership(mob, 0, np.arange(3), [0.9, 0.9, 0.0], base, 2)
    assert m0.tolist() == [True, True, False]
    # device 2 arrives (battery back) -> weakest member (0) is displaced
    m1, _, _ = _membership(mob, 0, np.arange(3), [0.9, 0.9, 0.9], base, 2)
    assert m1.tolist() == [False, True, True]


def test_membership_prefix_stable_under_candidate_padding():
    """Fleet lanes are padded to the widest candidate pool; padded lanes
    (cand_mask False) must never alter the real lanes' membership —
    mirroring the schedule's prefix-stability guarantee."""
    mob = MobilityConfig(radio_range_m=120.0, leg_rounds=2, seed=3)
    base = np.array([0.6, 0.4, 0.8], np.float32)
    level = np.array([0.9, 0.8, 0.7], np.float32)
    for r in range(6):
        m_small, _, _ = _membership(mob, r, np.arange(3), level, base, 2)
        m_big, _, _ = _membership(
            mob, r, np.arange(6),
            np.concatenate([level, np.ones(3, np.float32)]),
            np.concatenate([base, np.full(3, 99.0, np.float32)]), 2,
            cand_mask=np.array([1, 1, 1, 0, 0, 0], bool))
        np.testing.assert_array_equal(m_small, m_big[:3])
        assert not m_big[3:].any()


def test_membership_ties_break_by_lane_index():
    mob = MobilityConfig(mode="static", radio_range_m=1e6, seed=0)
    base = np.full(4, 0.5, np.float32)
    member, rank, _ = _membership(mob, 0, np.arange(4), np.ones(4), base, 2)
    assert member.tolist() == [True, True, False, False]
    assert rank.tolist() == [0, 1, 2, 3]


def test_membership_events_counts_joins_and_leaves():
    trace = np.array([[1, 1, 0], [1, 0, 1], [1, 0, 1], [0, 0, 1]], bool)
    joins, leaves = mobility.membership_events(trace)
    assert joins == 1 and leaves == 2


# ---------------------------------------------------------------------------
# fleet integration: chunk invariance + engine parity of the world
# ---------------------------------------------------------------------------


def _tiny_problem(n_contrib=4, n_samples=260, seed=0):
    from repro.core import SupervisedTask, make_fleet
    from repro.data import (CaloriesDatasetConfig, dirichlet_partition,
                            make_calories_tabular)
    from repro.models import MLPClassifier, MLPClassifierConfig

    x, y = make_calories_tabular(CaloriesDatasetConfig(num_samples=n_samples))
    task = SupervisedTask(MLPClassifier(MLPClassifierConfig(8, (8,), 5)), lr=3e-3)
    parts = dirichlet_partition(y, num_clients=n_contrib + 1, alpha=100.0, seed=seed)
    shards = [(x[p], y[p]) for p in parts]
    own_x, own_y = shards[0]
    n = int(len(own_x) * 0.8)
    fleet = make_fleet(n_contrib, seed=1, p_has_model=1.0)
    states = {}
    for i, dev in enumerate(fleet):
        dev.reservation_price = 0.4
        states[dev.device_id] = {"params": task.init(seed=10 + i),
                                 "data": shards[i + 1]}
    return (task, (own_x[:n], own_y[:n]), (own_x[n:], own_y[n:]), fleet,
            states)


def test_fleet_mobility_round_chunk_invariance():
    """The churn trajectory (membership masks AND params) is an invariant
    of the world, not of the early-exit chunking."""
    from jax.flatten_util import ravel_pytree

    from repro.core import EnFedConfig, RequesterSpec, run_fleet

    task, own_train, own_test, fleet, states = _tiny_problem()
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=5, epochs=1,
                      batch_size=16, encrypt=False, n_max=3,
                      contributor_refresh_epochs=1,
                      mobility=MobilityConfig(radio_range_m=110.0,
                                              leg_rounds=2, seed=3))
    results = [run_fleet(task, [RequesterSpec(own_train, own_test, fleet,
                                              copy.deepcopy(states))],
                         cfg, round_chunk=c) for c in (1, 3, 8)]
    ref = results[0]
    for res in results[1:]:
        np.testing.assert_array_equal(res.history_raw["member"],
                                      ref.history_raw["member"])
        assert res.sessions[0].rounds == ref.sessions[0].rounds
        rv, _ = ravel_pytree(ref.sessions[0].params)
        fv, _ = ravel_pytree(res.sessions[0].params)
        np.testing.assert_allclose(np.asarray(fv), np.asarray(rv), rtol=1e-6)


def test_loop_and_fleet_derive_identical_world():
    """Same seed => identical membership masks and battery trajectories
    across the two engines, independently of training tolerances."""
    from repro.core import EnFedConfig, EnFedSession, RequesterSpec, run_fleet

    task, own_train, own_test, fleet, states = _tiny_problem()
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=5, epochs=1,
                      batch_size=16, encrypt=False, n_max=2,
                      contributor_refresh_epochs=1,
                      mobility=MobilityConfig(radio_range_m=90.0,
                                              leg_rounds=2, seed=7))
    loop = EnFedSession(task, own_train, own_test, fleet,
                        copy.deepcopy(states), cfg).run()
    fl = run_fleet(task, [RequesterSpec(own_train, own_test, fleet,
                                        copy.deepcopy(states))], cfg).sessions[0]
    assert fl.rounds == loop.rounds
    np.testing.assert_array_equal(np.array(loop.history_raw["member_mask"]),
                                  np.array(fl.history_raw["member_mask"]))
    np.testing.assert_allclose(fl.history_raw["battery"], loop.history_raw["battery"],
                               rtol=1e-5, atol=1e-6)


def test_mobility_config_validation():
    with pytest.raises(AssertionError):
        MobilityConfig(mode="teleport")
    with pytest.raises(AssertionError):
        MobilityConfig(leg_rounds=0)


# ---------------------------------------------------------------------------
# launch.mesh stays importable on the pinned toolchain (version gate)
# ---------------------------------------------------------------------------


def test_launch_mesh_imports_on_pinned_jax():
    """repro.launch.mesh must import (and fail loudly only on device
    COUNT, never on AxisType) regardless of the jax version."""
    from repro.launch import mesh

    assert isinstance(mesh.AXIS_TYPES_SUPPORTED, bool)
    with pytest.raises(RuntimeError, match="devices"):
        mesh.make_production_mesh()
