"""Optimizers in pure JAX (no optax in this environment).

API mirrors the optax ``GradientTransformation`` pair so later swapping
is mechanical:

    opt = adam(1e-3)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Adam moments are kept in fp32 regardless of param dtype (bf16-safe), and
the second moment uses the gradient squared in fp32 — matching production
mixed-precision practice and what the dry-run memory analysis assumes.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


def _lr_at(lr: Schedule, step):
    return lr(step) if callable(lr) else jnp.float32(lr)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state)


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: object
    nu: object


def adam(lr: Schedule, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, grad_clip: Optional[float] = None) -> Optimizer:
    def init(params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(f32, params),
            nu=jax.tree_util.tree_map(f32, params),
        )

    def update(grads, state, params):
        step = state.step + 1
        if grad_clip is not None:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in jax.tree_util.tree_leaves(grads)))
            scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = _lr_at(lr, step)

        def upd(m, v, p):
            u = -(lr_t * (m / bc1) / (jnp.sqrt(v / bc2) + eps))
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u.astype(p.dtype)

        updates = jax.tree_util.tree_map(upd, mu, nu, params)
        return updates, AdamState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def adamw(lr: Schedule, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: object


def sgd(lr: Schedule, momentum: float = 0.0) -> Optimizer:
    def init(params):
        mom = (jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
               if momentum else None)
        return SGDState(step=jnp.zeros((), jnp.int32), momentum=mom)

    def update(grads, state, params):
        step = state.step + 1
        lr_t = _lr_at(lr, step)
        if momentum:
            mom = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g.astype(jnp.float32), state.momentum, grads)
            updates = jax.tree_util.tree_map(
                lambda m, p: (-lr_t * m).astype(p.dtype), mom, params)
            return updates, SGDState(step=step, momentum=mom)
        updates = jax.tree_util.tree_map(
            lambda g, p: (-lr_t * g.astype(jnp.float32)).astype(p.dtype), grads, params)
        return updates, SGDState(step=step, momentum=None)

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(jnp.add, params, updates)
