"""Asynchronous cadence world: counter-based device round clocks.

``repro.core.cadence`` ends the lockstep round barrier: per-device
speed classes, duty cycles, transient offline windows and battery
pacing make each lane's round clock advance on its own tick steps.
These tests pin the three contracts the subsystem guarantees:

* the tick derivation is closed-form counter-based — traced and
  concrete evaluation agree bitwise, and a step's tick set does not
  depend on which other steps were queried;
* both engines derive the SAME asynchronous trajectory: bitwise round
  clocks / idle counts / membership masks / tick sets, allclose params,
  across static, mobility, and fault worlds — including kill-and-resume
  bit-identity with cadence on;
* ``cadence=None`` (and the degenerate always-tick config) reproduce
  the lockstep engines bit for bit: lockstep is a special case, not a
  separate code path.
"""

import copy
import glob
import os

import jax
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.core import (CadenceConfig, EnFedConfig, EnFedSession,
                        MobilityConfig, RequesterSpec, run_fleet)
from repro.core import cadence as cadence_mod
from repro.core.battery import BatteryState
from repro.core.faults import FaultConfig

from test_fleet_engine import BATCH, _build

# seed 0 hashes the requester (id 1<<22) to stride 2 under two speed
# classes — the REQUESTER idles between its rounds; seed 5 hashes it to
# stride 1 while two of the three contributors land on stride 2 — the
# requester outpaces its STRAGGLERS (asserted below, not assumed)
CC_SLOW_REQ = CadenceConfig(n_speed_classes=2, seed=0)
CC_STRAGGLER = CadenceConfig(n_speed_classes=2, seed=5)


@pytest.fixture(scope="module")
def problem():
    return _build()


# ---------------------------------------------------------------------------
# the cadence derivation itself
# ---------------------------------------------------------------------------


def test_cadence_config_validates():
    with pytest.raises(ValueError):
        CadenceConfig(n_speed_classes=0)
    with pytest.raises(ValueError):
        CadenceConfig(duty_cycle=4, duty_on=0)
    with pytest.raises(ValueError):
        CadenceConfig(duty_cycle=4, duty_on=5)
    with pytest.raises(ValueError):
        CadenceConfig(p_offline=1.0)
    with pytest.raises(ValueError):
        CadenceConfig(pace_factor=0)
    with pytest.raises(ValueError):
        CadenceConfig(pace_battery_threshold=1.5)
    with pytest.raises(ValueError):
        CadenceConfig(idle_step_s=-0.1)


def test_tick_mask_traced_equals_concrete():
    """The jit/vmap evaluation the fleet engine runs must agree bitwise
    with the loop engine's concrete host-side calls."""
    cc = CadenceConfig(n_speed_classes=3, duty_cycle=4, duty_on=2,
                       p_offline=0.2, seed=11)
    ids = np.arange(1, 9, dtype=np.int32)
    traced = jax.jit(lambda t: cadence_mod.tick_mask(cc, t, ids))
    for t in range(12):
        np.testing.assert_array_equal(
            np.asarray(traced(t)),
            np.asarray(cadence_mod.tick_mask(cc, t, ids)))


def test_tick_mask_is_closed_form():
    """Counter-based world state: step t's ticks are a pure function of
    (seed, t, device) — per-step queries equal any batched/shuffled
    evaluation order, so no replay is ever needed."""
    cc = CadenceConfig(n_speed_classes=2, duty_cycle=3, duty_on=1,
                       p_offline=0.3, seed=4)
    ids = np.arange(1, 6, dtype=np.int32)
    forward = [np.asarray(cadence_mod.tick_mask(cc, t, ids))
               for t in range(10)]
    backward = [np.asarray(cadence_mod.tick_mask(cc, t, ids))
                for t in reversed(range(10))]
    np.testing.assert_array_equal(np.stack(forward),
                                  np.stack(backward[::-1]))
    # and a single device queried alone matches its column in the batch
    for t in (0, 3, 7):
        for j, d in enumerate(ids):
            assert bool(cadence_mod.tick_mask(cc, t, d)) == bool(forward[t][j])


def test_events_budget():
    # worst stride x duty ceiling x offline allowance
    assert cadence_mod.events_budget(CadenceConfig(), 7) == 7
    assert cadence_mod.events_budget(
        CadenceConfig(n_speed_classes=2, seed=3), 4) == 8
    assert cadence_mod.events_budget(
        CadenceConfig(n_speed_classes=2, duty_cycle=4, duty_on=2,
                      p_offline=0.1), 3) == 3 * 2 * 2 * 2
    assert cadence_mod.events_budget(
        CadenceConfig(max_events=11, n_speed_classes=5), 3) == 11


def test_stride_one_always_ticks():
    cc = CadenceConfig()   # one speed class, no duty/offline/pacing
    ids = np.arange(100, dtype=np.int32)
    for t in range(5):
        assert np.asarray(cadence_mod.tick_mask(cc, t, ids)).all()


# ---------------------------------------------------------------------------
# engine parity on async worlds
# ---------------------------------------------------------------------------


def _run_both(problem, cfg, battery_kw=None):
    task, own_train, own_test, fleet, states = problem
    bk = battery_kw or {}
    loop = EnFedSession(task, own_train, own_test, fleet,
                        copy.deepcopy(states), cfg,
                        battery=BatteryState(**bk)).run()
    spec = RequesterSpec(own_train=own_train, own_test=own_test,
                         neighborhood=fleet,
                         contributor_states=copy.deepcopy(states),
                         battery=BatteryState(**bk))
    fl = run_fleet(task, [spec], cfg).sessions[0]
    return loop, fl


def _assert_async_parity(loop, fl):
    """Bitwise on the async trajectory (clocks, idle counts, masks),
    allclose on the float metrics — the ISSUE's parity contract."""
    lh, fh = loop.history_raw, fl.history_raw
    assert fl.rounds == loop.rounds
    assert fl.stop_reason == loop.stop_reason
    assert lh["round_clock"] == fh["round_clock"]
    assert lh["idle_steps"] == fh["idle_steps"]
    np.testing.assert_allclose(fh["battery"], lh["battery"],
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(fh["accuracy"], lh["accuracy"],
                               rtol=1e-5, atol=1e-6)
    for k in ("member_mask", "deliver_mask"):
        if k in lh:
            lm, fm = np.stack(lh[k]), np.stack(fh[k])
            np.testing.assert_array_equal(lm, fm[:, :lm.shape[1]])
            assert not fm[:, lm.shape[1]:].any()   # fleet N-padding only
    lv, _ = ravel_pytree(loop.params)
    fv, _ = ravel_pytree(fl.params)
    np.testing.assert_allclose(np.asarray(fv), np.asarray(lv),
                               rtol=1e-4, atol=1e-5)
    # the idle pricing went through the one shared helper identically
    assert abs(loop.report.times.t_com - fl.report.times.t_com) < 1e-9


def test_async_parity_static_requester_idles(problem):
    """Requester on stride 2: its clock skips every other event step and
    the idle windows are priced identically by both engines."""
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=3, epochs=2,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1, cadence=CC_SLOW_REQ)
    loop, fl = _run_both(problem, cfg)
    _assert_async_parity(loop, fl)
    clock = loop.history_raw["round_clock"]
    assert clock == [1, 3, 5]                      # stride-2 requester
    assert loop.history_raw["idle_steps"] == [1, 1, 1]
    assert loop.report.times.t_com > 0             # idle seconds priced


def test_async_parity_static_stragglers_refresh_less(problem):
    """Requester on stride 1 with stride-2 contributors: straggler
    rounds provably happen (a signed contributor's tick is off on at
    least one executed step) and their resident wire images are
    aggregated as-is by both engines."""
    task, own_train, own_test, fleet, states = problem
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=3, epochs=2,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1, cadence=CC_STRAGGLER)
    loop, fl = _run_both(problem, cfg)
    _assert_async_parity(loop, fl)
    ids = np.array([d.device_id for d in fleet], np.int32)
    straggled = sum(
        int((~np.asarray(cadence_mod.tick_mask(CC_STRAGGLER, t, ids))).sum())
        for t in loop.history_raw["round_clock"])
    assert straggled >= 1, "no straggler round exercised: pick a new seed"


def test_async_parity_duty_cycle_and_offline(problem):
    cc = CadenceConfig(n_speed_classes=2, duty_cycle=3, duty_on=2,
                       p_offline=0.15, seed=1)
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=3, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1, cadence=cc)
    loop, fl = _run_both(problem, cfg)
    _assert_async_parity(loop, fl)
    assert max(loop.history_raw["idle_steps"]) >= 2   # real duty gaps


def test_async_parity_fault_world(problem):
    """Fault weather keys on the global event step; delivered/stale
    masks stay bitwise identical across engines under cadence."""
    cfg = EnFedConfig(
        desired_accuracy=0.99, max_rounds=3, epochs=1, batch_size=BATCH,
        encrypt=False, contributor_refresh_epochs=1, cadence=CC_STRAGGLER,
        faults=FaultConfig(p_drop=0.3, p_stale=0.25, max_retries=1, seed=7))
    loop, fl = _run_both(problem, cfg)
    _assert_async_parity(loop, fl)


def test_async_parity_mobility_world(problem):
    """Mobility kinematics key on the global event step; the membership
    trajectory stays bitwise identical across engines under cadence."""
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=3, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1, cadence=CC_SLOW_REQ,
                      mobility=MobilityConfig(seed=3))
    loop, fl = _run_both(problem, cfg)
    _assert_async_parity(loop, fl)
    assert loop.history_raw["round_clock"] == [1, 3, 5]


def test_async_parity_battery_pacing(problem):
    """The one state-coupled rule: crossing the pacing threshold slows
    the requester's clock mid-session, identically in both engines."""
    cc = CadenceConfig(pace_battery_threshold=0.87, pace_factor=2, seed=2)
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=4, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1, battery_threshold=0.05,
                      cadence=cc)
    loop, fl = _run_both(problem, cfg,
                         battery_kw=dict(capacity_j=4.0, level=0.9))
    _assert_async_parity(loop, fl)
    # unpaced round 0 at t=0, then the drained battery halves the clock
    assert loop.history_raw["round_clock"][0] == 0
    assert max(loop.history_raw["idle_steps"][1:]) >= 1


# ---------------------------------------------------------------------------
# lockstep is a special case, not a fork
# ---------------------------------------------------------------------------


def test_degenerate_cadence_is_lockstep_bitwise(problem):
    """An always-tick cadence (one speed class, no duty/offline/pacing,
    budget == round budget) reproduces cadence=None bit for bit in both
    engines — the async code path contains the lockstep protocol as its
    fixed point."""
    base = dict(desired_accuracy=0.99, max_rounds=2, epochs=1,
                batch_size=BATCH, encrypt=False,
                contributor_refresh_epochs=1)
    off = EnFedConfig(**base)
    on = EnFedConfig(**base, cadence=CadenceConfig(max_events=2))
    for engine in ("loop", "fleet"):
        a = _engine_run(problem, off, engine)
        b = _engine_run(problem, on, engine)
        pa, _ = ravel_pytree(a.params)
        pb, _ = ravel_pytree(b.params)
        assert np.array_equal(np.asarray(pa), np.asarray(pb)), engine
        np.testing.assert_array_equal(a.history_raw["battery"],
                                      b.history_raw["battery"])
        np.testing.assert_array_equal(a.history_raw["accuracy"],
                                      b.history_raw["accuracy"])
        assert b.history_raw["round_clock"] == [0, 1]   # t == r exactly
        assert b.history_raw["idle_steps"] == [0, 0]


def _engine_run(problem, cfg, engine):
    task, own_train, own_test, fleet, states = problem
    if engine == "loop":
        return EnFedSession(task, own_train, own_test, fleet,
                            copy.deepcopy(states), cfg,
                            battery=BatteryState()).run()
    spec = RequesterSpec(own_train=own_train, own_test=own_test,
                         neighborhood=fleet,
                         contributor_states=copy.deepcopy(states),
                         battery=BatteryState())
    return run_fleet(task, [spec], cfg).sessions[0]


def test_cadence_is_enfed_only(problem):
    task, own_train, own_test, fleet, states = problem
    cfg = EnFedConfig(max_rounds=1, cadence=CadenceConfig())
    spec = RequesterSpec(own_train=own_train, own_test=own_test,
                         neighborhood=fleet,
                         contributor_states=copy.deepcopy(states))
    with pytest.raises(ValueError, match="cadence"):
        run_fleet(task, [spec], cfg, method="dfl")


# ---------------------------------------------------------------------------
# kill-and-resume with cadence on
# ---------------------------------------------------------------------------


def _kill_after(ckpt_dir, keep_step):
    removed = 0
    for f in glob.glob(os.path.join(ckpt_dir, "step_*.npz")):
        if int(os.path.basename(f)[5:13]) > keep_step:
            os.remove(f)
            removed += 1
    assert removed > 0, "nothing to kill: checkpointing did not run"


def _assert_resume_identical(full, res):
    fp, _ = ravel_pytree(full.params)
    rp, _ = ravel_pytree(res.params)
    assert np.array_equal(np.asarray(fp), np.asarray(rp))
    assert res.rounds == full.rounds
    assert res.stop_reason == full.stop_reason
    fh, rh = full.history_raw, res.history_raw
    assert fh["round_clock"] == rh["round_clock"]
    assert fh["idle_steps"] == rh["idle_steps"]
    np.testing.assert_array_equal(fh["battery"], rh["battery"])
    assert full.report.times.t_com == res.report.times.t_com


def test_loop_resume_with_cadence(problem, tmp_path):
    """Killed-and-resumed == uninterrupted, with the event clock and the
    accumulated idle run restored from the checkpoint payload."""
    task, own_train, own_test, fleet, states = problem
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=4, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1, cadence=CC_SLOW_REQ)

    def run(**kw):
        return EnFedSession(task, own_train, own_test, fleet,
                            copy.deepcopy(states), cfg,
                            battery=BatteryState()).run(**kw)

    full = run()
    d = str(tmp_path / "loop")
    run(checkpoint_dir=d)
    _kill_after(d, 2)
    _assert_resume_identical(full, run(resume_from=d))


def test_fleet_resume_with_cadence(problem, tmp_path):
    """The named carry's clock/idle fields round-trip through the
    checkpoint at chunk boundaries (event-step granularity)."""
    task, own_train, own_test, fleet, states = problem
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=4, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1, cadence=CC_SLOW_REQ)

    def run(**kw):
        spec = RequesterSpec(own_train=own_train, own_test=own_test,
                             neighborhood=fleet,
                             contributor_states=copy.deepcopy(states),
                             battery=BatteryState())
        return run_fleet(task, [spec], cfg, round_chunk=2, **kw).sessions[0]

    full = run()
    d = str(tmp_path / "fleet")
    run(checkpoint_dir=d)
    _kill_after(d, 2)
    _assert_resume_identical(full, run(resume_from=d))
