"""Parameter and input sharding rules (FSDP + tensor/expert parallel).

``param_specs`` maps every parameter leaf to a ``NamedSharding`` using
path- and shape-based rules:

* leaves under a ``scan`` subtree have a leading stacked-layer axis that
  is never sharded;
* 3-D expert weights (``ffn/w{g,u,d}`` of a MoE block) put the expert
  axis on ``model`` (expert parallel) and FSDP the next axis on ``data``;
* otherwise the last-most axis divisible by the ``model`` axis size is
  tensor-parallel, and the largest remaining axis divisible by the
  ``data`` axis size is FSDP-sharded (ZeRO-3 style) — how
  billion-parameter optimizer state would fit 16 GB/chip;
* 1-D leaves (biases, norm scales, RG-LRU ``lam``) stay replicated.

Inputs shard their leading (batch) axis over ``("pod", "data")``.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _spec_for(path: str, shape, mesh: Mesh, fsdp: bool = True) -> P:
    ndim = len(shape)
    axes = [None] * ndim
    start = 1 if ("scan/" in path or path.startswith("encoder/")) and ndim >= 1 else 0
    model_n = _axis_size(mesh, "model")
    data_n = _axis_size(mesh, "data") if fsdp else 1
    eff = ndim - start
    if eff <= 1:
        return P(*axes)  # replicate 1-D leaves

    if path.rsplit("/", 1)[-1] == "embed" and eff == 2:
        # Embedding tables are gathered by token id.  XLA's SPMD
        # partitioner CHECK-fails on feature-dim-sharded gather operands
        # under partial-manual meshes (subgroup replication bug), so
        # embeddings shard ONLY the vocab axis, Megatron-style, over
        # 'model' (and 'data' too when fsdp and still divisible).
        if model_n > 1 and shape[start] % model_n == 0:
            axes[start] = "model"
            if data_n > 1 and shape[start] % (model_n * data_n) == 0:
                axes[start] = ("data", "model")
        elif data_n > 1 and shape[start] % data_n == 0:
            axes[start] = "data"
        return P(*axes)

    if path.rsplit("/", 1)[-1] == "router":
        # router enters the token-local MoE shard_map: must be replicated
        # over 'data' (same partitioner constraint as expert weights)
        if eff == 2 and model_n > 1 and shape[ndim - 1] % model_n == 0:
            axes[ndim - 1] = "model"
        return P(*axes)

    is_expert = "/ffn/" in path and path.rsplit("/", 1)[-1] in ("wg", "wu", "wd") and eff == 3
    if is_expert:
        # Expert parallel over 'model' only.  Expert weights must enter the
        # token-local MoE shard_map replicated over 'data' (an FSDP'd
        # expert tensor under a manual-'data' region CHECK-crashes XLA's
        # partitioner), so even fsdp configs keep experts un-FSDP'd here —
        # the §Perf expert-parallel all-to-all schedule is the fix that
        # shards E over (data x model).
        if shape[start] % model_n == 0:
            axes[start] = "model"
        return P(*axes)

    # tensor parallel: last-most divisible axis -> model
    tp_axis = None
    for i in range(ndim - 1, start - 1, -1):
        if model_n > 1 and shape[i] % model_n == 0:
            tp_axis = i
            axes[i] = "model"
            break
    # FSDP: largest remaining divisible axis -> data
    best, best_size = None, 0
    for i in range(start, ndim):
        if i != tp_axis and data_n > 1 and shape[i] % data_n == 0 and shape[i] > best_size:
            best, best_size = i, shape[i]
    if best is not None:
        axes[best] = "data"
    return P(*axes)


def param_specs(params, mesh: Mesh, fsdp: bool = True):
    """NamedSharding pytree for a parameter pytree.

    ``fsdp=False`` keeps params replicated over the data axis (tensor
    parallel only) — required when the data axis doubles as the EnFed
    client axis (non-fsdp configs, see ModelConfig.fsdp).
    """

    def f(path, leaf):
        return NamedSharding(mesh, _spec_for(_path_str(path), leaf.shape, mesh, fsdp=fsdp))

    return jax.tree_util.tree_map_with_path(f, params)


def batch_spec(mesh: Mesh) -> tuple:
    if "pod" in mesh.axis_names:
        return ("pod", "data")
    return ("data",)


def input_specs_sharding(batch, mesh: Mesh):
    """Shard the leading axis of every input leaf over the batch axes."""
    b = batch_spec(mesh)
    spec_b = b if len(b) > 1 else b[0]

    def f(leaf):
        axes = [None] * len(leaf.shape)
        if len(axes) >= 1:
            axes[0] = spec_b
        return NamedSharding(mesh, P(*axes))

    return jax.tree_util.tree_map(f, batch)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
