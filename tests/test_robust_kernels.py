"""Byzantine-robust aggregation kernels (repro.kernels.robust): Pallas
vs pure-jnp oracles, statistic semantics, and the fused-q8 twins.

The ref module formulates each statistic differently from the kernels
(sort/argmax/take_along_axis vs comparison networks and one-hot
selections), so agreement here cross-checks two independent
derivations; the q8 tests pin the never-re-densify property — fused
dequant-aggregate equals the dense statistic on the dequantized buffer.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.robust.ops import (clip_factors, l2norm_flat_batched,
                                      l2norm_flat_batched_q8,
                                      median_flat_batched,
                                      median_flat_batched_q8,
                                      robust_aggregate, robust_aggregate_q8,
                                      trimmed_mean_flat_batched,
                                      trimmed_mean_flat_batched_q8)
from repro.kernels.robust.ref import (median_batched_ref, sqnorm_batched_ref,
                                      trimmed_mean_batched_ref)
from repro.kernels.quantize.ops import (dequantize_flat_batched,
                                        quantize_flat_batched)

RNG = np.random.default_rng(17)

SHAPES = [(1, 3, 17), (4, 5, 2048), (8, 4, 3001), (16, 6, 777)]


def _world(r, n, l):
    u = jnp.asarray(RNG.normal(size=(r, n, l)).astype(np.float32))
    w = jnp.asarray((RNG.random((r, n)) > 0.3).astype(np.float32)
                    * RNG.random((r, n)).astype(np.float32))
    return u, w


def _q8_world(r, n, lp):
    assert lp % 1024 == 0, "q8 shapes must be TILE-padded"
    dense = jnp.asarray(RNG.normal(size=(r * n, lp)).astype(np.float32))
    q, s = quantize_flat_batched(dense)
    w = jnp.asarray((RNG.random((r, n)) > 0.3).astype(np.float32)
                    * RNG.random((r, n)).astype(np.float32))
    return (q.reshape(r, n, lp), s.reshape(r, n, -1), w)


# ---------------------------------------------------------------------------
# Pallas vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("r,n,l", SHAPES)
def test_trimmed_mean_matches_ref(r, n, l):
    u, w = _world(r, n, l)
    got = trimmed_mean_flat_batched(u, w, use_pallas=True)
    want = trimmed_mean_flat_batched(u, w, use_pallas=False)
    assert got.shape == (r, l)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("r,n,l", SHAPES)
def test_median_matches_ref(r, n, l):
    u, w = _world(r, n, l)
    got = median_flat_batched(u, w, use_pallas=True)
    want = median_flat_batched(u, w, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("r,n,l", SHAPES)
def test_l2norm_matches_ref(r, n, l):
    u, _ = _world(r, n, l)
    got = l2norm_flat_batched(u, use_pallas=True)
    want = l2norm_flat_batched(u, use_pallas=False)
    assert got.shape == (r, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# statistic semantics (hand-checkable cases)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_pallas", [True, False], ids=["pallas", "ref"])
def test_trimmed_mean_drops_extremes(use_pallas):
    u = jnp.asarray([[[1.0], [100.0], [3.0], [-50.0], [2.0]]], jnp.float32)
    w = jnp.ones((1, 5), jnp.float32)
    out = trimmed_mean_flat_batched(u, w, use_pallas=use_pallas)
    # 100 and -50 drop; mean(1, 3, 2) = 2
    np.testing.assert_allclose(np.asarray(out), [[2.0]], atol=1e-6)


@pytest.mark.parametrize("use_pallas", [True, False], ids=["pallas", "ref"])
def test_trimmed_mean_tie_breaks_first_instance(use_pallas):
    # two equal maxima: only the FIRST instance drops (matches argmax)
    u = jnp.asarray([[[5.0], [5.0], [0.0], [1.0]]], jnp.float32)
    w = jnp.ones((1, 4), jnp.float32)
    out = trimmed_mean_flat_batched(u, w, use_pallas=use_pallas)
    # drop first 5 (max) and the 0 (min): mean(5, 1) = 3
    np.testing.assert_allclose(np.asarray(out), [[3.0]], atol=1e-6)


@pytest.mark.parametrize("use_pallas", [True, False], ids=["pallas", "ref"])
def test_trimmed_mean_small_active_falls_back_to_mean(use_pallas):
    # <= 2 active: nothing to trim, plain weighted mean
    u = jnp.asarray([[[1.0], [3.0], [99.0]]], jnp.float32)
    w = jnp.asarray([[1.0, 3.0, 0.0]], jnp.float32)
    out = trimmed_mean_flat_batched(u, w, use_pallas=use_pallas)
    np.testing.assert_allclose(np.asarray(out), [[2.5]], atol=1e-6)
    # 0 active -> 0 (the fedavg convention; caller keeps prior params)
    out0 = trimmed_mean_flat_batched(u, jnp.zeros((1, 3), jnp.float32),
                                     use_pallas=use_pallas)
    np.testing.assert_allclose(np.asarray(out0), [[0.0]], atol=1e-6)


@pytest.mark.parametrize("use_pallas", [True, False], ids=["pallas", "ref"])
def test_median_weights_gate_activity_only(use_pallas):
    u = jnp.asarray([[[1.0], [9.0], [4.0], [777.0]]], jnp.float32)
    w = jnp.asarray([[0.1, 5.0, 2.0, 0.0]], jnp.float32)
    out = median_flat_batched(u, w, use_pallas=use_pallas)
    # active values {1, 9, 4}: median 4 regardless of weight magnitudes
    np.testing.assert_allclose(np.asarray(out), [[4.0]], atol=1e-6)
    # even active count: mean of the two middles
    w2 = jnp.ones((1, 4), jnp.float32)
    out2 = median_flat_batched(u, w2, use_pallas=use_pallas)
    np.testing.assert_allclose(np.asarray(out2), [[6.5]], atol=1e-6)


def test_clip_factors_median_threshold():
    norms = jnp.asarray([[1.0, 2.0, 10.0]], jnp.float32)
    w = jnp.ones((1, 3), jnp.float32)
    c, clipped, tau = clip_factors(norms, w)
    np.testing.assert_allclose(np.asarray(tau), [2.0])
    np.testing.assert_allclose(np.asarray(c), [[1.0, 1.0, 0.2]], atol=1e-6)
    np.testing.assert_array_equal(np.asarray(clipped),
                                  [[False, False, True]])
    # inactive slots: factor 1, never flagged — even with a huge norm
    w0 = jnp.asarray([[1.0, 1.0, 0.0]], jnp.float32)
    c0, clipped0, _ = clip_factors(norms, w0)
    assert float(c0[0, 2]) == 1.0 and not bool(clipped0[0, 2])
    # by construction at most half the active set clips
    r = jnp.asarray(RNG.random((6, 9)).astype(np.float32)) * 10
    wr = jnp.ones((6, 9), jnp.float32)
    _, cl, _ = clip_factors(r, wr)
    assert int(np.asarray(cl).sum(axis=1).max()) <= 4


# ---------------------------------------------------------------------------
# fused q8 twins (never-re-densify)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("r,n,lp", [(2, 3, 1024), (4, 5, 2048), (8, 4, 3072)])
@pytest.mark.parametrize("use_pallas", [True, False], ids=["pallas", "ref"])
def test_q8_twins_match_dense_on_dequantized(r, n, lp, use_pallas):
    q, s, w = _q8_world(r, n, lp)
    dense = dequantize_flat_batched(q.reshape(r * n, lp),
                                    s.reshape(r * n, -1)).reshape(r, n, lp)
    for fused, plain in [
        (trimmed_mean_flat_batched_q8, trimmed_mean_flat_batched),
        (median_flat_batched_q8, median_flat_batched),
    ]:
        got = fused(q, s, w, use_pallas=use_pallas)
        want = plain(dense, w, use_pallas=use_pallas)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)
    gn = l2norm_flat_batched_q8(q, s, use_pallas=use_pallas)
    wn = l2norm_flat_batched(dense, use_pallas=use_pallas)
    np.testing.assert_allclose(np.asarray(gn), np.asarray(wn),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# the dispatch entry both engines call
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["trimmed_mean", "median", "clip"])
def test_robust_aggregate_dispatch(method):
    u, w = _world(4, 5, 777)
    agg_p, cl_p = robust_aggregate(u, w, method=method, use_pallas=True)
    agg_r, cl_r = robust_aggregate(u, w, method=method, use_pallas=False)
    assert agg_p.shape == (4, 777) and cl_p.shape == (4, 5)
    np.testing.assert_allclose(np.asarray(agg_p), np.asarray(agg_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(cl_p), np.asarray(cl_r))
    if method != "clip":
        # trim/median carry no per-contributor verdict
        assert not np.asarray(cl_p).any()


def test_robust_aggregate_q8_dispatch():
    q, s, w = _q8_world(3, 4, 1024)
    dense = dequantize_flat_batched(q.reshape(12, 1024),
                                    s.reshape(12, -1)).reshape(3, 4, 1024)
    for method in ("trimmed_mean", "median", "clip"):
        agg_q, cl_q = robust_aggregate_q8(q, s, w, method=method)
        agg_d, cl_d = robust_aggregate(dense, w, method=method)
        np.testing.assert_allclose(np.asarray(agg_q), np.asarray(agg_d),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(cl_q), np.asarray(cl_d))


def test_robust_aggregate_unknown_method():
    u, w = _world(1, 3, 17)
    with pytest.raises(ValueError, match="robust method"):
        robust_aggregate(u, w, method="krum")


def test_clip_recovers_from_scale_attack():
    """End-to-end sanity: one 100x-scaled contributor drags plain fedavg
    but barely moves the clip/trim aggregates."""
    from repro.kernels.fedavg.ops import fedavg_flat_batched
    honest = RNG.normal(size=(1, 5, 256)).astype(np.float32)
    attacked = honest.copy()
    attacked[0, 2] *= 100.0
    u = jnp.asarray(attacked)
    w = jnp.ones((1, 5), jnp.float32)
    clean = np.asarray(fedavg_flat_batched(jnp.asarray(honest), w))
    naive = np.asarray(fedavg_flat_batched(u, w))
    assert np.linalg.norm(naive - clean) > 10 * np.linalg.norm(clean)
    for method in ("clip", "trimmed_mean", "median"):
        rob = np.asarray(robust_aggregate(u, w, method=method)[0])
        assert (np.linalg.norm(rob - clean)
                < 0.5 * np.linalg.norm(naive - clean)), method
