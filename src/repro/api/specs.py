"""Declarative experiment specs — the orthogonal axes of one run.

The legacy entrypoints tangle three concerns into incompatible call
conventions: *what world* the session runs in (requesters, neighbors,
contributor states, mobility, cost model, batteries), *which method*
trains (EnFed vs the paper's DFL/CFL/cloud baselines and their protocol
knobs), and *how it executes* (loop vs fleet engine, Pallas interpret
mode, early-exit chunking).  This module splits them:

* :class:`WorldSpec` — the simulated world, shared verbatim across every
  method of a comparison (that is what makes the paper's Table-style
  reductions meaningful).
* :class:`MethodSpec` — a method name from the registry
  (``repro.api.methods``) plus the protocol knobs, mapped 1:1 onto
  :class:`repro.core.rounds.EnFedConfig` so baselines consume the SAME
  configuration surface as EnFed.
* :class:`ExecutionSpec` — engine selection and engine tuning knobs;
  changing it must never change the simulated outcome, only how fast it
  is computed (parity-tested in ``tests/test_api.py``).
"""

from __future__ import annotations

import copy
import dataclasses
from typing import Dict, List, Optional, Union

import numpy as np

from repro.core.adversary import AdversaryConfig
from repro.core.battery import BatteryState
from repro.core.cadence import CadenceConfig
from repro.core.energy import CostModel
from repro.core.faults import FaultConfig
from repro.core.fleet import RequesterSpec
from repro.core.mobility import MobilityConfig
from repro.core.rounds import EnFedConfig
from repro.core.topology import AggregationStrategy


@dataclasses.dataclass
class WorldSpec:
    """The simulated world: who exists, what data/models/batteries they
    hold, how they move, and what everything costs.

    ``requesters[0]`` is "the requesting device" of the paper's
    comparisons; baselines that model a single participating device
    (CFL/DFL/cloud) are evaluated from its perspective.  ``seed`` drives
    every derivation (schedules, keys, kinematics) so two runs on one
    ``WorldSpec`` see the identical world.
    """

    task: object                          # SupervisedTask-like (init/fit/evaluate)
    requesters: List[RequesterSpec]
    cost_model: CostModel = dataclasses.field(default_factory=CostModel)
    mobility: Optional[MobilityConfig] = None
    pooled_train: Optional[tuple] = None  # cloud baseline corpus (default: all shards)
    seed: int = 0

    @classmethod
    def single(cls, task, own_train, own_test, neighborhood,
               contributor_states: Dict[int, dict], *,
               battery: Optional[BatteryState] = None,
               cost_model: Optional[CostModel] = None,
               mobility: Optional[MobilityConfig] = None,
               pooled_train: Optional[tuple] = None,
               seed: int = 0) -> "WorldSpec":
        """The common one-requester world, from ``EnFedSession``-style args."""
        return cls(task=task,
                   requesters=[RequesterSpec(
                       own_train=own_train, own_test=own_test,
                       neighborhood=neighborhood,
                       contributor_states=contributor_states,
                       battery=battery)],
                   cost_model=cost_model or CostModel(),
                   mobility=mobility, pooled_train=pooled_train, seed=seed)

    def fresh_requesters(self) -> List[RequesterSpec]:
        """Per-run copies of the mutable state, so every
        ``Experiment.run`` starts from the same world.  The engines
        mutate by REBINDING ``states[id]["params"]`` (refresh training)
        and replacing batteries — the param trees and data shards
        themselves are immutable arrays — so a two-level shallow copy of
        the state dicts is sufficient isolation without duplicating
        multi-MB training shards per run."""
        return [RequesterSpec(
            own_train=r.own_train, own_test=r.own_test,
            neighborhood=r.neighborhood,
            contributor_states={k: dict(v)
                                for k, v in r.contributor_states.items()},
            battery=copy.deepcopy(r.battery)) for r in self.requesters]

    def client_data(self, i: int = 0) -> List[tuple]:
        """The CFL/DFL client list seen from requester ``i``: its own
        shard first (client 0 = the requesting device), then each
        neighbor's shard in neighborhood order."""
        r = self.requesters[i]
        shards = [r.own_train]
        for dev in r.neighborhood:
            st = r.contributor_states.get(dev.device_id)
            if st is not None:
                shards.append(st["data"])
        return shards

    def pooled(self, i: int = 0) -> tuple:
        """The cloud-baseline corpus: ``pooled_train`` if given, else the
        concatenation of requester ``i``'s client shards."""
        if self.pooled_train is not None:
            return self.pooled_train
        shards = self.client_data(i)
        x = np.concatenate([np.asarray(s[0]) for s in shards])
        y = np.concatenate([np.asarray(s[1]) for s in shards])
        return x, y


@dataclasses.dataclass(frozen=True)
class MethodSpec:
    """Which method trains, and with which protocol knobs.

    The knobs are exactly :class:`repro.core.rounds.EnFedConfig`'s
    fields (world-owned ``seed``/``mobility`` excluded) so every
    registered method — EnFed and the re-plumbed baselines — consumes
    one configuration surface; ``topology`` only matters to ``"dfl"``.
    Coerce a bare registry name with :meth:`coerce`.
    """

    name: str = "enfed"
    desired_accuracy: float = 0.95       # A_A
    max_rounds: int = 10                 # R_A
    epochs: int = 5                      # E
    batch_size: int = 32                 # B_A
    n_max: int = 5                       # N_max
    battery_threshold: float = 0.2       # B_min
    offered_incentive: float = 0.6
    encrypt: bool = True
    contributor_refresh_epochs: int = 1
    strategy: Optional[AggregationStrategy] = None
    topology: str = "mesh"               # dfl: "mesh" | "ring"
    # transported-update compression (None | "int8" | "auto").  A
    # PROTOCOL knob, not an execution knob: it changes the simulated
    # outcome (wire bytes, eq. (4)-(7) energy, quantized params), so it
    # lives here and every method prices its transport through the same
    # repro.core.energy.update_wire_bytes helper.  "auto" resolves per
    # model size via repro.kernels.quantize.ops.resolve_compress — int8
    # only past the padding-overhead crossover, fp32 below it.
    compress: Optional[str] = None
    # unreliable-link world (None = perfect links).  Like ``compress``
    # this is a PROTOCOL knob: drops/retries/stale delivery change the
    # simulated outcome for enfed (Phase.DELIVER in both engines) and
    # re-price the extra transmissions for every method through the same
    # CostModel.retry_energy term.  Validation is FaultConfig's own
    # __post_init__ — a bad probability fails at spec construction.
    faults: Optional[FaultConfig] = None
    # asynchronous-cadence world (None = lockstep round barrier).  A
    # PROTOCOL knob like ``faults``: per-device speed classes, duty
    # cycles, transient offline windows and battery pacing desynchronize
    # the engines' round clocks (global event steps, straggler wire
    # images aggregated as-is) and price the idle windows through
    # CostModel.idle_energy.  enfed-only: the host-side baselines have
    # no per-device round clock — they warn-and-ignore, and the fleet
    # baselines refuse.  Validation is CadenceConfig's __post_init__.
    cadence: Optional[CadenceConfig] = None
    # Byzantine-contributor world (None = every contributor honest).  A
    # PROTOCOL knob like ``faults``/``cadence``: which links corrupt
    # their delivered wire image each round is counter-based world
    # state (repro.core.adversary), derived identically by both
    # engines.  enfed-only: the baselines' loop oracles define their
    # aggregation semantics without Phase.DELIVER — they warn-and-
    # ignore, and the fleet baselines refuse.
    adversary: Optional[AdversaryConfig] = None
    # Byzantine-robust Phase.AGGREGATE statistic ("none" | "clip" |
    # "trimmed_mean" | "median" — repro.kernels.robust), and the
    # staleness decay gamma on the aggregation weights (1.0 = none).
    # Both are enfed-only protocol knobs like ``adversary``.
    robust: str = "none"
    staleness_gamma: float = 1.0
    label: Optional[str] = None          # display/compare key (default: name)

    @property
    def key(self) -> str:
        """The name this run is reported/keyed under in a comparison —
        lets e.g. ``dfl``-mesh and ``dfl``-ring coexist in one table."""
        return self.label or self.name

    @classmethod
    def coerce(cls, m: Union[str, "MethodSpec"],
               like: Optional["MethodSpec"] = None) -> "MethodSpec":
        """``"dfl"`` -> a MethodSpec inheriting every knob from ``like``
        (or the defaults); a MethodSpec passes through unchanged.  The
        ``label`` is NOT inherited — it names ``like``'s own run, and
        carrying it over would mislabel the coerced method (and collide
        compare() keys)."""
        if isinstance(m, MethodSpec):
            return m
        base = like if like is not None else cls()
        return dataclasses.replace(base, name=str(m), label=None)

    def to_enfed_config(self, world: WorldSpec) -> EnFedConfig:
        """The method knobs + the world's seed/mobility as the config
        object both engines (and the re-plumbed baselines) execute."""
        return EnFedConfig(
            desired_accuracy=self.desired_accuracy,
            max_rounds=self.max_rounds,
            n_max=self.n_max,
            battery_threshold=self.battery_threshold,
            offered_incentive=self.offered_incentive,
            epochs=self.epochs,
            batch_size=self.batch_size,
            encrypt=self.encrypt,
            contributor_refresh_epochs=self.contributor_refresh_epochs,
            seed=world.seed,
            strategy=self.strategy,
            compress=self.compress,
            faults=self.faults,
            cadence=self.cadence,
            adversary=self.adversary,
            robust=self.robust,
            staleness_gamma=self.staleness_gamma,
            mobility=world.mobility)


@dataclasses.dataclass(frozen=True)
class ExecutionSpec:
    """How the run executes — never *what* it computes.

    ``engine="loop"`` is the readable Python oracle; ``"fleet"`` compiles
    all requesters into one jit program.  ``use_pallas`` / ``interpret``
    select the aggregation-kernel path (``interpret=None`` resolves per
    backend via ``repro.kernels.common.resolve_interpret``);
    ``round_chunk`` is the fleet engine's early-exit granularity.
    ``enfed``, ``dfl`` and ``cfl`` honor the engine choice — the
    baselines run as traced protocol variants of the same fleet program
    (``run_fleet(method=...)``), parity-tested against their loop
    learners.  ``cloud`` has no round structure to compile and always
    records ``engine="loop"``.
    """

    engine: str = "loop"                 # "loop" | "fleet"
    use_pallas: bool = True
    interpret: Optional[bool] = None
    round_chunk: int = 4
    # crash-resumable round state (enfed only; baselines warn-and-ignore).
    # ``checkpoint_dir`` serializes the flat wire-format round state +
    # batteries + masks + round clocks via repro.checkpoint every
    # ``checkpoint_every`` rounds (0 = the engine default: every round
    # for the loop engine, every round_chunk for the fleet engine);
    # ``resume_from`` restores the latest checkpoint in a directory and
    # continues bit-identically.  Execution knobs: a resumed run
    # computes the same outcome an uninterrupted one does.
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0
    resume_from: Optional[str] = None
    # observability (repro.telemetry.TraceConfig): which artifacts the
    # run exports (event JSONL, Chrome trace, jax profiler dump, HLO cost
    # summary).  The strictest execution knob of all — observation can
    # never change the simulated outcome; tracing on is bitwise identical
    # to tracing off (enforced by tests/test_telemetry.py and the bench
    # trace smoke gate).  Fleet-only selections (jax_profiler_dir,
    # hlo_stats) warn-and-ignore on the loop engine.
    trace: Optional[object] = None

    def __post_init__(self):
        if self.engine not in ("loop", "fleet"):
            raise ValueError(f"unknown engine {self.engine!r} (loop|fleet)")
        if self.round_chunk < 1:
            raise ValueError(
                f"round_chunk must be >= 1 (got {self.round_chunk})")
        if self.checkpoint_every < 0:
            raise ValueError(
                f"checkpoint_every must be >= 0 (got {self.checkpoint_every})")
        if self.trace is not None:
            from repro.telemetry import TraceConfig

            if not isinstance(self.trace, TraceConfig):
                raise ValueError(
                    f"trace must be a repro.telemetry.TraceConfig "
                    f"(got {type(self.trace).__name__})")
