"""`Experiment` — the single public entrypoint over world x method x engine.

``Experiment(world, method, execution).run()`` executes one method;
``Experiment.compare([...])`` runs N methods on the SAME world + seed +
cost model and returns the paper's Table-style comparison.  All legacy
entrypoints (``EnFedSession.run``, ``run_fleet``, the baseline learners)
remain as thin shims; this facade is where new call conventions stop
accreting.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Sequence, Union

from repro.api.methods import get_runner, method_names
from repro.api.result import CompareResult, RunResult
from repro.api.specs import ExecutionSpec, MethodSpec, WorldSpec

DEFAULT_COMPARISON = ("enfed", "dfl", "cfl", "cloud")


@dataclasses.dataclass
class Experiment:
    """One declarative experiment: a world, a method, an execution plan.

    ``method`` may be a registry name (``"enfed"``, ``"dfl"``, ``"cfl"``,
    ``"cloud"``) or a full :class:`MethodSpec`; ``execution`` tunes *how*
    (never *what*) is computed.
    """

    world: WorldSpec
    method: Union[str, MethodSpec] = "enfed"
    execution: ExecutionSpec = dataclasses.field(default_factory=ExecutionSpec)

    def run(self, method: Union[str, MethodSpec, None] = None, *,
            resume: Union[str, None] = None) -> RunResult:
        """Execute one method (default: ``self.method``) and return the
        unified :class:`RunResult`.  The world's mutable state is copied
        per run, so repeated calls are independent and identical.

        ``resume`` restores enfed round state from a checkpoint
        directory (shorthand for ``ExecutionSpec.resume_from``): a run
        killed mid-session and resumed computes the identical outcome
        the uninterrupted run would have."""
        spec = MethodSpec.coerce(method if method is not None else self.method,
                                 like=MethodSpec.coerce(self.method))
        runner = get_runner(spec.name)
        execution = (self.execution if resume is None else
                     dataclasses.replace(self.execution, resume_from=resume))
        t0 = time.perf_counter()
        result = runner(self.world, spec, execution)
        result.wall_s = time.perf_counter() - t0
        result.method = spec.key
        # observability exports happen HERE, after the outcome exists —
        # host-side file I/O only, so tracing can never perturb the run
        # (the telemetry house rule)
        tr = execution.trace
        if tr is not None:
            from repro.telemetry import write_chrome_trace, write_events_jsonl

            if tr.events_jsonl:
                write_events_jsonl(result.trace, tr.events_jsonl)
            if tr.chrome_trace and result.timeline is not None:
                write_chrome_trace(result.timeline, tr.chrome_trace)
        return result

    def compare(self, methods: Sequence[Union[str, MethodSpec]]
                = DEFAULT_COMPARISON) -> CompareResult:
        """Run every method on the same world+seed+cost model.

        Bare names inherit all protocol knobs from ``self.method``, so a
        comparison differs ONLY in the method axis — which is what makes
        ``CompareResult.reduction("enfed", "dfl")`` reproduce the
        paper's time/energy reduction claims.

        Caveat: only EnFed executes ``world.mobility`` — the host-side
        baselines train their full static client set every round, and
        WARN when a mobility world is dropped, since EnFed-under-churn
        vs static baselines is not a same-world comparison.
        """
        base = MethodSpec.coerce(self.method)
        results: Dict[str, RunResult] = {}
        for m in methods:
            spec = MethodSpec.coerce(m, like=base)
            if spec.key in results:
                raise ValueError(
                    f"duplicate method key {spec.key!r} in compare() "
                    "(set MethodSpec.label to disambiguate)")
            results[spec.key] = self.run(spec)
        return CompareResult(results=results)

    @staticmethod
    def available_methods() -> tuple:
        return method_names()
