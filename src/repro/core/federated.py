"""High-level federated learners.

* :class:`SupervisedTask` — jit-compiled local fit/evaluate for the
  paper's classifiers (Adam, categorical cross-entropy, paper Table III).
* :class:`CFLLearner`, :class:`DFLLearner` — the paper's baselines at
  fleet scale (a virtual server for CFL; mesh/ring gossip for DFL), with
  eq. (4)-(7) cost reports for the *requesting* device.
* :func:`cloud_only_baseline` — the no-FL system of §IV-G.
* :class:`FederatedTrainer` — jit-native client-stacked trainer (params
  carry a leading client axis, topologies are mixing matrices) used to
  federate the architecture zoo; shards clients over the mesh data axis.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, schedule, topology
from repro.core.energy import CostModel, EnergyReport, update_wire_bytes
from repro.models.classifiers import (accuracy as _accuracy,
                                      masked_cross_entropy_loss)
from repro.optim import adam, apply_updates
from repro.utils.tree import tree_size, tree_bytes, tree_where


# ---------------------------------------------------------------------------
# supervised task wrapper (paper's LSTM / MLP classifiers)
# ---------------------------------------------------------------------------


class SupervisedTask:
    def __init__(self, model, lr: float = 1e-3, batch_size_hint: int = 32):
        self.model = model
        self.lr = lr
        self._opt = adam(lr)
        self._fit_step = jax.jit(self._step)
        self._eval = jax.jit(lambda p, x, y: _accuracy(self.model.forward(p, x), y))

    def init(self, seed: int = 0):
        return self.model.init(jax.random.PRNGKey(seed))

    def _step(self, params, opt_state, xb, yb, wb):
        """One masked Adam step — the EXACT math both engines run.

        ``wb`` is the per-sample weight row from the derived schedule
        (``repro.core.schedule``); a step whose weights are all zero is a
        no-op (the fleet engine's padded lanes hit this path).
        """
        def loss_fn(p):
            return masked_cross_entropy_loss(self.model.forward(p, xb), yb, wb)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, new_opt = self._opt.update(grads, opt_state, params)
        new_params = apply_updates(params, updates)
        take = jnp.sum(wb) > 0
        return (tree_where(take, new_params, params),
                tree_where(take, new_opt, opt_state),
                jnp.where(take, loss, 0.0))

    def fit(self, params, data, epochs: int, batch_size: int, seed: int = 0):
        """Epochs of Adam over shuffled minibatches. Returns (params, losses).

        Batches come from the counter-based derived schedule
        (``repro.core.schedule.minibatch_plan``), the same derivation the
        fleet engine evaluates inside its compiled round loop — so the
        two engines see identical batches by construction.  Shards
        smaller than one batch run as a single padded step whose padding
        carries zero weight.
        """
        x, y = data
        idx, w = schedule.minibatch_plan(seed, epochs=epochs, n=len(x),
                                         batch=batch_size)
        idx, w = np.asarray(idx), np.asarray(w)
        steps = idx.shape[1]
        opt_state = self._opt.init(params)
        losses = []
        for e in range(epochs):
            ep_loss = 0.0
            for s in range(steps):
                sel = idx[e, s]
                params, opt_state, loss = self._fit_step(
                    params, opt_state, x[sel], y[sel], w[e, s])
                ep_loss += float(loss)
            losses.append(ep_loss / steps)
        return params, losses

    def evaluate(self, params, data) -> float:
        x, y = data
        return float(self._eval(params, x, y))


# ---------------------------------------------------------------------------
# baselines: CFL and DFL at fleet scale
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BaselineResult:
    accuracy: float
    rounds: int
    report: EnergyReport
    history: Dict[str, List[float]]
    params: object = None

    @property
    def history_raw(self) -> Dict[str, List[float]]:
        """Alias for ``history`` — baseline traces are not deprecated,
        but the alias keeps call sites uniform with SessionResult/
        RunResult, whose raw access goes through ``history_raw``."""
        return self.history


def _as_enfed_config(target_accuracy: float, max_rounds: int, epochs: int,
                     batch_size: int, seed: int):
    """Legacy baseline kwargs -> the shared EnFedConfig surface."""
    from repro.core.rounds import EnFedConfig

    return EnFedConfig(desired_accuracy=target_accuracy, max_rounds=max_rounds,
                       epochs=epochs, batch_size=batch_size, seed=seed)


class CFLLearner:
    """Centralized FedAvg: virtual server, all clients train every round.

    The primary entrypoint is :meth:`run_config`, which consumes the same
    :class:`repro.core.rounds.EnFedConfig` fields as EnFed itself
    (``desired_accuracy``, ``max_rounds``, ``epochs``, ``batch_size``,
    ``seed``) and the shared :class:`CostModel` — the discipline that
    makes the paper's EnFed-vs-CFL comparison one call on one world
    (``repro.api.Experiment.compare``).
    """

    def __init__(self, task: SupervisedTask, client_data: Sequence, requester_test,
                 cost_model: Optional[CostModel] = None):
        self.task = task
        self.client_data = list(client_data)
        self.requester_test = requester_test
        self.cost = cost_model or CostModel()

    def run_config(self, cfg) -> BaselineResult:
        """Run the baseline under an :class:`EnFedConfig`'s knobs."""
        params = self.task.init(cfg.seed)
        history = {"accuracy": [], "loss": []}
        measured = 0.0
        rounds = 0
        for r in range(cfg.max_rounds):
            updates, weights = [], []
            for ci, data in enumerate(self.client_data):
                t0 = time.perf_counter()
                p_c, losses = self.task.fit(params, data, cfg.epochs,
                                            cfg.batch_size,
                                            seed=cfg.seed + 31 * r + ci)
                dt = time.perf_counter() - t0
                if ci == 0:  # client 0 is "the requesting device"
                    measured += dt
                updates.append(p_c)
                weights.append(len(data[0]))
            params = aggregation.fedavg(updates, weights)
            acc = self.task.evaluate(params, self.requester_test)
            rounds = r + 1
            history["accuracy"].append(acc)
            if acc >= cfg.desired_accuracy:
                break
        # model_bytes through the shared wire helper: the compress knob
        # prices the baseline's transport exactly like EnFed's, so a
        # compare() row reflects compression in every method's report.
        # Cost-domain only: the baseline still trains/aggregates fp32
        # (no quantization noise in its params), like the fleet engine
        # models AES in the cost domain — a compressed-vs-compressed
        # accuracy comparison is EnFed-vs-EnFed, not EnFed-vs-baseline
        report = self.cost.cfl_session(
            rounds=rounds, num_params=tree_size(params),
            model_bytes=update_wire_bytes(
                tree_size(params), encrypt=False,
                compress=getattr(cfg, "compress", None),
                raw_bytes=tree_bytes(params)),
            num_samples=len(self.client_data[0][0]), epochs=cfg.epochs,
            measured_local_time=measured)
        return BaselineResult(accuracy=history["accuracy"][-1], rounds=rounds,
                              report=report, history=history, params=params)

    def run(self, *, target_accuracy: float, max_rounds: int, epochs: int,
            batch_size: int, seed: int = 0) -> BaselineResult:
        """Deprecated shim: private-kwarg form of :meth:`run_config`.
        Prefer ``repro.api.Experiment(world, method="cfl").run()``."""
        warnings.warn(
            "CFLLearner.run is deprecated; use CFLLearner.run_config "
            "(shared EnFedConfig surface) or repro.api.Experiment(world, "
            "method='cfl').run()", DeprecationWarning, stacklevel=2)
        return self.run_config(_as_enfed_config(target_accuracy, max_rounds,
                                                epochs, batch_size, seed))


class DFLLearner:
    """Decentralized FL over a mesh or ring topology (paper's DFL baseline).

    Like :class:`CFLLearner`, the primary entrypoint is
    :meth:`run_config` on the shared EnFedConfig surface; ``run`` is the
    deprecated private-kwarg shim.
    """

    def __init__(self, task: SupervisedTask, client_data: Sequence, requester_test,
                 topology_kind: str = "mesh", cost_model: Optional[CostModel] = None):
        assert topology_kind in ("mesh", "ring")
        self.task = task
        self.client_data = list(client_data)
        self.requester_test = requester_test
        self.kind = topology_kind
        self.cost = cost_model or CostModel()

    def run_config(self, cfg) -> BaselineResult:
        """Run the baseline under an :class:`EnFedConfig`'s knobs."""
        n = len(self.client_data)
        node_params = [self.task.init(cfg.seed + i) for i in range(n)]
        strategy = topology.AggregationStrategy(
            kind="dfl_mesh" if self.kind == "mesh" else "dfl_ring")
        M = topology.group_mixing_matrix(n, strategy)
        history = {"accuracy": []}
        measured = 0.0
        rounds = 0
        for r in range(cfg.max_rounds):
            # local training at every node
            for i, data in enumerate(self.client_data):
                t0 = time.perf_counter()
                node_params[i], _ = self.task.fit(node_params[i], data,
                                                  cfg.epochs, cfg.batch_size,
                                                  seed=cfg.seed + 77 * r + i)
                if i == 0:
                    measured += time.perf_counter() - t0
            # gossip/mix according to topology
            stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *node_params)
            mixed = topology.apply_mixing(stacked, M)
            node_params = [jax.tree_util.tree_map(lambda x: x[i], mixed) for i in range(n)]
            acc = self.task.evaluate(node_params[0], self.requester_test)
            rounds = r + 1
            history["accuracy"].append(acc)
            if acc >= cfg.desired_accuracy:
                break
        p0 = node_params[0]
        report = self.cost.dfl_session(
            rounds=rounds, n_peers=n - 1, num_params=tree_size(p0),
            model_bytes=update_wire_bytes(
                tree_size(p0), encrypt=False,
                compress=getattr(cfg, "compress", None),
                raw_bytes=tree_bytes(p0)),
            num_samples=len(self.client_data[0][0]),
            epochs=cfg.epochs, topology=self.kind, measured_local_time=measured)
        return BaselineResult(accuracy=history["accuracy"][-1], rounds=rounds,
                              report=report, history=history, params=p0)

    def run(self, *, target_accuracy: float, max_rounds: int, epochs: int,
            batch_size: int, seed: int = 0) -> BaselineResult:
        """Deprecated shim: private-kwarg form of :meth:`run_config`.
        Prefer ``repro.api.Experiment(world, method="dfl").run()``."""
        warnings.warn(
            "DFLLearner.run is deprecated; use DFLLearner.run_config "
            "(shared EnFedConfig surface) or repro.api.Experiment(world, "
            "method='dfl').run()", DeprecationWarning, stacklevel=2)
        return self.run_config(_as_enfed_config(target_accuracy, max_rounds,
                                                epochs, batch_size, seed))


def cloud_only_config(task: SupervisedTask, pooled_train, requester_test, cfg,
                      cost_model: Optional[CostModel] = None) -> BaselineResult:
    """§IV-G no-FL baseline on the shared EnFedConfig surface: the user
    ships raw data to the cloud, the cloud trains, the result comes back.

    The device-side :class:`EnergyReport` comes from
    :meth:`CostModel.cloud_session` (upload tx + waiting rx energy, zero
    on-device compute); ``report.t_train`` is the end-to-end response
    time the paper plots — WAN upload + measured cloud training walltime
    + the result round trip.
    """
    cost = cost_model or CostModel()
    params = task.init(cfg.seed)
    t0 = time.perf_counter()
    params, _ = task.fit(params, pooled_train, cfg.epochs, cfg.batch_size,
                         seed=cfg.seed)
    t_cloud_train = time.perf_counter() - t0
    acc = task.evaluate(params, requester_test)
    x, _y = pooled_train
    report = cost.cloud_session(data_bytes=int(np.asarray(x).nbytes),
                                cloud_train_s=t_cloud_train)
    return BaselineResult(accuracy=acc, rounds=1, report=report,
                          history={"accuracy": [acc]}, params=params)


def cloud_only_baseline(task: SupervisedTask, pooled_train, requester_test, *,
                        epochs: int, batch_size: int,
                        cost_model: Optional[CostModel] = None, seed: int = 0):
    """Deprecated shim over :func:`cloud_only_config`.  Prefer
    ``repro.api.Experiment(world, method="cloud").run()``.
    Returns (accuracy, response_time_s, params)."""
    res = cloud_only_config(
        task, pooled_train, requester_test,
        _as_enfed_config(0.0, 1, epochs, batch_size, seed),
        cost_model=cost_model)
    return res.accuracy, res.report.t_train, res.params


# ---------------------------------------------------------------------------
# client-stacked federated trainer for the architecture zoo
# ---------------------------------------------------------------------------


class FederatedTrainer:
    """Jit-native FL over a stacked client axis.

    ``params`` leaves have shape (C, ...) and are sharded over the mesh
    data axis; each round every client runs ``local_steps`` of SGD/Adam on
    its own batch shard (via vmap), then the topology mixing matrix is
    applied (CFL / DFL / EnFed neighborhoods with participation masks).
    This gives exact per-client FL semantics inside a single jit program.
    """

    def __init__(self, loss_fn: Callable, num_clients: int,
                 strategy: topology.AggregationStrategy, lr: float = 1e-3,
                 local_steps: int = 1):
        self.loss_fn = loss_fn            # (params, batch) -> scalar loss
        self.num_clients = num_clients
        self.strategy = strategy
        self.opt = adam(lr)
        self.local_steps = local_steps

    def init(self, params_one, opt_state_one=None):
        C = self.num_clients
        stack = lambda t: jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (C,) + x.shape).copy(), t)
        opt_state_one = opt_state_one if opt_state_one is not None else self.opt.init(params_one)
        return stack(params_one), jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (C,) + x.shape).copy(), opt_state_one)

    def round(self, stacked_params, stacked_opt, batches, mask=None):
        """batches: pytree with leading (C, local_steps, ...) axes."""

        def client_update(params, opt_state, client_batches):
            def one_step(carry, batch):
                p, s = carry
                loss, grads = jax.value_and_grad(self.loss_fn)(p, batch)
                upd, s = self.opt.update(grads, s, p)
                return (apply_updates(p, upd), s), loss

            (params, opt_state), losses = jax.lax.scan(
                one_step, (params, opt_state), client_batches)
            return params, opt_state, jnp.mean(losses)

        new_params, new_opt, losses = jax.vmap(client_update)(
            stacked_params, stacked_opt, batches)
        M = topology.mixing_matrix_jnp(self.num_clients, self.strategy, mask)
        mixed = topology.apply_mixing(new_params, M)
        return mixed, new_opt, losses
