"""Fleet-engine scaling benchmark: rounds/s and simulated energy as the
number of concurrent requester sessions grows 8 -> 512.

For each fleet size R the jit fleet engine (``repro.core.fleet``) runs
all R sessions as ONE compiled program; the loop engine
(``EnFedSession.run``) is timed on a few sessions and extrapolated to
the same R (its cost is linear in sessions by construction — one Python
round loop each).  The headline metric is session-rounds/s; the
crossover (fleet engine beating the loop engine's per-session
wall-clock) lands well below R=32 on CPU.

  PYTHONPATH=src python -m benchmarks.fleet_bench [--sizes 8,32,128,512]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import (EnFedConfig, EnFedSession, RequesterSpec,
                        SupervisedTask, make_fleet, run_fleet)
from repro.data import CaloriesDatasetConfig, dirichlet_partition, make_calories_tabular
from repro.models import MLPClassifier, MLPClassifierConfig

BATCH = 32
N_CONTRIB = 3
LOOP_SAMPLE_SESSIONS = 3   # loop engine timed on this many, extrapolated


def _build_problem(seed: int = 0):
    """Shared task + contributor population for every requester."""
    x, y = make_calories_tabular(CaloriesDatasetConfig(num_samples=1200, seed=seed))
    task = SupervisedTask(MLPClassifier(MLPClassifierConfig(8, (32,), 5)), lr=3e-3)
    parts = dirichlet_partition(y, num_clients=N_CONTRIB + 1, alpha=100.0, seed=seed)
    shards = [(x[p], y[p]) for p in parts]
    fleet = make_fleet(N_CONTRIB, seed=seed + 1, p_has_model=1.0)
    states = {}
    for i, dev in enumerate(fleet):
        dev.reservation_price = 0.4
        p = task.init(seed=10 + i)
        p, _ = task.fit(p, shards[i + 1], epochs=1, batch_size=BATCH, seed=i)
        states[dev.device_id] = {"params": p, "data": shards[i + 1]}
    own_x, own_y = shards[0]
    n = int(len(own_x) * 0.8)
    return task, fleet, states, (own_x[:n], own_y[:n]), (own_x[n:], own_y[n:])


def _make_specs(R: int, own_train, own_test, fleet, states, seed: int = 0):
    rng = np.random.default_rng(seed)
    specs = []
    for _ in range(R):
        sel = rng.permutation(len(own_train[0]))[:4 * BATCH]
        specs.append(RequesterSpec(
            own_train=(own_train[0][sel], own_train[1][sel]),
            own_test=own_test, neighborhood=fleet, contributor_states=states))
    return specs


def run(verbose: bool = True, sizes=(8, 32, 128, 512)):
    task, fleet, states, own_train, own_test = _build_problem()
    cfg = EnFedConfig(desired_accuracy=0.999, max_rounds=3, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1)

    # loop-engine baseline: seconds per session, measured once (cost is
    # per-session linear: one Python dispatch chain per session)
    loop_specs = _make_specs(LOOP_SAMPLE_SESSIONS, own_train, own_test, fleet, states)
    t0 = time.perf_counter()
    loop_rounds = 0
    for spec in loop_specs:
        res = EnFedSession(task, spec.own_train, spec.own_test, fleet,
                           {k: dict(v) for k, v in states.items()}, cfg).run()
        loop_rounds += res.rounds
    loop_s_per_session = (time.perf_counter() - t0) / LOOP_SAMPLE_SESSIONS

    rows = []
    for R in sizes:
        specs = _make_specs(R, own_train, own_test, fleet, states)
        t0 = time.perf_counter()
        result = run_fleet(task, specs, cfg)
        wall = time.perf_counter() - t0          # includes jit compile
        t0 = time.perf_counter()
        result = run_fleet(task, specs, cfg)     # steady-state (cached jit)
        wall_warm = time.perf_counter() - t0
        total_rounds = int(result.rounds.sum())
        rps = total_rounds / wall_warm
        loop_equiv_s = loop_s_per_session * R
        rows.append((f"fleet/R={R}", wall_warm * 1e6 / R,
                     f"rounds/s={rps:.1f} E={result.total_energy_j:.1f}J "
                     f"loop_equiv={loop_equiv_s:.1f}s speedup={loop_equiv_s / wall_warm:.1f}x"))
        if verbose:
            print(f"[fleet R={R:4d}] warm {wall_warm:6.2f}s (cold {wall:6.2f}s) | "
                  f"{total_rounds} session-rounds -> {rps:7.1f} rounds/s | "
                  f"simulated E={result.total_energy_j:9.1f} J | "
                  f"loop engine would need ~{loop_equiv_s:6.1f}s "
                  f"({loop_equiv_s / wall_warm:5.1f}x slower)")
    if verbose:
        print(f"[loop baseline] {loop_s_per_session:.2f} s/session "
              f"({LOOP_SAMPLE_SESSIONS} sessions measured)")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="8,32,128,512",
                    help="comma list of fleet sizes to sweep")
    args = ap.parse_args()
    run(sizes=tuple(int(s) for s in args.sizes.split(",")))


if __name__ == "__main__":
    main()
