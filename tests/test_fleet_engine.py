"""Loop-engine / fleet-engine parity (Algorithm 1, two executions).

The loop engine (`repro.core.rounds.EnFedSession`) is the readable
reference oracle; the fleet engine (`repro.core.fleet.run_fleet`)
compiles many concurrent requester sessions into one jit program.  These
tests assert the fleet engine reproduces the oracle exactly: aggregated
params (allclose), round counts, stop reasons, and per-round battery
trajectories — across aggregation strategies, encrypt on/off, and all
three stop conditions.
"""

import copy
import dataclasses

import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.core import (AggregationStrategy, EnFedConfig, EnFedSession,
                        MobilityConfig, RequesterSpec, SupervisedTask,
                        make_fleet, run_fleet)
from repro.core.battery import BatteryState
from repro.data import CaloriesDatasetConfig, dirichlet_partition, make_calories_tabular
from repro.models import MLPClassifier, MLPClassifierConfig

BATCH = 16


def _build(n_contrib=3, n_samples=600, seed=0):
    """One tiny HAR-style problem: shared task, requester shard + test
    split, a contributor fleet with pre-trained states."""
    x, y = make_calories_tabular(CaloriesDatasetConfig(num_samples=n_samples))
    task = SupervisedTask(MLPClassifier(MLPClassifierConfig(8, (16,), 5)), lr=3e-3)
    parts = dirichlet_partition(y, num_clients=n_contrib + 1, alpha=100.0, seed=seed)
    shards = [(x[p], y[p]) for p in parts]
    own_x, own_y = shards[0]
    n = int(len(own_x) * 0.8)
    own_train, own_test = (own_x[:n], own_y[:n]), (own_x[n:], own_y[n:])
    fleet = make_fleet(n_contrib, seed=1, p_has_model=1.0)
    states = {}
    for i, dev in enumerate(fleet):
        dev.reservation_price = 0.4
        p = task.init(seed=10 + i)
        p, _ = task.fit(p, shards[i + 1], epochs=1, batch_size=BATCH, seed=i)
        states[dev.device_id] = {"params": p, "data": shards[i + 1]}
    return task, own_train, own_test, fleet, states


@pytest.fixture(scope="module")
def problem():
    return _build()


def _run_both(problem, cfg, battery_kw=None):
    """Run the same session through both engines on fresh copies of the
    mutable state (contributor params, battery)."""
    task, own_train, own_test, fleet, states = problem
    battery_kw = battery_kw or {}
    loop = EnFedSession(task, own_train, own_test, fleet, copy.deepcopy(states),
                        cfg, battery=BatteryState(**battery_kw)).run()
    spec = RequesterSpec(own_train=own_train, own_test=own_test, neighborhood=fleet,
                         contributor_states=copy.deepcopy(states),
                         battery=BatteryState(**battery_kw))
    fleet_res = run_fleet(task, [spec], cfg)
    return loop, fleet_res.sessions[0]


def _assert_parity(loop, fl):
    assert fl.rounds == loop.rounds
    assert fl.stop_reason == loop.stop_reason
    assert fl.n_contributors == loop.n_contributors
    np.testing.assert_allclose(fl.history_raw["accuracy"], loop.history_raw["accuracy"],
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(fl.history_raw["battery"], loop.history_raw["battery"],
                               rtol=1e-5, atol=1e-6)
    lv, _ = ravel_pytree(loop.params)
    fv, _ = ravel_pytree(fl.params)
    np.testing.assert_allclose(np.asarray(fv), np.asarray(lv), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# parity across strategies x encryption
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy,encrypt", [
    (None, True),                                            # paper default
    (AggregationStrategy(kind="dfl_mesh"), False),           # full mesh
    (AggregationStrategy(kind="dfl_ring"), False),           # ring neighbours
    (AggregationStrategy(kind="cfl"), True),                 # virtual server
    (AggregationStrategy(kind="enfed", neighborhood_size=2), True),
], ids=["default-enc", "mesh-plain", "ring-plain", "cfl-enc", "enfed2-enc"])
def test_fleet_matches_loop_across_strategies(problem, strategy, encrypt):
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=2, epochs=2,
                      batch_size=BATCH, encrypt=encrypt,
                      contributor_refresh_epochs=1, strategy=strategy)
    loop, fl = _run_both(problem, cfg)
    assert loop.stop_reason == "max_rounds"
    _assert_parity(loop, fl)


# ---------------------------------------------------------------------------
# stop conditions
# ---------------------------------------------------------------------------


def test_fleet_stops_on_accuracy_like_loop(problem):
    cfg = EnFedConfig(desired_accuracy=0.05, max_rounds=4, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=0)
    loop, fl = _run_both(problem, cfg)
    assert loop.stop_reason == "accuracy_reached" and loop.rounds == 1
    _assert_parity(loop, fl)


def test_fleet_stops_on_battery_like_loop(problem):
    # tiny battery: one round's energy drains it below the threshold
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=4, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=0)
    loop, fl = _run_both(problem, cfg, battery_kw=dict(capacity_j=0.2, level=0.3))
    assert loop.stop_reason == "battery_low"
    _assert_parity(loop, fl)


def test_fleet_writes_back_refreshed_contributors(problem):
    """Side-effect parity: after a session with contributor refresh, both
    engines leave the SAME refreshed params in contributor_states."""
    task, own_train, own_test, fleet, states = problem
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=2, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1)
    loop_states = copy.deepcopy(states)
    EnFedSession(task, own_train, own_test, fleet, loop_states, cfg).run()
    fleet_states = copy.deepcopy(states)
    run_fleet(task, [RequesterSpec(own_train, own_test, fleet, fleet_states)], cfg)
    for dev_id, st in loop_states.items():
        before, _ = ravel_pytree(states[dev_id]["params"])
        lv, _ = ravel_pytree(st["params"])
        fv, _ = ravel_pytree(fleet_states[dev_id]["params"])
        assert not np.allclose(np.asarray(lv), np.asarray(before)), "refresh ran"
        np.testing.assert_allclose(np.asarray(fv), np.asarray(lv),
                                   rtol=1e-4, atol=1e-5)


def test_session_fleet_engine_flag(problem):
    """EnFedSession.run(engine='fleet') routes through the fleet engine."""
    task, own_train, own_test, fleet, states = problem
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=1, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=0)
    sess = EnFedSession(task, own_train, own_test, fleet, copy.deepcopy(states), cfg)
    res = sess.run(engine="fleet")
    ref = EnFedSession(task, own_train, own_test, fleet, copy.deepcopy(states), cfg).run()
    assert res.rounds == ref.rounds and res.stop_reason == ref.stop_reason
    np.testing.assert_allclose(res.accuracy, ref.accuracy, rtol=1e-5)
    assert sess.battery.level == pytest.approx(ref.battery.level, rel=1e-5)
    with pytest.raises(ValueError):
        sess.run(engine="warp")


# ---------------------------------------------------------------------------
# many concurrent sessions in one program
# ---------------------------------------------------------------------------


def test_fleet_runs_64_concurrent_sessions(problem):
    """>= 64 requester sessions advance in ONE jit program, and lanes
    match per-session loop-engine runs spot-checked at both ends."""
    task, own_train, own_test, fleet, states = problem
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=1, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=0)
    R = 64
    rng = np.random.default_rng(0)
    specs = []
    for i in range(R):
        # distinct shards per requester: rotate + subsample the own shard
        sel = rng.permutation(len(own_train[0]))[:max(BATCH * 2, len(own_train[0]) // 2)]
        specs.append(RequesterSpec(
            own_train=(own_train[0][sel], own_train[1][sel]),
            own_test=own_test, neighborhood=fleet,
            contributor_states=copy.deepcopy(states), battery=BatteryState()))
    result = run_fleet(task, specs, cfg)
    assert len(result.sessions) == R
    assert result.rounds.shape == (R,) and (result.rounds == 1).all()
    assert result.history_raw["accuracy"].shape == (cfg.max_rounds, R)
    assert result.total_energy_j > 0

    for lane in (0, R - 1):
        loop = EnFedSession(task, (specs[lane].own_train[0], specs[lane].own_train[1]),
                            own_test, fleet, copy.deepcopy(states), cfg).run()
        fl = result.sessions[lane]
        assert fl.rounds == loop.rounds and fl.stop_reason == loop.stop_reason
        np.testing.assert_allclose(fl.history_raw["accuracy"], loop.history_raw["accuracy"],
                                   rtol=1e-5, atol=1e-6)
        lv, _ = ravel_pytree(loop.params)
        fv, _ = ravel_pytree(fl.params)
        np.testing.assert_allclose(np.asarray(fv), np.asarray(lv),
                                   rtol=1e-4, atol=1e-5)


def test_fleet_rejects_empty():
    with pytest.raises(ValueError):
        run_fleet(None, [])


def test_shard_staging_dedups_equal_content(problem):
    """Contributor shards are staged ONCE per unique (device, content)
    pair even when every RequesterSpec deep-copies the states dict (the
    standard usage pattern) — object identity must not defeat the dedup."""
    task, own_train, own_test, fleet, states = problem
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=1, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1)
    R = 4
    specs = [RequesterSpec(own_train, own_test, fleet, copy.deepcopy(states))
             for _ in range(R)]
    res = run_fleet(task, specs, cfg)
    assert res.staged_shard_bytes_dense > 0
    # R requesters sharing one 3-device population: ~R x fewer bytes
    assert res.staged_shard_bytes < res.staged_shard_bytes_dense / (R - 1)


def test_fleet_sub_batch_shard_matches_loop():
    """A requester shard smaller than one batch runs in the fleet engine
    as a single padded+masked step — and matches the loop engine, which
    takes the same padded step through the shared derived schedule."""
    task, own_train, own_test, fleet, states = _build(n_contrib=2, n_samples=300)
    tiny = (own_train[0][:BATCH - 4], own_train[1][:BATCH - 4])  # < one batch
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=2, epochs=2,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1)
    loop = EnFedSession(task, tiny, own_test, fleet, copy.deepcopy(states),
                        cfg).run()
    fl = run_fleet(task, [RequesterSpec(tiny, own_test, fleet,
                                        copy.deepcopy(states))], cfg).sessions[0]
    _assert_parity(loop, fl)


def test_fleet_mixed_sub_batch_and_full_lanes():
    """Sub-batch and full-batch requesters coexist in ONE program; each
    lane matches its own loop-engine run."""
    task, own_train, own_test, fleet, states = _build(n_contrib=2, n_samples=300)
    shards = [(own_train[0][:BATCH // 2], own_train[1][:BATCH // 2]),
              (own_train[0], own_train[1])]
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=2, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=0)
    specs = [RequesterSpec(sh, own_test, fleet, copy.deepcopy(states))
             for sh in shards]
    result = run_fleet(task, specs, cfg)
    for lane, sh in enumerate(shards):
        loop = EnFedSession(task, sh, own_test, fleet,
                            copy.deepcopy(states), cfg).run()
        _assert_parity(loop, result.sessions[lane])


# ---------------------------------------------------------------------------
# churn: the opportunistic world (repro.core.mobility) in both engines
# ---------------------------------------------------------------------------


def _assert_churn_parity(loop, fl):
    """Static parity PLUS the mobility surface: per-round membership
    masks and member counts must be bit-identical."""
    _assert_parity(loop, fl)
    np.testing.assert_array_equal(np.array(loop.history_raw["member_mask"]),
                                  np.array(fl.history_raw["member_mask"]))
    assert loop.history_raw["members"] == fl.history_raw["members"]


@pytest.mark.parametrize("mob_kw,cfg_kw", [
    # devices wander in/out of a 110 m radio range every 2 rounds
    (dict(radio_range_m=110.0, leg_rounds=2, seed=3), {}),
    # sparse world: rounds with an EMPTY neighborhood (requester trains alone)
    (dict(radio_range_m=55.0, leg_rounds=2, seed=3), {}),
    # encrypted transport while churning
    (dict(radio_range_m=110.0, leg_rounds=2, seed=3), dict(encrypt=True)),
    # battery-floor releases drive the churn (static positions, tiny
    # contributor batteries): members drain out and are replaced
    (dict(mode="static", radio_range_m=500.0, seed=3,
          contributor_capacity_j=0.004, battery_floor=0.3), {}),
], ids=["waypoint-churn", "empty-rounds", "churn-encrypted", "floor-release"])
def test_fleet_matches_loop_under_mobility(problem, mob_kw, cfg_kw):
    cfg_base = dict(desired_accuracy=0.99, max_rounds=6, epochs=1,
                    batch_size=BATCH, encrypt=False, n_max=2,
                    contributor_refresh_epochs=1,
                    mobility=MobilityConfig(**mob_kw))
    cfg_base.update(cfg_kw)
    loop, fl = _run_both(problem, EnFedConfig(**cfg_base))
    _assert_churn_parity(loop, fl)


def test_mobility_renegotiation_actually_churns(problem):
    """The parity gate must exercise RE-NEGOTIATION, not a static mask:
    this config provably changes membership mid-session in both engines."""
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=6, epochs=1,
                      batch_size=BATCH, encrypt=False, n_max=2,
                      contributor_refresh_epochs=1,
                      mobility=MobilityConfig(radio_range_m=55.0,
                                              leg_rounds=2, seed=3))
    loop, fl = _run_both(problem, cfg)
    _assert_churn_parity(loop, fl)
    masks = np.array(loop.history_raw["member_mask"])
    assert (masks != masks[0]).any(), "membership must change mid-session"


def test_mobility_strategies_follow_dynamic_members(problem):
    """Aggregation strategies compose with churn: the enfed/ring round
    weights are derived from the CURRENT membership each round."""
    for strategy in (AggregationStrategy(kind="enfed", neighborhood_size=2),
                     AggregationStrategy(kind="dfl_ring")):
        cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=4, epochs=1,
                          batch_size=BATCH, encrypt=False, n_max=3,
                          contributor_refresh_epochs=1, strategy=strategy,
                          mobility=MobilityConfig(radio_range_m=130.0,
                                                  leg_rounds=2, seed=7))
        loop, fl = _run_both(problem, cfg)
        _assert_churn_parity(loop, fl)


def test_mobility_battery_stop_parity(problem):
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=6, epochs=1,
                      batch_size=BATCH, encrypt=False, n_max=3,
                      contributor_refresh_epochs=1,
                      mobility=MobilityConfig(radio_range_m=110.0,
                                              leg_rounds=2, seed=3))
    loop, fl = _run_both(problem, cfg, battery_kw=dict(capacity_j=0.2, level=0.3))
    assert loop.stop_reason == "battery_low"
    _assert_churn_parity(loop, fl)


def test_mobility_multi_lane_fleet_matches_per_lane_loops(problem):
    """Concurrent churning sessions in ONE program: fleet lane i walks as
    requester_id + i, so each lane must match a loop run configured with
    that requester id."""
    task, own_train, own_test, fleet, states = problem
    mob = MobilityConfig(radio_range_m=110.0, leg_rounds=2, seed=3)
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=4, epochs=1,
                      batch_size=BATCH, encrypt=False, n_max=2,
                      contributor_refresh_epochs=1, mobility=mob)
    R = 3
    specs = [RequesterSpec(own_train, own_test, fleet, copy.deepcopy(states))
             for _ in range(R)]
    result = run_fleet(task, specs, cfg)
    saw_different_worlds = False
    ref_members = result.sessions[0].history_raw["members"]
    for lane in range(R):
        lane_cfg = dataclasses.replace(
            cfg, mobility=dataclasses.replace(
                mob, requester_id=mob.requester_id + lane))
        loop = EnFedSession(task, own_train, own_test, fleet,
                            copy.deepcopy(states), lane_cfg).run()
        _assert_churn_parity(loop, result.sessions[lane])
        if result.sessions[lane].history_raw["members"] != ref_members:
            saw_different_worlds = True
    assert saw_different_worlds, "lanes should see distinct neighborhoods"


def test_mobility_writes_back_member_refreshed_contributors(problem):
    """Refresh write-back under churn: only devices that were members
    while the session ran get trained; both engines leave identical
    contributor params behind."""
    task, own_train, own_test, fleet, states = problem
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=4, epochs=1,
                      batch_size=BATCH, encrypt=False, n_max=2,
                      contributor_refresh_epochs=1,
                      mobility=MobilityConfig(radio_range_m=110.0,
                                              leg_rounds=2, seed=3))
    loop_states = copy.deepcopy(states)
    EnFedSession(task, own_train, own_test, fleet, loop_states, cfg).run()
    fleet_states = copy.deepcopy(states)
    run_fleet(task, [RequesterSpec(own_train, own_test, fleet, fleet_states)], cfg)
    for dev_id in states:
        lv, _ = ravel_pytree(loop_states[dev_id]["params"])
        fv, _ = ravel_pytree(fleet_states[dev_id]["params"])
        np.testing.assert_allclose(np.asarray(fv), np.asarray(lv),
                                   rtol=1e-4, atol=1e-5)


def test_session_fleet_engine_flag_with_mobility(problem):
    """EnFedSession.run(engine='fleet') carries cfg.mobility through."""
    task, own_train, own_test, fleet, states = problem
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=3, epochs=1,
                      batch_size=BATCH, encrypt=False, n_max=2,
                      contributor_refresh_epochs=0,
                      mobility=MobilityConfig(radio_range_m=110.0,
                                              leg_rounds=2, seed=3))
    res = EnFedSession(task, own_train, own_test, fleet,
                       copy.deepcopy(states), cfg).run(engine="fleet")
    ref = EnFedSession(task, own_train, own_test, fleet,
                       copy.deepcopy(states), cfg).run()
    _assert_churn_parity(ref, res)


# ---------------------------------------------------------------------------
# early exit: a converged fleet executes O(k), not O(max_rounds), bodies
# ---------------------------------------------------------------------------


def _baseline_client_data(own_train, fleet, states):
    """The roster both engines train for a baseline method: the
    requester's shard first, then each neighborhood device's shard."""
    return [own_train] + [states[dev.device_id]["data"] for dev in fleet]


def test_fleet_baseline_cfl_matches_loop(problem):
    """method="cfl" lanes of the fleet program reproduce the CFLLearner
    oracle: same accuracy trajectory, rounds, stop, aggregated params."""
    from repro.core.federated import CFLLearner

    task, own_train, own_test, fleet, states = problem
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=3, epochs=2,
                      batch_size=BATCH, seed=5)
    loop = CFLLearner(task, _baseline_client_data(own_train, fleet, states),
                      own_test).run_config(cfg)
    fl = run_fleet(task, [RequesterSpec(own_train, own_test, fleet,
                                        copy.deepcopy(states))],
                   cfg, method="cfl").sessions[0]
    assert fl.rounds == loop.rounds
    assert fl.battery is None
    assert fl.stop_reason == ("accuracy_reached"
                              if loop.accuracy >= cfg.desired_accuracy
                              else "max_rounds")
    np.testing.assert_allclose(fl.history_raw["accuracy"], loop.history_raw["accuracy"],
                               rtol=1e-5, atol=1e-6)
    lv, _ = ravel_pytree(loop.params)
    fv, _ = ravel_pytree(fl.params)
    np.testing.assert_allclose(np.asarray(fv), np.asarray(lv),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("topology", ["mesh", "ring"])
def test_fleet_baseline_dfl_matches_loop(problem, topology):
    """method="dfl" lanes (mesh AND ring gossip) reproduce DFLLearner."""
    from repro.core.federated import DFLLearner

    task, own_train, own_test, fleet, states = problem
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=3, epochs=1,
                      batch_size=BATCH, seed=5)
    loop = DFLLearner(task, _baseline_client_data(own_train, fleet, states),
                      own_test, topology).run_config(cfg)
    fl = run_fleet(task, [RequesterSpec(own_train, own_test, fleet,
                                        copy.deepcopy(states))],
                   cfg, method="dfl", dfl_topology=topology).sessions[0]
    assert fl.rounds == loop.rounds
    assert fl.battery is None
    np.testing.assert_allclose(fl.history_raw["accuracy"], loop.history_raw["accuracy"],
                               rtol=1e-5, atol=1e-6)
    lv, _ = ravel_pytree(loop.params)
    fv, _ = ravel_pytree(fl.params)
    np.testing.assert_allclose(np.asarray(fv), np.asarray(lv),
                               rtol=1e-4, atol=1e-5)


def test_fleet_baseline_multi_lane_matches_per_requester_loops(problem):
    """Several baseline sessions advance in ONE compiled program; each
    lane matches the loop oracle run on that lane's own roster."""
    from repro.core.federated import CFLLearner, DFLLearner

    task, own_train, own_test, fleet, states = problem
    half = (own_train[0][:len(own_train[0]) // 2],
            own_train[1][:len(own_train[1]) // 2])
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=2, epochs=1,
                      batch_size=BATCH, seed=2)
    specs = [RequesterSpec(sh, own_test, fleet, copy.deepcopy(states))
             for sh in (own_train, half)]
    for method, learner in (("cfl", CFLLearner),
                            ("dfl", lambda t, d, te: DFLLearner(t, d, te, "mesh"))):
        result = run_fleet(task, specs, cfg, method=method)
        assert len(result.sessions) == 2
        for lane, sh in enumerate((own_train, half)):
            loop = learner(task, _baseline_client_data(sh, fleet, states),
                           own_test).run_config(cfg)
            fl = result.sessions[lane]
            assert fl.rounds == loop.rounds
            np.testing.assert_allclose(fl.history_raw["accuracy"],
                                       loop.history_raw["accuracy"],
                                       rtol=1e-5, atol=1e-6)
            lv, _ = ravel_pytree(loop.params)
            fv, _ = ravel_pytree(fl.params)
            np.testing.assert_allclose(np.asarray(fv), np.asarray(lv),
                                       rtol=1e-4, atol=1e-5)


def test_fleet_baseline_rejects_unknown_method_and_topology(problem):
    task, own_train, own_test, fleet, states = problem
    cfg = EnFedConfig(max_rounds=1, epochs=1, batch_size=BATCH)
    spec = RequesterSpec(own_train, own_test, fleet, states)
    with pytest.raises(ValueError):
        run_fleet(task, [spec], cfg, method="fedprox")
    with pytest.raises(ValueError):
        run_fleet(task, [spec], cfg, method="dfl", dfl_topology="torus")


def test_fleet_early_exit_executes_o_k_round_bodies(problem):
    """Every session stops by round 1 (trivial accuracy target); with
    max_rounds=32 the program must execute only the first round chunk —
    asserted via the executed-body trace, which is written in place by
    the rounds that actually ran."""
    task, own_train, own_test, fleet, states = problem
    cfg = EnFedConfig(desired_accuracy=0.05, max_rounds=32, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1)
    result = run_fleet(task, [RequesterSpec(own_train, own_test, fleet,
                                            copy.deepcopy(states))],
                       cfg, round_chunk=4)
    assert (result.rounds == 1).all()
    assert (result.stop_codes == 1).all()  # protocol.STOP_ACCURACY
    body = result.history_raw["round_executed"]
    assert body.shape == (cfg.max_rounds,)
    # O(k): at most one chunk of bodies ran, nothing near max_rounds
    assert body.sum() <= 4
    assert body[0] == 1.0 and (body[4:] == 0.0).all()
    # per-lane active mask agrees: only round 0 had a live lane
    assert result.history_raw["executed"][0].all()
    assert (result.history_raw["executed"][1:] == 0.0).all()


def test_fleet_round_chunk_does_not_change_results(problem):
    """Parity across chunk sizes, including a chunk that overshoots
    max_rounds (the in-chunk lax.cond masks the overhang)."""
    task, own_train, own_test, fleet, states = problem
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=3, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1)
    results = [run_fleet(task, [RequesterSpec(own_train, own_test, fleet,
                                              copy.deepcopy(states))],
                         cfg, round_chunk=c) for c in (1, 2, 8)]
    ref = results[0].sessions[0]
    for res in results[1:]:
        fl = res.sessions[0]
        assert fl.rounds == ref.rounds and fl.stop_reason == ref.stop_reason
        np.testing.assert_allclose(fl.history_raw["accuracy"], ref.history_raw["accuracy"],
                                   rtol=1e-6)
        lv, _ = ravel_pytree(ref.params)
        fv, _ = ravel_pytree(fl.params)
        np.testing.assert_allclose(np.asarray(fv), np.asarray(lv), rtol=1e-6)
    with pytest.raises(ValueError):
        run_fleet(task, [RequesterSpec(own_train, own_test, fleet, states)],
                  cfg, round_chunk=0)
