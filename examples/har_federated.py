"""Full HAR comparison scenario: EnFed vs CFL vs DFL(mesh/ring) vs
cloud-only, on both paper datasets (calories->MLP, HARSense->LSTM) —
expressed entirely through the ``repro.api`` facade.

This is the experiment behind Tables IV/V/VII of the paper, at example
scale (the full benchmark lives in benchmarks/).  One ``WorldSpec`` is
built once; ``Experiment.compare`` runs every method on that SAME world,
seed, and cost model, which is what makes the printed reduction
percentages meaningful.

  PYTHONPATH=src python examples/har_federated.py [--dataset har|calories]
                                                  [--engine loop|fleet]
                                                  [--churn] [--faults]
                                                  [--cadence] [--byzantine]
                                                  [--compress int8]
                                                  [-v | -q]

Output goes through stdlib ``logging`` ("repro.example.har", stdout):
``-q`` keeps errors only, ``-v`` adds the per-run telemetry span
timings (``RunResult.timings``).

``--engine fleet`` runs the EnFed session through the jit-native fleet
engine (repro.core.fleet) instead of the Python round loop — same
protocol, same result (parity-tested), one compiled program; the
baselines are host-side either way.

``--churn`` turns on the opportunistic world (repro.core.mobility): the
neighbors walk random-waypoint trajectories, contracts are re-negotiated
every round as devices enter/leave radio range or hit their battery
floor, and the walkthrough prints the per-round membership so you can
watch the requester keep training while its neighborhood churns.

``--faults`` turns on the unreliable-link world (repro.core.faults):
links drop with bounded retries, exhausted links are zeroed out of the
round's aggregation (the session degrades gracefully instead of
stalling), and some deliveries arrive STALE — the contributor's
round-(r-1) wire image.  The walkthrough prints per-round drop/retry/
stale counts and the delivered set; the fault world is counter-based
(like mobility), so ``--engine loop`` and ``--engine fleet`` print the
identical weather.  Composes with ``--churn``: delivery then requires
both radio range AND a surviving link.

``--cadence`` breaks the lockstep round barrier (repro.core.cadence):
devices advance on their own counter-based duty cycles, so the
requester's round clock skips global event steps (idle steps priced via
``CostModel.idle_energy``) and slow contributors become STRAGGLERS whose
resident wire image is aggregated as-is.  The walkthrough prints each
round's global clock step, the idle steps burned since the previous
round, and the straggler set; the cadence world is counter-based, so
``--engine loop`` and ``--engine fleet`` print the identical clocks and
straggler deliveries.  Composes with ``--churn``/``--faults``.

``--byzantine`` turns on the adversarial world (repro.core.adversary):
30% of contributor links deliver a corrupted wire image each round (a
25x scale attack), and the session defends with ``robust="clip"`` —
per-coordinate norm clipping at the masked median norm
(repro.kernels.robust), its screening pass priced via
``CostModel.screening_energy``.  The walkthrough prints each round's
CORRUPTED set (which links the counter-based draws poisoned) and
CLIPPED set (which contributors the defense throttled); corruption is
counter-keyed like mobility/faults/cadence, so ``--engine loop`` and
``--engine fleet`` print the identical sets.  Composes with
``--churn``/``--faults``/``--cadence``.

``--compress int8`` adds an ``enfed-int8`` row to the compare table: the
same world and knobs with the transported updates (and the fleet
engine's round state) int8-compressed — ~4x fewer wire bytes into
eq. (4)-(7), so the table shows the transmission/crypto energy delta
compression buys on the same problem.
"""

import argparse
import dataclasses
import logging
import sys

import numpy as np

from repro.api import Experiment, ExecutionSpec, MethodSpec, WorldSpec
from repro.core import (AdversaryConfig, CadenceConfig, FaultConfig,
                        MobilityConfig, SupervisedTask, make_fleet)
from repro.core.cadence import tick_mask
from repro.data import (CaloriesDatasetConfig, HARDatasetConfig,
                        dirichlet_partition, make_calories_tabular,
                        make_har_windows)
from repro.models import (LSTMClassifier, LSTMClassifierConfig, MLPClassifier,
                          MLPClassifierConfig)

log = logging.getLogger("repro.example.har")


def _setup_logging(verbosity: int) -> None:
    """The walkthrough/table output IS the example's product, so it logs
    to stdout at INFO; ``-q`` silences it (errors only), ``--verbose``
    adds debug detail."""
    level = (logging.ERROR if verbosity < 0
             else logging.DEBUG if verbosity > 0 else logging.INFO)
    handler = logging.StreamHandler(sys.stdout)
    handler.setFormatter(logging.Formatter("%(message)s"))
    log.handlers[:] = [handler]
    log.setLevel(level)
    log.propagate = False


def build(dataset: str):
    if dataset == "har":
        x, y, _ = make_har_windows(HARDatasetConfig(num_samples=3000, seq_len=32))
        task = SupervisedTask(LSTMClassifier(LSTMClassifierConfig(6, 32, 64, 6)), lr=3e-3)
    else:
        x, y = make_calories_tabular(CaloriesDatasetConfig(num_samples=3000))
        task = SupervisedTask(MLPClassifier(MLPClassifierConfig(8, (64, 32), 5)), lr=3e-3)
    parts = dirichlet_partition(y, num_clients=6, alpha=1.0, seed=0)
    shards = [(x[p], y[p]) for p in parts]
    own_x, own_y = shards[0]
    n = int(len(own_x) * 0.8)
    return task, shards, (own_x[:n], own_y[:n]), (own_x[n:], own_y[n:]), (x, y)


def make_world(task, shards, own_train, own_test, *, fit_epochs: int,
               pooled=None, mobility=None) -> WorldSpec:
    """One shared world: a 5-device neighborhood whose contributors hold
    pre-trained models over their own shards."""
    fleet = make_fleet(5, seed=1, p_has_model=1.0)
    states = {}
    for i, dev in enumerate(fleet):
        dev.reservation_price = 0.4
        p = task.init(seed=10 + i)
        p, _ = task.fit(p, shards[i + 1], epochs=fit_epochs, batch_size=32, seed=i)
        states[dev.device_id] = {"params": p, "data": shards[i + 1]}
    return WorldSpec.single(task, own_train, own_test, fleet, states,
                            pooled_train=pooled, mobility=mobility)


def walkthrough(task, shards, own_train, own_test, args):
    """The simulated-world demo: one requester keeps training for the
    whole round budget while its world misbehaves.

    With ``--churn``, every round the session re-negotiates:
    contributors that wandered out of the 90 m range (or drained to the
    battery floor) are released, devices that wandered in are signed,
    and a higher-utility arrival displaces the weakest member.  Rounds
    with an EMPTY neighborhood are survivable — the requester trains
    alone on its own shard.

    With ``--faults``, the links themselves are unreliable: drops with
    bounded retries (each retry burns an extra priced receive window),
    exhausted links zeroed out of the aggregation, and stale deliveries
    replaying the previous round's wire image.

    With ``--cadence``, the lockstep barrier is gone: the requester's
    own duty cycle makes its round clock skip global event steps, and
    misphased contributors never tick on the requester's steps — their
    resident wire images are aggregated as-is every round (the
    straggler path).

    With ``--byzantine``, some links are adversarial: counter-based
    draws pick the round's corrupted set, each corrupted link delivers
    a 25x-scaled wire image, and the ``robust="clip"`` defense clips
    outlier-norm contributions at the masked median norm before the
    aggregate.  All four worlds are counter-based, so both engines
    derive the identical weather; pick with --engine.
    """
    mob = MobilityConfig(arena_m=200.0, radio_range_m=90.0,
                         leg_rounds=2, seed=5) if args.churn else None
    faults = FaultConfig(p_drop=0.4, p_stale=0.3, max_retries=1,
                         release_after=2, seed=7) if args.faults else None
    # seed 0 on the two-speed world: the requester lands on stride 2
    # (every other global step is an idle step), and two neighbors land
    # on stride 2 with the opposite phase — permanent stragglers
    cadence = (CadenceConfig(n_speed_classes=2, seed=0)
               if args.cadence else None)
    adversary = (AdversaryConfig(p_byzantine=0.3, attack="scale",
                                 scale=25.0, seed=9)
                 if args.byzantine else None)
    world = make_world(task, shards, own_train, own_test, fit_epochs=1,
                       mobility=mob)
    res = Experiment(
        world,
        method=MethodSpec(desired_accuracy=args.target, epochs=args.epochs,
                          max_rounds=10, n_max=3,
                          contributor_refresh_epochs=1, faults=faults,
                          cadence=cadence, adversary=adversary,
                          robust="clip" if args.byzantine else "none"),
        execution=ExecutionSpec(engine=args.engine)).run()

    label = "+".join(n for n, on in (("churn", args.churn),
                                     ("faults", args.faults),
                                     ("cadence", args.cadence),
                                     ("byzantine", args.byzantine)) if on)
    log.info(f"\n=== {label} walkthrough ({args.dataset}, engine={res.engine}) ===")
    # with neither churn nor faults there is no membership history: the
    # contract set is static, so the set column shows who is AWAKE on
    # the round's clock step instead (everyone, absent a cadence)
    have_mask = args.churn or args.faults
    set_head = "contract set" if have_mask else "awake set"
    head = f"{'round':>5}"
    if args.cadence:
        head += f" {'clock':>5} {'idle':>4}"
    head += f" {'members':>8} {set_head:<18}"
    if args.faults:
        head += f" {'delivered':<12} {'drop':>4} {'rtry':>4} {'stale':>5}"
    if args.cadence:
        head += f" {'stragglers':<12}"
    if args.byzantine:
        head += f" {'corrupted':<12} {'clipped':<12}"
    log.info(head + f" {'acc':>6} {'battery':>8}")
    mask_key = "member_mask" if args.churn else "deliver_mask"
    lane_ids = np.arange(len(world.requesters[0].neighborhood))
    device_ids = np.array(
        [d.device_id for d in world.requesters[0].neighborhood], np.int32)
    prev = None
    for r in range(res.rounds):
        clock = (int(res.history_raw["round_clock"][r])
                 if args.cadence else r)
        awake = (np.asarray(tick_mask(cadence, clock, device_ids))
                 if args.cadence else np.ones(len(device_ids), bool))
        if have_mask:
            mask = np.asarray(res.history_raw[mask_key][r]) > 0
        else:
            mask = awake
        ids = [d for d, m in enumerate(mask) if m]
        line = f"{r:>5}"
        if args.cadence:
            line += (f" {clock:>5}"
                     f" {int(res.history_raw['idle_steps'][r]):>4}")
        line += f" {int(mask.sum()):>8} {str(ids):<18}"
        if args.faults:
            got = [d for d, m in enumerate(
                np.asarray(res.history_raw["deliver_mask"][r]) > 0) if m]
            line += (f" {str(got):<12} {int(res.history_raw['drops'][r]):>4}"
                     f" {int(res.history_raw['retries'][r]):>4}"
                     f" {int(res.history_raw['stale'][r]):>5}")
        if args.cadence:
            lagging = [int(d) for d, aw in zip(lane_ids, awake) if not aw]
            line += f" {str(lagging):<12}"
        if args.byzantine:
            bad = [d for d, m in enumerate(np.asarray(
                res.history_raw["corrupted_mask"][r]) > 0) if m]
            cl = [d for d, m in enumerate(np.asarray(
                res.history_raw["clipped_mask"][r]) > 0) if m]
            line += f" {str(bad):<12} {str(cl):<12}"
        note = ""
        if prev is not None:
            joined = sorted(set(ids) - set(prev))
            left = sorted(set(prev) - set(ids))
            bits = ([f"+{j}" for j in joined] + [f"-{l}" for l in left])
            note = "  " + " ".join(bits) if bits else ""
        log.info(line + f" {res.history_raw['accuracy'][r]:6.3f} "
                 f"{res.history_raw['battery'][r]:8.3f}{note}")
        prev = ids
    if args.faults:
        log.info(f"fault weather: {int(np.sum(res.history_raw['drops']))} drops, "
                 f"{int(np.sum(res.history_raw['retries']))} retries, "
                 f"{int(np.sum(res.history_raw['stale']))} stale deliveries "
                 f"(retry windows priced via CostModel.retry_energy)")
    if args.cadence:
        clocks = [int(c) for c in res.history_raw["round_clock"]]
        idle = int(np.sum(res.history_raw["idle_steps"]))
        log.info(f"cadence: {res.rounds} rounds over {clocks[-1] + 1} global "
                 f"event steps, {idle} idle steps priced via "
                 f"CostModel.idle_energy; stragglers' resident wire images "
                 f"aggregated as-is (both engines print this identically)")
    if args.byzantine:
        corrupted = int(np.sum([np.sum(np.asarray(m) > 0)
                                for m in res.history_raw["corrupted_mask"]]))
        clipped = int(np.sum([np.sum(np.asarray(m) > 0)
                              for m in res.history_raw["clipped_mask"]]))
        log.info(f"byzantine weather: {corrupted} corrupted deliveries "
                 f"(counter-keyed 25x scale attack), {clipped} clipped by "
                 f"the robust='clip' screen (masked-median-norm threshold, "
                 f"priced via CostModel.screening_energy); both engines "
                 f"print the identical sets")
    log.info(f"requester finished: {res.rounds} rounds, stop={res.stop_reason}, "
             f"final acc {res.accuracy:.3f}")
    log.debug(f"timings: { {k: round(v, 4) for k, v in res.timings.items()} }")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", choices=("har", "calories"), default="har")
    ap.add_argument("--target", type=float, default=0.95)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--engine", choices=("loop", "fleet"), default="loop",
                    help="EnFed execution engine (fleet = one jit program)")
    ap.add_argument("--churn", action="store_true",
                    help="opportunistic-world walkthrough: neighbors enter/"
                         "leave radio range mid-session (repro.core.mobility)")
    ap.add_argument("--faults", action="store_true",
                    help="unreliable-link walkthrough: per-round drop/retry/"
                         "stale counts under the counter-based fault world "
                         "(repro.core.faults); composes with --churn")
    ap.add_argument("--cadence", action="store_true",
                    help="async walkthrough: per-device duty cycles end the "
                         "lockstep barrier (repro.core.cadence) — prints "
                         "per-round clock steps, priced idle steps, and the "
                         "straggler set, identical in both engines; composes "
                         "with --churn/--faults")
    ap.add_argument("--byzantine", action="store_true",
                    help="adversarial walkthrough: counter-based Byzantine "
                         "links deliver 25x-scaled wire images and the "
                         "robust='clip' screen throttles them "
                         "(repro.core.adversary + repro.kernels.robust) — "
                         "prints per-round corrupted/clipped sets, identical "
                         "in both engines; composes with "
                         "--churn/--faults/--cadence")
    ap.add_argument("--compress", choices=("int8",), default=None,
                    help="add an enfed-int8 row: same world with the "
                         "transported updates int8-compressed (shows the "
                         "eq. (4)-(7) energy delta in the compare table)")
    vq = ap.add_mutually_exclusive_group()
    vq.add_argument("-v", "--verbose", action="store_true",
                    help="debug logging (adds the per-run span timings)")
    vq.add_argument("-q", "--quiet", action="store_true",
                    help="errors only; suppress the table/walkthrough output")
    args = ap.parse_args()
    _setup_logging(1 if args.verbose else -1 if args.quiet else 0)

    task, shards, own_train, own_test, pooled = build(args.dataset)
    if args.churn or args.faults or args.cadence or args.byzantine:
        return walkthrough(task, shards, own_train, own_test, args)

    # one world, N methods: the facade guarantees every method sees the
    # same requesters, contributor states, seed, and cost model
    world = make_world(task, shards, own_train, own_test,
                       fit_epochs=args.epochs, pooled=pooled)
    exp = Experiment(
        world,
        method=MethodSpec(desired_accuracy=args.target, epochs=args.epochs,
                          max_rounds=10, batch_size=32),
        execution=ExecutionSpec(engine=args.engine))
    methods = ["enfed", "cfl",
               dataclasses.replace(exp.method, name="dfl",
                                   topology="mesh", label="dfl-mesh"),
               dataclasses.replace(exp.method, name="dfl",
                                   topology="ring", label="dfl-ring"),
               "cloud"]
    if args.compress:
        methods.insert(1, dataclasses.replace(exp.method,
                                              compress=args.compress,
                                              label="enfed-int8"))
    cmp = exp.compare(methods)

    log.info(f"\n=== {args.dataset} ===")
    log.info(cmp.table())
    for row in cmp.reductions("enfed"):
        log.info(f"EnFed vs {row['baseline']:<10}: "
                 f"{row['time_reduction_pct']:+.1f}% time, "
                 f"{row['energy_reduction_pct']:+.1f}% energy")
    if args.compress:
        fp32, q8 = cmp["enfed"].report, cmp["enfed-int8"].report
        log.info(f"int8 wire: t_com {fp32.times.t_com:.4f}s -> "
                 f"{q8.times.t_com:.4f}s, E_comm {fp32.e_comm:.3f}J -> "
                 f"{q8.e_comm:.3f}J on the same world")
    log.info("(cloud T_train is the §IV-G response time: upload + cloud "
             "training + round trip)")
    log.debug(f"enfed timings: "
              f"{ {k: round(v, 4) for k, v in cmp['enfed'].timings.items()} }")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
