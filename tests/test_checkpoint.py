"""repro.checkpoint unit coverage: atomic .npz pytree round trips.

The checkpointer is the substrate of crash-resumable sessions
(`tests/test_checkpoint_resume.py` covers the engine contract); these
tests pin the primitive itself — bit-exact round trips across mixed
dtypes, newest-step selection, fail-fast on structural drift, and the
atomic-write rule that a directory never accumulates torn files.
"""

import os

import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def _state(seed=0, scale=1.0):
    """A nested pytree shaped like real round state: int8 wire payload,
    fp32 scales/params, float64 battery, int64 clock, bool masks."""
    rng = np.random.default_rng(seed)
    return {
        "wire": {"q": rng.integers(-128, 127, (3, 16), dtype=np.int8),
                 "s": (scale * rng.standard_normal((3, 2))).astype(np.float32)},
        "params": [rng.standard_normal((4, 5)).astype(np.float32),
                   rng.standard_normal((5,)).astype(np.float32)],
        "battery": np.float64(0.7313 * scale),
        "round": np.int64(3),
        "mask": np.array([True, False, True]),
    }


def test_roundtrip_bit_exact(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 7, state)
    out, step = restore_checkpoint(str(tmp_path), _state(seed=1))
    assert step == 7
    np.testing.assert_array_equal(out["wire"]["q"], state["wire"]["q"])
    np.testing.assert_array_equal(out["wire"]["s"], state["wire"]["s"])
    for a, b in zip(out["params"], state["params"]):
        np.testing.assert_array_equal(a, b)
        assert a.dtype == b.dtype
    assert out["battery"] == state["battery"]
    assert out["round"] == state["round"]
    np.testing.assert_array_equal(out["mask"], state["mask"])


def test_latest_step_ordering(tmp_path):
    for step, scale in [(2, 0.5), (10, 2.0), (6, 1.5)]:
        save_checkpoint(str(tmp_path), step, _state(scale=scale))
    assert latest_step(str(tmp_path)) == 10
    out, step = restore_checkpoint(str(tmp_path), _state())
    assert step == 10
    # the newest payload, not just the newest step number
    np.testing.assert_array_equal(out["wire"]["s"],
                                  _state(scale=2.0)["wire"]["s"])
    # explicit step selection still works
    out6, step6 = restore_checkpoint(str(tmp_path), _state(), step=6)
    assert step6 == 6
    np.testing.assert_array_equal(out6["wire"]["s"],
                                  _state(scale=1.5)["wire"]["s"])


def test_missing_dir_and_empty_dir(tmp_path):
    missing = str(tmp_path / "nope")
    assert latest_step(missing) is None
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(missing, _state())
    empty = tmp_path / "empty"
    empty.mkdir()
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(empty), _state())


def test_missing_key_raises(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 1, state)
    template = dict(state)
    template["extra"] = np.zeros(3, np.float32)   # not in the checkpoint
    with pytest.raises(KeyError):
        restore_checkpoint(str(tmp_path), template)


def test_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, _state())
    template = _state()
    template["params"][0] = np.zeros((4, 6), np.float32)
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_checkpoint(str(tmp_path), template)


def test_dtype_mismatch_raises_not_downcasts(tmp_path):
    """An fp32 checkpoint must never silently astype into an int8
    template (or vice versa) — wire-format state restores AS its
    resident dtype or not at all."""
    save_checkpoint(str(tmp_path), 1, _state())
    template = _state()
    template["wire"]["q"] = template["wire"]["q"].astype(np.float32)
    with pytest.raises(ValueError, match="dtype mismatch"):
        restore_checkpoint(str(tmp_path), template)
    template = _state()
    template["params"][0] = template["params"][0].astype(np.float16)
    with pytest.raises(ValueError, match="dtype mismatch"):
        restore_checkpoint(str(tmp_path), template)


def test_tmp_files_swept_and_never_listed(tmp_path):
    """A crash between savez and os.replace leaves step_N.npz.tmp.npz
    behind; latest_step must neither count it as a checkpoint nor let it
    accumulate."""
    save_checkpoint(str(tmp_path), 3, _state())
    orphan = tmp_path / "step_00000099.npz.tmp.npz"
    orphan.write_bytes(b"torn write")
    assert latest_step(str(tmp_path)) == 3
    assert not orphan.exists()


def test_save_is_atomic_replace(tmp_path):
    """Re-saving a step replaces the file completely (no partial
    content) and leaves no tmp residue."""
    p1 = save_checkpoint(str(tmp_path), 5, _state(scale=1.0))
    p2 = save_checkpoint(str(tmp_path), 5, _state(scale=3.0))
    assert p1 == p2
    assert sorted(os.listdir(tmp_path)) == ["step_00000005.npz"]
    out, _ = restore_checkpoint(str(tmp_path), _state())
    np.testing.assert_array_equal(out["wire"]["s"],
                                  _state(scale=3.0)["wire"]["s"])
