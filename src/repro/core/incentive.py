"""Contract-theory incentive mechanism (paper §III, [31]).

The requesting device publishes an offered incentive; each nearby device
has a private reservation price (its cost of participating: battery it
will burn, staleness of its model, data it holds).  A device agrees iff
the offer covers its reservation; the requester then ranks agreeing
devices by a contract utility (fresher model, more data, healthier
battery = better contribution per unit incentive) and signs contracts
with the top ``N_max``.

This module is deterministic given the fleet state + rng key, and it is
what produces the per-round participation mask used by the opportunistic
aggregation strategies in ``repro.core.topology``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np


@dataclasses.dataclass
class NeighborDevice:
    device_id: int
    battery_level: float          # [0, 1]
    model_staleness: float        # rounds since the neighbour last updated (>=0)
    data_size: int                # samples backing its local model
    reservation_price: float      # minimum acceptable incentive
    has_model: bool = True        # neighbour actually has a model for app A


@dataclasses.dataclass(frozen=True)
class Contract:
    device_id: int
    incentive: float
    utility: float


def contract_utility(dev: NeighborDevice, max_data: int) -> float:
    """Value of a contribution: fresh, data-rich, battery-healthy models."""
    freshness = 1.0 / (1.0 + dev.model_staleness)
    data_term = dev.data_size / max(max_data, 1)
    battery_term = min(dev.battery_level / 0.5, 1.0)   # below 50% progressively risky
    return 0.5 * freshness + 0.3 * data_term + 0.2 * battery_term


def select_contributors(devices: Sequence[NeighborDevice], offered_incentive: float,
                        n_max: int, min_battery: float = 0.1) -> List[Contract]:
    """Handshaking phase of Algorithm 1: who agrees, and whom we sign.

    Returns contracts sorted by utility (best first), at most ``n_max``.
    """
    agreeing = [d for d in devices
                if d.has_model
                and d.battery_level >= min_battery
                and offered_incentive >= d.reservation_price]
    max_data = max((d.data_size for d in agreeing), default=1)
    ranked = sorted(agreeing, key=lambda d: -contract_utility(d, max_data))
    return [Contract(device_id=d.device_id, incentive=offered_incentive,
                     utility=contract_utility(d, max_data))
            for d in ranked[:n_max]]


def participation_mask(num_devices: int, contracts: Sequence[Contract]) -> np.ndarray:
    mask = np.zeros((num_devices,), np.float32)
    for c in contracts:
        mask[c.device_id] = 1.0
    return mask


def sign_contracts_fleet(neighborhoods: Sequence[Sequence[NeighborDevice]],
                         offered_incentive: float, n_max: int,
                         min_battery: float = 0.1):
    """Handshake phase for a whole *fleet of requesters* at once.

    ``neighborhoods[i]`` is requester *i*'s view of the shared device
    population (the devices in its radio range).  Returns
    ``(contracts, mask)`` where ``contracts[i]`` is requester *i*'s
    ranked contract list and ``mask`` is an (R, n_max) float32 matrix
    with 1.0 at slot (i, j) iff requester *i* signed a j-th contributor.
    The mask is the static participation input of the jit fleet engine
    (``repro.core.fleet``); slot order == contract rank, matching the
    loop engine's aggregation order.
    """
    contracts = [select_contributors(devs, offered_incentive, n_max, min_battery)
                 for devs in neighborhoods]
    mask = np.zeros((len(contracts), n_max), np.float32)
    for i, cs in enumerate(contracts):
        mask[i, :len(cs)] = 1.0
    return contracts, mask


# ---------------------------------------------------------------------------
# dynamic contracts (the mobility / churn path, repro.core.mobility)
# ---------------------------------------------------------------------------


def candidate_pool(devices: Sequence[NeighborDevice],
                   offered_incentive: float) -> List[NeighborDevice]:
    """The *agreeing* devices of a neighborhood, in stable device order.

    Under mobility (``repro.core.mobility``) the handshake no longer
    freezes a contract set: it fixes the candidate pool — every device
    that holds a model and whose reservation price the offer covers.
    Battery and radio range are checked PER ROUND by
    :func:`repro.core.mobility.membership_step`, which re-negotiates the
    actual contract set from this pool; candidate order here defines the
    contributor lane order of both engines.
    """
    return [d for d in devices
            if d.has_model and offered_incentive >= d.reservation_price]


def contracts_from_membership(candidates: Sequence[NeighborDevice],
                              member, util,
                              offered_incentive: float) -> List[Contract]:
    """Host view of one round's re-negotiated contract set.

    ``member``/``util`` are the (N,) outputs of
    :func:`repro.core.mobility.membership_step` for one requester;
    returns the signed :class:`Contract` list ranked best-utility first
    (the loop engine's per-round analogue of :func:`select_contributors`).
    """
    member = np.asarray(member, bool)
    util = np.asarray(util, np.float32)
    order = sorted((j for j in range(len(candidates)) if member[j]),
                   key=lambda j: (-util[j], j))
    return [Contract(device_id=candidates[j].device_id,
                     incentive=offered_incentive, utility=float(util[j]))
            for j in order]


def make_fleet(num_devices: int, seed: int = 0, p_has_model: float = 0.9) -> List[NeighborDevice]:
    """Randomized nearby-device fleet for simulations."""
    rng = np.random.default_rng(seed)
    fleet = []
    for i in range(num_devices):
        fleet.append(NeighborDevice(
            device_id=i,
            battery_level=float(rng.uniform(0.15, 1.0)),
            model_staleness=float(rng.exponential(1.0)),
            data_size=int(rng.integers(200, 2000)),
            reservation_price=float(rng.uniform(0.2, 1.0)),
            has_model=bool(rng.random() < p_has_model),
        ))
    return fleet
