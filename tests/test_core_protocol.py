"""Unit + property tests for the EnFed core: aggregation, incentives,
energy model, battery, crypto, convergence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (BatteryState, CostModel, aggregation, fedavg,
                        make_fleet, masked_fedavg, participation_mask,
                        select_contributors)
from repro.core.convergence import aggregated_loss, loss_delta_converged
from repro.core.topology import (AggregationStrategy, group_mixing_matrix,
                                 mixing_matrix_jnp)

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# aggregation (paper eq. 14)
# ---------------------------------------------------------------------------


def _rand_tree(seed):
    r = np.random.default_rng(seed)
    return {"w": jnp.asarray(r.normal(size=(4, 3)).astype(np.float32)),
            "b": jnp.asarray(r.normal(size=(5,)).astype(np.float32))}


def test_fedavg_is_mean():
    trees = [_rand_tree(i) for i in range(4)]
    avg = fedavg(trees)
    manual = np.mean([np.asarray(t["w"]) for t in trees], axis=0)
    np.testing.assert_allclose(np.asarray(avg["w"]), manual, rtol=1e-6)


def test_masked_fedavg_excludes_nonparticipants():
    trees = [_rand_tree(i) for i in range(4)]
    avg = masked_fedavg(trees, mask=[1, 0, 1, 0])
    manual = (np.asarray(trees[0]["w"]) + np.asarray(trees[2]["w"])) / 2
    np.testing.assert_allclose(np.asarray(avg["w"]), manual, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 8), st.integers(0, 10_000))
def test_fedavg_bounded_by_extremes(n, seed):
    """Convexity: every coordinate of the average lies within the
    per-coordinate min/max of the contributors."""
    r = np.random.default_rng(seed)
    trees = [{"x": jnp.asarray(r.normal(size=(6,)).astype(np.float32))} for _ in range(n)]
    w = r.random(n).astype(np.float32) + 0.01
    avg = np.asarray(fedavg(trees, list(w))["x"])
    stack = np.stack([np.asarray(t["x"]) for t in trees])
    assert (avg >= stack.min(0) - 1e-5).all() and (avg <= stack.max(0) + 1e-5).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.floats(0.1, 10.0))
def test_fedavg_scale_equivariance(n, scale):
    trees = [_rand_tree(i) for i in range(n)]
    avg1 = fedavg(trees)
    scaled = [jax.tree_util.tree_map(lambda x: x * scale, t) for t in trees]
    avg2 = fedavg(scaled)
    np.testing.assert_allclose(np.asarray(avg2["w"]),
                               np.asarray(avg1["w"]) * scale, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# mixing matrices
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["cfl", "dfl_mesh", "dfl_ring", "enfed", "none"])
@pytest.mark.parametrize("C", [4, 6, 8])
def test_mixing_matrix_row_stochastic(kind, C):
    s = AggregationStrategy(kind=kind, neighborhood_size=2)
    M = group_mixing_matrix(C, s)
    np.testing.assert_allclose(M.sum(axis=1), np.ones(C), rtol=1e-5)
    assert (M >= 0).all()


@pytest.mark.parametrize("kind", ["cfl", "dfl_mesh", "dfl_ring", "enfed", "none"])
def test_mixing_matrix_jnp_matches_numpy(kind):
    C = 6
    mask = np.array([1, 1, 0, 1, 1, 1], np.float32)
    s = AggregationStrategy(kind=kind, neighborhood_size=3)
    M_np = group_mixing_matrix(C, s, mask=mask)
    M_j = np.asarray(mixing_matrix_jnp(C, s, jnp.asarray(mask)))
    np.testing.assert_allclose(M_j, M_np, rtol=1e-5, atol=1e-6)


def test_enfed_mixing_is_block_diagonal():
    s = AggregationStrategy(kind="enfed", neighborhood_size=2)
    M = group_mixing_matrix(6, s)
    for i in range(6):
        for j in range(6):
            if i // 2 != j // 2:
                assert M[i, j] == 0.0, "EnFed must not mix across neighborhoods"


# ---------------------------------------------------------------------------
# incentives / contracts
# ---------------------------------------------------------------------------


def test_contract_selection_respects_reservation_and_nmax():
    fleet = make_fleet(10, seed=0, p_has_model=1.0)
    for d in fleet:
        d.reservation_price = 0.9 if d.device_id < 5 else 0.1
    contracts = select_contributors(fleet, offered_incentive=0.5, n_max=3)
    assert len(contracts) <= 3
    assert all(c.device_id >= 5 for c in contracts), "reservation price ignored"
    mask = participation_mask(10, contracts)
    assert mask.sum() == len(contracts)


def test_contract_selection_prefers_fresh_models():
    fleet = make_fleet(4, seed=1, p_has_model=1.0)
    for d in fleet:
        d.reservation_price = 0.1
        d.battery_level = 0.9
        d.data_size = 1000
        d.model_staleness = 5.0
    fleet[2].model_staleness = 0.0
    contracts = select_contributors(fleet, offered_incentive=0.5, n_max=1)
    assert contracts[0].device_id == 2


# ---------------------------------------------------------------------------
# energy model (eqs. 4-7)
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 10), st.integers(1, 8), st.integers(1, 20))
def test_energy_monotone_in_rounds_contributors_epochs(rounds, n_c, epochs):
    cm = CostModel()
    kw = dict(num_params=10_000, model_bytes=40_000, num_samples=500)
    base = cm.session(rounds=rounds, n_contrib=n_c, epochs=epochs, **kw)
    more_rounds = cm.session(rounds=rounds + 1, n_contrib=n_c, epochs=epochs, **kw)
    more_contrib = cm.session(rounds=rounds, n_contrib=n_c + 1, epochs=epochs, **kw)
    assert more_rounds.e_tot > base.e_tot
    assert more_rounds.t_train > base.t_train
    assert more_contrib.e_tot >= base.e_tot


def test_energy_decomposition_consistent():
    cm = CostModel()
    rep = cm.session(rounds=3, n_contrib=5, num_params=10_000,
                     model_bytes=40_000, num_samples=500, epochs=5)
    assert rep.e_tot == pytest.approx(rep.e_comp + rep.e_comm)
    assert rep.t_train == pytest.approx(rep.times.total)


def test_encryption_adds_time_and_energy():
    cm = CostModel()
    kw = dict(rounds=3, n_contrib=5, num_params=10_000, model_bytes=40_000,
              num_samples=500, epochs=5)
    enc = cm.session(encrypt=True, **kw)
    plain = cm.session(encrypt=False, **kw)
    assert enc.t_train > plain.t_train
    assert enc.e_tot > plain.e_tot


def test_dfl_ring_cheaper_than_mesh():
    cm = CostModel()
    kw = dict(rounds=4, n_peers=5, num_params=10_000, model_bytes=40_000,
              num_samples=500, epochs=5)
    ring = cm.dfl_session(topology="ring", **kw)
    mesh = cm.dfl_session(topology="mesh", **kw)
    assert ring.e_tot < mesh.e_tot, "paper: ring DFL costs less than mesh DFL"


# ---------------------------------------------------------------------------
# battery
# ---------------------------------------------------------------------------


def test_battery_discharge_and_threshold():
    b = BatteryState(capacity_j=100.0, level=0.5)
    b2 = b.discharge(10.0, avg_power_w=1.0)
    assert b2.level == pytest.approx(0.4)
    assert not b2.below(0.2) and b2.discharge(100.0).below(0.2)


def test_battery_high_load_penalty():
    b = BatteryState(capacity_j=100.0, level=1.0)
    light = b.discharge(10.0, avg_power_w=1.0)
    heavy = b.discharge(10.0, avg_power_w=5.0)
    assert heavy.level < light.level, "non-linear discharge under load"


# ---------------------------------------------------------------------------
# crypto
# ---------------------------------------------------------------------------


def test_aes_fips197_vector():
    from repro.core import crypto
    key = np.array([int(x, 16) for x in
                    "00 01 02 03 04 05 06 07 08 09 0a 0b 0c 0d 0e 0f".split()], np.uint8)
    pt = np.array([int(x, 16) for x in
                   "00 11 22 33 44 55 66 77 88 99 aa bb cc dd ee ff".split()], np.uint8)
    rks = jnp.asarray(crypto.expand_key(key))
    ct = np.asarray(crypto.aes128_encrypt_blocks(jnp.asarray(pt[None]), rks))[0]
    assert "".join(f"{b:02x}" for b in ct) == "69c4e0d86a7b0430d8cdb78070b4c55a"


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 500), st.integers(0, 2**32 - 1))
def test_aes_ctr_update_roundtrip(n, seed):
    from repro.core import crypto
    r = np.random.default_rng(seed)
    key = r.integers(0, 256, 16).astype(np.uint8)
    nonce = r.integers(0, 256, 8).astype(np.uint8)
    vec = jnp.asarray(r.normal(size=(n,)).astype(np.float32))
    ct = crypto.encrypt_update(vec, key, nonce)
    back = crypto.decrypt_update(ct, key, nonce)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(vec))


def test_aes_wrong_key_fails_to_decrypt():
    from repro.core import crypto
    key1 = np.arange(16, dtype=np.uint8)
    key2 = key1.copy(); key2[0] ^= 1
    nonce = np.arange(8, dtype=np.uint8)
    vec = jnp.asarray(RNG.normal(size=(64,)).astype(np.float32))
    ct = crypto.encrypt_update(vec, key1, nonce)
    wrong = crypto.decrypt_update(ct, key2, nonce)
    assert not np.allclose(np.asarray(wrong), np.asarray(vec))


# ---------------------------------------------------------------------------
# convergence helpers
# ---------------------------------------------------------------------------


def test_loss_delta_convergence():
    assert loss_delta_converged([1.0, 0.5, 0.4999, 0.4998], tol=1e-3)
    assert not loss_delta_converged([1.0, 0.5, 0.3], tol=1e-3)
    assert aggregated_loss([1.0, 2.0, 3.0]) == pytest.approx(2.0)
