"""Pallas TPU kernel: fused masked-weighted FedAvg aggregation.

Aggregation (paper eq. 14) is a memory-bound reduction over the
contributor axis: for every parameter tile we stream N contributor
slices HBM -> VMEM once and emit one fp32 tile.  Fusing the mask, the
weighting, and the normalization into one pass avoids materializing the
masked intermediate (which a naive ``(mask*w)[:,None]*updates`` would
write back to HBM at full N x L size).

Tiling: grid over the flat parameter dimension, block (N, TILE_L) with
TILE_L = 2048 (16 x 128 lanes) so the working set N*TILE_L*4B stays well
under VMEM for fleet sizes up to ~256 contributors.  The weight vector
is small and replicated to every grid step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret
from repro.kernels.quantize.kernel import TILE as Q_TILE

TILE_L = 2048


def _fedavg_kernel(w_ref, u_ref, o_ref):
    """w_ref: (N,) fp32; u_ref: (N, TILE_L); o_ref: (TILE_L,)."""
    w = w_ref[...]
    u = u_ref[...].astype(jnp.float32)
    num = jnp.einsum("n,nl->l", w, u)
    denom = jnp.maximum(jnp.sum(w), 1e-9)
    o_ref[...] = num / denom


def _fedavg_batched_kernel(w_ref, u_ref, o_ref):
    """w_ref: (TR, N) fp32; u_ref: (TR, N, TILE_L); o_ref: (TR, TILE_L).

    A TILE of requester sessions per leading grid step — the fleet
    engine's aggregation hot path runs every session's eq. (14) in one
    launch.  Tiling R (instead of one session per step) keeps the grid
    small: interpret mode (the CPU path) walks grid steps serially with
    per-step overhead, so a (R, L/TILE_L) grid turned the aggregation
    into the R=512 scaling cliff; (R/TR, L/TILE_L) removes it while the
    (TR, N, TILE_L) block stays VMEM-bounded on TPU (see _tile_r).
    """
    w = w_ref[...]
    u = u_ref[...].astype(jnp.float32)
    num = jnp.einsum("rn,rnl->rl", w, u)
    denom = jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1e-9)
    o_ref[...] = num / denom


def _tile_r(r: int, n: int, tile_l: int, itemsize: int) -> int:
    """Requester-axis tile: as many sessions per grid step as keep the
    update block within a ~2 MB VMEM budget (double-buffered well under
    the ~16 MB/core ceiling), at least 1, at most R."""
    return max(1, min(r, (2 << 20) // max(n * tile_l * itemsize, 1)))


def _fedavg_batched_q8_kernel(w_ref, q_ref, s_ref, o_ref):
    """w_ref: (TR, N) fp32; q_ref: (TR, N, Q_TILE) int8; s_ref:
    (TR, N, 1) fp32 per-tile scales; o_ref: (TR, Q_TILE) fp32.

    The compressed round state's hot path: dequantize every
    contributor's int8 tile (``q * scale``, the exact wire inverse) and
    reduce it into the masked weighted mean in ONE pass through VMEM —
    the (N, Q_TILE) fp32 intermediate a separate dequant would write
    back to HBM at full round-state size never exists.  R is tiled like
    :func:`_fedavg_batched_kernel` to keep the grid small.
    """
    w = w_ref[...]
    u = q_ref[...].astype(jnp.float32) * s_ref[...]
    num = jnp.einsum("rn,rnl->rl", w, u)
    denom = jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1e-9)
    o_ref[...] = num / denom


@functools.partial(jax.jit, static_argnames=("interpret",))
def fedavg_batched_q8_pallas(q, scales, weights, *, interpret=None):
    """q: (R, N, Lp) int8 wire payload, Lp % Q_TILE == 0; scales:
    (R, N, Lp/Q_TILE) fp32; weights: (R, N).  Returns (R, Lp) fp32.

    The q8 counterpart of :func:`fedavg_batched_pallas`: grid
    (R/TR, Lp/Q_TILE) — one quantization tile per trailing grid step so
    each block sees exactly one scale scalar per contributor — with the
    dequant fused into the reduction.  Used by ``repro.core.fleet``
    under ``EnFedConfig.compress="int8"`` to aggregate every concurrent
    session straight from the compressed round-state buffer.
    """
    interpret = resolve_interpret(interpret)
    r, n, lp = q.shape
    if lp % Q_TILE:
        raise ValueError(f"fedavg_batched_q8 needs Lp % {Q_TILE} == 0 "
                         f"(got {lp}); the wire format is tile-padded")
    tr = _tile_r(r, n, Q_TILE, 1)
    pad_r = (-r) % tr
    if pad_r:
        q = jnp.pad(q, ((0, pad_r), (0, 0), (0, 0)))
        scales = jnp.pad(scales, ((0, pad_r), (0, 0), (0, 0)))
        weights = jnp.pad(weights, ((0, pad_r), (0, 0)))
    grid = ((r + pad_r) // tr, lp // Q_TILE)
    out = pl.pallas_call(
        _fedavg_batched_q8_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, n), lambda i, j: (i, 0)),
            pl.BlockSpec((tr, n, Q_TILE), lambda i, j: (i, 0, j)),
            pl.BlockSpec((tr, n, 1), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((tr, Q_TILE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r + pad_r, lp), jnp.float32),
        interpret=interpret,
    )(weights.astype(jnp.float32), q, scales)
    return out[:r]


@functools.partial(jax.jit, static_argnames=("interpret",))
def fedavg_batched_pallas(updates, weights, *, interpret=None):
    """updates: (R, N, L); weights: (R, N). Returns (R, L) fp32.

    The requester-batched form of :func:`fedavg_pallas`: grid
    (R/TR, L/TILE_L), each step reduces a TILE of requesters' contributor
    stacks for one parameter tile.  Used by ``repro.core.fleet`` to
    aggregate every concurrent session in a single kernel launch.
    """
    interpret = resolve_interpret(interpret)
    r, n, l = updates.shape
    pad = (-l) % TILE_L
    if pad:
        updates = jnp.pad(updates, ((0, 0), (0, 0), (0, pad)))
    lp = l + pad
    tr = _tile_r(r, n, TILE_L, 4)
    pad_r = (-r) % tr
    if pad_r:
        updates = jnp.pad(updates, ((0, pad_r), (0, 0), (0, 0)))
        weights = jnp.pad(weights, ((0, pad_r), (0, 0)))
    grid = ((r + pad_r) // tr, lp // TILE_L)
    out = pl.pallas_call(
        _fedavg_batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, n), lambda i, j: (i, 0)),
            pl.BlockSpec((tr, n, TILE_L), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((tr, TILE_L), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r + pad_r, lp), jnp.float32),
        interpret=interpret,
    )(weights.astype(jnp.float32), updates)
    return out[:r, :l]


@functools.partial(jax.jit, static_argnames=("interpret",))
def fedavg_pallas(updates, weights, *, interpret=None):
    """updates: (N, L); weights: (N,). Returns (L,) fp32.

    L is padded to a TILE_L multiple internally; callers pass any L.
    """
    interpret = resolve_interpret(interpret)
    n, l = updates.shape
    pad = (-l) % TILE_L
    if pad:
        updates = jnp.pad(updates, ((0, 0), (0, pad)))
    lp = l + pad
    grid = (lp // TILE_L,)
    out = pl.pallas_call(
        _fedavg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n, TILE_L), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((TILE_L,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((lp,), jnp.float32),
        interpret=interpret,
    )(weights.astype(jnp.float32), updates)
    return out[:l]
