"""repro.telemetry: the observability layer and its house rule.

The one invariant everything here enforces: OBSERVATION CAN NEVER CHANGE
THE SIMULATED OUTCOME.  A run with tracing on (event JSONL, Chrome
trace, HLO stats) must be bitwise identical — params, masks, battery —
to the same run with tracing off, on static, mobility, and fault worlds,
through both engines.  On top of that: the two engines' normalized event
streams on one world must be equal, the exporters must round-trip
schema-valid, and the Timeline span stack must behave.
"""

import copy
import dataclasses
import json

import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.api import ExecutionSpec, Experiment, MethodSpec, WorldSpec
from repro.core import (CadenceConfig, FaultConfig, MobilityConfig,
                        SupervisedTask, make_fleet)
from repro.data import (CaloriesDatasetConfig, dirichlet_partition,
                        make_calories_tabular)
from repro.models import MLPClassifier, MLPClassifierConfig
from repro.telemetry import (EVENT_PHASES, RoundEvent, Timeline, TraceConfig,
                             compare_event_streams, read_events_jsonl,
                             timeline_chrome_trace, validate_events,
                             write_chrome_trace, write_events_jsonl)

BATCH = 16


def _build(n_contrib=3, n_samples=600, seed=0):
    x, y = make_calories_tabular(CaloriesDatasetConfig(num_samples=n_samples))
    task = SupervisedTask(MLPClassifier(MLPClassifierConfig(8, (16,), 5)), lr=3e-3)
    parts = dirichlet_partition(y, num_clients=n_contrib + 1, alpha=100.0, seed=seed)
    shards = [(x[p], y[p]) for p in parts]
    own_x, own_y = shards[0]
    n = int(len(own_x) * 0.8)
    own_train, own_test = (own_x[:n], own_y[:n]), (own_x[n:], own_y[n:])
    fleet = make_fleet(n_contrib, seed=1, p_has_model=1.0)
    states = {}
    for i, dev in enumerate(fleet):
        dev.reservation_price = 0.4
        p = task.init(seed=10 + i)
        p, _ = task.fit(p, shards[i + 1], epochs=1, batch_size=BATCH, seed=i)
        states[dev.device_id] = {"params": p, "data": shards[i + 1]}
    return task, own_train, own_test, fleet, states


@pytest.fixture(scope="module")
def problem():
    return _build()


_METHOD = MethodSpec(desired_accuracy=0.99, max_rounds=2, epochs=1,
                     batch_size=BATCH, encrypt=False,
                     contributor_refresh_epochs=1)
_MOB = MobilityConfig(radio_range_m=95.0, leg_rounds=1, seed=5)
_FAULTS = FaultConfig(p_drop=0.6, p_stale=0.4, max_retries=1,
                      release_after=2, seed=3)
# seed 0 puts the requester on stride 2 of 2 — real idle steps between
# rounds, so the async observability fields carry non-trivial values
_CADENCE = CadenceConfig(n_speed_classes=2, seed=0)

# world name -> (mobility, method) — the weather regimes the house rule
# is enforced on (cadence = the async event-step world of PR 9)
_WORLDS = {
    "static": (None, _METHOD),
    "mobility": (_MOB, dataclasses.replace(_METHOD, desired_accuracy=0.999,
                                           max_rounds=4, n_max=2)),
    "faults": (None, dataclasses.replace(_METHOD, desired_accuracy=0.999,
                                         max_rounds=4, faults=_FAULTS)),
    "cadence": (None, dataclasses.replace(_METHOD, desired_accuracy=0.999,
                                          max_rounds=3, cadence=_CADENCE)),
}


def _world(problem, mobility=None):
    task, own_train, own_test, fleet, states = problem
    return WorldSpec.single(task, own_train, own_test, fleet,
                            copy.deepcopy(states), mobility=mobility)


def _assert_outcome_bitwise(a, b):
    """Two RunResults computed the identical simulation: params, every
    history buffer (masks, battery, counters), rounds, stop reason."""
    assert a.rounds == b.rounds
    assert a.stop_reason == b.stop_reason
    av, _ = ravel_pytree(a.params)
    bv, _ = ravel_pytree(b.params)
    np.testing.assert_array_equal(np.asarray(av), np.asarray(bv))
    assert set(a.history_raw) == set(b.history_raw)
    for k in a.history_raw:
        ha, hb = a.history_raw[k], b.history_raw[k]
        assert len(ha) == len(hb), f"history[{k!r}] length"
        # row-wise: mobility histories hold per-round mask rows whose
        # width varies with the candidate pool
        for r, (ra, rb) in enumerate(zip(ha, hb)):
            np.testing.assert_array_equal(np.asarray(ra), np.asarray(rb),
                                          err_msg=f"history[{k!r}][{r}]")


# ---------------------------------------------------------------------------
# the house rule: tracing on == tracing off, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("world_name", list(_WORLDS))
@pytest.mark.parametrize("engine", ["loop", "fleet"])
def test_trace_on_is_bitwise_identical_to_trace_off(problem, engine,
                                                    world_name, tmp_path):
    mobility, method = _WORLDS[world_name]
    # exercise the heaviest trace on the fleet engine (profiling hooks
    # included); the loop engine gets the exports that apply to it
    trace = TraceConfig(events_jsonl=str(tmp_path / "events.jsonl"),
                        chrome_trace=str(tmp_path / "trace.json"),
                        hlo_stats=(engine == "fleet"))
    off = Experiment(_world(problem, mobility), method,
                     ExecutionSpec(engine=engine)).run()
    on = Experiment(_world(problem, mobility), method,
                    ExecutionSpec(engine=engine, trace=trace)).run()
    _assert_outcome_bitwise(off, on)
    # and the traced run actually observed something
    assert (tmp_path / "events.jsonl").exists()
    assert (tmp_path / "trace.json").exists()
    assert on.timings
    if engine == "fleet":
        assert on.hlo_stats and "flops" in on.hlo_stats


# ---------------------------------------------------------------------------
# cross-engine event-stream equality
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("world_name", list(_WORLDS))
def test_event_streams_equal_across_engines(problem, world_name):
    mobility, method = _WORLDS[world_name]
    loop = Experiment(_world(problem, mobility), method,
                      ExecutionSpec(engine="loop")).run()
    fl = Experiment(_world(problem, mobility), method,
                    ExecutionSpec(engine="fleet")).run()
    diffs = compare_event_streams(validate_events(loop.trace),
                                  validate_events(fl.trace))
    assert diffs == []


def test_fault_world_events_carry_the_weather(problem):
    """The fault world's drops/retries/stale and delivered sets must
    surface in the normalized stream, not just in raw history."""
    _, method = _WORLDS["faults"]
    res = Experiment(_world(problem), method,
                     ExecutionSpec(engine="fleet")).run()
    rounds = [e for e in res.trace if e.phase == "round"]
    assert sum(e.drops for e in rounds) > 0
    assert sum(e.retries for e in rounds) > 0
    assert all(e.delivered is not None for e in rounds)
    # wire bytes follow the delivered count, priced per session
    mb = res.sessions[0].model_bytes
    assert mb > 0
    assert all(e.wire_bytes == mb * len(e.delivered) for e in rounds)
    stops = [e for e in res.trace if e.phase == "stop"]
    assert len(stops) == 1 and stops[0].stop_reason == res.stop_reason


@pytest.mark.parametrize("engine", ["loop", "fleet"])
def test_cadence_world_events_carry_lane_clocks(problem, engine):
    """Async-cadence observability rides the ONE adapter: the per-event
    clock/idle fields are mapped from the engines' round_clock/idle_steps
    history buffers, never emitted from engine code — and lockstep worlds
    leave them None (absence, not zero)."""
    _, method = _WORLDS["cadence"]
    res = Experiment(_world(problem), method,
                     ExecutionSpec(engine=engine)).run()
    rounds = [e for e in res.trace if e.phase == "round"]
    clock_h = res.sessions[0].history_raw["round_clock"]
    idle_h = res.sessions[0].history_raw["idle_steps"]
    assert [e.clock for e in rounds] == [int(c) for c in clock_h]
    assert [e.idle for e in rounds] == [float(i) for i in idle_h]
    assert all(isinstance(e.clock, int) for e in rounds)
    assert all(isinstance(e.idle, float) for e in rounds)
    # requester stride 2 of 2: clocks advance on the global event
    # counter, strictly faster than the round index, with real idle gaps
    assert all(b > a for a, b in zip([e.clock for e in rounds],
                                     [e.clock for e in rounds][1:]))
    assert rounds[-1].clock > rounds[-1].round
    assert sum(e.idle for e in rounds) > 0
    stop = [e for e in res.trace if e.phase == "stop"]
    assert len(stop) == 1 and stop[0].clock is None and stop[0].idle is None
    # lockstep world: no cadence concept, so the fields stay None
    lock = Experiment(_world(problem), _METHOD,
                      ExecutionSpec(engine=engine)).run()
    assert all(e.clock is None and e.idle is None for e in lock.trace)


# ---------------------------------------------------------------------------
# exporters: JSONL round-trip, schema validation, Chrome trace
# ---------------------------------------------------------------------------


def test_events_jsonl_round_trips(problem, tmp_path):
    res = Experiment(_world(problem), _METHOD,
                     ExecutionSpec(engine="loop")).run()
    path = str(tmp_path / "events.jsonl")
    n = write_events_jsonl(res.trace, path)
    back = read_events_jsonl(path)
    assert n == len(back) == len(res.trace)
    assert back == res.trace          # frozen dataclasses: field equality
    # machine-readable: every line is one standalone JSON object
    with open(path) as f:
        rows = [json.loads(line) for line in f]
    assert len(rows) == n
    assert all(row["phase"] in EVENT_PHASES for row in rows)


def _event(**over):
    base = dict(round=0, requester=0, phase="round", executed=True,
                members=None, member_set=None, delivered=None,
                drops=0.0, retries=0.0, stale=0.0, battery=None,
                accuracy=0.5, loss=None, wire_bytes=0, energy_j=None,
                stop_reason=None)
    base.update(over)
    return RoundEvent(**base)


def test_validate_events_rejects_schema_violations():
    ok = [_event(), _event(round=1),
          _event(round=2, phase="stop", stop_reason="accuracy_reached")]
    assert validate_events(ok) == ok
    with pytest.raises(ValueError, match="phase"):
        validate_events([_event(phase="negotiate")])
    with pytest.raises(ValueError, match="stop_reason"):
        validate_events([_event(phase="stop")])          # stop w/o reason
    with pytest.raises(ValueError, match="stop_reason"):
        validate_events([_event(stop_reason="oops")])    # reason on round
    with pytest.raises(ValueError, match="does not follow"):
        validate_events([_event(), _event(round=2)])     # round gap
    with pytest.raises(ValueError, match="already stopped"):
        validate_events([_event(phase="stop", stop_reason="x"),
                         _event(round=1)])
    with pytest.raises(ValueError, match="bool"):
        validate_events([_event(wire_bytes=True)])       # bool is not int
    with pytest.raises(ValueError, match="accuracy"):
        validate_events([_event(accuracy=None)])         # non-noneable


def test_compare_event_streams_reports_diffs():
    a = [_event(accuracy=0.5)]
    assert compare_event_streams(a, [_event(accuracy=0.5 + 1e-6)]) == []
    assert compare_event_streams(a, [_event(accuracy=0.9)])
    assert compare_event_streams(a, [_event(drops=1.0)])
    assert compare_event_streams(a, a + [_event(round=1)])


def test_chrome_trace_structure(tmp_path):
    tl = Timeline()
    with tl.span("stage", what="x"):
        with tl.span("quantize_pack"):
            pass
    with tl.span("program", cache_miss=True):
        pass
    doc = timeline_chrome_trace(tl)
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    evs = doc["traceEvents"]
    assert [e["name"] for e in evs] == ["stage", "quantize_pack", "program"]
    for e in evs:
        assert e["ph"] == "X" and e["ts"] >= 0 and e["dur"] >= 0
        assert e["cat"] == "repro"
    assert evs[0]["args"] == {"what": "x"}
    # nested span lies inside its parent on the µs timeline
    assert evs[1]["ts"] >= evs[0]["ts"]
    assert evs[1]["ts"] + evs[1]["dur"] <= evs[0]["ts"] + evs[0]["dur"]
    path = str(tmp_path / "trace.json")
    assert write_chrome_trace(tl, path) == 3
    with open(path) as f:
        assert json.load(f) == doc


# ---------------------------------------------------------------------------
# Timeline spans
# ---------------------------------------------------------------------------


def test_timeline_nesting_and_totals():
    tl = Timeline()
    with tl.span("outer"):
        with tl.span("inner"):
            pass
        with tl.span("inner"):
            pass
    outer, i1, i2 = tl.spans
    assert (outer.depth, i1.depth, i2.depth) == (0, 1, 1)
    assert i1.parent == 0 and i2.parent == 0
    totals = tl.totals()
    # nested spans total under their own name, inside the parent's wall
    assert totals["inner"] <= totals["outer"]
    assert tl.total("inner") == totals["inner"]
    assert tl.total("missing") == 0.0


def test_timeline_finish_is_strictly_lifo():
    tl = Timeline()
    a = tl.begin("a")
    tl.begin("b")
    with pytest.raises(RuntimeError, match="innermost"):
        tl.finish(a)


def test_open_span_excluded_from_totals_and_trace():
    tl = Timeline()
    tl.begin("open")
    assert tl.totals() == {}
    assert timeline_chrome_trace(tl)["traceEvents"] == []


# ---------------------------------------------------------------------------
# the ExecutionSpec knob
# ---------------------------------------------------------------------------


def test_execution_spec_rejects_non_trace_config():
    with pytest.raises(ValueError, match="TraceConfig"):
        ExecutionSpec(trace={"events_jsonl": "x.jsonl"})


def test_loop_engine_warns_on_fleet_only_trace_knobs(problem, tmp_path):
    trace = TraceConfig(hlo_stats=True)
    with pytest.warns(UserWarning, match="hlo_stats"):
        Experiment(_world(problem), _METHOD,
                   ExecutionSpec(engine="loop", trace=trace)).run()
