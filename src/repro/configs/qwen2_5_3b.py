"""Qwen2.5-3B [hf:Qwen/Qwen2.5-0.5B family card] — dense GQA decoder
with QKV bias (the Qwen signature).

Assigned spec: 36L, d_model=2048, 16H (GQA kv=2, head_dim 128),
d_ff=11008, vocab=151936.  Full attention => long_500k skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    citation="hf:Qwen/Qwen2.5-0.5B",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11008,
    vocab_size=151_936,
    block_pattern=("attn",),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    dtype="bfloat16",
)
