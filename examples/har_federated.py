"""Full HAR comparison scenario: EnFed vs CFL vs DFL(mesh/ring) vs
cloud-only, on both paper datasets (calories->MLP, HARSense->LSTM).

This is the experiment behind Tables IV/V/VII of the paper, at example
scale (the full benchmark lives in benchmarks/).

  PYTHONPATH=src python examples/har_federated.py [--dataset har|calories]
                                                  [--engine loop|fleet]
                                                  [--churn]

``--engine fleet`` runs the same EnFed session through the jit-native
fleet engine (repro.core.fleet) instead of the Python round loop — same
protocol, same result (parity-tested), one compiled program.

``--churn`` turns on the opportunistic world (repro.core.mobility): the
neighbors walk random-waypoint trajectories, contracts are re-negotiated
every round as devices enter/leave radio range or hit their battery
floor, and the walkthrough prints the per-round membership so you can
watch the requester keep training while its neighborhood churns.
"""

import argparse

import numpy as np

from repro.core import (CFLLearner, DFLLearner, EnFedConfig, EnFedSession,
                        MobilityConfig, SupervisedTask, cloud_only_baseline,
                        make_fleet)
from repro.data import (CaloriesDatasetConfig, HARDatasetConfig,
                        dirichlet_partition, make_calories_tabular,
                        make_har_windows)
from repro.models import (LSTMClassifier, LSTMClassifierConfig, MLPClassifier,
                          MLPClassifierConfig)


def build(dataset: str):
    if dataset == "har":
        x, y, _ = make_har_windows(HARDatasetConfig(num_samples=3000, seq_len=32))
        task = SupervisedTask(LSTMClassifier(LSTMClassifierConfig(6, 32, 64, 6)), lr=3e-3)
    else:
        x, y = make_calories_tabular(CaloriesDatasetConfig(num_samples=3000))
        task = SupervisedTask(MLPClassifier(MLPClassifierConfig(8, (64, 32), 5)), lr=3e-3)
    parts = dirichlet_partition(y, num_clients=6, alpha=1.0, seed=0)
    shards = [(x[p], y[p]) for p in parts]
    own_x, own_y = shards[0]
    n = int(len(own_x) * 0.8)
    return task, shards, (own_x[:n], own_y[:n]), (own_x[n:], own_y[n:]), (x, y)


def churn_walkthrough(task, shards, own_train, own_test, args):
    """The opportunistic-world demo: one requester keeps training for the
    whole round budget while neighbors churn through its radio range.

    Every round the session re-negotiates: contributors that wandered
    out of the 90 m range (or drained to the battery floor) are
    released, devices that wandered in are signed, and a higher-utility
    arrival displaces the weakest member.  Rounds with an EMPTY
    neighborhood are survivable — the requester trains alone on its own
    shard.  Both engines derive the identical world; pick with --engine.
    """
    fleet = make_fleet(5, seed=1, p_has_model=1.0)
    states = {}
    for i, dev in enumerate(fleet):
        dev.reservation_price = 0.4
        p = task.init(seed=10 + i)
        p, _ = task.fit(p, shards[i + 1], epochs=1, batch_size=32, seed=i)
        states[dev.device_id] = {"params": p, "data": shards[i + 1]}
    cfg = EnFedConfig(
        desired_accuracy=args.target, epochs=args.epochs, max_rounds=10,
        n_max=3, contributor_refresh_epochs=1,
        mobility=MobilityConfig(arena_m=200.0, radio_range_m=90.0,
                                leg_rounds=2, seed=5))
    res = EnFedSession(task, own_train, own_test, fleet, states,
                       cfg).run(engine=args.engine)

    print(f"\n=== churn walkthrough ({args.dataset}, engine={args.engine}) ===")
    print(f"{'round':>5} {'members':>8} {'contract set':<18} {'acc':>6} {'battery':>8}")
    prev = None
    for r in range(res.rounds):
        mask = np.asarray(res.history["member_mask"][r]) > 0
        ids = [d for d, m in enumerate(mask) if m]
        note = ""
        if prev is not None:
            joined = sorted(set(ids) - set(prev))
            left = sorted(set(prev) - set(ids))
            bits = ([f"+{j}" for j in joined] + [f"-{l}" for l in left])
            note = "  " + " ".join(bits) if bits else ""
        print(f"{r:>5} {int(mask.sum()):>8} {str(ids):<18} "
              f"{res.history['accuracy'][r]:6.3f} "
              f"{res.history['battery'][r]:8.3f}{note}")
        prev = ids
    print(f"requester finished: {res.rounds} rounds, stop={res.stop_reason}, "
          f"final acc {res.accuracy:.3f}")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", choices=("har", "calories"), default="har")
    ap.add_argument("--target", type=float, default=0.95)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--engine", choices=("loop", "fleet"), default="loop",
                    help="EnFed execution engine (fleet = one jit program)")
    ap.add_argument("--churn", action="store_true",
                    help="opportunistic-world walkthrough: neighbors enter/"
                         "leave radio range mid-session (repro.core.mobility)")
    args = ap.parse_args()

    task, shards, own_train, own_test, pooled = build(args.dataset)
    if args.churn:
        return churn_walkthrough(task, shards, own_train, own_test, args)

    # --- EnFed ---------------------------------------------------------
    fleet = make_fleet(5, seed=1, p_has_model=1.0)
    states = {}
    for i, dev in enumerate(fleet):
        dev.reservation_price = 0.4
        p = task.init(seed=10 + i)
        p, _ = task.fit(p, shards[i + 1], epochs=args.epochs, batch_size=32, seed=i)
        states[dev.device_id] = {"params": p, "data": shards[i + 1]}
    enfed = EnFedSession(task, own_train, own_test, fleet, states,
                         EnFedConfig(desired_accuracy=args.target, epochs=args.epochs,
                                     max_rounds=10)).run(engine=args.engine)

    # --- baselines -----------------------------------------------------
    client_data = [own_train] + shards[1:6]
    cfl = CFLLearner(task, client_data, own_test).run(
        target_accuracy=args.target, max_rounds=10, epochs=args.epochs, batch_size=32)
    dfl_mesh = DFLLearner(task, client_data, own_test, "mesh").run(
        target_accuracy=args.target, max_rounds=10, epochs=args.epochs, batch_size=32)
    dfl_ring = DFLLearner(task, client_data, own_test, "ring").run(
        target_accuracy=args.target, max_rounds=10, epochs=args.epochs, batch_size=32)
    cloud_acc, cloud_resp, _ = cloud_only_baseline(
        task, pooled, own_test, epochs=args.epochs, batch_size=32)

    print(f"\n=== {args.dataset} ===")
    print(f"{'system':<10} {'acc':>6} {'rounds':>6} {'T_train(s)':>11} {'E(J)':>9}")
    print(f"{'EnFed':<10} {enfed.accuracy:6.3f} {enfed.rounds:6d} "
          f"{enfed.report.t_train:11.2f} {enfed.report.e_tot:9.2f}")
    print(f"{'CFL':<10} {cfl.accuracy:6.3f} {cfl.rounds:6d} "
          f"{cfl.report.t_train:11.2f} {cfl.report.e_tot:9.2f}")
    print(f"{'DFL-mesh':<10} {dfl_mesh.accuracy:6.3f} {dfl_mesh.rounds:6d} "
          f"{dfl_mesh.report.t_train:11.2f} {dfl_mesh.report.e_tot:9.2f}")
    print(f"{'DFL-ring':<10} {dfl_ring.accuracy:6.3f} {dfl_ring.rounds:6d} "
          f"{dfl_ring.report.t_train:11.2f} {dfl_ring.report.e_tot:9.2f}")
    print(f"{'cloud':<10} {cloud_acc:6.3f} {'-':>6} {cloud_resp:11.2f} {'-':>9}  (response time)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
