"""Pytree checkpointing to .npz (no orbax in this environment).

Layout: ``<dir>/step_<N>.npz`` holding flattened leaves keyed by their
tree path, plus a ``__treedef__`` marker reconstructed from a template
pytree on restore (restore requires a structural template, which the
training loop always has: its freshly-initialized state).
"""

from __future__ import annotations

import os
import re
from typing import Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(directory: str, step: int, state) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"step_{step:08d}.npz")
    tmp = path + ".tmp"
    np.savez(tmp, **_flatten_with_paths(state))
    os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"step_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template, step: Optional[int] = None):
    """Restore into the structure of ``template``. Returns (state, step)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}.npz")
    data = np.load(path)
    flat_paths = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat_paths[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs template {np.shape(leaf)}")
        leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(flat_paths[1], leaves), step
