"""Public op: masked-weighted FedAvg over pytrees or flat stacks.

``fedavg_flat`` is the jit'd wrapper over the Pallas kernel;
``interpret=None`` (the default everywhere) resolves per backend via
``repro.kernels.common.resolve_interpret`` — compiled on TPU,
interpreted on CPU.  ``fedavg_tree`` applies it to a contributor-stacked
pytree by flattening leaves into one (N, L) stream — the same
serialization the AES transport uses, so on a real deployment decrypt +
aggregate fuse into one pass over the wire buffer.

The fleet engine (``repro.core.fleet``) does not pay the per-round
flatten: it ravels contributor params once at setup
(``repro.utils.tree.tree_ravel``) and launches ``fedavg_flat_batched``
directly on the flat (R, N, P) round-state buffer.  ``fedavg_tree_batched``
remains for callers that hold a stacked pytree.

``fedavg_flat_batched_q8`` is the same hot path when the round state is
int8-compressed (``EnFedConfig.compress="int8"``): the decrypt+aggregate
fuse above extended one stage further — dequantize (``q * scale``, the
exact wire inverse) and the masked weighted mean run as ONE pass over
the wire-format buffer, so the fp32 (R, N, P) block a standalone dequant
would materialize never exists; the refresh-side requantize
(``repro.kernels.quantize.ops.quantize_flat_batched``) closes the loop
back into wire format.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fedavg.kernel import (fedavg_batched_pallas,
                                         fedavg_batched_q8_pallas,
                                         fedavg_pallas)
from repro.kernels.fedavg.ref import (fedavg_batched_q8_ref,
                                      fedavg_batched_ref, fedavg_ref)


def fedavg_flat(updates, weights, *, use_pallas: bool = True, interpret=None):
    if use_pallas:
        return fedavg_pallas(updates, weights, interpret=interpret)
    return fedavg_ref(updates, weights)


def fedavg_flat_batched(updates, weights, *, use_pallas: bool = True,
                        interpret=None):
    """updates: (R, N, L); weights: (R, N) -> (R, L) fp32 per-session means.

    ``weights`` may be a traced per-round vector — under mobility
    (``repro.core.mobility``) the fleet engine passes each round's
    re-negotiated membership mask directly, so churn costs no extra
    kernel.  An all-zero weight row (a session whose whole neighborhood
    churned out of range) is well-defined: the kernel's
    ``max(sum_w, 1e-9)`` denominator returns a zero vector, and the
    caller substitutes the session's previous params.
    """
    if use_pallas:
        return fedavg_batched_pallas(updates, weights, interpret=interpret)
    return fedavg_batched_ref(updates, weights)


def fedavg_flat_batched_q8(q, scales, weights, *, use_pallas: bool = True,
                           interpret=None):
    """q: (R, N, Lp) int8 wire payload; scales: (R, N, Lp/TILE) fp32;
    weights: (R, N) -> (R, Lp) fp32 per-session means.

    The fused dequant->fedavg pipeline over the compressed round state.
    Semantics match ``fedavg_flat_batched(dequantize(q, scales), w)``
    exactly (same masked mean, same all-zero-row behaviour) without ever
    materializing the dequantized block; callers slice ``[:, :P]`` to
    drop the tile padding (which dequantizes to zero by construction).
    """
    if use_pallas:
        return fedavg_batched_q8_pallas(q, scales, weights,
                                        interpret=interpret)
    return fedavg_batched_q8_ref(q, scales, weights)


def fedavg_tree_batched(stacked_tree, weights, *, use_pallas: bool = True,
                        interpret=None):
    """Requester-batched tree aggregation for stacked-pytree callers.

    Leaves of ``stacked_tree`` have shape (R, N, ...): R concurrent
    requester sessions, N contributor slots each.  Returns the pytree of
    per-session aggregated params with leaves (R, ...).  All leaves are
    flattened into one (R, N, L) stream so the whole fleet's eq. (14)
    is a single kernel launch.  (The fleet engine skips this per-call
    flatten entirely by carrying its round state pre-raveled.)
    """
    leaves, treedef = jax.tree_util.tree_flatten(stacked_tree)
    r, n = leaves[0].shape[:2]
    sizes = [int(x.size) // (r * n) for x in leaves]
    flat = jnp.concatenate(
        [x.reshape(r, n, -1).astype(jnp.float32) for x in leaves], axis=2)
    avg = fedavg_flat_batched(flat, weights, use_pallas=use_pallas,
                              interpret=interpret)
    out, off = [], 0
    for leaf, sz in zip(leaves, sizes):
        out.append(avg[:, off:off + sz].reshape((r,) + leaf.shape[2:]).astype(leaf.dtype))
        off += sz
    return jax.tree_util.tree_unflatten(treedef, out)


def fedavg_tree(stacked_tree, weights, *, use_pallas: bool = True, interpret=None):
    """Leaves of ``stacked_tree`` have shape (N, ...); returns mean tree."""
    leaves, treedef = jax.tree_util.tree_flatten(stacked_tree)
    n = leaves[0].shape[0]
    sizes = [int(x.size) // n for x in leaves]
    flat = jnp.concatenate([x.reshape(n, -1).astype(jnp.float32) for x in leaves], axis=1)
    avg = fedavg_flat(flat, weights, use_pallas=use_pallas, interpret=interpret)
    out, off = [], 0
    for leaf, sz in zip(leaves, sizes):
        out.append(avg[off:off + sz].reshape(leaf.shape[1:]).astype(leaf.dtype))
        off += sz
    return jax.tree_util.tree_unflatten(treedef, out)
