"""Shared kernel-launch policy.

Every Pallas kernel in ``repro.kernels`` takes an ``interpret`` flag.
``interpret=True`` runs the kernel body as a jax interpreter program
(correct on any backend, used by the CPU test/CI tier);
``interpret=False`` compiles the kernel for the accelerator.  Callers
that don't care pass ``None`` and get the right default for the active
backend: real compilation on TPU, interpret mode everywhere Pallas
cannot lower natively (CPU CI images, laptops).
"""

from __future__ import annotations

from typing import Optional

import jax


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve an ``interpret`` request against the active backend.

    ``None`` (the default everywhere) means "interpret only if the
    backend cannot compile Pallas", i.e. ``jax.default_backend() ==
    "cpu"``.  Explicit ``True``/``False`` is passed through, so tests can
    force interpret mode and TPU users can force compilation.
    """
    if interpret is None:
        return jax.default_backend() == "cpu"
    return bool(interpret)
