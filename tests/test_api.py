"""The repro.api facade: parity with the legacy entrypoints, the unified
result schema, the shared-cost-model comparison, and the engine-knob
plumbing the facade subsumes.

Parity discipline: `Experiment.run(method="enfed")` must be a pure
re-expression of the legacy paths — bit-identical membership masks,
rounds, stop reasons and battery trajectories, and (bitwise, since it is
literally the same code on the same inputs) identical params — on static
AND mobility worlds, through BOTH engines.
"""

import copy
import dataclasses
import inspect

import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.api import (CompareResult, ExecutionSpec, Experiment, MethodSpec,
                       RunResult, WorldSpec, method_names)
from repro.core import (EnFedConfig, EnFedSession, MobilityConfig,
                        RequesterSpec, SupervisedTask, make_fleet, run_fleet)
from repro.core.energy import CostModel, DeviceProfile
from repro.data import CaloriesDatasetConfig, dirichlet_partition, make_calories_tabular
from repro.models import MLPClassifier, MLPClassifierConfig

BATCH = 16


def _build(n_contrib=3, n_samples=600, seed=0):
    x, y = make_calories_tabular(CaloriesDatasetConfig(num_samples=n_samples))
    task = SupervisedTask(MLPClassifier(MLPClassifierConfig(8, (16,), 5)), lr=3e-3)
    parts = dirichlet_partition(y, num_clients=n_contrib + 1, alpha=100.0, seed=seed)
    shards = [(x[p], y[p]) for p in parts]
    own_x, own_y = shards[0]
    n = int(len(own_x) * 0.8)
    own_train, own_test = (own_x[:n], own_y[:n]), (own_x[n:], own_y[n:])
    fleet = make_fleet(n_contrib, seed=1, p_has_model=1.0)
    states = {}
    for i, dev in enumerate(fleet):
        dev.reservation_price = 0.4
        p = task.init(seed=10 + i)
        p, _ = task.fit(p, shards[i + 1], epochs=1, batch_size=BATCH, seed=i)
        states[dev.device_id] = {"params": p, "data": shards[i + 1]}
    return task, own_train, own_test, fleet, states


@pytest.fixture(scope="module")
def problem():
    return _build()


def _world(problem, mobility=None):
    task, own_train, own_test, fleet, states = problem
    return WorldSpec.single(task, own_train, own_test, fleet,
                            copy.deepcopy(states), mobility=mobility)


_METHOD = MethodSpec(desired_accuracy=0.99, max_rounds=2, epochs=1,
                     batch_size=BATCH, encrypt=False,
                     contributor_refresh_epochs=1)

_MOB = MobilityConfig(radio_range_m=95.0, leg_rounds=1, seed=5)
_MOB_METHOD = dataclasses.replace(_METHOD, desired_accuracy=0.999,
                                  max_rounds=4, n_max=2)


def _legacy_cfg(method: MethodSpec, mobility=None) -> EnFedConfig:
    return EnFedConfig(
        desired_accuracy=method.desired_accuracy, max_rounds=method.max_rounds,
        n_max=method.n_max, battery_threshold=method.battery_threshold,
        offered_incentive=method.offered_incentive, epochs=method.epochs,
        batch_size=method.batch_size, encrypt=method.encrypt,
        contributor_refresh_epochs=method.contributor_refresh_epochs,
        seed=0, strategy=method.strategy, mobility=mobility)


def _assert_session_parity(facade_res, legacy, *, mobility: bool):
    """Facade requester-0 view == the legacy SessionResult, bit for bit
    on masks/battery, exactly on params (same code, same inputs)."""
    s = facade_res.sessions[0]
    assert facade_res.rounds == legacy.rounds == s.rounds
    assert facade_res.stop_reason == legacy.stop_reason == s.stop_reason
    np.testing.assert_array_equal(facade_res.history_raw["battery"],
                                  legacy.history_raw["battery"])
    np.testing.assert_array_equal(facade_res.history_raw["accuracy"],
                                  legacy.history_raw["accuracy"])
    if mobility:
        np.testing.assert_array_equal(
            np.array(facade_res.history_raw["member_mask"]),
            np.array(legacy.history_raw["member_mask"]))
    fv, _ = ravel_pytree(facade_res.params)
    lv, _ = ravel_pytree(legacy.params)
    np.testing.assert_allclose(np.asarray(fv), np.asarray(lv),
                               rtol=0.0, atol=0.0)


# ---------------------------------------------------------------------------
# facade vs legacy parity: static + mobility, loop + fleet
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["loop", "fleet"])
def test_facade_matches_legacy_static(problem, engine):
    task, own_train, own_test, fleet, states = problem
    res = Experiment(_world(problem), _METHOD,
                     ExecutionSpec(engine=engine)).run()
    cfg = _legacy_cfg(_METHOD)
    if engine == "loop":
        legacy = EnFedSession(task, own_train, own_test, fleet,
                              copy.deepcopy(states), cfg).run()
    else:
        legacy = run_fleet(task, [RequesterSpec(own_train, own_test, fleet,
                                                copy.deepcopy(states))],
                           cfg).sessions[0]
    assert res.method == "enfed" and res.engine == engine
    _assert_session_parity(res, legacy, mobility=False)


@pytest.mark.parametrize("engine", ["loop", "fleet"])
def test_facade_matches_legacy_mobility(problem, engine):
    task, own_train, own_test, fleet, states = problem
    res = Experiment(_world(problem, mobility=_MOB), _MOB_METHOD,
                     ExecutionSpec(engine=engine)).run()
    cfg = _legacy_cfg(_MOB_METHOD, mobility=_MOB)
    if engine == "loop":
        legacy = EnFedSession(task, own_train, own_test, fleet,
                              copy.deepcopy(states), cfg).run()
    else:
        legacy = run_fleet(task, [RequesterSpec(own_train, own_test, fleet,
                                                copy.deepcopy(states))],
                           cfg).sessions[0]
    assert res.history_raw["members"]  # the world actually re-negotiates
    _assert_session_parity(res, legacy, mobility=True)


def test_facade_multi_requester_mobility_engine_invariance(problem):
    """A 3-requester mobility world through BOTH engines: requester i
    must walk as device requester_id + i in either, so the engine choice
    never changes which world (masks, rounds, params) a requester sees."""
    task, own_train, own_test, fleet, states = problem
    mob = MobilityConfig(radio_range_m=110.0, leg_rounds=2, seed=3)

    def world3():
        return WorldSpec(task=task, requesters=[
            RequesterSpec(own_train, own_test, fleet, copy.deepcopy(states))
            for _ in range(3)], mobility=mob)

    res = {e: Experiment(world3(), _MOB_METHOD, ExecutionSpec(engine=e)).run()
           for e in ("loop", "fleet")}
    members = [res["fleet"].sessions[i].history_raw["members"] for i in range(3)]
    assert any(m != members[0] for m in members), \
        "requesters should see distinct neighborhoods"
    for i in range(3):
        sl, sf = res["loop"].sessions[i], res["fleet"].sessions[i]
        assert sl.rounds == sf.rounds and sl.stop_reason == sf.stop_reason
        np.testing.assert_array_equal(np.array(sl.history_raw["member_mask"]),
                                      np.array(sf.history_raw["member_mask"]))
        lv, _ = ravel_pytree(sl.params)
        fv, _ = ravel_pytree(sf.params)
        np.testing.assert_allclose(np.asarray(fv), np.asarray(lv),
                                   rtol=1e-4, atol=1e-5)


def test_facade_runs_are_independent(problem):
    """run() copies the world's mutable state: two runs are identical,
    and the WorldSpec's contributor params are never trained in place."""
    world = _world(problem)
    p_before, _ = ravel_pytree(
        next(iter(world.requesters[0].contributor_states.values()))["params"])
    exp = Experiment(world, _METHOD, ExecutionSpec(engine="loop"))
    a, b = exp.run(), exp.run()
    av, _ = ravel_pytree(a.params)
    bv, _ = ravel_pytree(b.params)
    np.testing.assert_array_equal(np.asarray(av), np.asarray(bv))
    p_after, _ = ravel_pytree(
        next(iter(world.requesters[0].contributor_states.values()))["params"])
    np.testing.assert_array_equal(np.asarray(p_before), np.asarray(p_after))


# ---------------------------------------------------------------------------
# compare(): one world, one seed, ONE cost model
# ---------------------------------------------------------------------------


def test_compare_all_methods_share_one_cost_model(problem):
    world = _world(problem)
    cmp = Experiment(world, _METHOD).compare(["enfed", "dfl", "cfl", "cloud"])
    assert isinstance(cmp, CompareResult)
    assert list(cmp.results) == ["enfed", "dfl", "cfl", "cloud"]
    for res in cmp:
        assert isinstance(res, RunResult)
        # every method's energy figures come from the SAME CostModel
        # instance the world declares
        assert res.cost_model is world.cost_model
        assert res.sessions and res.report is res.sessions[0].report
        assert np.isfinite(res.energy_j) and res.energy_j > 0.0
        assert np.isfinite(res.simulated_s) and res.simulated_s > 0.0
    row = cmp.reduction("enfed", "dfl")
    for k in ("time_reduction_pct", "energy_reduction_pct",
              "t_method_s", "e_baseline_j"):
        assert np.isfinite(row[k])
    assert len(cmp.reductions("enfed")) == 3
    assert "enfed" in cmp.table() and "cloud" in cmp.table()


def test_compare_cost_model_actually_flows(problem):
    """Scaling the device's power profile must scale EVERY method's
    reported energy — no baseline silently costing through a private
    default CostModel."""
    task, own_train, own_test, fleet, states = problem
    worlds = []
    for scale in (1.0, 10.0):
        d = DeviceProfile()
        dev = dataclasses.replace(d, p_tx=d.p_tx * scale, p_rx=d.p_rx * scale,
                                  p_train=d.p_train * scale,
                                  p_agg=d.p_agg * scale,
                                  p_crypto=d.p_crypto * scale,
                                  p_init=d.p_init * scale)
        worlds.append(WorldSpec.single(task, own_train, own_test, fleet,
                                       copy.deepcopy(states),
                                       cost_model=CostModel(device=dev)))
    for m in ("enfed", "dfl", "cfl", "cloud"):
        e1 = Experiment(worlds[0], _METHOD).run(m).energy_j
        e10 = Experiment(worlds[1], _METHOD).run(m).energy_j
        assert e10 > 2.0 * e1, (m, e1, e10)


def test_dfl_topologies_coexist_via_labels(problem):
    cmp = Experiment(_world(problem), _METHOD).compare([
        dataclasses.replace(_METHOD, name="dfl", topology="mesh",
                            label="dfl-mesh"),
        dataclasses.replace(_METHOD, name="dfl", topology="ring",
                            label="dfl-ring")])
    assert list(cmp.results) == ["dfl-mesh", "dfl-ring"]
    # mesh exchanges with all 3 peers, ring with 2 — its (analytic,
    # deterministic) per-round communication time must be strictly larger
    assert (cmp["dfl-mesh"].report.times.t_com
            > cmp["dfl-ring"].report.times.t_com)
    # coercing a bare name inherits knobs but NOT the base spec's label
    labeled = dataclasses.replace(_METHOD, name="dfl", label="dfl-mesh")
    assert MethodSpec.coerce("enfed", like=labeled).key == "enfed"


def test_baselines_warn_when_mobility_world_is_dropped(problem):
    """Only EnFed executes world.mobility; a baseline on a churn world
    must WARN that the mobility axis is ignored — never silently produce
    an apples-to-oranges comparison row."""
    method = dataclasses.replace(_MOB_METHOD, max_rounds=1)
    with pytest.warns(UserWarning, match="ignores world.mobility"):
        Experiment(_world(problem, mobility=_MOB), method).run("dfl")
    import warnings as _w

    with _w.catch_warnings():
        # static world: no mobility warning (UserWarning only — don't
        # escalate unrelated toolchain DeprecationWarnings)
        _w.simplefilter("error", UserWarning)
        Experiment(_world(problem), method).run("dfl")


def test_baselines_honor_fleet_engine(problem):
    """With ExecutionSpec(engine="fleet") the dfl/cfl compare rows come
    from the compiled fleet program — engine recorded as "fleet", raw
    FleetResult attached, NO loop_baseline extrapolation — and match the
    loop-engine rows on the same world + seed."""
    from repro.core.fleet import FleetResult

    results = {e: Experiment(_world(problem), _METHOD,
                             ExecutionSpec(engine=e)).compare(["dfl", "cfl"])
               for e in ("loop", "fleet")}
    for name in ("dfl", "cfl"):
        rl, rf = results["loop"][name], results["fleet"][name]
        assert rl.engine == "loop" and rf.engine == "fleet"
        assert isinstance(rf.raw, FleetResult)
        assert rf.rounds == rl.rounds
        assert rf.stop_reason == rl.stop_reason
        assert rf.sessions[0].battery is None
        np.testing.assert_allclose(rf.history_raw["accuracy"],
                                   rl.history_raw["accuracy"],
                                   rtol=1e-5, atol=1e-6)
        fv, _ = ravel_pytree(rf.params)
        lv, _ = ravel_pytree(rl.params)
        np.testing.assert_allclose(np.asarray(fv), np.asarray(lv),
                                   rtol=1e-4, atol=1e-5)
        # the energy figure is simulated through the shared cost model,
        # not extrapolated: finite and strictly positive
        assert np.isfinite(rf.energy_j) and rf.energy_j > 0.0


def test_deprecated_learner_run_shims_warn(problem):
    """CFLLearner.run / DFLLearner.run are legacy private-kwarg shims;
    they must point callers at run_config via DeprecationWarning."""
    from repro.core.federated import CFLLearner, DFLLearner

    task, own_train, own_test, fleet, states = problem
    data = [own_train] + [states[d.device_id]["data"] for d in fleet]
    with pytest.warns(DeprecationWarning, match="run_config"):
        CFLLearner(task, data, own_test).run(
            target_accuracy=0.05, max_rounds=1, epochs=1, batch_size=BATCH)
    with pytest.warns(DeprecationWarning, match="run_config"):
        DFLLearner(task, data, own_test, "ring").run(
            target_accuracy=0.05, max_rounds=1, epochs=1, batch_size=BATCH)


def test_unknown_method_and_engine_fail_fast(problem):
    with pytest.raises(ValueError, match="unknown method"):
        Experiment(_world(problem), "sputnik").run()
    with pytest.raises(ValueError, match="unknown engine"):
        ExecutionSpec(engine="warp")
    assert set(method_names()) >= {"enfed", "dfl", "cfl", "cloud"}


# ---------------------------------------------------------------------------
# engine-knob plumbing (the bug the ExecutionSpec subsumes)
# ---------------------------------------------------------------------------


def test_session_run_threads_engine_knobs_to_kernel(problem, monkeypatch):
    """Regression: EnFedSession.run(engine="fleet") used to DROP
    interpret/round_chunk on the floor.  Assert the knobs now reach (a)
    run_fleet and (b) the aggregation-kernel launch inside the compiled
    program."""
    from repro.core import fleet as fleet_mod

    task, own_train, own_test, fleet, states = _build(n_samples=400, seed=3)
    seen_run_fleet = {}
    seen_kernel = {}
    real_run_fleet = fleet_mod.run_fleet
    real_kernel = fleet_mod.fedavg_flat_batched

    def spy_run_fleet(*args, **kwargs):
        seen_run_fleet.update(kwargs)
        return real_run_fleet(*args, **kwargs)

    def spy_kernel(updates, weights, **kwargs):
        seen_kernel.update(kwargs)
        return real_kernel(updates, weights, **kwargs)

    monkeypatch.setattr(fleet_mod, "run_fleet", spy_run_fleet)
    monkeypatch.setattr(fleet_mod, "fedavg_flat_batched", spy_kernel)
    cfg = _legacy_cfg(dataclasses.replace(_METHOD, max_rounds=1))
    EnFedSession(task, own_train, own_test, fleet, states, cfg).run(
        engine="fleet", interpret=True, use_pallas=True, round_chunk=2)
    assert seen_run_fleet["interpret"] is True
    assert seen_run_fleet["use_pallas"] is True
    assert seen_run_fleet["round_chunk"] == 2
    # resolve_interpret(True) -> True must arrive at the kernel launch
    assert seen_kernel["interpret"] is True
    assert seen_kernel["use_pallas"] is True


def test_execution_spec_threads_knobs_through_facade(problem, monkeypatch):
    from repro.core import fleet as fleet_mod

    seen = {}
    real = fleet_mod.run_fleet

    def spy(*args, **kwargs):
        seen.update(kwargs)
        return real(*args, **kwargs)

    monkeypatch.setattr(fleet_mod, "run_fleet", spy)
    Experiment(_world(problem), dataclasses.replace(_METHOD, max_rounds=1),
               ExecutionSpec(engine="fleet", interpret=True,
                             round_chunk=3)).run()
    assert seen["interpret"] is True and seen["round_chunk"] == 3


# ---------------------------------------------------------------------------
# shared-mutable-default regression + export surface
# ---------------------------------------------------------------------------


def test_cfg_default_is_not_shared():
    """`cfg=EnFedConfig()` as a def-time default was ONE mutable dataclass
    aliased across all callers; cfg=None must construct per call."""
    assert inspect.signature(run_fleet).parameters["cfg"].default is None
    assert inspect.signature(EnFedSession.__init__).parameters["cfg"].default is None
    s1 = EnFedSession(None, None, None, [], {})
    s2 = EnFedSession(None, None, None, [], {})
    assert s1.cfg is not s2.cfg
    s1.cfg.max_rounds = 777
    assert s2.cfg.max_rounds != 777


def test_core_reexports_facade_and_all():
    import repro.core as core

    for name in ("Experiment", "WorldSpec", "MethodSpec", "ExecutionSpec",
                 "RunResult", "CompareResult", "register_method"):
        assert name in core.__all__
        assert getattr(core, name) is not None
    import repro.api as api

    assert core.Experiment is api.Experiment
    # __all__ is the single consolidated public list: every name resolves
    for name in core.__all__:
        assert getattr(core, name) is not None
