"""Shared experiment harness for the paper-table benchmarks.

Builds the two synthetic datasets, the client fleet, and runs
EnFed / CFL / DFL(mesh,ring) / cloud-only sessions with consistent
hyperparameters (paper Table III: Adam, categorical cross-entropy; local
epochs reduced from the paper's 100 to 8 for CPU walltime — recorded in
EXPERIMENTS.md §Deviations).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core import (CFLLearner, DFLLearner, EnFedConfig, EnFedSession,
                        SupervisedTask, cloud_only_baseline, make_fleet)
from repro.data import (CaloriesDatasetConfig, HARDatasetConfig,
                        dirichlet_partition, make_calories_tabular,
                        make_har_windows)
from repro.models import (LSTMClassifier, LSTMClassifierConfig, MLPClassifier,
                          MLPClassifierConfig)

EPOCHS = 8          # paper: 100 (reduced for CPU; see §Deviations)
BATCH = 32          # B_A
TARGET = 0.95       # A_A: EnFed stops at the desired personalized accuracy
TARGET_DFL = 0.96   # DFL runs until a 'generalized model' (paper §IV-B)
TARGET_CFL = 0.98   # CFL runs until an 'optimized global model' (paper: 99.9%)
MAX_ROUNDS = 10     # R_A
N_CLIENTS = 6       # requester + 5 supporters (paper's VM setup)
SEQ_LEN = 32


@dataclasses.dataclass
class Scenario:
    name: str
    task: SupervisedTask
    shards: list
    own_train: tuple
    own_test: tuple       # requester's personalized test split (EnFed target)
    global_test: tuple    # union-distribution holdout (CFL/DFL targets)
    pooled: tuple


def build_scenario(dataset: str, model_kind: str, seed: int = 0,
                   num_samples: int = 0) -> Scenario:
    """dataset: 'calories' (paper Dataset1) | 'har' (paper Dataset2).
    model_kind: 'lstm' | 'mlp'.  Default sizes give each of the 6 clients
    enough samples to reach the paper's accuracy band."""
    if num_samples == 0:
        num_samples = 9000 if dataset == "calories" else 3000
    if dataset == "har":
        x, y, _ = make_har_windows(HARDatasetConfig(num_samples=num_samples,
                                                    seq_len=SEQ_LEN, seed=seed))
    else:
        x, y = make_calories_tabular(CaloriesDatasetConfig(num_samples=num_samples,
                                                           seed=seed))
    n_classes = int(y.max()) + 1
    if model_kind == "lstm":
        if x.ndim == 2:  # tabular -> repeat as a short sequence for the LSTM
            x = np.repeat(x[:, None, :], 8, axis=1)
        task = SupervisedTask(LSTMClassifier(LSTMClassifierConfig(
            input_dim=x.shape[-1], seq_len=x.shape[1], hidden=64,
            num_classes=n_classes)), lr=3e-3)
    else:
        if x.ndim == 3:  # sequence -> summary features for the MLP
            x = np.concatenate([x.mean(1), x.std(1)], axis=-1)
        task = SupervisedTask(MLPClassifier(MLPClassifierConfig(
            input_dim=x.shape[-1], hidden=(64, 32), num_classes=n_classes)), lr=3e-3)

    parts = dirichlet_partition(y, N_CLIENTS, alpha=1.0, seed=seed)
    shards = [(x[p], y[p]) for p in parts]
    own_x, own_y = shards[0]
    n = int(len(own_x) * 0.8)
    # warm the jit caches so measured wall-times exclude compilation
    warm = task.init(seed=999)
    warm, _ = task.fit(warm, (own_x[:BATCH], own_y[:BATCH]), 1, BATCH, seed=0)
    task.evaluate(warm, (own_x[:BATCH], own_y[:BATCH]))
    rng = np.random.default_rng(seed + 7)
    hold = rng.permutation(len(x))[: max(len(x) // 10, 200)]
    return Scenario(
        name=f"{dataset}/{model_kind}", task=task, shards=shards,
        own_train=(own_x[:n], own_y[:n]), own_test=(own_x[n:], own_y[n:]),
        global_test=(x[hold], y[hold]), pooled=(x, y))


def run_enfed(sc: Scenario, n_contrib: int = 5, epochs: int = EPOCHS,
              target: float = TARGET, seed: int = 0, encrypt: bool = True,
              pretrain_epochs: int = 6):
    fleet = make_fleet(n_contrib, seed=seed + 1, p_has_model=1.0)
    states = {}
    for i, dev in enumerate(fleet):
        dev.reservation_price = 0.4
        p = sc.task.init(seed=10 + i)
        p, _ = sc.task.fit(p, sc.shards[(i % (N_CLIENTS - 1)) + 1],
                           epochs=pretrain_epochs, batch_size=BATCH, seed=i)
        states[dev.device_id] = {"params": p,
                                 "data": sc.shards[(i % (N_CLIENTS - 1)) + 1]}
    cfg = EnFedConfig(desired_accuracy=target, max_rounds=MAX_ROUNDS,
                      n_max=n_contrib, epochs=epochs, batch_size=BATCH,
                      encrypt=encrypt, seed=seed)
    return EnFedSession(sc.task, sc.own_train, sc.own_test, fleet, states, cfg).run()


def run_cfl(sc: Scenario, epochs: int = EPOCHS, target: float = TARGET_CFL, seed: int = 0):
    client_data = [sc.own_train] + sc.shards[1:N_CLIENTS]
    cfg = EnFedConfig(desired_accuracy=target, max_rounds=MAX_ROUNDS,
                      epochs=epochs, batch_size=BATCH, seed=seed)
    return CFLLearner(sc.task, client_data, sc.global_test).run_config(cfg)


def run_dfl(sc: Scenario, topology: str, n_nodes: int = N_CLIENTS,
            epochs: int = EPOCHS, target: float = TARGET_DFL, seed: int = 0):
    client_data = ([sc.own_train] + sc.shards[1:N_CLIENTS])[:n_nodes]
    cfg = EnFedConfig(desired_accuracy=target, max_rounds=MAX_ROUNDS,
                      epochs=epochs, batch_size=BATCH, seed=seed)
    return DFLLearner(sc.task, client_data, sc.global_test, topology).run_config(cfg)


def run_cloud(sc: Scenario, epochs: int = EPOCHS, seed: int = 0):
    return cloud_only_baseline(sc.task, sc.pooled, sc.own_test,
                               epochs=epochs, batch_size=BATCH, seed=seed)
