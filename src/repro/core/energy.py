"""Time and energy accounting — paper §III-A/III-B, equations (4)-(7).

    T_train = T_dev + T_hand + T_key + T_init + T_com
            + T_enc + T_dec + T_agg + T_loc                      (4)
    E_tot   = E_comp + E_comm                                     (5)
    E_comp  = T_init*E_ci + (T_enc+T_dec)*E_c + T_agg*E_ca + T_loc*E_cl   (6)
    E_comm  = (T_dev+T_hand)*E_s + (T_hand+T_key+T_com)*E_r       (7)

The device profile defaults approximate the paper's simulation setting
("mobile device with an average power consumption of 5 watts per unit
time") with per-mode powers; the link profile approximates OFDMA WiFi.
``measured_local_time`` lets the fleet simulator substitute the actual
wall-clock of local fitting for the analytic T_loc term (semi-empirical
mode, matching how the paper measures on VMs).

The same model, fed with roofline terms from the compiled dry-run
(FLOP-seconds x chip W, collective bytes x link W), produces the TPU
energy estimates in EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


def update_wire_bytes(num_params: int, *, encrypt: bool = True,
                      compress: Optional[str] = None,
                      raw_bytes: Optional[int] = None) -> int:
    """Bytes ONE model update occupies on the wire — the ``model_bytes``
    every eq. (4)-(7) term is priced from.

    This is the single place the ``EnFedConfig.compress`` protocol knob
    meets the cost model: under ``compress="int8"`` the update travels
    as a tile-padded int8 payload plus one fp32 scale per tile (~4x
    fewer bytes, see ``repro.kernels.quantize.ops.compressed_nbytes``),
    and AES-CTR preserves length so the count is the same encrypted or
    not.  Uncompressed, an encrypted update is the serialized fp32
    stream (``4 * num_params``); a plaintext one is the raw tree bytes
    when the caller supplies them.  Both engines and the re-plumbed
    CFL/DFL baselines MUST derive ``model_bytes`` through this helper so
    their transmission/crypto energies (and therefore battery
    trajectories) agree bit-exactly under every knob setting.

    ``compress="auto"`` resolves here through the same
    :func:`repro.kernels.quantize.ops.resolve_compress` crossover the
    engines use — the knob can be passed straight down from any config
    and the pricing still lands on the format actually on the wire.
    """
    if compress == "auto":
        from repro.kernels.quantize.ops import resolve_compress
        compress = resolve_compress("auto", num_params)
    if compress == "int8":
        from repro.kernels.quantize.ops import compressed_nbytes
        return compressed_nbytes(num_params)
    if compress is not None:
        raise ValueError(f"unknown compress mode {compress!r} (None|'int8'|'auto')")
    if encrypt or raw_bytes is None:
        return 4 * num_params
    return raw_bytes


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Per-mode average power draw (W) and compute throughput."""

    name: str = "mobile-5w"
    p_tx: float = 1.6            # E_s: transmit mode
    p_rx: float = 1.2            # E_r: receive mode
    p_init: float = 0.8          # E_ci: model initialization
    p_crypto: float = 1.0        # E_c: AES encrypt/decrypt
    p_agg: float = 1.5           # E_ca: aggregation
    p_train: float = 5.0         # E_cl: local training (paper: 5 W average)
    p_idle: float = 0.05         # low-power listen draw while waiting out
                                 # cadence idle / duty-cycle-off windows
    flops: float = 8e9           # sustained training FLOP/s of the device
    crypto_bytes_per_s: float = 80e6   # AES-128 throughput
    agg_params_per_s: float = 400e6    # aggregation throughput (params/s)
    battery_capacity_j: float = 40e3   # ~ 3000 mAh @ 3.7 V


@dataclasses.dataclass(frozen=True)
class LinkProfile:
    name: str = "ofdma-wifi"
    rate_bps: float = 40e6       # rho: data transmission rate
    request_bytes: int = 256     # beta: size of the request message
    key_bytes: int = 16          # AES-128 key
    handshake_s: float = 0.02    # per-contributor handshake latency
    # cloud path (for the cloud-only baseline): WAN uplink + server queue
    wan_rate_bps: float = 12e6
    cloud_rtt_s: float = 0.12


@dataclasses.dataclass
class PhaseTimes:
    """All terms of eq. (4), in seconds."""

    t_dev: float = 0.0
    t_hand: float = 0.0
    t_key: float = 0.0
    t_init: float = 0.0
    t_com: float = 0.0
    t_enc: float = 0.0
    t_dec: float = 0.0
    t_agg: float = 0.0
    t_loc: float = 0.0

    @property
    def total(self) -> float:
        return (self.t_dev + self.t_hand + self.t_key + self.t_init + self.t_com
                + self.t_enc + self.t_dec + self.t_agg + self.t_loc)


@dataclasses.dataclass
class EnergyReport:
    times: PhaseTimes
    e_comp: float
    e_comm: float

    @property
    def e_tot(self) -> float:
        return self.e_comp + self.e_comm

    @property
    def t_train(self) -> float:
        return self.times.total


class CostModel:
    """Accumulates eq. (4)-(7) terms for one device over an FL session."""

    def __init__(self, device: DeviceProfile = DeviceProfile(),
                 link: LinkProfile = LinkProfile(),
                 parallel_receive: bool = True):
        self.device = device
        self.link = link
        self.parallel_receive = parallel_receive

    # --- individual phase timings -----------------------------------------
    def t_request(self, n_devices: int) -> float:
        # broadcast request: beta/rho (paper: O(beta/rho) time)
        return 8.0 * self.link.request_bytes / self.link.rate_bps

    def t_handshake(self, n_contrib: int) -> float:
        return n_contrib * self.link.handshake_s

    def t_key_exchange(self, n_contrib: int) -> float:
        if n_contrib <= 0:
            return 0.0   # nobody to exchange keys with (empty neighborhood)
        per = 8.0 * self.link.key_bytes / self.link.rate_bps
        return per if self.parallel_receive else n_contrib * per

    def t_receive_updates(self, n_contrib: int, model_bytes: int) -> float:
        if n_contrib <= 0:
            return 0.0   # member-less round: nothing arrives on the wire
        per = 8.0 * model_bytes / self.link.rate_bps
        return per if self.parallel_receive else n_contrib * per

    def t_crypto(self, model_bytes: int) -> float:
        return model_bytes / self.device.crypto_bytes_per_s

    def t_aggregate(self, n_contrib: int, num_params: int) -> float:
        return n_contrib * num_params / self.device.agg_params_per_s

    def t_local_fit(self, num_params: int, num_samples: int, epochs: int) -> float:
        # fwd+bwd ~ 6 FLOPs per param per sample
        return 6.0 * num_params * num_samples * epochs / self.device.flops

    # --- full-session roll-up ----------------------------------------------
    def session(self, *, rounds: int, n_contrib: int, num_params: int,
                model_bytes: int, num_samples: int, epochs: int,
                n_devices: Optional[int] = None,
                measured_local_time: Optional[float] = None,
                encrypt: bool = True) -> EnergyReport:
        """EnFed session cost for the requesting device (Algorithm 1)."""
        n_devices = n_devices if n_devices is not None else n_contrib
        t = PhaseTimes()
        t.t_dev = self.t_request(n_devices)
        t.t_hand = self.t_handshake(n_contrib)
        t.t_key = self.t_key_exchange(n_contrib)
        t.t_init = 1e-3  # O(1)
        t.t_com = rounds * self.t_receive_updates(n_contrib, model_bytes)
        if encrypt:
            # requester decrypts every received update; its own outbound
            # traffic is requests only, so t_enc covers the (small) ack path
            t.t_dec = rounds * n_contrib * self.t_crypto(model_bytes)
            t.t_enc = rounds * self.t_crypto(self.link.request_bytes)
        t.t_agg = rounds * self.t_aggregate(n_contrib, num_params)
        t.t_loc = (measured_local_time if measured_local_time is not None
                   else rounds * self.t_local_fit(num_params, num_samples, epochs))
        return self._energy(t)

    def round_energy(self, *, n_contrib: int, num_params: int, model_bytes: int,
                     num_samples: int, epochs: int,
                     n_devices: Optional[int] = None,
                     encrypt: bool = True) -> float:
        """E_tot of one EnFed round (eq. 5 with ``rounds=1``).

        This is the per-round battery-discharge constant: given a fixed
        model/contributor population it does not depend on traced state,
        so the fleet engine precomputes it host-side per requester and
        the loop engine charges it after every executed round.  Both
        engines MUST use this method so battery trajectories match.
        """
        return self.session(rounds=1, n_contrib=n_contrib, num_params=num_params,
                            model_bytes=model_bytes, num_samples=num_samples,
                            epochs=epochs, n_devices=n_devices,
                            encrypt=encrypt).e_tot

    def contributor_round_energy(self, *, num_params: int, model_bytes: int,
                                 num_samples: int, refresh_epochs: int,
                                 encrypt: bool = True):
        """One participating round's cost on the CONTRIBUTOR side, split as
        ``(e_tx, e_refresh)``.

        ``e_tx`` — transmit (and, when the transport is encrypted,
        encrypt) one model update; paid every round the device is under
        contract.  ``e_refresh`` — the between-round local training of
        Phase.REFRESH; paid only when the session continues past the
        round.  The mobility layer (``repro.core.mobility``) discharges
        contributor batteries with these constants in BOTH engines, which
        is what makes the battery-floor release in
        ``membership_step`` meaningful.
        """
        d = self.device
        t_tx = 8.0 * model_bytes / self.link.rate_bps
        e_tx = t_tx * d.p_tx
        if encrypt:
            e_tx += self.t_crypto(model_bytes) * d.p_crypto
        e_refresh = (self.t_local_fit(num_params, num_samples, refresh_epochs)
                     * d.p_train if refresh_epochs > 0 else 0.0)
        return e_tx, e_refresh

    def retry_energy(self, *, model_bytes: int, encrypt: bool = True,
                     rate_bps: Optional[float] = None):
        """Cost of ONE retransmission of an update, split as
        ``(e_rx, e_tx, t_xfer_s)``.

        A retry re-prices the SAME wire bytes (``model_bytes`` must come
        through :func:`update_wire_bytes`, so the ``compress`` knob
        lowers retry cost exactly like first-attempt cost): the
        requester burns another receive window at ``p_rx`` plus — when
        the transport is encrypted — another decrypt pass at
        ``p_crypto`` (``e_rx``); the contributor re-transmits at
        ``p_tx`` plus the re-encrypt (``e_tx``); ``t_xfer_s`` is the
        extra eq. (4) ``t_com`` wall-clock per retransmission.  The
        fault layer (:mod:`repro.core.faults`) charges these constants
        per extra attempt in BOTH engines, and the dfl/cfl fleet
        variants price their retried transport with the same helper
        (``rate_bps`` overrides the link rate for the CFL WAN path).
        """
        rate = rate_bps if rate_bps is not None else self.link.rate_bps
        t_xfer = 8.0 * model_bytes / rate
        e_rx = t_xfer * self.device.p_rx
        e_tx = t_xfer * self.device.p_tx
        if encrypt:
            e_crypto = self.t_crypto(model_bytes) * self.device.p_crypto
            e_rx += e_crypto
            e_tx += e_crypto
        return e_rx, e_tx, t_xfer

    def idle_energy(self, *, idle_steps: int, idle_step_s: float):
        """Cost of sitting out ``idle_steps`` cadence event steps, split
        as ``(e_idle, t_idle_s)``.

        Under an asynchronous cadence (:mod:`repro.core.cadence`) a
        requester spends global event steps *not* executing a round —
        its own stride skipped the step, its duty window was asleep, or
        it drew a transient-offline step.  Those windows are priced at
        the low-power listen draw ``p_idle`` and land post-hoc in the
        report's ``t_com``/``e_comm`` (the retry-pricing pattern), in
        BOTH engines through this one helper.  Idle never drains the
        simulated battery: the discharge trajectory stays a function of
        executed rounds only, which is what keeps battery levels
        bitwise identical between the engines and across cadence knobs
        that change only the waiting, not the work.
        """
        t_idle = float(idle_steps) * float(idle_step_s)
        return t_idle * self.device.p_idle, t_idle

    def screening_energy(self, *, n_contrib: int, num_params: int):
        """Cost of ONE round's Byzantine-robust screening pass, split as
        ``(e_screen, t_screen_s)``.

        Under ``robust != "none"`` (:mod:`repro.kernels.robust`) the
        requester runs one extra pass over the ``n_contrib x num_params``
        delivered buffer — order statistics or the norm reduction —
        before the aggregate.  That compute is never free: it is priced
        at the aggregation throughput/power of the one device profile
        and lands post-hoc in the report's ``t_agg``/``e_comp`` (the
        retry/idle-pricing pattern), in BOTH engines through this one
        helper.  Screening never drains the simulated battery: the
        discharge trajectory stays a function of executed rounds only,
        which keeps battery levels bitwise identical between a defended
        and an undefended run of the same world — the property the
        robust-recovery bench comparison relies on.
        """
        t_screen = self.t_aggregate(n_contrib, num_params)
        return t_screen * self.device.p_agg, t_screen

    def _energy(self, t: PhaseTimes) -> EnergyReport:
        d = self.device
        e_comp = (t.t_init * d.p_init + (t.t_enc + t.t_dec) * d.p_crypto
                  + t.t_agg * d.p_agg + t.t_loc * d.p_train)
        e_comm = (t.t_dev + t.t_hand) * d.p_tx + (t.t_hand + t.t_key + t.t_com) * d.p_rx
        return EnergyReport(times=t, e_comp=e_comp, e_comm=e_comm)

    # --- baseline frameworks (paper §IV comparisons) ------------------------
    def cfl_session(self, *, rounds: int, num_params: int, model_bytes: int,
                    num_samples: int, epochs: int,
                    measured_local_time: Optional[float] = None) -> EnergyReport:
        """Centralized FL: each round upload + download the model to a server
        over the WAN and train locally. Cost for one participating device."""
        t = PhaseTimes()
        per_xfer = 8.0 * model_bytes / self.link.wan_rate_bps + self.link.cloud_rtt_s
        t.t_com = rounds * 2 * per_xfer          # upload + download
        t.t_init = 1e-3
        t.t_loc = (measured_local_time if measured_local_time is not None
                   else rounds * self.t_local_fit(num_params, num_samples, epochs))
        d = self.device
        e_comp = t.t_init * d.p_init + t.t_loc * d.p_train
        e_comm = rounds * per_xfer * d.p_tx + rounds * per_xfer * d.p_rx
        return EnergyReport(times=t, e_comp=e_comp, e_comm=e_comm)

    def dfl_session(self, *, rounds: int, n_peers: int, num_params: int,
                    model_bytes: int, num_samples: int, epochs: int,
                    topology: str = "mesh",
                    measured_local_time: Optional[float] = None) -> EnergyReport:
        """Decentralized FL: exchange updates with peers each round.
        mesh: every node sends to / receives from all n_peers;
        ring: 2 neighbours only (paper observes ring << mesh cost)."""
        fan = n_peers if topology == "mesh" else 2
        t = PhaseTimes()
        per_xfer = 8.0 * model_bytes / self.link.rate_bps
        t.t_com = rounds * fan * per_xfer                 # receive
        t_send = rounds * fan * per_xfer                  # transmit
        t.t_agg = rounds * self.t_aggregate(fan, num_params)
        t.t_enc = rounds * fan * self.t_crypto(model_bytes)
        t.t_dec = rounds * fan * self.t_crypto(model_bytes)
        t.t_init = 1e-3
        t.t_loc = (measured_local_time if measured_local_time is not None
                   else rounds * self.t_local_fit(num_params, num_samples, epochs))
        d = self.device
        e_comp = (t.t_init * d.p_init + (t.t_enc + t.t_dec) * d.p_crypto
                  + t.t_agg * d.p_agg + t.t_loc * d.p_train)
        e_comm = t_send * d.p_tx + t.t_com * d.p_rx
        rep = EnergyReport(times=t, e_comp=e_comp, e_comm=e_comm)
        rep.times.t_com += t_send  # total wall time includes sending
        return rep

    def round_energy_table(self, *, max_contrib: int, num_params: int,
                           model_bytes: int, num_samples: int, epochs: int,
                           n_devices: Optional[int] = None,
                           encrypt: bool = True):
        """``[round_energy(n_contrib=j) for j in 0..max_contrib]``.

        Under mobility the per-round contributor count is dynamic, so the
        battery-discharge constant becomes this (max_contrib + 1,) lookup
        table: the loop engine indexes it with each round's member count,
        the fleet engine stages it and gathers with the traced count.
        Entry 0 is a member-less round — the requester still fits on its
        own shard (and burns the request broadcast), it just receives
        nothing.
        """
        return [self.round_energy(
            n_contrib=j, num_params=num_params, model_bytes=model_bytes,
            num_samples=num_samples, epochs=epochs, n_devices=n_devices,
            encrypt=encrypt) for j in range(max_contrib + 1)]

    def cloud_session(self, *, data_bytes: int,
                      cloud_train_s: float) -> EnergyReport:
        """Device-side cost of the §IV-G no-FL baseline, in the same
        :class:`EnergyReport` schema as every FL method — so
        ``repro.api.Experiment.compare`` can put "cloud" in one table
        with EnFed/DFL/CFL under one cost model.

        The device uploads its raw dataset (``t_dev`` at transmit
        power), idles through the WAN round trips (``t_com`` at receive
        power), and waits out the server's measured training walltime
        (``t_loc``, burning NO device energy — the training joules are
        the cloud's).  ``times.total`` is therefore exactly the paper's
        response time: upload + RTT + cloud training + RTT.
        """
        t = PhaseTimes()
        t.t_dev = 8.0 * data_bytes / self.link.wan_rate_bps
        t.t_com = 2.0 * self.link.cloud_rtt_s
        t.t_loc = cloud_train_s
        e_comm = t.t_dev * self.device.p_tx + t.t_com * self.device.p_rx
        return EnergyReport(times=t, e_comp=0.0, e_comm=e_comm)

    def cloud_only_response(self, *, data_bytes: int, num_params: int,
                            num_samples: int, epochs: int,
                            cloud_flops: float = 2e11) -> float:
        """Response time of the no-FL cloud baseline: ship raw data up,
        train/infer on the server, ship the result down."""
        t_up = 8.0 * data_bytes / self.link.wan_rate_bps
        t_train = 6.0 * num_params * num_samples * epochs / cloud_flops
        return t_up + self.link.cloud_rtt_s + t_train + self.link.cloud_rtt_s
