"""Pallas TPU kernel: fused LSTM cell.

The paper's classifier hot loop is the per-timestep LSTM cell: two
matmuls into four gates plus a chain of elementwise ops.  Unfused, XLA
materializes the (B, 4H) gate tensor in HBM between the matmul and the
elementwise stage; fused, gates live in VMEM registers and only h/c
(B, H each) are written back — the cell becomes MXU-bound instead of
HBM-bound for the small H typical of HAR models.

Layout: the wrapper reshapes wx (F,4H) -> (F,4,H) and wh -> (H,4,H) so a
BlockSpec can slice one H-tile of all four gates per grid step.  Tiles:
grid (B/Bt, H/Ht), Ht = 128 (lane width), Bt up to 128; x and h enter
with their full contraction dims (F and H are small for this workload —
the whole working set sits in VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret

LANE = 128
SUBLANE = 8


def _lstm_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, h_out_ref, c_out_ref):
    """Blocks: x (Bt,F); h (Bt,H); c (Bt,Ht); wx (F,4,Ht); wh (H,4,Ht);
    b (4,Ht); outs (Bt,Ht)."""
    x = x_ref[...].astype(jnp.float32)
    h = h_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    wx = wx_ref[...].astype(jnp.float32)
    wh = wh_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)

    def gate(g):
        return (jnp.dot(x, wx[:, g, :], preferred_element_type=jnp.float32)
                + jnp.dot(h, wh[:, g, :], preferred_element_type=jnp.float32)
                + b[g])

    i_g = jax.nn.sigmoid(gate(0))
    f_g = jax.nn.sigmoid(gate(1))
    g_g = jnp.tanh(gate(2))
    o_g = jax.nn.sigmoid(gate(3))
    c_new = f_g * c + i_g * g_g
    h_new = o_g * jnp.tanh(c_new)
    h_out_ref[...] = h_new
    c_out_ref[...] = c_new


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lstm_cell_pallas(x, h, c, wx, wh, b, *, interpret=None):
    """Fused LSTM cell. Shapes as in the reference. Returns (h_new, c_new)."""
    interpret = resolve_interpret(interpret)
    B, F = x.shape
    H = h.shape[1]
    # pad to hardware tiles
    Hp = H + ((-H) % LANE)
    Fp = F + ((-F) % SUBLANE)
    Bt = min(128, B + ((-B) % SUBLANE))
    Bp = B + ((-B) % Bt)

    xp = _pad_to(_pad_to(x, 0, Bt), 1, SUBLANE)
    hp = _pad_to(_pad_to(h, 0, Bt), 1, LANE)
    cp = _pad_to(_pad_to(c, 0, Bt), 1, LANE)
    wx4 = _pad_to(_pad_to(wx.reshape(F, 4, H), 0, SUBLANE), 2, LANE)
    wh4 = _pad_to(_pad_to(wh.reshape(H, 4, H), 0, LANE), 2, LANE)
    b4 = _pad_to(b.reshape(4, H), 1, LANE)

    grid = (Bp // Bt, Hp // LANE)
    h_new, c_new = pl.pallas_call(
        _lstm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((Bt, Fp), lambda i, j: (i, 0)),
            pl.BlockSpec((Bt, Hp), lambda i, j: (i, 0)),
            pl.BlockSpec((Bt, LANE), lambda i, j: (i, j)),
            pl.BlockSpec((Fp, 4, LANE), lambda i, j: (0, 0, j)),
            pl.BlockSpec((Hp, 4, LANE), lambda i, j: (0, 0, j)),
            pl.BlockSpec((4, LANE), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((Bt, LANE), lambda i, j: (i, j)),
            pl.BlockSpec((Bt, LANE), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, Hp), jnp.float32),
            jax.ShapeDtypeStruct((Bp, Hp), jnp.float32),
        ],
        interpret=interpret,
    )(xp, hp, cp, wx4, wh4, b4)
    return h_new[:B, :H], c_new[:B, :H]
