"""Microbenchmarks for the Pallas kernels (interpret mode on CPU — wall
times characterize the reference execution, not TPU; the BlockSpec
tiling is what carries to hardware)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=5):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(verbose: bool = True):
    rng = np.random.default_rng(0)
    rows = []

    from repro.kernels.fedavg.kernel import fedavg_pallas
    from repro.kernels.fedavg.ref import fedavg_ref
    u = jnp.asarray(rng.normal(size=(8, 1 << 16)).astype(np.float32))
    w = jnp.ones((8,), jnp.float32)
    us_k = _time(lambda a, b: fedavg_pallas(a, b), u, w)
    us_r = _time(jax.jit(fedavg_ref), u, w)
    rows.append(("kernel/fedavg_pallas_8x64k", us_k, f"ref={us_r:.0f}us"))

    from repro.kernels.lstm_cell.kernel import lstm_cell_pallas
    from repro.kernels.lstm_cell.ref import lstm_cell_ref
    B, F, H = 128, 16, 128
    args = (jnp.asarray(rng.normal(size=(B, F)).astype(np.float32)),
            jnp.asarray(rng.normal(size=(B, H)).astype(np.float32)),
            jnp.asarray(rng.normal(size=(B, H)).astype(np.float32)),
            jnp.asarray(rng.normal(size=(F, 4 * H)).astype(np.float32)),
            jnp.asarray(rng.normal(size=(H, 4 * H)).astype(np.float32)),
            jnp.asarray(rng.normal(size=(4 * H,)).astype(np.float32)))
    us_k = _time(lambda *a: lstm_cell_pallas(*a), *args)
    us_r = _time(jax.jit(lstm_cell_ref), *args)
    rows.append(("kernel/lstm_cell_pallas_128x128", us_k, f"ref={us_r:.0f}us"))

    from repro.kernels.quantize.kernel import quantize_pallas
    v = jnp.asarray(rng.normal(size=(1 << 18,)).astype(np.float32))
    us_k = _time(lambda a: quantize_pallas(a), v)
    rows.append(("kernel/quantize_pallas_256k", us_k, "int8 4x compression"))

    from repro.kernels.aes_ctr.ops import encrypt_bytes
    key = np.arange(16, dtype=np.uint8)
    nonce = np.arange(8, dtype=np.uint8)
    pay = jnp.asarray(rng.integers(0, 256, 1 << 16).astype(np.uint8))
    us_k = _time(lambda p: encrypt_bytes(p, key, nonce), pay)
    rows.append(("kernel/aes_ctr_pallas_64k", us_k, "FIPS-197-validated"))

    if verbose:
        for name, us, extra in rows:
            print(f"[{name}] {us:.0f} us/call ({extra})")
    return [(n, u, e) for n, u, e in rows]


if __name__ == "__main__":
    run()
