"""repro.api — the one experiment API over worlds, methods, and engines.

Quickstart (the whole public surface in 10 lines)::

    from repro.api import Experiment, WorldSpec, MethodSpec, ExecutionSpec

    world = WorldSpec.single(task, own_train, own_test, fleet, states)
    exp = Experiment(world,
                     method=MethodSpec(name="enfed", desired_accuracy=0.95,
                                       max_rounds=10, epochs=8),
                     execution=ExecutionSpec(engine="fleet"))
    result = exp.run()                        # -> RunResult (any method/engine)
    table = exp.compare(["enfed", "dfl", "cfl", "cloud"])
    print(table.table(), table.reduction("enfed", "dfl"))

The specs are orthogonal: :class:`WorldSpec` is the simulated world
(requesters, neighborhoods, contributor states, mobility, batteries,
ONE shared :class:`~repro.core.energy.CostModel`), :class:`MethodSpec`
picks a registered method ("enfed" | "dfl" | "cfl" | "cloud", all
consuming the same EnFedConfig-shaped knobs), and
:class:`ExecutionSpec` tunes how it executes (loop vs fleet engine,
Pallas ``interpret``, early-exit ``round_chunk``, and the
:class:`~repro.telemetry.TraceConfig` observability knob) without
changing the simulated outcome.  Every run returns one
:class:`RunResult` — read ``result.trace`` for the normalized
round-event stream and ``result.timings`` for the wall-clock breakdown
(:mod:`repro.telemetry`); ``Experiment.compare`` returns a
:class:`CompareResult` whose ``reduction()`` rows reproduce the paper's
EnFed-vs-baseline time and energy savings.  Extend with
:func:`register_method`.
"""

from repro.api.experiment import DEFAULT_COMPARISON, Experiment
from repro.api.methods import get_runner, method_names, register_method
from repro.api.result import CompareResult, RunResult, reduction_row
from repro.api.specs import ExecutionSpec, MethodSpec, WorldSpec
from repro.telemetry import TraceConfig

__all__ = [
    "Experiment",
    "WorldSpec",
    "MethodSpec",
    "ExecutionSpec",
    "TraceConfig",
    "RunResult",
    "CompareResult",
    "DEFAULT_COMPARISON",
    "reduction_row",
    "register_method",
    "method_names",
    "get_runner",
]
