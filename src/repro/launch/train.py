"""End-to-end federated LM training driver.

Trains any registry architecture (``--arch``), at full scale on a real
mesh or at ``--preset smoke`` scale on CPU, with the EnFed aggregation
strategy as a first-class flag.  Clients are simulated with the
client-stacked FederatedTrainer (exact per-client semantics); the
per-round participation mask comes from the incentive/contract layer,
and battery/energy accounting per the paper runs alongside.

  PYTHONPATH=src python -m repro.launch.train --arch debug-dense \
      --preset smoke --steps 50 --strategy enfed --clients 8 --neighborhood 4
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint, latest_step, restore_checkpoint
from repro.configs import ARCHS, get_config
from repro.core.battery import BatteryState
from repro.core.energy import CostModel
from repro.core.federated import FederatedTrainer
from repro.core.incentive import make_fleet, select_contributors, participation_mask
from repro.core.topology import AggregationStrategy
from repro.data.tokens import synthetic_token_batches
from repro.launch.steps import lm_loss
from repro.models import Transformer
from repro.utils.tree import tree_size, tree_bytes


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="debug-dense")
    ap.add_argument("--preset", choices=("full", "smoke"), default="smoke")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8, help="global batch (tokens rows)")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--strategy", default="enfed",
                    choices=("cfl", "enfed", "dfl_ring", "dfl_mesh", "none"))
    ap.add_argument("--neighborhood", type=int, default=2)
    ap.add_argument("--incentive", type=float, default=0.6)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.preset == "smoke":
        cfg = cfg.smoke()
    cfg = cfg.replace(dtype="float32")
    model = Transformer(cfg)
    C = args.clients
    assert args.batch % C == 0, "global batch must divide across clients"

    strategy = AggregationStrategy(kind=args.strategy,
                                   neighborhood_size=args.neighborhood)
    trainer = FederatedTrainer(
        loss_fn=lambda p, b: lm_loss(model, p, b),
        num_clients=C, strategy=strategy, lr=args.lr,
        local_steps=args.local_steps)

    params_one = model.init(jax.random.PRNGKey(args.seed))
    n_params = tree_size(params_one)
    print(f"[train] {cfg.name} preset={args.preset} params={n_params/1e6:.1f}M "
          f"clients={C} strategy={args.strategy}")
    stacked, opt_state = trainer.init(params_one)

    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        (stacked, opt_state), start = restore_checkpoint(args.ckpt_dir, (stacked, opt_state))
        print(f"[train] restored step {start} from {args.ckpt_dir}")

    # incentive fleet drives the per-round participation mask
    fleet = make_fleet(C, seed=args.seed + 1, p_has_model=1.0)
    cost = CostModel()
    battery = BatteryState()
    round_jit = jax.jit(trainer.round)

    gen = synthetic_token_batches(cfg.vocab_size, args.batch * args.local_steps,
                                  args.seq, num_batches=args.steps,
                                  seed=args.seed + 2)
    history = []
    t0 = time.time()
    for step, flat in enumerate(gen, start=start):
        batch = {
            k: jnp.asarray(v.reshape(C, args.local_steps, args.batch // C, args.seq))
            for k, v in flat.items()
        }
        contracts = select_contributors(fleet, args.incentive, n_max=C)
        mask = participation_mask(C, contracts) if args.strategy == "enfed" else None
        stacked, opt_state, losses = round_jit(stacked, opt_state, batch,
                                               None if mask is None else jnp.asarray(mask))
        # energy bookkeeping for the (virtual) requesting client 0
        rep = cost.session(rounds=1, n_contrib=int(mask.sum()) if mask is not None else C,
                           num_params=n_params, model_bytes=tree_bytes(params_one),
                           num_samples=args.batch // C * args.seq, epochs=1)
        battery = battery.discharge(rep.e_tot, cost.device.p_train)
        loss = float(jnp.mean(losses))
        history.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"({dt:.1f}s, battery {battery.percent:.1f}%)", flush=True)
        if args.ckpt_dir and step > 0 and step % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step, (stacked, opt_state))
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, start + args.steps, (stacked, opt_state))
    improved = history[-1] < history[0]
    print(f"[train] done: loss {history[0]:.4f} -> {history[-1]:.4f} "
          f"({'improved' if improved else 'NOT improved'})")
    return 0 if improved else 1


if __name__ == "__main__":
    raise SystemExit(main())
