"""Pure-jnp oracle for the fused masked-weighted FedAvg reduction."""

from __future__ import annotations

import jax.numpy as jnp


def fedavg_ref(updates, weights):
    """updates: (N, L) contributor-stacked flat updates; weights: (N,)
    (participation mask x data-size weights). Returns (L,) fp32:

        out = sum_j w_j * u_j / max(sum_j w_j, eps)      (paper eq. 14)
    """
    w = weights.astype(jnp.float32)
    num = jnp.einsum("n,nl->l", w, updates.astype(jnp.float32))
    return num / jnp.maximum(jnp.sum(w), 1e-9)


def fedavg_batched_ref(updates, weights):
    """updates: (R, N, L); weights: (R, N). Requester-batched eq. (14):
    one independent masked-weighted mean per leading session index."""
    w = weights.astype(jnp.float32)
    num = jnp.einsum("rn,rnl->rl", w, updates.astype(jnp.float32))
    return num / jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1e-9)


def fedavg_batched_q8_ref(q, scales, weights):
    """q: (R, N, Lp) int8; scales: (R, N, Lp/tile) fp32; weights:
    (R, N).  Dequantize (exact ``q * scale``) then the batched eq. (14)
    — the oracle for the fused dequant->fedavg kernel."""
    r, n, lp = q.shape
    tile = lp // scales.shape[-1]
    u = (q.astype(jnp.float32).reshape(r, n, -1, tile)
         * scales[..., None]).reshape(r, n, lp)
    return fedavg_batched_ref(u, weights)
