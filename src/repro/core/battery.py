"""Battery state and discharge model.

The paper gates EnFed rounds on the requesting device's battery:
continue only while ``B_p >= B_min_A`` (Algorithm 1, checkbatterylevel).
Discharge is non-linear in reality (paper §III notes this); we model the
energy-to-charge conversion with a load-dependent efficiency factor so
heavy phases (training) drain proportionally more than their Joule count.

Two forms, one formula:

* :class:`BatteryState` — host-side dataclass used by the loop engine
  (``repro.core.rounds``), one instance per requesting device.
* :func:`discharge_level` — the same arithmetic on (possibly traced)
  arrays, used by the jit fleet engine (``repro.core.fleet``) where the
  battery of every requester is a lane of one vector.  The loop engine's
  ``BatteryState.discharge`` delegates to it so the two engines cannot
  drift apart.
"""

from __future__ import annotations

import dataclasses


def load_efficiency(avg_power_w: float, high_load_penalty: float,
                    high_load_threshold_w: float) -> float:
    """Peukert-like efficiency factor: >1 under heavy draw."""
    return 1.0 + (high_load_penalty if avg_power_w > high_load_threshold_w else 0.0)


def discharge_level(level, energy_j, capacity_j, efficiency=1.0):
    """New battery fraction after spending ``energy_j`` joules.

    Works on python floats and on jnp arrays alike (the fleet engine
    passes per-requester vectors); clamping uses whichever ``max``-like
    semantics the operand supports.
    """
    new_level = level - efficiency * energy_j / capacity_j
    if isinstance(new_level, (int, float)):  # host path (loop engine)
        return max(new_level, 0.0)
    import jax.numpy as jnp  # array path (fleet engine)

    return jnp.maximum(new_level, 0.0)


@dataclasses.dataclass
class BatteryState:
    capacity_j: float = 40e3
    level: float = 1.0                 # fraction of capacity remaining
    # non-linearity: effective capacity shrinks under high draw (Peukert-like)
    high_load_penalty: float = 0.15
    high_load_threshold_w: float = 3.0

    def discharge(self, energy_j: float, avg_power_w: float = 1.0) -> "BatteryState":
        eff = load_efficiency(avg_power_w, self.high_load_penalty,
                              self.high_load_threshold_w)
        new_level = discharge_level(self.level, energy_j, self.capacity_j, eff)
        return dataclasses.replace(self, level=float(new_level))

    def below(self, threshold: float) -> bool:
        return self.level < threshold

    @property
    def percent(self) -> float:
        return 100.0 * self.level
