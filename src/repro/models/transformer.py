"""Composable decoder / encoder-decoder transformer.

Layers are grouped by the config's cyclic ``block_pattern``: the stack is
``repeats`` copies of the pattern (parameters stacked on a leading axis
and iterated with ``lax.scan`` to bound HLO size for 48/61-layer configs)
plus an unscanned tail for ``num_layers % len(pattern)`` remainder layers
(e.g. RecurrentGemma's 26 = 8x(rec,rec,local) + (rec,rec)).

Entry points:
  * ``init(rng)``                          -> params
  * ``forward(params, batch)``             -> (logits, aux_loss)  (train/prefill)
  * ``init_cache(batch, max_len)``         -> decode cache
  * ``decode_step(params, tok, cache, pos[, memory])`` -> (logits, cache)

Batch dict keys: ``tokens`` (B,S) int32; optional ``prefix_embeds``
(B,P,D) for VLM; ``frames`` (B,T,D) for audio encoder input.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers, moe, recurrent
from repro.sharding.ctx import shard_activation, pvary_manual

ATTN_TYPES = ("attn", "swa", "local")
RECURRENT_TYPES = ("rglru", "mlstm", "slstm")


def _has_ffn(cfg: ModelConfig) -> bool:
    return cfg.d_ff > 0 or cfg.moe is not None


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------


def block_init(rng, cfg: ModelConfig, block_type: str, cross: bool = False):
    ks = jax.random.split(rng, 4)
    dt = cfg.jnp_dtype
    p = {"norm1": layers.rmsnorm_init(cfg.d_model, dt)}
    if block_type in ATTN_TYPES:
        p["mixer"] = layers.attention_init(ks[0], cfg)
    elif block_type == "mla":
        p["mixer"] = layers.mla_init(ks[0], cfg)
    elif block_type == "rglru":
        p["mixer"] = recurrent.rglru_init(ks[0], cfg)
    elif block_type == "mlstm":
        p["mixer"] = recurrent.mlstm_init(ks[0], cfg)
    elif block_type == "slstm":
        p["mixer"] = recurrent.slstm_init(ks[0], cfg)
    else:
        raise ValueError(f"unknown block type {block_type}")
    if cross:
        p["norm_x"] = layers.rmsnorm_init(cfg.d_model, dt)
        p["cross"] = layers.attention_init(ks[2], cfg)
    if _has_ffn(cfg):
        p["norm2"] = layers.rmsnorm_init(cfg.d_model, dt)
        p["ffn"] = moe.moe_init(ks[1], cfg) if cfg.moe is not None else layers.mlp_init(ks[1], cfg)
    return p


def block_apply(params, x, cfg: ModelConfig, block_type: str,
                memory=None, positions=None, causal: bool = True):
    """Full-sequence block. Returns (x, aux_loss)."""
    h = layers.rmsnorm_apply(params["norm1"], x, cfg.norm_eps)
    if block_type in ATTN_TYPES:
        if causal:
            h = layers.attention_apply(params["mixer"], h, cfg, block_type, positions)
        else:
            h = _bidir_attention(params["mixer"], h, cfg, positions)
    elif block_type == "mla":
        h = layers.mla_apply(params["mixer"], h, cfg, positions)
    elif block_type == "rglru":
        h = recurrent.rglru_apply(params["mixer"], h, cfg)
    elif block_type == "mlstm":
        h = recurrent.mlstm_apply(params["mixer"], h, cfg)
    elif block_type == "slstm":
        h = recurrent.slstm_apply(params["mixer"], h, cfg)
    x = x + h
    if "cross" in params and memory is not None:
        h = layers.rmsnorm_apply(params["norm_x"], x, cfg.norm_eps)
        x = x + layers.cross_attention_apply(params["cross"], h, memory, cfg)
    aux = jnp.float32(0.0)
    if "ffn" in params:
        h = layers.rmsnorm_apply(params["norm2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            h, aux = moe.moe_apply(params["ffn"], h, cfg)
        else:
            h = layers.mlp_apply(params["ffn"], h)
        x = x + h
    return x, aux


def _bidir_attention(params, x, cfg: ModelConfig, positions=None):
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = layers._qkv(params, x, cfg)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    out = layers._gqa_core(q, k, v, jnp.ones((1, 1, 1, 1, 1), bool))
    return out.reshape(B, S, -1) @ params["wo"]


def block_init_cache(cfg: ModelConfig, block_type: str, batch: int, max_len: int):
    if block_type in ATTN_TYPES:
        return layers.attention_init_cache(cfg, block_type, batch, max_len)
    if block_type == "mla":
        return layers.mla_init_cache(cfg, batch, max_len)
    if block_type == "rglru":
        return recurrent.rglru_init_state(cfg, batch)
    if block_type == "mlstm":
        return recurrent.mlstm_init_state(cfg, batch)
    if block_type == "slstm":
        return recurrent.slstm_init_state(cfg, batch)
    raise ValueError(block_type)


def block_decode(params, x, cache, pos, cfg: ModelConfig, block_type: str,
                 memory=None, mla_absorbed: bool = False):
    h = layers.rmsnorm_apply(params["norm1"], x, cfg.norm_eps)
    if block_type in ATTN_TYPES:
        h, cache = layers.attention_decode(params["mixer"], h, cache, pos, cfg, block_type)
    elif block_type == "mla":
        fn = layers.mla_decode_absorbed if mla_absorbed else layers.mla_decode
        h, cache = fn(params["mixer"], h, cache, pos, cfg)
    elif block_type == "rglru":
        h, cache = recurrent.rglru_decode(params["mixer"], h, cache, cfg)
    elif block_type == "mlstm":
        h, cache = recurrent.mlstm_decode(params["mixer"], h, cache, cfg)
    elif block_type == "slstm":
        h, cache = recurrent.slstm_decode(params["mixer"], h, cache, cfg)
    x = x + h
    if "cross" in params and memory is not None:
        h = layers.rmsnorm_apply(params["norm_x"], x, cfg.norm_eps)
        x = x + layers.cross_attention_apply(params["cross"], h, memory, cfg)
    if "ffn" in params:
        h = layers.rmsnorm_apply(params["norm2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            h, _ = moe.moe_apply(params["ffn"], h, cfg)
        else:
            h = layers.mlp_apply(params["ffn"], h)
        x = x + h
    return x, cache


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------


class Transformer:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        P = len(cfg.block_pattern)
        self.repeats = cfg.num_layers // P
        self.tail_types = cfg.block_pattern[: cfg.num_layers % P]

    # -- init ---------------------------------------------------------------
    def init(self, rng):
        cfg = self.cfg
        n_keys = 6 + len(self.tail_types)
        ks = list(jax.random.split(rng, n_keys))
        cross = cfg.is_encoder_decoder
        params = {"embed": layers.embed_init(ks[0], cfg.vocab_size, cfg.d_model, cfg.jnp_dtype)}

        def stack_init(rng_, block_type):
            subs = jax.random.split(rng_, self.repeats)
            ps = [block_init(k, cfg, block_type, cross=cross) for k in subs]
            return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ps)

        if self.repeats > 0:
            params["scan"] = {
                f"p{i}_{bt}": stack_init(jax.random.fold_in(ks[1], i), bt)
                for i, bt in enumerate(cfg.block_pattern)
            }
        for t, bt in enumerate(self.tail_types):
            params[f"tail{t}_{bt}"] = block_init(ks[2 + t], cfg, bt, cross=cross)
        params["final_norm"] = layers.rmsnorm_init(cfg.d_model, cfg.jnp_dtype)
        if not cfg.tie_embeddings:
            params["unembed"] = layers.dense_init(ks[3], cfg.d_model, cfg.vocab_size, cfg.jnp_dtype)
        if cfg.is_encoder_decoder:
            params["encoder"] = self._encoder_init(ks[4])
        if cfg.mtp_depth > 0:
            params["mtp"] = {
                "proj": layers.dense_init(ks[5], 2 * cfg.d_model, cfg.d_model, cfg.jnp_dtype),
                "block": block_init(jax.random.fold_in(ks[5], 1), cfg, cfg.block_pattern[-1]),
                "norm": layers.rmsnorm_init(cfg.d_model, cfg.jnp_dtype),
            }
        return params

    def _encoder_init(self, rng):
        cfg = self.cfg
        ks = jax.random.split(rng, cfg.encoder_layers + 1)
        ps = [block_init(k, cfg, "attn") for k in ks[:-1]]
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ps)
        return {"blocks": stacked, "norm": layers.rmsnorm_init(cfg.d_model, cfg.jnp_dtype)}

    # -- encoder ------------------------------------------------------------
    def encode(self, params, frames):
        """frames: (B,T,D) precomputed frontend embeddings (stub carve-out)."""
        cfg = self.cfg
        x = frames.astype(cfg.jnp_dtype)

        def body(x, blk):
            x, _ = block_apply(blk, x, cfg, "attn", causal=False)
            return x, None

        x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
        return layers.rmsnorm_apply(params["encoder"]["norm"], x, cfg.norm_eps)

    # -- embedding ----------------------------------------------------------
    def _embed(self, params, batch):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]]
        if cfg.num_prefix_tokens > 0 and "prefix_embeds" in batch:
            x = jnp.concatenate([batch["prefix_embeds"].astype(x.dtype), x], axis=1)
        return shard_activation(x, ("batch", None, None))

    def _unembed(self, params, x):
        cfg = self.cfg
        x = layers.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
        w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        logits = x @ w
        return layers.softcap(logits, cfg.logit_softcap)

    # -- full-sequence forward ---------------------------------------------
    def forward(self, params, batch, last_logit_only: bool = False):
        """``last_logit_only=True`` is the prefill path: hidden states run
        the full sequence but only the final position is unembedded (the
        vocab matmul dominates otherwise)."""
        cfg = self.cfg
        x = self._embed(params, batch)
        memory = self.encode(params, batch["frames"]) if cfg.is_encoder_decoder else None
        positions = jnp.arange(x.shape[1])[None, :]
        aux = pvary_manual(jnp.float32(0.0))

        def run_block(blk, x, bt):
            def f(blk, x, memory, positions):
                return block_apply(blk, x, cfg, bt, memory=memory, positions=positions)
            if cfg.remat:
                f = jax.checkpoint(f)
            return f(blk, x, memory, positions)

        if self.repeats > 0:
            def body(carry, blks):
                x, aux = carry
                for i, bt in enumerate(cfg.block_pattern):
                    x, a = run_block(blks[f"p{i}_{bt}"], x, bt)
                    aux = aux + a
                return (x, aux), None

            (x, aux), _ = jax.lax.scan(body, (x, aux), params["scan"])
        for t, bt in enumerate(self.tail_types):
            x, a = run_block(params[f"tail{t}_{bt}"], x, bt)
            aux = aux + a

        logits = self._unembed(params, x[:, -1:] if last_logit_only else x)
        out = {"logits": shard_activation(logits, ("batch", None, "model")), "aux_loss": aux}
        if cfg.mtp_depth > 0 and not last_logit_only:
            out["mtp_logits"] = self._mtp(params, x, batch)
        return out

    def _mtp(self, params, h, batch):
        """DeepSeek-V3 multi-token-prediction head: predict token t+2 from
        the final hidden state at t combined with the embedding of t+1."""
        cfg = self.cfg
        emb_next = params["embed"][batch["tokens"]]
        emb_next = jnp.roll(emb_next, -1, axis=1)
        if cfg.num_prefix_tokens > 0 and "prefix_embeds" in batch:
            pad = jnp.zeros((h.shape[0], cfg.num_prefix_tokens, cfg.d_model), h.dtype)
            emb_next = jnp.concatenate([pad, emb_next], axis=1)
        g = jnp.concatenate([layers.rmsnorm_apply(params["mtp"]["norm"], h, cfg.norm_eps),
                             emb_next.astype(h.dtype)], axis=-1)
        g = g @ params["mtp"]["proj"]
        g, _ = block_apply(params["mtp"]["block"], g, cfg, cfg.block_pattern[-1])
        return self._unembed(params, g)

    # -- decode ---------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        cache = {}
        if self.repeats > 0:
            cache["scan"] = {}
            for i, bt in enumerate(cfg.block_pattern):
                one = block_init_cache(cfg, bt, batch, max_len)
                cache["scan"][f"p{i}_{bt}"] = jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(x, (self.repeats,) + x.shape).copy(), one
                )
        for t, bt in enumerate(self.tail_types):
            cache[f"tail{t}_{bt}"] = block_init_cache(cfg, bt, batch, max_len)
        return cache

    def decode_step(self, params, tokens, cache, pos, memory=None,
                    mla_absorbed: bool = False):
        """tokens: (B,1) int32; pos: scalar int32 absolute position."""
        cfg = self.cfg
        x = params["embed"][tokens]
        x = shard_activation(x, ("batch", None, None))
        new_cache = {}

        if self.repeats > 0:
            def body(x, blks_and_cache):
                blks, cch = blks_and_cache
                new_c = {}
                for i, bt in enumerate(cfg.block_pattern):
                    key = f"p{i}_{bt}"
                    x, c = block_decode(blks[key], x, cch[key], pos, cfg, bt,
                                        memory=memory, mla_absorbed=mla_absorbed)
                    new_c[key] = c
                return x, new_c

            x, new_scan = jax.lax.scan(body, x, (params["scan"], cache["scan"]))
            new_cache["scan"] = new_scan
        for t, bt in enumerate(self.tail_types):
            key = f"tail{t}_{bt}"
            x, c = block_decode(params[key], x, cache[key], pos, cfg, bt,
                                memory=memory, mla_absorbed=mla_absorbed)
            new_cache[key] = c

        logits = self._unembed(params, x)
        return logits, new_cache
