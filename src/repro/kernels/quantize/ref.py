"""Pure-jnp oracle for per-tile symmetric int8 quantization."""

from __future__ import annotations

import jax.numpy as jnp

TILE = 1024


def quantize_ref(x, tile: int = TILE):
    """x: (L,) fp32, L % tile == 0. Returns (q int8 (L,), scales fp32 (L/tile,)).

    Symmetric per-tile: scale = absmax/127, q = round(x/scale).
    """
    xt = x.reshape(-1, tile).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xt), axis=1)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xt / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale


def dequantize_ref(q, scales, tile: int = TILE):
    qt = q.reshape(-1, tile).astype(jnp.float32)
    return (qt * scales[:, None]).reshape(-1)
