"""Pure-jnp oracles for the Byzantine-robust aggregation kernels.

Independent formulations of the same statistics — ``jnp.sort`` /
``argmax`` / ``take_along_axis`` instead of the kernels' comparison
networks and one-hot selections — so the interpret-equivalence tests in
``tests/test_robust_kernels.py`` actually cross-check two derivations.
Tie-break semantics match the kernels exactly: the trimmed mean drops
the FIRST max/min instance (``jnp.argmax``/``argmin`` return the first
index on ties, as does the kernels' min-index-of-one-hot trick).
"""

from __future__ import annotations

import jax.numpy as jnp


def _dequant(q, scales):
    """Exact wire inverse ``q * scale`` over per-tile scales."""
    r, n, lp = q.shape
    tile = lp // scales.shape[-1]
    return (q.astype(jnp.float32).reshape(r, n, -1, tile)
            * scales[..., None]).reshape(r, n, lp)


def trimmed_mean_batched_ref(updates, weights):
    """updates: (R, N, L); weights: (R, N) -> (R, L) fp32.

    Per-coordinate weighted trimmed mean over the active (w > 0)
    contributors: the single largest and single smallest active instance
    drop out (first instance on value ties), the rest weighted-average;
    <= 2 active falls back to the plain weighted mean; 0 active -> 0.
    """
    u = updates.astype(jnp.float32)
    w = weights.astype(jnp.float32)
    n = u.shape[1]
    act = (w > 0.0)[:, :, None]
    wb = jnp.where(act, w[:, :, None], 0.0)
    m3 = jnp.sum(act.astype(jnp.int32), axis=1, keepdims=True)
    n_idx = jnp.arange(n, dtype=jnp.int32)[None, :, None]
    amax = jnp.argmax(jnp.where(act, u, -jnp.inf), axis=1, keepdims=True)
    one_max = n_idx == amax
    amin = jnp.argmin(jnp.where(act & ~one_max, u, jnp.inf), axis=1,
                      keepdims=True)
    one_min = n_idx == amin
    w_eff = jnp.where(one_max | one_min, 0.0, wb)
    w_use = jnp.where(m3 > 2, w_eff, wb)
    num = jnp.sum(w_use * jnp.where(act, u, 0.0), axis=1)
    den = jnp.maximum(jnp.sum(w_use, axis=1), 1e-9)
    return num / den


def trimmed_mean_batched_q8_ref(q, scales, weights):
    """Dequantize (exact ``q * scale``) then the dense trimmed mean."""
    return trimmed_mean_batched_ref(_dequant(q, scales), weights)


def median_batched_ref(updates, weights):
    """updates: (R, N, L); weights: (R, N) -> (R, L) fp32.

    Per-coordinate masked median over the active contributors (weights
    gate activity only; mean of the two middles for even counts);
    0 active -> 0.
    """
    u = updates.astype(jnp.float32)
    w = weights.astype(jnp.float32)
    act = (w > 0.0)[:, :, None]
    m = jnp.sum((w > 0.0).astype(jnp.int32), axis=1)       # (R,)
    srt = jnp.sort(jnp.where(act, u, jnp.inf), axis=1)
    lo = jnp.maximum((m - 1) // 2, 0)[:, None, None]
    hi = jnp.maximum(m // 2, 0)[:, None, None]
    vlo = jnp.take_along_axis(srt, lo, axis=1)[:, 0, :]
    vhi = jnp.take_along_axis(srt, hi, axis=1)[:, 0, :]
    med = 0.5 * (vlo + vhi)
    return jnp.where((m > 0)[:, None], med, 0.0)


def median_batched_q8_ref(q, scales, weights):
    """Dequantize (exact ``q * scale``) then the dense median."""
    return median_batched_ref(_dequant(q, scales), weights)


def sqnorm_batched_ref(updates):
    """updates: (R, N, L) -> (R, N) fp32 squared L2 norms."""
    u = updates.astype(jnp.float32)
    return jnp.sum(u * u, axis=-1)


def sqnorm_batched_q8_ref(q, scales):
    """Dequantize (exact ``q * scale``) then the dense squared norms."""
    return sqnorm_batched_ref(_dequant(q, scales))
