"""Synthetic LM token pipeline for the architecture-zoo training drivers.

Generates Zipf-distributed token streams with short-range Markov structure
so a ~100M model has something non-trivial to fit in the end-to-end
example.  ``synthetic_token_batches`` yields {tokens, labels} dicts ready
for ``train_step``.
"""

from __future__ import annotations

from typing import Iterator

import numpy as np


def _zipf_probs(vocab: int, s: float = 1.1) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-s)
    return p / p.sum()


def synthetic_token_batches(vocab_size: int, batch_size: int, seq_len: int,
                            num_batches: int, seed: int = 0,
                            markov_weight: float = 0.5) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    base = _zipf_probs(min(vocab_size, 4096))
    sub = len(base)
    # sparse Markov successor table over the frequent sub-vocab
    succ = rng.integers(0, sub, size=(sub, 4))
    for _ in range(num_batches):
        toks = np.empty((batch_size, seq_len + 1), np.int64)
        cur = rng.choice(sub, size=batch_size, p=base)
        toks[:, 0] = cur
        for t in range(1, seq_len + 1):
            follow = rng.random(batch_size) < markov_weight
            nxt_markov = succ[cur, rng.integers(0, 4, size=batch_size)]
            nxt_iid = rng.choice(sub, size=batch_size, p=base)
            cur = np.where(follow, nxt_markov, nxt_iid)
            toks[:, t] = cur
        yield {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
