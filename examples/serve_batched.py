"""Serve a small model with batched requests (prefill + decode loop).

  PYTHONPATH=src python examples/serve_batched.py --arch debug-moe
(the smoke preset keeps it CPU-sized)
"""

import argparse

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="debug-moe")
    ap.add_argument("--mla-absorbed", action="store_true")
    args = ap.parse_args()
    argv = ["--arch", args.arch, "--preset", "smoke",
            "--batch", "2", "--prompt-len", "16", "--gen", "12"]
    if args.mla_absorbed:
        argv.append("--mla-absorbed")
    return serve_mod.main(argv)


if __name__ == "__main__":
    raise SystemExit(main())
