"""Pallas TPU kernels: Byzantine-robust aggregation statistics over the
wire-format round-state buffer.

Three robust statistics replace the plain masked-weighted mean of
``repro.kernels.fedavg`` when ``EnFedConfig.robust != "none"``:

* **trimmed mean** — per coordinate, the single largest and single
  smallest active contribution (first instance on value ties) are
  dropped and the weighted mean runs over the rest; with <= 2 active
  contributors it degrades to the plain weighted mean.
* **median** — per coordinate, the middle active value (mean of the two
  middles for even counts); weights gate activity only.
* **per-contributor squared L2 norm** — the reduction feeding norm-clip
  screening (``repro.kernels.robust.ops.robust_aggregate``'s "clip"
  path): norms accumulate tile by tile into an (R, N) output block that
  the grid revisits, so the full fp32 vector never round-trips HBM.

Every statistic ships a ``*_q8`` twin that fuses the int8 dequant
(``q * scale``, the exact wire inverse) into the same VMEM pass — the
compressed (R, N, P) round state is screened WITHOUT materializing the
dense fp32 block (the never-re-densify rule), exactly like
``fedavg_batched_q8``.  The q8 kernels dequantize first and then run
bit-identical arithmetic to the dense kernels, so the loop engine
(dense dequantized payloads) and the fleet engine (fused q8 buffer)
agree bitwise on every order statistic and clip decision.

Tiling matches ``repro.kernels.fedavg.kernel``: grid
(R/TR, L/TILE), block (TR, N, TILE), requester tile sized to a ~2 MB
VMEM budget.  The contributor axis N is small (n_max-bounded), so the
per-coordinate order statistics run as a static odd-even transposition
network / one-hot selections along axis 1 — no dynamic gather, Pallas-
lowerable on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret
from repro.kernels.quantize.kernel import TILE as Q_TILE

TILE_L = 2048


def _tile_r(r: int, n: int, tile_l: int, itemsize: int) -> int:
    """Requester-axis tile under a ~2 MB VMEM budget (see
    ``repro.kernels.fedavg.kernel._tile_r``)."""
    return max(1, min(r, (2 << 20) // max(n * tile_l * itemsize, 1)))


def _dequant(q, s):
    """Exact wire inverse ``q * scale`` for one (TR, N, TILE) block with
    per-block scales (TR, N, 1)."""
    return q.astype(jnp.float32) * s


# ---------------------------------------------------------------------------
# trimmed mean
# ---------------------------------------------------------------------------


def _trimmed_mean_block(w, u):
    """w: (TR, N) fp32; u: (TR, N, T) fp32 -> (TR, T) fp32.

    Per-coordinate weighted trimmed mean: drop the max and the min
    ACTIVE instance (first index on ties — the same instance the ref's
    argmax/argmin picks), weighted-average the rest; <= 2 active
    contributors fall back to the plain weighted mean; 0 active -> 0
    (the fedavg all-masked convention).
    """
    u = u.astype(jnp.float32)
    n = u.shape[1]
    act = (w > 0.0)[:, :, None]                      # (TR, N, 1)
    wb = jnp.where(act, w[:, :, None], 0.0)          # (TR, N, 1)
    m3 = jnp.sum(act.astype(jnp.int32), axis=1, keepdims=True)  # (TR, 1, 1)
    n_idx = jax.lax.broadcasted_iota(jnp.int32, u.shape, 1)
    vmax_in = jnp.where(act, u, -jnp.inf)
    vmax = jnp.max(vmax_in, axis=1, keepdims=True)
    is_max = act & (vmax_in == vmax)
    amax = jnp.min(jnp.where(is_max, n_idx, jnp.int32(n)), axis=1,
                   keepdims=True)
    one_max = n_idx == amax
    vmin_in = jnp.where(act & ~one_max, u, jnp.inf)
    vmin = jnp.min(vmin_in, axis=1, keepdims=True)
    is_min = (act & ~one_max) & (vmin_in == vmin)
    amin = jnp.min(jnp.where(is_min, n_idx, jnp.int32(n)), axis=1,
                   keepdims=True)
    one_min = n_idx == amin
    w_eff = jnp.where(one_max | one_min, 0.0, wb)
    w_use = jnp.where(m3 > 2, w_eff, wb)
    num = jnp.sum(w_use * jnp.where(act, u, 0.0), axis=1)
    den = jnp.maximum(jnp.sum(w_use, axis=1), 1e-9)
    return num / den


def _trimmed_mean_batched_kernel(w_ref, u_ref, o_ref):
    o_ref[...] = _trimmed_mean_block(w_ref[...], u_ref[...])


def _trimmed_mean_batched_q8_kernel(w_ref, q_ref, s_ref, o_ref):
    o_ref[...] = _trimmed_mean_block(w_ref[...], _dequant(q_ref[...],
                                                          s_ref[...]))


# ---------------------------------------------------------------------------
# median
# ---------------------------------------------------------------------------


def _sorted_rows(v, n: int):
    """Odd-even transposition sort along axis 1 (static N passes) — the
    sorted VALUES match ``jnp.sort(v, axis=1)`` exactly; the network is
    comparison/select only, hence Pallas-lowerable."""
    rows = [v[:, j, :] for j in range(n)]
    for phase in range(n):
        for j in range(phase % 2, n - 1, 2):
            a, b = rows[j], rows[j + 1]
            rows[j], rows[j + 1] = jnp.minimum(a, b), jnp.maximum(a, b)
    return rows


def _median_block(w, u):
    """w: (TR, N) fp32; u: (TR, N, T) fp32 -> (TR, T) fp32.

    Per-coordinate masked median over the active contributors (weights
    gate activity only); 0 active -> 0.
    """
    u = u.astype(jnp.float32)
    n = u.shape[1]
    act = (w > 0.0)[:, :, None]
    m = jnp.sum((w > 0.0).astype(jnp.int32), axis=1)     # (TR,)
    rows = _sorted_rows(jnp.where(act, u, jnp.inf), n)
    lo = jnp.maximum((m - 1) // 2, 0)[:, None]           # (TR, 1)
    hi = jnp.maximum(m // 2, 0)[:, None]
    vlo = rows[0] * 0.0
    vhi = rows[0] * 0.0
    for j in range(n):
        vlo = jnp.where(lo == j, rows[j], vlo)
        vhi = jnp.where(hi == j, rows[j], vhi)
    med = 0.5 * (vlo + vhi)
    return jnp.where((m > 0)[:, None], med, 0.0)


def _median_batched_kernel(w_ref, u_ref, o_ref):
    o_ref[...] = _median_block(w_ref[...], u_ref[...])


def _median_batched_q8_kernel(w_ref, q_ref, s_ref, o_ref):
    o_ref[...] = _median_block(w_ref[...], _dequant(q_ref[...], s_ref[...]))


# ---------------------------------------------------------------------------
# per-contributor squared L2 norm (clip screening)
# ---------------------------------------------------------------------------


def _sqnorm_batched_kernel(u_ref, o_ref):
    """u_ref: (TR, N, TILE) -> accumulate sum(u^2) over the L grid axis
    into o_ref (TR, N).  The output block is revisited across the
    trailing grid dimension (sequential on TPU), initialized at j == 0."""
    j = pl.program_id(1)
    u = u_ref[...].astype(jnp.float32)
    part = jnp.sum(u * u, axis=2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = part

    @pl.when(j > 0)
    def _acc():
        o_ref[...] += part


def _sqnorm_batched_q8_kernel(q_ref, s_ref, o_ref):
    j = pl.program_id(1)
    u = _dequant(q_ref[...], s_ref[...])
    part = jnp.sum(u * u, axis=2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = part

    @pl.when(j > 0)
    def _acc():
        o_ref[...] += part


# ---------------------------------------------------------------------------
# launch wrappers
# ---------------------------------------------------------------------------


def _launch_dense(kernel, updates, weights, interpret):
    """Shared (R, N, L) launch: pad L to TILE_L, tile R, slice back."""
    r, n, l = updates.shape
    pad = (-l) % TILE_L
    if pad:
        updates = jnp.pad(updates, ((0, 0), (0, 0), (0, pad)))
    lp = l + pad
    tr = _tile_r(r, n, TILE_L, 4)
    pad_r = (-r) % tr
    if pad_r:
        updates = jnp.pad(updates, ((0, pad_r), (0, 0), (0, 0)))
        weights = jnp.pad(weights, ((0, pad_r), (0, 0)))
    grid = ((r + pad_r) // tr, lp // TILE_L)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, n), lambda i, j: (i, 0)),
            pl.BlockSpec((tr, n, TILE_L), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((tr, TILE_L), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r + pad_r, lp), jnp.float32),
        interpret=interpret,
    )(weights.astype(jnp.float32), updates)
    return out[:r, :l]


def _launch_q8(kernel, q, scales, weights, interpret):
    """Shared (R, N, Lp) int8 launch: one Q_TILE per trailing grid step
    so each block sees exactly one scale scalar per contributor."""
    r, n, lp = q.shape
    if lp % Q_TILE:
        raise ValueError(f"robust q8 kernels need Lp % {Q_TILE} == 0 "
                         f"(got {lp}); the wire format is tile-padded")
    tr = _tile_r(r, n, Q_TILE, 1)
    pad_r = (-r) % tr
    if pad_r:
        q = jnp.pad(q, ((0, pad_r), (0, 0), (0, 0)))
        scales = jnp.pad(scales, ((0, pad_r), (0, 0), (0, 0)))
        weights = jnp.pad(weights, ((0, pad_r), (0, 0)))
    grid = ((r + pad_r) // tr, lp // Q_TILE)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, n), lambda i, j: (i, 0)),
            pl.BlockSpec((tr, n, Q_TILE), lambda i, j: (i, 0, j)),
            pl.BlockSpec((tr, n, 1), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((tr, Q_TILE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r + pad_r, lp), jnp.float32),
        interpret=interpret,
    )(weights.astype(jnp.float32), q, scales)
    return out[:r]


@functools.partial(jax.jit, static_argnames=("interpret",))
def trimmed_mean_batched_pallas(updates, weights, *, interpret=None):
    """updates: (R, N, L); weights: (R, N). Returns (R, L) fp32."""
    return _launch_dense(_trimmed_mean_batched_kernel, updates, weights,
                         resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def trimmed_mean_batched_q8_pallas(q, scales, weights, *, interpret=None):
    """q: (R, N, Lp) int8; scales: (R, N, Lp/Q_TILE); weights: (R, N).
    Returns (R, Lp) fp32 — dequant fused, fp32 block never materialized."""
    return _launch_q8(_trimmed_mean_batched_q8_kernel, q, scales, weights,
                      resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def median_batched_pallas(updates, weights, *, interpret=None):
    """updates: (R, N, L); weights: (R, N). Returns (R, L) fp32."""
    return _launch_dense(_median_batched_kernel, updates, weights,
                         resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def median_batched_q8_pallas(q, scales, weights, *, interpret=None):
    """q: (R, N, Lp) int8; scales: (R, N, Lp/Q_TILE); weights: (R, N).
    Returns (R, Lp) fp32 — dequant fused."""
    return _launch_q8(_median_batched_q8_kernel, q, scales, weights,
                      resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def sqnorm_batched_pallas(updates, *, interpret=None):
    """updates: (R, N, L) -> (R, N) fp32 squared L2 norms, accumulated
    tile-by-tile (the clip screening reduction)."""
    interpret = resolve_interpret(interpret)
    r, n, l = updates.shape
    pad = (-l) % TILE_L
    if pad:
        updates = jnp.pad(updates, ((0, 0), (0, 0), (0, pad)))
    lp = l + pad
    tr = _tile_r(r, n, TILE_L, 4)
    pad_r = (-r) % tr
    if pad_r:
        updates = jnp.pad(updates, ((0, pad_r), (0, 0), (0, 0)))
    grid = ((r + pad_r) // tr, lp // TILE_L)
    out = pl.pallas_call(
        _sqnorm_batched_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tr, n, TILE_L), lambda i, j: (i, 0, j))],
        out_specs=pl.BlockSpec((tr, n), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r + pad_r, n), jnp.float32),
        interpret=interpret,
    )(updates)
    return out[:r]


@functools.partial(jax.jit, static_argnames=("interpret",))
def sqnorm_batched_q8_pallas(q, scales, *, interpret=None):
    """q: (R, N, Lp) int8; scales: (R, N, Lp/Q_TILE) -> (R, N) fp32
    squared norms straight off the wire-format buffer (dequant fused)."""
    interpret = resolve_interpret(interpret)
    r, n, lp = q.shape
    if lp % Q_TILE:
        raise ValueError(f"robust q8 kernels need Lp % {Q_TILE} == 0 "
                         f"(got {lp}); the wire format is tile-padded")
    tr = _tile_r(r, n, Q_TILE, 1)
    pad_r = (-r) % tr
    if pad_r:
        q = jnp.pad(q, ((0, pad_r), (0, 0), (0, 0)))
        scales = jnp.pad(scales, ((0, pad_r), (0, 0), (0, 0)))
    grid = ((r + pad_r) // tr, lp // Q_TILE)
    out = pl.pallas_call(
        _sqnorm_batched_q8_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, n, Q_TILE), lambda i, j: (i, 0, j)),
            pl.BlockSpec((tr, n, 1), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((tr, n), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r + pad_r, n), jnp.float32),
        interpret=interpret,
    )(q, scales)
    return out[:r]
