"""Convergence criteria (paper §III-C, eqs. 10-21).

The paper's convergence argument: each contributor's local loss delta
``L(w^q) - L(w^{q+1}) -> 0`` as q -> E_j (eq. 13); the aggregated loss is
the mean of contributor losses (eq. 15); and the requester's local fit
converges the same way (eq. 21).  Operationally we check the loss-delta
criterion on recorded histories.
"""

from __future__ import annotations

from typing import Sequence


def loss_delta_converged(losses: Sequence[float], tol: float = 1e-3,
                         patience: int = 2) -> bool:
    """True when the last ``patience`` consecutive loss deltas are < tol
    (the empirical form of eq. (12)/(20))."""
    if len(losses) < patience + 1:
        return False
    deltas = [abs(losses[i - 1] - losses[i]) for i in range(len(losses) - patience, len(losses))]
    return all(d < tol for d in deltas)


def aggregated_loss(contributor_losses: Sequence[float]) -> float:
    """Eq. (15): L1(w_M) = (1/N_c) * sum_j L(w_j)."""
    if not contributor_losses:
        raise ValueError("no contributors")
    return float(sum(contributor_losses) / len(contributor_losses))
