"""FL topologies as TPU collective schedules.

The paper's four communication regimes map onto mesh collectives:

| regime          | collective                               | bytes/round (w = update size) |
|-----------------|------------------------------------------|-------------------------------|
| CFL (FedAvg)    | all-reduce (`psum`) over client axes     | ~2w (bandwidth-optimal ring)  |
| DFL mesh        | `all_gather` + local mean                | N*w (everyone gets everything)|
| DFL ring        | (N-1) neighbour `ppermute` hops          | (N-1)*w, neighbour links only |
| EnFed           | masked reduce within a *neighborhood*    | (k-1)*w, k = nearby devices,  |
|                 | (contiguous segment of the data axis,    | never crosses the pod axis    |
|                 | ring of `ppermute` among contract-masked |                               |
|                 | contributors)                            |                               |

Two integration modes:

* ``aggregate_updates`` — applied to a *gradient/update pytree* inside a
  pjit train step via ``jax.shard_map`` over the client axes.  Outputs
  are consistent (replicated) for cfl / dfl_mesh / dfl_ring / enfed-global.
  ``enfed`` with ``neighborhood_size < axis size`` returns
  neighborhood-consensus values: shards in different neighborhoods hold
  different (locally agreed) results, which is the paper's opportunistic
  semantics — the launcher alternates a cheap neighborhood program with a
  periodic full-sync program (local-SGD style), so replication is
  restored at every sync boundary.  ``check_vma=False`` reflects this
  deliberate divergence.

* ``group_mixing_matrix`` — for the client-stacked trainer
  (``repro.core.federated.FederatedTrainer``), where params carry a
  leading client axis and every topology is a (C, C) row-stochastic
  mixing matrix applied per round: exact per-client FL semantics, fully
  jit-safe, sharded over the data axis.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

STRATEGIES = ("cfl", "dfl_mesh", "dfl_ring", "enfed", "none")


@dataclasses.dataclass(frozen=True)
class AggregationStrategy:
    kind: str = "cfl"
    client_axes: Tuple[str, ...] = ("data",)
    neighborhood_size: int = 0     # enfed: contributors per neighborhood (0 = whole axis)
    pod_local: bool = False        # enfed: never reduce across "pod" (hierarchical mode)
    # int8-compress ring hops (EnFed/DFL-ring): the update-quantization
    # lever the paper cites ([13],[14]) for communication energy, applied
    # to the wire — 4x fewer collective bytes per hop, lossy (per-leaf
    # absmax symmetric quantization).
    compress: Optional[str] = None  # None | "int8"

    def __post_init__(self):
        assert self.kind in STRATEGIES, self.kind
        assert self.compress in (None, "int8")


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def _client_index(axes, mesh: Mesh):
    idx = jnp.int32(0)
    for ax in axes:
        idx = idx * _axis_size(mesh, ax) + jax.lax.axis_index(ax)
    return idx


def _ring_sum(val, axis: str, n: int, group: int, compress: Optional[str] = None):
    """Sum within contiguous groups of size ``group`` along ``axis`` using
    neighbour ppermute hops only (EnFed 'nearby devices' = adjacent ICI).

    ``compress="int8"`` quantizes each hop's payload (per-leaf absmax
    symmetric int8 + one fp32 scale) before the permute — 4x fewer wire
    bytes, lossy by <= absmax/127 per hop per element."""
    perm = [(i, (i // group) * group + ((i % group) + 1) % group) for i in range(n)]

    def hop(tree):
        if compress != "int8":
            return jax.tree_util.tree_map(
                lambda x: jax.lax.ppermute(x, axis, perm), tree)

        def q(x):
            xf = x.astype(jnp.float32)
            scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
            qx = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
            qx = jax.lax.ppermute(qx, axis, perm)
            scale = jax.lax.ppermute(scale, axis, perm)
            return (qx.astype(jnp.float32) * scale).astype(x.dtype)

        return jax.tree_util.tree_map(q, tree)

    acc, cur = val, val
    for _ in range(group - 1):
        cur = hop(cur)
        acc = jax.tree_util.tree_map(jnp.add, acc, cur)
    return acc


def _full_ring_allreduce(tree, axis: str, n: int, compress=None):
    return _ring_sum(tree, axis, n, n, compress)


def aggregate_local(u, m, mesh: Mesh, strategy: AggregationStrategy):
    """Aggregation body — must run INSIDE a shard_map whose manual axes
    include ``strategy.client_axes``.  ``u`` is the local update pytree,
    ``m`` the replicated per-client participation vector."""
    axes = strategy.client_axes

    if True:  # keep the original dispatch block indentation
        idx = _client_index(axes, mesh)
        my = m[idx]

        if strategy.kind == "cfl":
            tot = jax.lax.psum(my, axes)
            summed = jax.lax.psum(jax.tree_util.tree_map(lambda x: x * my, u), axes)
            return jax.tree_util.tree_map(lambda x: x / jnp.maximum(tot, 1e-9), summed)

        if strategy.kind == "dfl_mesh":
            # every node gathers every node's update, then averages locally
            def leaf(x):
                g = jax.lax.all_gather(x * my, axes[-1])
                for ax in axes[:-1]:
                    g = jax.lax.all_gather(g, ax)
                return jnp.sum(g, axis=tuple(range(len(axes))))
            tot = jax.lax.psum(my, axes)
            summed = jax.tree_util.tree_map(leaf, u)
            return jax.tree_util.tree_map(lambda x: x / jnp.maximum(tot, 1e-9), summed)

        if strategy.kind == "dfl_ring":
            # exact consensus via n-1 neighbour hops along the innermost axis
            ax = axes[-1]
            n = _axis_size(mesh, ax)
            masked = jax.tree_util.tree_map(lambda x: x * my, u)
            summed = _full_ring_allreduce(masked, ax, n, strategy.compress)
            tot = _full_ring_allreduce(my, ax, n)
            if len(axes) > 1:  # hierarchical: finish over the outer axes
                summed = jax.lax.psum(summed, axes[:-1])
                tot = jax.lax.psum(tot, axes[:-1])
            return jax.tree_util.tree_map(lambda x: x / jnp.maximum(tot, 1e-9), summed)

        if strategy.kind == "enfed":
            # opportunistic: masked reduce among nearby devices only.
            ax = axes[-1]
            n = _axis_size(mesh, ax)
            k = strategy.neighborhood_size or n
            masked = jax.tree_util.tree_map(lambda x: x * my, u)
            if k >= n:
                summed = jax.lax.psum(masked, ax)
                tot = jax.lax.psum(my, ax)
            else:
                summed = _ring_sum(masked, ax, n, k, strategy.compress)
                tot = _ring_sum(my, ax, n, k)
            if len(axes) > 1 and not strategy.pod_local:
                summed = jax.lax.psum(summed, axes[:-1])
                tot = jax.lax.psum(tot, axes[:-1])
            return jax.tree_util.tree_map(lambda x: x / jnp.maximum(tot, 1e-9), summed)

        raise ValueError(strategy.kind)


def aggregate_updates(updates, mesh: Mesh, strategy: AggregationStrategy,
                      mask: Optional[jnp.ndarray] = None):
    """Aggregate an update pytree over the client axes of ``mesh``.

    ``updates`` leaves must be replicated over ``strategy.client_axes``
    (they may be arbitrarily sharded over the remaining axes — those stay
    in auto mode).  ``mask`` is a per-client participation vector of
    length prod(client-axis sizes), replicated; None = all participate.

    Returns the **client-stacked** result: every leaf gains a leading
    axis of size prod(client-axis sizes) holding each client's
    post-aggregation value (identical rows for the consensus strategies;
    per-neighborhood values for opportunistic EnFed).  This matches the
    physical truth that ring/neighborhood results vary per shard, which
    the vma checker enforces.  The federated train step keeps its client
    axis explicit and calls :func:`aggregate_local` directly instead.
    """
    if strategy.kind == "none":
        return updates
    axes = strategy.client_axes
    n_clients = int(np.prod([_axis_size(mesh, a) for a in axes]))
    if mask is None:
        mask = jnp.ones((n_clients,), jnp.float32)
    cspec = axes if len(axes) > 1 else axes[0]

    def agg(u, m):
        out = aggregate_local(u, m, mesh, strategy)

        # psum-based strategies yield vma-invariant values; mark varying so
        # one out_spec fits all strategies (pcast rejects varying->varying,
        # so only cast leaves that are still invariant)
        def mark(x):
            vma = getattr(jax.typeof(x), "vma", frozenset())
            missing = tuple(a for a in axes if a not in vma)
            if missing:
                x = jax.lax.pcast(x, missing, to="varying")
            return x[None]

        return jax.tree_util.tree_map(mark, out)

    fn = jax.shard_map(agg, mesh=mesh, axis_names=set(axes),
                       in_specs=(P(), P()), out_specs=P(cspec))
    return fn(updates, mask)


# ---------------------------------------------------------------------------
# contributor-level round masks (requester-centric view, both EnFed engines)
# ---------------------------------------------------------------------------


def contributor_round_mask(n_contrib: int, strategy: AggregationStrategy) -> np.ndarray:
    """Which *signed* contributors feed the requester's eq. (14) each round.

    The requester-centric analogue of the fleet-scale regimes above, for
    the session engines (``repro.core.rounds`` loop engine and
    ``repro.core.fleet`` jit engine).  Contributors are indexed in
    contract order (best utility first):

    * ``cfl`` / ``dfl_mesh`` / ``none`` — every signed contributor's
      update reaches the requester (virtual server / full mesh).
    * ``dfl_ring`` — only the requester's two ring neighbours transmit
      (contract ranks 0 and n-1; with <= 2 contributors the ring is the
      mesh).
    * ``enfed`` — the ``neighborhood_size`` nearest (= best-utility)
      contributors; 0 means all signed contributors, the paper default.
    """
    m = np.ones((n_contrib,), np.float32)
    if n_contrib <= 0:
        return m
    if strategy.kind == "dfl_ring" and n_contrib > 2:
        m[:] = 0.0
        m[0] = 1.0
        m[n_contrib - 1] = 1.0
    elif strategy.kind == "enfed" and strategy.neighborhood_size:
        k = min(strategy.neighborhood_size, n_contrib)
        m[k:] = 0.0
    return m


def dynamic_round_weights(member, rank, strategy: Optional[AggregationStrategy] = None):
    """Traced per-round aggregation weights under mobility/churn.

    The churn-aware analogue of :func:`contributor_round_mask` — instead
    of a static contract-rank mask, the inputs are the per-round outputs
    of ``repro.core.mobility.membership_step``: ``member`` (..., N) bool
    (the re-negotiated contract set) and ``rank`` (..., N) int32 utility
    ranks (0 = best).  Any leading batch shape broadcasts, so one call
    serves the fleet engine's (R, N) grid and the loop engine's (N,)
    vector:

    * ``None`` / ``cfl`` / ``dfl_mesh`` — every current member feeds
      eq. (14);
    * ``dfl_ring`` — the requester's two ring neighbours among current
      members (best + worst utility rank; everyone when <= 2 members);
    * ``enfed`` with ``neighborhood_size`` k — the k best-utility current
      members (0 = all), the paper's nearest-devices semantics.

    Both engines call THIS function, so churn-time aggregation weights
    agree by construction (mirroring ``protocol.round_weights`` for the
    static path).
    """
    member = jnp.asarray(member, bool)
    rank = jnp.asarray(rank, jnp.int32)
    w = member
    if strategy is not None:
        if strategy.kind == "dfl_ring":
            count = jnp.sum(member, axis=-1, keepdims=True).astype(jnp.int32)
            ring = (rank == 0) | (rank == count - 1)
            w = member & jnp.where(count > 2, ring, True)
        elif strategy.kind == "enfed" and strategy.neighborhood_size:
            w = member & (rank < strategy.neighborhood_size)
    return w.astype(jnp.float32)


# ---------------------------------------------------------------------------
# mixing matrices for the client-stacked trainer
# ---------------------------------------------------------------------------


def group_mixing_matrix(num_clients: int, strategy: AggregationStrategy,
                        mask: Optional[np.ndarray] = None) -> np.ndarray:
    """Row-stochastic (C, C) mixing matrix M: params' = M @ params.

    cfl / dfl_mesh: global masked mean rows.
    dfl_ring: one gossip step — (self + left + right) / participating.
    enfed: block-diagonal neighborhood masked means (nearby devices only).
    """
    C = num_clients
    m = np.ones(C, np.float32) if mask is None else np.asarray(mask, np.float32)
    M = np.zeros((C, C), np.float32)
    if strategy.kind in ("cfl", "dfl_mesh"):
        row = m / max(m.sum(), 1e-9)
        M[:] = row[None, :]
    elif strategy.kind == "dfl_ring":
        for i in range(C):
            neigh = [i, (i - 1) % C, (i + 1) % C]
            w = np.array([m[j] for j in neigh], np.float32)
            if w.sum() <= 0:
                M[i, i] = 1.0
                continue
            w = w / w.sum()
            for j, wj in zip(neigh, w):
                M[i, j] += wj
    elif strategy.kind == "enfed":
        k = strategy.neighborhood_size or C
        for g0 in range(0, C, k):
            sl = slice(g0, min(g0 + k, C))
            mg = m[sl]
            if mg.sum() <= 0:
                M[sl, sl] = np.eye(sl.stop - sl.start, dtype=np.float32)
                continue
            row = mg / mg.sum()
            M[sl, sl] = row[None, :]
    elif strategy.kind == "none":
        M = np.eye(C, dtype=np.float32)
    else:
        raise ValueError(strategy.kind)
    # non-participants keep their own params (mask row override)
    for i in range(C):
        if m[i] == 0 and strategy.kind in ("cfl", "dfl_mesh", "enfed"):
            M[i] = 0.0
            M[i, i] = 1.0
    return M


def mixing_matrix_jnp(num_clients: int, strategy: AggregationStrategy, mask=None):
    """Jit-traceable mixing matrix (mask may be a traced array).

    Same semantics as :func:`group_mixing_matrix`; non-participants keep
    their own params (identity rows) for cfl/mesh/enfed.
    """
    C = num_clients
    m = jnp.ones((C,), jnp.float32) if mask is None else jnp.asarray(mask, jnp.float32)
    eye = jnp.eye(C, dtype=jnp.float32)
    kind = strategy.kind
    if kind == "none":
        return eye
    if kind in ("cfl", "dfl_mesh"):
        row = m / jnp.maximum(m.sum(), 1e-9)
        M = jnp.broadcast_to(row, (C, C))
        return jnp.where((m > 0)[:, None], M, eye)
    if kind == "dfl_ring":
        idx = jnp.arange(C)
        nb = jnp.stack([idx, (idx - 1) % C, (idx + 1) % C], axis=1)   # (C, 3)
        w = m[nb]
        tot = w.sum(axis=1, keepdims=True)
        w = jnp.where(tot > 0, w / jnp.maximum(tot, 1e-9), jnp.zeros_like(w))
        M = jnp.zeros((C, C), jnp.float32).at[idx[:, None], nb].add(w)
        return jnp.where((tot[:, 0] > 0)[:, None], M, eye)
    if kind == "enfed":
        k = strategy.neighborhood_size or C
        group = jnp.arange(C) // k
        same = (group[:, None] == group[None, :]).astype(jnp.float32)
        M = same * m[None, :]
        tot = M.sum(axis=1, keepdims=True)
        M = jnp.where(tot > 0, M / jnp.maximum(tot, 1e-9), eye)
        return jnp.where((m > 0)[:, None], M, eye)
    raise ValueError(kind)


def apply_mixing(stacked_params, M):
    """params' = M @ params over the leading client axis of every leaf."""
    Mj = jnp.asarray(M)

    def mix(leaf):
        flat = leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)
        out = Mj @ flat
        return out.reshape(leaf.shape).astype(leaf.dtype)

    return jax.tree_util.tree_map(mix, stacked_params)
