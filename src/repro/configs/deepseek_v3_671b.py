"""DeepSeek-V3-671B [arXiv:2412.19437] — MoE with multi-head latent
attention (MLA), 1 shared + 256 routed experts (top-8), and a
multi-token-prediction (MTP) head.

Assigned spec: 61L, d_model=7168, 128H, MLA (q_lora 1536, kv_lora 512,
qk nope/rope 128/64, v 128), expert d_ff=2048, vocab=129280.
Full MLA attention => long_500k skipped.  fsdp=True: 671B params cannot
hold Adam state at 512 chips without ZeRO-3 over the data axis (and the
dry-run memory analysis documents that even then v5e-512 is short for
training — see EXPERIMENTS.md §Dry-run); EnFed federates this config
over the pod axis (cross-silo regime).
"""

from repro.models.config import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    citation="arXiv:2412.19437",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=2048,
    vocab_size=129_280,
    block_pattern=("mla",),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=256, num_experts_per_tok=8,
                  num_shared_experts=1, d_ff_expert=2048),
    mtp_depth=1,
    dtype="bfloat16",
    fsdp=True,
)
