"""AES-128-CTR for model-update transport (paper §III: updates are
AES-128 encrypted during transmission; keys are exchanged at handshake).

The S-box is derived at import time from GF(2^8) arithmetic (inverse +
affine map) instead of a hard-coded table, and the implementation is
validated against the FIPS-197 test vector in the test suite.  Key
expansion runs host-side in numpy (keys are protocol state, not traced
values); block encryption is vectorized JAX over blocks so an update
stream can be enciphered on-accelerator.  ``repro.kernels.aes_ctr``
provides the Pallas TPU kernel for the same keystream-XOR hot loop.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# GF(2^8) tables (built at import, host-side)
# ---------------------------------------------------------------------------


def _gf_mul(a: int, b: int) -> int:
    p = 0
    for _ in range(8):
        if b & 1:
            p ^= a
        hi = a & 0x80
        a = (a << 1) & 0xFF
        if hi:
            a ^= 0x1B
        b >>= 1
    return p


def _build_sbox() -> np.ndarray:
    inv = np.zeros(256, np.uint8)
    for x in range(1, 256):
        for y in range(1, 256):
            if _gf_mul(x, y) == 1:
                inv[x] = y
                break
    sbox = np.zeros(256, np.uint8)
    for x in range(256):
        b = int(inv[x])
        s = 0
        for i in range(8):
            bit = ((b >> i) ^ (b >> ((i + 4) % 8)) ^ (b >> ((i + 5) % 8))
                   ^ (b >> ((i + 6) % 8)) ^ (b >> ((i + 7) % 8)) ^ (0x63 >> i)) & 1
            s |= bit << i
        sbox[x] = s
    return sbox


_SBOX = _build_sbox()
_MUL2 = np.array([_gf_mul(x, 2) for x in range(256)], np.uint8)
_MUL3 = np.array([_gf_mul(x, 3) for x in range(256)], np.uint8)

# ShiftRows permutation on column-major state layout (i = row + 4*col):
# output byte (row r, col c) comes from input (row r, col (c + r) mod 4)
_SHIFT_ROWS = np.array([r + 4 * ((c + r) % 4) for c in range(4) for r in range(4)])

_RCON = np.array([0x01, 0x02, 0x04, 0x08, 0x10, 0x20, 0x40, 0x80, 0x1B, 0x36], np.uint8)


def expand_key(key: np.ndarray) -> np.ndarray:
    """AES-128 key schedule: (16,) uint8 -> (11, 16) uint8 round keys."""
    key = np.asarray(key, np.uint8)
    assert key.shape == (16,)
    words = [key[i * 4:(i + 1) * 4].copy() for i in range(4)]
    for i in range(4, 44):
        temp = words[i - 1].copy()
        if i % 4 == 0:
            temp = np.roll(temp, -1)
            temp = _SBOX[temp]
            temp[0] ^= _RCON[i // 4 - 1]
        words.append(words[i - 4] ^ temp)
    return np.stack([np.concatenate(words[i * 4:(i + 1) * 4]) for i in range(11)])


# ---------------------------------------------------------------------------
# block cipher (JAX, vectorized over blocks)
# ---------------------------------------------------------------------------

_J_SBOX = jnp.asarray(_SBOX)
_J_MUL2 = jnp.asarray(_MUL2)
_J_MUL3 = jnp.asarray(_MUL3)
_J_SHIFT = jnp.asarray(_SHIFT_ROWS)


def _mix_columns(state):
    """state: (N, 16) uint8, column-major (i = row + 4*col)."""
    s = state.reshape(-1, 4, 4)  # (N, col, row)
    a0, a1, a2, a3 = s[:, :, 0], s[:, :, 1], s[:, :, 2], s[:, :, 3]
    b0 = _J_MUL2[a0] ^ _J_MUL3[a1] ^ a2 ^ a3
    b1 = a0 ^ _J_MUL2[a1] ^ _J_MUL3[a2] ^ a3
    b2 = a0 ^ a1 ^ _J_MUL2[a2] ^ _J_MUL3[a3]
    b3 = _J_MUL3[a0] ^ a1 ^ a2 ^ _J_MUL2[a3]
    return jnp.stack([b0, b1, b2, b3], axis=-1).reshape(-1, 16)


def aes128_encrypt_blocks(blocks, round_keys):
    """blocks: (N, 16) uint8; round_keys: (11, 16) uint8 -> (N, 16) uint8."""
    state = blocks ^ round_keys[0]
    for rnd in range(1, 10):
        state = _J_SBOX[state]
        state = state[:, _J_SHIFT]
        state = _mix_columns(state)
        state = state ^ round_keys[rnd]
    state = _J_SBOX[state]
    state = state[:, _J_SHIFT]
    return state ^ round_keys[10]


# ---------------------------------------------------------------------------
# CTR mode over arbitrary payloads
# ---------------------------------------------------------------------------


def _counter_blocks(nonce: np.ndarray, n_blocks: int) -> np.ndarray:
    """nonce: (8,) uint8; returns (n_blocks, 16) uint8 CTR blocks."""
    ctr = np.arange(n_blocks, dtype=np.uint64)
    ctr_bytes = ctr[:, None].view(np.uint8).reshape(n_blocks, 8)[:, ::-1]  # big-endian
    return np.concatenate([np.broadcast_to(nonce, (n_blocks, 8)), ctr_bytes], axis=1)


def keystream(key: np.ndarray, nonce: np.ndarray, n_bytes: int):
    n_blocks = (n_bytes + 15) // 16
    rks = jnp.asarray(expand_key(key))
    blocks = jnp.asarray(_counter_blocks(np.asarray(nonce, np.uint8), n_blocks))
    ks = aes128_encrypt_blocks(blocks, rks)
    return ks.reshape(-1)[:n_bytes]


def encrypt_bytes(payload_u8, key, nonce):
    """CTR encryption: payload (n,) uint8 -> ciphertext (n,) uint8."""
    ks = keystream(key, nonce, int(payload_u8.shape[0]))
    return payload_u8 ^ ks


decrypt_bytes = encrypt_bytes  # CTR is an involution given the same keystream


def float_vector_to_bytes(vec):
    """(n,) float32 -> (4n,) uint8 via bitcast (serialization for transport)."""
    u8 = jax.lax.bitcast_convert_type(vec.astype(jnp.float32), jnp.uint8)
    return u8.reshape(-1)


def bytes_to_float_vector(u8):
    return jax.lax.bitcast_convert_type(u8.reshape(-1, 4), jnp.float32).reshape(-1)


def encrypt_update(vec, key, nonce):
    """Encrypt a flattened fp32 model update (the paper's transport unit)."""
    return encrypt_bytes(float_vector_to_bytes(vec), key, nonce)


def decrypt_update(cipher_u8, key, nonce):
    return bytes_to_float_vector(decrypt_bytes(cipher_u8, key, nonce))
