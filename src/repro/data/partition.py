"""Non-IID client partitioning.

The paper distributes both datasets "non-identically" across the
requesting node and five supporting nodes.  The standard way to control
that heterogeneity is a Dirichlet(alpha) label split (lower alpha = more
skewed clients); alpha=0.5 gives a realistic moderately non-IID fleet.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np


def dirichlet_partition(y: np.ndarray, num_clients: int, alpha: float = 0.5,
                        seed: int = 0, min_per_client: int = 8) -> List[np.ndarray]:
    """Partition sample indices across clients with Dirichlet label skew."""
    rng = np.random.default_rng(seed)
    classes = np.unique(y)
    idx_by_class = [np.flatnonzero(y == c) for c in classes]
    for idx in idx_by_class:
        rng.shuffle(idx)
    client_idx = [[] for _ in range(num_clients)]
    for idx in idx_by_class:
        props = rng.dirichlet(np.full(num_clients, alpha))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for cid, part in enumerate(np.split(idx, cuts)):
            client_idx[cid].extend(part.tolist())
    out = []
    pool = np.arange(len(y))
    for cid in range(num_clients):
        arr = np.asarray(client_idx[cid], dtype=np.int64)
        if len(arr) < min_per_client:  # top up starved clients
            extra = rng.choice(pool, size=min_per_client - len(arr), replace=False)
            arr = np.concatenate([arr, extra])
        rng.shuffle(arr)
        out.append(arr)
    return out


def iid_partition(n: int, num_clients: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    return [np.asarray(p) for p in np.array_split(idx, num_clients)]


def partition_stats(y: np.ndarray, parts: List[np.ndarray]) -> Tuple[np.ndarray, float]:
    """Per-client class histogram and a heterogeneity score (mean TV distance
    between client label distribution and the global one)."""
    classes = np.unique(y)
    global_p = np.array([(y == c).mean() for c in classes])
    hists = []
    tvs = []
    for p in parts:
        yy = y[p]
        h = np.array([(yy == c).mean() if len(yy) else 0.0 for c in classes])
        hists.append(h)
        tvs.append(0.5 * np.abs(h - global_p).sum())
    return np.stack(hists), float(np.mean(tvs))
