"""Model-zoo correctness: decode-cache parity vs full forward for every
mixer type, MLA absorbed-decode parity, MoE dispatch equivalences, and
classifier learnability."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig, MoEConfig, MLAConfig
from repro.models.transformer import Transformer
from repro.models import moe as moe_mod

BASE = ModelConfig(name="t", family="dense", num_layers=3, d_model=64,
                   num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128)

VARIANTS = {
    "dense": BASE,
    "swa": BASE.replace(block_pattern=("swa",), sliding_window=8),
    "local": BASE.replace(block_pattern=("local",), local_window=4),
    "mla": BASE.replace(block_pattern=("mla",), mla=MLAConfig(64, 32, 16, 8, 16)),
    "rg_hybrid": BASE.replace(num_layers=5, block_pattern=("rglru", "rglru", "local"),
                              local_window=8, rnn_width=64),
    "xlstm": BASE.replace(num_layers=3, block_pattern=("mlstm", "mlstm", "slstm"), d_ff=0),
}


# tier-1 keeps one attention (dense) and one recurrent (xlstm) decode
# parity check; the remaining mixer variants run with -m slow alongside
# the multi-arch smoke sweep
FAST_DECODE = ("dense", "xlstm")


@pytest.mark.parametrize(
    "name", [pytest.param(n, marks=() if n in FAST_DECODE else pytest.mark.slow)
             for n in sorted(VARIANTS)])
def test_decode_matches_forward(name):
    cfg = VARIANTS[name]
    m = Transformer(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full = m.forward(params, {"tokens": toks})["logits"]
    cache = m.init_cache(B, S)
    dec = []
    for t in range(S):
        lg, cache = m.decode_step(params, toks[:, t:t + 1], cache, t)
        dec.append(lg[:, 0])
    dec = jnp.stack(dec, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-3)


@pytest.mark.slow
def test_mla_absorbed_decode_parity():
    cfg = VARIANTS["mla"]
    m = Transformer(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    c1, c2 = m.init_cache(B, S), m.init_cache(B, S)
    for t in range(S):
        l1, c1 = m.decode_step(params, toks[:, t:t + 1], c1, t, mla_absorbed=False)
        l2, c2 = m.decode_step(params, toks[:, t:t + 1], c2, t, mla_absorbed=True)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-4)


@pytest.mark.parametrize("shape", [(4, 32), (2, 64)])
def test_moe_sort_matches_einsum(shape):
    cfg = BASE.replace(family="moe", moe=MoEConfig(
        num_experts=8, num_experts_per_tok=2, num_shared_experts=1,
        d_ff_expert=32, dispatch="sort"))
    cfg_e = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch="einsum"))
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), shape + (64,))
    y_s, a_s = moe_mod.moe_apply(params, x, cfg)
    y_e, a_e = moe_mod.moe_apply(params, x, cfg_e)
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_e), atol=1e-4)
    assert float(a_s) == pytest.approx(float(a_e), abs=1e-6)


def test_moe_aux_loss_increases_with_imbalance():
    cfg = BASE.replace(family="moe", moe=MoEConfig(
        num_experts=4, num_experts_per_tok=1, num_shared_experts=0, d_ff_expert=32))
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64))
    _, aux_balanced = moe_mod.moe_apply(params, x, cfg)
    # force the router to prefer a single expert
    skew = params.copy()
    skew["router"] = params["router"].at[:, 0].add(100.0)
    _, aux_skewed = moe_mod.moe_apply(skew, x, cfg)
    assert float(aux_skewed) > float(aux_balanced)


def test_mlstm_chunked_scan_exact():
    from repro.models import recurrent
    cfg = BASE.replace(family="ssm", block_pattern=("mlstm",), d_ff=0)
    p = recurrent.mlstm_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 64))
    y0 = recurrent.mlstm_apply(p, x, cfg)
    y1 = recurrent.mlstm_apply(p, x, cfg.replace(mlstm_chunk=8))
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)


def test_classifiers_learn_har():
    from repro.core import SupervisedTask
    from repro.data import HARDatasetConfig, make_har_windows, train_test_split
    from repro.models import LSTMClassifier, LSTMClassifierConfig
    x, y, _ = make_har_windows(HARDatasetConfig(num_samples=800, seq_len=16))
    (tx, ty), (ex, ey) = train_test_split(x, y, 0.2)
    task = SupervisedTask(LSTMClassifier(LSTMClassifierConfig(6, 16, 48, 6)), lr=3e-3)
    p = task.init(0)
    p, losses = task.fit(p, (tx, ty), epochs=10, batch_size=32, seed=0)
    assert task.evaluate(p, (ex, ey)) > 0.85
    assert losses[-1] < losses[0]


def test_logit_softcap_bounds_logits():
    cfg = BASE.replace(logit_softcap=5.0)
    m = Transformer(cfg)
    params = m.init(jax.random.PRNGKey(0))
    out = m.forward(params, {"tokens": jnp.zeros((2, 8), jnp.int32)})
    assert float(jnp.max(jnp.abs(out["logits"]))) <= 5.0 + 1e-5
