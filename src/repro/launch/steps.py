"""Step builders: federated train step, prefill step, serve (decode) step.

The federated train step is the paper's technique as a first-class
feature of the distributed runtime.  Every client of the FL fleet is one
shard of the mesh client axes (``data``, plus ``pod`` multi-pod; fsdp
configs federate over ``pod`` only).  Parameters and optimizer state
carry an explicit leading client axis of size C = prod(client axis
sizes), sharded so each device holds exactly one client's replica — the
same memory as replicated storage, but honest semantics: clients may
diverge (opportunistic EnFed neighborhoods) and the aggregation
collective is *explicit* and selectable:

  cfl       psum over all client axes          (~2w bytes, FedAvg)
  dfl_mesh  all_gather + local mean            (N*w bytes)
  dfl_ring  (N-1) neighbour ppermute hops      ((N-1)*w, neighbour links)
  enfed     masked ring-reduce within a        ((k-1)*w, never crosses pod)
            k-neighborhood of the data axis

Everything inside the client shard_map keeps the ``model`` (and for
fsdp configs ``data``) axes in auto mode, so tensor-parallel / ZeRO
sharding composes with the FL schedule.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.topology import AggregationStrategy, aggregate_local
from repro.models import Transformer, cross_entropy_loss
from repro.optim import adam, apply_updates
from repro.sharding import param_specs, manual_axes
from repro.sharding.specs import _spec_for, _path_str

MTP_LOSS_WEIGHT = 0.3


def lm_loss(model: Transformer, params, batch):
    out = model.forward(params, batch)
    logits = out["logits"]
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:  # VLM prefix tokens carry no labels
        logits = logits[:, -labels.shape[1]:]
    loss = cross_entropy_loss(logits.reshape(-1, logits.shape[-1]), labels.reshape(-1))
    loss = loss + out["aux_loss"]
    if "mtp_logits" in out:
        mtp = out["mtp_logits"]
        if mtp.shape[1] != labels.shape[1]:
            mtp = mtp[:, -labels.shape[1]:]
        # MTP head predicts token t+2: shift labels left by one more step
        mtp_labels = jnp.roll(labels, -1, axis=1)
        loss = loss + MTP_LOSS_WEIGHT * cross_entropy_loss(
            mtp[:, :-1].reshape(-1, mtp.shape[-1]), mtp_labels[:, :-1].reshape(-1))
    return loss


def num_clients(mesh: Mesh, client_axes) -> int:
    return int(np.prod([mesh.shape[a] for a in client_axes])) if client_axes else 1


# ---------------------------------------------------------------------------
# federated parameter/opt-state stacking
# ---------------------------------------------------------------------------


def stack_for_clients(tree, C: int):
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (C,) + x.shape).copy(), tree)


def fed_param_shardings(params_shape, mesh: Mesh, client_axes, fsdp: bool):
    """NamedShardings for client-stacked params: axis0 over the client
    axes, remaining axes per the base (TP/FSDP) rules."""
    client = tuple(client_axes)

    def f(path, leaf):
        base = _spec_for(_path_str(path), leaf.shape[1:], mesh, fsdp=fsdp)
        inner = [None if (e in client or (isinstance(e, tuple) and set(e) & set(client))) else e
                 for e in base]
        spec = P(client if len(client) > 1 else client[0], *inner) if client else P(None, *inner)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(f, params_shape)


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------


def make_federated_train_step(model: Transformer, mesh: Mesh,
                              strategy: AggregationStrategy, lr: float = 1e-4):
    """Returns (train_step, opt).  train_step(params_fed, opt_fed, batch,
    mask) -> (params_fed, opt_fed, loss): one FL round of 1 local step +
    the strategy's aggregation collective."""
    opt = adam(lr)
    client_axes = tuple(strategy.client_axes)

    import contextlib
    from repro.models.moe import disable_token_local
    # bf16 MoE token-local routing under grad + auto-sharded params crashes
    # the XLA-CPU partitioner (see repro.models.moe) — those train steps
    # enter the routing region through an fp32 boundary cast.  The
    # client-stacked path is only affected for fsdp configs (the client
    # shard_map already makes 'data' manual for the others).
    needs_guard = (model.cfg.moe is not None
                   and model.cfg.jnp_dtype == jnp.bfloat16
                   and (model.cfg.fsdp or not client_axes or strategy.kind == "none"))
    tl_guard = disable_token_local if needs_guard else contextlib.nullcontext

    if not client_axes or strategy.kind == "none":
        # conventional pjit path: XLA inserts the grad reduction
        def plain_step(params, opt_state, batch, mask):
            del mask
            with tl_guard():
                loss, grads = jax.value_and_grad(lambda p: lm_loss(model, p, batch))(params)
            upd, opt_state = opt.update(grads, opt_state, params)
            return apply_updates(params, upd), opt_state, loss

        return plain_step, opt

    cspec = client_axes if len(client_axes) > 1 else client_axes[0]

    def local_step(p_blk, o_blk, batch_blk, mask):
        squeeze = lambda t: jax.tree_util.tree_map(lambda x: x[0], t)
        expand = lambda t: jax.tree_util.tree_map(lambda x: x[None], t)
        p = squeeze(p_blk)
        o = squeeze(o_blk)
        with manual_axes(client_axes), tl_guard():
            loss, grads = jax.value_and_grad(lambda q: lm_loss(model, q, batch_blk))(p)
            grads = aggregate_local(grads, mask, mesh, strategy)
            loss = jax.lax.pmean(loss, client_axes)
        upd, o = opt.update(grads, o, p)
        p = apply_updates(p, upd)
        return expand(p), expand(o), loss

    def train_step(params_fed, opt_fed, batch, mask):
        return jax.shard_map(
            local_step, mesh=mesh, axis_names=set(client_axes),
            in_specs=(P(cspec), P(cspec), P(cspec), P()),
            out_specs=(P(cspec), P(cspec), P()),
        )(params_fed, opt_fed, batch, mask)

    return train_step, opt


# ---------------------------------------------------------------------------
# prefill / serve steps
# ---------------------------------------------------------------------------


def make_prefill_step(model: Transformer):
    def prefill_step(params, batch):
        out = model.forward(params, batch, last_logit_only=True)
        return out["logits"]

    return prefill_step


def make_serve_step(model: Transformer, mla_absorbed: bool = False):
    def serve_step(params, cache, tokens, pos, memory=None):
        logits, cache = model.decode_step(params, tokens, cache, pos,
                                          memory=memory, mla_absorbed=mla_absorbed)
        return logits, cache

    return serve_step
