"""The paper's HAR data-analysis models: an LSTM and an MLP classifier.

Table III of the paper: LSTM (softmax head, Adam, categorical
cross-entropy, 100 epochs) and MLP (hidden sizes (64, 32), ReLU, Adam).
These are the models federated by EnFed in the faithful reproduction.

The LSTM cell is injectable (``cell="ref" | "pallas"``): the Pallas
kernel in ``repro.kernels.lstm_cell`` is the TPU hot-path implementation
and is validated against the reference cell here.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class LSTMClassifierConfig:
    input_dim: int        # sensor features per timestep
    seq_len: int          # window length
    hidden: int = 64
    num_classes: int = 6
    cell: str = "ref"     # "ref" | "pallas"


@dataclasses.dataclass(frozen=True)
class MLPClassifierConfig:
    input_dim: int
    hidden: Tuple[int, ...] = (64, 32)   # paper Table III
    num_classes: int = 5


# ---------------------------------------------------------------------------
# LSTM
# ---------------------------------------------------------------------------


def lstm_cell_ref(x, h, c, wx, wh, b):
    """Reference LSTM cell. x:(B,F) h,c:(B,H) wx:(F,4H) wh:(H,4H) b:(4H,)."""
    gates = x @ wx + h @ wh + b
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def _get_cell(name: str):
    if name == "ref":
        return lstm_cell_ref
    if name == "pallas":
        from repro.kernels.lstm_cell.ops import lstm_cell as pallas_cell
        return pallas_cell
    raise ValueError(name)


class LSTMClassifier:
    def __init__(self, cfg: LSTMClassifierConfig):
        self.cfg = cfg

    def init(self, rng):
        cfg = self.cfg
        ks = jax.random.split(rng, 4)
        H = cfg.hidden
        return {
            "wx": layers.dense_init(ks[0], cfg.input_dim, 4 * H, jnp.float32),
            "wh": layers.dense_init(ks[1], H, 4 * H, jnp.float32),
            "b": jnp.zeros((4 * H,), jnp.float32),
            "w_out": layers.dense_init(ks[2], H, cfg.num_classes, jnp.float32),
            "b_out": jnp.zeros((cfg.num_classes,), jnp.float32),
        }

    def forward(self, params, x):
        """x: (B, T, F) -> logits (B, num_classes)."""
        cfg = self.cfg
        B = x.shape[0]
        cell = _get_cell(cfg.cell)
        h0 = jnp.zeros((B, cfg.hidden), jnp.float32)
        c0 = jnp.zeros((B, cfg.hidden), jnp.float32)

        def step(carry, x_t):
            h, c = carry
            h, c = cell(x_t, h, c, params["wx"], params["wh"], params["b"])
            return (h, c), None

        (h, _), _ = jax.lax.scan(step, (h0, c0), jnp.moveaxis(x, 1, 0))
        return h @ params["w_out"] + params["b_out"]


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


class MLPClassifier:
    def __init__(self, cfg: MLPClassifierConfig):
        self.cfg = cfg

    def init(self, rng):
        cfg = self.cfg
        dims = (cfg.input_dim,) + tuple(cfg.hidden) + (cfg.num_classes,)
        ks = jax.random.split(rng, len(dims) - 1)
        return {
            f"layer{i}": {
                "w": layers.dense_init(ks[i], dims[i], dims[i + 1], jnp.float32),
                "b": jnp.zeros((dims[i + 1],), jnp.float32),
            }
            for i in range(len(dims) - 1)
        }

    def forward(self, params, x):
        """x: (B, F) -> logits (B, num_classes)."""
        n = len(params)
        for i in range(n):
            lp = params[f"layer{i}"]
            x = x @ lp["w"] + lp["b"]
            if i < n - 1:
                x = jax.nn.relu(x)
        return x


# ---------------------------------------------------------------------------
# shared loss / metrics
# ---------------------------------------------------------------------------


def cross_entropy_loss(logits, labels):
    """Categorical cross-entropy (labels are int class ids)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def masked_cross_entropy_loss(logits, labels, weights):
    """Per-sample-weighted categorical cross-entropy.

    ``weights`` is the minibatch's 0/1 sample mask from
    ``repro.core.schedule`` (all-ones for full batches; zero on the
    padding of a sub-batch shard's single padded step).  Both EnFed
    engines optimize THIS loss, so their training math is identical even
    on shards smaller than one batch.
    """
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.sum(nll * weights) / jnp.maximum(jnp.sum(weights), 1.0)


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
