"""Device cadence world: per-device availability, duty-cycle windows and
compute-speed classes — the counter-based clock that ends the lockstep
round barrier, shared by BOTH EnFed engines.

A production fleet of battery-constrained mobile devices does not tick
on one global round clock: devices differ in compute speed, sleep their
radios on duty cycles, and drop offline for stretches.  This module
makes that cadence part of the simulated world, with the same design
rule as :mod:`repro.core.mobility` and :mod:`repro.core.faults`: whether
a device *ticks* (advances its own round clock) at global event step
``t`` is a closed-form function of ``(seed, step, device)`` — pure
counter-based ``jax.random.fold_in`` chains and exact int32
comparisons, no carried RNG — so the loop engine (host-side, concrete
steps) and the fleet engine (traced steps inside one jit program)
derive bit-identical cadence by construction, and any step's tick set
can be queried without replaying earlier steps.

A device's tick rule composes three independent counter-based gates:

* **Speed class** — each device hashes to a round *stride* in
  ``1..n_speed_classes`` (stride 1 = fastest); the device ticks only on
  steps where ``(t + phase) % stride == 0``, with a per-device hashed
  phase so classes desynchronize instead of herding.
* **Duty cycle** — with ``duty_cycle > 0`` the device's radio is awake
  only ``duty_on`` steps out of every ``duty_cycle`` window (per-device
  hashed window offset); asleep steps never tick.
* **Transient offline** — each ``(step, device)`` draws an independent
  int32 and the device is offline iff it lands under the ``p_offline``
  threshold, exactly the faults-module drop arithmetic.

On top of the closed-form gates sits the one *state-coupled* rule,
battery-aware pacing: when the device's battery fraction is below
``pace_battery_threshold`` its effective stride multiplies by
``pace_factor`` (a drained device slows its own round clock to stretch
what charge remains — the 2208.04505 policy).  Battery levels are
carried state, but both engines carry bitwise-identical levels, and the
comparison is performed in float32 on both sides, so pacing decisions
cannot diverge between engines.

Under cadence the engines loop over *global event steps* rather than
rounds: world state (mobility kinematics, fault weather) is keyed on
the step counter, each requester lane carries its own round clock that
advances only on its ticks, and a contributor that does not tick simply
skips its REFRESH — its wire image stays resident and faster neighbors
aggregate it as-is (the straggler path; composes with the stale/int8
prev-wire buffers, never a staged fp32 shadow).  ``cadence=None`` keeps
today's lockstep loop: one step per round, every device ticks every
step, bit-for-bit.

Parity-safety rule (same as mobility/faults): every predicate is an
exact integer comparison — thresholds precomputed host-side from the
static probabilities, draws and modular arithmetic in int32 — except
the battery-pacing compare, which is float32-exact on bitwise-equal
operands.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# Offline draws live in [0, _DRAW_MAX); a probability p maps to the
# threshold int(p * _DRAW_MAX) — identical arithmetic to repro.core.faults.
_DRAW_MAX = 2**31 - 1

_SALT_SPEED = 0x5C    # per-device compute-speed class
_SALT_PHASE = 0xB1    # per-device stride phase offset
_SALT_DUTY = 0xD2     # per-device duty-window offset
_SALT_OFFLINE = 0x0F  # per-(step, device) transient availability


@dataclasses.dataclass(frozen=True)
class CadenceConfig:
    """Device-cadence world parameters for one simulated session
    (frozen/hashable => usable as a static arg of the compiled fleet
    program, exactly like :class:`repro.core.faults.FaultConfig`).

    ``requester_id`` is the requesting device's id in the cadence
    hash-space; fleet lanes use ``requester_id + lane`` so concurrent
    requesters draw independent clocks.  The default offset keeps
    cadence-space requester ids clear of contributor ids AND of the
    mobility/fault id spaces.  Contributors tick by their real device
    ids — their cadence is a property of the device, not of any one
    session observing it.
    """

    n_speed_classes: int = 1      # strides hash into 1..n_speed_classes
    duty_cycle: int = 0           # radio duty window length (0 = always on)
    duty_on: int = 1              # awake steps per duty window
    p_offline: float = 0.0        # per-step transient-offline probability
    pace_battery_threshold: float = 0.0   # below this battery fraction...
    pace_factor: int = 1          # ...the stride multiplies by this
    idle_step_s: float = 0.05     # wall seconds one idle event step costs
    max_events: int = 0           # global event-step budget (0 = derive
                                  # from max_rounds via events_budget)
    seed: int = 0                 # cadence hash seed
    requester_id: int = 1 << 22   # requester lane 0's id in cadence space

    def __post_init__(self):
        # fail fast at CONSTRUCTION — not as a silent never-ticking lane
        # deep inside the jit program (the satellite rule FaultConfig set)
        if self.n_speed_classes < 1:
            raise ValueError(
                f"n_speed_classes must be >= 1 (got {self.n_speed_classes})")
        if self.duty_cycle < 0:
            raise ValueError(
                f"duty_cycle must be >= 0 (got {self.duty_cycle})")
        if self.duty_cycle > 0 and not 1 <= self.duty_on <= self.duty_cycle:
            raise ValueError(
                f"duty_on must be within [1, duty_cycle] "
                f"(got {self.duty_on} of {self.duty_cycle})")
        if not 0.0 <= self.p_offline < 1.0:
            raise ValueError(
                f"p_offline must be within [0, 1) (got {self.p_offline})")
        if not 0.0 <= self.pace_battery_threshold <= 1.0:
            raise ValueError(
                f"pace_battery_threshold must be within [0, 1] "
                f"(got {self.pace_battery_threshold})")
        if self.pace_factor < 1:
            raise ValueError(
                f"pace_factor must be >= 1 (got {self.pace_factor})")
        if self.idle_step_s < 0.0:
            raise ValueError(
                f"idle_step_s must be >= 0 (got {self.idle_step_s})")
        if self.max_events < 0:
            raise ValueError(
                f"max_events must be >= 0 (got {self.max_events})")


def _threshold(p: float) -> jnp.int32:
    """The static int32 threshold a probability compiles to."""
    return jnp.int32(int(min(max(float(p), 0.0), 1.0) * _DRAW_MAX))


def _device_draw(seed: int, salt: int, device_id, t):
    """One int32 draw in [0, _DRAW_MAX) hashed from ``(seed, salt,
    device, step)`` alone — prefix-stable in every argument, traced or
    concrete.  Per-device constants pass ``t=0``."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), jnp.uint32(salt))
    key = jax.random.fold_in(key, jnp.asarray(device_id, jnp.uint32))
    key = jax.random.fold_in(key, jnp.asarray(t, jnp.uint32))
    return jax.random.randint(key, (), 0, _DRAW_MAX, jnp.int32)


def speed_stride(cc: CadenceConfig, device_ids):
    """(...,) int32 base round stride per device, in 1..n_speed_classes.

    Stride 1 devices tick every step; stride k devices every k-th step.
    Hashed once per device — a device's speed class is a property of the
    device, constant for the whole session.
    """
    ids = jnp.asarray(device_ids, jnp.int32)
    draw = jax.vmap(lambda d: _device_draw(cc.seed, _SALT_SPEED, d, 0))(
        ids.reshape(-1)).reshape(ids.shape)
    return jnp.int32(1) + jnp.remainder(draw, jnp.int32(cc.n_speed_classes))


def effective_stride(cc: CadenceConfig, device_ids, level=None):
    """Per-device stride after battery-aware pacing.

    ``level`` (matching ``device_ids``' shape, or None) is the battery
    fraction; below ``pace_battery_threshold`` the stride multiplies by
    ``pace_factor``.  The compare is float32 on both operands — battery
    levels are bitwise-identical across engines, so the paced set is too.
    """
    stride = speed_stride(cc, device_ids)
    if level is None or cc.pace_factor <= 1 or cc.pace_battery_threshold <= 0:
        return stride
    paced = (jnp.asarray(level, jnp.float32)
             < jnp.float32(cc.pace_battery_threshold))
    return jnp.where(paced, stride * jnp.int32(cc.pace_factor), stride)


def tick_mask(cc: CadenceConfig, t, device_ids, level=None):
    """(...,) bool: which devices tick at global event step ``t`` — THE
    shared derivation of both engines.

    ``t`` is scalar (python int or traced); ``device_ids`` any shape;
    ``level`` optional battery fractions (enables pacing).  A ticking
    device executes its next protocol round this step; a non-ticking
    device idles (requester) or skips its refresh, leaving its resident
    wire image for faster neighbors to aggregate as-is (contributor).
    """
    ids = jnp.asarray(device_ids, jnp.int32)
    ts = jnp.asarray(t, jnp.int32)
    stride = effective_stride(cc, ids, level)
    phase_draw = jax.vmap(lambda d: _device_draw(cc.seed, _SALT_PHASE, d, 0))(
        ids.reshape(-1)).reshape(ids.shape)
    phase = jnp.remainder(phase_draw, stride)
    on = jnp.remainder(ts + phase, stride) == 0
    if cc.duty_cycle > 0:
        duty_draw = jax.vmap(
            lambda d: _device_draw(cc.seed, _SALT_DUTY, d, 0))(
            ids.reshape(-1)).reshape(ids.shape)
        duty_phase = jnp.remainder(duty_draw, jnp.int32(cc.duty_cycle))
        on &= (jnp.remainder(ts + duty_phase, jnp.int32(cc.duty_cycle))
               < jnp.int32(cc.duty_on))
    if cc.p_offline > 0.0:
        thr = _threshold(cc.p_offline)
        off_draw = jax.vmap(
            lambda d: _device_draw(cc.seed, _SALT_OFFLINE, d, ts))(
            ids.reshape(-1)).reshape(ids.shape)
        on &= off_draw >= thr
    return on


def image_lag(cc: CadenceConfig, t, device_ids):
    """(...,) int32: event steps since each device's wire image was last
    refreshed, as seen by an aggregate at step ``t`` — the closed-form
    staleness clock behind ``EnFedConfig.staleness_gamma``.

    A stride-``s`` device with hashed phase ``phi`` ticks on steps where
    ``(t + phi) % s == 0``; its REFRESH publishes the image the NEXT
    step consumes, so at step ``t`` the image dates from the latest tick
    at or before ``t - 1`` and the lag is ``(t - 1 + phi) % s``.  A
    stride-1 device therefore always shows lag 0 and (with the fault
    module's +1 for stale delivery) ``gamma == 1`` reproduces today's
    weights bit-for-bit.

    Deliberately derived from the UNPACED base stride and phase only —
    the same schedule the refresh gate uses — so the lag is a pure
    ``(seed, step, device)`` closed form shared verbatim by both
    engines.  Duty-cycle sleep, transient offline draws and
    battery-aware pacing can delay the actual refresh beyond this bound;
    those gates deepen staleness without deepening the *decay*, a
    documented approximation that keeps the weight schedule
    state-free.
    """
    ids = jnp.asarray(device_ids, jnp.int32)
    ts = jnp.asarray(t, jnp.int32)
    stride = speed_stride(cc, ids)
    phase_draw = jax.vmap(lambda d: _device_draw(cc.seed, _SALT_PHASE, d, 0))(
        ids.reshape(-1)).reshape(ids.shape)
    phase = jnp.remainder(phase_draw, stride)
    return jnp.remainder(ts - jnp.int32(1) + phase, stride)


def events_budget(cc: CadenceConfig, max_rounds: int) -> int:
    """The global event-step budget a session loops over (static, host).

    ``max_events`` when set; otherwise derived so the *slowest possible*
    device (worst speed class, battery-paced, worst duty window) can
    still complete ``max_rounds`` rounds, with a 2x allowance for
    transient-offline streaks.  A lane that exhausts the budget mid-run
    simply stops with fewer rounds (stop reason ``max_rounds``) —
    exactly how the lockstep loop treats its round budget.
    """
    if cc.max_events > 0:
        return int(cc.max_events)
    stride_max = cc.n_speed_classes * max(cc.pace_factor, 1)
    duty_factor = (-(-cc.duty_cycle // cc.duty_on)
                   if cc.duty_cycle > 0 else 1)
    offline_factor = 2 if cc.p_offline > 0.0 else 1
    return int(max_rounds) * stride_max * duty_factor * offline_factor
