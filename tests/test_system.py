"""End-to-end behaviour tests: the EnFed protocol against the paper's
claims at test scale, plus the training/serving drivers."""

import numpy as np
import pytest

from repro.core import (EnFedConfig, EnFedSession, SupervisedTask,
                        BatteryState, make_fleet)
from repro.data import HARDatasetConfig, dirichlet_partition, make_har_windows
from repro.models import LSTMClassifier, LSTMClassifierConfig


@pytest.fixture(scope="module")
def har_setup():
    x, y, _ = make_har_windows(HARDatasetConfig(num_samples=1200, seq_len=24))
    parts = dirichlet_partition(y, 6, alpha=1.0, seed=0)
    shards = [(x[p], y[p]) for p in parts]
    own_x, own_y = shards[0]
    n = int(len(own_x) * 0.8)
    task = SupervisedTask(LSTMClassifier(LSTMClassifierConfig(6, 24, 48, 6)), lr=3e-3)
    fleet = make_fleet(5, seed=1, p_has_model=1.0)
    states = {}
    for i, dev in enumerate(fleet):
        dev.reservation_price = 0.4
        p = task.init(seed=10 + i)
        p, _ = task.fit(p, shards[i + 1], epochs=4, batch_size=32, seed=i)
        states[dev.device_id] = {"params": p, "data": shards[i + 1]}
    return task, shards, (own_x[:n], own_y[:n]), (own_x[n:], own_y[n:]), fleet, states


def test_enfed_session_improves_over_random(har_setup):
    task, shards, own_train, own_test, fleet, states = har_setup
    rand_acc = task.evaluate(task.init(seed=123), own_test)
    res = EnFedSession(task, own_train, own_test, fleet, states,
                       EnFedConfig(desired_accuracy=0.9, epochs=4, max_rounds=4)).run()
    assert res.accuracy > max(rand_acc + 0.2, 0.5)
    assert res.n_contributors == 5
    assert res.stop_reason in ("accuracy_reached", "max_rounds", "battery_low")


def test_enfed_stops_on_battery_threshold(har_setup):
    task, shards, own_train, own_test, fleet, states = har_setup
    battery = BatteryState(capacity_j=3.0, level=0.25)
    res = EnFedSession(task, own_train, own_test, fleet, states,
                       EnFedConfig(desired_accuracy=0.999, epochs=2, max_rounds=10),
                       battery=battery).run()
    assert res.stop_reason == "battery_low"
    assert res.rounds < 10


def test_enfed_respects_round_budget(har_setup):
    task, shards, own_train, own_test, fleet, states = har_setup
    res = EnFedSession(task, own_train, own_test, fleet, states,
                       EnFedConfig(desired_accuracy=0.9999, epochs=1, max_rounds=2)).run()
    assert res.rounds == 2 and res.stop_reason == "max_rounds"


def test_enfed_encrypted_equals_plain_aggregation(har_setup):
    """AES transport must be transparent: same accuracy trajectory."""
    task, shards, own_train, own_test, fleet, states = har_setup
    states2 = {k: {"params": v["params"], "data": v["data"]} for k, v in states.items()}
    cfg = EnFedConfig(desired_accuracy=0.999, epochs=2, max_rounds=2,
                      contributor_refresh_epochs=0)
    r1 = EnFedSession(task, own_train, own_test, fleet, states, cfg).run()
    cfg2 = EnFedConfig(desired_accuracy=0.999, epochs=2, max_rounds=2,
                       contributor_refresh_epochs=0, encrypt=False)
    r2 = EnFedSession(task, own_train, own_test, fleet, states2, cfg2).run()
    np.testing.assert_allclose(r1.history_raw["accuracy"], r2.history_raw["accuracy"], atol=1e-3)


@pytest.mark.slow  # full train driver re-jits a transformer from scratch
def test_train_driver_end_to_end(tmp_path):
    from repro.launch import train as train_mod
    rc = train_mod.main(["--arch", "debug-dense", "--preset", "smoke",
                         "--steps", "8", "--clients", "2", "--batch", "4",
                         "--seq", "32", "--strategy", "enfed",
                         "--ckpt-dir", str(tmp_path / "ckpt"),
                         "--ckpt-every", "4", "--log-every", "100"])
    assert rc == 0  # loss improved
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path / "ckpt")) is not None


def test_serve_driver_end_to_end():
    from repro.launch import serve as serve_mod
    rc = serve_mod.main(["--arch", "debug-dense", "--preset", "smoke",
                         "--batch", "2", "--prompt-len", "8", "--gen", "4"])
    assert rc == 0
