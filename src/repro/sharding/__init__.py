from repro.sharding.ctx import (
    MeshContext,
    use_mesh,
    current_mesh_context,
    shard_activation,
    batch_axes,
    manual_axes,
)
from repro.sharding.specs import param_specs, input_specs_sharding, batch_spec

__all__ = [
    "MeshContext",
    "use_mesh",
    "current_mesh_context",
    "shard_activation",
    "batch_axes",
    "manual_axes",
    "param_specs",
    "input_specs_sharding",
    "batch_spec",
]
