"""Jit-native EnFed fleet engine: many concurrent requester sessions,
one compiled program.

The loop engine (``repro.core.rounds.EnFedSession``) executes Algorithm 1
as Python control flow — one ``task.fit`` dispatch per contributor per
round — which caps simulations at a handful of sessions.  This module
ports the same protocol onto stacked arrays so an entire fleet of
requesting devices advances together:

* **handshake** — contract selection stays host-side (it is cheap,
  deterministic numpy); it emits the (R, N_max) contract mask and, with
  the session strategy (``topology.contributor_round_mask``), the static
  per-round aggregation weights.
* **collect + aggregate** — contributor params carry a leading
  (R, N_max) axis; eq. (14) for every session is ONE launch of the
  batched Pallas ``fedavg`` kernel (``repro.kernels.fedavg``).
* **fit / refresh** — minibatch index schedules are precomputed
  host-side from the same ``numpy`` RNG seeds the loop engine uses, so
  both engines see identical batches; the epochs×steps Adam loop is a
  ``lax.scan`` and requesters advance under ``vmap``.
* **score + account** — accuracy/battery stopping conditions are
  ``jnp.where`` masks over per-requester lanes instead of Python
  ``break``; battery is traced per-device state discharged by the
  precomputed eq. (5) per-round constant (``CostModel.round_energy``).
* **rounds** — ``lax.scan`` over the round axis; a stopped session's
  lanes freeze (params, battery, round count, stop code).

Parity with the loop engine — same aggregated params, round counts, stop
reasons, and battery trajectories — is asserted by
``tests/test_fleet_engine.py`` across aggregation strategies and
encrypt on/off.  The AES-128-CTR transport is bit-exact (validated in
the loop engine / kernel tests), so the fleet engine models encryption
in the cost domain (byte counts -> eq. (4)-(7) -> battery) without
re-running the cipher per round.

Constraints: every requester/contributor shard must hold at least
``cfg.batch_size`` samples (the loop engine's sub-batch fallback is not
vectorized), and all sessions share one ``SupervisedTask``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import protocol
from repro.core.battery import BatteryState, discharge_level, load_efficiency
from repro.core.energy import CostModel
from repro.core.incentive import NeighborDevice, sign_contracts_fleet
from repro.core.rounds import EnFedConfig, SessionResult
from repro.kernels.fedavg.ops import fedavg_tree_batched
from repro.models.classifiers import cross_entropy_loss
from repro.optim import apply_updates
from repro.utils.tree import tree_bytes, tree_size, tree_where


@dataclasses.dataclass
class RequesterSpec:
    """One requesting device's inputs, mirroring ``EnFedSession``'s."""

    own_train: tuple                      # (x, y) numpy/array shard
    own_test: tuple
    neighborhood: Sequence[NeighborDevice]
    contributor_states: Dict[int, dict]   # device_id -> {params, data}
    battery: Optional[BatteryState] = None


@dataclasses.dataclass
class FleetResult:
    """Stacked outcome of one fleet program plus per-session views."""

    sessions: List[SessionResult]
    rounds: np.ndarray          # (R,) executed rounds per session
    stop_codes: np.ndarray      # (R,) protocol.STOP_* codes
    accuracy: np.ndarray        # (R,) final accuracy
    battery_level: np.ndarray   # (R,) final battery fraction
    total_energy_j: float       # summed eq. (5) energy across the fleet
    history: Dict[str, np.ndarray]  # (max_rounds, R) traces + executed mask


def _fit_schedule(n: int, epochs: int, batch: int, seed: int, steps_max: int):
    """The loop engine's minibatch plan, materialized: same numpy RNG,
    same permutation, same truncation to n//batch full batches."""
    steps = n // batch
    if steps < 1:
        raise ValueError(
            f"fleet engine needs >= batch_size samples per shard (got {n} < {batch})")
    rng = np.random.default_rng(seed)
    idx = np.zeros((epochs, steps_max, batch), np.int32)
    valid = np.zeros((epochs, steps_max), np.float32)
    for e in range(epochs):
        perm = rng.permutation(n)[:steps * batch].astype(np.int32)
        idx[e, :steps] = perm.reshape(steps, batch)
        valid[e, :steps] = 1.0
    return idx, valid


def _pad_stack(arrays, pad_len: int):
    """Stack ragged leading-axis arrays into (R, pad_len, ...) + mask."""
    shape = arrays[0].shape[1:]
    out = np.zeros((len(arrays), pad_len) + shape, arrays[0].dtype)
    mask = np.zeros((len(arrays), pad_len), np.float32)
    for i, a in enumerate(arrays):
        out[i, :len(a)] = a
        mask[i, :len(a)] = 1.0
    return out, mask


def _stack_trees(trees, template=None):
    """List of pytrees -> pytree with leading stacked axis (None entries
    become zeros_like(template))."""
    template = template if template is not None else next(t for t in trees if t is not None)
    filled = [t if t is not None else jax.tree_util.tree_map(np.zeros_like, template)
              for t in trees]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
                                  *filled)


@functools.partial(jax.jit, static_argnames=("task", "use_pallas", "do_refresh"))
def _fleet_program(task, use_pallas, do_refresh, arrays):
    """The whole fleet's Algorithm 1 as one compiled program.

    Module-level so the jit cache is shared across ``run_fleet`` calls:
    re-running with the same ``task`` (id-hashed static) and the same
    array shapes — e.g. parametrized parity tests sweeping strategies,
    encryption, or stopping thresholds, all of which are traced inputs
    (``round_w``, ``e_round``, ``desired_accuracy``...) — reuses the
    compiled executable instead of re-tracing per call.
    """
    model, opt = task.model, task._opt
    R, N = arrays["round_w"].shape
    _, _, ref_epochs, ref_steps, _ = arrays["ref_idx"].shape

    def fit_one(params, x, y, idx, valid):
        """Identical math to SupervisedTask.fit for one device's shard."""
        E, S, B = idx.shape

        def one_step(carry, sv):
            p, s = carry
            ib, v = sv
            xb, yb = x[ib], y[ib]
            loss, grads = jax.value_and_grad(
                lambda pp: cross_entropy_loss(model.forward(pp, xb), yb))(p)
            upd, s2 = opt.update(grads, s, p)
            p2 = apply_updates(p, upd)
            return (tree_where(v > 0, p2, p), tree_where(v > 0, s2, s)), loss * v

        (params, _), losses = jax.lax.scan(
            one_step, (params, opt.init(params)),
            (idx.reshape(E * S, B), valid.reshape(E * S)))
        per_epoch = losses.reshape(E, S).sum(1) / jnp.maximum(valid.reshape(E, S).sum(1), 1.0)
        return params, per_epoch[-1]

    def eval_one(params, x, y, mask):
        logits = model.forward(params, x)
        correct = (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)
        return jnp.sum(correct * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    def round_body(carry, fit_idx_r):
        contrib_p, last_p, level, active, stop_code, rounds_done = carry

        # Phase.COLLECT + Phase.AGGREGATE: one batched kernel launch
        global_p = fedavg_tree_batched(contrib_p, arrays["round_w"],
                                       use_pallas=use_pallas)
        # Phase.FIT (requesters personalize) + Phase.SCORE
        new_p, last_loss = jax.vmap(fit_one)(global_p, arrays["own_x"],
                                             arrays["own_y"], fit_idx_r,
                                             arrays["fit_valid"])
        acc = jax.vmap(eval_one)(new_p, arrays["test_x"], arrays["test_y"],
                                 arrays["test_mask"])

        # Phase.ACCOUNT: traced battery discharge for executed rounds
        level_new = discharge_level(level, arrays["e_round"],
                                    arrays["capacity"], arrays["eff"])
        reached = acc >= arrays["desired_accuracy"]
        low = level_new < arrays["battery_threshold"]
        stop_code = jnp.where(active & reached, protocol.STOP_ACCURACY,
                              jnp.where(active & ~reached & low,
                                        protocol.STOP_BATTERY, stop_code))
        level = jnp.where(active, level_new, level)
        rounds_done = rounds_done + active.astype(jnp.int32)
        last_p = tree_where(active, new_p, last_p)
        next_active = active & ~reached & ~low

        # Phase.REFRESH: contributors keep training (frozen once stopped)
        if do_refresh:
            cx, cy = arrays["cx"], arrays["cy"]
            flat = jax.tree_util.tree_map(
                lambda l: l.reshape((R * N,) + l.shape[2:]), contrib_p)
            refreshed, _ = jax.vmap(fit_one)(
                flat, cx.reshape((R * N,) + cx.shape[2:]),
                cy.reshape(R * N, -1),
                arrays["ref_idx"].reshape((R * N, ref_epochs, ref_steps) +
                                          arrays["ref_idx"].shape[4:]),
                arrays["ref_valid"].reshape(R * N, ref_epochs, ref_steps))
            refreshed = jax.tree_util.tree_map(
                lambda l, ref: ref.reshape(l.shape), contrib_p, refreshed)
            contrib_p = tree_where(next_active, refreshed, contrib_p)

        carry = (contrib_p, last_p, level, next_active, stop_code, rounds_done)
        return carry, (acc, last_loss, level, active.astype(jnp.float32))

    contrib_p = arrays["contrib_p"]
    last_p0 = jax.tree_util.tree_map(lambda l: jnp.zeros_like(l[:, 0]), contrib_p)
    carry0 = (contrib_p, last_p0, arrays["level0"],
              jnp.ones((R,), bool),
              jnp.full((R,), protocol.STOP_MAX_ROUNDS, jnp.int32),
              jnp.zeros((R,), jnp.int32))
    carry, traces = jax.lax.scan(round_body, carry0, arrays["fit_idx"])
    contrib_final, last_p, level, _, stop_code, rounds_done = carry
    return contrib_final, last_p, level, stop_code, rounds_done, traces


def run_fleet(task, requesters: Sequence[RequesterSpec],
              cfg: EnFedConfig = EnFedConfig(),
              cost_model: Optional[CostModel] = None,
              use_pallas: bool = True) -> FleetResult:
    """Run ``len(requesters)`` concurrent EnFed sessions as one jit program."""
    cost = cost_model or CostModel()
    R = len(requesters)
    if R == 0:
        raise ValueError("empty fleet")

    # ---- Phase.HANDSHAKE (host-side, static) ------------------------------
    contracts, contract_mask = sign_contracts_fleet(
        [spec.neighborhood for spec in requesters],
        cfg.offered_incentive, cfg.n_max)
    for i, cs in enumerate(contracts):
        if not cs:
            raise RuntimeError(
                f"requester {i}: no nearby device agreed to the incentive (N_d < 1)")
    N = contract_mask.shape[1]

    # per-round aggregation weights = contract mask x strategy round mask
    round_w = np.zeros((R, N), np.float32)
    for i, cs in enumerate(contracts):
        round_w[i, :len(cs)] = protocol.round_weights(len(cs), cfg.strategy)

    # ---- contributor state / data stacks ----------------------------------
    template = requesters[0].contributor_states[
        contracts[0][0].device_id]["params"]
    contrib_params, contrib_x, contrib_y = [], [], []
    for spec, cs in zip(requesters, contracts):
        row_p, row_x, row_y = [], [], []
        for c in cs:
            st = spec.contributor_states[c.device_id]
            row_p.append(st["params"])
            row_x.append(np.asarray(st["data"][0]))
            row_y.append(np.asarray(st["data"][1]).astype(np.int32))
        contrib_params.append(row_p)
        contrib_x.append(row_x)
        contrib_y.append(row_y)

    n_c_max = max(max(len(x) for x in row) for row in contrib_x)
    cx = np.zeros((R, N, n_c_max) + contrib_x[0][0].shape[1:], np.float32)
    cy = np.zeros((R, N, n_c_max), np.int32)
    for i in range(R):
        for j, (x, y) in enumerate(zip(contrib_x[i], contrib_y[i])):
            cx[i, j, :len(x)] = x
            cy[i, j, :len(y)] = y
    padded_rows = [row + [None] * (N - len(row)) for row in contrib_params]
    contrib_stack = _stack_trees(
        [_stack_trees(row, template) for row in padded_rows])

    # ---- requester data + schedules ---------------------------------------
    own_x, _ = _pad_stack([np.asarray(s.own_train[0], np.float32) for s in requesters],
                          max(len(s.own_train[0]) for s in requesters))
    own_y, _ = _pad_stack([np.asarray(s.own_train[1], np.int32) for s in requesters],
                          own_x.shape[1])
    test_x, test_mask = _pad_stack([np.asarray(s.own_test[0], np.float32) for s in requesters],
                                   max(len(s.own_test[0]) for s in requesters))
    test_y, _ = _pad_stack([np.asarray(s.own_test[1], np.int32) for s in requesters],
                           test_x.shape[1])

    fit_steps_max = max(len(s.own_train[0]) // cfg.batch_size for s in requesters)
    fit_idx = np.zeros((cfg.max_rounds, R, cfg.epochs, fit_steps_max, cfg.batch_size),
                       np.int32)
    fit_valid = np.zeros((R, cfg.epochs, fit_steps_max), np.float32)
    for i, spec in enumerate(requesters):
        n_i = len(spec.own_train[0])
        for r in range(cfg.max_rounds):
            idx, valid = _fit_schedule(n_i, cfg.epochs, cfg.batch_size,
                                       cfg.seed + r, fit_steps_max)
            fit_idx[r, i] = idx
            if r == 0:  # the valid-step mask is round-invariant
                fit_valid[i] = valid

    ref_epochs = max(cfg.contributor_refresh_epochs, 0)
    ref_steps_max = max((len(x) // cfg.batch_size
                         for row in contrib_x for x in row), default=1)
    ref_idx = np.zeros((R, N, ref_epochs, ref_steps_max, cfg.batch_size), np.int32)
    ref_valid = np.zeros((R, N, ref_epochs, ref_steps_max), np.float32)
    if ref_epochs > 0:
        for i, cs in enumerate(contracts):
            for j, c in enumerate(cs):
                idx, valid = _fit_schedule(len(contrib_x[i][j]), ref_epochs,
                                           cfg.batch_size, cfg.seed + c.device_id,
                                           ref_steps_max)
                ref_idx[i, j] = idx
                ref_valid[i, j] = valid

    # ---- Phase.ACCOUNT constants (static per requester) -------------------
    num_params = tree_size(template)
    model_bytes = 4 * num_params if cfg.encrypt else tree_bytes(template)
    batteries = [s.battery or BatteryState() for s in requesters]
    e_round = np.array([cost.round_energy(
        n_contrib=len(cs), num_params=num_params, model_bytes=model_bytes,
        num_samples=len(spec.own_train[0]), epochs=cfg.epochs,
        n_devices=len(spec.neighborhood), encrypt=cfg.encrypt)
        for spec, cs in zip(requesters, contracts)], np.float32)
    capacity = np.array([b.capacity_j for b in batteries], np.float32)
    level0 = np.array([b.level for b in batteries], np.float32)
    eff = np.array([load_efficiency(cost.device.p_train, b.high_load_penalty,
                                    b.high_load_threshold_w) for b in batteries],
                   np.float32)

    # ---- the compiled program ---------------------------------------------
    arrays = dict(
        contrib_p=contrib_stack, fit_idx=jnp.asarray(fit_idx),
        level0=jnp.asarray(level0), own_x=jnp.asarray(own_x),
        own_y=jnp.asarray(own_y), test_x=jnp.asarray(test_x),
        test_y=jnp.asarray(test_y), test_mask=jnp.asarray(test_mask),
        fit_valid=jnp.asarray(fit_valid), round_w=jnp.asarray(round_w),
        e_round=jnp.asarray(e_round), capacity=jnp.asarray(capacity),
        eff=jnp.asarray(eff),
        desired_accuracy=jnp.float32(cfg.desired_accuracy),
        battery_threshold=jnp.float32(cfg.battery_threshold),
        cx=jnp.asarray(cx), cy=jnp.asarray(cy),
        ref_idx=jnp.asarray(ref_idx), ref_valid=jnp.asarray(ref_valid))
    contrib_final, last_p, level, stop_code, rounds_done, traces = _fleet_program(
        task, use_pallas, ref_epochs > 0, arrays)
    acc_h, loss_h, bat_h, exec_h = (np.asarray(t) for t in traces)
    rounds_np = np.asarray(rounds_done)
    codes_np = np.asarray(stop_code)
    level_np = np.asarray(level)

    # contributor write-back: like the loop engine's in-place refresh,
    # each requester's contributor_states end up holding that session's
    # final (refresh-trained, frozen-once-stopped) contributor params.
    # Requesters sharing one states dict see the last writer's lanes.
    if ref_epochs > 0:
        for i, (spec, cs) in enumerate(zip(requesters, contracts)):
            for j, c in enumerate(cs):
                spec.contributor_states[c.device_id]["params"] = (
                    jax.tree_util.tree_map(lambda l: l[i, j], contrib_final))

    # ---- per-session views (loop-engine-compatible SessionResults) --------
    sessions = []
    total_e = 0.0
    for i, (spec, cs, b0) in enumerate(zip(requesters, contracts, batteries)):
        r_i = int(rounds_np[i])
        report = cost.session(
            rounds=r_i, n_contrib=len(cs), num_params=num_params,
            model_bytes=model_bytes, num_samples=len(spec.own_train[0]),
            epochs=cfg.epochs, n_devices=len(spec.neighborhood),
            encrypt=cfg.encrypt)
        total_e += report.e_tot
        battery = dataclasses.replace(b0, level=float(level_np[i]))
        history = {"accuracy": [float(a) for a in acc_h[:r_i, i]],
                   "loss": [float(l) for l in loss_h[:r_i, i]],
                   "battery": [float(l) for l in bat_h[:r_i, i]]}
        sessions.append(SessionResult(
            accuracy=history["accuracy"][-1] if history["accuracy"] else 0.0,
            rounds=r_i, n_contributors=len(cs), report=report, battery=battery,
            history=history, stop_reason=protocol.stop_reason_name(codes_np[i]),
            params=jax.tree_util.tree_map(lambda l: l[i], last_p)))
    return FleetResult(
        sessions=sessions, rounds=rounds_np, stop_codes=codes_np,
        accuracy=np.array([s.accuracy for s in sessions], np.float32),
        battery_level=level_np, total_energy_j=float(total_e),
        history={"accuracy": acc_h, "loss": loss_h, "battery": bat_h,
                 "executed": exec_h})
