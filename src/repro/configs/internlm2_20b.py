"""InternLM2-20B [arXiv:2403.17297] — dense GQA decoder.

Assigned spec: 48L, d_model=6144, 48H (GQA kv=8, head_dim 128),
d_ff=16384, vocab=92544.  Largest dense model in the pool: the FL
aggregation-volume stress test.  Full attention => long_500k skipped
(noted in DESIGN.md).  fsdp=True: 20B params + Adam state exceed
16 GB/chip under tensor-parallel alone, so ZeRO-3 over the data axis is
required; EnFed federates this config over the pod axis.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    citation="arXiv:2403.17297",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92_544,
    block_pattern=("attn",),
    rope_theta=1_000_000.0,
    dtype="bfloat16",
    fsdp=True,
)
