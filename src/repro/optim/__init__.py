from repro.optim.optimizers import Optimizer, adam, adamw, sgd, apply_updates
from repro.optim.schedules import constant, cosine_decay, warmup_cosine

__all__ = [
    "Optimizer",
    "adam",
    "adamw",
    "sgd",
    "apply_updates",
    "constant",
    "cosine_decay",
    "warmup_cosine",
]
