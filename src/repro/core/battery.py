"""Battery state and discharge model.

The paper gates EnFed rounds on the requesting device's battery:
continue only while ``B_p >= B_min_A`` (Algorithm 1, checkbatterylevel).
Discharge is non-linear in reality (paper §III notes this); we model the
energy-to-charge conversion with a load-dependent efficiency factor so
heavy phases (training) drain proportionally more than their Joule count.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class BatteryState:
    capacity_j: float = 40e3
    level: float = 1.0                 # fraction of capacity remaining
    # non-linearity: effective capacity shrinks under high draw (Peukert-like)
    high_load_penalty: float = 0.15
    high_load_threshold_w: float = 3.0

    def discharge(self, energy_j: float, avg_power_w: float = 1.0) -> "BatteryState":
        eff = 1.0 + (self.high_load_penalty if avg_power_w > self.high_load_threshold_w else 0.0)
        new_level = self.level - eff * energy_j / self.capacity_j
        return dataclasses.replace(self, level=max(new_level, 0.0))

    def below(self, threshold: float) -> bool:
        return self.level < threshold

    @property
    def percent(self) -> float:
        return 100.0 * self.level
