from repro.models.config import ModelConfig, MoEConfig, MLAConfig
from repro.models.transformer import Transformer
from repro.models.classifiers import (
    LSTMClassifier,
    LSTMClassifierConfig,
    MLPClassifier,
    MLPClassifierConfig,
    cross_entropy_loss,
    masked_cross_entropy_loss,
    accuracy,
)

__all__ = [
    "ModelConfig",
    "MoEConfig",
    "MLAConfig",
    "Transformer",
    "LSTMClassifier",
    "LSTMClassifierConfig",
    "MLPClassifier",
    "MLPClassifierConfig",
    "cross_entropy_loss",
    "masked_cross_entropy_loss",
    "accuracy",
]
