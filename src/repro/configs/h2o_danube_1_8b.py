"""H2O-Danube-1.8B [arXiv:2401.16818] — llama/mistral-mix dense decoder
with sliding-window attention.

Assigned spec: 24L, d_model=2560, 32H (GQA kv=8, head_dim 80),
d_ff=6912, vocab=32000, SWA window 4096 (mistral-style).
Windowed KV decode state => long_500k runs.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    citation="arXiv:2401.16818",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32_000,
    block_pattern=("swa",),
    sliding_window=4096,
    rope_theta=10000.0,
    dtype="bfloat16",
)
