"""Pure-jnp oracle for per-tile symmetric int8 quantization."""

from __future__ import annotations

import jax.numpy as jnp

TILE = 1024


def quantize_ref(x, tile: int = TILE):
    """x: (L,) fp32, L % tile == 0. Returns (q int8 (L,), scales fp32 (L/tile,)).

    Symmetric per-tile: scale = absmax/127, q = round(x/scale).
    """
    xt = x.reshape(-1, tile).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xt), axis=1)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xt / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale


def dequantize_ref(q, scales, tile: int = TILE):
    qt = q.reshape(-1, tile).astype(jnp.float32)
    return (qt * scales[:, None]).reshape(-1)


def quantize_batched_ref(x, tile: int = TILE):
    """x: (..., Lp) fp32, Lp % tile == 0.  Returns (q int8 (..., Lp),
    scales fp32 (..., Lp/tile)) — per-tile symmetric int8, tiles taken
    along the trailing parameter axis of each batch element.  Tile math
    is identical to :func:`quantize_ref`, so a batched row reproduces
    the 1-D quantization of that row (bit-equal codes; scales within a
    codegen ulp) — the property that aligns the fleet engine's
    requantized round state with the loop engine's per-device
    ``compress_update``."""
    lead = x.shape[:-1]
    xt = x.reshape(lead + (-1, tile)).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xt), axis=-1)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xt / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(lead + (-1,)), scale


def dequantize_batched_ref(q, scales, tile: int = TILE):
    """Inverse of :func:`quantize_batched_ref` (exact elementwise
    ``q * scale`` — the same single multiply every dequant path runs)."""
    lead = q.shape[:-1]
    qt = q.reshape(lead + (-1, tile)).astype(jnp.float32)
    return (qt * scales[..., None]).reshape(lead + (-1,))
