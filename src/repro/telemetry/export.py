"""Exporters: RoundEvents as JSONL, the Timeline as a Chrome trace.

Both formats are dependency-free:

* **events JSONL** — one JSON object per line, field names exactly the
  :class:`RoundEvent` schema.  :func:`read_events_jsonl` restores real
  ``RoundEvent`` objects (tuples re-tupled from JSON lists) and
  schema-validates the stream, so a round-tripped log is
  indistinguishable from the in-process one.
* **Chrome trace** — the ``{"traceEvents": [...]}`` JSON that
  ``chrome://tracing`` and https://ui.perfetto.dev load directly: one
  complete ("ph": "X") event per finished span, microsecond timestamps,
  span attrs in ``args``.
"""

from __future__ import annotations

import json
from typing import Iterable, List

from repro.telemetry.events import RoundEvent, validate_events
from repro.telemetry.spans import Timeline


def write_events_jsonl(events: Iterable[RoundEvent], path: str) -> int:
    """Write one event per line; returns the number written."""
    events = validate_events(events)
    with open(path, "w") as fh:
        for ev in events:
            fh.write(json.dumps(ev.__dict__, sort_keys=True) + "\n")
    return len(events)


def read_events_jsonl(path: str) -> List[RoundEvent]:
    """Read + schema-validate a JSONL event log back into RoundEvents."""
    events: List[RoundEvent] = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            row = json.loads(line)
            for key in ("member_set", "delivered"):
                if row.get(key) is not None:
                    row[key] = tuple(row[key])
            events.append(RoundEvent(**row))
    return validate_events(events)


def timeline_chrome_trace(timeline: Timeline) -> dict:
    """The Timeline as a Chrome-trace/Perfetto JSON object (not yet
    serialized).  Unfinished spans are skipped — a trace of a crashed
    run still loads."""
    trace_events = []
    for sp in timeline.spans:
        if sp.dur < 0:
            continue
        trace_events.append({
            "name": sp.name,
            "ph": "X",
            "ts": round(sp.t0 * 1e6, 3),      # microseconds
            "dur": round(sp.dur * 1e6, 3),
            "pid": 0,
            "tid": 0,
            "cat": "repro",
            "args": {k: v for k, v in sp.attrs.items()},
        })
    return {"traceEvents": trace_events, "displayTimeUnit": "ms"}


def write_chrome_trace(timeline: Timeline, path: str) -> int:
    """Write ``trace.json``; returns the number of trace events."""
    doc = timeline_chrome_trace(timeline)
    with open(path, "w") as fh:
        json.dump(doc, fh)
    return len(doc["traceEvents"])
