"""Profiling hooks: jax.profiler wrapping and compiled-program stats.

Both hooks are best-effort by design — a trace knob must never turn a
working run into a crashed one, so every jax interaction here is guarded
and degrades to a no-op / empty dict.
"""

from __future__ import annotations

import logging
from contextlib import contextmanager
from typing import Optional

from repro.launch.hlo_stats import collective_bytes, cost_summary, memory_summary

log = logging.getLogger(__name__)


@contextmanager
def maybe_jax_profiler(trace_dir: Optional[str]):
    """``jax.profiler.trace`` around the wrapped block when ``trace_dir``
    is set; a plain no-op otherwise (or if the profiler is unavailable —
    logged, never raised)."""
    if not trace_dir:
        yield
        return
    try:
        import jax
        ctx = jax.profiler.trace(trace_dir)
    except Exception as exc:  # pragma: no cover - environment-dependent
        log.warning("jax profiler unavailable (%s); continuing untraced", exc)
        yield
        return
    with ctx:
        yield


def jit_hlo_stats(jit_fn, *args, **kwargs) -> dict:
    """Flops/bytes/memory of ``jit_fn`` compiled for ``args``.

    Uses the AOT path (``lower(...).compile()``): lowering only reads
    abstract shapes, so calling this BEFORE the real program invocation
    is safe even when the real call donates its buffers.  The extra
    compile is why ``TraceConfig.hlo_stats`` is opt-in.  Returns {} on
    any failure.
    """
    try:
        compiled = jit_fn.lower(*args, **kwargs).compile()
    except Exception as exc:
        log.warning("hlo_stats lowering failed (%s); skipping", exc)
        return {}
    stats: dict = {}
    stats.update(cost_summary(compiled))
    memory = memory_summary(compiled)
    if memory:
        stats["memory"] = memory
    try:
        hlo_text = compiled.as_text()
    except Exception:
        hlo_text = ""
    if hlo_text:
        coll = collective_bytes(hlo_text)
        if coll.get("total_collective_bytes"):
            stats["collectives"] = coll
    return stats
