"""Public op: AES-128-CTR encryption of model updates (Pallas path)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import crypto
from repro.kernels.aes_ctr.kernel import aes_ctr_pallas
from repro.kernels.aes_ctr.ref import aes_ctr_ref


def encrypt_bytes(payload_u8, key, nonce, *, use_pallas: bool = True,
                  interpret=None):
    """CTR encryption of a uint8 payload; decryption is the same call."""
    if not use_pallas:
        return aes_ctr_ref(payload_u8, key, nonce)
    n = int(payload_u8.shape[0])
    n_blocks = (n + 15) // 16
    rks = jnp.asarray(crypto.expand_key(np.asarray(key, np.uint8)))
    ctr = jnp.asarray(crypto._counter_blocks(np.asarray(nonce, np.uint8), n_blocks))
    return aes_ctr_pallas(payload_u8, rks, ctr, interpret=interpret)


decrypt_bytes = encrypt_bytes  # CTR involution


def encrypt_update(vec_f32, key, nonce, **kw):
    return encrypt_bytes(crypto.float_vector_to_bytes(vec_f32), key, nonce, **kw)


def decrypt_update(cipher_u8, key, nonce, **kw):
    return crypto.bytes_to_float_vector(decrypt_bytes(cipher_u8, key, nonce, **kw))
