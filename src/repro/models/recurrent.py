"""Recurrent blocks: RG-LRU (Griffin / RecurrentGemma) and xLSTM.

RG-LRU [arXiv:2402.19427]:
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
    a_t = exp(-c * softplus(lam) * sigmoid(r_t)),  c = 8
Full-sequence mode uses ``jax.lax.associative_scan`` (the recurrence is a
linear first-order scan, which maps to a log-depth parallel scan on TPU);
decode mode is a single fused step carrying ``h``.

xLSTM [arXiv:2405.04517]:
  * mLSTM: matrix memory C (dh x dh per head), exponential input gate,
    stabilized with a running max state m.
  * sLSTM: scalar memory with exponential gating and a recurrent kernel.
Both iterate with ``lax.scan`` over time for training (hillclimb target:
chunkwise-parallel form) and carry O(1)-in-seq state for decode, which is
what makes ``long_500k`` decode feasible for the ssm/hybrid families.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers
from repro.sharding.ctx import pvary_manual

_RG_LRU_C = 8.0


# ---------------------------------------------------------------------------
# RG-LRU / Griffin recurrent block
# ---------------------------------------------------------------------------


def rglru_init(rng, cfg: ModelConfig):
    W = cfg.rnn_width or cfg.d_model
    dt = cfg.jnp_dtype
    ks = jax.random.split(rng, 7)
    # lambda init so that a ~ U[0.9, 0.999]^c-root (Griffin appendix)
    u = jax.random.uniform(ks[0], (W,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _RG_LRU_C))
    return {
        "wx": layers.dense_init(ks[1], cfg.d_model, W, dt),       # recurrent branch
        "wy": layers.dense_init(ks[2], cfg.d_model, W, dt),       # gate branch
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_width, W), jnp.float32) * 0.02).astype(dt),
        "conv_b": jnp.zeros((W,), dt),
        "w_input_gate": layers.dense_init(ks[4], W, W, dt, scale=0.5),
        "w_rec_gate": layers.dense_init(ks[5], W, W, dt, scale=0.5),
        "lam": lam,
        "wo": layers.dense_init(ks[6], W, cfg.d_model, dt),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B,S,W); w: (K,W)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for k in range(K):  # K is 4: unrolled taps
        out = out + pad[:, k : k + x.shape[1], :] * w[k]
    return out + b


def _rglru_coeffs(params, x):
    """Gated decay a_t and normalized input b_t for the linear scan."""
    r = jax.nn.sigmoid(x @ params["w_rec_gate"])
    i = jax.nn.sigmoid(x @ params["w_input_gate"])
    log_a = -_RG_LRU_C * jax.nn.softplus(params["lam"]) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) multiplier keeps the state norm bounded
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * (i * x).astype(jnp.float32)
    return a, b


def rglru_apply(params, x, cfg: ModelConfig):
    """Full-sequence Griffin block. x: (B,S,D) -> (B,S,D)."""
    gate = jax.nn.gelu(x @ params["wy"])
    u = x @ params["wx"]
    u = _causal_conv(u, params["conv_w"], params["conv_b"])
    a, b = _rglru_coeffs(params, u)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(x.dtype) * gate
    return h @ params["wo"]


def rglru_init_state(cfg: ModelConfig, batch: int):
    W = cfg.rnn_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, W), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, W), cfg.jnp_dtype),
    }


def rglru_decode(params, x, state, cfg: ModelConfig):
    """One-token Griffin step. x: (B,1,D)."""
    B = x.shape[0]
    gate = jax.nn.gelu(x @ params["wy"])                           # (B,1,W)
    u = (x @ params["wx"])[:, 0, :]                                # (B,W)
    hist = jnp.concatenate([state["conv"], u[:, None, :]], axis=1)  # (B,K,W)
    u_conv = jnp.einsum("bkw,kw->bw", hist, params["conv_w"]) + params["conv_b"]
    a, b = _rglru_coeffs(params, u_conv[:, None, :])
    h = a[:, 0] * state["h"] + b[:, 0]
    out = (h[:, None, :].astype(x.dtype) * gate) @ params["wo"]
    return out, {"h": h, "conv": hist[:, 1:, :]}


# ---------------------------------------------------------------------------
# xLSTM: mLSTM
# ---------------------------------------------------------------------------


def _xlstm_dims(cfg: ModelConfig):
    d_inner = int(cfg.d_model * cfg.mlstm_proj_factor)
    H = cfg.num_heads
    dh = d_inner // H
    return d_inner, H, dh


def mlstm_init(rng, cfg: ModelConfig):
    d_inner, H, dh = _xlstm_dims(cfg)
    dt = cfg.jnp_dtype
    ks = jax.random.split(rng, 8)
    return {
        "w_up": layers.dense_init(ks[0], cfg.d_model, 2 * d_inner, dt),
        "wq": layers.dense_init(ks[1], d_inner, d_inner, dt),
        "wk": layers.dense_init(ks[2], d_inner, d_inner, dt),
        "wv": layers.dense_init(ks[3], d_inner, d_inner, dt),
        "w_if": layers.dense_init(ks[4], d_inner, 2 * H, dt),
        "b_if": jnp.concatenate([jnp.zeros((H,)), jnp.ones((H,)) * 3.0]).astype(dt),
        "norm": layers.rmsnorm_init(d_inner, dt),
        "w_down": layers.dense_init(ks[5], d_inner, cfg.d_model, dt),
    }


def _mlstm_step(carry, inp, dh):
    """Stabilized mLSTM recurrence, one timestep.

    carry: C (B,H,dh,dh), n (B,H,dh), m (B,H)
    inp: q,k,v (B,H,dh), i_t, f_t (B,H) pre-activations
    """
    C, n, m = carry
    q, k, v, it, ft = inp
    log_f = -jax.nn.softplus(-ft)                                  # log sigmoid(f)
    m_new = jnp.maximum(log_f + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    C = f_p[..., None, None] * C + i_p[..., None, None] * (v[..., :, None] * k[..., None, :])
    n = f_p[..., None] * n + i_p[..., None] * k
    num = jnp.einsum("bhvk,bhk->bhv", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), jnp.exp(-m_new))
    h = num / den[..., None]
    return (C, n, m_new), h


def mlstm_apply(params, x, cfg: ModelConfig):
    """x: (B,S,D) -> (B,S,D). Sequential scan over time (train/prefill)."""
    B, S, D = x.shape
    d_inner, H, dh = _xlstm_dims(cfg)
    up = x @ params["w_up"]
    u, z = jnp.split(up, 2, axis=-1)                               # (B,S,d_inner)
    q = (u @ params["wq"]).reshape(B, S, H, dh).astype(jnp.float32) / math.sqrt(dh)
    k = (u @ params["wk"]).reshape(B, S, H, dh).astype(jnp.float32)
    v = (u @ params["wv"]).reshape(B, S, H, dh).astype(jnp.float32)
    gif = (u @ params["w_if"] + params["b_if"]).astype(jnp.float32)
    it, ft = jnp.split(gif.reshape(B, S, 2 * H), 2, axis=-1)       # (B,S,H)

    init = pvary_manual((
        jnp.zeros((B, H, dh, dh), jnp.float32),
        jnp.zeros((B, H, dh), jnp.float32),
        jnp.full((B, H), -1e30, jnp.float32),
    ))
    xs = (
        jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0),
        jnp.moveaxis(it, 1, 0), jnp.moveaxis(ft, 1, 0),
    )
    chunk = cfg.mlstm_chunk
    if chunk and S % chunk == 0 and S > chunk:
        # chunked remat: store the (B,H,dh,dh) matrix-memory carry only at
        # chunk boundaries; backward recomputes within each chunk.
        n_chunks = S // chunk
        xs_c = jax.tree_util.tree_map(
            lambda a: a.reshape((n_chunks, chunk) + a.shape[1:]), xs)

        @jax.checkpoint
        def chunk_body(carry, chunk_xs):
            return jax.lax.scan(lambda c, i: _mlstm_step(c, i, dh), carry, chunk_xs)

        _, hs_c = jax.lax.scan(chunk_body, init, xs_c)
        hs = hs_c.reshape((S,) + hs_c.shape[2:])
    else:
        _, hs = jax.lax.scan(lambda c, i: _mlstm_step(c, i, dh), init, xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d_inner).astype(x.dtype)
    h = layers.rmsnorm_apply(params["norm"], h, cfg.norm_eps)
    h = h * jax.nn.silu(z)
    return h @ params["w_down"]


def mlstm_init_state(cfg: ModelConfig, batch: int):
    _, H, dh = _xlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def mlstm_decode(params, x, state, cfg: ModelConfig):
    B = x.shape[0]
    d_inner, H, dh = _xlstm_dims(cfg)
    up = x[:, 0, :] @ params["w_up"]
    u, z = jnp.split(up, 2, axis=-1)
    q = (u @ params["wq"]).reshape(B, H, dh).astype(jnp.float32) / math.sqrt(dh)
    k = (u @ params["wk"]).reshape(B, H, dh).astype(jnp.float32)
    v = (u @ params["wv"]).reshape(B, H, dh).astype(jnp.float32)
    gif = (u @ params["w_if"] + params["b_if"]).astype(jnp.float32)
    it, ft = jnp.split(gif, 2, axis=-1)
    (C, n, m), h = _mlstm_step((state["C"], state["n"], state["m"]), (q, k, v, it, ft), dh)
    h = h.reshape(B, d_inner).astype(x.dtype)
    h = layers.rmsnorm_apply(params["norm"], h, cfg.norm_eps)
    h = h * jax.nn.silu(z)
    return (h @ params["w_down"])[:, None, :], {"C": C, "n": n, "m": m}


# ---------------------------------------------------------------------------
# xLSTM: sLSTM
# ---------------------------------------------------------------------------


def slstm_init(rng, cfg: ModelConfig):
    d_inner, H, dh = _xlstm_dims(cfg)
    dt = cfg.jnp_dtype
    ks = jax.random.split(rng, 4)
    return {
        "w_up": layers.dense_init(ks[0], cfg.d_model, d_inner, dt),
        # input projections for z, i, f, o gates
        "w_gates": layers.dense_init(ks[1], d_inner, 4 * d_inner, dt),
        # block-diagonal recurrent kernel: per head (dh x 4*dh)
        "r_gates": (jax.random.normal(ks[2], (H, dh, 4 * dh), jnp.float32) / math.sqrt(dh)).astype(dt),
        "b_gates": jnp.zeros((4 * d_inner,), dt),
        "norm": layers.rmsnorm_init(d_inner, dt),
        "w_down": layers.dense_init(ks[3], d_inner, cfg.d_model, dt),
    }


def _slstm_step(params, carry, u_t, cfg: ModelConfig):
    """carry: c, n, m, h (B, d_inner) fp32; u_t: (B, d_inner)."""
    d_inner, H, dh = _xlstm_dims(cfg)
    c, n, m, h = carry
    B = u_t.shape[0]
    rec = jnp.einsum("bhd,hde->bhe", h.reshape(B, H, dh), params["r_gates"].astype(jnp.float32))
    gates = (u_t @ params["w_gates"] + params["b_gates"]).astype(jnp.float32)
    gates = gates.reshape(B, H, 4 * dh) + rec
    z, i, f, o = jnp.split(gates.reshape(B, 4 * d_inner), 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o)
    log_f = -jax.nn.softplus(-f)
    m_new = jnp.maximum(log_f + m, i)
    i_p = jnp.exp(i - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c = f_p * c + i_p * z
    n = f_p * n + i_p
    h_new = o * c / jnp.maximum(n, 1.0)
    return (c, n, m_new, h_new), h_new


def slstm_apply(params, x, cfg: ModelConfig):
    B, S, D = x.shape
    d_inner, H, dh = _xlstm_dims(cfg)
    u = (x @ params["w_up"]).astype(jnp.float32)
    init = pvary_manual((
        jnp.zeros((B, d_inner), jnp.float32),   # c
        jnp.zeros((B, d_inner), jnp.float32),   # n
        jnp.full((B, d_inner), -1e30, jnp.float32),  # m
        jnp.zeros((B, d_inner), jnp.float32),   # h
    ))
    _, hs = jax.lax.scan(lambda c, ut: _slstm_step(params, c, ut, cfg), init, jnp.moveaxis(u, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    h = layers.rmsnorm_apply(params["norm"], h, cfg.norm_eps)
    return h @ params["w_down"]


def slstm_init_state(cfg: ModelConfig, batch: int):
    d_inner, _, _ = _xlstm_dims(cfg)
    return {
        "c": jnp.zeros((batch, d_inner), jnp.float32),
        "n": jnp.zeros((batch, d_inner), jnp.float32),
        "m": jnp.full((batch, d_inner), -1e30, jnp.float32),
        "h": jnp.zeros((batch, d_inner), jnp.float32),
    }


def slstm_decode(params, x, state, cfg: ModelConfig):
    u = (x[:, 0, :] @ params["w_up"]).astype(jnp.float32)
    carry = (state["c"], state["n"], state["m"], state["h"])
    (c, n, m, h), h_out = _slstm_step(params, carry, u, cfg)
    y = layers.rmsnorm_apply(params["norm"], h_out.astype(x.dtype), cfg.norm_eps)
    return (y @ params["w_down"])[:, None, :], {"c": c, "n": n, "m": m, "h": h}
