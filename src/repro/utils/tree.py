"""Pytree arithmetic utilities.

Every federated-learning primitive in ``repro.core`` operates on model
parameter pytrees; these helpers keep that code free of repeated
``jax.tree_util.tree_map`` boilerplate and are themselves jit-safe.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    """Elementwise ``a + b`` over two pytrees with identical structure."""
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a, b):
    """Elementwise ``a - b`` over two pytrees with identical structure."""
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(tree, scalar):
    """Multiply every leaf of ``tree`` by ``scalar`` (python or 0-d array)."""
    return jax.tree_util.tree_map(lambda x: x * scalar, tree)


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_dot(a, b):
    """Inner product of two pytrees (fp32 accumulation)."""
    leaves = jax.tree_util.tree_map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b
    )
    return jax.tree_util.tree_reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_l2_norm(tree):
    return jnp.sqrt(tree_dot(tree, tree))


def tree_size(tree) -> int:
    """Total number of scalar parameters in a pytree (static)."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    """Total byte footprint of a pytree (static)."""
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_weighted_mean(trees, weights):
    """Weighted mean of a list of pytrees.

    ``weights`` is a 1-D array of the same length as ``trees``; the result
    is ``sum_i w_i * tree_i / sum_i w_i``.  This is FedAvg (paper eq. 14)
    in its list form, used by the single-host simulator.  The distributed
    path uses ``repro.core.aggregation`` collectives instead.
    """
    weights = jnp.asarray(weights, dtype=jnp.float32)
    total = jnp.sum(weights)

    def _avg(*leaves):
        stacked = jnp.stack([l.astype(jnp.float32) for l in leaves])
        w = weights.reshape((-1,) + (1,) * (stacked.ndim - 1))
        return (jnp.sum(stacked * w, axis=0) / total).astype(leaves[0].dtype)

    return jax.tree_util.tree_map(_avg, *trees)


def tree_cast(tree, dtype):
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), tree)


def tree_where(cond, a, b):
    """Leafwise ``jnp.where(cond, a, b)`` with broadcast over trailing dims.

    ``cond`` is a scalar or a vector indexing the leaves' leading axis
    (e.g. the fleet engine's per-requester active mask); it is reshaped
    to broadcast against each leaf.  Used for masked state updates inside
    jit round loops (``jnp.where`` instead of Python ``break``).
    """
    cond = jnp.asarray(cond)

    def _where(x, y):
        c = cond.reshape(cond.shape + (1,) * (x.ndim - cond.ndim)) if x.ndim > cond.ndim else cond
        return jnp.where(c, x, y)

    return jax.tree_util.tree_map(_where, a, b)


def tree_ravel(tree, batch_ndim: int = 0):
    """Ravel a (possibly batch-stacked) pytree into one fp32 buffer.

    The first ``batch_ndim`` axes of every leaf are treated as shared
    batch axes (e.g. the fleet engine's (R, N) requester x contributor
    grid); everything after them is concatenated into a flat trailing
    parameter axis.  Returns ``(flat, spec)`` where ``flat`` has shape
    ``batch_shape + (P,)`` and ``spec`` is a static, hashable description
    consumed by :func:`tree_unravel`.

    This is the fleet engine's zero-copy round-state representation: the
    ravel happens ONCE at setup, the (R, N, P) buffer is carried through
    the whole round loop (and donated back to XLA), and the Pallas fedavg
    kernel launches directly on it with no per-round concatenate/split.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        return jnp.zeros((0,) * (batch_ndim + 1), jnp.float32), (treedef, ())
    batch_shape = leaves[0].shape[:batch_ndim]
    meta = tuple((tuple(l.shape[batch_ndim:]), jnp.dtype(l.dtype).name) for l in leaves)
    flat = jnp.concatenate(
        [l.reshape(batch_shape + (-1,)).astype(jnp.float32) for l in leaves],
        axis=-1)
    return flat, (treedef, meta)


def tree_unravel(spec, flat):
    """Inverse of :func:`tree_ravel` for any leading batch shape.

    ``flat`` has shape ``batch_shape + (P,)`` (the batch shape need not
    match the one seen at ravel time — per-lane views unravel the same
    spec), leaves come back as ``batch_shape + leaf_shape`` in their
    original dtypes.
    """
    treedef, meta = spec
    batch_shape = flat.shape[:-1]
    out, off = [], 0
    for shape, dtype in meta:
        size = 1
        for d in shape:
            size *= d
        out.append(flat[..., off:off + size].reshape(batch_shape + shape).astype(dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)


def flatten_to_vector(tree):
    """Concatenate all leaves into a single 1-D fp32 vector.

    Returns ``(vector, unflatten_fn)``.  Used by the crypto / quantize
    layers which operate on the serialized update stream exactly as the
    paper's AES-128 transport does.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [l.shape for l in leaves]
    dtypes = [l.dtype for l in leaves]
    sizes = [int(l.size) for l in leaves]
    vec = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves]) if leaves else jnp.zeros((0,), jnp.float32)

    def unflatten(v):
        out = []
        offset = 0
        for shape, dtype, size in zip(shapes, dtypes, sizes):
            out.append(v[offset : offset + size].reshape(shape).astype(dtype))
            offset += size
        return jax.tree_util.tree_unflatten(treedef, out)

    return vec, unflatten


def unflatten_from_vector(vec, like_tree):
    """Inverse of :func:`flatten_to_vector` given a template pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(like_tree)
    out = []
    offset = 0
    for l in leaves:
        size = int(l.size)
        out.append(vec[offset : offset + size].reshape(l.shape).astype(l.dtype))
        offset += size
    return jax.tree_util.tree_unflatten(treedef, out)
