"""Public op: int8 update compression for the EnFed transport.

``compress_update`` / ``decompress_update`` wrap a flattened fp32 model
update into (int8 payload, per-tile scales) and back — a 4x cut of the
bytes entering the AES transport and the aggregation collectives.
"""

from __future__ import annotations

from repro.kernels.quantize.kernel import quantize_pallas, dequantize_pallas, TILE
from repro.kernels.quantize.ref import quantize_ref, dequantize_ref


def compress_update(vec, *, use_pallas: bool = True, interpret=None):
    """vec: (L,) fp32 -> (q, scales, L)."""
    if use_pallas:
        q, s = quantize_pallas(vec, interpret=interpret)
    else:
        import jax.numpy as jnp
        pad = (-vec.shape[0]) % TILE
        q, s = quantize_ref(jnp.pad(vec, (0, pad)))
    return q, s, vec.shape[0]


def decompress_update(q, scales, orig_len, *, use_pallas: bool = True,
                      interpret=None):
    if use_pallas:
        return dequantize_pallas(q, scales, orig_len, interpret=interpret)
    return dequantize_ref(q, scales)[:orig_len]
