"""Roofline analysis from the dry-run compiled artifacts.

Per (arch x shape x mesh):
  compute term    = HLO_FLOPs/device   / 197e12  FLOP/s  (bf16 v5e chip)
  memory term     = HLO_bytes/device   / 819e9   B/s     (HBM)
  collective term = collective_bytes/device x algo-factor / 50e9 B/s (ICI)
plus the dominant bottleneck, MODEL_FLOPS = 6·N·D (train) / 2·N_active·D
(inference), and the MODEL_FLOPS / HLO_FLOPs utilization ratio.

Collective algo factor: all-reduce counts 2x its payload (ring
reduce-scatter + all-gather); the others count 1x.
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs import ARCHS, INPUT_SHAPES, get_config

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s
ICI_BW = 50e9            # B/s/link

RESULTS_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun")


def params_count(cfg) -> int:
    """Analytic parameter count from the config."""
    D, V, L = cfg.d_model, cfg.vocab_size, cfg.num_layers
    dh = cfg.resolved_head_dim
    total = V * D * (1 if cfg.tie_embeddings else 2)
    for i in range(L):
        bt = cfg.block_type(i)
        if bt in ("attn", "swa", "local"):
            total += D * dh * (cfg.num_heads + 2 * cfg.num_kv_heads) + cfg.num_heads * dh * D
        elif bt == "mla":
            m = cfg.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            total += (D * m.q_lora_rank + m.q_lora_rank * cfg.num_heads * qk
                      + D * m.kv_lora_rank + D * m.qk_rope_head_dim
                      + m.kv_lora_rank * cfg.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                      + cfg.num_heads * m.v_head_dim * D)
        elif bt == "rglru":
            W = cfg.rnn_width or D
            total += 2 * D * W + 2 * W * W + W * D + cfg.conv_width * W
        elif bt in ("mlstm", "slstm"):
            di = int(D * cfg.mlstm_proj_factor)
            if bt == "mlstm":
                total += D * 2 * di + 3 * di * di + di * D
            else:
                total += D * di + 4 * di * di + di * D
        if cfg.moe is not None:
            dff = cfg.moe.d_ff_expert or cfg.d_ff
            total += cfg.moe.num_experts * 3 * D * dff + D * cfg.moe.num_experts
            total += cfg.moe.num_shared_experts * 3 * D * dff
        elif cfg.d_ff > 0:
            total += 3 * D * cfg.d_ff
    if cfg.encoder_layers:
        total += cfg.encoder_layers * (D * dh * (cfg.num_heads + 2 * cfg.num_kv_heads)
                                       + cfg.num_heads * dh * D + 3 * D * cfg.d_ff)
        total += L * (D * dh * (cfg.num_heads + 2 * cfg.num_kv_heads))  # cross-attn
    return int(total)


def active_params_count(cfg) -> int:
    if cfg.moe is None:
        return params_count(cfg)
    full = params_count(cfg)
    dff = cfg.moe.d_ff_expert or cfg.d_ff
    all_experts = cfg.num_layers * cfg.moe.num_experts * 3 * cfg.d_model * dff
    active = cfg.num_layers * cfg.moe.num_experts_per_tok * 3 * cfg.d_model * dff
    return int(full - all_experts + active)


def model_flops(cfg, shape_name: str, n_devices: int) -> float:
    """Per-device useful model FLOPs for the step."""
    shp = INPUT_SHAPES[shape_name]
    B, S = shp["global_batch"], shp["seq_len"]
    n_active = active_params_count(cfg)
    if shp["kind"] == "train":
        tokens = B * S
        return 6.0 * n_active * tokens / n_devices
    if shp["kind"] == "prefill":
        tokens = B * S
        return 2.0 * n_active * tokens / n_devices
    # decode: one token per sequence
    return 2.0 * n_active * B / n_devices


def scan_correction(cfg) -> float:
    """XLA's cost_analysis counts a lax.scan body ONCE regardless of trip
    count (verified against an unrolled oracle in the §Roofline method
    notes).  The layer stack is scanned, so reported flops/bytes cover
    ``pattern_len (+ tail) (+ encoder body)`` layers out of
    ``num_layers + encoder_layers``.  This multiplier restores the full
    stack; the non-scanned prologue (embed/unembed/loss/optimizer) gets
    over-scaled by it, which we accept and document (it is small for the
    multi-layer configs where the correction matters).  Time-recurrent
    scans (mlstm/slstm over seq) remain under-counted — flagged per arch.
    """
    pattern = len(cfg.block_pattern)
    tail = cfg.num_layers % pattern
    counted = pattern + tail + (1 if cfg.encoder_layers else 0)
    true_layers = cfg.num_layers + cfg.encoder_layers
    return max(true_layers / counted, 1.0)


def analyze(rec: dict) -> dict:
    cfg = get_config(rec["arch"])
    n_dev = rec.get("n_devices", 256)
    corr = scan_correction(cfg)
    flops = rec.get("flops", 0.0) * corr
    t_compute = flops / PEAK_FLOPS
    t_memory = rec.get("bytes_accessed", 0.0) * corr / HBM_BW
    # collectives: in-body reshards scale with layers; the one-shot grad
    # all-reduce does not.  Scale all-gather/permute/all-to-all (activation
    # reshards) by corr, keep all-reduce (dominated by the post-scan grad
    # reduction over stacked params, which IS fully counted) raw.
    ar = rec.get("all-reduce_bytes", 0.0)
    other = rec.get("total_collective_bytes", 0.0) - ar
    coll = other * corr + ar
    t_coll = (coll + ar) / ICI_BW  # all-reduce counted twice (ring algo)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, rec["shape"], n_dev)
    has_time_scan = any(t in ("mlstm", "slstm") for t in cfg.block_pattern)
    return {
        **{f"t_{k}_s": v for k, v in terms.items()},
        "bottleneck": bottleneck,
        "scan_corr": corr,
        "model_flops_per_dev": mf,
        "useful_flops_ratio": (mf / flops) if flops else 0.0,
        "step_time_lb_s": max(terms.values()),
        "time_scan_undercount": has_time_scan,
    }


def load_records(pattern: str = "*.json"):
    recs = []
    for f in sorted(glob.glob(os.path.join(RESULTS_DIR, pattern))):
        r = json.load(open(f))
        if r.get("status") == "ok":
            r["file"] = os.path.basename(f)
            recs.append(r)
    return recs


def run(verbose: bool = True):
    rows = []
    recs = load_records()
    if verbose:
        print(f"{'arch':<24}{'shape':<13}{'mesh':<6}{'strat':<9}"
              f"{'compute_s':>10}{'memory_s':>10}{'coll_s':>9} {'bound':<11}{'useful%':>8}")
    for r in recs:
        a = analyze(r)
        mesh = "pod2" if r["multi_pod"] else "pod1"
        tag = f"roofline/{r['arch']}/{r['shape']}/{mesh}/{r.get('strategy','cfl')}"
        if r.get("mla_absorbed"):
            tag += "/absorbed"
        rows.append((tag, a["step_time_lb_s"],
                     f"{a['bottleneck']},useful={100*a['useful_flops_ratio']:.0f}%"))
        if verbose:
            print(f"{r['arch']:<24}{r['shape']:<13}{mesh:<6}{r.get('strategy','cfl'):<9}"
                  f"{a['t_compute_s']:>10.4f}{a['t_memory_s']:>10.4f}{a['t_collective_s']:>9.4f}"
                  f" {a['bottleneck']:<11}{100*a['useful_flops_ratio']:>7.1f}%")
    return rows


if __name__ == "__main__":
    run()
