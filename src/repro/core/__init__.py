"""EnFed core: the paper's contribution as a first-class feature.

Protocol (incentives, handshake, AES transport, Algorithm-1 round loop),
cost model (eqs. 4-7), and the FL topologies expressed as TPU collective
schedules.
"""

from repro.core.aggregation import fedavg, masked_fedavg, masked_weighted_mean_stacked
from repro.core.battery import BatteryState
from repro.core.energy import CostModel, DeviceProfile, LinkProfile, EnergyReport
from repro.core.incentive import (
    NeighborDevice,
    Contract,
    select_contributors,
    participation_mask,
    make_fleet,
)
from repro.core.rounds import EnFedConfig, EnFedSession, SessionResult
from repro.core.federated import (
    SupervisedTask,
    CFLLearner,
    DFLLearner,
    FederatedTrainer,
    cloud_only_baseline,
)
from repro.core.fleet import FleetResult, RequesterSpec, run_fleet
from repro.core.mobility import MobilityConfig
from repro.core.protocol import Phase
from repro.core.topology import AggregationStrategy, aggregate_updates, group_mixing_matrix

__all__ = [
    "fedavg", "masked_fedavg", "masked_weighted_mean_stacked",
    "BatteryState", "CostModel", "DeviceProfile", "LinkProfile", "EnergyReport",
    "NeighborDevice", "Contract", "select_contributors", "participation_mask", "make_fleet",
    "EnFedConfig", "EnFedSession", "SessionResult",
    "SupervisedTask", "CFLLearner", "DFLLearner", "FederatedTrainer", "cloud_only_baseline",
    "FleetResult", "RequesterSpec", "run_fleet", "MobilityConfig", "Phase",
    "AggregationStrategy", "aggregate_updates", "group_mixing_matrix",
]
