"""Per-architecture smoke tests: reduced variants (2 layers-ish,
d_model<=512, <=4 experts) run one forward AND one train step on CPU,
asserting output shapes and finite values. Decode-capable archs also run
one serve step against a KV cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import Transformer, cross_entropy_loss
from repro.optim import adam, apply_updates

# the full architecture sweep is minutes of compile time; tier-1 covers
# the representative architectures via tests/test_models.py (forward /
# decode parity), the exhaustive sweep runs with -m slow
pytestmark = pytest.mark.slow

ARCH_IDS = sorted(ARCHS)


def _batch_for(cfg, B=2, S=16, rng=None):
    rng = rng or np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)),
    }
    if cfg.frontend == "audio":
        batch["frames"] = jnp.asarray(rng.normal(size=(B, 12, cfg.d_model)).astype(np.float32))
    if cfg.frontend == "vision":
        batch["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_prefix_tokens, cfg.d_model)).astype(np.float32))
    return batch


def _lm_loss(model, params, batch):
    out = model.forward(params, batch)
    logits = out["logits"]
    # prefix tokens carry no labels
    if logits.shape[1] != batch["labels"].shape[1]:
        logits = logits[:, -batch["labels"].shape[1]:]
    return cross_entropy_loss(
        logits.reshape(-1, logits.shape[-1]),
        batch["labels"].reshape(-1)) + out["aux_loss"]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_config(arch).smoke()
    assert cfg.d_model <= 512
    assert cfg.moe is None or cfg.moe.num_experts <= 4
    model = Transformer(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    out = model.forward(params, batch)
    B, S = batch["tokens"].shape
    extra = cfg.num_prefix_tokens if "prefix_embeds" in batch else 0
    assert out["logits"].shape == (B, S + extra, cfg.vocab_size)
    assert bool(jnp.isfinite(out["logits"]).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch).smoke()
    model = Transformer(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adam(1e-3)
    opt_state = opt.init(params)
    batch = _batch_for(cfg)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lambda p: _lm_loss(model, p, batch))(params)
        upd, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, upd), opt_state, loss

    params2, opt_state, loss = step(params, opt_state, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree_util.tree_leaves(params),
                                jax.tree_util.tree_leaves(params2)))
    assert delta > 0, f"{arch}: train step did not update params"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch).smoke()
    model = Transformer(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, max_len = 2, 32
    batch = _batch_for(cfg, B=B)
    memory = model.encode(params, batch["frames"]) if cfg.is_encoder_decoder else None
    cache = model.init_cache(B, max_len)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache = model.decode_step(params, tok, cache, 0, memory=memory)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite decode logits"
