"""Benchmark entrypoint — one module per paper table/figure + kernels +
roofline.  Prints ``name,us_per_call,derived`` CSV rows at the end.

  PYTHONPATH=src python -m benchmarks.run [--only table4,kernels,...]
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table4,table5,table7,figs,kernels,fleet,roofline")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    suites = []
    if only is None or "kernels" in only:
        suites.append(("kernels", "benchmarks.kernel_bench"))
    if only is None or "fleet" in only:
        suites.append(("fleet", "benchmarks.fleet_bench"))
    if only is None or "table4" in only:
        suites.append(("table4", "benchmarks.table4_lstm"))
    if only is None or "table5" in only:
        suites.append(("table5", "benchmarks.table5_mlp"))
    if only is None or "table7" in only:
        suites.append(("table7", "benchmarks.table7_cloud"))
    if only is None or "figs" in only:
        suites.append(("figs", "benchmarks.figs_contributors"))
    if only is None or "roofline" in only:
        suites.append(("roofline", "benchmarks.roofline"))

    csv_rows = []
    for name, modname in suites:
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        mod = __import__(modname, fromlist=["run"])
        rows = mod.run(verbose=True)
        print(f"===== {name} done in {time.time()-t0:.1f}s =====", flush=True)
        for row in rows:
            tag, val, extra = row[0], row[1], row[-1]
            csv_rows.append((tag, val, extra))

    print("\nname,us_per_call,derived")
    for tag, val, extra in csv_rows:
        print(f"{tag},{val},{extra}")


if __name__ == "__main__":
    main()
