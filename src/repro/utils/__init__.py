from repro.utils.tree import (
    tree_add,
    tree_sub,
    tree_scale,
    tree_zeros_like,
    tree_dot,
    tree_l2_norm,
    tree_size,
    tree_bytes,
    tree_weighted_mean,
    tree_cast,
    flatten_to_vector,
    unflatten_from_vector,
)

__all__ = [
    "tree_add",
    "tree_sub",
    "tree_scale",
    "tree_zeros_like",
    "tree_dot",
    "tree_l2_norm",
    "tree_size",
    "tree_bytes",
    "tree_weighted_mean",
    "tree_cast",
    "flatten_to_vector",
    "unflatten_from_vector",
]
