"""Pure-jnp oracle for AES-128-CTR — delegates to repro.core.crypto,
which is itself validated against the FIPS-197 test vector."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import crypto


def aes_ctr_ref(payload_u8, key, nonce):
    """payload_u8: (n,) uint8 -> ciphertext (n,) uint8 (CTR XOR)."""
    return crypto.encrypt_bytes(payload_u8, key, nonce)


def keystream_ref(key, nonce, n_bytes: int):
    return crypto.keystream(key, nonce, n_bytes)
