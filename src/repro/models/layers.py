"""Transformer building blocks (pure JAX, functional params-as-pytrees).

Every block exposes two entry points:

* ``*_apply(params, x, ...)``  — full-sequence (training / prefill)
* ``*_decode(params, x, cache, pos, ...)`` — one-token step against a cache

KV caches for windowed attention ("swa" / "local") are ring buffers of the
window size, so ``long_500k`` decode holds O(window) state, not O(seq).
RoPE is applied at cache-write time with absolute positions, making ring
order irrelevant to the (order-invariant) softmax.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.sharding.ctx import shard_activation

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(rng, fan_in, fan_out, dtype, scale=1.0):
    std = scale / math.sqrt(fan_in)
    return (jax.random.normal(rng, (fan_in, fan_out), jnp.float32) * std).astype(dtype)


def embed_init(rng, vocab, dim, dtype):
    return (jax.random.normal(rng, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(dim, dtype):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm_apply(params, x, eps=1e-6):
    orig = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(orig)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (full / sliding-window / local)
# ---------------------------------------------------------------------------


def attention_init(rng, cfg: ModelConfig):
    dh = cfg.resolved_head_dim
    ks = jax.random.split(rng, 4)
    dt = cfg.jnp_dtype
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.num_heads * dh, dt),
        "wk": dense_init(ks[1], cfg.d_model, cfg.num_kv_heads * dh, dt),
        "wv": dense_init(ks[2], cfg.d_model, cfg.num_kv_heads * dh, dt),
        "wo": dense_init(ks[3], cfg.num_heads * dh, cfg.d_model, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * dh,), dt)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * dh,), dt)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * dh,), dt)
    return p


def _qkv(params, x, cfg: ModelConfig):
    B, S, _ = x.shape
    dh = cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, cfg.num_heads, dh)
    k = k.reshape(B, S, cfg.num_kv_heads, dh)
    v = v.reshape(B, S, cfg.num_kv_heads, dh)
    return q, k, v


def _gqa_core(q, k, v, mask):
    """q: (B,S,H,dh); k/v: (B,T,K,dh); mask: broadcastable (B,1,1,S,T)."""
    B, S, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    qr = q.reshape(B, S, K, G, dh)
    scale = 1.0 / math.sqrt(dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qr.astype(jnp.float32), k.astype(jnp.float32)) * scale
    scores = jnp.where(mask, scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, dh).astype(q.dtype)


def _window_for(block_type: str, cfg: ModelConfig) -> Optional[int]:
    if block_type == "swa":
        return cfg.sliding_window
    if block_type == "local":
        return cfg.local_window
    return None


def attention_apply(params, x, cfg: ModelConfig, block_type: str = "attn",
                    positions=None):
    """Full-sequence causal (optionally windowed) attention."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(params, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard_activation(q, ("batch", None, "model", None))
    k = shard_activation(k, ("batch", None, None, None))
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = j <= i
    window = _window_for(block_type, cfg)
    if window is not None:
        mask = mask & (j > i - window)
    out = _gqa_core(q, k, v, mask[None, None, None])
    out = out.reshape(B, S, -1) @ params["wo"]
    return shard_activation(out, ("batch", None, None))


def attention_init_cache(cfg: ModelConfig, block_type: str, batch: int, max_len: int):
    dh = cfg.resolved_head_dim
    window = _window_for(block_type, cfg)
    C = max_len if window is None else min(window, max_len)
    dt = cfg.jnp_dtype
    return {
        "k": jnp.zeros((batch, C, cfg.num_kv_heads, dh), dt),
        "v": jnp.zeros((batch, C, cfg.num_kv_heads, dh), dt),
    }


def attention_decode(params, x, cache, pos, cfg: ModelConfig, block_type: str = "attn"):
    """One-token decode. ``pos`` is the scalar absolute position.

    Full attention: cache slot = pos.  Windowed: ring buffer slot = pos % C.
    """
    B, S, _ = x.shape
    assert S == 1
    q, k, v = _qkv(params, x, cfg)
    posb = jnp.full((B, 1), pos, dtype=jnp.int32)
    q = apply_rope(q, posb, cfg.rope_theta)
    k = apply_rope(k, posb, cfg.rope_theta)
    C = cache["k"].shape[1]
    window = _window_for(block_type, cfg)
    slot = pos if window is None else pos % C
    new_k = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    new_v = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    valid = jnp.arange(C) <= (pos if window is None else jnp.minimum(pos, C - 1))
    out = _gqa_core(q, new_k, new_v, valid[None, None, None, None, :])
    out = out.reshape(B, 1, -1) @ params["wo"]
    return out, {"k": new_k, "v": new_v}


# ---------------------------------------------------------------------------
# Multi-head latent attention (DeepSeek-V3)
# ---------------------------------------------------------------------------


def mla_init(rng, cfg: ModelConfig):
    m = cfg.mla
    dt = cfg.jnp_dtype
    ks = jax.random.split(rng, 8)
    H = cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wdq": dense_init(ks[0], cfg.d_model, m.q_lora_rank, dt),
        "q_norm": rmsnorm_init(m.q_lora_rank, dt),
        "wuq": dense_init(ks[1], m.q_lora_rank, H * qk_dim, dt),
        "wdkv": dense_init(ks[2], cfg.d_model, m.kv_lora_rank, dt),
        "kv_norm": rmsnorm_init(m.kv_lora_rank, dt),
        "wuk": dense_init(ks[3], m.kv_lora_rank, H * m.qk_nope_head_dim, dt),
        "wuv": dense_init(ks[4], m.kv_lora_rank, H * m.v_head_dim, dt),
        "wkr": dense_init(ks[5], cfg.d_model, m.qk_rope_head_dim, dt),
        "wo": dense_init(ks[6], H * m.v_head_dim, cfg.d_model, dt),
    }


def _mla_q(params, x, positions, cfg: ModelConfig):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    cq = rmsnorm_apply(params["q_norm"], x @ params["wdq"], cfg.norm_eps)
    q = (cq @ params["wuq"]).reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_expand_kv(params, c_kv, cfg: ModelConfig):
    """Expand cached latent to per-head k_nope / v."""
    m = cfg.mla
    B, T, _ = c_kv.shape
    H = cfg.num_heads
    k_nope = (c_kv @ params["wuk"]).reshape(B, T, H, m.qk_nope_head_dim)
    v = (c_kv @ params["wuv"]).reshape(B, T, H, m.v_head_dim)
    return k_nope, v


def _mla_core(q_nope, q_rope, k_nope, k_rope, v, mask, cfg: ModelConfig):
    m = cfg.mla
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s_nope = jnp.einsum("bshd,bthd->bhst", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32))
    s_rope = jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32))
    scores = (s_nope + s_rope) * scale
    scores = jnp.where(mask, scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    return out.astype(q_nope.dtype)


def mla_apply(params, x, cfg: ModelConfig, positions=None):
    B, S, _ = x.shape
    m = cfg.mla
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q_nope, q_rope = _mla_q(params, x, positions, cfg)
    c_kv = rmsnorm_apply(params["kv_norm"], x @ params["wdkv"], cfg.norm_eps)
    k_rope = apply_rope((x @ params["wkr"])[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    k_nope, v = _mla_expand_kv(params, c_kv, cfg)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = (j <= i)[None, None]
    out = _mla_core(q_nope, q_rope, k_nope, k_rope, v, mask, cfg)
    out = out.reshape(B, S, -1) @ params["wo"]
    return shard_activation(out, ("batch", None, None))


def mla_init_cache(cfg: ModelConfig, batch: int, max_len: int):
    m = cfg.mla
    dt = cfg.jnp_dtype
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dt),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dt),
    }


def mla_decode(params, x, cache, pos, cfg: ModelConfig):
    B, S, _ = x.shape
    assert S == 1
    posb = jnp.full((B, 1), pos, dtype=jnp.int32)
    q_nope, q_rope = _mla_q(params, x, posb, cfg)
    c_kv_t = rmsnorm_apply(params["kv_norm"], x @ params["wdkv"], cfg.norm_eps)
    k_rope_t = apply_rope((x @ params["wkr"])[:, :, None, :], posb, cfg.rope_theta)[:, :, 0, :]
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv_t, (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope_t, (0, pos, 0))
    # Baseline: expand the whole latent cache to per-head K/V each step.
    # (§Perf hillclimb replaces this with the absorbed-matmul form.)
    k_nope, v = _mla_expand_kv(params, c_kv, cfg)
    valid = (jnp.arange(c_kv.shape[1]) <= pos)[None, None, None, :]
    out = _mla_core(q_nope, q_rope, k_nope, k_rope, v, valid, cfg)
    out = out.reshape(B, 1, -1) @ params["wo"]
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def mla_decode_absorbed(params, x, cache, pos, cfg: ModelConfig):
    """Weight-absorbed MLA decode (DeepSeek-V3 §2.1.2 inference form).

    Instead of expanding the latent cache to per-head K/V (which reads
    ``T × H × (qk_nope + v)`` elements from HBM per step), fold ``wuk``
    into the query and ``wuv`` into the output so attention runs directly
    against the rank-``r`` latent: per-step reads drop to ``T × r``.
    """
    m = cfg.mla
    B, S, _ = x.shape
    assert S == 1
    H = cfg.num_heads
    posb = jnp.full((B, 1), pos, dtype=jnp.int32)
    q_nope, q_rope = _mla_q(params, x, posb, cfg)
    c_kv_t = rmsnorm_apply(params["kv_norm"], x @ params["wdkv"], cfg.norm_eps)
    k_rope_t = apply_rope((x @ params["wkr"])[:, :, None, :], posb, cfg.rope_theta)[:, :, 0, :]
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv_t, (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope_t, (0, pos, 0))
    wuk = params["wuk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    # q_lat[h] = wuk[:,h,:] @ q_nope[h]  -> query in latent space
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope.astype(jnp.float32), wuk.astype(jnp.float32))
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s_lat = jnp.einsum("bshr,btr->bhst", q_lat, c_kv.astype(jnp.float32))
    s_rope = jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32))
    scores = (s_lat + s_rope) * scale
    valid = (jnp.arange(c_kv.shape[1]) <= pos)[None, None, None, :]
    scores = jnp.where(valid, scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1)
    ctx_lat = jnp.einsum("bhst,btr->bshr", probs, c_kv.astype(jnp.float32))
    wuv = params["wuv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bshr,rhd->bshd", ctx_lat, wuv.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(B, 1, -1) @ params["wo"]
    return out, {"c_kv": c_kv, "k_rope": k_rope}


# ---------------------------------------------------------------------------
# Cross-attention (encoder-decoder)
# ---------------------------------------------------------------------------


def cross_attention_apply(params, x, memory, cfg: ModelConfig):
    """Decoder query attends over encoder ``memory`` (B, T, D). No mask."""
    B, S, _ = x.shape
    dh = cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(B, S, cfg.num_heads, dh)
    k = (memory @ params["wk"]).reshape(B, memory.shape[1], cfg.num_kv_heads, dh)
    v = (memory @ params["wv"]).reshape(B, memory.shape[1], cfg.num_kv_heads, dh)
    out = _gqa_core(q, k, v, jnp.ones((1, 1, 1, 1, 1), bool))
    return out.reshape(B, S, -1) @ params["wo"]


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_init(rng, cfg: ModelConfig, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    dt = cfg.jnp_dtype
    return {
        "wg": dense_init(ks[0], cfg.d_model, d_ff, dt),
        "wu": dense_init(ks[1], cfg.d_model, d_ff, dt),
        "wd": dense_init(ks[2], d_ff, cfg.d_model, dt),
    }


def mlp_apply(params, x):
    h = jax.nn.silu(x @ params["wg"]) * (x @ params["wu"])
    h = shard_activation(h, ("batch", None, "model"))
    return h @ params["wd"]


# ---------------------------------------------------------------------------
# logits
# ---------------------------------------------------------------------------


def softcap(logits, cap: float):
    if cap and cap > 0:
        return jnp.tanh(logits / cap) * cap
    return logits
