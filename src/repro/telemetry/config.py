"""TraceConfig — the ``ExecutionSpec.trace`` knob.

An execution knob in the strict repro.api sense: it selects which
observability artifacts a run emits (event JSONL, Chrome trace, jax
profiler dump, HLO cost summary) and must NEVER change the simulated
outcome — parity between traced and untraced runs is bitwise
(params/masks/battery), enforced by tests/test_telemetry.py and the
bench trace smoke gate.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """What to export/profile for one run.  All fields default off;
    a default ``TraceConfig()`` still costs nothing beyond the always-on
    host-side Timeline.

    ``events_jsonl`` / ``chrome_trace`` are written by the
    ``Experiment.run`` facade after the run completes (host-side file
    I/O, outcome-neutral).  ``jax_profiler_dir`` wraps the fleet
    program's execution in ``jax.profiler.trace`` (fleet engine only —
    the loop engine warns and ignores it).  ``hlo_stats`` lowers and
    compiles the fleet program a second time through the AOT API to
    report flops/bytes (:mod:`repro.launch.hlo_stats`) — nothing is
    executed, but the extra compile makes it strictly opt-in.
    """

    events_jsonl: Optional[str] = None   # write the RoundEvent stream here
    chrome_trace: Optional[str] = None   # write the Timeline as trace.json
    jax_profiler_dir: Optional[str] = None  # jax.profiler.trace around the
                                            # fleet program (fleet only)
    hlo_stats: bool = False              # attach compiled-program flops/bytes
