"""Mesh context: activation sharding constraints that degrade to no-ops.

Model code calls :func:`shard_activation(x, ("batch", None, "model"))`
with *logical* axis names.  When a mesh context is active (set by the
launcher / dry-run) the logical names are mapped to mesh axes and a
``with_sharding_constraint`` is inserted; on a bare CPU test run the call
is a no-op, so smoke tests see a single device and no mesh.

Logical axes:
  "batch"  -> ("pod", "data") on the multi-pod mesh, ("data",) single-pod
  "model"  -> "model" (tensor-parallel: heads / d_ff / experts)
  "expert" -> "model" (expert-parallel shares the model axis)
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


class MeshContext:
    def __init__(self, mesh: Mesh):
        self.mesh = mesh
        names = mesh.axis_names
        if "pod" in names:
            self.batch_axes: Tuple[str, ...] = ("pod", "data")
        else:
            self.batch_axes = ("data",)
        self.model_axis = "model" if "model" in names else None
        self.manual: frozenset = frozenset()

    def resolve(self, logical):
        """Map a tuple of logical axis names to a PartitionSpec."""
        out = []
        for ax in logical:
            if ax is None:
                out.append(None)
            elif ax == "batch":
                out.append(self.batch_axes if len(self.batch_axes) > 1 else self.batch_axes[0])
            elif ax in ("model", "expert"):
                out.append(self.model_axis)
            else:  # raw mesh axis name
                out.append(ax if ax in self.mesh.axis_names else None)
        return P(*out)


def current_mesh_context() -> Optional[MeshContext]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = MeshContext(mesh) if mesh is not None else None
    try:
        if mesh is not None:
            with mesh:
                yield _STATE.ctx
        else:
            yield None
    finally:
        _STATE.ctx = prev


def batch_axes() -> Tuple[str, ...]:
    ctx = current_mesh_context()
    return ctx.batch_axes if ctx is not None else ()


@contextlib.contextmanager
def manual_axes(axes):
    """Mark mesh axes as manual (inside a ``shard_map`` over the client
    axes): sharding constraints must not mention them, so ``resolve``
    drops them while the context is active."""
    ctx = current_mesh_context()
    if ctx is None:
        yield
        return
    prev = ctx.manual
    ctx.manual = frozenset(axes)
    try:
        yield
    finally:
        ctx.manual = prev


def pvary_manual(x):
    """Mark ``x`` as varying over the active manual (client) axes.

    Needed for scan carries initialized from constants inside the
    federated shard_map (the MoE aux-loss accumulator): the carry must
    enter the scan with the same varying-manual-axes type it exits with.
    No-op outside a manual region.
    """
    ctx = current_mesh_context()
    if ctx is None or not ctx.manual:
        return x
    return jax.lax.pcast(x, tuple(sorted(ctx.manual)), to="varying")


def shard_activation(x, logical):
    """Constrain ``x`` to the logical sharding; no-op without a mesh."""
    ctx = current_mesh_context()
    if ctx is None:
        return x
    spec = ctx.resolve(logical)
    if ctx.manual:
        # drop manual (client) axes — they are local inside the shard_map —
        # and constrain against an abstract mesh that marks them Manual
        def strip(entry):
            if entry is None:
                return None
            if isinstance(entry, tuple):
                kept = tuple(a for a in entry if a not in ctx.manual)
                return kept if kept else None
            return None if entry in ctx.manual else entry
        spec = P(*[strip(e) for e in spec])
        if all(e is None for e in spec):
            return x
        from jax.sharding import AxisType
        amesh = ctx.mesh.abstract_mesh.update_axis_types(
            {a: AxisType.Manual for a in ctx.manual})
        return jax.lax.with_sharding_constraint(x, NamedSharding(amesh, spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
