"""Quickstart: the EnFed protocol end-to-end in ~60 lines.

A resource-constrained device (requester) builds an HAR model by asking
5 nearby devices for their (AES-encrypted) model updates against an
incentive, aggregating them, and personalizing on its own data —
Algorithm 1 of the paper — then reports accuracy, training time, energy,
and remaining battery.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (EnFedConfig, EnFedSession, SupervisedTask, make_fleet)
from repro.data import HARDatasetConfig, dirichlet_partition, make_har_windows
from repro.models import LSTMClassifier, LSTMClassifierConfig


def main():
    # synthetic HARSense-like dataset (accelerometer+gyro, 6 activities)
    x, y, _user = make_har_windows(HARDatasetConfig(num_samples=3000, seq_len=32))
    parts = dirichlet_partition(y, num_clients=6, alpha=1.0, seed=0)
    shards = [(x[p], y[p]) for p in parts]

    # requester (device M) keeps shard 0; 80/20 split for personalization
    own_x, own_y = shards[0]
    n_train = int(len(own_x) * 0.8)
    own_train = (own_x[:n_train], own_y[:n_train])
    own_test = (own_x[n_train:], own_y[n_train:])

    task = SupervisedTask(
        LSTMClassifier(LSTMClassifierConfig(input_dim=6, seq_len=32,
                                            hidden=64, num_classes=6)),
        lr=3e-3)

    # nearby devices: each pre-trains a local model on its own shard
    fleet = make_fleet(5, seed=1, p_has_model=1.0)
    contributor_states = {}
    for i, dev in enumerate(fleet):
        dev.reservation_price = 0.4        # all will accept a 0.6 incentive
        params = task.init(seed=10 + i)
        params, _ = task.fit(params, shards[i + 1], epochs=6, batch_size=32, seed=i)
        contributor_states[dev.device_id] = {"params": params, "data": shards[i + 1]}

    session = EnFedSession(
        task, own_train, own_test, fleet, contributor_states,
        EnFedConfig(desired_accuracy=0.95, max_rounds=10, n_max=5,
                    battery_threshold=0.2, offered_incentive=0.6,
                    epochs=8, batch_size=32, encrypt=True))
    res = session.run()

    print(f"accuracy        : {res.accuracy:.3f} (target 0.95, stop: {res.stop_reason})")
    print(f"rounds          : {res.rounds} with {res.n_contributors} contributors")
    print(f"training time   : {res.report.t_train:.2f} s   (eq. 4)")
    print(f"energy consumed : {res.report.e_tot:.2f} J   (eqs. 5-7: "
          f"{res.report.e_comp:.2f} comp + {res.report.e_comm:.2f} comm)")
    print(f"battery left    : {res.battery.percent:.1f} %")
    return 0 if res.accuracy >= 0.9 else 1


if __name__ == "__main__":
    raise SystemExit(main())
