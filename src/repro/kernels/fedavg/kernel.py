"""Pallas TPU kernel: fused masked-weighted FedAvg aggregation.

Aggregation (paper eq. 14) is a memory-bound reduction over the
contributor axis: for every parameter tile we stream N contributor
slices HBM -> VMEM once and emit one fp32 tile.  Fusing the mask, the
weighting, and the normalization into one pass avoids materializing the
masked intermediate (which a naive ``(mask*w)[:,None]*updates`` would
write back to HBM at full N x L size).

Tiling: grid over the flat parameter dimension, block (N, TILE_L) with
TILE_L = 2048 (16 x 128 lanes) so the working set N*TILE_L*4B stays well
under VMEM for fleet sizes up to ~256 contributors.  The weight vector
is small and replicated to every grid step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret

TILE_L = 2048


def _fedavg_kernel(w_ref, u_ref, o_ref):
    """w_ref: (N,) fp32; u_ref: (N, TILE_L); o_ref: (TILE_L,)."""
    w = w_ref[...]
    u = u_ref[...].astype(jnp.float32)
    num = jnp.einsum("n,nl->l", w, u)
    denom = jnp.maximum(jnp.sum(w), 1e-9)
    o_ref[...] = num / denom


def _fedavg_batched_kernel(w_ref, u_ref, o_ref):
    """w_ref: (1, N) fp32; u_ref: (1, N, TILE_L); o_ref: (1, TILE_L).

    One requester session per leading grid step — the fleet engine's
    aggregation hot path runs every session's eq. (14) in one launch.
    """
    w = w_ref[0]
    u = u_ref[0].astype(jnp.float32)
    num = jnp.einsum("n,nl->l", w, u)
    denom = jnp.maximum(jnp.sum(w), 1e-9)
    o_ref[0] = num / denom


@functools.partial(jax.jit, static_argnames=("interpret",))
def fedavg_batched_pallas(updates, weights, *, interpret=None):
    """updates: (R, N, L); weights: (R, N). Returns (R, L) fp32.

    The requester-batched form of :func:`fedavg_pallas`: grid
    (R, L/TILE_L), each step reduces one requester's contributor stack
    for one parameter tile.  Used by ``repro.core.fleet`` to aggregate
    every concurrent session in a single kernel launch.
    """
    interpret = resolve_interpret(interpret)
    r, n, l = updates.shape
    pad = (-l) % TILE_L
    if pad:
        updates = jnp.pad(updates, ((0, 0), (0, 0), (0, pad)))
    lp = l + pad
    grid = (r, lp // TILE_L)
    out = pl.pallas_call(
        _fedavg_batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n), lambda i, j: (i, 0)),
            pl.BlockSpec((1, n, TILE_L), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, TILE_L), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, lp), jnp.float32),
        interpret=interpret,
    )(weights.astype(jnp.float32), updates)
    return out[:, :l]


@functools.partial(jax.jit, static_argnames=("interpret",))
def fedavg_pallas(updates, weights, *, interpret=None):
    """updates: (N, L); weights: (N,). Returns (L,) fp32.

    L is padded to a TILE_L multiple internally; callers pass any L.
    """
    interpret = resolve_interpret(interpret)
    n, l = updates.shape
    pad = (-l) % TILE_L
    if pad:
        updates = jnp.pad(updates, ((0, 0), (0, pad)))
    lp = l + pad
    grid = (lp // TILE_L,)
    out = pl.pallas_call(
        _fedavg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n, TILE_L), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((TILE_L,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((lp,), jnp.float32),
        interpret=interpret,
    )(weights.astype(jnp.float32), updates)
    return out[:l]
