"""Unified result types: one schema for every method and engine.

:class:`RunResult` supersedes the ``SessionResult`` / ``FleetResult`` /
``BaselineResult`` split at the public surface: whatever ran — the loop
oracle, the jit fleet program, or a host-side baseline — the caller gets
per-requester :class:`repro.core.rounds.SessionResult` views in
``sessions`` plus fleet-level aggregates, all costed by ONE shared
:class:`repro.core.energy.CostModel`.  :class:`CompareResult` holds N
methods run on the same world+seed+cost model and emits the paper's
Table-style time/energy reduction rows.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Sequence

from repro.core.battery import BatteryState
from repro.core.energy import CostModel, EnergyReport
from repro.core.rounds import SessionResult
from repro.telemetry.events import RoundEvent, session_events
from repro.telemetry.spans import Timeline


@dataclasses.dataclass
class RunResult:
    """Outcome of one ``Experiment.run()`` in a method/engine-agnostic schema.

    Scalars (``accuracy``, ``rounds``, ``report`` ...) describe "the
    requesting device" — requester 0, the paper's measured device;
    ``sessions`` carries every requester's full view (history, energy
    report, battery, params).  ``simulated_s`` is the modeled eq. (4)
    training time, ``wall_s`` the host wall-clock of the run itself.
    """

    method: str
    engine: str
    accuracy: float
    rounds: int
    report: EnergyReport               # requester 0's eq. (4)-(7) roll-up
    # DEPRECATED view: requester 0's raw per-engine dict-of-lists —
    # attribute access warns (property attached below the class); new
    # code reads the normalized event stream (``trace``) or, for the raw
    # buffers, ``history_raw``
    history: Dict[str, list] = dataclasses.field(repr=False, compare=False)
    stop_reason: str
    sessions: List[SessionResult]
    cost_model: Optional[CostModel] = None
    params: object = None
    n_contributors: float = 0.0
    battery: Optional[BatteryState] = None
    total_energy_j: float = 0.0        # summed across all requesters
    wall_s: float = 0.0
    raw: object = None                 # underlying engine result, if any
    timeline: Optional[Timeline] = None  # host-side wall-clock spans
    hlo_stats: Optional[dict] = None     # fleet program flops/bytes
                                         # (TraceConfig.hlo_stats)

    @property
    def history_raw(self) -> Dict[str, list]:
        """Requester 0's raw per-engine dict-of-lists, without the
        deprecation warning — the internal surface."""
        return self.__dict__["_history_raw"]

    @property
    def simulated_s(self) -> float:
        """Modeled training time T_train (eq. 4) of the requesting device."""
        return float(self.report.t_train)

    @property
    def energy_j(self) -> float:
        """Modeled energy E_tot (eq. 5) of the requesting device."""
        return float(self.report.e_tot)

    @property
    def trace(self) -> List[RoundEvent]:
        """The run as one normalized RoundEvent stream — every session's
        rounds (requester-stamped) plus stop events, identical across
        engines on the same world (``repro.telemetry.events``)."""
        events: List[RoundEvent] = []
        for i, s in enumerate(self.sessions):
            events.extend(session_events(s, requester=i))
        return events

    @property
    def corruption_summary(self) -> Optional[Dict[str, float]]:
        """Fleet-wide Byzantine roll-up from the normalized trace:
        total corrupted deliveries and robust-clipped links across every
        requester's executed rounds.  ``None`` on honest worlds (no
        ``MethodSpec.adversary`` — absence stays distinguishable from an
        observed 0, same rule as the RoundEvent fields)."""
        events = [e for e in self.trace if e.phase == "round"]
        if not any(e.corrupted is not None or e.clipped is not None
                   for e in events):
            return None
        corrupted = sum(len(e.corrupted or ()) for e in events)
        clipped = sum(len(e.clipped or ()) for e in events)
        rounds = len(events)
        return {"corrupted_links": float(corrupted),
                "clipped_links": float(clipped),
                "rounds": float(rounds),
                "corrupted_per_round": (corrupted / rounds if rounds
                                        else 0.0)}

    @property
    def timings(self) -> Dict[str, float]:
        """Summed seconds per span name (``Timeline.totals()``); empty
        when no timeline was recorded."""
        return self.timeline.totals() if self.timeline is not None else {}

    @classmethod
    def from_sessions(cls, method: str, engine: str,
                      sessions: Sequence[SessionResult],
                      cost_model: Optional[CostModel] = None,
                      total_energy_j: Optional[float] = None,
                      raw: object = None,
                      timeline: Optional[Timeline] = None,
                      hlo_stats: Optional[dict] = None) -> "RunResult":
        s0 = sessions[0]
        total = (float(total_energy_j) if total_energy_j is not None
                 else float(sum(s.report.e_tot for s in sessions)))
        return cls(method=method, engine=engine, accuracy=s0.accuracy,
                   rounds=s0.rounds, report=s0.report,
                   history=s0.history_raw,
                   stop_reason=s0.stop_reason, sessions=list(sessions),
                   cost_model=cost_model, params=s0.params,
                   n_contributors=float(s0.n_contributors),
                   battery=s0.battery, total_energy_j=total, raw=raw,
                   timeline=timeline, hlo_stats=hlo_stats)


def _run_history_get(self):
    warnings.warn(
        "RunResult.history is deprecated; use .trace (normalized "
        "RoundEvent stream) or .history_raw for the raw buffers",
        DeprecationWarning, stacklevel=2)
    return self.__dict__["_history_raw"]


def _run_history_set(self, value):
    # dataclass __init__ assigns through here — store raw, never warn
    self.__dict__["_history_raw"] = value


RunResult.history = property(_run_history_get, _run_history_set)


def reduction_row(method_res: RunResult, baseline_res: RunResult) -> dict:
    """The paper's Table-IV/V-style comparison row: how much training
    time and energy ``method`` saves over ``baseline`` on the same world
    (positive percentages = the method is cheaper)."""
    t_m, t_b = method_res.simulated_s, baseline_res.simulated_s
    e_m, e_b = method_res.energy_j, baseline_res.energy_j
    return {
        "method": method_res.method, "baseline": baseline_res.method,
        "t_method_s": round(t_m, 4), "t_baseline_s": round(t_b, 4),
        "time_reduction_pct": round(100.0 * (1.0 - t_m / t_b), 2) if t_b else None,
        "e_method_j": round(e_m, 4), "e_baseline_j": round(e_b, 4),
        "energy_reduction_pct": round(100.0 * (1.0 - e_m / e_b), 2) if e_b else None,
        "acc_method": round(method_res.accuracy, 4),
        "acc_baseline": round(baseline_res.accuracy, 4),
    }


@dataclasses.dataclass
class CompareResult:
    """N methods on one world+seed+cost model (``Experiment.compare``)."""

    results: Dict[str, RunResult]      # insertion-ordered by methods arg

    def __getitem__(self, name: str) -> RunResult:
        return self.results[name]

    def __iter__(self):
        return iter(self.results.values())

    def reduction(self, method: str = "enfed", baseline: str = "dfl") -> dict:
        return reduction_row(self.results[method], self.results[baseline])

    def reductions(self, method: str = "enfed") -> List[dict]:
        """``method`` vs every other method in the comparison."""
        return [reduction_row(self.results[method], r)
                for name, r in self.results.items() if name != method]

    def table(self) -> str:
        """Printable paper-style summary table."""
        lines = [f"{'method':<12} {'acc':>6} {'rounds':>6} "
                 f"{'T_train(s)':>11} {'E(J)':>10}"]
        for name, r in self.results.items():
            lines.append(f"{name:<12} {r.accuracy:6.3f} {r.rounds:6d} "
                         f"{r.simulated_s:11.2f} {r.energy_j:10.2f}")
        return "\n".join(lines)
