"""Crash-resumable round state: killed-at-round-k == uninterrupted.

Both engines serialize their full round state — wire-format contributor
buffers (int8 stays int8), batteries, masks, round clocks, fault/
mobility traces — through repro.checkpoint at round/chunk boundaries.
These tests kill a run mid-session (by dropping every checkpoint past
round k) and assert the resumed run is bit-identical to the
uninterrupted one: params, battery, delivered/membership masks.
"""

import copy
import glob
import os

import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.core import (EnFedConfig, EnFedSession, FaultConfig,
                        MobilityConfig, RequesterSpec, run_fleet)
from repro.core.battery import BatteryState

from test_fleet_engine import BATCH, _build

FC = FaultConfig(p_drop=0.6, p_stale=0.4, max_retries=1, release_after=2,
                 seed=3)
MOB = MobilityConfig(arena_m=120.0, radio_range_m=60.0, leg_rounds=2, seed=5)


@pytest.fixture(scope="module")
def problem():
    return _build()


def _cfg(**kw):
    base = dict(desired_accuracy=0.99, max_rounds=6, epochs=1,
                batch_size=BATCH, encrypt=False,
                contributor_refresh_epochs=1)
    base.update(kw)
    return EnFedConfig(**base)


def _kill_after(ckpt_dir, keep_step):
    """Simulate a crash: drop every checkpoint past ``keep_step`` so the
    resume has to restart from round ``keep_step``'s state."""
    removed = 0
    for f in glob.glob(os.path.join(ckpt_dir, "step_*.npz")):
        if int(os.path.basename(f)[5:13]) > keep_step:
            os.remove(f)
            removed += 1
    assert removed > 0, "nothing to kill: checkpointing did not run"


def _assert_identical(full, res, *, mask_key=None):
    fp, _ = ravel_pytree(full.params)
    rp, _ = ravel_pytree(res.params)
    assert np.array_equal(np.asarray(fp), np.asarray(rp)), \
        "resumed params differ from uninterrupted run"
    assert res.rounds == full.rounds
    assert res.stop_reason == full.stop_reason
    np.testing.assert_array_equal(full.history_raw["battery"],
                                  res.history_raw["battery"])
    np.testing.assert_array_equal(full.history_raw["accuracy"],
                                  res.history_raw["accuracy"])
    if mask_key:
        np.testing.assert_array_equal(np.stack(full.history_raw[mask_key]),
                                      np.stack(res.history_raw[mask_key]))


# ---------------------------------------------------------------------------
# loop engine
# ---------------------------------------------------------------------------


def _run_loop(problem, cfg, **run_kw):
    task, own_train, own_test, fleet, states = problem
    return EnFedSession(task, own_train, own_test, fleet,
                        copy.deepcopy(states), cfg,
                        battery=BatteryState()).run(**run_kw)


@pytest.mark.parametrize("cfg_kw,mask_key", [
    (dict(), None),
    (dict(faults=FC, compress="int8"), "deliver_mask"),
    (dict(faults=FC, mobility=MOB), "member_mask"),
], ids=["static", "faults-int8", "mobility-faults"])
def test_loop_kill_and_resume_bit_identical(problem, cfg_kw, mask_key,
                                            tmp_path):
    cfg = _cfg(**cfg_kw)
    full = _run_loop(problem, cfg)
    d = str(tmp_path / "ck")
    _run_loop(problem, cfg, checkpoint_dir=d)      # default: every round
    _kill_after(d, 3)
    res = _run_loop(problem, cfg, resume_from=d)
    _assert_identical(full, res, mask_key=mask_key)


def test_loop_resume_missing_dir_raises(problem):
    with pytest.raises(FileNotFoundError):
        _run_loop(problem, _cfg(), resume_from="/nonexistent/ckpts")


def test_loop_checkpoint_every_validation(problem):
    with pytest.raises(ValueError):
        _run_loop(problem, _cfg(), checkpoint_dir="/tmp/x",
                  checkpoint_every=-1)


# ---------------------------------------------------------------------------
# fleet engine
# ---------------------------------------------------------------------------


def _spec(problem):
    _, own_train, own_test, fleet, states = problem
    return RequesterSpec(own_train=own_train, own_test=own_test,
                         neighborhood=fleet,
                         contributor_states=copy.deepcopy(states),
                         battery=BatteryState())


@pytest.mark.parametrize("cfg_kw,mask_key", [
    (dict(faults=FC, compress="int8"), "deliver_mask"),
    (dict(mobility=MOB, faults=FC), "member_mask"),
], ids=["faults-int8", "mobility-faults"])
def test_fleet_kill_and_resume_bit_identical(problem, cfg_kw, mask_key,
                                             tmp_path):
    task = problem[0]
    cfg = _cfg(**cfg_kw)
    d_full = str(tmp_path / "full")
    full = run_fleet(task, [_spec(problem)], cfg, round_chunk=2,
                     checkpoint_dir=d_full, checkpoint_every=2)
    d_kill = str(tmp_path / "kill")
    run_fleet(task, [_spec(problem)], cfg, round_chunk=2,
              checkpoint_dir=d_kill, checkpoint_every=2)
    _kill_after(d_kill, 2)
    res = run_fleet(task, [_spec(problem)], cfg, round_chunk=2,
                    resume_from=d_kill)
    _assert_identical(full.sessions[0], res.sessions[0], mask_key=mask_key)
    np.testing.assert_array_equal(np.asarray(full.battery_level),
                                  np.asarray(res.battery_level))


def test_fleet_chunked_matches_while_loop_path(problem):
    """The host-driven checkpoint loop and the fully-compiled while_loop
    trace the SAME round bodies — outcomes agree without checkpointing
    even being exercised."""
    import tempfile
    task = problem[0]
    cfg = _cfg(faults=FC)
    plain = run_fleet(task, [_spec(problem)], cfg, round_chunk=2)
    with tempfile.TemporaryDirectory() as d:
        chunked = run_fleet(task, [_spec(problem)], cfg, round_chunk=2,
                            checkpoint_dir=d)
    pv, _ = ravel_pytree(plain.sessions[0].params)
    cv, _ = ravel_pytree(chunked.sessions[0].params)
    np.testing.assert_allclose(np.asarray(cv), np.asarray(pv),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(plain.history_raw["deliver"],
                                  chunked.history_raw["deliver"])


def test_fleet_checkpoint_rejected_for_baselines(problem):
    task = problem[0]
    with pytest.raises(ValueError, match="enfed-only"):
        run_fleet(task, [_spec(problem)], _cfg(), method="dfl",
                  checkpoint_dir="/tmp/x")


# ---------------------------------------------------------------------------
# api facade
# ---------------------------------------------------------------------------


def test_experiment_resume_shorthand(problem, tmp_path):
    """Experiment.run(resume=...) == the uninterrupted run, through the
    facade, for both engines sharing one checkpoint layout."""
    from repro.api import Experiment, ExecutionSpec, MethodSpec, WorldSpec

    task, own_train, own_test, fleet, states = problem
    world = WorldSpec.single(task, own_train, own_test, fleet, states)
    method = MethodSpec(desired_accuracy=0.99, max_rounds=6, epochs=1,
                        batch_size=BATCH, encrypt=False,
                        contributor_refresh_epochs=1, faults=FC)
    full = Experiment(world, method).run()
    d = str(tmp_path / "api_ck")
    Experiment(world, method,
               ExecutionSpec(checkpoint_dir=d)).run()
    _kill_after(d, 3)
    res = Experiment(world, method).run(resume=d)
    _assert_identical(full.sessions[0], res.sessions[0],
                      mask_key="deliver_mask")


def test_execution_spec_validation():
    from repro.api import ExecutionSpec
    with pytest.raises(ValueError):
        ExecutionSpec(checkpoint_every=-1)


def test_baseline_warns_checkpoint_ignored(problem):
    from repro.api import Experiment, ExecutionSpec, MethodSpec, WorldSpec

    task, own_train, own_test, fleet, states = problem
    world = WorldSpec.single(task, own_train, own_test, fleet, states)
    method = MethodSpec(name="cfl", max_rounds=1, epochs=1,
                        batch_size=BATCH, encrypt=False)
    with pytest.warns(UserWarning, match="enfed-only"):
        Experiment(world, method,
                   ExecutionSpec(checkpoint_dir="/tmp/never-used")).run()
