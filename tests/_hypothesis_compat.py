"""Import-or-skip shim for ``hypothesis``.

Tier-1 must collect and run green on a bare interpreter (CI CPU image,
fresh checkout) where ``hypothesis`` is not installed.  Property tests
import ``given``/``settings``/``st`` from here instead of from
``hypothesis`` directly: when the real library is present they behave
identically; when it is absent each property test becomes a single
skipped test with a clear reason instead of a collection error.

Install the real thing with ``pip install -r requirements-dev.txt``.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare CI images
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any ``st.<strategy>(...)`` call at decoration time."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda fn: fn

    def given(*a, **k):
        def deco(fn):
            # Replace the property test with a zero-arg skipper so pytest
            # neither calls it without its hypothesis-driven args nor
            # mistakes those args for fixtures.
            def skipper():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco
