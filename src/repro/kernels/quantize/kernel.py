"""Pallas TPU kernel: per-tile symmetric int8 quantize / dequantize.

Beyond-paper optimization: the paper cites gradient/weight quantization
as the standard lever for communication energy ([13], [14]) but does not
use it.  EnFed's update transport is the dominant communication cost
(R x N_c x w bytes), so int8-compressing the update stream cuts both the
radio energy of the fleet simulation and the collective bytes of the
distributed roofline by ~4x.

One fused pass: absmax reduction and scaled round-to-int8 in VMEM, one
tile per grid step, scale emitted per tile.  Dequant is the inverse pass
fused into the receive path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret

TILE = 1024


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[0] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_pallas(x, *, interpret=None):
    """x: (L,) fp32 -> (q int8 (Lp,), scales (Lp/TILE,), L). Pads to TILE."""
    interpret = resolve_interpret(interpret)
    l = x.shape[0]
    pad = (-l) % TILE
    if pad:
        x = jnp.pad(x, (0, pad))
    lp = l + pad
    grid = (lp // TILE,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((TILE,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((lp,), jnp.int8),
            jax.ShapeDtypeStruct((lp // TILE,), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, s


def _quant_batched_kernel(x_ref, q_ref, s_ref):
    """x_ref: (TB, TILE) fp32; q_ref: (TB, TILE) int8; s_ref: (TB, 1)
    fp32.

    A TILE of (batch row, tile) pairs per grid step — the batched form
    the fleet engine's Phase.REFRESH uses to requantize every lane's
    freshly-trained params back into the int8 round state in one launch.
    Per-row tile math is identical to :func:`_quant_kernel` (the absmax
    reduction stays within a row), so a batched row reproduces the 1-D
    kernel: bit-equal int8 codes, scales within 1 ulp of codegen.  Rows
    are tiled (TB per step) to keep the grid small — interpret mode
    walks grid steps serially.
    """
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=1)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q_ref[...] = jnp.clip(jnp.round(x / scale[:, None]),
                          -127, 127).astype(jnp.int8)
    s_ref[...] = scale[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_batched_pallas(x, *, interpret=None):
    """x: (B, Lp) fp32 with Lp % TILE == 0 (wire-format rows are padded
    by construction) -> (q int8 (B, Lp), scales fp32 (B, Lp/TILE))."""
    interpret = resolve_interpret(interpret)
    b, lp = x.shape
    if lp % TILE:
        raise ValueError(f"quantize_batched_pallas needs Lp % {TILE} == 0 "
                         f"(got {lp}); pad the wire buffer first")
    tb = max(1, min(b, (2 << 20) // (TILE * 4)))
    pad_b = (-b) % tb
    if pad_b:
        x = jnp.pad(x, ((0, pad_b), (0, 0)))
    grid = ((b + pad_b) // tb, lp // TILE)
    q, s = pl.pallas_call(
        _quant_batched_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tb, TILE), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((tb, TILE), lambda i, j: (i, j)),
            pl.BlockSpec((tb, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b + pad_b, lp), jnp.int8),
            jax.ShapeDtypeStruct((b + pad_b, lp // TILE), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q[:b], s[:b]


@functools.partial(jax.jit, static_argnames=("orig_len", "interpret"))
def dequantize_pallas(q, scales, orig_len: int, *, interpret=None):
    interpret = resolve_interpret(interpret)
    lp = q.shape[0]
    grid = (lp // TILE,)
    x = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((lp,), jnp.float32),
        interpret=interpret,
    )(q, scales)
    return x[:orig_len]
