"""Full HAR comparison scenario: EnFed vs CFL vs DFL(mesh/ring) vs
cloud-only, on both paper datasets (calories->MLP, HARSense->LSTM).

This is the experiment behind Tables IV/V/VII of the paper, at example
scale (the full benchmark lives in benchmarks/).

  PYTHONPATH=src python examples/har_federated.py [--dataset har|calories]
                                                  [--engine loop|fleet]

``--engine fleet`` runs the same EnFed session through the jit-native
fleet engine (repro.core.fleet) instead of the Python round loop — same
protocol, same result (parity-tested), one compiled program.
"""

import argparse

import numpy as np

from repro.core import (CFLLearner, DFLLearner, EnFedConfig, EnFedSession,
                        SupervisedTask, cloud_only_baseline, make_fleet)
from repro.data import (CaloriesDatasetConfig, HARDatasetConfig,
                        dirichlet_partition, make_calories_tabular,
                        make_har_windows)
from repro.models import (LSTMClassifier, LSTMClassifierConfig, MLPClassifier,
                          MLPClassifierConfig)


def build(dataset: str):
    if dataset == "har":
        x, y, _ = make_har_windows(HARDatasetConfig(num_samples=3000, seq_len=32))
        task = SupervisedTask(LSTMClassifier(LSTMClassifierConfig(6, 32, 64, 6)), lr=3e-3)
    else:
        x, y = make_calories_tabular(CaloriesDatasetConfig(num_samples=3000))
        task = SupervisedTask(MLPClassifier(MLPClassifierConfig(8, (64, 32), 5)), lr=3e-3)
    parts = dirichlet_partition(y, num_clients=6, alpha=1.0, seed=0)
    shards = [(x[p], y[p]) for p in parts]
    own_x, own_y = shards[0]
    n = int(len(own_x) * 0.8)
    return task, shards, (own_x[:n], own_y[:n]), (own_x[n:], own_y[n:]), (x, y)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", choices=("har", "calories"), default="har")
    ap.add_argument("--target", type=float, default=0.95)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--engine", choices=("loop", "fleet"), default="loop",
                    help="EnFed execution engine (fleet = one jit program)")
    args = ap.parse_args()

    task, shards, own_train, own_test, pooled = build(args.dataset)

    # --- EnFed ---------------------------------------------------------
    fleet = make_fleet(5, seed=1, p_has_model=1.0)
    states = {}
    for i, dev in enumerate(fleet):
        dev.reservation_price = 0.4
        p = task.init(seed=10 + i)
        p, _ = task.fit(p, shards[i + 1], epochs=args.epochs, batch_size=32, seed=i)
        states[dev.device_id] = {"params": p, "data": shards[i + 1]}
    enfed = EnFedSession(task, own_train, own_test, fleet, states,
                         EnFedConfig(desired_accuracy=args.target, epochs=args.epochs,
                                     max_rounds=10)).run(engine=args.engine)

    # --- baselines -----------------------------------------------------
    client_data = [own_train] + shards[1:6]
    cfl = CFLLearner(task, client_data, own_test).run(
        target_accuracy=args.target, max_rounds=10, epochs=args.epochs, batch_size=32)
    dfl_mesh = DFLLearner(task, client_data, own_test, "mesh").run(
        target_accuracy=args.target, max_rounds=10, epochs=args.epochs, batch_size=32)
    dfl_ring = DFLLearner(task, client_data, own_test, "ring").run(
        target_accuracy=args.target, max_rounds=10, epochs=args.epochs, batch_size=32)
    cloud_acc, cloud_resp, _ = cloud_only_baseline(
        task, pooled, own_test, epochs=args.epochs, batch_size=32)

    print(f"\n=== {args.dataset} ===")
    print(f"{'system':<10} {'acc':>6} {'rounds':>6} {'T_train(s)':>11} {'E(J)':>9}")
    print(f"{'EnFed':<10} {enfed.accuracy:6.3f} {enfed.rounds:6d} "
          f"{enfed.report.t_train:11.2f} {enfed.report.e_tot:9.2f}")
    print(f"{'CFL':<10} {cfl.accuracy:6.3f} {cfl.rounds:6d} "
          f"{cfl.report.t_train:11.2f} {cfl.report.e_tot:9.2f}")
    print(f"{'DFL-mesh':<10} {dfl_mesh.accuracy:6.3f} {dfl_mesh.rounds:6d} "
          f"{dfl_mesh.report.t_train:11.2f} {dfl_mesh.report.e_tot:9.2f}")
    print(f"{'DFL-ring':<10} {dfl_ring.accuracy:6.3f} {dfl_ring.rounds:6d} "
          f"{dfl_ring.report.t_train:11.2f} {dfl_ring.report.e_tot:9.2f}")
    print(f"{'cloud':<10} {cloud_acc:6.3f} {'-':>6} {cloud_resp:11.2f} {'-':>9}  (response time)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
