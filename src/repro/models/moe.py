"""Mixture-of-experts layer.

Two dispatch schedules, selected by ``MoEConfig.dispatch``:

* ``"sort"`` (default, production path) — sort-based scatter dispatch:
  top-k assignments are flattened, stably sorted by expert id, capacity
  is enforced by position-within-expert, and tokens are scattered into a
  per-expert buffer with one gather/scatter pair.  Memory is O(T*k*D),
  *linear* in tokens (the one-hot form is O(T^2 * k / E * ...) once
  capacity scales with T, which is infeasible at 32k tokens/device).
  Routing runs token-local: when a mesh is active and the ``data`` axis
  is not already manual (fsdp configs federate over ``pod`` only), the
  dispatch is wrapped in a nested ``shard_map`` over ``data`` so sort /
  cumsum / scatter never cross devices.  The expert dimension stays in
  auto mode, sharded over ``model`` (expert parallel): XLA inserts the
  buffer reshard (the all-to-all of a classic MoE) around the expert
  matmuls.

* ``"einsum"`` — the GShard one-hot dispatch (kept for small models and
  as a cross-validation oracle for the sort path; both enforce identical
  token-order-within-expert capacity-drop semantics).

Compute is proportional to ``top_k x capacity_factor``, not to the
number of experts, so dry-run FLOPs are faithful to a real MoE
deployment (DeepSeek-V3: 256 routed, 8 active [arXiv:2412.19437]).
"""

from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models import layers
from repro.sharding.ctx import current_mesh_context, shard_activation

CAPACITY_FACTOR = 1.25

# Differentiating a nested manual-'data' region with *bf16* values at
# the shard_map boundary, composed with ZeRO-sharded params, CHECK-
# crashes XLA-CPU's SPMD partitioner ("Invalid binary instruction opcode
# copy"; bisection: bf16+fsdp+wrap+grad — any one removed compiles).
# fsdp TRAIN steps therefore enter the token-local region through an
# fp32 boundary cast (compute penalty recorded in §Perf); prefill/serve
# keep the native-dtype boundary (no grad involved).
_TL_STATE = threading.local()


@contextlib.contextmanager
def disable_token_local():
    """Grad-safe mode: fp32-cast the token-local shard_map boundary."""
    prev = getattr(_TL_STATE, "off", False)
    _TL_STATE.off = True
    try:
        yield
    finally:
        _TL_STATE.off = prev


def moe_init(rng, cfg: ModelConfig):
    m = cfg.moe
    d_ff = m.d_ff_expert or cfg.d_ff
    ks = jax.random.split(rng, 5)
    dt = cfg.jnp_dtype
    E = m.num_experts
    p = {
        "router": layers.dense_init(ks[0], cfg.d_model, E, jnp.float32),
        "wg": (jax.random.normal(ks[1], (E, cfg.d_model, d_ff), jnp.float32) / jnp.sqrt(cfg.d_model)).astype(dt),
        "wu": (jax.random.normal(ks[2], (E, cfg.d_model, d_ff), jnp.float32) / jnp.sqrt(cfg.d_model)).astype(dt),
        "wd": (jax.random.normal(ks[3], (E, d_ff, cfg.d_model), jnp.float32) / jnp.sqrt(d_ff)).astype(dt),
    }
    if m.num_shared_experts > 0:
        p["shared"] = layers.mlp_init(ks[4], cfg, d_ff=d_ff * m.num_shared_experts)
    return p


def _expert_ffn(params, expert_in):
    """expert_in: (E, C, D) -> (E, C, D) batched SwiGLU."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, params["wg"]))
    h = h * jnp.einsum("ecd,edf->ecf", expert_in, params["wu"])
    return jnp.einsum("ecf,efd->ecd", h, params["wd"])


def _topk(gates, k):
    top_v, top_i = jax.lax.top_k(gates, k)
    top_v = top_v / (jnp.sum(top_v, axis=-1, keepdims=True) + 1e-9)
    return top_v, top_i


# ---------------------------------------------------------------------------
# sort-based scatter dispatch (token-local)
# ---------------------------------------------------------------------------


def _moe_local_sort(params, xt, cfg: ModelConfig):
    """xt: (T, D) token-local block. Returns (y (T, D), aux scalar)."""
    m = cfg.moe
    T, D = xt.shape
    E, k = m.num_experts, m.num_experts_per_tok
    capacity = max(1, int(T * k * CAPACITY_FACTOR / E))

    logits = xt.astype(jnp.float32) @ params["router"]
    gates = jax.nn.softmax(logits, axis=-1)                       # (T, E)
    top_v, top_i = _topk(gates, k)

    # Flatten choice-major (j*T + t) so each expert's queue holds all
    # 1st-choice tokens (in token order) before any 2nd-choice token —
    # the same capacity-drop order the einsum/GShard oracle enforces via
    # its per-j cumsum with carried counts.
    e_flat = top_i.T.reshape(-1)                                   # (k*T,)
    w_flat = top_v.T.reshape(-1)
    tok_flat = jnp.tile(jnp.arange(T, dtype=jnp.int32), k)

    order = jnp.argsort(e_flat, stable=True)                       # token order within expert
    e_sorted = e_flat[order]
    # position of each routed token within its expert's queue
    starts = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
    pos = jnp.arange(T * k, dtype=jnp.int32) - starts[e_sorted].astype(jnp.int32)
    keep = pos < capacity
    slot = jnp.where(keep, e_sorted * capacity + pos, E * capacity)  # sentinel row

    buf = jnp.zeros((E * capacity + 1, D), xt.dtype)
    buf = buf.at[slot].set(xt[tok_flat[order]])
    expert_in = buf[:-1].reshape(E, capacity, D)
    expert_in = shard_activation(expert_in, ("expert", None, None))
    expert_out = _expert_ffn(params, expert_in)
    expert_out = shard_activation(expert_out, ("expert", None, None))
    rows = jnp.concatenate([expert_out.reshape(E * capacity, D),
                            jnp.zeros((1, D), xt.dtype)], axis=0)
    routed = rows[slot] * w_flat[order, None].astype(xt.dtype)     # (T*k, D)
    y = jnp.zeros((T, D), xt.dtype).at[tok_flat[order]].add(routed)

    # Switch-style load-balance auxiliary loss (token-local estimate)
    me = jnp.mean(gates, axis=0)
    frac = jnp.mean(jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32), axis=0)
    aux = jnp.sum(me * frac) * E * m.router_aux_loss_coef
    return y, aux


# ---------------------------------------------------------------------------
# expert-parallel replicated-dispatch schedule (§Perf hillclimb)
# ---------------------------------------------------------------------------


def _moe_expert_parallel(params, x, cfg: ModelConfig, mesh):
    """Zero-communication dispatch: tokens are already replicated over the
    'model' axis (tensor-parallel replicates activations), so each model
    rank routes its local copy and keeps ONLY the tokens assigned to the
    E/n_model experts it owns.  The only collective is the psum of the
    (T, D) combined output — O(T*D) instead of the O(E*C*D) buffer
    all-gather XLA inserts for the auto-sharded schedule (the dominant
    collective term of the MoE train baselines, §Perf).

    Binds 'data' (token-local routing) and 'model' (expert ownership) in
    ONE shard_map — Shardy rejects nesting a Manual-marked mesh, so the
    ep schedule replaces the generic token-local wrap instead of nesting
    inside it.  Expert weights enter sharded over 'model' on the expert
    axis.
    """
    from repro.sharding.ctx import current_mesh_context as _cmc
    _ctx = _cmc()
    m = cfg.moe
    B, S, D = x.shape
    E, k = m.num_experts, m.num_experts_per_tok
    n_model = mesh.shape["model"]
    n_data = mesh.shape["data"] if "data" in mesh.axis_names else 1
    # if 'data' is already manual (client shard_map), x is already local
    _bind = n_data > 1 and (_ctx is None or "data" not in _ctx.manual)
    e_local = E // n_model
    T = (B * S) // (n_data if _bind else 1)   # tokens per data shard
    capacity = max(1, int(T * k * CAPACITY_FACTOR / E))

    def body(xb_l, router, wg, wu, wd):
        xt_l = xb_l.reshape(-1, D)
        rank = jax.lax.axis_index("model")
        logits = xt_l.astype(jnp.float32) @ router
        gates = jax.nn.softmax(logits, axis=-1)
        top_v, top_i = _topk(gates, k)
        # choice-major flatten: match the GShard capacity-drop order
        # (see _moe_local_sort)
        e_flat = top_i.T.reshape(-1)
        w_flat = top_v.T.reshape(-1)
        tok_flat = jnp.tile(jnp.arange(T, dtype=jnp.int32), k)

        order = jnp.argsort(e_flat, stable=True)
        e_sorted = e_flat[order]
        starts = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
        pos = jnp.arange(T * k, dtype=jnp.int32) - starts[e_sorted].astype(jnp.int32)
        lo = rank * e_local
        mine = (e_sorted >= lo) & (e_sorted < lo + e_local) & (pos < capacity)
        slot = jnp.where(mine, (e_sorted - lo) * capacity + pos, e_local * capacity)

        buf = jnp.zeros((e_local * capacity + 1, D), xt_l.dtype)
        buf = buf.at[slot].set(xt_l[tok_flat[order]])
        expert_in = buf[:-1].reshape(e_local, capacity, D)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, wg))
        h = h * jnp.einsum("ecd,edf->ecf", expert_in, wu)
        expert_out = jnp.einsum("ecf,efd->ecd", h, wd)
        rows = jnp.concatenate([expert_out.reshape(e_local * capacity, D),
                                jnp.zeros((1, D), xt_l.dtype)], axis=0)
        routed = rows[slot] * w_flat[order, None].astype(xt_l.dtype)
        y_part = jnp.zeros((T, D), xt_l.dtype).at[tok_flat[order]].add(routed)
        y = jax.lax.psum(y_part, "model")          # the ONLY collective

        me = jnp.mean(gates, axis=0)
        frac = jnp.mean(jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32), axis=0)
        aux = jnp.sum(me * frac) * E * m.router_aux_loss_coef
        if bind_data:
            aux = jax.lax.pmean(aux, "data")
        return y.reshape(xb_l.shape), aux

    from repro.sharding.ctx import current_mesh_context, manual_axes as _man
    ctx = current_mesh_context()
    bind_data = ("data" in mesh.axis_names and n_data > 1
                 and (ctx is None or "data" not in ctx.manual))
    axes = {"model"} | ({"data"} if bind_data else set())
    smesh = mesh
    if ctx is not None and ctx.manual:
        from jax.sharding import AxisType
        smesh = mesh.abstract_mesh.update_axis_types(
            {a: AxisType.Manual for a in ctx.manual})

    def wrapped(xb_l, router, wg, wu, wd):
        with _man((set(ctx.manual) if ctx else set()) | axes):
            return body(xb_l, router, wg, wu, wd)

    x_spec = P("data") if bind_data else P()
    return jax.shard_map(
        wrapped, mesh=smesh, axis_names=axes,
        in_specs=(x_spec, P(), P("model"), P("model"), P("model")),
        out_specs=(x_spec, P()),
    )(x, params["router"], params["wg"], params["wu"], params["wd"])


# ---------------------------------------------------------------------------
# GShard one-hot dispatch (oracle / small models)
# ---------------------------------------------------------------------------


def _moe_local_einsum(params, xt, cfg: ModelConfig):
    m = cfg.moe
    T, D = xt.shape
    E, k = m.num_experts, m.num_experts_per_tok
    capacity = max(1, int(T * k * CAPACITY_FACTOR / E))

    logits = xt.astype(jnp.float32) @ params["router"]
    gates = jax.nn.softmax(logits, axis=-1)
    top_v, top_i = _topk(gates, k)

    dispatch = jnp.zeros((T, E, capacity), gates.dtype)
    combine = jnp.zeros((T, E, capacity), gates.dtype)
    counts = jnp.zeros((E,), jnp.int32)
    for j in range(k):  # unrolled: k is a small static int
        oh = jax.nn.one_hot(top_i[:, j], E, dtype=jnp.int32)
        pos = jnp.cumsum(oh, axis=0) - 1 + counts[None, :]
        within = (pos < capacity) & (oh > 0)
        pos_oh = jax.nn.one_hot(jnp.clip(pos, 0, capacity - 1), capacity, dtype=gates.dtype)
        slot = pos_oh * within[..., None].astype(gates.dtype)
        dispatch = dispatch + slot
        combine = combine + slot * top_v[:, j, None, None]
        counts = counts + jnp.sum(oh * within.astype(jnp.int32), axis=0)

    expert_in = jnp.einsum("tec,td->ecd", dispatch.astype(xt.dtype), xt)
    expert_out = _expert_ffn(params, expert_in)
    y = jnp.einsum("tec,ecd->td", combine.astype(xt.dtype), expert_out)

    me = jnp.mean(gates, axis=0)
    frac = jnp.mean(jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32), axis=0)
    aux = jnp.sum(me * frac) * E * m.router_aux_loss_coef
    return y, aux


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------


def moe_apply(params, x, cfg: ModelConfig):
    """Returns (y, aux_loss).  x: (B, S, D)."""
    m = cfg.moe
    B, S, D = x.shape
    ctx = current_mesh_context()
    dispatch = m.dispatch
    if dispatch == "ep":
        ok = (ctx is not None and "model" in ctx.mesh.axis_names
              and ctx.mesh.shape["model"] > 1
              and m.num_experts % ctx.mesh.shape["model"] == 0
              and "model" not in ctx.manual)
        if not ok:
            dispatch = "sort"  # no mesh / no model axis: fall back
    if dispatch == "ep":
        y, aux = _moe_expert_parallel(params, x, cfg, ctx.mesh)
        if m.num_shared_experts > 0:
            y = y + layers.mlp_apply(params["shared"], x)
        return y, aux
    local = _moe_local_sort if dispatch == "sort" else _moe_local_einsum
    wrap_data = (ctx is not None and "data" in ctx.mesh.axis_names
                 and ctx.mesh.shape["data"] > 1 and "data" not in ctx.manual
                 and B % ctx.mesh.shape["data"] == 0)
    f32_boundary = getattr(_TL_STATE, "off", False)

    if wrap_data:
        # run routing token-local: manual over 'data', experts stay auto.
        # Only the routed-path params enter the manual region (they are
        # data-replicated by the sharding rules); the shared expert stays
        # outside so it can be FSDP-sharded.
        mesh = ctx.mesh
        if ctx.manual:
            # nested inside the client shard_map: the inner shard_map must
            # see the already-manual axes marked Manual on its mesh
            from jax.sharding import AxisType
            mesh = ctx.mesh.abstract_mesh.update_axis_types(
                {a: AxisType.Manual for a in ctx.manual})
        from repro.sharding.ctx import manual_axes as _man
        bdt = jnp.float32 if f32_boundary else x.dtype
        routed_params = {k: params[k].astype(bdt) if f32_boundary else params[k]
                         for k in ("router", "wg", "wu", "wd")}

        def body(xb, p):
            xt = xb.reshape(-1, D)
            with _man(set(ctx.manual) | {"data"}):
                y, aux = local(p, xt, cfg)
            aux = jax.lax.pmean(aux, "data")
            return y.reshape(xb.shape), aux

        y, aux = jax.shard_map(
            body, mesh=mesh, axis_names={"data"},
            in_specs=(P("data"), P()), out_specs=(P("data"), P()),
        )(x.astype(bdt), routed_params)
        y = y.astype(x.dtype)
    else:
        y, aux = local(params, x.reshape(-1, D), cfg)
        y = y.reshape(B, S, D)

    if m.num_shared_experts > 0:
        y = y + layers.mlp_apply(params["shared"], x)
    return y, aux
