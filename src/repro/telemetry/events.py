"""The normalized round-event stream: one schema, two engines.

:func:`session_events` is the single history->event adapter.  It reads a
``SessionResult.history`` dict — whichever engine produced it — and
materializes a list of :class:`RoundEvent`, erasing the per-engine
buffer-layout differences at this boundary:

* membership / delivery masks become **index sets** (tuples of lane
  indices), so the loop engine's length-``n_contributors`` rows and the
  fleet engine's N-padded rows normalize to the same value;
* keys an engine or method legitimately lacks (no battery, no faults,
  dfl's accuracy-only history) become ``None`` / zero, identically for
  both engines;
* per-round wire bytes and energy are derived here, from
  ``SessionResult.model_bytes`` and the battery trajectory, rather than
  being one more ad-hoc history list each engine would have to keep in
  sync.

Because both engines run counter-based worlds (schedule / mobility /
faults), their event streams on the same world are equal field for
field: exactly on the structural fields, to tolerance on the float
metrics (:func:`compare_event_streams`).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# Event phases.  "round" = one executed (or faulted-out) protocol round;
# "stop" = the session's terminal event carrying the stop reason.
EVENT_PHASES: Tuple[str, ...] = ("round", "stop")


@dataclasses.dataclass(frozen=True)
class RoundEvent:
    """One normalized observation of one session round (or its stop).

    Optional fields are ``None`` when the concept does not exist for the
    run (no battery model, static membership, perfect links) — never
    silently zeroed, so absence is distinguishable from an observed 0.
    """

    round: int                    # 0-based round index; stop events use
                                  # the total executed-round count
    requester: int                # lane index of the requesting device
    phase: str                    # "round" | "stop"
    executed: bool                # False for battery-faulted skip rounds
    members: Optional[int]        # contributor count this round (mobility)
    member_set: Optional[Tuple[int, ...]]   # member lane indices (mobility)
    delivered: Optional[Tuple[int, ...]]    # lanes whose update arrived
                                            # (faults; None = perfect links)
    drops: float                  # dropped links this round
    retries: float                # retransmissions this round
    stale: float                  # stale (round r-1) deliveries this round
    battery: Optional[float]      # requester battery fraction after round
    accuracy: float               # requester test accuracy after round
    loss: Optional[float]         # mean fit loss (None if untracked)
    wire_bytes: int               # update bytes received = model_bytes
                                  # x delivered contributor count
    energy_j: Optional[float]     # battery-derived joules spent this
                                  # round (None for round 0 / no battery)
    stop_reason: Optional[str]    # protocol stop reason (stop phase only)
    # async-cadence observability (repro.core.cadence; None = lockstep
    # world).  Mapped HERE from the engines' round_clock/idle_steps
    # history buffers — the house rule stands: engines write history,
    # only this adapter emits events.
    clock: Optional[int] = None   # global event step this round ran at
    idle: Optional[float] = None  # idle event steps since the previous
                                  # executed round
    # Byzantine observability (repro.core.adversary / kernels.robust;
    # None = honest world / fedavg aggregation).  Index sets like
    # member_set/delivered, mapped from the corrupted_mask/clipped_mask
    # history rows — same house rule, same padding erasure.
    corrupted: Optional[Tuple[int, ...]] = None  # lanes whose delivered
                                                 # image was corrupted
    clipped: Optional[Tuple[int, ...]] = None    # lanes the robust
                                                 # aggregator norm-clipped


# name -> (allowed value types, allows None).  bool before int: a bool IS
# an int to isinstance, so fields typed int here explicitly reject bools.
ROUND_EVENT_FIELDS: Dict[str, tuple] = {
    "round": ((int,), False),
    "requester": ((int,), False),
    "phase": ((str,), False),
    "executed": ((bool,), False),
    "members": ((int,), True),
    "member_set": ((tuple,), True),
    "delivered": ((tuple,), True),
    "drops": ((float,), False),
    "retries": ((float,), False),
    "stale": ((float,), False),
    "battery": ((float,), True),
    "accuracy": ((float,), False),
    "loss": ((float,), True),
    "wire_bytes": ((int,), False),
    "energy_j": ((float,), True),
    "stop_reason": ((str,), True),
    "clock": ((int,), True),
    "idle": ((float,), True),
    "corrupted": ((tuple,), True),
    "clipped": ((tuple,), True),
}

# Fields compared exactly across engines; the rest are float metrics
# compared to tolerance (see compare_event_streams).  Lane clocks are
# exact by construction (counter-based cadence), so any drift is a bug.
_EXACT_FIELDS = ("round", "requester", "phase", "executed", "members",
                 "member_set", "delivered", "drops", "retries", "stale",
                 "wire_bytes", "stop_reason", "clock", "corrupted",
                 "clipped")


def _mask_to_set(row) -> Tuple[int, ...]:
    """A 0/1 mask row of any length -> the tuple of set lane indices.
    Erases the loop-vs-fleet padding asymmetry."""
    return tuple(i for i, v in enumerate(row) if float(v) > 0.5)


def session_events(session, *, requester: int = 0) -> List[RoundEvent]:
    """Adapt one SessionResult's history (either engine) to RoundEvents.

    ``requester`` is the lane index stamped on every event (the session
    itself does not know its position in the fleet).
    """
    history = (session.history_raw if hasattr(session, "history_raw")
               else session.history) or {}
    acc = [float(a) for a in history.get("accuracy", [])]
    rounds = len(acc)
    loss = history.get("loss")
    bat = history.get("battery")
    executed = history.get("round_executed")
    members = history.get("members")
    member_mask = history.get("member_mask")
    deliver_mask = history.get("deliver_mask")
    drops = history.get("drops")
    retries = history.get("retries")
    stale = history.get("stale")
    clock_h = history.get("round_clock")
    idle_h = history.get("idle_steps")
    corrupted_mask = history.get("corrupted_mask")
    clipped_mask = history.get("clipped_mask")
    model_bytes = int(getattr(session, "model_bytes", 0) or 0)
    capacity = (float(session.battery.capacity_j)
                if getattr(session, "battery", None) is not None else None)

    events: List[RoundEvent] = []
    for r in range(rounds):
        member_set = (_mask_to_set(member_mask[r])
                      if member_mask is not None else None)
        if members is not None:
            n_members: Optional[int] = int(members[r])
        elif member_set is not None:
            n_members = len(member_set)
        else:
            n_members = None
        delivered = (_mask_to_set(deliver_mask[r])
                     if deliver_mask is not None else None)
        if delivered is not None:
            n_recv = len(delivered)
        elif n_members is not None:
            n_recv = n_members
        else:
            n_recv = int(getattr(session, "n_contributors", 0))
        level = float(bat[r]) if bat else None
        if bat and capacity is not None and r > 0:
            # round 0's predecessor level is not in the history, so the
            # first round's energy is unobservable here (None), not 0
            energy: Optional[float] = max(
                0.0, (float(bat[r - 1]) - float(bat[r])) * capacity)
        else:
            energy = None
        events.append(RoundEvent(
            round=r, requester=requester, phase="round",
            executed=bool(float(executed[r]) > 0.5) if executed is not None
            else True,
            members=n_members, member_set=member_set, delivered=delivered,
            drops=float(drops[r]) if drops is not None else 0.0,
            retries=float(retries[r]) if retries is not None else 0.0,
            stale=float(stale[r]) if stale is not None else 0.0,
            battery=level, accuracy=acc[r],
            loss=float(loss[r]) if loss else None,
            wire_bytes=model_bytes * n_recv, energy_j=energy,
            stop_reason=None,
            clock=int(clock_h[r]) if clock_h is not None else None,
            idle=float(idle_h[r]) if idle_h is not None else None,
            corrupted=(_mask_to_set(corrupted_mask[r])
                       if corrupted_mask is not None else None),
            clipped=(_mask_to_set(clipped_mask[r])
                     if clipped_mask is not None else None)))
    events.append(RoundEvent(
        round=rounds, requester=requester, phase="stop", executed=True,
        members=None, member_set=None, delivered=None,
        drops=0.0, retries=0.0, stale=0.0,
        battery=float(bat[-1]) if bat else None,
        accuracy=acc[-1] if acc else 0.0, loss=None,
        wire_bytes=0, energy_j=None,
        stop_reason=str(session.stop_reason)))
    return events


def validate_events(events: Iterable[RoundEvent]) -> List[RoundEvent]:
    """Schema-check an event stream; raises ValueError on the first
    violation, returns the (listed) stream otherwise."""
    events = list(events)
    last_round: Dict[int, int] = {}
    stopped: set = set()
    for k, ev in enumerate(events):
        if not isinstance(ev, RoundEvent):
            raise ValueError(f"event {k}: not a RoundEvent: {type(ev)!r}")
        for name, (types, noneable) in ROUND_EVENT_FIELDS.items():
            val = getattr(ev, name)
            if val is None:
                if not noneable:
                    raise ValueError(f"event {k}: field {name} is None")
                continue
            if types == (int,) and isinstance(val, bool):
                raise ValueError(f"event {k}: field {name} is bool, not int")
            if not isinstance(val, types):
                raise ValueError(
                    f"event {k}: field {name} has type {type(val).__name__}, "
                    f"expected {'/'.join(t.__name__ for t in types)}")
        if ev.phase not in EVENT_PHASES:
            raise ValueError(f"event {k}: unknown phase {ev.phase!r}")
        if ev.phase == "stop" and ev.stop_reason is None:
            raise ValueError(f"event {k}: stop event without stop_reason")
        if ev.phase == "round" and ev.stop_reason is not None:
            raise ValueError(f"event {k}: round event with stop_reason")
        if ev.requester in stopped:
            raise ValueError(
                f"event {k}: requester {ev.requester} already stopped")
        prev = last_round.get(ev.requester)
        if prev is not None and ev.round != prev + 1:
            raise ValueError(
                f"event {k}: requester {ev.requester} round {ev.round} "
                f"does not follow round {prev}")
        if prev is None and ev.round != 0 and ev.phase == "round":
            raise ValueError(
                f"event {k}: requester {ev.requester} starts at round "
                f"{ev.round}, expected 0")
        last_round[ev.requester] = ev.round
        if ev.phase == "stop":
            stopped.add(ev.requester)
    return events


def _close(a: Optional[float], b: Optional[float], atol: float) -> bool:
    if a is None or b is None:
        return a is None and b is None
    return abs(a - b) <= atol


def compare_event_streams(a: Sequence[RoundEvent], b: Sequence[RoundEvent],
                          *, atol: float = 1e-4,
                          energy_atol: float = 1.0) -> List[str]:
    """Cross-engine stream equality: exact on structural fields, within
    ``atol`` on accuracy/loss/battery and ``energy_atol`` on energy
    (battery levels agree to ~1e-5 across engines, which a 40 kJ
    capacity amplifies to ~1 J of per-round energy slack).  Returns a
    list of human-readable mismatches — empty means equal.
    """
    diffs: List[str] = []
    if len(a) != len(b):
        diffs.append(f"stream length {len(a)} vs {len(b)}")
    for k, (ea, eb) in enumerate(zip(a, b)):
        for name in _EXACT_FIELDS:
            va, vb = getattr(ea, name), getattr(eb, name)
            if va != vb:
                diffs.append(f"event {k}: {name} {va!r} != {vb!r}")
        for name in ("accuracy", "loss", "battery", "idle"):
            if not _close(getattr(ea, name), getattr(eb, name), atol):
                diffs.append(f"event {k}: {name} {getattr(ea, name)} !~ "
                             f"{getattr(eb, name)} (atol={atol})")
        if not _close(ea.energy_j, eb.energy_j, energy_atol):
            diffs.append(f"event {k}: energy_j {ea.energy_j} !~ "
                         f"{eb.energy_j} (atol={energy_atol})")
    return diffs
