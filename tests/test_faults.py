"""Unreliable-link world (repro.core.faults): engine parity + semantics.

The fault model is WORLD state — a closed-form function of
(seed, round, requester, contributor) — so the loop engine (host-side,
concrete rounds) and the fleet engine (traced rounds inside one jit
program) must derive bit-identical outcomes: the same delivered masks,
the same retry/stale counts, the same graceful degradation, the same
retry-energy accounting through the one CostModel.
"""

import copy

import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.core import (EnFedConfig, EnFedSession, FaultConfig,
                        MobilityConfig, RequesterSpec, run_fleet)
from repro.core.battery import BatteryState
from repro.core.faults import blocked_mask, link_outcomes

from test_fleet_engine import BATCH, _build

# exercises all three failure modes within 4 rounds of the tiny problem
FC = FaultConfig(p_drop=0.6, p_stale=0.4, max_retries=1, release_after=2,
                 seed=3)


@pytest.fixture(scope="module")
def problem():
    return _build()


def _run_both(problem, cfg):
    task, own_train, own_test, fleet, states = problem
    loop = EnFedSession(task, own_train, own_test, fleet,
                        copy.deepcopy(states), cfg,
                        battery=BatteryState()).run()
    spec = RequesterSpec(own_train=own_train, own_test=own_test,
                         neighborhood=fleet,
                         contributor_states=copy.deepcopy(states),
                         battery=BatteryState())
    fl = run_fleet(task, [spec], cfg).sessions[0]
    return loop, fl


def _assert_fault_parity(loop, fl, atol_p=1e-5):
    assert fl.rounds == loop.rounds
    assert fl.stop_reason == loop.stop_reason
    # fault traces are exact integer world state: bitwise equality
    for k in ("drops", "retries", "stale"):
        np.testing.assert_array_equal(fl.history_raw[k], loop.history_raw[k])
    lm = np.stack(loop.history_raw["deliver_mask"])
    fm = np.stack(fl.history_raw["deliver_mask"])
    np.testing.assert_array_equal(fm[:, :lm.shape[1]], lm)
    assert not fm[:, lm.shape[1]:].any()          # padded lanes never deliver
    np.testing.assert_allclose(fl.history_raw["battery"], loop.history_raw["battery"],
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(fl.history_raw["accuracy"],
                               loop.history_raw["accuracy"], rtol=1e-5, atol=1e-6)
    lv, _ = ravel_pytree(loop.params)
    fv, _ = ravel_pytree(fl.params)
    np.testing.assert_allclose(np.asarray(fv), np.asarray(lv),
                               rtol=1e-4, atol=atol_p)
    # retry-transport accounting lands identically in both reports
    assert fl.report.e_comm == pytest.approx(loop.report.e_comm, abs=1e-3)
    assert fl.report.times.t_com == pytest.approx(loop.report.times.t_com,
                                                  abs=1e-4)


# ---------------------------------------------------------------------------
# config validation (fail fast at construction, not as NaNs mid-program)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw", [
    dict(p_drop=-0.1), dict(p_drop=1.5), dict(p_stale=2.0),
    dict(p_stale=-1e-9), dict(max_retries=-1), dict(release_after=-2),
])
def test_fault_config_validation(kw):
    with pytest.raises(ValueError):
        FaultConfig(**kw)


def test_fault_config_bounds_ok():
    fc = FaultConfig(p_drop=1.0, p_stale=0.0, max_retries=0)
    assert fc.attempts_max == 1


# ---------------------------------------------------------------------------
# world-state semantics
# ---------------------------------------------------------------------------


def test_link_outcomes_deterministic_and_counterbased():
    fc = FaultConfig(p_drop=0.5, p_stale=0.3, max_retries=2, seed=9)
    ids = np.arange(6, dtype=np.int32)
    d1, a1, s1 = (np.asarray(v) for v in link_outcomes(fc, 4, 100, ids))
    d2, a2, s2 = (np.asarray(v) for v in link_outcomes(fc, 4, 100, ids))
    np.testing.assert_array_equal(d1, d2)      # pure function of the counter
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(s1, s2)
    # attempts: delivered links used 1..attempts_max, failed links exhaust
    assert np.all(a1[d1] >= 1) and np.all(a1[d1] <= fc.attempts_max)
    assert np.all(a1[~d1] == fc.attempts_max)
    # stale only fires on delivered links
    assert not np.any(s1 & ~d1)
    # other requesters see independent link weather
    d3, _, _ = (np.asarray(v) for v in link_outcomes(fc, 4, 101, ids))
    assert not np.array_equal(d1, d3)


def test_blocked_mask_streaks():
    fc = FaultConfig(p_drop=0.9, max_retries=0, release_after=2, seed=1)
    ids = np.arange(8, dtype=np.int32)
    # no fault history before round 0 -> nothing blocked early
    assert not np.asarray(blocked_mask(fc, 0, 7, ids)).any()
    assert not np.asarray(blocked_mask(fc, 1, 7, ids)).any()
    for r in range(2, 6):
        d1 = np.asarray(link_outcomes(fc, r - 1, 7, ids)[0])
        d2 = np.asarray(link_outcomes(fc, r - 2, 7, ids)[0])
        np.testing.assert_array_equal(np.asarray(blocked_mask(fc, r, 7, ids)),
                                      ~d1 & ~d2)
    # release_after=0 never blocks
    fc0 = FaultConfig(p_drop=0.9, max_retries=0, release_after=0, seed=1)
    assert not np.asarray(blocked_mask(fc0, 5, 7, ids)).any()


# ---------------------------------------------------------------------------
# engine parity under faults
# ---------------------------------------------------------------------------


def test_engines_agree_static_faults(problem):
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=4, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1, faults=FC)
    loop, fl = _run_both(problem, cfg)
    _assert_fault_parity(loop, fl)
    # all three failure modes provably exercised in this world
    tot = {k: float(np.sum(loop.history_raw[k]))
           for k in ("drops", "retries", "stale")}
    assert tot["drops"] > 0 and tot["retries"] > 0 and tot["stale"] > 0, tot


def test_engines_agree_int8_wire_faults(problem):
    """Stale links replay the round-(r-1) WIRE image: under compress the
    second buffer stays int8-resident in both engines."""
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=4, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1, compress="int8",
                      faults=FC)
    loop, fl = _run_both(problem, cfg)
    _assert_fault_parity(loop, fl, atol_p=2e-2)   # tile-quantization bound


def test_engines_agree_mobility_plus_faults(problem):
    mob = MobilityConfig(arena_m=120.0, radio_range_m=60.0, leg_rounds=2,
                         seed=5)
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=4, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1, mobility=mob, faults=FC)
    loop, fl = _run_both(problem, cfg)
    _assert_fault_parity(loop, fl)
    # delivery implies membership that round, in both engines
    mm = np.stack(loop.history_raw["member_mask"])
    dm = np.stack(loop.history_raw["deliver_mask"])
    assert not np.any(dm.astype(bool) & ~mm.astype(bool))


def test_all_links_failed_falls_back_to_own_params(problem):
    """p_drop=1: nothing ever delivers — the session degrades to solo
    training (the empty-neighborhood fallback), identically in both
    engines, instead of aggregating zeros."""
    dead = FaultConfig(p_drop=1.0, max_retries=0, seed=0)
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=3, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=0, faults=dead)
    loop, fl = _run_both(problem, cfg)
    _assert_fault_parity(loop, fl)
    assert not np.stack(loop.history_raw["deliver_mask"]).any()
    assert all(v > 0 for v in loop.history_raw["accuracy"])   # still learning


def test_retry_energy_overhead_vs_clean_world(problem):
    """The faulty world costs strictly more transport energy/time than
    the clean one — drops and retries burn extra receive windows priced
    by CostModel.retry_energy."""
    base = EnFedConfig(desired_accuracy=0.99, max_rounds=4, epochs=1,
                       batch_size=BATCH, encrypt=False,
                       contributor_refresh_epochs=1)
    clean, _ = _run_both(problem, base)
    faulty, faulty_fl = _run_both(
        problem, EnFedConfig(desired_accuracy=0.99, max_rounds=4, epochs=1,
                             batch_size=BATCH, encrypt=False,
                             contributor_refresh_epochs=1, faults=FC))
    extra = float(np.sum(faulty.history_raw["drops"])
                  + np.sum(faulty.history_raw["retries"]))
    assert extra > 0
    assert faulty.report.e_comm > clean.report.e_comm
    assert faulty.report.times.t_com > clean.report.times.t_com
    assert np.isfinite(faulty.report.e_tot)
    assert faulty_fl.report.e_comm > clean.report.e_comm
