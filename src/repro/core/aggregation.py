"""Model-update aggregation (paper eq. 14: FedAvg over contributors).

Two forms:

* **List form** (`fedavg`, `masked_fedavg`) — used by the fleet
  simulator, where contributor updates arrive as a list of pytrees
  (optionally decrypted from the AES transport).  Eq. (14):
  ``w <- (1/N_c) * sum_j w_j`` with optional per-contributor weights
  (data-size weighting) and the participation mask from the
  incentive/contract layer.

* **Stacked form** (`masked_weighted_mean_stacked`) — jit-friendly, a
  single pytree whose leaves carry a leading contributor axis; used by
  the vmapped-clients federated trainer.

The distributed (mesh) form lives in ``repro.core.topology``.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_weighted_mean


def fedavg(updates: Sequence, weights: Optional[Sequence[float]] = None):
    """Paper eq. (14). ``weights`` default to uniform (1/N_c each)."""
    if not updates:
        raise ValueError("fedavg needs at least one update")
    if weights is None:
        weights = [1.0] * len(updates)
    return tree_weighted_mean(list(updates), jnp.asarray(weights, jnp.float32))


def masked_fedavg(updates: Sequence, mask: Sequence[float],
                  weights: Optional[Sequence[float]] = None):
    """FedAvg over the contributors selected by the participation mask."""
    mask = jnp.asarray(mask, jnp.float32)
    if weights is None:
        weights = jnp.ones_like(mask)
    else:
        weights = jnp.asarray(weights, jnp.float32)
    return tree_weighted_mean(list(updates), mask * weights)


def masked_weighted_mean_stacked(stacked, mask, weights=None):
    """Leaves of ``stacked`` have shape (N_c, ...). Fully jit-safe.

    Equivalent to `masked_fedavg` but over a stacked axis — this is the
    form the Pallas ``fedavg`` kernel implements for the TPU hot path.
    """
    mask = jnp.asarray(mask, jnp.float32)
    w = mask if weights is None else mask * jnp.asarray(weights, jnp.float32)
    denom = jnp.sum(w) + 1e-9

    def _avg(leaf):
        wb = w.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return (jnp.sum(leaf.astype(jnp.float32) * wb, axis=0) / denom).astype(leaf.dtype)

    return jax.tree_util.tree_map(_avg, stacked)


def delta(new_params, old_params):
    """Model update as a delta (what contributors actually transmit when
    the requester already holds a base model)."""
    return jax.tree_util.tree_map(jnp.subtract, new_params, old_params)


def apply_delta(params, d, scale: float = 1.0):
    return jax.tree_util.tree_map(lambda p, u: p + scale * u, params, d)
