"""Paper Figs 4-7: accuracy / training time / energy vs number of
contributors (2..5), plus the local-model loss trajectory."""

from __future__ import annotations

from benchmarks._harness import build_scenario, run_enfed


def run(verbose: bool = True):
    rows = []
    for ds_id, dataset in (("Dataset1", "calories"), ("Dataset2", "har")):
        sc = build_scenario(dataset, "lstm")
        for n_c in (2, 3, 4, 5):
            res = run_enfed(sc, n_contrib=n_c)
            rows.append((f"figs4-6/{ds_id}/contrib{n_c}", res.accuracy,
                         res.report.t_train, res.report.e_tot))
            if verbose:
                print(f"[figs4-6/{ds_id}] N_c={n_c}: acc={res.accuracy:.3f} "
                      f"T={res.report.t_train:.2f}s E={res.report.e_tot:.1f}J "
                      f"rounds={res.rounds}")
        # Fig 7: loss trajectory with 5 contributors
        res = run_enfed(sc, n_contrib=5)
        losses = ", ".join(f"{l:.3f}" for l in res.history_raw["loss"])
        if verbose:
            print(f"[fig7/{ds_id}] local-model loss per round: [{losses}]")
        rows.append((f"fig7/{ds_id}/final_loss", res.history_raw["loss"][-1],
                     res.report.t_train, res.report.e_tot))
    return rows


if __name__ == "__main__":
    run()
