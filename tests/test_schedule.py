"""The derived minibatch schedule (repro.core.schedule) is the parity
keystone of PR 2: both engines draw batches from the same counter-based
jax.random derivation, so these tests pin down (a) prefix stability —
the property that lets one traced fleet program serve shards of
different sizes — and (b) loop-plan == fleet-plan equality under
padding, including the sub-batch single-padded-step fallback."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import schedule


def test_index_scores_prefix_stable():
    key = jax.random.PRNGKey(7)
    s_small = np.asarray(schedule.index_scores(key, 33))
    s_big = np.asarray(schedule.index_scores(key, 257))
    np.testing.assert_array_equal(s_small, s_big[:33])


def test_epoch_scores_depend_on_seed_and_epoch():
    a = np.asarray(schedule.epoch_scores(0, 3, 64))
    b = np.asarray(schedule.epoch_scores(1, 3, 64))
    assert a.shape == (3, 64)
    assert not (a == b).any(axis=1).all(), "different seeds, different orders"
    assert not (a[0] == a[1]).all(), "different epochs, different orders"


def test_epoch_scores_traced_seed_matches_python_seed():
    """The fleet engine derives seeds as traced scalars inside its round
    loop; the loop engine passes python ints.  Same value, same scores."""
    traced = jax.jit(lambda s: schedule.epoch_scores(s, 2, 40))(jnp.int32(13))
    host = schedule.epoch_scores(13, 2, 40)
    np.testing.assert_array_equal(np.asarray(traced), np.asarray(host))


@pytest.mark.parametrize("n,n_pad", [(64, 64), (64, 100), (37, 96), (7, 96), (7, 7)])
def test_plan_padded_matches_unpadded(n, n_pad):
    """The fleet evaluates the plan over a padded shard with a traced
    ``n``; restricted to usable positions it must equal the loop
    engine's unpadded plan exactly (same indices, same weights)."""
    batch, epochs = 16, 3
    steps_loop = schedule.fit_steps(n, batch)
    steps_fleet = max(steps_loop, (n_pad // batch) or 1) + 1  # over-provisioned
    idx_l, w_l = (np.asarray(a) for a in schedule.minibatch_plan(
        5, epochs=epochs, n=n, batch=batch))
    scores = schedule.epoch_scores(5, epochs, n_pad)
    idx_f, w_f = (np.asarray(a) for a in schedule.plan_from_scores(
        scores, jnp.int32(n), batch, steps_fleet))
    assert idx_l.shape == (epochs, steps_loop, batch)
    assert idx_f.shape == (epochs, steps_fleet, batch)
    np.testing.assert_array_equal(idx_f[:, :steps_loop], idx_l)
    np.testing.assert_array_equal(w_f[:, :steps_loop], w_l)
    assert (w_f[:, steps_loop:] == 0).all(), "over-provisioned steps are masked"
    assert (idx_f[:, steps_loop:] == 0).all()


def test_plan_is_a_permutation_of_full_batches():
    idx, w = (np.asarray(a) for a in schedule.minibatch_plan(
        0, epochs=2, n=48, batch=16))
    assert idx.shape == (2, 3, 16) and (w == 1.0).all()
    for e in range(2):
        seen = idx[e].ravel()
        assert len(set(seen.tolist())) == 48, "each epoch visits each sample once"
        assert seen.max() < 48


def test_sub_batch_plan_single_padded_step():
    """n < batch: one step, first n slots carry the n samples (each
    exactly once), the rest are zero-weight padding."""
    idx, w = (np.asarray(a) for a in schedule.minibatch_plan(
        3, epochs=2, n=5, batch=16))
    assert idx.shape == (2, 1, 16)
    assert (w[:, :, :5] == 1.0).all() and (w[:, :, 5:] == 0.0).all()
    for e in range(2):
        assert sorted(idx[e, 0, :5].tolist()) == [0, 1, 2, 3, 4]
        assert (idx[e, 0, 5:] == 0).all()


def test_drop_last_truncation():
    """n not a batch multiple: (n // batch) * batch samples are used,
    mirroring the loop engine's historical drop-last behaviour."""
    idx, w = (np.asarray(a) for a in schedule.minibatch_plan(
        1, epochs=1, n=50, batch=16))
    assert idx.shape == (1, 3, 16)
    assert (w == 1.0).all()
    assert len(set(idx[0].ravel().tolist())) == 48  # 48 distinct samples


def test_supervised_task_fit_consumes_derived_plan():
    """SupervisedTask.fit batches come from minibatch_plan: training with
    a manually-applied plan reproduces fit() exactly."""
    from jax.flatten_util import ravel_pytree

    from repro.core import SupervisedTask
    from repro.models import MLPClassifier, MLPClassifierConfig

    rng = np.random.default_rng(0)
    x = rng.normal(size=(70, 8)).astype(np.float32)
    y = rng.integers(0, 5, 70).astype(np.int32)
    task = SupervisedTask(MLPClassifier(MLPClassifierConfig(8, (16,), 5)), lr=1e-2)
    p0 = task.init(seed=0)
    fitted, _ = task.fit(p0, (x, y), epochs=2, batch_size=32, seed=9)

    idx, w = (np.asarray(a) for a in schedule.minibatch_plan(
        9, epochs=2, n=70, batch=32))
    params, opt_state = p0, task._opt.init(p0)
    for e in range(idx.shape[0]):
        for s in range(idx.shape[1]):
            sel = idx[e, s]
            params, opt_state, _ = task._fit_step(params, opt_state,
                                                  x[sel], y[sel], w[e, s])
    want, _ = ravel_pytree(fitted)
    got, _ = ravel_pytree(params)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_tree_ravel_unravel_roundtrip():
    """The fleet engine's flat round state: ravel once, unravel lanes."""
    from repro.utils.tree import tree_ravel, tree_unravel

    rng = np.random.default_rng(1)
    tree = {"w": jnp.asarray(rng.normal(size=(4, 3, 6, 2)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(4, 3, 5)).astype(np.float32))}
    flat, spec = tree_ravel(tree, batch_ndim=2)
    assert flat.shape == (4, 3, 6 * 2 + 5)
    back = tree_unravel(spec, flat)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))
    np.testing.assert_array_equal(np.asarray(back["b"]), np.asarray(tree["b"]))
    # per-lane view: unravel a single (P,) row with the same spec
    lane = tree_unravel(spec, flat[2, 1])
    np.testing.assert_array_equal(np.asarray(lane["w"]), np.asarray(tree["w"][2, 1]))
