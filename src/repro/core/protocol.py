"""Shared EnFed protocol-phase vocabulary (Algorithm 1).

Both execution engines speak this vocabulary:

* ``repro.core.rounds.EnFedSession`` — the **loop engine**: one Python
  iteration per round, one ``task.fit`` dispatch per contributor.  It is
  the readable reference oracle, faithful to Algorithm 1 line by line.
* ``repro.core.fleet`` — the **fleet engine**: many concurrent requester
  sessions compiled into a single jit program (``vmap`` over requesters,
  ``lax.scan`` over rounds, masked stopping).

Keeping the phase names, stop reasons, and per-round aggregation weights
in one module is what makes the two engines provably equivalent: the
parity tests in ``tests/test_fleet_engine.py`` assert the fleet engine
reproduces the loop engine phase for phase.

Under an async-cadence world (``repro.core.cadence``) the engines loop
over GLOBAL EVENT STEPS rather than rounds: the world-keyed phases
(RENEGOTIATE's mobility kinematics, DELIVER's fault weather) derive
their counter-based state from the event step, while the protocol-keyed
phases (FIT's minibatch schedule, the round budget) key on the lane's
own round clock, which advances only on its tick steps.  A contributor
that does not tick skips REFRESH — its resident wire image is collected
and aggregated as-is (the straggler path).  ``cadence=None`` collapses
event step == round everywhere, bit-for-bit.
"""

from __future__ import annotations

import enum
from typing import Sequence

import numpy as np


class Phase(enum.Enum):
    """The protocol phases of Algorithm 1 / eq. (4), in execution order."""

    HANDSHAKE = "handshake"    # contract selection + AES key exchange
    RENEGOTIATE = "renegotiate"  # mobility: per-round contract churn
    #   (release out-of-range / battery-floored members, sign arrivals;
    #   repro.core.mobility.membership_step, identical in both engines)
    COLLECT = "collect"        # receive (and decrypt) contributor updates
    DELIVER = "deliver"        # faults: which collected updates actually
    #   arrived this round (drops / bounded retries / stale images;
    #   repro.core.faults.link_outcomes, identical in both engines) —
    #   the delivered mask feeds AGGREGATE's existing weight-mask path.
    #   Fault x adversary ordering pin (repro.core.adversary): the
    #   stale-delivery substitution resolves FIRST, then corruption
    #   applies to whatever image is actually delivered, with its draw
    #   keyed on the DELIVERING round — a Byzantine contributor poisons
    #   the bytes leaving its radio this round, whether those bytes are
    #   its fresh image or the round-(r-1) snapshot.  Both engines
    #   corrupt at this exact point (loop: inside _collect_update after
    #   the stale select; fleet: on the delivered buffer after the
    #   stale_sel where), so the order cannot diverge — pinned by
    #   tests/test_adversary.py.
    AGGREGATE = "aggregate"    # eq. (14) masked FedAvg — or, under
    #   robust != "none", the Byzantine-robust statistic over the same
    #   masked lane buffer (repro.kernels.robust), with
    #   staleness-decayed weights (decayed_round_weights below)
    FIT = "fit"                # requester personalizes on its own shard
    SCORE = "score"            # evaluate against the desired accuracy A_A
    ACCOUNT = "account"        # eq. (4)-(7) cost roll-up + battery discharge
    REFRESH = "refresh"        # contributors keep training between rounds


ROUND_PHASES = (Phase.RENEGOTIATE, Phase.COLLECT, Phase.DELIVER,
                Phase.AGGREGATE, Phase.FIT, Phase.SCORE, Phase.ACCOUNT,
                Phase.REFRESH)

# ---------------------------------------------------------------------------
# Method variants: every method the fleet engine can trace is a subset of
# the same phase vocabulary.  ``method_phases(name)`` is the per-method
# phase mask — the fleet engine consults it at trace time (the method is
# a static jit argument) to decide which protocol steps are live, so
# "dfl" and "cfl" are literally the enfed round body with phases masked
# off, not separate programs:
#
# * ``enfed`` — the full Algorithm-1 round (requester-side aggregation,
#   mobility renegotiation, contributor refresh, battery accounting).
# * ``dfl``   — decentralized FedAvg: every client fits its own shard
#   from its own params, then gossip-mixes over the mesh/ring topology
#   (AGGREGATE is the mixing step).  No renegotiate/refresh/battery.
#   DELIVER is enfed-only: the baselines' loop oracles define their
#   convergence semantics, so a FaultConfig prices their retry transport
#   in the cost domain without perturbing aggregation.
# * ``cfl``   — centralized FedAvg: every client fits from the shared
#   global, a server-side data-size-weighted FedAvg replaces it
#   (AGGREGATE is server-side).  No renegotiate/refresh/battery.
#
# The loop learners (``repro.core.federated.CFLLearner`` /
# ``DFLLearner.run_config``) are the parity oracles for the two baseline
# variants, exactly as ``EnFedSession`` is for enfed.
FLEET_METHODS = ("enfed", "dfl", "cfl")

_METHOD_PHASES = {
    "enfed": ROUND_PHASES,      # includes Phase.DELIVER (fault masking)
    "dfl": (Phase.COLLECT, Phase.AGGREGATE, Phase.FIT, Phase.SCORE,
            Phase.ACCOUNT),
    "cfl": (Phase.COLLECT, Phase.AGGREGATE, Phase.FIT, Phase.SCORE,
            Phase.ACCOUNT),
}


def method_phases(method: str):
    """The protocol phases live for ``method`` (trace-time phase mask)."""
    if method not in _METHOD_PHASES:
        raise ValueError(
            f"unknown fleet method {method!r}; one of {FLEET_METHODS}")
    return _METHOD_PHASES[method]

# Stop reasons, encoded as small ints so the fleet engine can carry them
# as traced per-requester state.  Order encodes check priority: the loop
# engine tests accuracy before battery, so does the fleet engine.
STOP_MAX_ROUNDS = 0
STOP_ACCURACY = 1
STOP_BATTERY = 2

STOP_REASONS = ("max_rounds", "accuracy_reached", "battery_low")


def stop_reason_name(code: int) -> str:
    return STOP_REASONS[int(code)]


def round_weights(n_contrib: int, strategy=None) -> np.ndarray:
    """Per-round aggregation weights over the *signed* contributors.

    The strategy (``repro.core.topology.AggregationStrategy``) decides
    which of the signed contributors feed eq. (14) each round; see
    :func:`repro.core.topology.contributor_round_mask`.  Both engines
    call this function so their aggregation weights are identical by
    construction.
    """
    from repro.core.topology import contributor_round_mask

    if strategy is None:
        return np.ones((n_contrib,), np.float32)
    return contributor_round_mask(n_contrib, strategy)


def decayed_round_weights(weights, lag, gamma: float):
    """Staleness-decayed aggregation weights: ``w * gamma**lag``.

    ``weights`` (..., N) fp32, ``lag`` (..., N) int rounds-behind per
    contributor image (``repro.core.cadence.image_lag`` for the stride
    lag, +1 for a fault-stale delivery), ``gamma`` the
    ``EnFedConfig.staleness_gamma`` knob.  The decay keys on the LANE
    CLOCK's view of the image — a pure closed form, zero new carried
    state.  One jnp float32 expression shared verbatim by both engines,
    so the decayed weights (and everything downstream of eq. (14)) are
    bit-identical by construction.  ``gamma == 1.0`` is the identity and
    both engines skip the call entirely.
    """
    import jax.numpy as jnp

    w = jnp.asarray(weights, jnp.float32)
    return w * jnp.power(jnp.float32(gamma),
                         jnp.asarray(lag, jnp.int32).astype(jnp.float32))
