"""HLO-derived statistics for the roofline analysis.

``collective_bytes`` parses the post-SPMD (per-partition) HLO text and
sums the output-shape bytes of every collective op, bucketed by kind.
Shapes in the partitioned module are per-device, so the totals
approximate the bytes crossing each device's ICI links per step (the
ring-algorithm factor ~2x for all-reduce is applied in the roofline
calculation, not here).

``cost_summary`` normalizes ``compiled.cost_analysis()`` across jax
versions (dict or list-of-dicts).
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-reduce.5 = bf16[1024,512]{1,0} all-reduce(...)
#       %ag = (f32[8,4]{1,0}, f32[8,4]{1,0}) all-gather-start(...)
_OP_RE = re.compile(
    r"=\s*([^=\n]*?)\s*(" + "|".join(_COLLECTIVES) + r")(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes by collective kind + op counts."""
    by_kind = defaultdict(int)
    counts = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        shape_text, kind = m.group(1), m.group(2)
        # async pairs appear as -start/-done; the -done's operand is the
        # -start tuple — count only ops whose text isn't a -done
        tail = hlo_text[m.end(2):m.end(2) + 6]
        if tail.startswith("-done"):
            continue
        # async -start ops carry an (input, output) staging tuple: halve
        factor = 0.5 if tail.startswith("-start") else 1.0
        by_kind[kind] += int(_shape_bytes(shape_text) * factor)
        counts[kind] += 1
    out = {f"{k}_bytes": float(v) for k, v in by_kind.items()}
    out.update({f"{k}_count": float(v) for k, v in counts.items()})
    out["total_collective_bytes"] = float(sum(by_kind.values()))
    return out


def cost_summary(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if not isinstance(ca, dict):
        return {}
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def memory_summary(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    if out:
        out["total_bytes_per_device"] = (
            out.get("argument_size_in_bytes", 0.0)
            + out.get("output_size_in_bytes", 0.0)
            + out.get("temp_size_in_bytes", 0.0)
            - out.get("alias_size_in_bytes", 0.0))
    return out
