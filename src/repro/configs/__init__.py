"""Architecture registry: ``--arch <id>`` -> ModelConfig.

Ten assigned architectures (each cites its source in its module) plus
the EnFed paper's own HAR classifiers.
"""

from __future__ import annotations

from typing import Dict

from repro.models.config import ModelConfig
from repro.models.classifiers import LSTMClassifierConfig, MLPClassifierConfig

from repro.configs.recurrentgemma_2b import CONFIG as RECURRENTGEMMA_2B
from repro.configs.h2o_danube_1_8b import CONFIG as H2O_DANUBE_1_8B
from repro.configs.internlm2_20b import CONFIG as INTERNLM2_20B
from repro.configs.qwen2_5_3b import CONFIG as QWEN2_5_3B
from repro.configs.xlstm_125m import CONFIG as XLSTM_125M
from repro.configs.minitron_8b import CONFIG as MINITRON_8B
from repro.configs.seamless_m4t_large_v2 import CONFIG as SEAMLESS_M4T_LARGE_V2
from repro.configs.llava_next_mistral_7b import CONFIG as LLAVA_NEXT_MISTRAL_7B
from repro.configs.deepseek_v3_671b import CONFIG as DEEPSEEK_V3_671B
from repro.configs.granite_moe_1b_a400m import CONFIG as GRANITE_MOE_1B_A400M

ARCHS: Dict[str, ModelConfig] = {
    c.name: c for c in [
        RECURRENTGEMMA_2B,
        H2O_DANUBE_1_8B,
        INTERNLM2_20B,
        QWEN2_5_3B,
        XLSTM_125M,
        MINITRON_8B,
        SEAMLESS_M4T_LARGE_V2,
        LLAVA_NEXT_MISTRAL_7B,
        DEEPSEEK_V3_671B,
        GRANITE_MOE_1B_A400M,
    ]
}

# the EnFed paper's own models (Table III)
PAPER_LSTM = LSTMClassifierConfig(input_dim=6, seq_len=64, hidden=64, num_classes=6)
PAPER_MLP = MLPClassifierConfig(input_dim=8, hidden=(64, 32), num_classes=5)

# input shapes assigned to this paper
INPUT_SHAPES = {
    "train_4k": {"seq_len": 4096, "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32_768, "global_batch": 32, "kind": "prefill"},
    "decode_32k": {"seq_len": 32_768, "global_batch": 128, "kind": "decode"},
    "long_500k": {"seq_len": 524_288, "global_batch": 1, "kind": "decode"},
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)


def shape_supported(cfg: ModelConfig, shape_name: str) -> bool:
    """long_500k only runs for sub-quadratic-decode architectures
    (DESIGN.md §Arch-applicability); everything else runs all shapes."""
    if shape_name == "long_500k":
        return cfg.supports_long_decode
    return True
