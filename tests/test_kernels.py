"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept across shapes and dtypes — including non-multiple-of-block shapes
for every kernel (fedavg TILE_L, lstm_cell batch/hidden tiles, aes_ctr
BLOCK_TILE, quantize TILE)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# fedavg
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,l", [(1, 17), (3, 2048), (5, 3001), (16, 777), (64, 4096)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_fedavg_matches_ref(n, l, dtype):
    from repro.kernels.fedavg.kernel import fedavg_pallas
    from repro.kernels.fedavg.ref import fedavg_ref
    u = jnp.asarray(RNG.normal(size=(n, l)).astype(dtype))
    w = jnp.asarray((RNG.random(n) > 0.3).astype(np.float32) * RNG.random(n).astype(np.float32))
    got = fedavg_pallas(u, w)
    want = fedavg_ref(u, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_fedavg_all_masked_is_zero():
    from repro.kernels.fedavg.kernel import fedavg_pallas
    u = jnp.asarray(RNG.normal(size=(4, 100)).astype(np.float32))
    out = fedavg_pallas(u, jnp.zeros((4,), jnp.float32))
    assert np.allclose(np.asarray(out), 0.0)


def test_fedavg_tree_roundtrip():
    from repro.kernels.fedavg.ops import fedavg_tree
    tree = {"a": jnp.asarray(RNG.normal(size=(3, 8, 4)).astype(np.float32)),
            "b": jnp.asarray(RNG.normal(size=(3, 5)).astype(np.float32))}
    w = jnp.asarray([1.0, 1.0, 1.0])
    avg = fedavg_tree(tree, w)
    np.testing.assert_allclose(np.asarray(avg["a"]),
                               np.asarray(tree["a"]).mean(0), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("r,n,l", [(1, 1, 17), (4, 3, 2048), (8, 5, 3001),
                                   (64, 4, 777)])
def test_fedavg_batched_matches_ref(r, n, l):
    """The fleet engine's hot path: every session's eq. (14) in one
    launch, including padded (zero-weight) contributor slots and
    non-multiple-of-TILE_L lengths."""
    from repro.kernels.fedavg.kernel import fedavg_batched_pallas
    from repro.kernels.fedavg.ref import fedavg_batched_ref
    u = jnp.asarray(RNG.normal(size=(r, n, l)).astype(np.float32))
    w = jnp.asarray((RNG.random((r, n)) > 0.3).astype(np.float32)
                    * RNG.random((r, n)).astype(np.float32))
    got = fedavg_batched_pallas(u, w)
    want = fedavg_batched_ref(u, w)
    assert got.shape == (r, l)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fedavg_batched_each_session_independent():
    """Row i of the batched kernel == the single-session kernel on row i."""
    from repro.kernels.fedavg.kernel import fedavg_batched_pallas, fedavg_pallas
    u = jnp.asarray(RNG.normal(size=(3, 4, 513)).astype(np.float32))
    w = jnp.asarray(RNG.random((3, 4)).astype(np.float32))
    got = fedavg_batched_pallas(u, w)
    for i in range(3):
        np.testing.assert_allclose(np.asarray(got[i]),
                                   np.asarray(fedavg_pallas(u[i], w[i])),
                                   rtol=1e-5, atol=1e-5)


def test_fedavg_tree_batched_matches_list_form():
    """fedavg_tree_batched (fleet engine) == masked_fedavg per session."""
    from repro.core.aggregation import masked_fedavg
    from repro.kernels.fedavg.ops import fedavg_tree_batched
    R, N = 3, 4
    trees = [[{"w": jnp.asarray(RNG.normal(size=(6, 3)).astype(np.float32)),
               "b": jnp.asarray(RNG.normal(size=(5,)).astype(np.float32))}
              for _ in range(N)] for _ in range(R)]
    w = np.zeros((R, N), np.float32)
    w[:, :2] = 1.0  # only the first two contributors participate
    stacked = jax.tree_util.tree_map(
        lambda *leaves: jnp.stack(leaves),
        *[jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *row) for row in trees])
    got = fedavg_tree_batched(stacked, jnp.asarray(w))
    for i in range(R):
        want = masked_fedavg(trees[i], list(w[i]))
        np.testing.assert_allclose(np.asarray(got["w"][i]), np.asarray(want["w"]),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(got["b"][i]), np.asarray(want["b"]),
                                   rtol=1e-5, atol=1e-6)


def test_fedavg_batched_on_preraveled_flat_buffer():
    """The fleet engine's PR 2 hot path: contributor params raveled ONCE
    (tree_ravel) into the (R, N, P) round-state buffer, the batched
    kernel launched directly on it — interpret mode vs the jnp oracle,
    off-tile P (not a TILE_L multiple) and the N=1 edge case."""
    from repro.kernels.fedavg.kernel import fedavg_batched_pallas
    from repro.kernels.fedavg.ref import fedavg_batched_ref
    from repro.utils.tree import tree_ravel, tree_unravel

    for r, n in [(3, 4), (2, 1)]:  # N=1: single-contributor sessions
        tree = {"w": jnp.asarray(RNG.normal(size=(r, n, 37, 19)).astype(np.float32)),
                "b": jnp.asarray(RNG.normal(size=(r, n, 300)).astype(np.float32))}
        flat, spec = tree_ravel(tree, batch_ndim=2)
        assert flat.shape[-1] % 2048 != 0, "off-tile by construction"
        w = jnp.asarray(RNG.random((r, n)).astype(np.float32) + 0.1)
        got = fedavg_batched_pallas(flat, w, interpret=True)
        want = fedavg_batched_ref(flat, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)
        # unravel of the aggregate == leafwise weighted mean of the tree
        agg = tree_unravel(spec, got)
        for key in ("w", "b"):
            leaf = np.asarray(tree[key], np.float32)
            wn = np.asarray(w)[..., None]
            while wn.ndim < leaf.ndim:
                wn = wn[..., None]
            want_leaf = (leaf * wn).sum(1) / np.asarray(w).sum(1).reshape(
                (r,) + (1,) * (leaf.ndim - 2))
            np.testing.assert_allclose(np.asarray(agg[key]), want_leaf,
                                       rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("r,n,lp", [(1, 1, 1024), (3, 4, 3072), (17, 5, 2048),
                                    (64, 3, 1024)])
def test_fedavg_batched_q8_matches_ref(r, n, lp):
    """The fused dequant->fedavg kernel vs the jnp oracle — including
    R not a multiple of the requester tile (padded rows) and N=1."""
    from repro.kernels.fedavg.kernel import fedavg_batched_q8_pallas
    from repro.kernels.fedavg.ref import fedavg_batched_q8_ref
    from repro.kernels.quantize.ref import quantize_batched_ref
    u = jnp.asarray(RNG.normal(size=(r * n, lp)).astype(np.float32))
    q, s = quantize_batched_ref(u)
    q, s = q.reshape(r, n, lp), s.reshape(r, n, -1)
    w = jnp.asarray((RNG.random((r, n)) > 0.3).astype(np.float32)
                    * RNG.random((r, n)).astype(np.float32))
    got = fedavg_batched_q8_pallas(q, s, w, interpret=True)
    want = fedavg_batched_q8_ref(q, s, w)
    assert got.shape == (r, lp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_fedavg_batched_q8_rejects_off_tile():
    from repro.kernels.fedavg.kernel import fedavg_batched_q8_pallas
    q = jnp.zeros((2, 3, 1000), jnp.int8)
    s = jnp.ones((2, 3, 1), jnp.float32)
    with pytest.raises(ValueError):
        fedavg_batched_q8_pallas(q, s, jnp.ones((2, 3), jnp.float32))


def test_fedavg_batched_r_tiling_matches_per_session():
    """R-tiled batched kernel row i == the single-session kernel on row
    i, across an R that exercises requester-tile padding."""
    from repro.kernels.fedavg.kernel import fedavg_batched_pallas, fedavg_pallas
    r = 7
    u = jnp.asarray(RNG.normal(size=(r, 3, 513)).astype(np.float32))
    w = jnp.asarray(RNG.random((r, 3)).astype(np.float32))
    got = fedavg_batched_pallas(u, w)
    for i in range(r):
        np.testing.assert_allclose(np.asarray(got[i]),
                                   np.asarray(fedavg_pallas(u[i], w[i])),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# lstm_cell
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,F,H", [(1, 3, 16), (8, 10, 32), (32, 6, 64),
                                   (100, 7, 130), (128, 128, 128), (129, 16, 200)])
def test_lstm_cell_matches_ref(B, F, H):
    from repro.kernels.lstm_cell.kernel import lstm_cell_pallas
    from repro.kernels.lstm_cell.ref import lstm_cell_ref
    x = jnp.asarray(RNG.normal(size=(B, F)).astype(np.float32))
    h = jnp.asarray(RNG.normal(size=(B, H)).astype(np.float32))
    c = jnp.asarray(RNG.normal(size=(B, H)).astype(np.float32))
    wx = jnp.asarray(RNG.normal(size=(F, 4 * H)).astype(np.float32) * 0.1)
    wh = jnp.asarray(RNG.normal(size=(H, 4 * H)).astype(np.float32) * 0.1)
    b = jnp.asarray(RNG.normal(size=(4 * H,)).astype(np.float32) * 0.1)
    h1, c1 = lstm_cell_pallas(x, h, c, wx, wh, b)
    h2, c2 = lstm_cell_ref(x, h, c, wx, wh, b)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-5, atol=1e-5)


def test_lstm_classifier_pallas_parity():
    from repro.models import LSTMClassifier, LSTMClassifierConfig
    ref = LSTMClassifier(LSTMClassifierConfig(6, 16, hidden=32, num_classes=6, cell="ref"))
    pal = LSTMClassifier(LSTMClassifierConfig(6, 16, hidden=32, num_classes=6, cell="pallas"))
    p = ref.init(jax.random.PRNGKey(0))
    x = jnp.asarray(RNG.normal(size=(8, 16, 6)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(ref.forward(p, x)),
                               np.asarray(pal.forward(p, x)), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# quantize
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("l", [1024, 5000, 1 << 15, 1 << 15 | 3])
def test_quantize_roundtrip_error_bound(l):
    from repro.kernels.quantize.kernel import quantize_pallas, dequantize_pallas
    v = jnp.asarray(RNG.normal(size=(l,)).astype(np.float32))
    q, s = quantize_pallas(v)
    back = dequantize_pallas(q, s, l)
    # per-tile error bound: absmax/127 per tile, bounded globally
    err = np.abs(np.asarray(back) - np.asarray(v)).max()
    bound = float(jnp.max(jnp.abs(v))) / 127 + 1e-6
    assert err <= bound


def test_quantize_matches_ref_on_tile_multiple():
    from repro.kernels.quantize.kernel import quantize_pallas
    from repro.kernels.quantize.ref import quantize_ref
    v = jnp.asarray(RNG.normal(size=(4096,)).astype(np.float32))
    qk, sk = quantize_pallas(v)
    qr, sr = quantize_ref(v)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)


@pytest.mark.parametrize("l", [100, 1024 + 1, 3 * 1024 - 7])
def test_quantize_matches_ref_on_non_tile_multiple(l):
    """Kernel zero-pads to TILE; the ref on the explicitly padded input
    must agree, and the dequantized head must round-trip the original."""
    from repro.kernels.quantize.kernel import dequantize_pallas, quantize_pallas
    from repro.kernels.quantize.ref import TILE, dequantize_ref, quantize_ref
    v = jnp.asarray(RNG.normal(size=(l,)).astype(np.float32))
    pad = (-l) % TILE
    vp = jnp.pad(v, (0, pad))
    qk, sk = quantize_pallas(v)
    qr, sr = quantize_ref(vp)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)
    back_k = dequantize_pallas(qk, sk, l)
    back_r = dequantize_ref(qr, sr)[:l]
    np.testing.assert_allclose(np.asarray(back_k), np.asarray(back_r),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("b,lp", [(1, 1024), (5, 2048), (33, 1024)])
def test_quantize_batched_matches_ref_and_rows(b, lp):
    """Batched quantize (the fleet refresh requantize) == the ref == the
    1-D kernel per row, bit-exact, including row-tile padding."""
    from repro.kernels.quantize.kernel import quantize_batched_pallas, quantize_pallas
    from repro.kernels.quantize.ref import quantize_batched_ref
    x = jnp.asarray(RNG.normal(size=(b, lp)).astype(np.float32))
    qk, sk = quantize_batched_pallas(x, interpret=True)
    qr, sr = quantize_batched_ref(x)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    # scales agree to 1 ulp (XLA may codegen the /127 division
    # differently across shapes/eager-vs-jit); int8 codes are what the
    # wire carries and they are bit-equal
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=2e-7)
    q0, s0 = quantize_pallas(x[0])
    np.testing.assert_array_equal(np.asarray(qk[0]), np.asarray(q0))
    np.testing.assert_allclose(np.asarray(sk[0]), np.asarray(s0), rtol=2e-7)


def test_quantize_batched_rejects_off_tile():
    from repro.kernels.quantize.kernel import quantize_batched_pallas
    with pytest.raises(ValueError):
        quantize_batched_pallas(jnp.zeros((2, 1000), jnp.float32))


# ---------------------------------------------------------------------------
# aes_ctr
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [16, 100, 5000, 8192 + 5])
def test_aes_ctr_kernel_matches_ref(n):
    from repro.kernels.aes_ctr.ops import encrypt_bytes
    from repro.kernels.aes_ctr.ref import aes_ctr_ref
    key = RNG.integers(0, 256, 16).astype(np.uint8)
    nonce = RNG.integers(0, 256, 8).astype(np.uint8)
    pay = jnp.asarray(RNG.integers(0, 256, n).astype(np.uint8))
    np.testing.assert_array_equal(np.asarray(encrypt_bytes(pay, key, nonce)),
                                  np.asarray(aes_ctr_ref(pay, key, nonce)))


def test_aes_ctr_kernel_roundtrip():
    from repro.kernels.aes_ctr.ops import encrypt_bytes, decrypt_bytes
    key = np.arange(16, dtype=np.uint8)
    nonce = np.arange(8, dtype=np.uint8)
    pay = jnp.asarray(RNG.integers(0, 256, 1000).astype(np.uint8))
    ct = encrypt_bytes(pay, key, nonce)
    assert not np.array_equal(np.asarray(ct), np.asarray(pay))
    np.testing.assert_array_equal(np.asarray(decrypt_bytes(ct, key, nonce)),
                                  np.asarray(pay))
