"""Batched serving driver: prefill a prompt batch, then decode tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch debug-dense \
      --preset smoke --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import Transformer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="debug-dense")
    ap.add_argument("--preset", choices=("full", "smoke"), default="smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--mla-absorbed", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.preset == "smoke":
        cfg = cfg.smoke()
    cfg = cfg.replace(dtype="float32")
    model = Transformer(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng)
    B = args.batch
    max_len = args.prompt_len + args.gen

    prompts = jax.random.randint(jax.random.fold_in(rng, 1),
                                 (B, args.prompt_len), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    memory = None
    if cfg.frontend == "audio":
        frames = jax.random.normal(jax.random.fold_in(rng, 2), (B, 16, cfg.d_model))
        memory = model.encode(params, frames)
    if cfg.frontend == "vision":
        batch["prefix_embeds"] = jax.random.normal(
            jax.random.fold_in(rng, 3), (B, cfg.num_prefix_tokens, cfg.d_model))

    decode = jax.jit(
        lambda p, t, c, pos: model.decode_step(p, t, c, pos, memory=memory,
                                               mla_absorbed=args.mla_absorbed))

    # prefill via sequential decode into the cache (cache-building path),
    # which exercises the same serve_step the dry-run lowers
    cache = model.init_cache(B, max_len)
    t0 = time.time()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = decode(params, prompts[:, t:t + 1], cache, t)
    t_prefill = time.time() - t0

    out_tokens = []
    key = jax.random.fold_in(rng, 4)
    t0 = time.time()
    for t in range(args.gen):
        key, sub = jax.random.split(key)
        nxt = jax.random.categorical(sub, logits[:, 0] / args.temperature)[:, None]
        out_tokens.append(np.asarray(nxt))
        logits, cache = decode(params, nxt, cache, args.prompt_len + t)
    t_gen = time.time() - t0

    toks = np.concatenate(out_tokens, axis=1)
    print(f"[serve] {cfg.name}: batch={B} prompt={args.prompt_len} gen={args.gen}")
    print(f"[serve] prefill {t_prefill:.2f}s; decode {t_gen:.2f}s "
          f"({B * args.gen / max(t_gen, 1e-9):.1f} tok/s)")
    print(f"[serve] sample token ids: {toks[0][:12].tolist()}")
    assert toks.shape == (B, args.gen)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
