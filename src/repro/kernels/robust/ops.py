"""Public ops: Byzantine-robust aggregation over the flat round state.

``robust_aggregate`` / ``robust_aggregate_q8`` are the ONE aggregation
entry both engines call when ``EnFedConfig.robust != "none"`` — the loop
engine on its (1, N, P) stacked round, the fleet engine on the whole
(R, N, P) buffer — so every float op and every clip decision runs
through identical code and the engines' ``clipped`` masks agree bitwise
by construction (row-wise arithmetic is independent of R-tiling).

Methods:

* ``"trimmed_mean"`` — per-coordinate weighted trimmed mean (drop the
  extreme active instance at each end); the workhorse defense against
  signflip/scale poisoning.
* ``"median"``       — per-coordinate masked median (weights gate
  activity only); the classic high-breakdown statistic.
* ``"clip"``         — per-contributor L2 norm clip to the masked
  median norm ``tau``: contribution ``j`` scales by
  ``min(1, tau / ||u_j||)``; implemented as the existing fedavg kernel
  on rescaled weights plus an exact per-requester denominator
  correction, so only the small (R, N) norm reduction is new work.
  Returns the ``clipped`` mask (which active contributors exceeded
  ``tau``) for the history/telemetry trail.

The q8 twins run the SAME post-dequant arithmetic fused over the int8
wire buffer (never re-densified), so dense-on-dequantized and fused-q8
paths are bit-identical — the property the loop/fleet parity tests pin.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.fedavg.ops import (fedavg_flat_batched,
                                      fedavg_flat_batched_q8)
from repro.kernels.robust.kernel import (median_batched_pallas,
                                         median_batched_q8_pallas,
                                         sqnorm_batched_pallas,
                                         sqnorm_batched_q8_pallas,
                                         trimmed_mean_batched_pallas,
                                         trimmed_mean_batched_q8_pallas)
from repro.kernels.robust.ref import (median_batched_q8_ref,
                                      median_batched_ref,
                                      sqnorm_batched_q8_ref,
                                      sqnorm_batched_ref,
                                      trimmed_mean_batched_q8_ref,
                                      trimmed_mean_batched_ref)

# The robust-aggregation vocabulary ("none" = the plain fedavg path,
# byte-for-byte untouched — engines skip this module entirely).
ROBUST_METHODS = ("none", "clip", "trimmed_mean", "median")


def trimmed_mean_flat_batched(updates, weights, *, use_pallas: bool = True,
                              interpret=None):
    """updates: (R, N, L); weights: (R, N) -> (R, L) fp32."""
    if use_pallas:
        return trimmed_mean_batched_pallas(updates, weights,
                                           interpret=interpret)
    return trimmed_mean_batched_ref(updates, weights)


def trimmed_mean_flat_batched_q8(q, scales, weights, *,
                                 use_pallas: bool = True, interpret=None):
    """q: (R, N, Lp) int8; scales: (R, N, Lp/TILE); weights: (R, N)."""
    if use_pallas:
        return trimmed_mean_batched_q8_pallas(q, scales, weights,
                                              interpret=interpret)
    return trimmed_mean_batched_q8_ref(q, scales, weights)


def median_flat_batched(updates, weights, *, use_pallas: bool = True,
                        interpret=None):
    """updates: (R, N, L); weights: (R, N) -> (R, L) fp32."""
    if use_pallas:
        return median_batched_pallas(updates, weights, interpret=interpret)
    return median_batched_ref(updates, weights)


def median_flat_batched_q8(q, scales, weights, *, use_pallas: bool = True,
                           interpret=None):
    """q: (R, N, Lp) int8; scales: (R, N, Lp/TILE); weights: (R, N)."""
    if use_pallas:
        return median_batched_q8_pallas(q, scales, weights,
                                        interpret=interpret)
    return median_batched_q8_ref(q, scales, weights)


def l2norm_flat_batched(updates, *, use_pallas: bool = True, interpret=None):
    """updates: (R, N, L) -> (R, N) fp32 L2 norms (clip screening)."""
    if use_pallas:
        sq = sqnorm_batched_pallas(updates, interpret=interpret)
    else:
        sq = sqnorm_batched_ref(updates)
    return jnp.sqrt(sq)


def l2norm_flat_batched_q8(q, scales, *, use_pallas: bool = True,
                           interpret=None):
    """q: (R, N, Lp) int8; scales: (R, N, Lp/TILE) -> (R, N) fp32 norms."""
    if use_pallas:
        sq = sqnorm_batched_q8_pallas(q, scales, interpret=interpret)
    else:
        sq = sqnorm_batched_q8_ref(q, scales)
    return jnp.sqrt(sq)


def _masked_median_1d(values, active):
    """values, active: (R, N) -> (R,) masked median over active entries
    (inf for empty rows — callers' downstream ``min(1, tau/...)`` then
    clips nothing, matching the all-masked zero-aggregate convention)."""
    m = jnp.sum(active.astype(jnp.int32), axis=1)
    srt = jnp.sort(jnp.where(active, values.astype(jnp.float32), jnp.inf),
                   axis=1)
    lo = jnp.maximum((m - 1) // 2, 0)[:, None]
    hi = jnp.maximum(m // 2, 0)[:, None]
    vlo = jnp.take_along_axis(srt, lo, axis=1)[:, 0]
    vhi = jnp.take_along_axis(srt, hi, axis=1)[:, 0]
    return 0.5 * (vlo + vhi)


def clip_factors(norms, weights):
    """norms, weights: (R, N) -> ``(c, clipped, tau)``.

    ``tau`` (R,) is the masked median norm of the active contributors,
    ``c`` (R, N) the per-contributor clip factor ``min(1, tau/norm)``
    (1 where inactive), ``clipped`` (R, N) bool the active contributors
    whose norm strictly exceeds ``tau``.  The median-norm threshold is
    self-calibrating — no new magnitude knob — and by construction at
    most half the active set can be clipped, so an honest majority
    anchors the scale.
    """
    w = jnp.asarray(weights, jnp.float32)
    norms = jnp.asarray(norms, jnp.float32)
    active = w > 0.0
    tau = _masked_median_1d(norms, active)
    c = jnp.where(active,
                  jnp.minimum(1.0, tau[:, None]
                              / jnp.maximum(norms, 1e-12)),
                  1.0)
    clipped = active & (norms > tau[:, None])
    return c, clipped, tau


def _clip_combine(raw, weights, c):
    """Exact denominator correction turning ``fedavg(u, w*c)`` into
    ``sum(w*c*u) / sum(w)`` — norm-clip rescales contributions, never
    the normalization mass."""
    w = jnp.asarray(weights, jnp.float32)
    s_clip = jnp.maximum(jnp.sum(w * c, axis=1), 1e-9)
    s_all = jnp.maximum(jnp.sum(w, axis=1), 1e-9)
    return raw * (s_clip / s_all)[:, None]


def robust_aggregate(updates, weights, *, method: str,
                     use_pallas: bool = True, interpret=None):
    """updates: (R, N, L); weights: (R, N) -> ``(agg, clipped)``.

    ``agg`` (R, L) fp32 robust aggregate; ``clipped`` (R, N) bool for
    ``method="clip"``, else an all-false mask (trim/median have no
    per-contributor verdict — the statistic decides per coordinate).
    All-zero weight rows return zero vectors (the fedavg convention);
    callers substitute the session's previous params.
    """
    w = jnp.asarray(weights, jnp.float32)
    if method == "trimmed_mean":
        agg = trimmed_mean_flat_batched(updates, w, use_pallas=use_pallas,
                                        interpret=interpret)
        return agg, jnp.zeros(w.shape, bool)
    if method == "median":
        agg = median_flat_batched(updates, w, use_pallas=use_pallas,
                                  interpret=interpret)
        return agg, jnp.zeros(w.shape, bool)
    if method == "clip":
        norms = l2norm_flat_batched(updates, use_pallas=use_pallas,
                                    interpret=interpret)
        c, clipped, _ = clip_factors(norms, w)
        raw = fedavg_flat_batched(updates, w * c, use_pallas=use_pallas,
                                  interpret=interpret)
        return _clip_combine(raw, w, c), clipped
    raise ValueError(
        f"robust method must be one of {ROBUST_METHODS[1:]} (got {method!r})")


def robust_aggregate_q8(q, scales, weights, *, method: str,
                        use_pallas: bool = True, interpret=None):
    """q: (R, N, Lp) int8; scales: (R, N, Lp/TILE); weights: (R, N) ->
    ``(agg, clipped)`` with ``agg`` (R, Lp) fp32 — the fused-dequant
    twin of :func:`robust_aggregate`, arithmetic bit-identical to the
    dense path on the dequantized buffer."""
    w = jnp.asarray(weights, jnp.float32)
    if method == "trimmed_mean":
        agg = trimmed_mean_flat_batched_q8(q, scales, w,
                                           use_pallas=use_pallas,
                                           interpret=interpret)
        return agg, jnp.zeros(w.shape, bool)
    if method == "median":
        agg = median_flat_batched_q8(q, scales, w, use_pallas=use_pallas,
                                     interpret=interpret)
        return agg, jnp.zeros(w.shape, bool)
    if method == "clip":
        norms = l2norm_flat_batched_q8(q, scales, use_pallas=use_pallas,
                                       interpret=interpret)
        c, clipped, _ = clip_factors(norms, w)
        raw = fedavg_flat_batched_q8(q, scales, w * c, use_pallas=use_pallas,
                                     interpret=interpret)
        return _clip_combine(raw, w, c), clipped
    raise ValueError(
        f"robust method must be one of {ROBUST_METHODS[1:]} (got {method!r})")
