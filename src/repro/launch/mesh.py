"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run
driver sets XLA_FLAGS before any jax import to get 512 placeholder host
devices; tests and benches import this module freely and see 1 device.

Single pod:  (16, 16)    axes ("data", "model")   = 256 chips (v5e pod)
Multi-pod:   (2, 16, 16) axes ("pod", "data", "model") = 512 chips

The ``pod`` axis is the EnFed cross-silo client axis for fsdp configs;
``data`` doubles as the client axis for everything else (DESIGN.md §5).

``jax.sharding.AxisType`` only exists on jax >= 0.5; on the pinned
0.4.x toolchain (where every axis is implicitly auto) meshes are built
without ``axis_types`` so this module stays importable everywhere.
``AXIS_TYPES_SUPPORTED`` is the feature gate tests key off.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:  # pinned 0.4.x: axes are implicitly auto-typed
    AxisType = None

AXIS_TYPES_SUPPORTED = AxisType is not None


def _mesh(shape, axes):
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} present; "
            "the dry-run driver must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before any jax import")
    devs = np.asarray(devices[:n]).reshape(shape)
    if AXIS_TYPES_SUPPORTED:
        return Mesh(devs, axes, axis_types=(AxisType.Auto,) * len(shape))
    return Mesh(devs, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Small mesh for CPU integration tests (needs 8 fake host devices)."""
    shape = (2, 2, 2) if multi_pod else (4, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def client_axes_for(cfg, mesh) -> tuple:
    """Which mesh axes act as the EnFed/FL client axes for this config.

    fsdp configs consume the data axis for ZeRO sharding, so they
    federate over the pod axis only (cross-silo); everything else
    federates over (pod,) data.

    fsdp + MoE cannot federate at all in THIS environment:
    the token-local MoE dispatch nested inside a client shard_map trips
    three distinct XLA-CPU SPMD-partitioner CHECK-failures (bisected in
    EXPERIMENTS.md §Dry-run).  It trains as conventional sync DP across
    pods instead; on a real TPU backend the pod-level schedule is the
    same one an fsdp dense config exercises successfully.
    """
    names = mesh.axis_names
    if getattr(cfg, "fsdp", False):
        if getattr(cfg, "moe", None) is not None:
            return ()
        return ("pod",) if "pod" in names else ()
    axes = [a for a in ("pod", "data") if a in names]
    return tuple(axes)
