"""Byzantine-contributor world: per-round per-link payload corruption —
shared by BOTH EnFed engines.

The paper assumes honest contributors: every delivered update is the
sender's true wire image.  A real opportunistic fleet contains devices
that send corrupted, poisoned, or garbage payloads — *delivered but
wrong*, which the fault world (:mod:`repro.core.faults`) cannot express.
This module makes the adversary part of the simulated world, with the
same design rule as mobility/faults/cadence: whether a delivered payload
is corrupted — and, for the randomized attack, *what* the corruption is
— is a closed-form function of ``(seed, round, requester, contributor)``
— pure counter-based ``jax.random.fold_in`` chains, no carried RNG — so
the loop engine (host-side, concrete rounds) and the fleet engine
(traced rounds inside one jit program) derive bit-identical attacks by
construction, and any round's corruption set can be queried without
replaying earlier rounds.

Four attack modes, applied to the WIRE image at the protocol's transport
point (``Phase.COLLECT``/``Phase.DELIVER`` boundary — the loop engine
corrupts the payload inside ``_collect_update``, the fleet engine
corrupts the delivered ``(R, N, ·)`` buffer in its round body):

* **signflip** — the payload is negated (gradient-ascent poisoning).
  int8 wire: the quantized codes negate exactly (codes live in
  [-127, 127], so no overflow) and the scales pass through.
* **scale**  — the payload is multiplied by ``scale`` (an amplified
  update that drags the average).  int8 wire: only the per-tile scales
  multiply — the codes never re-densify.
* **noise**  — the payload is REPLACED by counter-keyed garbage of
  magnitude ``scale`` (a device answering with junk).  Dense wire:
  ``scale * N(0, 1)`` per coordinate; int8 wire: uniform codes in
  [-127, 127] with constant per-tile scale ``scale / 127``.
* **zero**   — the payload (codes AND scales) zeroes out: a free-riding
  contributor that sends nothing useful while collecting the incentive.

Corruption is transport-level: the contributor's resident wire image is
NEVER modified — only the copy the requester aggregates this round —
so a corrupted round leaves no residue in later rounds' deliveries.

Ordering pin (fault x adversary): stale-delivery substitution happens
FIRST, corruption draws are keyed on the DELIVERING round and applied to
whatever image is actually delivered.  A stale corrupted image and a
corrupted stale image therefore cannot diverge between engines — see
``protocol.py``'s COLLECT/DELIVER notes and the pinning test in
``tests/test_adversary.py``.

Parity-safety rule (same as mobility/faults/cadence): the corruption
predicate is an exact integer comparison — the threshold is precomputed
host-side from the static probability, draws are int32 — so no float
fusion regime can flip a corruption outcome between engines.  The
attack *payloads* are either exact elementwise transforms (negate, zero,
multiply) or counter-keyed generation with identical keys and shapes in
both engines, hence bit-identical by construction.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# Corruption draws live in [0, _DRAW_MAX); a probability p maps to the
# threshold int(p * _DRAW_MAX) — identical arithmetic to repro.core.faults.
_DRAW_MAX = 2**31 - 1

_SALT_BYZ = 0xB7    # per-(round, link) corruption predicate
_SALT_NOISE = 0xA6  # per-(round, link) noise payload

# The attack vocabulary (static jit argument via the frozen config).
ATTACKS = ("signflip", "scale", "noise", "zero")


@dataclasses.dataclass(frozen=True)
class AdversaryConfig:
    """Byzantine-contributor world parameters for one simulated session
    (frozen/hashable => usable as a static arg of the compiled fleet
    program, exactly like :class:`repro.core.faults.FaultConfig`).

    ``requester_id`` is the requesting device's id in the adversary
    hash-space; fleet lanes use ``requester_id + lane`` so concurrent
    requesters see independent corruption weather.  The default offset
    keeps adversary-space requester ids clear of contributor ids AND of
    the mobility/fault/cadence id spaces.
    """

    p_byzantine: float = 0.0   # per-(round, link) corruption probability
    attack: str = "signflip"   # one of ATTACKS
    scale: float = 10.0        # magnitude knob for "scale" / "noise"
    seed: int = 0              # adversary hash seed
    requester_id: int = 1 << 23  # requester lane 0's id in adversary space

    def __post_init__(self):
        # fail fast at CONSTRUCTION — not as silent clean rounds deep
        # inside the jit program (the satellite rule FaultConfig set)
        if not 0.0 <= self.p_byzantine <= 1.0:
            raise ValueError(
                f"p_byzantine must be within [0, 1] (got {self.p_byzantine})")
        if self.attack not in ATTACKS:
            raise ValueError(
                f"attack must be one of {ATTACKS} (got {self.attack!r})")
        if self.scale <= 0.0:
            raise ValueError(
                f"scale must be > 0 (got {self.scale})")


def _threshold(p: float) -> jnp.int32:
    """The static int32 threshold a probability compiles to."""
    return jnp.int32(int(min(max(float(p), 0.0), 1.0) * _DRAW_MAX))


def _link_draw(seed: int, salt: int, r, requester_id, cand_id):
    """One int32 draw in [0, _DRAW_MAX) hashed from ``(seed, salt,
    round, requester, contributor)`` alone — prefix-stable in every
    argument, traced or concrete."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), jnp.uint32(salt))
    key = jax.random.fold_in(key, jnp.asarray(r, jnp.uint32))
    key = jax.random.fold_in(key, jnp.asarray(requester_id, jnp.uint32))
    key = jax.random.fold_in(key, jnp.asarray(cand_id, jnp.uint32))
    return jax.random.randint(key, (), 0, _DRAW_MAX, jnp.int32)


def _noise_key(seed: int, r, requester_id, cand_id):
    """The PRNG key the "noise" attack payload derives from — the same
    fold_in chain as the predicate draw under a different salt, so the
    garbage a corrupted link delivers is itself closed-form world state."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), jnp.uint32(_SALT_NOISE))
    key = jax.random.fold_in(key, jnp.asarray(r, jnp.uint32))
    key = jax.random.fold_in(key, jnp.asarray(requester_id, jnp.uint32))
    key = jax.random.fold_in(key, jnp.asarray(cand_id, jnp.uint32))
    return key


def corruption_mask(ac: AdversaryConfig, r, requester_id, cand_ids):
    """(..., N) bool: which delivered payloads are corrupted at round
    ``r`` — THE shared derivation of both engines.

    Inputs broadcast like :func:`repro.core.faults.link_outcomes`:
    ``requester_id`` is scalar or (R,), ``cand_ids`` (N,) or (R, N).
    ``r`` is the DELIVERING round (the round the requester aggregates
    the payload, not the round the image was trained) — the fault x
    adversary ordering pin.

    Whether a link *counts* (contract member, delivered) is the caller's
    mask — corruption here is pure world state: the draw of a round
    exists whether or not that link transmitted.
    """
    ids = jnp.asarray(cand_ids, jnp.int32)
    req = jnp.broadcast_to(
        jnp.asarray(requester_id, jnp.int32)[..., None], ids.shape)
    thr = _threshold(ac.p_byzantine)
    draws = jax.vmap(lambda q, c: _link_draw(ac.seed, _SALT_BYZ, r, q, c))(
        req.reshape(-1), ids.reshape(-1))
    return (draws < thr).reshape(ids.shape)


def noise_vector(ac: AdversaryConfig, r, requester_id, cand_id, length: int):
    """(length,) fp32 garbage payload of the "noise" attack for ONE link
    (dense wire format): ``scale * N(0, 1)``, counter-keyed."""
    key = _noise_key(ac.seed, r, requester_id, cand_id)
    return jnp.float32(ac.scale) * jax.random.normal(
        key, (int(length),), jnp.float32)


def noise_codes(ac: AdversaryConfig, r, requester_id, cand_id, length: int):
    """(length,) int8 garbage codes of the "noise" attack for ONE link
    (int8 wire format): uniform in [-127, 127], counter-keyed.  Pairs
    with the constant per-tile scale ``scale / 127`` so the dequantized
    garbage has magnitude ~``scale``."""
    key = _noise_key(ac.seed, r, requester_id, cand_id)
    return jax.random.randint(
        key, (int(length),), -127, 128, jnp.int32).astype(jnp.int8)


def noise_scale(ac: AdversaryConfig) -> jnp.float32:
    """The constant per-tile quantization scale of int8 noise payloads."""
    return jnp.float32(float(ac.scale) / 127.0)


def corrupt_dense(ac: AdversaryConfig, u, corrupt, r, requester_id, cand_id):
    """Apply the configured attack to ONE dense wire payload.

    ``u`` (L,) fp32, ``corrupt`` scalar bool (from
    :func:`corruption_mask`).  Returns the payload the requester actually
    receives; the contributor's resident image is untouched.
    """
    u = jnp.asarray(u, jnp.float32)
    if ac.attack == "signflip":
        bad = -u
    elif ac.attack == "scale":
        bad = jnp.float32(ac.scale) * u
    elif ac.attack == "zero":
        bad = jnp.zeros_like(u)
    else:  # noise
        bad = noise_vector(ac, r, requester_id, cand_id, u.shape[-1])
    return jnp.where(corrupt, bad, u)


def corrupt_wire(ac: AdversaryConfig, q, scales, corrupt, r, requester_id,
                 cand_id):
    """Apply the configured attack to ONE int8 wire payload — codes and
    per-tile scales, never the densified fp32 vector (the
    never-re-densify rule).

    ``q`` (Lp,) int8 codes, ``scales`` (Lp / Q_TILE,) fp32 per-tile
    scales, ``corrupt`` scalar bool.  Returns ``(q', scales')``.
    """
    q = jnp.asarray(q, jnp.int8)
    scales = jnp.asarray(scales, jnp.float32)
    if ac.attack == "signflip":
        bad_q, bad_s = -q, scales  # codes in [-127, 127]: exact negation
    elif ac.attack == "scale":
        bad_q, bad_s = q, jnp.float32(ac.scale) * scales
    elif ac.attack == "zero":
        bad_q, bad_s = jnp.zeros_like(q), jnp.zeros_like(scales)
    else:  # noise
        bad_q = noise_codes(ac, r, requester_id, cand_id, q.shape[-1])
        bad_s = jnp.full_like(scales, noise_scale(ac))
    return jnp.where(corrupt, bad_q, q), jnp.where(corrupt, bad_s, scales)


def corrupt_dense_batched(ac: AdversaryConfig, u, corrupt, r, requester_ids,
                          cand_ids):
    """The fleet engine's vectorized :func:`corrupt_dense`.

    ``u`` (R, N, L) fp32 delivered buffer, ``corrupt`` (R, N) bool,
    ``requester_ids`` (R,) adversary-space lane ids, ``cand_ids`` (N,)
    or (R, N).  The noise payload vmaps the SAME per-link keys and
    shapes the loop engine draws, hence bit-identical garbage.
    """
    u = jnp.asarray(u, jnp.float32)
    corrupt = jnp.asarray(corrupt, bool)
    if ac.attack == "noise":
        ids = jnp.broadcast_to(jnp.asarray(cand_ids, jnp.int32),
                               corrupt.shape)
        req = jnp.broadcast_to(
            jnp.asarray(requester_ids, jnp.int32)[..., None], corrupt.shape)
        bad = jax.vmap(
            lambda q_, c_: noise_vector(ac, r, q_, c_, u.shape[-1]))(
            req.reshape(-1), ids.reshape(-1)).reshape(u.shape)
    elif ac.attack == "signflip":
        bad = -u
    elif ac.attack == "scale":
        bad = jnp.float32(ac.scale) * u
    else:  # zero
        bad = jnp.zeros_like(u)
    return jnp.where(corrupt[..., None], bad, u)


def corrupt_wire_batched(ac: AdversaryConfig, q, scales, corrupt, r,
                         requester_ids, cand_ids):
    """The fleet engine's vectorized :func:`corrupt_wire`.

    ``q`` (R, N, Lp) int8 codes, ``scales`` (R, N, Lp / Q_TILE) fp32,
    ``corrupt`` (R, N) bool.  Returns ``(q', scales')`` — the codes stay
    int8-resident throughout (the never-re-densify rule).
    """
    q = jnp.asarray(q, jnp.int8)
    scales = jnp.asarray(scales, jnp.float32)
    corrupt = jnp.asarray(corrupt, bool)
    if ac.attack == "noise":
        ids = jnp.broadcast_to(jnp.asarray(cand_ids, jnp.int32),
                               corrupt.shape)
        req = jnp.broadcast_to(
            jnp.asarray(requester_ids, jnp.int32)[..., None], corrupt.shape)
        bad_q = jax.vmap(
            lambda q_, c_: noise_codes(ac, r, q_, c_, q.shape[-1]))(
            req.reshape(-1), ids.reshape(-1)).reshape(q.shape)
        bad_s = jnp.full_like(scales, noise_scale(ac))
    elif ac.attack == "signflip":
        bad_q, bad_s = -q, scales
    elif ac.attack == "scale":
        bad_q, bad_s = q, jnp.float32(ac.scale) * scales
    else:  # zero
        bad_q, bad_s = jnp.zeros_like(q), jnp.zeros_like(scales)
    return (jnp.where(corrupt[..., None], bad_q, q),
            jnp.where(corrupt[..., None], bad_s, scales))
