"""The method registry: ``"enfed"``, ``"dfl"``, ``"cfl"``, ``"cloud"``.

Each registered runner maps ``(world, method, execution) -> RunResult``
under the shared contract that makes comparisons meaningful:

* it trains on the world's data/models as-is (``world.fresh_requesters``
  copies keep runs independent),
* every energy/time figure comes from the world's ONE
  :class:`repro.core.energy.CostModel`, with ``model_bytes`` priced
  through the shared :func:`repro.core.energy.update_wire_bytes` helper
  — so the ``MethodSpec.compress`` knob lowers transmission/crypto
  energy consistently for enfed AND the dfl/cfl baselines (cloud ships
  raw data, not model updates, and is unaffected),
* the protocol knobs are read from the :class:`MethodSpec`'s
  EnFedConfig-shaped surface — the baselines have no private kwargs.

New workloads plug in with :func:`register_method` instead of growing a
fourth ad-hoc entrypoint signature.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, Tuple

from repro.api.result import RunResult
from repro.api.specs import ExecutionSpec, MethodSpec, WorldSpec
from repro.core import federated, protocol
from repro.core.energy import update_wire_bytes
from repro.core.rounds import EnFedSession, SessionResult
from repro.telemetry.spans import Timeline
from repro.utils.tree import tree_bytes, tree_size

MethodRunner = Callable[[WorldSpec, MethodSpec, ExecutionSpec], RunResult]

_REGISTRY: Dict[str, MethodRunner] = {}


def register_method(name: str):
    """Decorator: add a runner under ``name`` (e.g. a new baseline)."""

    def deco(fn: MethodRunner) -> MethodRunner:
        _REGISTRY[name] = fn
        return fn

    return deco


def method_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def get_runner(name: str) -> MethodRunner:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown method {name!r}; registered: {sorted(_REGISTRY)}") from None


def _warn_if_mobility_ignored(world: WorldSpec, name: str) -> None:
    """The host-side baselines have no opportunistic-world execution:
    they train their full static client set every round.  Comparing them
    against EnFed-under-churn is apples-to-oranges, so dropping the
    world's mobility axis must never be silent."""
    if world.mobility is not None:
        warnings.warn(
            f"method {name!r} ignores world.mobility (no opportunistic-"
            "world execution: its full static client set trains every "
            "round); a compare() against EnFed-under-churn mixes a churn "
            "world with static baselines", stacklevel=3)


def _warn_if_cadence_ignored(method: MethodSpec, name: str) -> None:
    """The baselines have no per-device round clock — dfl/cfl sweep
    every node each round and cloud has no rounds at all.  Same
    never-silent rule as the mobility axis: asking a baseline to run an
    async-cadence world warns, and the knob is stripped before the run
    (the fleet engine refuses cadence for non-enfed methods)."""
    if method.cadence is not None:
        warnings.warn(
            f"method {name!r} ignores MethodSpec.cadence (async device "
            "round clocks are enfed-only: baselines sweep their full "
            "client set every round); comparing against EnFed-under-"
            "cadence mixes an async world with lockstep baselines",
            stacklevel=3)


def _strip_cadence(method: MethodSpec) -> MethodSpec:
    return (dataclasses.replace(method, cadence=None)
            if method.cadence is not None else method)


def _warn_if_adversary_ignored(method: MethodSpec, name: str) -> None:
    """Byzantine contributors, the robust aggregation statistic, and
    staleness decay are enfed protocol knobs (Phase.DELIVER/AGGREGATE);
    the baselines' loop oracles define their aggregation semantics
    without them.  Same never-silent rule as the mobility/cadence axes:
    asking a baseline to run a Byzantine world warns, and the knobs are
    stripped before the run (the fleet baselines refuse them)."""
    if (method.adversary is not None or method.robust != "none"
            or method.staleness_gamma != 1.0):
        warnings.warn(
            f"method {name!r} ignores MethodSpec.adversary/robust/"
            "staleness_gamma (Byzantine contributors and robust "
            "aggregation are enfed-only); comparing against "
            "EnFed-under-attack mixes an adversarial world with honest "
            "baselines", stacklevel=3)


def _strip_adversary(method: MethodSpec) -> MethodSpec:
    if (method.adversary is None and method.robust == "none"
            and method.staleness_gamma == 1.0):
        return method
    return dataclasses.replace(method, adversary=None, robust="none",
                               staleness_gamma=1.0)


def _warn_if_checkpoint_ignored(execution: ExecutionSpec, name: str) -> None:
    """Resumable round state is an enfed contract (the baselines' loop
    oracles have no serialized mid-run state).  Same never-silent rule
    as the mobility axis: asking a baseline to checkpoint must warn, not
    quietly do nothing."""
    if execution.checkpoint_dir or execution.resume_from:
        warnings.warn(
            f"method {name!r} ignores ExecutionSpec checkpointing "
            "(checkpoint_dir/resume_from are enfed-only: baselines have "
            "no resumable round-state contract)", stacklevel=3)


def _warn_if_trace_fleet_only(execution: ExecutionSpec, name: str) -> None:
    """``TraceConfig.jax_profiler_dir`` / ``hlo_stats`` instrument THE
    compiled fleet program — the loop engine (and the host-side
    baselines) has no such program to profile.  Never-silent rule:
    asking for them on a loop run warns instead of quietly exporting
    nothing.  The outcome-neutral selections (events_jsonl,
    chrome_trace) work on every engine and stay silent."""
    tr = execution.trace
    if tr is not None and (getattr(tr, "jax_profiler_dir", None)
                           or getattr(tr, "hlo_stats", False)):
        warnings.warn(
            f"{name} run ignores TraceConfig.jax_profiler_dir/hlo_stats "
            "(fleet-engine-only: they profile the compiled fleet program); "
            "event/timeline exports still apply", stacklevel=3)


def _baseline_model_bytes(params, cfg) -> int:
    """One update's wire bytes for a loop cfl/dfl session — the same
    ``update_wire_bytes`` call ``_run_fleet_baseline`` prices its views
    with, so the two engines' event streams carry identical
    ``wire_bytes``."""
    return update_wire_bytes(tree_size(params), encrypt=False,
                             compress=getattr(cfg, "compress", None),
                             raw_bytes=tree_bytes(params))


def _baseline_session(res: "federated.BaselineResult", *, target: float,
                      n_contributors: float,
                      model_bytes: int = 0) -> SessionResult:
    """A BaselineResult in the per-requester SessionResult schema."""
    stopped = res.accuracy >= target
    stop = (protocol.STOP_ACCURACY if stopped else protocol.STOP_MAX_ROUNDS)
    return SessionResult(
        accuracy=res.accuracy, rounds=res.rounds,
        n_contributors=n_contributors, report=res.report, battery=None,
        history=res.history, stop_reason=protocol.stop_reason_name(stop),
        params=res.params, model_bytes=model_bytes)


@register_method("enfed")
def run_enfed(world: WorldSpec, method: MethodSpec,
              execution: ExecutionSpec) -> RunResult:
    """EnFed Algorithm 1 — the only method with two engines; the
    ExecutionSpec picks which and tunes the compiled one."""
    from repro.core import fleet as fleet_mod

    cfg = method.to_enfed_config(world)
    cost = world.cost_model
    reqs = world.fresh_requesters()
    tl = Timeline()
    if execution.engine == "fleet":
        fr = fleet_mod.run_fleet(
            world.task, reqs, cfg, cost_model=cost,
            use_pallas=execution.use_pallas, interpret=execution.interpret,
            round_chunk=execution.round_chunk,
            checkpoint_dir=execution.checkpoint_dir,
            checkpoint_every=execution.checkpoint_every,
            resume_from=execution.resume_from,
            timeline=tl, trace=execution.trace)
        return RunResult.from_sessions(
            "enfed", "fleet", fr.sessions, cost_model=cost,
            total_energy_j=fr.total_energy_j, raw=fr,
            timeline=tl, hlo_stats=fr.hlo_stats)
    _warn_if_trace_fleet_only(execution, "loop-engine enfed")

    def _sub(root, i):
        # multi-requester loop runs checkpoint per session: requester
        # i's state lives under <root>/req<i> (a 1-requester world keeps
        # the bare directory, so loop and fleet runs can share paths)
        if not root or len(reqs) == 1:
            return root
        import os
        return os.path.join(root, f"req{i}")

    sessions = []
    for i, r in enumerate(reqs):
        # requester i walks as device mobility.requester_id + i and rolls
        # fault dice as faults.requester_id + i — the fleet engine's lane
        # conventions — so ExecutionSpec.engine can never change which
        # world a requester experiences
        cfg_i = cfg
        if cfg.mobility is not None and i > 0:
            cfg_i = dataclasses.replace(
                cfg_i, mobility=dataclasses.replace(
                    cfg.mobility,
                    requester_id=cfg.mobility.requester_id + i))
        if cfg.faults is not None and i > 0:
            cfg_i = dataclasses.replace(
                cfg_i, faults=dataclasses.replace(
                    cfg.faults,
                    requester_id=cfg.faults.requester_id + i))
        if cfg.cadence is not None and i > 0:
            cfg_i = dataclasses.replace(
                cfg_i, cadence=dataclasses.replace(
                    cfg.cadence,
                    requester_id=cfg.cadence.requester_id + i))
        if cfg.adversary is not None and i > 0:
            cfg_i = dataclasses.replace(
                cfg_i, adversary=dataclasses.replace(
                    cfg.adversary,
                    requester_id=cfg.adversary.requester_id + i))
        sessions.append(EnFedSession(
            world.task, r.own_train, r.own_test,
            r.neighborhood, r.contributor_states,
            cfg_i, cost_model=cost, battery=r.battery).run(
                checkpoint_dir=_sub(execution.checkpoint_dir, i),
                checkpoint_every=execution.checkpoint_every,
                resume_from=_sub(execution.resume_from, i), timeline=tl))
    return RunResult.from_sessions("enfed", "loop", sessions, cost_model=cost,
                                   timeline=tl)


def _run_baseline_fleet(world: WorldSpec, method: MethodSpec,
                        execution: ExecutionSpec, name: str) -> RunResult:
    """dfl/cfl as traced protocol variants of the compiled fleet engine
    (``run_fleet(method=...)``) — the rows a large-R ``compare()`` gets
    are simulated by the same jit program enfed runs in, not
    extrapolated from loop sessions.  Baselines re-init node params and
    write nothing back, so the world's requesters are used read-only."""
    from repro.core import fleet as fleet_mod

    cfg = method.to_enfed_config(world)
    cost = world.cost_model
    tl = Timeline()
    fr = fleet_mod.run_fleet(
        world.task, world.requesters, cfg, cost_model=cost,
        use_pallas=execution.use_pallas, interpret=execution.interpret,
        round_chunk=execution.round_chunk, method=name,
        dfl_topology=method.topology,
        timeline=tl, trace=execution.trace)
    return RunResult.from_sessions(name, "fleet", fr.sessions,
                                   cost_model=cost,
                                   total_energy_j=fr.total_energy_j, raw=fr,
                                   timeline=tl, hlo_stats=fr.hlo_stats)


@register_method("cfl")
def run_cfl(world: WorldSpec, method: MethodSpec,
            execution: ExecutionSpec) -> RunResult:
    """Centralized FL baseline, per requesting device (client 0)."""
    _warn_if_mobility_ignored(world, "cfl")
    _warn_if_checkpoint_ignored(execution, "cfl")
    _warn_if_cadence_ignored(method, "cfl")
    method = _strip_cadence(method)
    _warn_if_adversary_ignored(method, "cfl")
    method = _strip_adversary(method)
    if execution.engine == "fleet":
        return _run_baseline_fleet(world, method, execution, "cfl")
    _warn_if_trace_fleet_only(execution, "cfl")
    cfg = method.to_enfed_config(world)
    cost = world.cost_model
    sessions = []
    # baselines re-init their node params, so the world's contributor
    # states are read-only here — no fresh copies needed
    for i, r in enumerate(world.requesters):
        data = world.client_data(i)
        res = federated.CFLLearner(world.task, data, r.own_test,
                                   cost_model=cost).run_config(cfg)
        sessions.append(_baseline_session(
            res, target=cfg.desired_accuracy, n_contributors=len(data) - 1,
            model_bytes=_baseline_model_bytes(res.params, cfg)))
    return RunResult.from_sessions("cfl", "loop", sessions, cost_model=cost,
                                   timeline=Timeline())


@register_method("dfl")
def run_dfl(world: WorldSpec, method: MethodSpec,
            execution: ExecutionSpec) -> RunResult:
    """Decentralized FL baseline over ``method.topology`` (mesh|ring)."""
    _warn_if_mobility_ignored(world, "dfl")
    _warn_if_checkpoint_ignored(execution, "dfl")
    _warn_if_cadence_ignored(method, "dfl")
    method = _strip_cadence(method)
    _warn_if_adversary_ignored(method, "dfl")
    method = _strip_adversary(method)
    if execution.engine == "fleet":
        return _run_baseline_fleet(world, method, execution, "dfl")
    _warn_if_trace_fleet_only(execution, "dfl")
    cfg = method.to_enfed_config(world)
    cost = world.cost_model
    sessions = []
    for i, r in enumerate(world.requesters):
        data = world.client_data(i)
        res = federated.DFLLearner(world.task, data, r.own_test,
                                   method.topology,
                                   cost_model=cost).run_config(cfg)
        sessions.append(_baseline_session(
            res, target=cfg.desired_accuracy, n_contributors=len(data) - 1,
            model_bytes=_baseline_model_bytes(res.params, cfg)))
    return RunResult.from_sessions("dfl", "loop", sessions, cost_model=cost,
                                   timeline=Timeline())


@register_method("cloud")
def run_cloud(world: WorldSpec, method: MethodSpec,
              execution: ExecutionSpec) -> RunResult:
    """The §IV-G no-FL baseline: ship raw data to the cloud, wait, get
    the result back.  Device-side cost via ``CostModel.cloud_session``."""
    _warn_if_mobility_ignored(world, "cloud")
    _warn_if_checkpoint_ignored(execution, "cloud")
    _warn_if_cadence_ignored(method, "cloud")
    method = _strip_cadence(method)
    _warn_if_adversary_ignored(method, "cloud")
    method = _strip_adversary(method)
    _warn_if_trace_fleet_only(execution, "cloud")
    cfg = method.to_enfed_config(world)
    cost = world.cost_model
    sessions = []
    for i, r in enumerate(world.requesters):
        res = federated.cloud_only_config(world.task, world.pooled(i),
                                          r.own_test, cfg, cost_model=cost)
        # cloud ships raw data, not model updates: no per-round wire
        sessions.append(_baseline_session(
            res, target=cfg.desired_accuracy, n_contributors=0.0))
    return RunResult.from_sessions("cloud", "loop", sessions, cost_model=cost,
                                   timeline=Timeline())
