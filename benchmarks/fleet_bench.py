"""Fleet-engine scaling benchmark: rounds/s, staged host->device bytes,
and simulated energy as the number of concurrent requester sessions
grows 8 -> 512 — emitted as ``BENCH_fleet.json`` so every PR leaves a
perf trail.

For each fleet size R the jit fleet engine (``repro.core.fleet``) runs
all R sessions as ONE compiled program; the loop engine
(``EnFedSession.run``) is timed on a few sessions and extrapolated to
the same R (its cost is linear in sessions by construction — one Python
round loop each).  The headline metrics:

* **session-rounds/s** (warm, cached jit) — the scaling number, for the
  static world AND for the opportunistic world (``results_mobility``:
  per-round on-device re-negotiation — waypoint kinematics, radio-range
  masks, battery-floor releases — with membership stats per row);
* **staged index bytes** — what the host ships to the device for
  minibatch scheduling.  The PR 1 engine staged a
  (max_rounds, R, epochs, steps, batch) int32 tensor (plus the
  contributor-refresh plan); the PR 2 engine derives schedules on
  device from counters, staging only (R,) shard sizes and (R, N)
  seeds.  Both numbers land in the JSON as before/after.
* **staged shard bytes** — contributor training shards.  Dense
  per-requester staging shipped the same shared shards R times as an
  (R, N, n_c, F) block; the deduplicated engine stages each unique
  shard once plus an (R, N) gather index.  Before/after per row.

* **compressed round state** (``results_compress``) — the same sweep
  with ``EnFedConfig.compress="int8"`` on a tile-amortizing model
  (the tiny smoke model is padding-limited): staged param bytes and
  ``device_round_state_bytes`` fp32 vs int8 (>= 3.5x), and warm
  rounds/s for both so the fused dequant->fedavg path is perf-tracked.

``--smoke`` additionally runs (a) a 1-session fleet against the
loop-engine oracle, (b) a CHURN scenario — contributors leave radio
range mid-session and contracts are re-negotiated — asserting full
parity including the per-round membership masks, (c) a FAULT scenario —
unreliable links drop, retry, and deliver stale round-(r-1) wire images
— asserting bitwise-identical fault masks/counters across engines plus
matching retry-energy accounting, and proving all three failure modes
actually fired, (d) a KILL-AND-RESUME gate — a checkpointed fleet run
is killed after its first chunk's checkpoint and resumed from disk; the
resumed outcome must be BIT-identical to the uninterrupted run,
(e) the ``--compare`` paper-claim rows (below), (e2) the ASYNC gates —
on the cadence world (``repro.core.cadence`` composed with the fault
world) both engines must agree bitwise on per-round clocks, idle-step
counters, and delivered masks (battery/params to the same tolerance the
churn gate uses) with >= 1 straggler round and >= 1 idle step provably
exercised, and a killed-and-resumed cadence run must restore the
per-lane clocks/idle counters bit-identically — (f) the TRACE gate —
a traced run (``repro.telemetry.TraceConfig``) must be BIT-identical to
the untraced one, its ``events.jsonl`` + ``trace.json`` exports (written
next to ``--out`` for the CI artifact upload) must round-trip
schema-valid, and both engines' event streams must agree — (f2) the
ROBUST gates — on a Byzantine world (``repro.core.adversary``) both
engines must agree BITWISE on the per-round corrupted/clipped masks
with >= 1 corruption and >= 1 norm-clip provably fired, ``robust="none"``
on a clean world must stay bit-identical to the undefended aggregation,
and on the pinned recovery world trimmed-mean screening must recover
>= 90% of the clean final accuracy under the noise attack while plain
fedavg does not — and (g) the PERF GATE:
at the largest fleet size shared with the committed
``BENCH_fleet.json`` (same config + backend), warm rounds/s must not
regress more than 25% on the machine that committed the baseline; on a
different host (fingerprint mismatch) the gate compares the
host-normalized ``speedup_vs_loop`` instead at a looser threshold —
nothing else stops a perf cliff merging.  The same gate runs over the
``results_faults`` sweep (below), so the fault-world round body is
perf-tracked too.  It exits non-zero on any regression — the CI gate.
Every gate verdict is logged as one ``[gate] <name> PASS|FAIL`` stderr
line; a failing gate names itself and fingerprints the report section
it judged, and ALL gates are evaluated before the non-zero exit.

* **faulty-world sweep** (``results_faults``) — the static sweep re-run
  with an unreliable-link world (drops + bounded retries + stale
  delivery): warm rounds/s per R, the drop/retry/stale totals, and the
  retry-energy overhead — extra receive windows priced through the ONE
  ``CostModel.retry_energy`` — alongside the clean-world energy so the
  robustness tax is a committed number.

* **async-cadence sweep** (``results_async``) — the static sweep with
  the lockstep round barrier broken (``repro.core.cadence``): per-device
  duty cycles put every lane on its own round clock.  Warm rounds/s per
  R, the straggler-lag histogram (how many event steps stale the
  aggregated wire images run), and the idle-step pricing — low-power
  listen windows through the ONE ``CostModel.idle_energy`` — next to
  the lockstep energy at the same R.  The ``--smoke`` perf gate covers
  this sweep too (``async_perf_gate``, same 0.75x threshold,
  section-parameterized; it arms itself on the first committed baseline
  that carries the section).

* **Byzantine-robust sweep** (``results_robust``) — the static sweep
  re-run under the pinned adversarial weather (``repro.core.adversary``,
  20% of contributor links corrupted per round) with trimmed-mean
  screening ON: warm rounds/s per R for the defended program, the
  corrupted-link totals, and the screening-energy overhead — one extra
  pass over the delivered buffer priced through the ONE
  ``CostModel.screening_energy`` — next to the clean energy at the same
  R.  The section also records the RECOVERY study (``recovery``): final
  accuracy on the bench MLP world for clean / attacked+``robust="none"``
  / attacked+``robust="trimmed_mean"`` arms under BOTH the pinned
  signflip attack and the noise attack.  The signflip arms document a
  protocol finding: EnFed ships MODEL IMAGES, so a minority sign-flip
  only shrinks the weighted average — which a ReLU MLP largely absorbs —
  and plain fedavg fails only when flipped mass outweighs honest mass,
  the same event that defeats a trim; the enforced recovery gate
  therefore runs on the noise arms, whose counter-keyed garbage payloads
  plain fedavg provably cannot absorb.  ``robust_perf_gate`` covers the
  sweep (same machinery as the fault/async gates).

``--compare`` runs ``repro.api.Experiment.compare(["enfed", "dfl"])``
through the one-call facade — both methods on ONE world, seed, and
CostModel — and writes TWO Table-style reduction rows into the JSON:
``enfed_vs_dfl`` on the tiny smoke config (a parity/cost-model gate
ONLY — at that scale the one-time handshake dwarfs a few milliseconds
of training, so its negative "reductions" say nothing about the paper
claim) and ``enfed_vs_dfl_paper`` on a paper-shaped world — encrypted
transport, a model big enough that transport matters, neighbors holding
WELL-TRAINED models (EnFed's premise), an achievable accuracy target —
where EnFed's fewer-rounds-to-target advantage shows as positive
time/energy reductions.  The ``enfed_vs_dfl`` row executes with
``ExecutionSpec(engine="fleet")``: since PR 6 the dfl/cfl baselines are
traced protocol variants of the same compiled fleet program, so the
row's baseline figures are SIMULATED at fleet scale, not extrapolated
from loop sessions.  ``results_compare_fleet`` measures that directly:
at the largest swept R every method runs as one compiled program and
reports its own measured warm wall — no ``loop_baseline_s_per_session``
multiplication anywhere in the row.

Each static-sweep row also carries a Timeline-derived ``breakdown``
(compile_s / warm_s / staging_s / checkpoint_s) from the engine's
host-side spans (``repro.telemetry.spans``).  Progress and gate
diagnostics go through stdlib ``logging`` on stderr (``-v`` debug,
``-q`` errors only); stdout stays machine-clean.

  PYTHONPATH=src python -m benchmarks.fleet_bench [--sizes 8,32,128,512]
      [--smoke] [--compare] [--out BENCH_fleet.json]
      [--perf-baseline PATH] [-v | -q]
"""

from __future__ import annotations

import argparse
import copy
import json
import logging
import sys
import time

import numpy as np

from repro.core import (AdversaryConfig, CadenceConfig, EnFedConfig,
                        EnFedSession, FaultConfig, MobilityConfig,
                        RequesterSpec, SupervisedTask, make_fleet, run_fleet)
from repro.core import mobility, schedule
from repro.core.cadence import tick_mask
from repro.data import CaloriesDatasetConfig, dirichlet_partition, make_calories_tabular
from repro.models import MLPClassifier, MLPClassifierConfig

BATCH = 32
N_CONTRIB = 3
LOOP_SAMPLE_SESSIONS = 3   # loop engine timed on this many, extrapolated

log = logging.getLogger("repro.bench.fleet")


def _setup_logging(verbosity: int) -> None:
    """Progress/gate logging on STDERR only — stdout stays machine-clean
    for anyone piping the report (the JSON itself goes to ``--out``).
    verbosity: -1 = errors only (-q), 0 = progress (default), 1 = -v."""
    level = (logging.ERROR if verbosity < 0
             else logging.DEBUG if verbosity > 0 else logging.INFO)
    handler = logging.StreamHandler(sys.stderr)
    handler.setFormatter(logging.Formatter("%(message)s"))
    log.handlers[:] = [handler]
    log.setLevel(level)
    log.propagate = False


def _build_problem(seed: int = 0, hidden=(32,), num_samples: int = 1200,
                   pretrain_epochs: int = 1):
    """Shared task + contributor population for every requester."""
    x, y = make_calories_tabular(CaloriesDatasetConfig(num_samples=num_samples,
                                                       seed=seed))
    task = SupervisedTask(MLPClassifier(MLPClassifierConfig(8, hidden, 5)), lr=3e-3)
    parts = dirichlet_partition(y, num_clients=N_CONTRIB + 1, alpha=100.0, seed=seed)
    shards = [(x[p], y[p]) for p in parts]
    fleet = make_fleet(N_CONTRIB, seed=seed + 1, p_has_model=1.0)
    states = {}
    for i, dev in enumerate(fleet):
        dev.reservation_price = 0.4
        p = task.init(seed=10 + i)
        p, _ = task.fit(p, shards[i + 1], epochs=pretrain_epochs,
                        batch_size=BATCH, seed=i)
        states[dev.device_id] = {"params": p, "data": shards[i + 1]}
    own_x, own_y = shards[0]
    n = int(len(own_x) * 0.8)
    return task, fleet, states, (own_x[:n], own_y[:n]), (own_x[n:], own_y[n:])


def _make_specs(R: int, own_train, own_test, fleet, states, seed: int = 0):
    rng = np.random.default_rng(seed)
    specs = []
    for _ in range(R):
        sel = rng.permutation(len(own_train[0]))[:4 * BATCH]
        specs.append(RequesterSpec(
            own_train=(own_train[0][sel], own_train[1][sel]),
            own_test=own_test, neighborhood=fleet, contributor_states=states))
    return specs


def _pr1_index_bytes(cfg: EnFedConfig, R: int, specs, states) -> int:
    """Bytes the PR 1 engine staged for minibatch scheduling: the
    host-materialized (max_rounds, R, epochs, steps, batch) fit_idx +
    fit_valid + the (R, N, ref_epochs, ref_steps, batch) refresh plan."""
    steps = max(schedule.fit_steps(len(s.own_train[0]), cfg.batch_size)
                for s in specs)
    fit_idx = 4 * cfg.max_rounds * R * cfg.epochs * steps * cfg.batch_size
    fit_valid = 4 * R * cfg.epochs * steps
    ref = 0
    if cfg.contributor_refresh_epochs > 0:
        ref_steps = max(schedule.fit_steps(len(st["data"][0]), cfg.batch_size)
                        for st in states.values())
        n = len(states)
        ref = (4 * R * n * cfg.contributor_refresh_epochs * ref_steps
               * (cfg.batch_size + 1))
    return fit_idx + fit_valid + ref


def _parity_smoke(task, fleet, states, own_train, own_test, cfg) -> dict:
    """1-session fleet vs the loop-engine oracle; the CI regression gate."""
    loop = EnFedSession(task, own_train, own_test, fleet,
                        copy.deepcopy(states), cfg).run()
    fl = run_fleet(task, [RequesterSpec(own_train, own_test, fleet,
                                        copy.deepcopy(states))],
                   cfg).sessions[0]
    if fl.rounds != loop.rounds or fl.stop_reason != loop.stop_reason:
        # histories have different lengths; report the structural
        # divergence instead of diffing them
        return {"pass": False, "rounds": (loop.rounds, fl.rounds),
                "stop": (loop.stop_reason, fl.stop_reason),
                "max_param_diff": None, "max_accuracy_diff": None}
    from jax.flatten_util import ravel_pytree
    lv, _ = ravel_pytree(loop.params)
    fv, _ = ravel_pytree(fl.params)
    max_diff = float(np.abs(np.asarray(lv) - np.asarray(fv)).max())
    acc_diff = float(np.abs(np.asarray(loop.history_raw["accuracy"])
                            - np.asarray(fl.history_raw["accuracy"])).max())
    ok = max_diff < 1e-4 and acc_diff < 1e-5
    return {"pass": bool(ok), "rounds": (loop.rounds, fl.rounds),
            "stop": (loop.stop_reason, fl.stop_reason),
            "max_param_diff": max_diff, "max_accuracy_diff": acc_diff}


def _compare_row(task, fleet, states, own_train, own_test,
                 cfg: EnFedConfig) -> dict:
    """The paper-claim row: EnFed vs DFL through the one-call facade.

    Both methods run on the SAME WorldSpec (requester shard, contributor
    states, seed) and the SAME CostModel instance — and, since PR 6,
    through the SAME compiled fleet program (``engine="fleet"``): the
    dfl row is a traced protocol variant, simulated, not an
    extrapolation.  The row is the Table-IV-style time/energy reduction.
    ``pass`` requires finite reduction percentages, both rows actually
    coming off the fleet engine, AND proof that the world's CostModel
    actually prices every method: the comparison is re-run on a world
    whose device profile draws 10x the power, and each method's reported
    energy must scale with it — a method silently costing through a
    private default CostModel would not move, and trips the CI gate."""
    import dataclasses

    from repro.api import ExecutionSpec, Experiment, MethodSpec, WorldSpec
    from repro.core import CostModel, DeviceProfile

    method = MethodSpec(
        desired_accuracy=cfg.desired_accuracy, max_rounds=cfg.max_rounds,
        epochs=cfg.epochs, batch_size=cfg.batch_size, encrypt=cfg.encrypt,
        contributor_refresh_epochs=cfg.contributor_refresh_epochs)
    execution = ExecutionSpec(engine="fleet")
    world = WorldSpec.single(task, own_train, own_test, fleet,
                             copy.deepcopy(states), seed=cfg.seed)
    exp = Experiment(world, method, execution)
    exp.compare(["enfed", "dfl"])        # warm the jit caches
    cmp = exp.compare(["enfed", "dfl"])
    row = cmp.reduction("enfed", "dfl")
    row["engines"] = {m: cmp[m].engine for m in ("enfed", "dfl")}

    d = DeviceProfile()
    hot = dataclasses.replace(
        d, p_tx=d.p_tx * 10, p_rx=d.p_rx * 10, p_init=d.p_init * 10,
        p_crypto=d.p_crypto * 10, p_agg=d.p_agg * 10, p_train=d.p_train * 10)
    world_hot = WorldSpec.single(task, own_train, own_test, fleet,
                                 copy.deepcopy(states), seed=cfg.seed,
                                 cost_model=CostModel(device=hot))
    cmp_hot = Experiment(world_hot, method, execution).compare(["enfed", "dfl"])
    row["cost_model_flows"] = bool(
        all(r.cost_model is world.cost_model for r in cmp)
        and cmp_hot["enfed"].energy_j > 2.0 * cmp["enfed"].energy_j
        and cmp_hot["dfl"].energy_j > 2.0 * cmp["dfl"].energy_j)
    _finalize_row(row,
                  extra_pass=(row["cost_model_flows"]
                              and all(e == "fleet"
                                      for e in row["engines"].values())),
                  note="smoke-scale gate config (tiny model, milliseconds "
                       "of training): the one-time handshake dominates, so "
                       "the reductions here are NOT the paper claim — see "
                       "enfed_vs_dfl_paper; both rows simulated by the "
                       "compiled fleet engine")
    return row


def _finalize_row(row: dict, *, note: str, extra_pass: bool = True) -> dict:
    """Shared CI-gate contract for every compare row: all reduction and
    time/energy figures finite, plus any row-specific condition."""
    vals = [row["time_reduction_pct"], row["energy_reduction_pct"],
            row["t_method_s"], row["t_baseline_s"],
            row["e_method_j"], row["e_baseline_j"]]
    row["pass"] = bool(extra_pass
                       and all(v is not None and np.isfinite(v) for v in vals))
    row["note"] = note
    return row


def _paper_compare_row() -> dict:
    """The honest paper-claim row: EnFed vs DFL on a paper-shaped world.

    EnFed's premise is leveraging neighbors that ALREADY hold trained
    models, with encrypted transport and a model big enough that
    transmission matters.  On that world EnFed reaches the target in
    fewer rounds than from-scratch DFL, which is the mechanism behind
    the paper's Table IV/V reductions; the tiny smoke row above cannot
    show it (its handshake constant dwarfs everything).  ``pass`` gates
    on finiteness + a reported-enfed-wins flag kept separate, so the
    row stays honest if a future change flips the outcome."""
    from repro.api import Experiment, MethodSpec, WorldSpec

    task, fleet, states, own_train, own_test = _build_problem(
        hidden=(128, 64), num_samples=2400, pretrain_epochs=8)
    method = MethodSpec(desired_accuracy=0.5, max_rounds=10, epochs=2,
                        batch_size=BATCH, encrypt=True,
                        contributor_refresh_epochs=1)
    world = WorldSpec.single(task, own_train, own_test, fleet,
                             copy.deepcopy(states), seed=0)
    exp = Experiment(world, method)
    exp.compare(["enfed", "dfl"])        # warm jit: T_loc is semi-empirical
    cmp = exp.compare(["enfed", "dfl"])
    row = cmp.reduction("enfed", "dfl")
    row["rounds_method"] = int(cmp["enfed"].rounds)
    row["rounds_baseline"] = int(cmp["dfl"].rounds)
    row["enfed_wins"] = bool(row["time_reduction_pct"] > 0
                             and row["energy_reduction_pct"] > 0)
    return _finalize_row(
        row, note="paper-shaped: encrypted, MLP(128,64), neighbors "
                  "pre-trained 8 epochs, achievable target 0.5 — EnFed "
                  "converges in fewer rounds than from-scratch DFL")


def _host_fingerprint() -> dict:
    """Coarse host identity for the perf gate: absolute rounds/s are
    only comparable on a like-for-like machine, so when the committed
    baseline came from different hardware (a cpu_count or arch change
    is the detectable proxy) the gate switches to the host-normalized
    ``speedup_vs_loop`` metric instead of comparing raw throughput."""
    import os
    import platform

    return {"machine": platform.machine(), "cpu_count": os.cpu_count()}


def _section_rows(sec) -> list:
    """A sweep section is a list of per-R rows, or (``results_robust``)
    a dict carrying the rows under ``"rows"`` next to the recovery
    study — the perf gate reads either shape."""
    if isinstance(sec, dict):
        return sec.get("rows", [])
    return sec or []


def _gate_fingerprint(section) -> str:
    """12-hex digest of the JSON-serialized section a gate judged —
    enough to tie a red CI line back to the exact evidence inside the
    uploaded ``BENCH_fleet.json``."""
    import hashlib

    blob = json.dumps(section, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:12]


def _perf_gate(report: dict, baseline_path: str, threshold: float = 0.75,
               section: str = "results") -> dict:
    """The CI perf gate: perf at the largest fleet size shared with the
    COMMITTED ``BENCH_fleet.json`` must be >= ``threshold`` x the
    committed number, under a matching (config, backend) fingerprint.

    On the machine that committed the baseline (matching host
    fingerprint) the gate compares absolute warm rounds/s.  On a
    DIFFERENT host, absolute rounds/s are meaningless, so the gate
    falls back to ``speedup_vs_loop`` — fleet warm time vs the loop
    engine extrapolation, both measured in the SAME run on the SAME
    machine — with a looser threshold (two noisy measurements instead
    of one).  Either way a real perf cliff (the fleet engine getting
    slow relative to its own baseline work) cannot merge silently; only
    a missing/config-mismatched baseline skips the gate.

    ``section`` selects which sweep the gate reads (``results`` is the
    clean static world; ``results_faults`` the unreliable-link world) —
    a baseline that predates the section skips cleanly, so a new sweep
    arms its gate on the first baseline commit that carries it."""
    try:
        with open(baseline_path) as f:
            base = json.load(f)
    except (OSError, ValueError):
        return {"pass": True, "skipped": f"no readable baseline at {baseline_path}"}
    if (base.get("config") != report["config"]
            or base.get("backend") != report["backend"]):
        return {"pass": True, "skipped": "baseline config/backend mismatch"}
    if base.get(section) is None:
        return {"pass": True, "skipped": f"baseline predates {section}"}
    same_host = base.get("host") == report["host"]
    metric = "rounds_per_s" if same_host else "speedup_vs_loop"
    if not same_host:
        threshold = 0.6
    base_rows = {r["R"]: r.get(metric)
                 for r in _section_rows(base.get(section))
                 if r.get(metric)}
    cur_rows = _section_rows(report[section])
    common = [row["R"] for row in cur_rows if row["R"] in base_rows]
    if not common:
        return {"pass": True, "skipped": "no common fleet size with baseline"}
    R = max(common)
    cur = next(r[metric] for r in cur_rows if r["R"] == R)
    ratio = cur / max(base_rows[R], 1e-9)
    return {"R": R, "section": section, "metric": metric,
            "same_host": same_host, "baseline": base_rows[R], "current": cur,
            "ratio": round(ratio, 3), "threshold": threshold,
            "pass": bool(ratio >= threshold)}


def _compress_sweep(sizes) -> list:
    """fp32 vs int8 round state, per fleet size, on a tile-amortizing
    model (MLP(64,32), P=2821 > 2 quantization tiles).  The smoke
    model's P=453 fits inside one 1024-wide tile, where padding eats the
    compression — honest physics, but not the regime the knob exists
    for, so the byte-reduction claim is measured here instead."""
    task, fleet, states, own_train, own_test = _build_problem(hidden=(64, 32))
    rows = []
    for R in sizes:
        row = {"R": R}
        for compress in (None, "int8"):
            cfg = EnFedConfig(desired_accuracy=0.999, max_rounds=3, epochs=1,
                              batch_size=BATCH, encrypt=False,
                              contributor_refresh_epochs=1, compress=compress)
            # fresh contributor states per run: run_fleet writes
            # refresh-trained params back, and the fp32 and int8 legs of
            # one row must measure the SAME world
            specs = _make_specs(R, own_train, own_test, fleet,
                                copy.deepcopy(states))
            run_fleet(task, specs, cfg)                 # compile
            specs = _make_specs(R, own_train, own_test, fleet,
                                copy.deepcopy(states))
            t0 = time.perf_counter()
            result = run_fleet(task, specs, cfg)
            wall = time.perf_counter() - t0
            row["int8" if compress else "fp32"] = {
                "warm_s": round(wall, 4),
                "rounds_per_s": round(int(result.rounds.sum()) / wall, 2),
                "staged_param_bytes": result.staged_param_bytes,
                "device_round_state_bytes": result.device_round_state_bytes}
        row["staged_param_reduction_x"] = round(
            row["fp32"]["staged_param_bytes"]
            / max(row["int8"]["staged_param_bytes"], 1), 2)
        row["device_state_reduction_x"] = round(
            row["fp32"]["device_round_state_bytes"]
            / max(row["int8"]["device_round_state_bytes"], 1), 2)
        rows.append(row)
        log.info(f"[compress R={R:4d}] fp32 {row['fp32']['rounds_per_s']:7.1f} r/s"
                 f" | int8 {row['int8']['rounds_per_s']:7.1f} r/s | "
                 f"staged {row['fp32']['staged_param_bytes']} -> "
                 f"{row['int8']['staged_param_bytes']} B "
                 f"({row['staged_param_reduction_x']}x), device state "
                 f"{row['device_state_reduction_x']}x")
    return rows


def _baseline_parity_smoke(task, fleet, states, own_train, own_test) -> dict:
    """dfl-as-a-fleet-lane vs the DFLLearner loop oracle: the CI gate for
    the method-variant path (``run_fleet(method="dfl")``) that the
    compare rows now execute through."""
    from repro.core.federated import DFLLearner

    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=2, epochs=1,
                      batch_size=BATCH, seed=0)
    data = [own_train] + [states[dev.device_id]["data"] for dev in fleet]
    loop = DFLLearner(task, data, own_test, "mesh").run_config(cfg)
    fl = run_fleet(task, [RequesterSpec(own_train, own_test, fleet,
                                        copy.deepcopy(states))],
                   cfg, method="dfl").sessions[0]
    out = {"pass": False, "rounds": (loop.rounds, fl.rounds)}
    if fl.rounds != loop.rounds:
        return out
    from jax.flatten_util import ravel_pytree
    lv, _ = ravel_pytree(loop.params)
    fv, _ = ravel_pytree(fl.params)
    out["max_param_diff"] = float(np.abs(np.asarray(lv) - np.asarray(fv)).max())
    out["max_accuracy_diff"] = float(np.abs(
        np.asarray(loop.history_raw["accuracy"])
        - np.asarray(fl.history_raw["accuracy"])).max())
    out["pass"] = bool(out["max_param_diff"] < 1e-4
                       and out["max_accuracy_diff"] < 1e-5)
    return out


def _fleet_compare_sweep(task, fleet, states, own_train, own_test,
                         R: int) -> dict:
    """Every method of the comparison as ONE compiled fleet program at
    the largest swept R — each row's warm wall is MEASURED on that
    method's own program, never derived from the loop-engine
    extrapolation (the pre-PR-6 dfl/cfl rows were loop runs, so a
    512-session comparison was R x one Python session)."""
    from repro.api import ExecutionSpec, Experiment, MethodSpec, WorldSpec

    method = MethodSpec(desired_accuracy=0.999, max_rounds=3, epochs=1,
                        batch_size=BATCH, encrypt=False,
                        contributor_refresh_epochs=1)
    out = {"R": R, "measured": True, "methods": {}}
    for name in ("enfed", "dfl", "cfl"):
        world = WorldSpec(task=task,
                          requesters=_make_specs(R, own_train, own_test,
                                                 fleet,
                                                 copy.deepcopy(states)),
                          seed=0)
        exp = Experiment(world, method, ExecutionSpec(engine="fleet"))
        exp.run(name)                                  # compile
        t0 = time.perf_counter()
        res = exp.run(name)
        wall = time.perf_counter() - t0
        total_rounds = int(sum(s.rounds for s in res.sessions))
        out["methods"][name] = {
            "engine": res.engine,
            "warm_s": round(wall, 4),
            "session_rounds": total_rounds,
            "rounds_per_s": round(total_rounds / wall, 2),
            "simulated_energy_j": round(res.energy_j * len(res.sessions), 2)
            if res.raw is None else round(res.raw.total_energy_j, 2)}
        m = out["methods"][name]
        log.info(f"[compare-fleet R={R:4d}] {name:5s} warm {m['warm_s']:7.3f}s"
                 f" | {m['session_rounds']} session-rounds -> "
                 f"{m['rounds_per_s']:8.1f} rounds/s | "
                 f"E={m['simulated_energy_j']:.1f}J (measured, engine="
                 f"{m['engine']})")
    out["pass"] = bool(all(m["engine"] == "fleet"
                           and np.isfinite(m["rounds_per_s"])
                           and np.isfinite(m["simulated_energy_j"])
                           for m in out["methods"].values()))
    return out


def _fleet_compare_gate(report: dict, baseline_path: str,
                        threshold: float = 0.75) -> dict:
    """Perf gate for the method-variant path: the dfl fleet program's
    warm rounds/s at the compare-sweep R must not regress against the
    committed baseline.  Skips cleanly when the committed
    ``BENCH_fleet.json`` predates the ``results_compare_fleet`` section
    (the gate arms itself on the first post-PR-6 baseline commit), on a
    config/backend mismatch, or on a different host — where it falls
    back to the host-normalized dfl/enfed throughput ratio."""
    try:
        with open(baseline_path) as f:
            base = json.load(f)
    except (OSError, ValueError):
        return {"pass": True, "skipped": f"no readable baseline at {baseline_path}"}
    sec = base.get("results_compare_fleet")
    if not sec:
        return {"pass": True,
                "skipped": "baseline predates results_compare_fleet"}
    if (base.get("config") != report["config"]
            or base.get("backend") != report["backend"]):
        return {"pass": True, "skipped": "baseline config/backend mismatch"}
    cur = report["results_compare_fleet"]
    if sec.get("R") != cur["R"]:
        return {"pass": True, "skipped": "compare-sweep R mismatch"}
    same_host = base.get("host") == report["host"]

    def rel(section):
        enfed = section["methods"]["enfed"]["rounds_per_s"]
        return section["methods"]["dfl"]["rounds_per_s"] / max(enfed, 1e-9)

    if same_host:
        metric, b, c = "dfl_rounds_per_s", \
            sec["methods"]["dfl"]["rounds_per_s"], \
            cur["methods"]["dfl"]["rounds_per_s"]
    else:
        metric, b, c, threshold = "dfl_vs_enfed_throughput", \
            rel(sec), rel(cur), 0.6
    ratio = c / max(b, 1e-9)
    return {"R": cur["R"], "metric": metric, "same_host": same_host,
            "baseline": round(b, 2), "current": round(c, 2),
            "ratio": round(ratio, 3), "threshold": threshold,
            "pass": bool(ratio >= threshold)}


def _churn_mobility() -> MobilityConfig:
    """The benchmark's opportunistic world: devices re-waypoint every
    round inside a 200 m arena with a 95 m radio range — enough motion
    that a contract-holding contributor walks out of range mid-session
    (>= 25% of the pool leaves at least once) and re-negotiation signs
    replacements."""
    return MobilityConfig(radio_range_m=95.0, leg_rounds=1, seed=5)


def _membership_stats(result) -> dict:
    """Fleet-level churn statistics from the (max_rounds, R, N) trace.

    Join/leave transitions only count between rounds a lane actually
    EXECUTED — a session stopping (or the fleet early-exiting) zeroes
    its trailing trace rows, which is termination, not radio churn."""
    member = result.history_raw["member"] > 0            # (T, R, N)
    executed = result.history_raw["executed"] > 0        # (T, R)
    both = (executed[1:] & executed[:-1])[..., None]
    diff = member[1:].astype(np.int8) - member[:-1].astype(np.int8)
    joins = int(((diff > 0) & both).sum())
    leaves = int(((diff < 0) & both).sum())
    exec_rounds = max(float(executed.sum()), 1.0)
    counts = member.sum(-1)
    return {
        "mean_members_per_round": round(
            float((counts * executed).sum() / exec_rounds), 3),
        "join_events": joins, "leave_events": leaves,
        "empty_neighborhood_rounds": int(((counts == 0) & executed).sum()),
        "member_rounds": int((member & executed[..., None]).sum())}


def _churn_smoke(task, fleet, states, own_train, own_test) -> dict:
    """Churn parity gate: a session whose contributor set is provably
    re-negotiated mid-run (members leave radio range / arrivals sign)
    must match the loop-engine oracle on rounds, stop reason, membership
    masks, params, and battery trajectory."""
    cfg = EnFedConfig(desired_accuracy=0.999, max_rounds=6, epochs=1,
                      batch_size=BATCH, encrypt=False, n_max=2,
                      contributor_refresh_epochs=1,
                      mobility=_churn_mobility())
    loop = EnFedSession(task, own_train, own_test, fleet,
                        copy.deepcopy(states), cfg).run()
    res = run_fleet(task, [RequesterSpec(own_train, own_test, fleet,
                                         copy.deepcopy(states))], cfg)
    fl = res.sessions[0]
    out = {"pass": False, "rounds": (loop.rounds, fl.rounds),
           "stop": (loop.stop_reason, fl.stop_reason),
           "loop_members": loop.history_raw["members"],
           "fleet_members": fl.history_raw["members"]}
    if fl.rounds != loop.rounds or fl.stop_reason != loop.stop_reason:
        return out
    masks_l = np.array(loop.history_raw["member_mask"])
    masks_f = np.array(fl.history_raw["member_mask"])
    out["mask_match"] = bool((masks_l == masks_f).all())
    joins, leaves = mobility.membership_events(masks_l)
    out["join_events"], out["leave_events"] = joins, leaves
    # the gate must exercise RE-NEGOTIATION: >= 25% of the pool (here,
    # >= 1 of 3 contributors) leaves mid-session
    out["churned"] = leaves >= max(1, len(fleet) // 4)
    from jax.flatten_util import ravel_pytree
    lv, _ = ravel_pytree(loop.params)
    fv, _ = ravel_pytree(fl.params)
    out["max_param_diff"] = float(np.abs(np.asarray(lv) - np.asarray(fv)).max())
    out["max_battery_diff"] = float(np.abs(
        np.asarray(loop.history_raw["battery"])
        - np.asarray(fl.history_raw["battery"])).max())
    out["pass"] = bool(out["mask_match"] and out["churned"]
                       and out["max_param_diff"] < 1e-4
                       and out["max_battery_diff"] < 1e-5)
    return out


def _fault_world() -> FaultConfig:
    """The benchmark's unreliable-link world: 60% per-attempt drop odds
    with ONE retry (36% of links fail a round outright), 40% of
    deliveries stale, and a 2-round blocked streak before a link is
    quarantined — enough weather that drops, retries, AND stale
    deliveries all fire within a 3-4 round session."""
    return FaultConfig(p_drop=0.6, p_stale=0.4, max_retries=1,
                       release_after=2, seed=3)


def _fault_parity_smoke(task, fleet, states, own_train, own_test) -> dict:
    """Fault parity gate: both engines roll the SAME counter-based link
    weather, so the drop/retry/stale counters and per-round delivered
    masks must be BITWISE equal, the degraded aggregation must agree on
    params, and the retry windows must be priced identically through the
    one CostModel.  The gate also proves the scenario exercises every
    failure mode — a fault world where nothing fails gates nothing."""
    cfg = EnFedConfig(desired_accuracy=0.999, max_rounds=4, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1, faults=_fault_world())
    loop = EnFedSession(task, own_train, own_test, fleet,
                        copy.deepcopy(states), cfg).run()
    fl = run_fleet(task, [RequesterSpec(own_train, own_test, fleet,
                                        copy.deepcopy(states))],
                   cfg).sessions[0]
    tot = {k: int(np.sum(loop.history_raw[k]))
           for k in ("drops", "retries", "stale")}
    out = {"pass": False, "rounds": (loop.rounds, fl.rounds),
           "stop": (loop.stop_reason, fl.stop_reason), **tot}
    if fl.rounds != loop.rounds or fl.stop_reason != loop.stop_reason:
        return out
    out["counters_match"] = bool(all(
        np.array_equal(fl.history_raw[k], loop.history_raw[k])
        for k in ("drops", "retries", "stale")))
    lm = np.stack(loop.history_raw["deliver_mask"])
    fm = np.stack(fl.history_raw["deliver_mask"])
    out["mask_match"] = bool(np.array_equal(fm[:, :lm.shape[1]], lm)
                             and not fm[:, lm.shape[1]:].any())
    from jax.flatten_util import ravel_pytree
    lv, _ = ravel_pytree(loop.params)
    fv, _ = ravel_pytree(fl.params)
    out["max_param_diff"] = float(np.abs(np.asarray(lv) - np.asarray(fv)).max())
    out["max_ecomm_diff"] = float(abs(fl.report.e_comm - loop.report.e_comm))
    out["all_modes_fired"] = bool(tot["drops"] > 0 and tot["retries"] > 0
                                  and tot["stale"] > 0)
    out["pass"] = bool(out["counters_match"] and out["mask_match"]
                       and out["all_modes_fired"]
                       and out["max_param_diff"] < 1e-4
                       and out["max_ecomm_diff"] < 1e-3)
    return out


def _async_cadence() -> CadenceConfig:
    """The benchmark's async world: two speed classes, seed 0 — on this
    fleet the requester draws stride 2 (every other global event step is
    a priced idle step) and one contributor draws stride 2 on the
    OPPOSITE phase, so it never ticks on an executed step: every
    aggregation consumes its resident (straggler) wire image."""
    return CadenceConfig(n_speed_classes=2, seed=0)


def _straggler_lag_hist(result, cc, device_ids) -> dict:
    """{lag: count} over every (lane, executed round, contributor).

    A contributor's lag at an executed round is the round's global event
    step minus the contributor's last tick step at or before it — 0
    means it refreshed for this round, lag > 0 means the aggregation
    consumed a wire image that many event steps stale (the straggler
    path).  The cadence is counter-based, so the histogram is exactly
    recomputable host-side from ``tick_mask``."""
    clock_h = np.asarray(result.history_raw["round_clock"])    # (T, R)
    rounds = np.asarray(result.rounds)
    max_t = int(clock_h.max(initial=0))
    ticks = np.stack([np.asarray(tick_mask(cc, t, device_ids), bool)
                      for t in range(max_t + 1)])              # (S, N)
    steps = np.arange(max_t + 1)[:, None]
    last = np.maximum.accumulate(np.where(ticks, steps, -1), axis=0)
    lags = []
    for i in range(clock_h.shape[1]):
        for t in clock_h[:int(rounds[i]), i]:
            lags.extend((int(t) - last[int(t)]).tolist())
    vals, counts = np.unique(np.asarray(lags, int), return_counts=True)
    return {str(int(v)): int(c) for v, c in zip(vals, counts)}


def _async_parity_smoke(task, fleet, states, own_train, own_test) -> dict:
    """Async-cadence parity gate: on the cadence world (composed with
    the fault world so delivered masks exist) both engines must agree
    BITWISE on the per-round clocks, idle-step counters, and delivered
    masks, to float tolerance on battery/params (the engines' long-
    standing f32-vs-f64 energy-staging gap, same bound the churn gate
    uses), with identical idle-time pricing — and the scenario must
    provably exercise >= 1 straggler round AND >= 1 idle step, else the
    gate gates nothing."""
    cc = _async_cadence()
    cfg = EnFedConfig(desired_accuracy=0.999, max_rounds=4, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1, faults=_fault_world(),
                      cadence=cc)
    loop = EnFedSession(task, own_train, own_test, fleet,
                        copy.deepcopy(states), cfg).run()
    fl = run_fleet(task, [RequesterSpec(own_train, own_test, fleet,
                                        copy.deepcopy(states))],
                   cfg).sessions[0]
    out = {"pass": False, "rounds": (loop.rounds, fl.rounds),
           "stop": (loop.stop_reason, fl.stop_reason)}
    if fl.rounds != loop.rounds or fl.stop_reason != loop.stop_reason:
        return out
    out["clocks_bit_equal"] = bool(list(loop.history_raw["round_clock"])
                                   == list(fl.history_raw["round_clock"]))
    out["idle_bit_equal"] = bool(list(loop.history_raw["idle_steps"])
                                 == list(fl.history_raw["idle_steps"]))
    lm = np.stack(loop.history_raw["deliver_mask"])
    fm = np.stack(fl.history_raw["deliver_mask"])
    out["mask_bit_equal"] = bool(np.array_equal(fm[:, :lm.shape[1]], lm)
                                 and not fm[:, lm.shape[1]:].any())
    out["max_battery_diff"] = float(np.abs(
        np.asarray(loop.history_raw["battery"])
        - np.asarray(fl.history_raw["battery"])).max())
    from jax.flatten_util import ravel_pytree
    lv, _ = ravel_pytree(loop.params)
    fv, _ = ravel_pytree(fl.params)
    out["max_param_diff"] = float(np.abs(np.asarray(lv) - np.asarray(fv)).max())
    out["max_tcom_diff"] = float(abs(fl.report.times.t_com
                                     - loop.report.times.t_com))
    ids = np.array([d.device_id for d in fleet], np.int32)
    out["straggler_rounds"] = int(sum(
        int((~np.asarray(tick_mask(cc, t, ids))).sum())
        for t in loop.history_raw["round_clock"]))
    out["idle_steps"] = int(np.sum(loop.history_raw["idle_steps"]))
    out["pass"] = bool(out["clocks_bit_equal"] and out["idle_bit_equal"]
                       and out["mask_bit_equal"]
                       and out["straggler_rounds"] >= 1
                       and out["idle_steps"] >= 1
                       and out["max_param_diff"] < 1e-4
                       and out["max_battery_diff"] < 1e-5
                       and out["max_tcom_diff"] < 1e-9)
    return out


def _async_resume_smoke(task, fleet, states, own_train, own_test) -> dict:
    """Kill-and-resume gate with the cadence ON: checkpoints land at
    EVENT-step boundaries under the async world, and the resumed run
    must restore the per-lane round clocks and idle counters — not just
    params/battery/masks — bit-identically to the uninterrupted run."""
    import glob
    import os
    import tempfile

    cfg = EnFedConfig(desired_accuracy=0.999, max_rounds=4, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1, faults=_fault_world(),
                      cadence=_async_cadence())

    def _specs():
        return [RequesterSpec(own_train, own_test, fleet,
                              copy.deepcopy(states))]

    with tempfile.TemporaryDirectory() as d:
        full = run_fleet(task, _specs(), cfg, round_chunk=2,
                         checkpoint_dir=os.path.join(d, "full"),
                         checkpoint_every=2)
        kill_dir = os.path.join(d, "kill")
        run_fleet(task, _specs(), cfg, round_chunk=2,
                  checkpoint_dir=kill_dir, checkpoint_every=2)
        removed = 0
        for f in glob.glob(os.path.join(kill_dir, "step_*.npz")):
            if int(os.path.basename(f)[5:13]) > 2:
                os.remove(f)
                removed += 1
        res = run_fleet(task, _specs(), cfg, round_chunk=2,
                        resume_from=kill_dir)
    from jax.flatten_util import ravel_pytree
    fv, _ = ravel_pytree(full.sessions[0].params)
    rv, _ = ravel_pytree(res.sessions[0].params)
    fh, rh = full.sessions[0].history_raw, res.sessions[0].history_raw
    out = {"checkpoints_killed": removed,
           "rounds": (full.sessions[0].rounds, res.sessions[0].rounds),
           "params_bit_equal": bool(np.array_equal(np.asarray(fv),
                                                   np.asarray(rv))),
           "battery_bit_equal": bool(np.array_equal(
               np.asarray(full.battery_level), np.asarray(res.battery_level))),
           "deliver_bit_equal": bool(np.array_equal(
               full.history_raw["deliver"], res.history_raw["deliver"])),
           "clocks_bit_equal": bool(list(fh["round_clock"])
                                    == list(rh["round_clock"])),
           "idle_bit_equal": bool(list(fh["idle_steps"])
                                  == list(rh["idle_steps"]))}
    out["pass"] = bool(removed > 0 and out["params_bit_equal"]
                       and out["battery_bit_equal"]
                       and out["deliver_bit_equal"]
                       and out["clocks_bit_equal"] and out["idle_bit_equal"]
                       and res.sessions[0].rounds == full.sessions[0].rounds)
    return out


def _resume_smoke(task, fleet, states, own_train, own_test) -> dict:
    """Kill-and-resume gate: a checkpointed fleet run (2-round chunks,
    checkpoint every chunk) is 'crashed' by deleting every checkpoint
    past the first, then resumed from disk — and the resumed run must be
    BIT-identical (params, battery, delivered masks) to an uninterrupted
    run of the same chunked program."""
    import glob
    import os
    import tempfile

    cfg = EnFedConfig(desired_accuracy=0.999, max_rounds=4, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1, faults=_fault_world())

    def _specs():
        return [RequesterSpec(own_train, own_test, fleet,
                              copy.deepcopy(states))]

    with tempfile.TemporaryDirectory() as d:
        full = run_fleet(task, _specs(), cfg, round_chunk=2,
                         checkpoint_dir=os.path.join(d, "full"),
                         checkpoint_every=2)
        kill_dir = os.path.join(d, "kill")
        run_fleet(task, _specs(), cfg, round_chunk=2,
                  checkpoint_dir=kill_dir, checkpoint_every=2)
        removed = 0
        for f in glob.glob(os.path.join(kill_dir, "step_*.npz")):
            if int(os.path.basename(f)[5:13]) > 2:
                os.remove(f)
                removed += 1
        res = run_fleet(task, _specs(), cfg, round_chunk=2,
                        resume_from=kill_dir)
    from jax.flatten_util import ravel_pytree
    fv, _ = ravel_pytree(full.sessions[0].params)
    rv, _ = ravel_pytree(res.sessions[0].params)
    out = {"checkpoints_killed": removed,
           "rounds": (full.sessions[0].rounds, res.sessions[0].rounds),
           "params_bit_equal": bool(np.array_equal(np.asarray(fv),
                                                   np.asarray(rv))),
           "battery_bit_equal": bool(np.array_equal(
               np.asarray(full.battery_level), np.asarray(res.battery_level))),
           "deliver_bit_equal": bool(np.array_equal(
               full.history_raw["deliver"], res.history_raw["deliver"]))}
    out["pass"] = bool(removed > 0 and out["params_bit_equal"]
                       and out["battery_bit_equal"]
                       and out["deliver_bit_equal"]
                       and res.sessions[0].rounds == full.sessions[0].rounds)
    return out


def _byzantine_world(attack: str = "signflip") -> AdversaryConfig:
    """The pinned adversarial weather for the robust sweep and gates:
    20% of contributor links Byzantine each round.  Draws are
    counter-keyed on (seed, round, requester, contributor), so both
    engines — and every rerun on every host — derive the exact same
    corrupted set; the recovery numbers below are deterministic, not a
    sampled estimate."""
    return AdversaryConfig(p_byzantine=0.2, attack=attack, scale=2.0, seed=3)


def _robust_parity_smoke(task, fleet, states, own_train, own_test) -> dict:
    """Byzantine parity gate: both engines roll the SAME counter-based
    corruption draws, so the per-round corrupted masks must be BITWISE
    equal, and under ``robust="clip"`` the norm-clip verdicts (which
    depend on the corrupted buffers) must be bitwise equal too.  The
    scenario must provably corrupt AND clip — an adversary that never
    fires gates nothing — and ``robust="none"`` on a clean world
    (p_byzantine=0) must stay bit-identical to the undefended
    aggregation path, so the defense machinery costs honest worlds
    nothing."""
    adv = AdversaryConfig(p_byzantine=0.5, attack="scale", scale=50.0, seed=7)
    cfg = EnFedConfig(desired_accuracy=0.999, max_rounds=3, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1, adversary=adv,
                      robust="clip")
    loop = EnFedSession(task, own_train, own_test, fleet,
                        copy.deepcopy(states), cfg).run()
    fl = run_fleet(task, [RequesterSpec(own_train, own_test, fleet,
                                        copy.deepcopy(states))],
                   cfg).sessions[0]
    out = {"pass": False, "rounds": (loop.rounds, fl.rounds),
           "stop": (loop.stop_reason, fl.stop_reason)}
    if fl.rounds != loop.rounds or fl.stop_reason != loop.stop_reason:
        return out
    for key, name in (("corrupted_mask", "corrupted"),
                      ("clipped_mask", "clipped")):
        lm = np.stack(loop.history_raw[key])
        fm = np.stack(fl.history_raw[key])
        out[f"{name}_bit_equal"] = bool(np.array_equal(fm[:, :lm.shape[1]], lm)
                                        and not fm[:, lm.shape[1]:].any())
        out[f"{name}_links"] = int(lm.sum())
    from jax.flatten_util import ravel_pytree
    lv, _ = ravel_pytree(loop.params)
    fv, _ = ravel_pytree(fl.params)
    out["max_param_diff"] = float(np.abs(np.asarray(lv) - np.asarray(fv)).max())
    out["tagg_rel_diff"] = float(
        abs(fl.report.times.t_agg - loop.report.times.t_agg)
        / max(abs(loop.report.times.t_agg), 1e-12))
    # none-on-clean identity: an armed-but-silent adversary (p=0) plus
    # robust="none" must reproduce the pre-defense aggregation bit for bit
    base = EnFedConfig(desired_accuracy=0.999, max_rounds=3, epochs=1,
                       batch_size=BATCH, encrypt=False,
                       contributor_refresh_epochs=1)
    p0 = EnFedConfig(desired_accuracy=0.999, max_rounds=3, epochs=1,
                     batch_size=BATCH, encrypt=False,
                     contributor_refresh_epochs=1, robust="none",
                     adversary=AdversaryConfig(p_byzantine=0.0, attack="scale",
                                               scale=50.0, seed=7))
    a = run_fleet(task, [RequesterSpec(own_train, own_test, fleet,
                                       copy.deepcopy(states))],
                  base).sessions[0]
    b = run_fleet(task, [RequesterSpec(own_train, own_test, fleet,
                                       copy.deepcopy(states))],
                  p0).sessions[0]
    av, _ = ravel_pytree(a.params)
    bv, _ = ravel_pytree(b.params)
    out["clean_world_bit_identical"] = bool(
        np.array_equal(np.asarray(av), np.asarray(bv)))
    out["pass"] = bool(out["corrupted_bit_equal"] and out["clipped_bit_equal"]
                       and out["corrupted_links"] >= 1
                       and out["clipped_links"] >= 1
                       and out["clean_world_bit_identical"]
                       and out["max_param_diff"] < 1e-4
                       and out["tagg_rel_diff"] < 1e-6)
    return out


def _robust_recovery_rows(R: int = 8, max_rounds: int = 6) -> dict:
    """Final accuracy on the bench MLP world under the pinned Byzantine
    weather, three arms per attack: clean, attacked + ``robust="none"``,
    attacked + ``robust="trimmed_mean"``.  Contributors pre-train 8
    epochs (the paper-shaped premise: neighbors hold WELL-TRAINED
    models) so the clean arm has accuracy worth defending.

    Both the ISSUE-pinned SIGNFLIP attack and the NOISE attack are
    recorded.  Signflip arms document the absorption finding (EnFed
    ships model images; a minority flip shrinks the weighted average,
    which the ReLU MLP largely absorbs — plain fedavg only fails when
    flipped mass outweighs honest mass, exactly the event that defeats
    a trim, so none-vs-trimmed CANNOT separate under signflip on this
    protocol at any world shape); the recovery gate is enforced on the
    noise arms, whose garbage payloads plain fedavg cannot absorb."""
    task, fleet, states, own_train, own_test = _build_problem(
        pretrain_epochs=8)
    base = dict(desired_accuracy=0.999, max_rounds=max_rounds, epochs=1,
                batch_size=BATCH, encrypt=False, contributor_refresh_epochs=1)

    def _arm(adversary, robust):
        cfg = EnFedConfig(**base, adversary=adversary, robust=robust)
        specs = _make_specs(R, own_train, own_test, fleet,
                            copy.deepcopy(states), seed=4)
        result = run_fleet(task, specs, cfg)
        acc = float(np.mean([s.accuracy for s in result.sessions]))
        corrupted = (int(np.sum(result.history_raw["corrupted"]))
                     if adversary is not None else 0)
        return acc, corrupted

    clean_acc, _ = _arm(None, "none")
    out = {"R": R, "max_rounds": max_rounds, "pretrain_epochs": 8,
           "p_byzantine": 0.2, "seed": 3,
           "clean_final_accuracy": round(clean_acc, 4), "attacks": {}}
    for attack in ("signflip", "noise"):
        adv = _byzantine_world(attack)
        none_acc, none_corr = _arm(adv, "none")
        trim_acc, trim_corr = _arm(adv, "trimmed_mean")
        out["attacks"][attack] = {
            "final_accuracy_none": round(none_acc, 4),
            "final_accuracy_trimmed_mean": round(trim_acc, 4),
            "ratio_none": round(none_acc / max(clean_acc, 1e-9), 4),
            "ratio_trimmed_mean": round(trim_acc / max(clean_acc, 1e-9), 4),
            "corrupted_links": none_corr,
            "corrupted_links_trimmed_mean": trim_corr}
    out["note"] = (
        "signflip arms are recorded, not gated: EnFed transports MODEL "
        "IMAGES, so a minority sign-flip shrinks the weighted average — "
        "near-invisible to the (positively homogeneous) ReLU MLP — and "
        "plain fedavg only fails when flipped mass outweighs honest "
        "mass, the same event that defeats a trim; the enforced "
        "recovery gate runs on the noise attack, whose counter-keyed "
        "garbage payloads plain fedavg provably cannot absorb")
    return out


def _robust_recovery_gate(recovery: dict) -> dict:
    """The CI recovery gate, on the noise arms of the recovery study:
    trimmed-mean screening must recover >= 90% of the clean final
    accuracy while plain fedavg must NOT — and corruption must provably
    fire in every attacked arm (a silent adversary gates nothing)."""
    noise = recovery["attacks"]["noise"]
    fired = all(a["corrupted_links"] >= 1
                and a["corrupted_links_trimmed_mean"] >= 1
                for a in recovery["attacks"].values())
    out = {"attack": "noise", "threshold": 0.9,
           "ratio_none": noise["ratio_none"],
           "ratio_trimmed_mean": noise["ratio_trimmed_mean"],
           "corruption_fired": bool(fired)}
    out["pass"] = bool(fired
                       and noise["ratio_trimmed_mean"] >= 0.9
                       and noise["ratio_none"] < 0.9)
    return out


def _trace_smoke(task, fleet, states, own_train, own_test,
                 out_path: str | None) -> dict:
    """Trace gate: the telemetry house rule, CI-enforced.

    A traced fleet run (event JSONL + Chrome trace exports, on the fault
    world so delivered masks exist) must be BIT-identical — params,
    delivered masks, battery trajectory — to the identical run with
    tracing off; the exported artifacts must round-trip schema-valid;
    and the loop engine's event stream for the same world must equal the
    fleet engine's (``compare_event_streams`` = []).  The artifacts land
    next to ``--out`` so CI uploads them with ``BENCH_fleet.json``."""
    import os

    from repro.api import (ExecutionSpec, Experiment, MethodSpec,
                           TraceConfig, WorldSpec)
    from repro.telemetry import (compare_event_streams, read_events_jsonl,
                                 validate_events)

    method = MethodSpec(desired_accuracy=0.999, max_rounds=4, epochs=1,
                        batch_size=BATCH, encrypt=False,
                        contributor_refresh_epochs=1, faults=_fault_world())

    def _world():
        return WorldSpec.single(task, own_train, own_test, fleet,
                                copy.deepcopy(states), seed=0)

    out_dir = (os.path.dirname(os.path.abspath(out_path))
               if out_path else os.getcwd())
    ev_path = os.path.join(out_dir, "events.jsonl")
    tr_path = os.path.join(out_dir, "trace.json")
    trace = TraceConfig(events_jsonl=ev_path, chrome_trace=tr_path)
    res_off = Experiment(_world(), method,
                         ExecutionSpec(engine="fleet")).run()
    res_on = Experiment(_world(), method,
                        ExecutionSpec(engine="fleet", trace=trace)).run()
    res_loop = Experiment(_world(), method,
                          ExecutionSpec(engine="loop")).run()

    from jax.flatten_util import ravel_pytree
    ov, _ = ravel_pytree(res_off.params)
    nv, _ = ravel_pytree(res_on.params)
    out = {"pass": False, "artifacts": [ev_path, tr_path],
           "params_bit_equal": bool(np.array_equal(np.asarray(ov),
                                                   np.asarray(nv))),
           "deliver_bit_equal": bool(np.array_equal(
               np.stack(res_off.history_raw["deliver_mask"]),
               np.stack(res_on.history_raw["deliver_mask"]))),
           "battery_bit_equal": bool(np.array_equal(
               np.asarray(res_off.history_raw["battery"]),
               np.asarray(res_on.history_raw["battery"])))}
    try:
        out["events"] = len(validate_events(read_events_jsonl(ev_path)))
        with open(tr_path) as f:
            out["trace_events"] = len(json.load(f)["traceEvents"])
    except (OSError, ValueError, KeyError) as e:
        out["export_error"] = f"{type(e).__name__}: {e}"
        return out
    out["cross_engine_diffs"] = compare_event_streams(res_loop.trace,
                                                      res_on.trace)
    out["pass"] = bool(out["params_bit_equal"] and out["deliver_bit_equal"]
                       and out["battery_bit_equal"] and out["events"] > 0
                       and out["trace_events"] > 0
                       and not out["cross_engine_diffs"])
    return out


def run(verbose: bool = True, sizes=(8, 32, 128, 512), smoke: bool = False,
        compare: bool = False, out: str | None = None,
        perf_baseline: str | None = None):
    import jax

    # benchmarks.run calls run(verbose=...) directly (no CLI flags);
    # self-configure stderr logging unless main() already did
    if not log.handlers:
        _setup_logging(0 if verbose else -1)

    task, fleet, states, own_train, own_test = _build_problem()
    cfg = EnFedConfig(desired_accuracy=0.999, max_rounds=3, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1)
    report = {"backend": jax.default_backend(),
              "config": {"max_rounds": cfg.max_rounds, "epochs": cfg.epochs,
                         "batch_size": cfg.batch_size, "n_contrib": N_CONTRIB,
                         "model": "mlp8-32-5"},
              "host": _host_fingerprint(),
              "results": []}
    # the committed baseline must be read BEFORE --out overwrites it
    baseline_path = perf_baseline or out

    # the paper-claim comparison rows ride with --compare AND with the
    # --smoke CI gate, so the facade-level claim is regression-checked
    # every PR
    if compare or smoke:
        report["enfed_vs_dfl"] = _compare_row(task, fleet, states, own_train,
                                              own_test, cfg)
        log.info(f"[compare enfed_vs_dfl] {report['enfed_vs_dfl']}")
        report["enfed_vs_dfl_paper"] = _paper_compare_row()
        log.info(f"[compare enfed_vs_dfl_paper] {report['enfed_vs_dfl_paper']}")

    if smoke:
        smoke_cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=2, epochs=1,
                                batch_size=BATCH, encrypt=False,
                                contributor_refresh_epochs=1)
        report["parity_smoke"] = _parity_smoke(task, fleet, states, own_train,
                                               own_test, smoke_cfg)
        log.info(f"[parity smoke] {report['parity_smoke']}")
        report["churn_smoke"] = _churn_smoke(task, fleet, states, own_train,
                                             own_test)
        log.info(f"[churn smoke] {report['churn_smoke']}")
        report["baseline_parity_smoke"] = _baseline_parity_smoke(
            task, fleet, states, own_train, own_test)
        log.info(f"[baseline parity smoke] {report['baseline_parity_smoke']}")
        report["fault_parity_smoke"] = _fault_parity_smoke(
            task, fleet, states, own_train, own_test)
        log.info(f"[fault parity smoke] {report['fault_parity_smoke']}")
        report["resume_smoke"] = _resume_smoke(task, fleet, states,
                                               own_train, own_test)
        log.info(f"[resume smoke] {report['resume_smoke']}")
        report["async_parity_smoke"] = _async_parity_smoke(
            task, fleet, states, own_train, own_test)
        log.info(f"[async parity smoke] {report['async_parity_smoke']}")
        report["async_resume_smoke"] = _async_resume_smoke(
            task, fleet, states, own_train, own_test)
        log.info(f"[async resume smoke] {report['async_resume_smoke']}")
        report["trace_smoke"] = _trace_smoke(task, fleet, states,
                                             own_train, own_test, out)
        log.info(f"[trace smoke] {report['trace_smoke']}")
        report["robust_parity_smoke"] = _robust_parity_smoke(
            task, fleet, states, own_train, own_test)
        log.info(f"[robust parity smoke] {report['robust_parity_smoke']}")

    # loop-engine baseline: seconds per session, measured once (cost is
    # per-session linear: one Python dispatch chain per session)
    loop_specs = _make_specs(LOOP_SAMPLE_SESSIONS, own_train, own_test, fleet, states)
    t0 = time.perf_counter()
    for spec in loop_specs:
        EnFedSession(task, spec.own_train, spec.own_test, fleet,
                     {k: dict(v) for k, v in states.items()}, cfg).run()
    loop_s_per_session = (time.perf_counter() - t0) / LOOP_SAMPLE_SESSIONS
    report["loop_baseline_s_per_session"] = loop_s_per_session

    rows = []
    for R in sizes:
        specs = _make_specs(R, own_train, own_test, fleet, states)
        t0 = time.perf_counter()
        result = run_fleet(task, specs, cfg)
        wall = time.perf_counter() - t0          # includes jit compile
        cold_t = result.timeline.totals()
        t0 = time.perf_counter()
        result = run_fleet(task, specs, cfg)     # steady-state (cached jit)
        wall_warm = time.perf_counter() - t0
        warm_t = result.timeline.totals()
        total_rounds = int(result.rounds.sum())
        rps = total_rounds / wall_warm
        loop_equiv_s = loop_s_per_session * R
        before_idx = _pr1_index_bytes(cfg, R, specs, states)
        # Timeline-derived wall-clock breakdown (repro.telemetry.spans):
        # the cold "program" span includes jit trace+compile, the warm
        # one is pure execution — their difference is the compile cost
        breakdown = {
            "compile_s": round(max(cold_t.get("program", 0.0)
                                   - warm_t.get("program", 0.0), 0.0), 4),
            "warm_s": round(warm_t.get("program", 0.0), 4),
            "staging_s": round(warm_t.get("stage", 0.0), 4),
            "checkpoint_s": round(warm_t.get("checkpoint_save", 0.0)
                                  + warm_t.get("checkpoint_restore", 0.0), 4)}
        report["results"].append({
            "R": R, "cold_s": round(wall, 4), "warm_s": round(wall_warm, 4),
            "breakdown": breakdown,
            "session_rounds": total_rounds, "rounds_per_s": round(rps, 2),
            "simulated_energy_j": round(result.total_energy_j, 2),
            "loop_equiv_s": round(loop_equiv_s, 2),
            "speedup_vs_loop": round(loop_equiv_s / wall_warm, 2),
            "staged_host_bytes": result.staged_host_bytes,
            "staged_index_bytes_after": result.staged_index_bytes,
            "staged_index_bytes_before_pr1": before_idx,
            "index_bytes_reduction_x": round(
                before_idx / max(result.staged_index_bytes, 1), 1),
            "staged_shard_bytes_after": result.staged_shard_bytes,
            "staged_shard_bytes_before_dense": result.staged_shard_bytes_dense,
            "shard_bytes_reduction_x": round(
                result.staged_shard_bytes_dense
                / max(result.staged_shard_bytes, 1), 1),
            "staged_param_bytes": result.staged_param_bytes,
            "device_round_state_bytes": result.device_round_state_bytes,
            "refresh_gather_bytes": result.refresh_gather_bytes,
            "refresh_gather_bytes_dense": result.refresh_gather_bytes_dense})
        rows.append((f"fleet/R={R}", wall_warm * 1e6 / R,
                     f"rounds/s={rps:.1f} E={result.total_energy_j:.1f}J "
                     f"loop_equiv={loop_equiv_s:.1f}s speedup={loop_equiv_s / wall_warm:.1f}x"))
        log.info(f"[fleet R={R:4d}] warm {wall_warm:6.2f}s (cold {wall:6.2f}s, "
                 f"compile ~{breakdown['compile_s']:.2f}s, staging "
                 f"{breakdown['staging_s']:.2f}s) | "
                 f"{total_rounds} session-rounds -> {rps:7.1f} rounds/s | "
                 f"staged {result.staged_host_bytes / 1e6:7.2f} MB "
                 f"(index bytes {result.staged_index_bytes} vs PR1 {before_idx}) | "
                 f"loop engine would need ~{loop_equiv_s:6.1f}s "
                 f"({loop_equiv_s / wall_warm:5.1f}x slower)")
    log.info(f"[loop baseline] {loop_s_per_session:.2f} s/session "
             f"({LOOP_SAMPLE_SESSIONS} sessions measured)")

    # opportunistic-world sweep: the SAME fleet sizes with per-round
    # on-device re-negotiation (mobility kinematics + radio-range masks +
    # contributor battery dynamics).  The headline acceptance number is
    # rounds/s at the largest R with mobility enabled.
    mob_cfg = EnFedConfig(desired_accuracy=0.999, max_rounds=cfg.max_rounds,
                          epochs=cfg.epochs, batch_size=BATCH, encrypt=False,
                          n_max=2, contributor_refresh_epochs=1,
                          mobility=_churn_mobility())
    report["results_mobility"] = []
    for R in sizes:
        specs = _make_specs(R, own_train, own_test, fleet, states, seed=1)
        run_fleet(task, specs, mob_cfg)               # compile
        specs = _make_specs(R, own_train, own_test, fleet, states, seed=1)
        t0 = time.perf_counter()
        result = run_fleet(task, specs, mob_cfg)
        wall_warm = time.perf_counter() - t0
        total_rounds = int(result.rounds.sum())
        rps = total_rounds / wall_warm
        row = {"R": R, "warm_s": round(wall_warm, 4),
               "session_rounds": total_rounds, "rounds_per_s": round(rps, 2),
               "simulated_energy_j": round(result.total_energy_j, 2)}
        row.update(_membership_stats(result))
        report["results_mobility"].append(row)
        log.info(f"[mobility R={R:4d}] warm {wall_warm:6.2f}s | "
                 f"{total_rounds} session-rounds -> {rps:7.1f} rounds/s | "
                 f"mean members {row['mean_members_per_round']:.2f} | "
                 f"joins {row['join_events']} leaves {row['leave_events']} "
                 f"empty rounds {row['empty_neighborhood_rounds']}")

    # faulty-world sweep: the static sweep re-run under unreliable links
    # (drops + bounded retries + stale delivery).  Per row: warm
    # rounds/s, the fault totals, and the retry-energy overhead — the
    # extra receive windows priced by the ONE CostModel.retry_energy —
    # next to the clean-world energy at the same R.
    from jax.flatten_util import ravel_pytree as _ravel

    from repro.core.energy import CostModel, update_wire_bytes

    fault_cfg = EnFedConfig(desired_accuracy=0.999, max_rounds=cfg.max_rounds,
                            epochs=cfg.epochs, batch_size=BATCH, encrypt=False,
                            contributor_refresh_epochs=1,
                            faults=_fault_world())
    num_params = int(_ravel(task.init(seed=0))[0].size)
    model_bytes = update_wire_bytes(num_params, encrypt=fault_cfg.encrypt,
                                    compress=fault_cfg.compress)
    e_rx_retry, _, t_retry = CostModel().retry_energy(
        model_bytes=model_bytes, encrypt=fault_cfg.encrypt)
    t0 = time.perf_counter()
    for spec in _make_specs(LOOP_SAMPLE_SESSIONS, own_train, own_test,
                            fleet, states, seed=2):
        EnFedSession(task, spec.own_train, spec.own_test, fleet,
                     {k: dict(v) for k, v in states.items()},
                     fault_cfg).run()
    fault_loop_s = (time.perf_counter() - t0) / LOOP_SAMPLE_SESSIONS
    clean_e = {r["R"]: r["simulated_energy_j"] for r in report["results"]}
    report["results_faults"] = []
    for R in sizes:
        specs = _make_specs(R, own_train, own_test, fleet, states, seed=2)
        run_fleet(task, specs, fault_cfg)             # compile
        specs = _make_specs(R, own_train, own_test, fleet, states, seed=2)
        t0 = time.perf_counter()
        result = run_fleet(task, specs, fault_cfg)
        wall_warm = time.perf_counter() - t0
        total_rounds = int(result.rounds.sum())
        rps = total_rounds / wall_warm
        drops = int(np.sum(result.history_raw["drops"]))
        retries = int(np.sum(result.history_raw["retries"]))
        stale = int(np.sum(result.history_raw["stale"]))
        windows = drops + retries
        row = {"R": R, "warm_s": round(wall_warm, 4),
               "session_rounds": total_rounds, "rounds_per_s": round(rps, 2),
               "speedup_vs_loop": round(fault_loop_s * R / wall_warm, 2),
               "drops": drops, "retries": retries, "stale_deliveries": stale,
               "extra_receive_windows": windows,
               "retry_energy_j": round(windows * e_rx_retry, 4),
               "retry_time_s": round(windows * t_retry, 4),
               "simulated_energy_j": round(result.total_energy_j, 2),
               "clean_energy_j": clean_e.get(R)}
        report["results_faults"].append(row)
        log.info(f"[faults R={R:4d}] warm {wall_warm:6.2f}s | "
                 f"{total_rounds} session-rounds -> {rps:7.1f} rounds/s | "
                 f"drops {drops} retries {retries} stale {stale} -> "
                 f"retry overhead {row['retry_energy_j']:.3f}J "
                 f"(E={row['simulated_energy_j']:.1f}J vs clean "
                 f"{row['clean_energy_j']}J)")

    # async-cadence sweep: the static sweep re-run with the lockstep
    # round barrier broken (repro.core.cadence) — per-device duty cycles
    # put every lane on its own round clock.  Per row: warm rounds/s,
    # the straggler-lag histogram (how stale the aggregated wire images
    # run), and the idle-step pricing next to the lockstep energy at the
    # same R, so the asynchrony tax is a committed number.
    async_cc = _async_cadence()
    async_cfg = EnFedConfig(desired_accuracy=0.999, max_rounds=cfg.max_rounds,
                            epochs=cfg.epochs, batch_size=BATCH,
                            encrypt=False, contributor_refresh_epochs=1,
                            cadence=async_cc)
    device_ids = np.array([d.device_id for d in fleet], np.int32)
    t0 = time.perf_counter()
    for spec in _make_specs(LOOP_SAMPLE_SESSIONS, own_train, own_test,
                            fleet, states, seed=3):
        EnFedSession(task, spec.own_train, spec.own_test, fleet,
                     {k: dict(v) for k, v in states.items()},
                     async_cfg).run()
    async_loop_s = (time.perf_counter() - t0) / LOOP_SAMPLE_SESSIONS
    report["results_async"] = []
    for R in sizes:
        specs = _make_specs(R, own_train, own_test, fleet, states, seed=3)
        run_fleet(task, specs, async_cfg)             # compile
        specs = _make_specs(R, own_train, own_test, fleet, states, seed=3)
        t0 = time.perf_counter()
        result = run_fleet(task, specs, async_cfg)
        wall_warm = time.perf_counter() - t0
        total_rounds = int(result.rounds.sum())
        rps = total_rounds / wall_warm
        # idle steps between executed rounds, priced through the ONE
        # CostModel.idle_energy (residual idle after a lane's last round
        # is priced in the engines but not re-derived here)
        total_idle = int(np.sum(result.history_raw["idle_steps"]))
        e_idle, t_idle = CostModel().idle_energy(
            idle_steps=total_idle, idle_step_s=async_cc.idle_step_s)
        hist = _straggler_lag_hist(result, async_cc, device_ids)
        row = {"R": R, "warm_s": round(wall_warm, 4),
               "session_rounds": total_rounds, "rounds_per_s": round(rps, 2),
               "speedup_vs_loop": round(async_loop_s * R / wall_warm, 2),
               "idle_steps": total_idle,
               "idle_energy_j": round(e_idle, 4),
               "idle_time_s": round(t_idle, 4),
               "straggler_lag_hist": hist,
               "straggler_rounds": sum(c for lag, c in hist.items()
                                       if int(lag) > 0),
               "simulated_energy_j": round(result.total_energy_j, 2),
               "lockstep_energy_j": clean_e.get(R)}
        report["results_async"].append(row)
        log.info(f"[async R={R:4d}] warm {wall_warm:6.2f}s | "
                 f"{total_rounds} session-rounds -> {rps:7.1f} rounds/s | "
                 f"idle {total_idle} steps -> {row['idle_energy_j']:.3f}J | "
                 f"lag hist {hist} | E={row['simulated_energy_j']:.1f}J vs "
                 f"lockstep {row['lockstep_energy_j']}J")

    # compressed-round-state sweep: fp32 vs int8 staged/resident bytes
    # and rounds/s on a model that amortizes the quantization tile
    report["results_compress"] = _compress_sweep(sizes)

    # Byzantine-robust sweep: the static sweep re-run under the pinned
    # adversarial weather with trimmed-mean screening ON.  Per row: warm
    # rounds/s for the defended program, the corrupted-link totals, and
    # the screening overhead — one extra pass over the delivered buffer
    # per executed round, priced through the ONE
    # CostModel.screening_energy — next to the clean energy at the same
    # R.  The recovery study (fixed R, deterministic counter-keyed
    # draws) rides in the same section.
    rob_cfg = EnFedConfig(desired_accuracy=0.999, max_rounds=cfg.max_rounds,
                          epochs=cfg.epochs, batch_size=BATCH, encrypt=False,
                          contributor_refresh_epochs=1,
                          adversary=_byzantine_world("noise"),
                          robust="trimmed_mean")
    e_scr, t_scr = CostModel().screening_energy(n_contrib=N_CONTRIB,
                                                num_params=num_params)
    t0 = time.perf_counter()
    for spec in _make_specs(LOOP_SAMPLE_SESSIONS, own_train, own_test,
                            fleet, states, seed=5):
        EnFedSession(task, spec.own_train, spec.own_test, fleet,
                     {k: dict(v) for k, v in states.items()},
                     rob_cfg).run()
    rob_loop_s = (time.perf_counter() - t0) / LOOP_SAMPLE_SESSIONS
    rob_rows = []
    for R in sizes:
        specs = _make_specs(R, own_train, own_test, fleet, states, seed=5)
        run_fleet(task, specs, rob_cfg)               # compile
        specs = _make_specs(R, own_train, own_test, fleet, states, seed=5)
        t0 = time.perf_counter()
        result = run_fleet(task, specs, rob_cfg)
        wall_warm = time.perf_counter() - t0
        total_rounds = int(result.rounds.sum())
        rps = total_rounds / wall_warm
        corrupted = int(np.sum(result.history_raw["corrupted"]))
        row = {"R": R, "warm_s": round(wall_warm, 4),
               "session_rounds": total_rounds, "rounds_per_s": round(rps, 2),
               "speedup_vs_loop": round(rob_loop_s * R / wall_warm, 2),
               "robust": rob_cfg.robust, "attack": rob_cfg.adversary.attack,
               "corrupted_links": corrupted,
               "screening_energy_j": round(total_rounds * e_scr, 4),
               "screening_time_s": round(total_rounds * t_scr, 4),
               "simulated_energy_j": round(result.total_energy_j, 2),
               "clean_energy_j": clean_e.get(R)}
        rob_rows.append(row)
        log.info(f"[robust R={R:4d}] warm {wall_warm:6.2f}s | "
                 f"{total_rounds} session-rounds -> {rps:7.1f} rounds/s | "
                 f"corrupted links {corrupted} -> screening overhead "
                 f"{row['screening_energy_j']:.4f}J "
                 f"(E={row['simulated_energy_j']:.1f}J vs clean "
                 f"{row['clean_energy_j']}J)")
    recovery = _robust_recovery_rows()
    report["results_robust"] = {"rows": rob_rows, "recovery": recovery}
    log.info(f"[robust recovery] clean={recovery['clean_final_accuracy']} | "
             + " | ".join(
                 f"{a}: none {v['ratio_none']}x, trimmed "
                 f"{v['ratio_trimmed_mean']}x of clean"
                 for a, v in recovery["attacks"].items()))

    # method-variant sweep: enfed/dfl/cfl each as ONE compiled program at
    # the largest R, with measured (not extrapolated) baseline walls
    report["results_compare_fleet"] = _fleet_compare_sweep(
        task, fleet, states, own_train, own_test, max(sizes))

    # early-exit demo: a fleet whose sessions all hit the accuracy target
    # in round 1 executes O(1) round bodies even with a 16-round budget
    # (the PR 1 engine scanned all 16 regardless).
    R_demo = min(max(sizes), 128)
    ee_cfg = EnFedConfig(desired_accuracy=0.05, max_rounds=16, epochs=1,
                         batch_size=BATCH, encrypt=False,
                         contributor_refresh_epochs=1)
    ee_specs = _make_specs(R_demo, own_train, own_test, fleet, states)
    run_fleet(task, ee_specs, ee_cfg)                  # compile
    t0 = time.perf_counter()
    ee = run_fleet(task, ee_specs, ee_cfg)
    ee_warm = time.perf_counter() - t0
    bodies = int(ee.history_raw["round_executed"].sum())
    report["early_exit_demo"] = {
        "R": R_demo, "max_rounds": ee_cfg.max_rounds,
        "round_bodies_executed": bodies, "warm_s": round(ee_warm, 4),
        "rounds_per_session": int(ee.rounds.max())}
    log.info(f"[early exit R={R_demo}] all sessions stop in round "
             f"{int(ee.rounds.max())}: {bodies}/{ee_cfg.max_rounds} round "
             f"bodies executed, warm {ee_warm:.2f}s")

    # the perf gate reads the committed baseline (already loaded path);
    # it must run before the report overwrites that file
    if smoke:
        report["perf_gate"] = _perf_gate(report, baseline_path or "")
        log.info(f"[perf gate] {report['perf_gate']}")
        report["fleet_compare_gate"] = _fleet_compare_gate(
            report, baseline_path or "")
        log.info(f"[fleet compare gate] {report['fleet_compare_gate']}")
        report["faults_perf_gate"] = _perf_gate(report, baseline_path or "",
                                                section="results_faults")
        log.info(f"[faults perf gate] {report['faults_perf_gate']}")
        report["async_perf_gate"] = _perf_gate(report, baseline_path or "",
                                               section="results_async")
        log.info(f"[async perf gate] {report['async_perf_gate']}")
        report["robust_perf_gate"] = _perf_gate(report, baseline_path or "",
                                                section="results_robust")
        log.info(f"[robust perf gate] {report['robust_perf_gate']}")
        report["robust_recovery_gate"] = _robust_recovery_gate(
            report["results_robust"]["recovery"])
        log.info(f"[robust recovery gate] {report['robust_recovery_gate']}")

    if out:
        with open(out, "w") as f:
            json.dump(report, f, indent=2)
        log.info(f"[bench] wrote {out}")
    # --- smoke gate verdicts -------------------------------------------
    # One named entry per gate: (report key, why-it-failed message
    # builder).  Every gate logs a one-line PASS/FAIL verdict with the
    # fingerprint of the section it judged; a failure names the gate and
    # the fingerprint so a red CI run points at the exact evidence in
    # the uploaded BENCH_fleet.json.  ALL gates are evaluated before the
    # non-zero exit — one run reports every broken invariant.
    def _why_perf(what):
        return lambda s: (f"PERF REGRESSION: {what} rounds/s at "
                          f"R={s.get('R')} fell to {s.get('ratio')}x the "
                          f"committed baseline (gate: >= "
                          f"{s.get('threshold')}x)")

    gate_specs = [
        ("parity_smoke", lambda s: (
            "PARITY REGRESSION: fleet engine diverged from the loop oracle")),
        ("churn_smoke", lambda s: (
            "CHURN REGRESSION: mobility re-negotiation diverged from the "
            "loop oracle (or the scenario stopped churning)")),
        ("enfed_vs_dfl", lambda s: (
            "COMPARE REGRESSION: Experiment.compare(['enfed','dfl']) no "
            "longer yields a finite reduction row under one shared "
            "CostModel")),
        ("enfed_vs_dfl_paper", lambda s: (
            "COMPARE REGRESSION: the paper-shaped enfed_vs_dfl_paper row "
            "no longer yields finite reductions")),
        ("perf_gate", _why_perf("warm")),
        ("fault_parity_smoke", lambda s: (
            "FAULT REGRESSION: the engines no longer agree on the "
            "unreliable-link world (masks/counters/params/retry pricing), "
            "or the scenario stopped exercising all three failure modes")),
        ("resume_smoke", lambda s: (
            "RESUME REGRESSION: a killed-and-resumed fleet run is no "
            "longer bit-identical to the uninterrupted one")),
        ("trace_smoke", lambda s: (
            "TRACE REGRESSION: tracing a run changed its outcome "
            "(params/masks/battery no longer bit-identical to the untraced "
            "run), the exported events.jsonl/trace.json failed schema "
            "validation, or the engines' event streams diverged")),
        ("faults_perf_gate", _why_perf("faulty-world")),
        ("async_parity_smoke", lambda s: (
            "ASYNC REGRESSION: the engines no longer agree on the cadence "
            "world (clocks/idle/masks bitwise, battery/params to "
            "tolerance, idle pricing), or the scenario stopped exercising "
            "straggler rounds / idle steps")),
        ("async_resume_smoke", lambda s: (
            "ASYNC RESUME REGRESSION: a killed-and-resumed cadence run no "
            "longer restores the per-lane round clocks and idle counters "
            "bit-identically")),
        ("async_perf_gate", _why_perf("async-cadence")),
        ("robust_parity_smoke", lambda s: (
            "ROBUST REGRESSION: the engines no longer agree on the "
            "Byzantine world (corrupted/clipped masks bitwise, params, "
            "screening pricing), the scenario stopped corrupting or "
            "clipping, or robust='none' on a clean world is no longer "
            "bit-identical to the undefended aggregation")),
        ("robust_recovery_gate", lambda s: (
            f"ROBUST RECOVERY REGRESSION: under the pinned noise attack "
            f"trimmed-mean screening recovered "
            f"{s.get('ratio_trimmed_mean')}x of clean final accuracy "
            f"(gate: >= {s.get('threshold')}x) while plain fedavg "
            f"recovered {s.get('ratio_none')}x (gate: < "
            f"{s.get('threshold')}x), corruption_fired="
            f"{s.get('corruption_fired')}")),
        ("robust_perf_gate", _why_perf("Byzantine-robust")),
        ("baseline_parity_smoke", lambda s: (
            "BASELINE PARITY REGRESSION: the dfl fleet lanes diverged "
            "from the DFLLearner loop oracle")),
        ("results_compare_fleet", lambda s: (
            "COMPARE-FLEET REGRESSION: a method of the fleet-engine "
            "comparison produced non-finite figures or fell back off the "
            "compiled engine")),
        ("fleet_compare_gate", lambda s: (
            f"PERF REGRESSION: the dfl fleet program at R={s.get('R')} "
            f"fell to {s.get('ratio')}x the committed baseline (gate: >= "
            f"{s.get('threshold')}x)")),
    ]
    if smoke:
        failed = []
        for key, why in gate_specs:
            sec = report.get(key)
            ok = bool(sec) and bool(sec.get("pass"))
            fp = _gate_fingerprint(sec)
            line = f"[gate] {key:22s} {'PASS' if ok else 'FAIL'} ({fp})"
            (log.info if ok else log.error)(line)
            if not ok:
                failed.append(key)
                log.error(f"GATE FAILED: {key} — {why(sec or {})} "
                          f"(section fingerprint {fp})")
        if failed:
            log.error(f"{len(failed)}/{len(gate_specs)} smoke gates "
                      f"failed: {', '.join(failed)}")
            sys.exit(1)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="8,32,128,512",
                    help="comma list of fleet sizes to sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="run the fleet-vs-loop parity gate (includes the "
                         "enfed-vs-dfl compare row); exit 1 on regression")
    ap.add_argument("--compare", action="store_true",
                    help="write the repro.api Experiment.compare "
                         "enfed_vs_dfl reduction row (time + energy %%) "
                         "into the JSON")
    ap.add_argument("--out", default="BENCH_fleet.json",
                    help="JSON report path ('' disables)")
    ap.add_argument("--perf-baseline", default=None,
                    help="committed BENCH_fleet.json to gate warm rounds/s "
                         "against (default: the --out path, read before "
                         "overwrite)")
    vq = ap.add_mutually_exclusive_group()
    vq.add_argument("-v", "--verbose", action="store_true",
                    help="debug-level progress logging (stderr)")
    vq.add_argument("-q", "--quiet", action="store_true",
                    help="errors only; progress logging off")
    args = ap.parse_args()
    _setup_logging(1 if args.verbose else -1 if args.quiet else 0)
    run(sizes=tuple(int(s) for s in args.sizes.split(",")),
        smoke=args.smoke, compare=args.compare, out=args.out or None,
        perf_baseline=args.perf_baseline)


if __name__ == "__main__":
    main()
