"""Distributed-runtime tests (8 fake host devices, subprocess-isolated
so the rest of the suite keeps seeing 1 device)."""

import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch.mesh import AXIS_TYPES_SUPPORTED

# each test spawns a fresh interpreter with 8 fake devices and re-jits
# from scratch; tier-1 skips them, run with -m slow.  repro.launch.mesh
# itself imports fine on the pinned 0.4.x toolchain (AxisType gated),
# but these tests exercise shard_map vma/pcast semantics that ship with
# jax >= 0.5 — skip them cleanly below that.
pytestmark = [
    pytest.mark.slow,
    pytest.mark.skipif(
        not AXIS_TYPES_SUPPORTED,
        reason="shard_map vma/pcast semantics need jax.sharding.AxisType (jax>=0.5)"),
]

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_aggregation_strategies_numerics():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.topology import AggregationStrategy, aggregate_updates
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh()  # (4,2) data, model
        u = {"w": jnp.ones((8, 4))}
        mask = jnp.array([1., 0., 1., 1.])
        # results come back client-stacked: (4, 8, 4)
        for kind in ("cfl", "dfl_mesh", "dfl_ring"):
            s = AggregationStrategy(kind=kind, client_axes=("data",))
            out = aggregate_updates(u, mesh, s, mask)
            assert out["w"].shape == (4, 8, 4)
            np.testing.assert_allclose(np.asarray(out["w"]), 1.0, rtol=1e-5)
        # enfed neighborhoods of 2: group [0,1] only member 0 participates
        s = AggregationStrategy(kind="enfed", client_axes=("data",), neighborhood_size=2)
        out = aggregate_updates(u, mesh, s, mask)
        np.testing.assert_allclose(np.asarray(out["w"]), 1.0, rtol=1e-5)
        print("STRATEGIES-OK")
    """)
    assert "STRATEGIES-OK" in out


def test_federated_train_step_all_strategies():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import Transformer
        from repro.launch.mesh import make_debug_mesh, client_axes_for
        from repro.launch.steps import (make_federated_train_step, stack_for_clients,
                                        fed_param_shardings, num_clients)
        from repro.launch.inputs import batch_input_shardings
        from repro.core.topology import AggregationStrategy
        from repro.sharding import use_mesh
        mesh = make_debug_mesh(multi_pod=True)
        cfg = get_config("debug-moe")
        model = Transformer(cfg)
        caxes = client_axes_for(cfg, mesh)
        C = num_clients(mesh, caxes)
        losses = {}
        for kind in ("cfl", "enfed", "dfl_ring", "dfl_mesh"):
            strat = AggregationStrategy(kind=kind, client_axes=caxes, neighborhood_size=2)
            with use_mesh(mesh):
                params = model.init(jax.random.PRNGKey(0))
                step, opt = make_federated_train_step(model, mesh, strat, lr=1e-3)
                pf = stack_for_clients(params, C)
                of = stack_for_clients(opt.init(params), C)
                psh = fed_param_shardings(jax.eval_shape(lambda: pf), mesh, caxes, cfg.fsdp)
                batch = {"tokens": jnp.zeros((8, 16), jnp.int32),
                         "labels": jnp.zeros((8, 16), jnp.int32)}
                bsh = batch_input_shardings(batch, mesh, client_stacked=True, client_axes=caxes)
                jitted = jax.jit(step, in_shardings=(psh, None, bsh, None))
                p2, o2, loss = jitted(pf, of, batch, jnp.ones((C,), jnp.float32))
            losses[kind] = float(loss)
            assert np.isfinite(losses[kind])
        # same data, same init => same loss regardless of aggregation kind
        vals = list(losses.values())
        assert max(vals) - min(vals) < 1e-4, losses
        print("FEDSTEP-OK")
    """)
    assert "FEDSTEP-OK" in out


def test_dryrun_single_combo_on_debug_scale():
    """Exercise the dry-run path end to end at 8-device scale."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import Transformer
        from repro.launch.mesh import make_debug_mesh
        from repro.launch import inputs as inp
        from repro.launch.steps import make_serve_step
        from repro.sharding import param_specs, use_mesh
        from repro.launch.hlo_stats import collective_bytes, cost_summary
        cfg = get_config("debug-dense")
        mesh = make_debug_mesh()
        model = Transformer(cfg)
        with use_mesh(mesh):
            step = make_serve_step(model)
            params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            cache = inp.cache_shapes(model, 8, 64)
            psh = param_specs(params_shape, mesh, fsdp=cfg.fsdp)
            csh = inp.cache_shardings(cache, mesh)
            jitted = jax.jit(step, in_shardings=(psh, csh, None, None))
            lowered = jitted.lower(params_shape, cache,
                                   jax.ShapeDtypeStruct((8, 1), jnp.int32),
                                   jax.ShapeDtypeStruct((), jnp.int32))
            compiled = lowered.compile()
        cs = cost_summary(compiled)
        assert cs.get("flops", 0) > 0
        stats = collective_bytes(compiled.as_text())
        print("DRYRUN-OK", stats.get("total_collective_bytes", 0))
    """)
    assert "DRYRUN-OK" in out
