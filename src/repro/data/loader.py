"""Minimal batching utilities (numpy-side, feeding jit'd steps)."""

from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def train_test_split(x: np.ndarray, y: np.ndarray, test_frac: float = 0.2, seed: int = 0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(x))
    n_test = int(len(x) * test_frac)
    te, tr = idx[:n_test], idx[n_test:]
    return (x[tr], y[tr]), (x[te], y[te])


def batch_iterator(x: np.ndarray, y: np.ndarray, batch_size: int, seed: int = 0,
                   drop_remainder: bool = True) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """One epoch of shuffled minibatches."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(x))
    end = (len(x) // batch_size) * batch_size if drop_remainder else len(x)
    for s in range(0, max(end, batch_size if not drop_remainder else 0), batch_size):
        sel = idx[s : s + batch_size]
        if len(sel) == 0 or (drop_remainder and len(sel) < batch_size):
            return
        yield x[sel], y[sel]


def pad_to_batch(x: np.ndarray, y: np.ndarray, batch_size: int):
    """Pad (repeat) a client shard so it is a multiple of batch_size."""
    n = len(x)
    if n % batch_size == 0 and n > 0:
        return x, y
    reps = int(np.ceil(max(batch_size, n) / max(n, 1)))
    x = np.concatenate([x] * reps)[: max(batch_size, (n // batch_size + 1) * batch_size)]
    y = np.concatenate([y] * reps)[: len(x)]
    return x, y
