"""Host-side timing spans: ``Span`` records on a per-run ``Timeline``.

The engines' real cost centers are host-visible walls — jit
trace/compile vs warm execution, shard staging, quantize/dequant
packing, checkpoint save/restore, the loop engine's AES-CTR transport —
so the instrument is a plain ``time.perf_counter`` stack, not anything
that touches traced state (the observation-never-changes-outcome rule).

Span-name vocabulary used by the engines (``Timeline.totals()`` keys):

===================  =====================================================
``stage``            host-side handshake + array staging (fleet)
``quantize_pack``    int8 round-state quantization (nested in ``stage``)
``program``          the one jitted fleet program call (compile included
                     on a cache miss — ``attrs["cache_miss"]``)
``chunk``            one ``_fleet_chunk_program`` call of the host-driven
                     checkpoint loop
``hlo_stats``        the opt-in AOT lower+compile for the cost summary
``checkpoint_save``  ``repro.checkpoint`` serialization
``checkpoint_restore``  checkpoint restore (both engines)
``unpack``           device->host result unpacking + write-back
``dequant_unpack``   int8->fp32 write-back dequant (nested in ``unpack``)
``handshake``        loop-engine contract signing + key exchange
``transport``        loop-engine AES-CTR collect of one round's updates
``fit``              loop-engine requester fit of one round
``refresh``          loop-engine contributor refresh of one round
===================  =====================================================
"""

from __future__ import annotations

import dataclasses
import time
from contextlib import contextmanager
from typing import Dict, List, Optional


@dataclasses.dataclass
class Span:
    """One timed region.  ``t0``/``dur`` are seconds relative to the
    owning Timeline's epoch; ``dur < 0`` marks a span still open."""

    name: str
    t0: float
    dur: float = -1.0
    depth: int = 0
    parent: Optional[int] = None   # index into Timeline.spans
    attrs: Dict[str, object] = dataclasses.field(default_factory=dict)


class Timeline:
    """An append-only list of (possibly nested) spans for one run.

    Recording is always on in the engines — a span costs two
    ``perf_counter`` reads and one small object, and records nothing
    that can feed back into the simulation.  Use :meth:`span` as a
    context manager for small regions, or :meth:`begin`/:meth:`finish`
    around regions that are awkward to indent.
    """

    def __init__(self):
        self.spans: List[Span] = []
        self._stack: List[int] = []
        self._epoch = time.perf_counter()

    def begin(self, name: str, **attrs) -> int:
        """Open a span; returns its index for :meth:`finish`."""
        idx = len(self.spans)
        self.spans.append(Span(
            name=name, t0=time.perf_counter() - self._epoch,
            depth=len(self._stack),
            parent=self._stack[-1] if self._stack else None,
            attrs=dict(attrs)))
        self._stack.append(idx)
        return idx

    def finish(self, idx: int) -> None:
        """Close the span opened by :meth:`begin` (strictly LIFO)."""
        if not self._stack or self._stack[-1] != idx:
            raise RuntimeError(
                f"span {idx} is not the innermost open span "
                f"(stack: {self._stack})")
        self._stack.pop()
        sp = self.spans[idx]
        sp.dur = time.perf_counter() - self._epoch - sp.t0

    @contextmanager
    def span(self, name: str, **attrs):
        idx = self.begin(name, **attrs)
        try:
            yield self.spans[idx]
        finally:
            self.finish(idx)

    def totals(self) -> Dict[str, float]:
        """Summed duration (s) per span name — the wall-clock breakdown.
        Nested spans count under their own name AND inside their
        parent's duration (so e.g. ``quantize_pack`` is a sub-slice of
        ``stage``, not additive with it)."""
        out: Dict[str, float] = {}
        for sp in self.spans:
            if sp.dur >= 0:
                out[sp.name] = out.get(sp.name, 0.0) + sp.dur
        return out

    def total(self, name: str) -> float:
        return self.totals().get(name, 0.0)
