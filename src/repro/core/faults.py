"""Unreliable-link world: per-round per-link drops, bounded retries, and
stale delivery — shared by BOTH EnFed engines.

EnFed's premise is opportunistic collaboration over edge radios, yet the
simulated transport used to be perfect: every accepted contributor's
update arrived intact, on time, every round.  This module makes the link
itself part of the simulated world, with the same design rule as
:mod:`repro.core.mobility`: fault outcomes are a *closed-form function
of (seed, round, requester, contributor)* — pure counter-based
``jax.random.fold_in`` chains, no carried RNG state — so the loop engine
(concrete round numbers, host-side) and the fleet engine (traced round
numbers, inside one jit program) derive bit-identical outcomes by
construction, and any round's faults can be queried without replaying
earlier rounds.

Three failure modes per (requester, contributor) link per round:

* **Drop** — a transmission attempt fails outright.  Each attempt draws
  an independent int32 from ``(seed, round, requester, contributor,
  attempt)`` and fails iff it lands under the ``p_drop`` threshold.
* **Timeout + bounded retry** — up to ``max_retries`` retransmissions
  follow a failed attempt.  The update is *delivered* iff any of the
  ``max_retries + 1`` attempts succeeds; every attempt re-prices the
  same wire bytes through :meth:`repro.core.energy.CostModel.retry_energy`
  (extra receive window + decrypt on the requester, extra transmit +
  encrypt on the contributor), so flaky links visibly burn battery.
* **Stale delivery** — a delivered update may be the contributor's
  round-(r-1) wire image instead of the current one (a lagging device
  answering with its previous payload).  Both engines keep that previous
  image wire-format-resident: the fleet engine carries a second
  (R, N, ·) buffer in its loop state, the loop engine a ``_prev`` cache
  snapshotted at the same protocol point.

Degradation is protocol-level, not an error path: undelivered links are
zeroed out of the round's fedavg weight mask (``protocol.Phase.DELIVER``
feeding the existing mask path), an all-links-failed round falls back to
the requester's own params exactly like the empty-neighborhood case, and
a link whose previous ``release_after`` rounds ALL failed is *blocked* —
released at ``Phase.RENEGOTIATE`` as if out of radio range (static
worlds suspend the link for the round: no attempt, no cost).

Like mobility's kinematics, link quality is WORLD state: the fault draws
of a round exist whether or not a transmission was attempted that round,
which is what lets the blocked-streak be closed-form instead of carried
state (membership depending on faults depending on membership would
otherwise recurse).

Parity-safety rule (same as mobility): every predicate is an exact
integer comparison — thresholds are precomputed host-side from the
static probabilities, draws are int32 — so no float fusion regime can
flip an outcome between engines.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# Fault draws live in [0, _DRAW_MAX); a probability p maps to the
# threshold int(p * _DRAW_MAX), so p=0 never fires and p=1 always does
# (draws are strictly below _DRAW_MAX).  ~4.7e-10 probability
# resolution — far below anything the simulation distinguishes.
_DRAW_MAX = 2**31 - 1

_SALT_DROP = 0x0D
_SALT_STALE = 0x57


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Unreliable-link world parameters for one simulated session
    (frozen/hashable => usable as a static arg of the compiled fleet
    program, exactly like :class:`repro.core.mobility.MobilityConfig`).

    ``requester_id`` is the requesting device's id in the fault
    hash-space; fleet lanes use ``requester_id + lane`` so concurrent
    requesters see independent link weather.  The default offset keeps
    fault-space requester ids clear of contributor ids AND of the
    mobility kinematics ids.
    """

    p_drop: float = 0.0        # per-ATTEMPT transmission failure probability
    p_stale: float = 0.0       # P(delivered update is the round-(r-1) image)
    max_retries: int = 2       # bounded retransmissions after the first attempt
    release_after: int = 0     # consecutive fully-failed rounds before the
                               # member is released at RENEGOTIATE (0 = never)
    seed: int = 0              # fault hash seed
    requester_id: int = 1 << 21  # requester lane 0's id in the fault space

    def __post_init__(self):
        # fail fast at CONSTRUCTION — not as NaN weights deep inside the
        # jit program (the satellite rule run_fleet/EnFedSession inherit
        # by constructing/receiving this config)
        if not 0.0 <= self.p_drop <= 1.0:
            raise ValueError(
                f"p_drop must be within [0, 1] (got {self.p_drop})")
        if not 0.0 <= self.p_stale <= 1.0:
            raise ValueError(
                f"p_stale must be within [0, 1] (got {self.p_stale})")
        if self.max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0 (got {self.max_retries})")
        if self.release_after < 0:
            raise ValueError(
                f"release_after must be >= 0 (got {self.release_after})")

    @property
    def attempts_max(self) -> int:
        """Transmission budget per link per round (first try + retries)."""
        return self.max_retries + 1


def _threshold(p: float) -> jnp.int32:
    """The static int32 threshold a probability compiles to."""
    return jnp.int32(int(min(max(float(p), 0.0), 1.0) * _DRAW_MAX))


def _link_draw(seed: int, salt: int, r, requester_id, cand_id, t):
    """One int32 fault draw in [0, _DRAW_MAX) hashed from
    ``(seed, salt, round, requester, contributor, attempt)`` alone —
    prefix-stable in every argument, traced or concrete."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), jnp.uint32(salt))
    key = jax.random.fold_in(key, jnp.asarray(r, jnp.uint32))
    key = jax.random.fold_in(key, jnp.asarray(requester_id, jnp.uint32))
    key = jax.random.fold_in(key, jnp.asarray(cand_id, jnp.uint32))
    key = jax.random.fold_in(key, jnp.asarray(t, jnp.uint32))
    return jax.random.randint(key, (), 0, _DRAW_MAX, jnp.int32)


def _per_link(fc: FaultConfig, r, req_id, cand_id):
    """Fault outcome of ONE link at round ``r`` (scalar ids)."""
    drop_thr = _threshold(fc.p_drop)
    stale_thr = _threshold(fc.p_stale)
    draws = jnp.stack([
        _link_draw(fc.seed, _SALT_DROP, r, req_id, cand_id, t)
        for t in range(fc.attempts_max)])
    ok = draws >= drop_thr
    delivered = jnp.any(ok)
    first = jnp.argmax(ok).astype(jnp.int32)      # first successful attempt
    attempts = jnp.where(delivered, first + 1, jnp.int32(fc.attempts_max))
    stale = delivered & (_link_draw(fc.seed, _SALT_STALE, r, req_id, cand_id,
                                    0) < stale_thr)
    return delivered, attempts, stale


def link_outcomes(fc: FaultConfig, r, requester_id, cand_ids):
    """Per-link fault outcomes at round ``r`` — THE shared derivation of
    both engines (``Phase.DELIVER``).

    Inputs broadcast like :func:`repro.core.mobility.in_range_mask`:
    ``requester_id`` is scalar or (R,), ``cand_ids`` (N,) or (R, N).

    Returns ``(delivered, attempts, stale)``:

    ``delivered``  (..., N) bool — the update arrived within the
                   ``max_retries + 1`` attempt budget;
    ``attempts``   (..., N) int32 — transmissions actually made
                   (1..attempts_max; an undelivered link exhausts the
                   whole budget);
    ``stale``      (..., N) bool — the delivered payload is the
                   round-(r-1) wire image (only meaningful where
                   ``delivered``; at round 0 the "previous" image is the
                   handshake staging, so a stale hit is a no-op there).

    Whether a link *counts* (contract member, not blocked) is the
    caller's mask — outcomes here are pure world state.
    """
    ids = jnp.asarray(cand_ids, jnp.int32)
    req = jnp.broadcast_to(
        jnp.asarray(requester_id, jnp.int32)[..., None], ids.shape)
    d, a, s = jax.vmap(lambda q, c: _per_link(fc, r, q, c))(
        req.reshape(-1), ids.reshape(-1))
    return d.reshape(ids.shape), a.reshape(ids.shape), s.reshape(ids.shape)


def blocked_mask(fc: FaultConfig, r, requester_id, cand_ids):
    """(..., N) bool: links whose previous ``release_after`` rounds ALL
    failed to deliver — the repeatedly-failing members released at
    ``Phase.RENEGOTIATE`` as if they walked out of range (suspended for
    the round in static worlds: no attempt, no retry cost).

    Closed-form: re-evaluates :func:`link_outcomes`'s delivered bit for
    rounds ``r - release_after .. r - 1`` (stateless, so both engines and
    any resumed run agree without replaying history).  Rounds before 0
    count as delivered — a session starts with no fault history — so
    nothing is blocked before round ``release_after``.  Once the trailing
    window contains a delivered round the link is eligible again, same
    as a device wandering back into range.
    """
    ids = jnp.asarray(cand_ids, jnp.int32)
    if fc.release_after <= 0:
        return jnp.zeros(ids.shape, bool)
    blocked = jnp.ones(ids.shape, bool)
    for k in range(1, fc.release_after + 1):
        rk = jnp.asarray(r, jnp.int32) - k
        d, _, _ = link_outcomes(fc, rk, requester_id, cand_ids)
        blocked &= ~(d | (rk < 0))
    return blocked
