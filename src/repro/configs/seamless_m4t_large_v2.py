"""SeamlessM4T-Large-v2 [arXiv:2308.11596] — multimodal encoder-decoder
backbone (speech/text translation).

Assigned spec: 24L decoder + 24L encoder, d_model=1024, 16H (kv=16),
d_ff=8192, vocab=256206.  The modality frontend (mel-spectrogram +
conv feature extractor) is STUBBED per the carve-out: input_specs()
provides precomputed frame embeddings (B, T, d_model); the transformer
encoder+decoder is fully implemented.  Encoder-decoder with full
attention => long_500k skipped (noted in DESIGN.md).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    citation="arXiv:2308.11596",
    num_layers=24,
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256_206,
    block_pattern=("attn",),
    frontend="audio",
    dtype="bfloat16",
)
