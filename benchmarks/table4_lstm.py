"""Paper Table IV: EnFed vs DFL vs CFL — LSTM, both datasets.

Reports accuracy, training time (eq. 4), and requester energy (eqs. 5-7)
and the relative reductions the paper claims (EnFed ~59%/19% lower
time&energy than DFL on datasets 1/2; ~85%/27% lower than CFL).
"""

from __future__ import annotations

from benchmarks._harness import build_scenario, run_cfl, run_dfl, run_enfed


def run(verbose: bool = True):
    rows = []
    for ds_id, dataset in (("Dataset1", "calories"), ("Dataset2", "har")):
        sc = build_scenario(dataset, "lstm")
        enfed = run_enfed(sc)
        cfl = run_cfl(sc)
        dfl_m = run_dfl(sc, "mesh")
        dfl_r = run_dfl(sc, "ring")
        dfl_t = (dfl_m.report.t_train + dfl_r.report.t_train) / 2
        dfl_e = (dfl_m.report.e_tot + dfl_r.report.e_tot) / 2
        dfl_acc = (dfl_m.accuracy + dfl_r.accuracy) / 2
        rows += [
            (f"table4/{ds_id}/EnFed", enfed.accuracy, enfed.report.t_train, enfed.report.e_tot),
            (f"table4/{ds_id}/DFL", dfl_acc, dfl_t, dfl_e),
            (f"table4/{ds_id}/CFL", cfl.accuracy, cfl.report.t_train, cfl.report.e_tot),
        ]
        if verbose:
            rt_d = 100 * (1 - enfed.report.t_train / dfl_t)
            re_d = 100 * (1 - enfed.report.e_tot / dfl_e)
            rt_c = 100 * (1 - enfed.report.t_train / cfl.report.t_train)
            re_c = 100 * (1 - enfed.report.e_tot / cfl.report.e_tot)
            print(f"[table4/{ds_id}] EnFed acc={enfed.accuracy:.3f} "
                  f"T={enfed.report.t_train:.2f}s E={enfed.report.e_tot:.1f}J | "
                  f"vs DFL: -{rt_d:.0f}% time, -{re_d:.0f}% energy | "
                  f"vs CFL: -{rt_c:.0f}% time, -{re_c:.0f}% energy")
    return rows


if __name__ == "__main__":
    run()
