"""ShapeDtypeStruct input factories + sharding rules for every step kind.

Everything here is abstract (no device allocation): the dry-run lowers
``train_step`` / ``prefill_step`` / ``serve_step`` against these specs.
The modality-frontend carve-out lives here too: audio gets precomputed
frame embeddings, VLM gets precomputed patch embeddings.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import Transformer
from repro.models.config import ModelConfig

SDS = jax.ShapeDtypeStruct


def _batch_tuple(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _nb(mesh: Mesh):
    bt = _batch_tuple(mesh)
    return int(np.prod([mesh.shape[a] for a in bt])) if bt else 1


# ---------------------------------------------------------------------------
# token / frontend inputs
# ---------------------------------------------------------------------------


def train_inputs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, SDS]:
    d = {
        "tokens": SDS((batch, seq), jnp.int32),
        "labels": SDS((batch, seq), jnp.int32),
    }
    if cfg.frontend == "audio":
        d["frames"] = SDS((batch, seq, cfg.d_model), cfg.jnp_dtype)
    if cfg.frontend == "vision":
        d["prefix_embeds"] = SDS((batch, cfg.num_prefix_tokens, cfg.d_model), cfg.jnp_dtype)
    return d


def prefill_inputs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, SDS]:
    d = train_inputs(cfg, batch, seq)
    del d["labels"]
    return d


def decode_inputs(cfg: ModelConfig, batch: int) -> Dict[str, SDS]:
    return {"tokens": SDS((batch, 1), jnp.int32)}


def decode_memory(cfg: ModelConfig, batch: int, seq: int) -> Optional[SDS]:
    """Encoder memory for enc-dec decode (frames already encoded)."""
    if cfg.is_encoder_decoder:
        return SDS((batch, seq, cfg.d_model), cfg.jnp_dtype)
    return None


def cache_shapes(model: Transformer, batch: int, max_len: int):
    return jax.eval_shape(lambda: model.init_cache(batch, max_len))


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------


def batch_input_shardings(inputs, mesh: Mesh, client_stacked: bool = False,
                          client_axes: Tuple[str, ...] = ()):
    """Inputs shard their leading batch axis over ('pod','data') — or over
    the client axes when feeding the federated (client-stacked) step."""
    if client_stacked and client_axes:
        spec0 = client_axes if len(client_axes) > 1 else client_axes[0]
    else:
        bt = _batch_tuple(mesh)
        spec0 = (bt if len(bt) > 1 else (bt[0] if bt else None))

    def f(leaf):
        axes = [None] * len(leaf.shape)
        if axes and leaf.shape[0] % max(_nb(mesh), 1) == 0 and leaf.shape[0] > 1:
            axes[0] = spec0
        return NamedSharding(mesh, P(*axes))

    return jax.tree_util.tree_map(f, inputs)


def cache_shardings(cache, mesh: Mesh):
    """Decode-state sharding heuristics (DESIGN.md §5):

    * stacked-layer leading axis (scan subtrees) never sharded;
    * batch axis over ('pod','data') when divisible;
    * otherwise a long (>=2048) sequence axis is sharded over 'data'
      (sequence-parallel decode for global_batch=1 long-context);
    * the innermost axis is tensor-parallel over 'model' when divisible.
    """
    bt = _batch_tuple(mesh)
    nb = _nb(mesh)
    model_n = mesh.shape["model"] if "model" in mesh.axis_names else 1
    data_n = mesh.shape["data"] if "data" in mesh.axis_names else 1

    def f(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        start = 1 if "scan" in keys else 0
        shape = leaf.shape
        axes = [None] * len(shape)
        used_data = False
        if len(shape) > start and shape[start] > 1 and shape[start] % nb == 0:
            axes[start] = bt if len(bt) > 1 else bt[0]
            used_data = True
        else:
            for j in range(start + 1, len(shape)):
                if shape[j] >= 2048 and data_n > 1 and shape[j] % data_n == 0:
                    axes[j] = "data"
                    used_data = True
                    break
        last = len(shape) - 1
        if last > start and axes[last] is None and model_n > 1 and shape[last] % model_n == 0:
            axes[last] = "model"
        return NamedSharding(mesh, P(*axes))

    return jax.tree_util.tree_map_with_path(f, cache)
