"""RecurrentGemma-2B [arXiv:2402.19427] — Griffin hybrid: RG-LRU linear
recurrence blocks interleaved with local (windowed) attention at 2:1.

Assigned spec: 26L, d_model=2560, 10H (MQA kv=1, head_dim 256),
d_ff=7680, vocab=256000, local window 2048, logit softcap 30.
26 layers = 8 x (rec, rec, local) + (rec, rec) tail.
Sub-quadratic decode state (RG-LRU state + windowed KV) => long_500k runs.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    citation="arXiv:2402.19427",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    block_pattern=("rglru", "rglru", "local"),
    local_window=2048,
    rnn_width=2560,
    conv_width=4,
    logit_softcap=30.0,
    tie_embeddings=True,
    dtype="bfloat16",
)
