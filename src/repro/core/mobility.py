"""Opportunistic-world simulator: device mobility, radio-range neighbor
discovery, and per-round contract re-negotiation — shared by BOTH EnFed
engines.

EnFed's premise is *opportunistic* collaboration (paper §III): the
requesting device recruits whoever happens to be in radio range, and the
neighborhood it exploits is transient — devices walk in and out of range
while a session runs.  Before this module both engines froze the
neighborhood at handshake time.  This module turns the contributor set
into a simulated world with three layers:

* **Counter-based kinematics** (:func:`device_position`).  Every device
  walks a discretized random-waypoint trajectory: time is split into legs
  of ``leg_rounds`` rounds, waypoint ``k`` of device ``d`` is a pure
  counter-based ``jax.random`` draw from ``(seed, d, k)`` (the same
  hashing style as ``repro.core.schedule``), and the position at round
  ``r`` linearly interpolates between the leg's endpoints.  Positions are
  a *closed-form function of (seed, round, device)* — no integrated
  state — so the loop engine (concrete round numbers, host-side) and the
  fleet engine (traced round numbers, inside one jit program) derive
  identical trajectories by construction, and any round's positions can
  be queried without replaying earlier rounds.  ``mode="static"`` pins
  every device to its 0th waypoint (classic fixed-topology runs).

* **Radio-range neighbor discovery** (:func:`membership_step`).  Each
  round the requester's candidate contributors are tested against
  ``radio_range_m`` — squared-distance proximity masks feed the contract
  layer.

* **Per-round contract re-negotiation** (:func:`membership_step`).
  Contributors that left radio range or dropped below the battery floor
  are released; devices that walked into range are offered contracts;
  when more eligible devices exist than ``n_max`` slots, the requester
  keeps the top-``n_max`` by contract utility (the same freshness /
  data / battery utility as ``repro.core.incentive``) — an arriving
  higher-utility device *undercuts* and displaces the weakest member.
  The function is pure jnp on arrays: the fleet engine calls it on traced
  round numbers inside its chunked ``while_loop``; the loop engine calls
  it eagerly per round and converts to host dataclasses
  (``repro.core.incentive.contracts_from_membership``).  One
  implementation, two engines, parity by construction
  (``tests/test_mobility.py``, ``tests/test_fleet_engine.py``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# Spatial grid: waypoints are drawn on a GRID x GRID integer lattice and
# positions are carried as int32 lattice coordinates scaled by
# ``leg_rounds`` (so leg interpolation is EXACT integer arithmetic).
# Floats only appear in the display/meter conversion — never in the
# proximity predicate.  This is deliberate: XLA may contract float
# multiply-add chains into FMAs under jit but not under eager
# evaluation, so a float kinematics would let the two engines disagree
# by 1 ULP — enough to flip an in-range test at the boundary.  Integer
# arithmetic is exact in every fusion regime, which is what makes the
# masks bit-identical across engines by construction.
GRID = 512


@dataclasses.dataclass(frozen=True)
class MobilityConfig:
    """World parameters for one simulated session (hashable => usable as
    a static arg of the compiled fleet program).

    ``requester_id`` is the device id of the requesting device in the
    shared kinematics hash-space; fleet lanes use ``requester_id + lane``
    so concurrent requesters walk distinct trajectories.  The default
    offset keeps requester ids clear of contributor ids.
    """

    mode: str = "waypoint"            # "waypoint" | "static"
    arena_m: float = 200.0            # square world side length (meters)
    radio_range_m: float = 80.0       # contract-eligible iff dist <= range
    leg_rounds: int = 4               # rounds per random-waypoint leg
    seed: int = 0                     # kinematics hash seed
    requester_id: int = 1 << 20       # requester lane 0's device id
    battery_floor: float = 0.1        # contributors below this are released
    contributor_capacity_j: float = 40e3  # battery capacity backing level

    def __post_init__(self):
        assert self.mode in ("waypoint", "static"), self.mode
        # scaled lattice coords stay < GRID * leg_rounds; 64 keeps the
        # exact int32 squared-distance test overflow-free
        assert 1 <= self.leg_rounds <= 64

    @property
    def _range2_units(self) -> int:
        """Radio range squared, on the scaled integer lattice (clamped
        to int32 — any range covering the arena diagonal is 'everyone')."""
        units = self.radio_range_m / self.arena_m * GRID * self.leg_rounds
        return min(int(units * units), 2**31 - 1)


def _waypoint_units(seed: int, device_id, k):
    """Waypoint ``k`` of ``device_id``: an int32 lattice point hashed
    from ``(seed, device, k)`` alone — prefix-stable in every argument,
    traced or concrete, and exact (integer) in both engines."""
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed),
                           jnp.asarray(device_id, jnp.uint32)),
        jnp.asarray(k, jnp.uint32))
    return jax.random.randint(key, (2,), 0, GRID, jnp.int32)


def _position_units(mob: MobilityConfig, device_id, r):
    """(2,) int32 lattice position x ``leg_rounds`` at round ``r`` —
    the exact coordinate both engines compare distances in."""
    L = mob.leg_rounds
    if mob.mode == "static":
        return _waypoint_units(mob.seed, device_id, 0) * L
    r = jnp.asarray(r, jnp.int32)
    leg = r // L
    m = r % L
    a = _waypoint_units(mob.seed, device_id, leg)
    b = _waypoint_units(mob.seed, device_id, leg + 1)
    return a * (L - m) + b * m          # exact linear leg interpolation


def device_position(mob: MobilityConfig, device_id, r):
    """(2,) fp32 position in METERS of one device at round ``r`` — the
    display/diagnostic view of the exact lattice coordinate.

    ``device_id`` and ``r`` may be python ints (loop engine) or traced
    scalars (fleet engine) — the derivation is counter-based either way,
    so both engines see the same world.
    """
    scale = float(mob.arena_m) / (GRID * mob.leg_rounds)
    return _position_units(mob, device_id, r).astype(jnp.float32) * scale


def device_positions(mob: MobilityConfig, device_ids, r):
    """Positions (meters) of a device-id array at round ``r``:
    ids shape + (2,)."""
    ids = jnp.asarray(device_ids, jnp.int32)
    flat = jax.vmap(lambda d: device_position(mob, d, r))(ids.reshape(-1))
    return flat.reshape(ids.shape + (2,))


def trajectory(mob: MobilityConfig, device_id, rounds: int):
    """(rounds, 2) closed-form trajectory (meters) — diagnostics/tests."""
    return jax.vmap(lambda r: device_position(mob, device_id, r))(
        jnp.arange(rounds, dtype=jnp.int32))


def in_range_mask(mob: MobilityConfig, requester_id, cand_ids, r):
    """(..., N) bool: candidate within ``radio_range_m`` of its requester
    at round ``r``.  ``requester_id`` broadcasts against leading axes of
    ``cand_ids`` ((N,) for one session, (R, N) for a fleet).  The
    comparison is exact int32 lattice arithmetic — bit-identical whether
    ``r`` is concrete (loop engine) or traced (fleet engine)."""
    ids = jnp.asarray(cand_ids, jnp.int32)
    pos_u = jax.vmap(lambda d: _position_units(mob, d, r))
    req = pos_u(jnp.asarray(requester_id, jnp.int32).reshape(-1)).reshape(
        jnp.asarray(requester_id).shape + (2,))
    cand = pos_u(ids.reshape(-1)).reshape(ids.shape + (2,))
    d = cand - req[..., None, :]
    dist2 = d[..., 0] * d[..., 0] + d[..., 1] * d[..., 1]
    return dist2 <= jnp.int32(mob._range2_units)


def battery_utility_term(level):
    """The dynamic slice of ``incentive.contract_utility``: battery below
    50% is progressively risky.  Written as a single min (no
    multiply-add chain XLA could FMA-contract differently under jit vs
    eager — the parity-safety rule of this module)."""
    return jnp.minimum(jnp.asarray(level, jnp.float32) * jnp.float32(0.4),
                       jnp.float32(0.2))


def static_utility_term(staleness, data_size, max_data):
    """The round-invariant slice of ``incentive.contract_utility``
    (freshness + data richness); precomputed once per session."""
    freshness = 1.0 / (1.0 + jnp.asarray(staleness, jnp.float32))
    data_term = jnp.asarray(data_size, jnp.float32) / jnp.maximum(
        jnp.asarray(max_data, jnp.float32), 1.0)
    return 0.5 * freshness + 0.3 * data_term


def membership_step(mob: MobilityConfig, r, requester_id, cand_ids,
                    cand_mask, base_util, level, n_max: int, blocked=None):
    """One round of contract re-negotiation, pure jnp — THE shared
    membership derivation of both engines.

    Inputs broadcast over any leading batch shape (the fleet engine
    passes (R, N) candidate grids, the loop engine (N,) vectors):

    ``r``            round number (python int or traced scalar);
    ``requester_id`` (...,) requester device ids in the kinematics space;
    ``cand_ids``     (..., N) candidate device ids;
    ``cand_mask``    (..., N) bool — real candidate lanes (padding False);
                     candidates are pre-filtered to *agreeing* devices
                     (has_model, reservation <= offer) at session setup;
    ``base_util``    (..., N) fp32 static utility (freshness + data);
    ``level``        (..., N) fp32 contributor battery fraction;
    ``n_max``        contract slots;
    ``blocked``      optional (..., N) bool — links suspended by the
                     fault world (``repro.core.faults.blocked_mask``:
                     repeatedly-failing members); treated exactly like
                     being out of radio range, so releases/arrivals and
                     undercutting compose with the fault streak.

    Returns ``(member, rank, util)``: ``member`` (..., N) bool — the
    re-negotiated contract set (in-range, above the battery floor, top
    ``n_max`` by utility, arrivals displacing weaker members); ``rank``
    (..., N) int32 utility rank among eligible candidates (0 = best,
    stable lane-index tiebreak); ``util`` the (..., N) fp32 utilities.
    """
    cand_mask = jnp.asarray(cand_mask, bool)
    level = jnp.asarray(level, jnp.float32)
    eligible = (cand_mask
                & in_range_mask(mob, requester_id, cand_ids, r)
                & (level >= jnp.float32(mob.battery_floor)))
    if blocked is not None:
        eligible = eligible & ~jnp.asarray(blocked, bool)
    util = base_util + battery_utility_term(level)
    n = util.shape[-1]
    # rank = how many ELIGIBLE candidates beat me (higher utility, or
    # equal utility at a lower lane index).  Pure comparisons — no
    # epsilon arithmetic that jit fusion could perturb; N is small (one
    # contract table), so the pairwise O(N^2) is free.
    uk, uj = util[..., None, :], util[..., :, None]
    lane = jnp.arange(n, dtype=jnp.int32)
    beats = (uk > uj) | ((uk == uj) & (lane[None, :] < lane[:, None]))
    rank = jnp.sum(beats & eligible[..., None, :], axis=-1).astype(jnp.int32)
    member = eligible & (rank < n_max)
    return member, rank, util


def contributor_discharge(level, member, e_tx, e_refresh, refresh_on,
                          capacity_j: float):
    """New contributor battery fractions after one participating round.

    ``member`` gates who pays at all (current contract holders);
    ``refresh_on`` (broadcastable bool) gates the Phase.REFRESH training
    term — contributors only refresh while their requester's session
    survives the round.  One arithmetic expression shared by both
    engines so battery-floor releases trigger on identical values.
    """
    pay = jnp.asarray(member, jnp.float32)
    drain = (jnp.asarray(e_tx, jnp.float32)
             + jnp.where(refresh_on, jnp.asarray(e_refresh, jnp.float32), 0.0))
    return jnp.maximum(jnp.asarray(level, jnp.float32)
                       - drain * pay / jnp.float32(capacity_j), 0.0)


def membership_events(member_trace):
    """Join/leave statistics from a (rounds, ..., N) membership trace:
    returns ``(joins, leaves)`` summed over rounds 1..end (round 0's
    initial signing counts as neither)."""
    import numpy as np

    m = np.asarray(member_trace, bool)
    if m.shape[0] < 2:
        return 0, 0
    diff = m[1:].astype(np.int8) - m[:-1].astype(np.int8)
    return int((diff > 0).sum()), int((diff < 0).sum())
