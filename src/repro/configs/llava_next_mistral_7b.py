"""LLaVA-NeXT (v1.6) Mistral-7B [hf:llava-hf/llava-v1.6-mistral-7b-hf] —
VLM: Mistral-7B language backbone consuming anyres-tiled image patches.

Assigned spec: 32L, d_model=4096, 32H (GQA kv=8, head_dim 128),
d_ff=14336, vocab=32000.  The vision tower (CLIP-ViT) + projector are
STUBBED per the carve-out: input_specs() provides precomputed patch
embeddings; anyres tiling = base 576 tokens + 4 tiles x 576 = 2880
prefix tokens per image.  Full attention => long_500k skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32_000,
    block_pattern=("attn",),
    frontend="vision",
    num_prefix_tokens=2880,
    rope_theta=1_000_000.0,
    dtype="bfloat16",
)
