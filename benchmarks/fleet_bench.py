"""Fleet-engine scaling benchmark: rounds/s, staged host->device bytes,
and simulated energy as the number of concurrent requester sessions
grows 8 -> 512 — emitted as ``BENCH_fleet.json`` so every PR leaves a
perf trail.

For each fleet size R the jit fleet engine (``repro.core.fleet``) runs
all R sessions as ONE compiled program; the loop engine
(``EnFedSession.run``) is timed on a few sessions and extrapolated to
the same R (its cost is linear in sessions by construction — one Python
round loop each).  The headline metrics:

* **session-rounds/s** (warm, cached jit) — the scaling number;
* **staged index bytes** — what the host ships to the device for
  minibatch scheduling.  The PR 1 engine staged a
  (max_rounds, R, epochs, steps, batch) int32 tensor (plus the
  contributor-refresh plan); the PR 2 engine derives schedules on
  device from counters, staging only (R,) shard sizes and (R, N)
  seeds.  Both numbers land in the JSON as before/after.

``--smoke`` additionally runs a 1-session fleet against the loop-engine
oracle and exits non-zero on any parity regression (rounds, stop
reason, accuracy history, final params) — the CI gate.

  PYTHONPATH=src python -m benchmarks.fleet_bench [--sizes 8,32,128,512]
      [--smoke] [--out BENCH_fleet.json]
"""

from __future__ import annotations

import argparse
import copy
import json
import sys
import time

import numpy as np

from repro.core import (EnFedConfig, EnFedSession, RequesterSpec,
                        SupervisedTask, make_fleet, run_fleet)
from repro.core import schedule
from repro.data import CaloriesDatasetConfig, dirichlet_partition, make_calories_tabular
from repro.models import MLPClassifier, MLPClassifierConfig

BATCH = 32
N_CONTRIB = 3
LOOP_SAMPLE_SESSIONS = 3   # loop engine timed on this many, extrapolated


def _build_problem(seed: int = 0):
    """Shared task + contributor population for every requester."""
    x, y = make_calories_tabular(CaloriesDatasetConfig(num_samples=1200, seed=seed))
    task = SupervisedTask(MLPClassifier(MLPClassifierConfig(8, (32,), 5)), lr=3e-3)
    parts = dirichlet_partition(y, num_clients=N_CONTRIB + 1, alpha=100.0, seed=seed)
    shards = [(x[p], y[p]) for p in parts]
    fleet = make_fleet(N_CONTRIB, seed=seed + 1, p_has_model=1.0)
    states = {}
    for i, dev in enumerate(fleet):
        dev.reservation_price = 0.4
        p = task.init(seed=10 + i)
        p, _ = task.fit(p, shards[i + 1], epochs=1, batch_size=BATCH, seed=i)
        states[dev.device_id] = {"params": p, "data": shards[i + 1]}
    own_x, own_y = shards[0]
    n = int(len(own_x) * 0.8)
    return task, fleet, states, (own_x[:n], own_y[:n]), (own_x[n:], own_y[n:])


def _make_specs(R: int, own_train, own_test, fleet, states, seed: int = 0):
    rng = np.random.default_rng(seed)
    specs = []
    for _ in range(R):
        sel = rng.permutation(len(own_train[0]))[:4 * BATCH]
        specs.append(RequesterSpec(
            own_train=(own_train[0][sel], own_train[1][sel]),
            own_test=own_test, neighborhood=fleet, contributor_states=states))
    return specs


def _pr1_index_bytes(cfg: EnFedConfig, R: int, specs, states) -> int:
    """Bytes the PR 1 engine staged for minibatch scheduling: the
    host-materialized (max_rounds, R, epochs, steps, batch) fit_idx +
    fit_valid + the (R, N, ref_epochs, ref_steps, batch) refresh plan."""
    steps = max(schedule.fit_steps(len(s.own_train[0]), cfg.batch_size)
                for s in specs)
    fit_idx = 4 * cfg.max_rounds * R * cfg.epochs * steps * cfg.batch_size
    fit_valid = 4 * R * cfg.epochs * steps
    ref = 0
    if cfg.contributor_refresh_epochs > 0:
        ref_steps = max(schedule.fit_steps(len(st["data"][0]), cfg.batch_size)
                        for st in states.values())
        n = len(states)
        ref = (4 * R * n * cfg.contributor_refresh_epochs * ref_steps
               * (cfg.batch_size + 1))
    return fit_idx + fit_valid + ref


def _parity_smoke(task, fleet, states, own_train, own_test, cfg) -> dict:
    """1-session fleet vs the loop-engine oracle; the CI regression gate."""
    loop = EnFedSession(task, own_train, own_test, fleet,
                        copy.deepcopy(states), cfg).run()
    fl = run_fleet(task, [RequesterSpec(own_train, own_test, fleet,
                                        copy.deepcopy(states))],
                   cfg).sessions[0]
    if fl.rounds != loop.rounds or fl.stop_reason != loop.stop_reason:
        # histories have different lengths; report the structural
        # divergence instead of diffing them
        return {"pass": False, "rounds": (loop.rounds, fl.rounds),
                "stop": (loop.stop_reason, fl.stop_reason),
                "max_param_diff": None, "max_accuracy_diff": None}
    from jax.flatten_util import ravel_pytree
    lv, _ = ravel_pytree(loop.params)
    fv, _ = ravel_pytree(fl.params)
    max_diff = float(np.abs(np.asarray(lv) - np.asarray(fv)).max())
    acc_diff = float(np.abs(np.asarray(loop.history["accuracy"])
                            - np.asarray(fl.history["accuracy"])).max())
    ok = max_diff < 1e-4 and acc_diff < 1e-5
    return {"pass": bool(ok), "rounds": (loop.rounds, fl.rounds),
            "stop": (loop.stop_reason, fl.stop_reason),
            "max_param_diff": max_diff, "max_accuracy_diff": acc_diff}


def run(verbose: bool = True, sizes=(8, 32, 128, 512), smoke: bool = False,
        out: str | None = None):
    import jax

    task, fleet, states, own_train, own_test = _build_problem()
    cfg = EnFedConfig(desired_accuracy=0.999, max_rounds=3, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1)
    report = {"backend": jax.default_backend(),
              "config": {"max_rounds": cfg.max_rounds, "epochs": cfg.epochs,
                         "batch_size": cfg.batch_size, "n_contrib": N_CONTRIB},
              "results": []}

    if smoke:
        smoke_cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=2, epochs=1,
                                batch_size=BATCH, encrypt=False,
                                contributor_refresh_epochs=1)
        report["parity_smoke"] = _parity_smoke(task, fleet, states, own_train,
                                               own_test, smoke_cfg)
        if verbose:
            print(f"[parity smoke] {report['parity_smoke']}")

    # loop-engine baseline: seconds per session, measured once (cost is
    # per-session linear: one Python dispatch chain per session)
    loop_specs = _make_specs(LOOP_SAMPLE_SESSIONS, own_train, own_test, fleet, states)
    t0 = time.perf_counter()
    for spec in loop_specs:
        EnFedSession(task, spec.own_train, spec.own_test, fleet,
                     {k: dict(v) for k, v in states.items()}, cfg).run()
    loop_s_per_session = (time.perf_counter() - t0) / LOOP_SAMPLE_SESSIONS
    report["loop_baseline_s_per_session"] = loop_s_per_session

    rows = []
    for R in sizes:
        specs = _make_specs(R, own_train, own_test, fleet, states)
        t0 = time.perf_counter()
        result = run_fleet(task, specs, cfg)
        wall = time.perf_counter() - t0          # includes jit compile
        t0 = time.perf_counter()
        result = run_fleet(task, specs, cfg)     # steady-state (cached jit)
        wall_warm = time.perf_counter() - t0
        total_rounds = int(result.rounds.sum())
        rps = total_rounds / wall_warm
        loop_equiv_s = loop_s_per_session * R
        before_idx = _pr1_index_bytes(cfg, R, specs, states)
        report["results"].append({
            "R": R, "cold_s": round(wall, 4), "warm_s": round(wall_warm, 4),
            "session_rounds": total_rounds, "rounds_per_s": round(rps, 2),
            "simulated_energy_j": round(result.total_energy_j, 2),
            "loop_equiv_s": round(loop_equiv_s, 2),
            "speedup_vs_loop": round(loop_equiv_s / wall_warm, 2),
            "staged_host_bytes": result.staged_host_bytes,
            "staged_index_bytes_after": result.staged_index_bytes,
            "staged_index_bytes_before_pr1": before_idx,
            "index_bytes_reduction_x": round(
                before_idx / max(result.staged_index_bytes, 1), 1)})
        rows.append((f"fleet/R={R}", wall_warm * 1e6 / R,
                     f"rounds/s={rps:.1f} E={result.total_energy_j:.1f}J "
                     f"loop_equiv={loop_equiv_s:.1f}s speedup={loop_equiv_s / wall_warm:.1f}x"))
        if verbose:
            print(f"[fleet R={R:4d}] warm {wall_warm:6.2f}s (cold {wall:6.2f}s) | "
                  f"{total_rounds} session-rounds -> {rps:7.1f} rounds/s | "
                  f"staged {result.staged_host_bytes / 1e6:7.2f} MB "
                  f"(index bytes {result.staged_index_bytes} vs PR1 {before_idx}) | "
                  f"loop engine would need ~{loop_equiv_s:6.1f}s "
                  f"({loop_equiv_s / wall_warm:5.1f}x slower)")
    if verbose:
        print(f"[loop baseline] {loop_s_per_session:.2f} s/session "
              f"({LOOP_SAMPLE_SESSIONS} sessions measured)")

    # early-exit demo: a fleet whose sessions all hit the accuracy target
    # in round 1 executes O(1) round bodies even with a 16-round budget
    # (the PR 1 engine scanned all 16 regardless).
    R_demo = min(max(sizes), 128)
    ee_cfg = EnFedConfig(desired_accuracy=0.05, max_rounds=16, epochs=1,
                         batch_size=BATCH, encrypt=False,
                         contributor_refresh_epochs=1)
    ee_specs = _make_specs(R_demo, own_train, own_test, fleet, states)
    run_fleet(task, ee_specs, ee_cfg)                  # compile
    t0 = time.perf_counter()
    ee = run_fleet(task, ee_specs, ee_cfg)
    ee_warm = time.perf_counter() - t0
    bodies = int(ee.history["round_executed"].sum())
    report["early_exit_demo"] = {
        "R": R_demo, "max_rounds": ee_cfg.max_rounds,
        "round_bodies_executed": bodies, "warm_s": round(ee_warm, 4),
        "rounds_per_session": int(ee.rounds.max())}
    if verbose:
        print(f"[early exit R={R_demo}] all sessions stop in round "
              f"{int(ee.rounds.max())}: {bodies}/{ee_cfg.max_rounds} round "
              f"bodies executed, warm {ee_warm:.2f}s")

    if out:
        with open(out, "w") as f:
            json.dump(report, f, indent=2)
        if verbose:
            print(f"[bench] wrote {out}")
    if smoke and not report["parity_smoke"]["pass"]:
        print("PARITY REGRESSION: fleet engine diverged from the loop oracle",
              file=sys.stderr)
        sys.exit(1)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="8,32,128,512",
                    help="comma list of fleet sizes to sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="run the fleet-vs-loop parity gate; exit 1 on regression")
    ap.add_argument("--out", default="BENCH_fleet.json",
                    help="JSON report path ('' disables)")
    args = ap.parse_args()
    run(sizes=tuple(int(s) for s in args.sizes.split(",")),
        smoke=args.smoke, out=args.out or None)


if __name__ == "__main__":
    main()
