"""Public op: masked-weighted FedAvg over pytrees or flat stacks.

``fedavg_flat`` is the jit'd wrapper over the Pallas kernel;
``interpret=None`` (the default everywhere) resolves per backend via
``repro.kernels.common.resolve_interpret`` — compiled on TPU,
interpreted on CPU.  ``fedavg_tree`` applies it to a contributor-stacked
pytree by flattening leaves into one (N, L) stream — the same
serialization the AES transport uses, so on a real deployment decrypt +
aggregate fuse into one pass over the wire buffer.

The fleet engine (``repro.core.fleet``) does not pay the per-round
flatten: it ravels contributor params once at setup
(``repro.utils.tree.tree_ravel``) and launches ``fedavg_flat_batched``
directly on the flat (R, N, P) round-state buffer.  ``fedavg_tree_batched``
remains for callers that hold a stacked pytree.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fedavg.kernel import fedavg_batched_pallas, fedavg_pallas
from repro.kernels.fedavg.ref import fedavg_batched_ref, fedavg_ref


def fedavg_flat(updates, weights, *, use_pallas: bool = True, interpret=None):
    if use_pallas:
        return fedavg_pallas(updates, weights, interpret=interpret)
    return fedavg_ref(updates, weights)


def fedavg_flat_batched(updates, weights, *, use_pallas: bool = True,
                        interpret=None):
    """updates: (R, N, L); weights: (R, N) -> (R, L) fp32 per-session means.

    ``weights`` may be a traced per-round vector — under mobility
    (``repro.core.mobility``) the fleet engine passes each round's
    re-negotiated membership mask directly, so churn costs no extra
    kernel.  An all-zero weight row (a session whose whole neighborhood
    churned out of range) is well-defined: the kernel's
    ``max(sum_w, 1e-9)`` denominator returns a zero vector, and the
    caller substitutes the session's previous params.
    """
    if use_pallas:
        return fedavg_batched_pallas(updates, weights, interpret=interpret)
    return fedavg_batched_ref(updates, weights)


def fedavg_tree_batched(stacked_tree, weights, *, use_pallas: bool = True,
                        interpret=None):
    """Requester-batched tree aggregation for stacked-pytree callers.

    Leaves of ``stacked_tree`` have shape (R, N, ...): R concurrent
    requester sessions, N contributor slots each.  Returns the pytree of
    per-session aggregated params with leaves (R, ...).  All leaves are
    flattened into one (R, N, L) stream so the whole fleet's eq. (14)
    is a single kernel launch.  (The fleet engine skips this per-call
    flatten entirely by carrying its round state pre-raveled.)
    """
    leaves, treedef = jax.tree_util.tree_flatten(stacked_tree)
    r, n = leaves[0].shape[:2]
    sizes = [int(x.size) // (r * n) for x in leaves]
    flat = jnp.concatenate(
        [x.reshape(r, n, -1).astype(jnp.float32) for x in leaves], axis=2)
    avg = fedavg_flat_batched(flat, weights, use_pallas=use_pallas,
                              interpret=interpret)
    out, off = [], 0
    for leaf, sz in zip(leaves, sizes):
        out.append(avg[:, off:off + sz].reshape((r,) + leaf.shape[2:]).astype(leaf.dtype))
        off += sz
    return jax.tree_util.tree_unflatten(treedef, out)


def fedavg_tree(stacked_tree, weights, *, use_pallas: bool = True, interpret=None):
    """Leaves of ``stacked_tree`` have shape (N, ...); returns mean tree."""
    leaves, treedef = jax.tree_util.tree_flatten(stacked_tree)
    n = leaves[0].shape[0]
    sizes = [int(x.size) // n for x in leaves]
    flat = jnp.concatenate([x.reshape(n, -1).astype(jnp.float32) for x in leaves], axis=1)
    avg = fedavg_flat(flat, weights, use_pallas=use_pallas, interpret=interpret)
    out, off = [], 0
    for leaf, sz in zip(leaves, sizes):
        out.append(avg[off:off + sz].reshape(leaf.shape[1:]).astype(leaf.dtype))
        off += sz
    return jax.tree_util.tree_unflatten(treedef, out)
