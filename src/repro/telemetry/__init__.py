"""repro.telemetry — structured observability for both EnFed engines.

The paper's contribution is an accounting argument (per-round training
time, energy, and response time — §IV-G, Tables IV/V), so the repo's
runtime evidence must be more than an ad-hoc dict-of-lists assembled
differently per engine.  This package is the one observability surface:

* **Round events** (:mod:`repro.telemetry.events`) — one
  :class:`RoundEvent` schema (round, requester, phase, membership,
  drop/retry/stale counters, delivered set, battery, accuracy, wire
  bytes, energy) materialized from EITHER engine's per-session history
  by a single adapter (:func:`session_events`).  The loop oracle and
  the compiled fleet program emit the SAME normalized stream on the
  same world — padding and buffer-layout differences are erased at
  this boundary (masks become index sets), so cross-engine equality is
  checkable event for event (:func:`compare_event_streams`).

* **Timing spans** (:mod:`repro.telemetry.spans`) — a host-side
  :class:`Timeline` of nested :class:`Span` records instrumenting the
  real cost centers: jit trace/compile + warm execution ("program" /
  "chunk"), shard staging ("stage"), quantize/dequantize packing
  ("quantize_pack" / "dequant_unpack"), checkpoint I/O
  ("checkpoint_save" / "checkpoint_restore"), and the loop engine's
  AES-CTR transport ("transport").  ``FleetResult.timeline`` /
  ``RunResult.timeline`` carry it; ``Timeline.totals()`` is the
  wall-clock breakdown the bench publishes.

* **Exporters** (:mod:`repro.telemetry.export`) — the event stream as
  JSONL (one event per line, schema-validated round trip) and the
  Timeline as a Chrome-trace/Perfetto ``trace.json``.

* **Profiling hooks** (:mod:`repro.telemetry.profile`) — an opt-in
  ``jax.profiler`` trace around the fleet program and an ``hlo_stats``
  summary (flops / bytes-accessed / memory of the compiled program,
  via :mod:`repro.launch.hlo_stats`).

* **The knob** (:class:`TraceConfig` on ``ExecutionSpec.trace``) —
  selects exports and profiling hooks per run.

House rule, enforced by ``tests/test_telemetry.py`` and the bench's
trace smoke gate: **observation can never change the simulated
outcome**.  Every instrument here is host-side — wall clocks, post-hoc
history adaptation, file exports — and a run with tracing on is bitwise
identical (params, masks, battery) to the same run with tracing off.
New protocol phases or methods must keep that contract: emit events by
extending the history→event adapter, never by touching traced state.
"""

from repro.telemetry.config import TraceConfig
from repro.telemetry.events import (EVENT_PHASES, ROUND_EVENT_FIELDS,
                                    RoundEvent, compare_event_streams,
                                    session_events, validate_events)
from repro.telemetry.export import (read_events_jsonl, timeline_chrome_trace,
                                    write_chrome_trace, write_events_jsonl)
from repro.telemetry.profile import jit_hlo_stats, maybe_jax_profiler
from repro.telemetry.spans import Span, Timeline

__all__ = [
    "TraceConfig",
    "RoundEvent",
    "ROUND_EVENT_FIELDS",
    "EVENT_PHASES",
    "session_events",
    "validate_events",
    "compare_event_streams",
    "Span",
    "Timeline",
    "write_events_jsonl",
    "read_events_jsonl",
    "timeline_chrome_trace",
    "write_chrome_trace",
    "jit_hlo_stats",
    "maybe_jax_profiler",
]
