"""Full HAR comparison scenario: EnFed vs CFL vs DFL(mesh/ring) vs
cloud-only, on both paper datasets (calories->MLP, HARSense->LSTM) —
expressed entirely through the ``repro.api`` facade.

This is the experiment behind Tables IV/V/VII of the paper, at example
scale (the full benchmark lives in benchmarks/).  One ``WorldSpec`` is
built once; ``Experiment.compare`` runs every method on that SAME world,
seed, and cost model, which is what makes the printed reduction
percentages meaningful.

  PYTHONPATH=src python examples/har_federated.py [--dataset har|calories]
                                                  [--engine loop|fleet]
                                                  [--churn] [--compress int8]

``--engine fleet`` runs the EnFed session through the jit-native fleet
engine (repro.core.fleet) instead of the Python round loop — same
protocol, same result (parity-tested), one compiled program; the
baselines are host-side either way.

``--churn`` turns on the opportunistic world (repro.core.mobility): the
neighbors walk random-waypoint trajectories, contracts are re-negotiated
every round as devices enter/leave radio range or hit their battery
floor, and the walkthrough prints the per-round membership so you can
watch the requester keep training while its neighborhood churns.

``--compress int8`` adds an ``enfed-int8`` row to the compare table: the
same world and knobs with the transported updates (and the fleet
engine's round state) int8-compressed — ~4x fewer wire bytes into
eq. (4)-(7), so the table shows the transmission/crypto energy delta
compression buys on the same problem.
"""

import argparse
import dataclasses

import numpy as np

from repro.api import Experiment, ExecutionSpec, MethodSpec, WorldSpec
from repro.core import MobilityConfig, SupervisedTask, make_fleet
from repro.data import (CaloriesDatasetConfig, HARDatasetConfig,
                        dirichlet_partition, make_calories_tabular,
                        make_har_windows)
from repro.models import (LSTMClassifier, LSTMClassifierConfig, MLPClassifier,
                          MLPClassifierConfig)


def build(dataset: str):
    if dataset == "har":
        x, y, _ = make_har_windows(HARDatasetConfig(num_samples=3000, seq_len=32))
        task = SupervisedTask(LSTMClassifier(LSTMClassifierConfig(6, 32, 64, 6)), lr=3e-3)
    else:
        x, y = make_calories_tabular(CaloriesDatasetConfig(num_samples=3000))
        task = SupervisedTask(MLPClassifier(MLPClassifierConfig(8, (64, 32), 5)), lr=3e-3)
    parts = dirichlet_partition(y, num_clients=6, alpha=1.0, seed=0)
    shards = [(x[p], y[p]) for p in parts]
    own_x, own_y = shards[0]
    n = int(len(own_x) * 0.8)
    return task, shards, (own_x[:n], own_y[:n]), (own_x[n:], own_y[n:]), (x, y)


def make_world(task, shards, own_train, own_test, *, fit_epochs: int,
               pooled=None, mobility=None) -> WorldSpec:
    """One shared world: a 5-device neighborhood whose contributors hold
    pre-trained models over their own shards."""
    fleet = make_fleet(5, seed=1, p_has_model=1.0)
    states = {}
    for i, dev in enumerate(fleet):
        dev.reservation_price = 0.4
        p = task.init(seed=10 + i)
        p, _ = task.fit(p, shards[i + 1], epochs=fit_epochs, batch_size=32, seed=i)
        states[dev.device_id] = {"params": p, "data": shards[i + 1]}
    return WorldSpec.single(task, own_train, own_test, fleet, states,
                            pooled_train=pooled, mobility=mobility)


def churn_walkthrough(task, shards, own_train, own_test, args):
    """The opportunistic-world demo: one requester keeps training for the
    whole round budget while neighbors churn through its radio range.

    Every round the session re-negotiates: contributors that wandered
    out of the 90 m range (or drained to the battery floor) are
    released, devices that wandered in are signed, and a higher-utility
    arrival displaces the weakest member.  Rounds with an EMPTY
    neighborhood are survivable — the requester trains alone on its own
    shard.  Both engines derive the identical world; pick with --engine.
    """
    world = make_world(task, shards, own_train, own_test, fit_epochs=1,
                       mobility=MobilityConfig(arena_m=200.0, radio_range_m=90.0,
                                               leg_rounds=2, seed=5))
    res = Experiment(
        world,
        method=MethodSpec(desired_accuracy=args.target, epochs=args.epochs,
                          max_rounds=10, n_max=3,
                          contributor_refresh_epochs=1),
        execution=ExecutionSpec(engine=args.engine)).run()

    print(f"\n=== churn walkthrough ({args.dataset}, engine={res.engine}) ===")
    print(f"{'round':>5} {'members':>8} {'contract set':<18} {'acc':>6} {'battery':>8}")
    prev = None
    for r in range(res.rounds):
        mask = np.asarray(res.history["member_mask"][r]) > 0
        ids = [d for d, m in enumerate(mask) if m]
        note = ""
        if prev is not None:
            joined = sorted(set(ids) - set(prev))
            left = sorted(set(prev) - set(ids))
            bits = ([f"+{j}" for j in joined] + [f"-{l}" for l in left])
            note = "  " + " ".join(bits) if bits else ""
        print(f"{r:>5} {int(mask.sum()):>8} {str(ids):<18} "
              f"{res.history['accuracy'][r]:6.3f} "
              f"{res.history['battery'][r]:8.3f}{note}")
        prev = ids
    print(f"requester finished: {res.rounds} rounds, stop={res.stop_reason}, "
          f"final acc {res.accuracy:.3f}")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", choices=("har", "calories"), default="har")
    ap.add_argument("--target", type=float, default=0.95)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--engine", choices=("loop", "fleet"), default="loop",
                    help="EnFed execution engine (fleet = one jit program)")
    ap.add_argument("--churn", action="store_true",
                    help="opportunistic-world walkthrough: neighbors enter/"
                         "leave radio range mid-session (repro.core.mobility)")
    ap.add_argument("--compress", choices=("int8",), default=None,
                    help="add an enfed-int8 row: same world with the "
                         "transported updates int8-compressed (shows the "
                         "eq. (4)-(7) energy delta in the compare table)")
    args = ap.parse_args()

    task, shards, own_train, own_test, pooled = build(args.dataset)
    if args.churn:
        return churn_walkthrough(task, shards, own_train, own_test, args)

    # one world, N methods: the facade guarantees every method sees the
    # same requesters, contributor states, seed, and cost model
    world = make_world(task, shards, own_train, own_test,
                       fit_epochs=args.epochs, pooled=pooled)
    exp = Experiment(
        world,
        method=MethodSpec(desired_accuracy=args.target, epochs=args.epochs,
                          max_rounds=10, batch_size=32),
        execution=ExecutionSpec(engine=args.engine))
    methods = ["enfed", "cfl",
               dataclasses.replace(exp.method, name="dfl",
                                   topology="mesh", label="dfl-mesh"),
               dataclasses.replace(exp.method, name="dfl",
                                   topology="ring", label="dfl-ring"),
               "cloud"]
    if args.compress:
        methods.insert(1, dataclasses.replace(exp.method,
                                              compress=args.compress,
                                              label="enfed-int8"))
    cmp = exp.compare(methods)

    print(f"\n=== {args.dataset} ===")
    print(cmp.table())
    for row in cmp.reductions("enfed"):
        print(f"EnFed vs {row['baseline']:<10}: "
              f"{row['time_reduction_pct']:+.1f}% time, "
              f"{row['energy_reduction_pct']:+.1f}% energy")
    if args.compress:
        fp32, q8 = cmp["enfed"].report, cmp["enfed-int8"].report
        print(f"int8 wire: t_com {fp32.times.t_com:.4f}s -> "
              f"{q8.times.t_com:.4f}s, E_comm {fp32.e_comm:.3f}J -> "
              f"{q8.e_comm:.3f}J on the same world")
    print("(cloud T_train is the §IV-G response time: upload + cloud "
          "training + round trip)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
