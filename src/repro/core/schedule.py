"""Counter-based minibatch schedule, shared by both EnFed engines.

Both engines draw their shuffled minibatches from the SAME derived
schedule, so engine parity holds by construction instead of by replaying
a host-side ``numpy`` RNG:

* every sample index ``i`` gets a uint32 sort key
  ``hash(fold_in(PRNGKey(seed), epoch), i)`` — a pure counter-based
  ``jax.random`` derivation with **no dependence on the shard size**, so
  the first ``n`` scores of a padded shard equal the scores of the
  unpadded shard (prefix stability);
* an epoch's sample order is the stable argsort of those scores, with
  out-of-shard (padded) slots forced to sort last;
* the order is chopped into ``steps`` batches of ``batch`` indices, with
  a per-sample 0/1 weight mask.  Shards holding at least one full batch
  truncate to ``(n // batch) * batch`` samples (the classic drop-last
  epoch); smaller shards run as ONE padded batch whose padding carries
  zero weight — the vectorized form of the loop engine's old full-batch
  fallback.

The **loop engine** (``SupervisedTask.fit``) evaluates the plan with
``n_pad == n`` host-side, one jitted step per batch.  The **fleet
engine** (``repro.core.fleet``) evaluates the SAME functions inside its
compiled round loop — the round index is a traced scalar, so no
``(max_rounds, R, epochs, steps, batch)`` index tensor is ever
materialized on the host or staged to the device.  Per-requester shard
sizes enter only through the traced ``n`` argument of
:func:`plan_from_scores`; prefix stability guarantees the batches match
the loop engine's exactly.

Seed convention (unchanged from the numpy era): requester fit in round
``r`` uses ``seed = cfg.seed + r``; contributor refresh uses
``seed = cfg.seed + device_id`` (round-invariant).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_UINT32_MAX = jnp.uint32(0xFFFFFFFF)


def index_scores(key, n: int):
    """(n,) uint32 per-sample sort keys; prefix-stable in ``n``.

    Score ``i`` is a threefry hash of ``(key, i)`` alone, so growing
    ``n`` (padding a shard) appends scores without changing existing
    ones — the property that lets one traced fleet program serve
    requesters with different shard sizes.
    """
    idx = jnp.arange(n, dtype=jnp.uint32)
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(idx)
    return jax.vmap(lambda k: jax.random.bits(k, (), jnp.uint32))(keys)


def epoch_scores(seed, epochs: int, n_pad: int):
    """(epochs, n_pad) uint32 scores for one fit call.

    ``seed`` may be a python int (loop engine) or a traced scalar (fleet
    engine deriving ``cfg.seed + round`` inside its round loop).
    """
    base = jax.random.PRNGKey(seed)
    keys = jax.vmap(lambda e: jax.random.fold_in(base, e))(
        jnp.arange(epochs, dtype=jnp.uint32))
    return jax.vmap(lambda k: index_scores(k, n_pad))(keys)


def plan_from_scores(scores, n, batch: int, steps: int):
    """Turn per-epoch scores into gather indices + per-sample weights.

    ``scores``: (epochs, n_pad) uint32 from :func:`epoch_scores`;
    ``n``: true shard size (python int or traced scalar), ``n <= n_pad``;
    ``steps``: static step count, ``steps * batch`` may exceed ``n_pad``
    (trailing positions carry zero weight).

    Returns ``idx`` (epochs, steps, batch) int32 and ``w`` (epochs,
    steps, batch) fp32.  Positions past the usable sample budget —
    ``(n // batch) * batch`` when the shard holds a full batch, else
    ``n`` (the padded single-step fallback) — get weight 0 and index 0.
    """
    epochs, n_pad = scores.shape
    take = steps * batch
    pos = jnp.arange(n_pad, dtype=jnp.int32)
    masked = jnp.where(pos[None, :] < n, scores, _UINT32_MAX)
    perm = jnp.argsort(masked, axis=-1).astype(jnp.int32)  # stable: valid first
    if take > n_pad:
        perm = jnp.pad(perm, ((0, 0), (0, take - n_pad)))
    n_limit = jnp.where(n >= batch, (n // batch) * batch, n)
    w = (jnp.arange(take, dtype=jnp.int32) < n_limit).astype(jnp.float32)
    idx = jnp.where(w > 0, perm[:, :take], 0).astype(jnp.int32)
    return (idx.reshape(epochs, steps, batch),
            jnp.broadcast_to(w.reshape(1, steps, batch), (epochs, steps, batch)))


def fit_steps(n: int, batch: int) -> int:
    """Static step count for a shard: drop-last full batches, or one
    padded+masked step when the shard is smaller than a batch."""
    return max(n // batch, 1)


@functools.partial(jax.jit, static_argnames=("epochs", "n", "batch"))
def minibatch_plan(seed, *, epochs: int, n: int, batch: int):
    """The loop engine's whole fit plan: ``idx, w`` with shapes
    (epochs, fit_steps(n, batch), batch).  Jitted with static shapes so
    successive rounds (seed changes value, not shape) reuse the trace."""
    scores = epoch_scores(seed, epochs, n)
    return plan_from_scores(scores, n, batch, fit_steps(n, batch))
