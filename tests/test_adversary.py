"""Byzantine-contributor world (repro.core.adversary): engine parity,
robust aggregation, and the fault x adversary ordering pin.

Corruption is WORLD state — a closed-form function of (seed, round,
requester, contributor) — so the loop engine (host-side, concrete
rounds) and the fleet engine (traced rounds inside one jit program)
must derive bit-identical attacks: the same corrupted links, the same
garbage payloads, the same robust-clip verdicts, the same screening
energy through the one CostModel.
"""

import copy

import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.core import (AdversaryConfig, CadenceConfig, EnFedConfig,
                        EnFedSession, FaultConfig, MobilityConfig,
                        RequesterSpec, run_fleet)
from repro.core import adversary as adversary_mod
from repro.core.adversary import corruption_mask, corrupt_dense, corrupt_wire
from repro.core.battery import BatteryState
from repro.core.protocol import decayed_round_weights

from test_fleet_engine import BATCH, _build

# fires corruptions every round of the tiny 4-round problem without
# drowning the honest majority (3 contributors)
AC = AdversaryConfig(p_byzantine=0.5, attack="signflip", seed=7)
FC = FaultConfig(p_drop=0.6, p_stale=0.4, max_retries=1, release_after=2,
                 seed=3)
MOB = MobilityConfig(arena_m=120.0, radio_range_m=60.0, leg_rounds=2, seed=5)
CAD = CadenceConfig(n_speed_classes=2, seed=5)


@pytest.fixture(scope="module")
def problem():
    return _build()


def _cfg(**kw):
    base = dict(desired_accuracy=0.99, max_rounds=4, epochs=1,
                batch_size=BATCH, encrypt=False,
                contributor_refresh_epochs=1)
    base.update(kw)
    return EnFedConfig(**base)


def _run_both(problem, cfg):
    task, own_train, own_test, fleet, states = problem
    loop = EnFedSession(task, own_train, own_test, fleet,
                        copy.deepcopy(states), cfg,
                        battery=BatteryState()).run()
    spec = RequesterSpec(own_train=own_train, own_test=own_test,
                         neighborhood=fleet,
                         contributor_states=copy.deepcopy(states),
                         battery=BatteryState())
    fl = run_fleet(task, [spec], cfg).sessions[0]
    return loop, fl


def _assert_mask_parity(loop, fl, key):
    """Bitwise mask equality across engines, padded fleet lanes all-zero."""
    lm = np.stack(loop.history_raw[key])
    fm = np.stack(fl.history_raw[key])
    np.testing.assert_array_equal(fm[:, :lm.shape[1]], lm, err_msg=key)
    assert not fm[:, lm.shape[1]:].any(), f"{key}: padded lanes flagged"


def _assert_adv_parity(loop, fl, *, robust="none"):
    assert fl.rounds == loop.rounds
    assert fl.stop_reason == loop.stop_reason
    # the corruption trace is exact integer world state: bitwise equality
    _assert_mask_parity(loop, fl, "corrupted_mask")
    if robust != "none":
        _assert_mask_parity(loop, fl, "clipped_mask")
    np.testing.assert_allclose(fl.history_raw["battery"],
                               loop.history_raw["battery"],
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(fl.history_raw["accuracy"],
                               loop.history_raw["accuracy"],
                               rtol=1e-5, atol=1e-6)
    lv, _ = ravel_pytree(loop.params)
    fv, _ = ravel_pytree(fl.params)
    np.testing.assert_allclose(np.asarray(fv), np.asarray(lv),
                               rtol=1e-4, atol=1e-5)
    # screening pricing lands identically in both t_agg roll-ups
    assert fl.report.times.t_agg == pytest.approx(loop.report.times.t_agg,
                                                  rel=1e-6)


# ---------------------------------------------------------------------------
# config validation (fail fast at construction, not as NaNs mid-program)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw", [
    dict(p_byzantine=-0.1), dict(p_byzantine=1.5),
    dict(attack="gradient_ascent"), dict(scale=0.0), dict(scale=-2.0),
])
def test_adversary_config_validation(kw):
    with pytest.raises(ValueError):
        AdversaryConfig(**kw)


def test_robust_vocabulary_rejected_early(problem):
    with pytest.raises(ValueError):
        _cfg(robust="krum")


# ---------------------------------------------------------------------------
# world-state semantics
# ---------------------------------------------------------------------------


def test_corruption_mask_deterministic_and_counterbased():
    ac = AdversaryConfig(p_byzantine=0.5, seed=9)
    ids = np.arange(64, dtype=np.int32)
    m1 = np.asarray(corruption_mask(ac, 4, ac.requester_id, ids))
    m2 = np.asarray(corruption_mask(ac, 4, ac.requester_id, ids))
    np.testing.assert_array_equal(m1, m2)  # pure function of the counter
    assert 0 < m1.sum() < len(ids)         # p=0.5 actually splits the links
    # other rounds and other requesters see independent corruption weather
    m3 = np.asarray(corruption_mask(ac, 5, ac.requester_id, ids))
    m4 = np.asarray(corruption_mask(ac, 4, ac.requester_id + 1, ids))
    assert not np.array_equal(m1, m3)
    assert not np.array_equal(m1, m4)


def test_corruption_mask_probability_bounds():
    ids = np.arange(16, dtype=np.int32)
    none = corruption_mask(AdversaryConfig(p_byzantine=0.0), 2, 7, ids)
    all_ = corruption_mask(AdversaryConfig(p_byzantine=1.0), 2, 7, ids)
    assert not np.asarray(none).any()
    assert np.asarray(all_).all()


def test_corrupt_dense_attacks():
    u = np.linspace(-1.0, 1.0, 8).astype(np.float32)
    for attack, expect in [
        ("signflip", -u),
        ("scale", 3.0 * u),
        ("zero", np.zeros_like(u)),
    ]:
        ac = AdversaryConfig(p_byzantine=1.0, attack=attack, scale=3.0)
        np.testing.assert_allclose(
            np.asarray(corrupt_dense(ac, u, True, 2, 7, 11)), expect)
        # corrupt=False is the identity regardless of attack
        np.testing.assert_array_equal(
            np.asarray(corrupt_dense(ac, u, False, 2, 7, 11)), u)
    # noise: counter-keyed garbage — deterministic, payload-independent
    ac = AdversaryConfig(p_byzantine=1.0, attack="noise", scale=2.0)
    n1 = np.asarray(corrupt_dense(ac, u, True, 2, 7, 11))
    n2 = np.asarray(corrupt_dense(ac, np.zeros_like(u), True, 2, 7, 11))
    np.testing.assert_array_equal(n1, n2)
    assert not np.array_equal(
        n1, np.asarray(corrupt_dense(ac, u, True, 3, 7, 11)))


def test_corrupt_wire_never_redensifies():
    q = np.array([-127, -3, 0, 5, 127, 1, -1, 2], np.int8)
    s = np.array([0.5, 0.25], np.float32)
    ac = AdversaryConfig(p_byzantine=1.0, attack="signflip")
    q2, s2 = corrupt_wire(ac, q, s, True, 2, 7, 11)
    assert np.asarray(q2).dtype == np.int8       # codes stay int8-resident
    np.testing.assert_array_equal(np.asarray(q2), -q)  # exact negation
    np.testing.assert_array_equal(np.asarray(s2), s)   # scales untouched
    ac = AdversaryConfig(p_byzantine=1.0, attack="scale", scale=4.0)
    q2, s2 = corrupt_wire(ac, q, s, True, 2, 7, 11)
    np.testing.assert_array_equal(np.asarray(q2), q)   # codes untouched
    np.testing.assert_allclose(np.asarray(s2), 4.0 * s)
    ac = AdversaryConfig(p_byzantine=1.0, attack="zero")
    q2, s2 = corrupt_wire(ac, q, s, True, 2, 7, 11)
    assert not np.asarray(q2).any() and not np.asarray(s2).any()


def test_decayed_round_weights():
    w = np.array([[1.0, 2.0, 4.0]], np.float32)
    lag = np.array([[0, 1, 3]], np.int32)
    out = np.asarray(decayed_round_weights(w, lag, 0.5))
    np.testing.assert_allclose(out, [[1.0, 1.0, 0.5]])
    np.testing.assert_allclose(
        np.asarray(decayed_round_weights(w, lag, 1.0)), w)


# ---------------------------------------------------------------------------
# engine parity under adversaries + robust aggregation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cfg_kw,robust", [
    (dict(adversary=AC, robust="trimmed_mean"), "trimmed_mean"),
    (dict(adversary=AdversaryConfig(p_byzantine=0.5, attack="noise",
                                    scale=2.0, seed=7),
          robust="clip", faults=FC, compress="int8"), "clip"),
    (dict(adversary=AdversaryConfig(p_byzantine=0.5, attack="scale",
                                    scale=5.0, seed=7),
          robust="median", faults=FC, staleness_gamma=0.5), "median"),
    (dict(adversary=AdversaryConfig(p_byzantine=0.5, attack="zero", seed=7),
          robust="trimmed_mean", compress="int8"), "trimmed_mean"),
    (dict(adversary=AC, robust="clip", encrypt=True), "clip"),
    (dict(adversary=AC, robust="trimmed_mean", cadence=CAD,
          staleness_gamma=0.7), "trimmed_mean"),
    (dict(adversary=AC, robust="clip", mobility=MOB), "clip"),
], ids=["signflip-trim", "noise-clip-faults-int8", "scale-median-decay",
        "zero-trim-int8", "signflip-clip-encrypt", "cadence-trim-decay",
        "mobility-clip"])
def test_engines_agree_adversary_worlds(problem, cfg_kw, robust):
    cfg = _cfg(**cfg_kw)
    loop, fl = _run_both(problem, cfg)
    _assert_adv_parity(loop, fl, robust=robust)
    # the adversary provably fired in this world
    assert np.stack(loop.history_raw["corrupted_mask"]).sum() > 0


def test_engines_agree_five_way_composition(problem):
    """The full world product: mobility x faults x cadence x int8 wire x
    adversary x trimmed mean x staleness decay, one jit program vs the
    host oracle."""
    cfg = _cfg(adversary=AC, robust="trimmed_mean", staleness_gamma=0.8,
               faults=FC, cadence=CAD, mobility=MOB, compress="int8")
    loop, fl = _run_both(problem, cfg)
    _assert_adv_parity(loop, fl, robust="trimmed_mean")
    assert np.stack(loop.history_raw["corrupted_mask"]).sum() > 0
    # satellite: the normalized event streams agree field for field
    from repro.telemetry.events import compare_event_streams, session_events
    assert compare_event_streams(session_events(loop),
                                 session_events(fl)) == []


def test_clip_actually_clips(problem):
    """The scale attack inflates norms past the median -> the clip
    aggregator flags exactly the corrupted deliveries, in both engines."""
    ac = AdversaryConfig(p_byzantine=0.5, attack="scale", scale=50.0, seed=7)
    cfg = _cfg(adversary=ac, robust="clip")
    loop, fl = _run_both(problem, cfg)
    _assert_adv_parity(loop, fl, robust="clip")
    clipped = np.stack(loop.history_raw["clipped_mask"])
    assert clipped.sum() > 0


def test_honest_world_with_adversary_off_is_untouched(problem):
    """p_byzantine=0 must be bit-identical to adversary=None — the
    adversary plumbing adds observability, never arithmetic."""
    loop0, fl0 = _run_both(problem, _cfg())
    ac0 = AdversaryConfig(p_byzantine=0.0)
    loop1, fl1 = _run_both(problem, _cfg(adversary=ac0))
    for a, b in ((loop0, loop1), (fl0, fl1)):
        av, _ = ravel_pytree(a.params)
        bv, _ = ravel_pytree(b.params)
        assert np.array_equal(np.asarray(av), np.asarray(bv))
        np.testing.assert_array_equal(a.history_raw["battery"],
                                      b.history_raw["battery"])
    # the p=0 world still carries the (all-zero) trace; None worlds don't
    assert "corrupted_mask" not in loop0.history_raw
    assert not np.stack(loop1.history_raw["corrupted_mask"]).any()


# ---------------------------------------------------------------------------
# the fault x adversary ordering pin (satellite): stale substitution
# FIRST, corruption keyed on the DELIVERING round
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("compress", [None, "int8"], ids=["dense", "int8"])
def test_ordering_pin_stale_then_corrupt(problem, compress):
    """Under the noise attack at p=1, every delivered payload is
    counter-keyed garbage of the DELIVERING round — so a world where
    every delivery is stale must train identically to one where none is.
    Any other ordering (corrupt-then-substitute, or draws keyed on the
    trained round) would deliver round-(r-1) garbage instead and the
    params would diverge.  Pinned in both engines."""
    ac = AdversaryConfig(p_byzantine=1.0, attack="noise", scale=0.5, seed=7)
    all_stale = FaultConfig(p_drop=0.0, p_stale=1.0, max_retries=0, seed=3)
    no_stale = FaultConfig(p_drop=0.0, p_stale=0.0, max_retries=0, seed=3)
    cfg_s = _cfg(adversary=ac, faults=all_stale, compress=compress)
    cfg_f = _cfg(adversary=ac, faults=no_stale, compress=compress)
    loop_s, fl_s = _run_both(problem, cfg_s)
    loop_f, fl_f = _run_both(problem, cfg_f)
    assert np.stack(loop_s.history_raw["stale"]).sum() > 0  # stale fired
    for a, b in ((loop_s, loop_f), (fl_s, fl_f)):
        av, _ = ravel_pytree(a.params)
        bv, _ = ravel_pytree(b.params)
        assert np.array_equal(np.asarray(av), np.asarray(bv)), \
            "corruption keyed/applied before stale substitution"


# ---------------------------------------------------------------------------
# crash-resume with the adversary enabled
# ---------------------------------------------------------------------------


def _adv_cfg(max_rounds=6):
    return _cfg(max_rounds=max_rounds, adversary=AC, robust="clip",
                staleness_gamma=0.8, faults=FC)


def test_loop_kill_and_resume_with_adversary(problem, tmp_path):
    from test_checkpoint_resume import _assert_identical, _kill_after, \
        _run_loop
    cfg = _adv_cfg()
    full = _run_loop(problem, cfg)
    d = str(tmp_path / "ck")
    _run_loop(problem, cfg, checkpoint_dir=d)
    _kill_after(d, 3)
    res = _run_loop(problem, cfg, resume_from=d)
    _assert_identical(full, res, mask_key="corrupted_mask")
    np.testing.assert_array_equal(np.stack(full.history_raw["clipped_mask"]),
                                  np.stack(res.history_raw["clipped_mask"]))


def test_fleet_kill_and_resume_with_adversary(problem, tmp_path):
    from test_checkpoint_resume import _assert_identical, _kill_after, _spec
    task = problem[0]
    cfg = _adv_cfg()
    d_full = str(tmp_path / "full")
    full = run_fleet(task, [_spec(problem)], cfg, round_chunk=2,
                     checkpoint_dir=d_full, checkpoint_every=2)
    d_kill = str(tmp_path / "kill")
    run_fleet(task, [_spec(problem)], cfg, round_chunk=2,
              checkpoint_dir=d_kill, checkpoint_every=2)
    _kill_after(d_kill, 2)
    res = run_fleet(task, [_spec(problem)], cfg, round_chunk=2,
                    resume_from=d_kill)
    _assert_identical(full.sessions[0], res.sessions[0],
                      mask_key="corrupted_mask")


# ---------------------------------------------------------------------------
# enfed-only enforcement + telemetry surface
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cfg_kw", [
    dict(adversary=AC), dict(robust="trimmed_mean"),
    dict(staleness_gamma=0.5),
], ids=["adversary", "robust", "gamma"])
def test_fleet_baselines_refuse_adversary(problem, cfg_kw):
    task = problem[0]
    from test_checkpoint_resume import _spec
    with pytest.raises(ValueError, match="enfed-only"):
        run_fleet(task, [_spec(problem)], _cfg(**cfg_kw), method="dfl")


def test_api_baselines_warn_and_strip(problem):
    from repro.api import Experiment, MethodSpec, WorldSpec

    task, own_train, own_test, fleet, states = problem
    world = WorldSpec.single(task, own_train, own_test, fleet, states)
    method = MethodSpec(name="cfl", max_rounds=1, epochs=1,
                        batch_size=BATCH, encrypt=False, adversary=AC,
                        robust="trimmed_mean")
    with pytest.warns(UserWarning, match="enfed-only"):
        res = Experiment(world, method).run()
    assert res.rounds >= 1                      # ran honestly, unpoisoned
    assert res.corruption_summary is None


def test_trace_carries_corruption_sets(problem):
    """RoundEvent.corrupted/clipped: index sets on adversary worlds,
    identical across engines; None (not empty) on honest worlds."""
    from repro.api import Experiment, ExecutionSpec, MethodSpec, WorldSpec

    task, own_train, own_test, fleet, states = problem
    world = WorldSpec.single(task, own_train, own_test, fleet, states)
    method = MethodSpec(desired_accuracy=0.99, max_rounds=4, epochs=1,
                        batch_size=BATCH, encrypt=False,
                        contributor_refresh_epochs=1, adversary=AC,
                        robust="clip")
    by_engine = {}
    for engine in ("loop", "fleet"):
        res = Experiment(world, method, ExecutionSpec(engine=engine)).run()
        rounds = [e for e in res.trace if e.phase == "round"]
        assert all(e.corrupted is not None and e.clipped is not None
                   for e in rounds)
        by_engine[engine] = [(e.corrupted, e.clipped) for e in rounds]
        summary = res.corruption_summary
        assert summary is not None and summary["corrupted_links"] > 0
    assert by_engine["loop"] == by_engine["fleet"]
    # honest world: absence stays distinguishable from an observed zero
    clean = Experiment(world, MethodSpec(
        desired_accuracy=0.99, max_rounds=2, epochs=1, batch_size=BATCH,
        encrypt=False, contributor_refresh_epochs=1)).run()
    assert all(e.corrupted is None and e.clipped is None
               for e in clean.trace)
    assert clean.corruption_summary is None
