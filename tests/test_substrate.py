"""Substrate tests: optimizers, schedules, data pipeline, partitioning,
checkpointing, pytree utils."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import (dirichlet_partition, iid_partition, make_calories_tabular,
                        make_har_windows, synthetic_token_batches, train_test_split)
from repro.data.har import CaloriesDatasetConfig, HARDatasetConfig
from repro.data.partition import partition_stats
from repro.optim import adam, apply_updates, sgd, warmup_cosine
from repro.utils.tree import (flatten_to_vector, tree_bytes, tree_size,
                              tree_weighted_mean, unflatten_from_vector)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("make_opt", [lambda: adam(0.1), lambda: sgd(0.05, momentum=0.9)])
def test_optimizer_minimizes_quadratic(make_opt):
    opt = make_opt()
    params = {"x": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["x"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 1e-3


def test_adam_grad_clip():
    opt = adam(0.1, grad_clip=1.0)
    params = {"x": jnp.array([1.0])}
    state = opt.init(params)
    upd, _ = opt.update({"x": jnp.array([1e6])}, state, params)
    assert abs(float(upd["x"][0])) < 1.0  # clipped step stays ~lr-sized


def test_warmup_cosine_schedule_shape():
    f = warmup_cosine(1.0, warmup_steps=10, total_steps=100)
    assert float(f(jnp.int32(0))) == pytest.approx(0.0)
    assert float(f(jnp.int32(10))) == pytest.approx(1.0, abs=0.05)
    assert float(f(jnp.int32(100))) < 0.2


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_har_dataset_learnable_and_shaped():
    x, y, user = make_har_windows(HARDatasetConfig(num_samples=500, seq_len=16))
    assert x.shape == (500, 16, 6) and y.shape == (500,) and user.shape == (500,)
    assert set(np.unique(y)) <= set(range(6))
    # static classes (sitting/standing) have much lower variance than running
    run_var = x[y == 0].std()
    sit_var = x[y == 2].std()
    assert run_var > sit_var


def test_calories_dataset_classes_nondegenerate():
    x, y = make_calories_tabular(CaloriesDatasetConfig(num_samples=2000))
    counts = np.bincount(y, minlength=5)
    assert (counts > 50).all(), f"degenerate class distribution {counts}"


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 10), st.floats(0.1, 5.0))
def test_dirichlet_partition_covers_everything(nc, alpha):
    y = np.random.default_rng(0).integers(0, 4, 400)
    parts = dirichlet_partition(y, nc, alpha=alpha, seed=1)
    all_idx = np.concatenate(parts)
    assert set(all_idx.tolist()) >= set(range(len(y))) - set()  # coverage (with top-ups)
    for p in parts:
        assert len(p) >= 8


def test_dirichlet_more_skewed_than_iid():
    y = np.random.default_rng(0).integers(0, 6, 3000)
    d_parts = dirichlet_partition(y, 6, alpha=0.3, seed=1)
    i_parts = iid_partition(len(y), 6, seed=1)
    _, d_tv = partition_stats(y, d_parts)
    _, i_tv = partition_stats(y, i_parts)
    assert d_tv > i_tv * 2


def test_token_pipeline_shapes():
    batches = list(synthetic_token_batches(1000, 4, 16, num_batches=3))
    assert len(batches) == 3
    for b in batches:
        assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)
        # labels are next-token shifted
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "step": jnp.int32(7)}
    save_checkpoint(str(tmp_path), 7, state)
    save_checkpoint(str(tmp_path), 12, state)
    assert latest_step(str(tmp_path)) == 12
    restored, step = restore_checkpoint(str(tmp_path), state)
    assert step == 12
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"w": jnp.zeros((3, 3))})


# ---------------------------------------------------------------------------
# tree utils
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 50), st.integers(0, 1000))
def test_flatten_roundtrip(n, seed):
    r = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(r.normal(size=(n,)).astype(np.float32)),
            "b": {"c": jnp.asarray(r.normal(size=(2, 3)).astype(np.float32))}}
    vec, unflatten = flatten_to_vector(tree)
    assert vec.shape == (tree_size(tree),)
    back = unflatten(vec)
    np.testing.assert_allclose(np.asarray(back["b"]["c"]),
                               np.asarray(tree["b"]["c"]), rtol=1e-6)
    back2 = unflatten_from_vector(vec, tree)
    np.testing.assert_allclose(np.asarray(back2["a"]), np.asarray(tree["a"]), rtol=1e-6)


def test_tree_weighted_mean_matches_manual():
    trees = [{"x": jnp.full((3,), float(i))} for i in range(4)]
    out = tree_weighted_mean(trees, jnp.asarray([1.0, 0.0, 0.0, 3.0]))
    np.testing.assert_allclose(np.asarray(out["x"]), np.full(3, 9.0 / 4.0), rtol=1e-6)
