"""EnFed Algorithm 1 — the requesting device's session loop (loop engine).

This is the faithful protocol implementation used by the fleet
simulator: handshake (contract-theory contributor selection + AES key
exchange), round loop (collect -> decrypt -> aggregate -> fit -> score),
gated on desired accuracy, battery threshold, and the round budget.

The model updates really are AES-128-CTR encrypted/decrypted through
``repro.core.crypto`` and the byte counts feed the eq. (4)-(7) cost
model, so the reported times/energies account for the same phases the
paper measures.

Two engines execute this protocol (phase names and stop reasons shared
via ``repro.core.protocol``):

* the **loop engine** below — one Python iteration per round; the
  readable reference oracle, and the only engine that runs the real AES
  transport bytes through ``repro.core.crypto`` each round.
* the **fleet engine** (``repro.core.fleet``) — many concurrent
  requester sessions vectorized into one jit program.  Select it with
  ``EnFedSession.run(engine="fleet")``; its round/stop/battery semantics
  are parity-tested against this loop in ``tests/test_fleet_engine.py``.

Both engines draw minibatches from the counter-based derived schedule
in ``repro.core.schedule`` (``task.fit`` evaluates it host-side with
``seed = cfg.seed + round``; the fleet engine derives the same indices
on device from its traced round number), so their batches are identical
by construction.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import adversary as adversary_mod
from repro.core import aggregation, crypto, faults as faults_mod
from repro.core import cadence as cadence_mod
from repro.core import mobility, protocol, topology
from repro.core.adversary import AdversaryConfig
from repro.core.battery import BatteryState
from repro.core.cadence import CadenceConfig
from repro.core.energy import CostModel, EnergyReport, update_wire_bytes
from repro.core.faults import FaultConfig
from repro.kernels.quantize.ops import (compress_update, decompress_update,
                                        resolve_compress)
from repro.kernels.robust.ops import ROBUST_METHODS, robust_aggregate
from repro.core.incentive import (Contract, NeighborDevice, candidate_pool,
                                  contracts_from_membership,
                                  select_contributors)
from repro.core.mobility import MobilityConfig
from repro.core.topology import AggregationStrategy
from repro.telemetry.spans import Timeline
from repro.utils.tree import flatten_to_vector, tree_bytes, tree_size, unflatten_from_vector


@dataclasses.dataclass
class EnFedConfig:
    desired_accuracy: float = 0.95   # A_A
    max_rounds: int = 10             # R_A  (paper sets 10)
    n_max: int = 5                   # N_max contributors (paper setup: 5 VMs)
    battery_threshold: float = 0.2   # B_min (paper: 20%)
    offered_incentive: float = 0.6
    epochs: int = 5                  # E  (local fit epochs per round)
    batch_size: int = 32             # B_A
    encrypt: bool = True
    contributor_refresh_epochs: int = 1  # contributors keep training between rounds
    seed: int = 0
    # transported-update compression (None = fp32 wire).  "int8": every
    # model update travels (and the fleet engine's round state persists)
    # as a tile-padded int8 payload + per-tile fp32 scales — ~4x fewer
    # bytes into the AES transport and eq. (4)-(7), at a quantization
    # error bounded per tile by absmax/254.  The first accuracy-affecting
    # protocol knob: both engines apply the identical
    # compress/decompress round-trip, parity-tested in
    # tests/test_compress.py.  "auto" resolves to "int8" or None per
    # model size via repro.kernels.quantize.ops.resolve_compress — below
    # the tile-padding crossover int8 is strictly worse (bigger wire,
    # slower simulation), so small models fall back to fp32.
    compress: Optional[str] = None
    # which signed contributors feed eq. (14) each round (None = all, the
    # paper's virtual-server behaviour); see topology.contributor_round_mask
    strategy: Optional[AggregationStrategy] = None
    # opportunistic world (repro.core.mobility): when set, the contributor
    # set is re-negotiated EVERY round — devices churn in and out of radio
    # range, contributor batteries drain and release members at the floor,
    # arrivals undercut weaker members.  None = the static-neighborhood
    # protocol above.
    mobility: Optional[MobilityConfig] = None
    # unreliable-link world (repro.core.faults): when set, every
    # (requester, contributor) link can drop a round's update, retry it
    # (bounded, each retransmission re-priced through the cost model),
    # or deliver the round-(r-1) wire image instead; undelivered links
    # are zeroed out of the round's aggregation mask (Phase.DELIVER) and
    # an all-links-failed round falls back to the requester's own
    # params.  Counter-based world state like mobility — both engines
    # derive bit-identical fault outcomes.  None = perfect links.
    faults: Optional[FaultConfig] = None
    # asynchronous-cadence world (repro.core.cadence): when set, the
    # engines loop over GLOBAL EVENT STEPS instead of rounds — the
    # requester's own round clock advances only on steps where its
    # counter-based tick fires (speed class / duty cycle / transient
    # offline / battery pacing), world state (mobility kinematics, fault
    # weather) keys on the step counter, and contributors that do not
    # tick skip their refresh, leaving their resident wire image for the
    # requester to aggregate as-is (the straggler path).  Counter-based
    # world state like mobility/faults — both engines derive bit-identical
    # tick sets.  None = today's lockstep loop, bit-for-bit.
    cadence: Optional[CadenceConfig] = None
    # Byzantine-contributor world (repro.core.adversary): when set, every
    # (round, requester, contributor) link draws a counter-based
    # corruption outcome and a corrupted link delivers a poisoned WIRE
    # payload (signflip / scale / noise / zero) instead of the true
    # image.  Corruption is transport-level — the contributor's resident
    # state is never modified — and keys on the DELIVERING round, after
    # any stale-delivery substitution (the fault x adversary ordering
    # pin).  Counter-based world state like mobility/faults/cadence —
    # both engines derive bit-identical attacks.  None = honest fleet.
    adversary: Optional[AdversaryConfig] = None
    # Byzantine-robust Phase.AGGREGATE (repro.kernels.robust): "none"
    # keeps eq. (14) fedavg byte-for-byte; "clip" L2-clips contributions
    # to the masked median norm (and reports which links clipped);
    # "trimmed_mean" / "median" swap the per-coordinate statistic.  Both
    # engines call the ONE robust_aggregate entry, so clip masks agree
    # bitwise.  The screening pass is priced per round through
    # CostModel.screening_energy — robustness is never free.
    robust: str = "none"
    # staleness-decayed aggregation weights (ROADMAP 1b): a contributor
    # whose delivered image lags `lag` rounds behind the aggregate
    # (cadence stride/phase lag, +1 for a fault-stale delivery) weighs
    # gamma**lag into eq. (14).  1.0 (default) = no decay, bit-for-bit
    # today's weights; 0.0 = stale images drop out entirely.  Zero new
    # state: the lag is the closed form cadence.image_lag.
    staleness_gamma: float = 1.0

    def __post_init__(self):
        if self.compress not in (None, "int8", "auto"):
            raise ValueError(
                f"unknown compress mode {self.compress!r} (None|'int8'|'auto')")
        if self.robust not in ROBUST_METHODS:
            raise ValueError(
                f"robust must be one of {ROBUST_METHODS} (got {self.robust!r})")
        if not 0.0 <= self.staleness_gamma <= 1.0:
            raise ValueError(
                f"staleness_gamma must be within [0, 1] "
                f"(got {self.staleness_gamma})")


@dataclasses.dataclass
class SessionResult:
    accuracy: float
    rounds: int
    n_contributors: int
    report: EnergyReport
    battery: BatteryState
    # DEPRECATED view: prefer the normalized event stream (``trace``) —
    # attribute access warns (DeprecationWarning) via the property
    # attached below the class; internal consumers read ``history_raw``
    history: Dict[str, List[float]] = dataclasses.field(
        repr=False, compare=False)
    stop_reason: str
    params: object = None
    model_bytes: int = 0   # one update's wire bytes (feeds event wire_bytes)

    @property
    def history_raw(self) -> Dict[str, List[float]]:
        """The raw per-engine dict-of-lists, without the deprecation
        warning — the internal surface (telemetry adapter, aggregation)."""
        return self.__dict__["_history_raw"]

    @property
    def trace(self):
        """The session as a normalized RoundEvent list (requester 0) —
        the engine-independent view of ``history``."""
        from repro.telemetry.events import session_events

        return session_events(self)


def _history_deprecated_get(self):
    warnings.warn(
        "SessionResult.history is deprecated; use .trace (normalized "
        "RoundEvent stream) or .history_raw for the raw buffers",
        DeprecationWarning, stacklevel=2)
    return self.__dict__["_history_raw"]


def _history_deprecated_set(self, value):
    # dataclass __init__ assigns through here — store raw, never warn
    # on construction
    self.__dict__["_history_raw"] = value


# attached after the dataclass decorator ran, so the generated __init__
# keeps its `history` parameter but access goes through the property
SessionResult.history = property(_history_deprecated_get,
                                 _history_deprecated_set)


class EnFedSession:
    """One requesting device M building its model for application A.

    ``task`` must provide:
      fit(params, data, epochs, batch_size, seed) -> (params, losses)
      evaluate(params, data) -> accuracy
      init(seed) -> params
    ``contributors`` hold their own (pre-trained) params and local data.
    """

    def __init__(self, task, own_train, own_test, fleet: List[NeighborDevice],
                 contributor_states: Dict[int, dict],
                 cfg: Optional[EnFedConfig] = None,
                 cost_model: Optional[CostModel] = None,
                 battery: Optional[BatteryState] = None):
        self.task = task
        self.own_train = own_train
        self.own_test = own_test
        self.fleet = fleet
        self.contributor_states = contributor_states  # id -> {params, data}
        # cfg=None constructs a fresh default per session — a shared
        # `cfg=EnFedConfig()` default would be ONE mutable instance
        # evaluated at import time, aliased across every caller
        self.cfg = cfg if cfg is not None else EnFedConfig()
        self.cost = cost_model or CostModel()
        self.battery = battery or BatteryState()
        # resolve the compress="auto" crossover ONCE, from the model size;
        # every wire/refresh/cost read below uses the resolved format so
        # both engines (run_fleet resolves identically from the same
        # param count) make the same int8-vs-fp32 call
        self._compress = self.cfg.compress
        if self._compress == "auto" and contributor_states:
            template = next(iter(contributor_states.values()))["params"]
            self._compress = resolve_compress("auto", tree_size(template))

    # -- protocol phases (protocol.Phase.HANDSHAKE) ---------------------------
    def handshake(self) -> List[Contract]:
        contracts = select_contributors(self.fleet, self.cfg.offered_incentive,
                                        self.cfg.n_max)
        rng = np.random.default_rng(self.cfg.seed)
        self.keys = {c.device_id: rng.integers(0, 256, 16).astype(np.uint8)
                     for c in contracts}
        self.nonces = {c.device_id: rng.integers(0, 256, 8).astype(np.uint8)
                       for c in contracts}
        self._wire = {}
        if self._compress == "int8":
            for c in contracts:
                self._wire_pack(c.device_id,
                                self.contributor_states[c.device_id]["params"])
        return contracts

    def _wire_pack(self, device_id: int, params):
        """Under ``compress="int8"`` a contributor's transported state IS
        wire format: quantize ``params`` into the (q, scales) cache and
        return the dequantized image of that payload.  This mirrors the
        fleet engine's int8 round state — both engines quantize at
        exactly the same protocol points (handshake staging, after every
        refresh fit) with the same tile math, which is what keeps their
        params allclose and their write-back contract identical under
        the knob.
        """
        vec, _ = flatten_to_vector(params)
        q, s, n = compress_update(vec)
        self._wire[device_id] = (q, s, n)
        return unflatten_from_vector(decompress_update(q, s, n), params)

    def _wire_image(self, device_id: int, template):
        """The dequantized fp32 image of a cached wire payload — what
        the receiver (and the refresh trainer) actually sees."""
        q, s, n = self._wire[device_id]
        return unflatten_from_vector(decompress_update(q, s, n), template)

    def _snap_prev(self, device_ids):
        """Phase.DELIVER bookkeeping (``cfg.faults``): remember this
        round's transported images so a lagging link can deliver them
        NEXT round (stale delivery).  Snapshotted before Phase.REFRESH
        rebinds the state — reference snapshots, since params/wire
        payloads are immutable; the fleet engine carries the identical
        snapshot as a second wire-format (R, N, ·) buffer in its loop
        state."""
        if self._compress == "int8":
            self._prev_wire = {int(d): self._wire[int(d)] for d in device_ids}
        else:
            self._prev_params = {
                int(d): self.contributor_states[int(d)]["params"]
                for d in device_ids}

    def _collect_update(self, device_id: int, stale: bool = False,
                        corrupt: bool = False, step: int = 0):
        """Phase.COLLECT: contributor -> (compress) -> (corrupt) ->
        (encrypt) -> wire -> (decrypt) -> (decompress).  ``stale``
        substitutes the round-(r-1) image snapshotted by
        :meth:`_snap_prev` — the wire bytes (and therefore the pricing)
        are unchanged, only the payload lags.

        ``corrupt`` applies the adversary's attack to the OUTGOING
        payload — in wire format under int8 (codes/scales, never a
        re-densified fp32 vector), keyed on the delivering event
        ``step``.  Ordering pin (fault x adversary): the stale
        substitution above runs FIRST, so a Byzantine contributor
        poisons whatever bytes actually leave its radio this step —
        stale image or fresh — and the corruption draw keys on the
        DELIVERING round, never the round the image was trained.  The
        resident wire/params caches are never modified."""
        ac = self.cfg.adversary
        params = self.contributor_states[device_id]["params"]
        if stale and self._compress != "int8":
            params = self._prev_params[device_id]
        if self._compress == "int8":
            # the wire image really is the int8 payload + fp32 scales;
            # under encryption the AES-CTR round trip runs over exactly
            # those bytes (CTR preserves length, so model_bytes is the
            # compressed count either way)
            q, s, n = (self._prev_wire if stale else self._wire)[device_id]
            if corrupt:
                q, s = adversary_mod.corrupt_wire(
                    ac, q, s, True, step, ac.requester_id, device_id)
            if not self.cfg.encrypt:
                return (unflatten_from_vector(decompress_update(q, s, n),
                                              params),
                        int(q.shape[0]) + 4 * int(s.shape[0]))
            payload = jnp.concatenate([
                jax.lax.bitcast_convert_type(q, jnp.uint8),
                crypto.float_vector_to_bytes(s)])
            cipher = crypto.encrypt_bytes(payload, self.keys[device_id],
                                          self.nonces[device_id])
            plain = crypto.decrypt_bytes(cipher, self.keys[device_id],
                                         self.nonces[device_id])
            qr = jax.lax.bitcast_convert_type(plain[:q.shape[0]], jnp.int8)
            sr = crypto.bytes_to_float_vector(plain[q.shape[0]:])
            return (unflatten_from_vector(decompress_update(qr, sr, n),
                                          params),
                    int(cipher.shape[0]))
        if not self.cfg.encrypt and not corrupt:
            return params, tree_bytes(params)
        vec, _ = flatten_to_vector(params)
        if corrupt:
            vec = adversary_mod.corrupt_dense(
                ac, vec, True, step, ac.requester_id, device_id)
        if not self.cfg.encrypt:
            return unflatten_from_vector(vec, params), tree_bytes(params)
        cipher = crypto.encrypt_update(vec, self.keys[device_id], self.nonces[device_id])
        plain = crypto.decrypt_update(cipher, self.keys[device_id], self.nonces[device_id])
        return unflatten_from_vector(plain, params), int(cipher.shape[0])

    def _robust_aggregate_full(self, updates, lanes, w_full, template,
                               use_pallas, interpret):
        """Phase.AGGREGATE under ``robust != "none"``: stack the
        delivered updates into the full-lane (1, N, P) buffer (zero rows
        for undelivered lanes — their weight is 0, and every robust
        statistic gates activity on w > 0) and run the ONE
        :func:`repro.kernels.robust.ops.robust_aggregate` entry the
        fleet engine also calls, so both engines' clip decisions are
        bitwise identical by construction.

        Returns ``(aggregated pytree, clipped bool row over the full
        lane set)``.  An all-zero weight row aggregates to the zero
        vector; the caller substitutes its own params (the fedavg
        convention)."""
        n_lanes = int(np.asarray(w_full).shape[0])
        num_p = tree_size(template)
        u = np.zeros((1, n_lanes, num_p), np.float32)
        for k, j in enumerate(lanes):
            u[0, int(j)] = np.asarray(flatten_to_vector(updates[k])[0],
                                      np.float32)
        agg, clipped = robust_aggregate(
            jnp.asarray(u), jnp.asarray(w_full, jnp.float32)[None, :],
            method=self.cfg.robust, use_pallas=use_pallas,
            interpret=interpret)
        return (unflatten_from_vector(agg[0], template),
                np.asarray(clipped[0], bool))

    def _refresh_contributors(self, contracts: List[Contract],
                              tick: Optional[Dict[int, bool]] = None):
        """Phase.REFRESH: contributors keep improving between rounds.

        ``tick`` (cadence world) maps device_id -> does this contributor
        tick at the current event step; a non-ticking contributor skips
        its refresh — its resident wire image stays put and the next
        aggregation consumes it as-is (the straggler path)."""
        if self.cfg.contributor_refresh_epochs <= 0:
            return
        compress = self._compress == "int8"
        for c in contracts:
            if tick is not None and not tick.get(c.device_id, True):
                continue
            st = self.contributor_states[c.device_id]
            # under compress the contributor's working copy is the wire
            # image (the fleet engine's round state holds nothing else)
            base = (self._wire_image(c.device_id, st["params"]) if compress
                    else st["params"])
            fitted, _ = self.task.fit(
                base, st["data"], self.cfg.contributor_refresh_epochs,
                self.cfg.batch_size, seed=self.cfg.seed + c.device_id)
            st["params"] = (self._wire_pack(c.device_id, fitted) if compress
                            else fitted)

    # -- checkpointing (repro.checkpoint) -------------------------------------
    @staticmethod
    def _hist_pad(vals, n, width=None):
        """History lists as fixed-shape arrays (zero-padded to the round
        budget) so a mid-run checkpoint and the pre-loop restore template
        always agree structurally."""
        if width is None:
            out = np.zeros((n,), np.float64)
            if vals:
                out[:len(vals)] = np.asarray(vals, np.float64)
        else:
            out = np.zeros((n, width), np.float32)
            if vals:
                out[:len(vals)] = np.stack(
                    [np.asarray(v, np.float32) for v in vals])
        return out

    def _state_payload(self, r_next, device_ids, params, history, rounds,
                       measured_fit_s, retry_windows, model_bytes=0,
                       util_rows=None, level=None, t_next=0, idle_run=0):
        """The loop engine's resumable round state as one pytree.

        Design rule (see ROADMAP): anything resumable checkpoints its
        wire-format RESIDENT form — under ``compress="int8"`` that is the
        (q, scales) cache itself (and its stale-delivery snapshot), never
        a re-densified fp32 image.  The fleet engine serializes the very
        same quantities as its flat (R, N, ·) carry.
        """
        cfg = self.cfg
        n_rounds = cfg.max_rounds
        ids = [int(d) for d in device_ids]
        pay = {
            "r": np.int64(r_next),
            "rounds": np.int64(rounds),
            "level": np.float64(self.battery.level),
            "fit_s": np.float64(measured_fit_s),
            "retry_windows": np.float64(retry_windows),
            "model_bytes": np.int64(model_bytes),
            "params": jax.tree_util.tree_map(np.asarray, params),
            "acc": self._hist_pad(history["accuracy"], n_rounds),
            "loss": self._hist_pad(history["loss"], n_rounds),
            "bat": self._hist_pad(history["battery"], n_rounds),
            "contrib": {str(d): jax.tree_util.tree_map(
                np.asarray, self.contributor_states[d]["params"])
                for d in ids},
        }
        if self._compress == "int8":
            pay["wire"] = {str(d): {"q": np.asarray(self._wire[d][0]),
                                    "s": np.asarray(self._wire[d][1])}
                           for d in ids}
        if cfg.faults is not None:
            pay["drops"] = self._hist_pad(history["drops"], n_rounds)
            pay["retries"] = self._hist_pad(history["retries"], n_rounds)
            pay["stale"] = self._hist_pad(history["stale"], n_rounds)
            pay["deliver"] = self._hist_pad(history["deliver_mask"],
                                            n_rounds, len(ids))
            if self._compress == "int8":
                pay["prev_wire"] = {
                    str(d): {"q": np.asarray(self._prev_wire[d][0]),
                             "s": np.asarray(self._prev_wire[d][1])}
                    for d in ids}
            else:
                pay["prev"] = {str(d): jax.tree_util.tree_map(
                    np.asarray, self._prev_params[d]) for d in ids}
        if cfg.adversary is not None:   # Byzantine world: corruption trail
            pay["corrupt_h"] = self._hist_pad(history["corrupted_mask"],
                                              n_rounds, len(ids))
        if cfg.robust != "none":        # robust aggregation: clip trail
            pay["clip_h"] = self._hist_pad(history["clipped_mask"],
                                           n_rounds, len(ids))
        if util_rows is not None:   # mobility world
            n_cand = len(ids)
            pay["clevel"] = np.asarray(level, np.float32)
            pay["members"] = self._hist_pad(history["members"], n_rounds)
            pay["member_h"] = self._hist_pad(history["member_mask"],
                                             n_rounds, n_cand)
            pay["util_h"] = self._hist_pad(util_rows, n_rounds, n_cand)
        if cfg.cadence is not None:   # async-cadence world: event clock
            pay["t"] = np.int64(t_next)
            pay["idle_run"] = np.int64(idle_run)
            pay["clock_h"] = self._hist_pad(history["round_clock"], n_rounds)
            pay["idle_h"] = self._hist_pad(history["idle_steps"], n_rounds)
        return pay

    def _restore_state(self, resume_from, template):
        """Restore a :meth:`_state_payload` checkpoint (dtype-strict) and
        rebind the session-held pieces (battery, contributor params, wire
        + stale caches).  Returns the payload for the caller to unpack
        its loop-local scalars/histories from."""
        from repro.checkpoint import restore_checkpoint

        pay, _ = restore_checkpoint(resume_from, template)
        self.battery = dataclasses.replace(self.battery,
                                           level=float(pay["level"]))
        for key, st in pay["contrib"].items():
            self.contributor_states[int(key)]["params"] = st
        if self._compress == "int8":
            for key, w in pay["wire"].items():
                did = int(key)
                n = tree_size(self.contributor_states[did]["params"])
                self._wire[did] = (jnp.asarray(w["q"]), jnp.asarray(w["s"]), n)
            if "prev_wire" in pay:
                for key, w in pay["prev_wire"].items():
                    did = int(key)
                    n = tree_size(self.contributor_states[did]["params"])
                    self._prev_wire[did] = (jnp.asarray(w["q"]),
                                            jnp.asarray(w["s"]), n)
        elif "prev" in pay:
            for key, st in pay["prev"].items():
                self._prev_params[int(key)] = st
        return pay

    @staticmethod
    def _refill_history(history, pay, rounds, faults, cadence=False,
                        adversary=False, robust=False):
        history["accuracy"] = [float(v) for v in pay["acc"][:rounds]]
        history["loss"] = [float(v) for v in pay["loss"][:rounds]]
        history["battery"] = [float(v) for v in pay["bat"][:rounds]]
        # not serialized — every loop-engine round that reached the
        # history executed, so the restored view is derivable
        history["round_executed"] = [1.0] * rounds
        if cadence:
            history["round_clock"] = [int(v) for v in pay["clock_h"][:rounds]]
            history["idle_steps"] = [int(v) for v in pay["idle_h"][:rounds]]
        if adversary:
            history["corrupted_mask"] = [row.copy()
                                         for row in pay["corrupt_h"][:rounds]]
        if robust:
            history["clipped_mask"] = [row.copy()
                                       for row in pay["clip_h"][:rounds]]
        if faults:
            history["drops"] = [float(v) for v in pay["drops"][:rounds]]
            history["retries"] = [float(v) for v in pay["retries"][:rounds]]
            history["stale"] = [float(v) for v in pay["stale"][:rounds]]
            history["deliver_mask"] = [row.copy()
                                       for row in pay["deliver"][:rounds]]

    @staticmethod
    def _normalize_ckpt(checkpoint_dir, checkpoint_every):
        if checkpoint_every < 0:
            raise ValueError(
                f"checkpoint_every must be >= 0, got {checkpoint_every}")
        if checkpoint_dir is not None and checkpoint_every == 0:
            checkpoint_every = 1   # loop engine: every round by default
        return checkpoint_every

    # -- Algorithm 1 ----------------------------------------------------------
    def run(self, engine: str = "loop", *, use_pallas: bool = True,
            interpret: Optional[bool] = None, round_chunk: int = 4,
            checkpoint_dir: Optional[str] = None, checkpoint_every: int = 0,
            resume_from: Optional[str] = None,
            timeline: Optional[Timeline] = None) -> SessionResult:
        """Execute the session.  ``engine="loop"`` (default) runs the
        Python reference loop below; ``engine="fleet"`` compiles this
        session as a 1-requester fleet through ``repro.core.fleet``,
        forwarding the engine knobs (``use_pallas``, ``interpret``,
        ``round_chunk``) to ``run_fleet``.

        Crash resumability: ``checkpoint_dir`` serializes the resumable
        round state (wire-format resident, see ``repro.checkpoint``)
        every ``checkpoint_every`` rounds (loop default: 1; fleet
        default: ``round_chunk``); ``resume_from`` restores the latest
        checkpoint in that directory such that killed-and-resumed is
        bit-identical (masks, battery, params) to an uninterrupted run.

        Note: prefer the :mod:`repro.api` facade
        (``Experiment(world, method, execution).run()``) — this method
        remains as the loop-engine oracle and a delegating shim.
        """
        if engine == "fleet":
            from repro.core import fleet as fleet_mod

            spec = fleet_mod.RequesterSpec(
                own_train=self.own_train, own_test=self.own_test,
                neighborhood=self.fleet,
                contributor_states=self.contributor_states,
                battery=self.battery)
            result = fleet_mod.run_fleet(self.task, [spec], self.cfg,
                                         cost_model=self.cost,
                                         use_pallas=use_pallas,
                                         interpret=interpret,
                                         round_chunk=round_chunk,
                                         checkpoint_dir=checkpoint_dir,
                                         checkpoint_every=checkpoint_every,
                                         resume_from=resume_from,
                                         timeline=timeline)
            self.battery = result.sessions[0].battery
            return result.sessions[0]
        if engine != "loop":
            raise ValueError(f"unknown engine {engine!r} (loop|fleet)")
        tl = timeline if timeline is not None else Timeline()
        checkpoint_every = self._normalize_ckpt(checkpoint_dir,
                                                checkpoint_every)
        if self.cfg.mobility is not None:
            return self._run_mobility(checkpoint_dir=checkpoint_dir,
                                      checkpoint_every=checkpoint_every,
                                      resume_from=resume_from, timeline=tl,
                                      use_pallas=use_pallas,
                                      interpret=interpret)
        from repro.checkpoint import save_checkpoint

        cfg = self.cfg
        fc = cfg.faults
        with tl.span("handshake"):
            contracts = self.handshake()
        if not contracts:
            raise RuntimeError("no nearby device agreed to the incentive (N_d < 1)")
        n_c = len(contracts)
        round_w = protocol.round_weights(n_c, cfg.strategy)
        ids = np.array([c.device_id for c in contracts], np.int32)
        ac = cfg.adversary
        robust = cfg.robust
        gamma = float(cfg.staleness_gamma)

        history = {"accuracy": [], "loss": [], "battery": [],
                   "round_executed": []}
        if ac is not None:
            history["corrupted_mask"] = []
        if robust != "none":
            history["clipped_mask"] = []
        params = None
        rounds = 0
        stop = protocol.STOP_MAX_ROUNDS
        measured_fit_s = 0.0
        model_bytes = 0
        retry_windows = 0.0
        e_rx_retry = t_retry = 0.0

        if fc is not None:
            history.update(drops=[], retries=[], stale=[], deliver_mask=[])
            # Under faults the requester owns its model from the start —
            # an all-links-failed round falls back to it, exactly like
            # the empty-neighborhood mobility case.
            params = self.task.init(seed=cfg.seed)
            num_params = tree_size(params)
            model_bytes = update_wire_bytes(num_params, encrypt=cfg.encrypt,
                                            compress=self._compress,
                                            raw_bytes=tree_bytes(params))
            e_tab = np.array(self.cost.round_energy_table(
                max_contrib=n_c, num_params=num_params,
                model_bytes=model_bytes,
                num_samples=len(self.own_train[0]), epochs=cfg.epochs,
                n_devices=len(self.fleet), encrypt=cfg.encrypt), np.float64)
            # every retransmission is one more receive window, re-priced
            # through the one cost model (air time + radio + crypto)
            e_rx_retry, _, t_retry = self.cost.retry_energy(
                model_bytes=model_bytes, encrypt=cfg.encrypt)
            self._snap_prev(ids)

        # Async cadence: the session loops over GLOBAL EVENT STEPS t; the
        # requester's round clock r advances only on its tick steps.
        # World state (fault weather) keys on t, protocol state (fit
        # seed, round budget) on r.  cadence=None keeps t == r exactly.
        cc = cfg.cadence
        total_events = (cadence_mod.events_budget(cc, cfg.max_rounds)
                        if cc is not None else cfg.max_rounds)
        if cc is not None:
            history.update(round_clock=[], idle_steps=[])
        idle_run = 0   # idle event steps since the last executed round

        r_start = t_start = 0
        if resume_from is not None:
            template_params = (params if params is not None
                               else self.task.init(seed=cfg.seed))
            with tl.span("checkpoint_restore"):
                pay = self._restore_state(resume_from, self._state_payload(
                    0, ids, template_params, history, 0, 0.0, 0.0,
                    model_bytes=model_bytes))
            r_start = t_start = int(pay["r"])
            rounds = int(pay["rounds"])
            params = pay["params"]
            measured_fit_s = float(pay["fit_s"])
            retry_windows = float(pay["retry_windows"])
            model_bytes = int(pay["model_bytes"])
            self._refill_history(history, pay, rounds, fc is not None,
                                 cadence=cc is not None,
                                 adversary=ac is not None,
                                 robust=robust != "none")
            if cc is not None:
                t_start = int(pay["t"])
                idle_run = int(pay["idle_run"])

        r = r_start
        for t in range(t_start, total_events):
            if cc is None:
                r = t   # lockstep: the event step IS the round
            elif r >= cfg.max_rounds:
                break   # round budget done; stop idling immediately
            elif not bool(np.asarray(cadence_mod.tick_mask(
                    cc, t, cc.requester_id,
                    level=np.float32(self.battery.level)))):
                # the requester's clock is silent this step: one idle
                # event, no protocol round
                idle_run += 1
                continue
            tick_map = None
            if cc is not None:
                ctick = np.asarray(cadence_mod.tick_mask(cc, t, ids), bool)
                tick_map = {int(ids[j]): bool(ctick[j])
                            for j in range(len(ids))}
            # Byzantine weather for this step: pure world state — the
            # draw exists whether or not the link transmitted; whether a
            # corrupted link COUNTS is the delivered mask below.
            cmask = (np.asarray(adversary_mod.corruption_mask(
                ac, t, ac.requester_id, ids), bool)
                if ac is not None else np.zeros((n_c,), bool))
            stale = np.zeros((n_c,), bool)
            if fc is not None:
                # Phase.DELIVER: closed-form link outcomes for this step.
                delivered, attempts, stale = (
                    np.asarray(v) for v in faults_mod.link_outcomes(
                        fc, t, fc.requester_id, ids))
                blocked = np.asarray(faults_mod.blocked_mask(
                    fc, t, fc.requester_id, ids))
                attempted = ~blocked   # streak-blocked links sit out
                delivered = delivered & attempted
                drops_r = float(np.sum(attempted & ~delivered))
                retries_r = float(np.sum(np.where(attempted,
                                                  attempts - 1, 0)))
                history["drops"].append(drops_r)
                history["retries"].append(retries_r)
                history["stale"].append(float(np.sum(delivered & stale)))
                history["deliver_mask"].append(delivered.astype(np.float32))
                lanes = np.nonzero(delivered)[0]
                updates = []
                _sp = tl.begin("transport", round=r)
                for j in lanes:
                    # ordering pin: stale selects the image FIRST, the
                    # corruption draw keys on the delivering step t
                    upd, nbytes = self._collect_update(
                        int(ids[j]), stale=bool(stale[j]),
                        corrupt=bool(cmask[j]), step=t)
                    model_bytes = max(model_bytes, nbytes)
                    updates.append(upd)
                tl.finish(_sp)
                dcount = len(updates)
            else:
                delivered = np.ones((n_c,), bool)
                lanes = np.arange(n_c)
                updates = []
                _sp = tl.begin("transport", round=r)
                for j, c in enumerate(contracts):
                    upd, nbytes = self._collect_update(
                        c.device_id, corrupt=bool(cmask[j]), step=t)
                    model_bytes = max(model_bytes, nbytes)
                    if params is None and not updates:
                        params = upd  # model init from the first received update
                    updates.append(upd)
                tl.finish(_sp)
            if ac is not None:
                history["corrupted_mask"].append(
                    (cmask & delivered).astype(np.float32))
            # staleness-decayed weights (gamma == 1.0: skipped, the
            # weights below are byte-for-byte today's round_w)
            w_eff = round_w
            if gamma < 1.0:
                lag = (np.asarray(cadence_mod.image_lag(cc, t, ids),
                                  np.int64)
                       if cc is not None else np.zeros((n_c,), np.int64))
                lag = lag + (delivered & stale).astype(np.int64)
                w_eff = np.asarray(protocol.decayed_round_weights(
                    round_w, lag, gamma), np.float32)
            # Phase.AGGREGATE (eq. 14) — or the Byzantine-robust
            # statistic over the full lane buffer (the ONE entry the
            # fleet engine also calls, so clip masks agree bitwise)
            if robust != "none":
                template = params if params is not None else updates[0]
                global_params, clipped = self._robust_aggregate_full(
                    updates, lanes,
                    w_eff * delivered.astype(np.float32), template,
                    use_pallas, interpret)
                history["clipped_mask"].append(clipped.astype(np.float32))
                if not updates:
                    global_params = params  # every link failed this round
            elif updates:
                global_params = aggregation.masked_fedavg(
                    updates, w_eff[lanes])
            else:
                global_params = params   # every link failed this round
            t0 = time.perf_counter()
            with tl.span("fit", round=r):
                params, losses = self.task.fit(global_params, self.own_train,
                                               cfg.epochs, cfg.batch_size,
                                               seed=cfg.seed + r)
            measured_fit_s += time.perf_counter() - t0
            # Phase.SCORE
            acc = float(self.task.evaluate(params, self.own_test))
            rounds = r + 1
            history["accuracy"].append(acc)
            history["loss"].append(float(losses[-1]))
            history["round_executed"].append(1.0)
            if cc is not None:
                history["round_clock"].append(t)
                history["idle_steps"].append(idle_run)
                idle_run = 0

            # Phase.ACCOUNT: battery bookkeeping for this round
            num_params = tree_size(params)
            if fc is not None:
                # The per-count table prices one receive window per
                # delivered update; every drop or retry attempt is one
                # MORE window on the requester's radio.
                extra = drops_r + retries_r
                retry_windows += extra
                e_round = float(e_tab[dcount]) + extra * e_rx_retry
            else:
                e_round = self.cost.round_energy(
                    n_contrib=n_c, num_params=num_params,
                    model_bytes=model_bytes,
                    num_samples=len(self.own_train[0]), epochs=cfg.epochs,
                    n_devices=len(self.fleet), encrypt=cfg.encrypt)
            self.battery = self.battery.discharge(e_round,
                                                  avg_power_w=self.cost.device.p_train)
            history["battery"].append(self.battery.level)

            if acc >= cfg.desired_accuracy:
                stop = protocol.STOP_ACCURACY
                break
            if self.battery.below(cfg.battery_threshold):
                stop = protocol.STOP_BATTERY
                break
            if fc is not None:
                self._snap_prev(ids)   # next round's stale images
            with tl.span("refresh", round=r):
                self._refresh_contributors(contracts, tick=tick_map)
            if checkpoint_dir is not None and (r + 1) % checkpoint_every == 0:
                with tl.span("checkpoint_save", round=r):
                    save_checkpoint(checkpoint_dir, r + 1, self._state_payload(
                        r + 1, ids, params, history, rounds, measured_fit_s,
                        retry_windows, model_bytes=model_bytes,
                        t_next=t + 1, idle_run=idle_run))
            r += 1   # this lane's round clock (lockstep: rebound from t)

        num_params = tree_size(params)
        report = self.cost.session(
            rounds=rounds, n_contrib=n_c, num_params=num_params,
            model_bytes=model_bytes, num_samples=len(self.own_train[0]),
            epochs=cfg.epochs, n_devices=len(self.fleet),
            measured_local_time=measured_fit_s, encrypt=cfg.encrypt)
        if fc is not None and retry_windows:
            report.times.t_com += retry_windows * t_retry
            report.e_comm += retry_windows * e_rx_retry
        if robust != "none" and rounds:
            # robustness is never free: every executed round ran one
            # screening pass over the full N x P lane buffer, priced
            # through the ONE shared helper (never drains the simulated
            # battery — see CostModel.screening_energy)
            e_scr, t_scr = self.cost.screening_energy(
                n_contrib=n_c, num_params=num_params)
            report.times.t_agg += rounds * t_scr
            report.e_comp += rounds * e_scr
        if cc is not None:
            # idle/duty-cycle windows priced through the ONE shared helper
            # (never drains the simulated battery — a sleeping radio costs
            # wall time and standby energy, not protocol charge)
            total_idle = int(sum(history["idle_steps"])) + idle_run
            if total_idle:
                e_idle, t_idle = self.cost.idle_energy(
                    idle_steps=total_idle, idle_step_s=cc.idle_step_s)
                report.times.t_com += t_idle
                report.e_comm += e_idle
        return SessionResult(
            accuracy=history["accuracy"][-1], rounds=rounds, n_contributors=n_c,
            report=report, battery=self.battery, history=history,
            stop_reason=protocol.stop_reason_name(stop), params=params,
            model_bytes=model_bytes)

    # -- Algorithm 1 in an opportunistic world (repro.core.mobility) ----------
    def _run_mobility(self, checkpoint_dir: Optional[str] = None,
                      checkpoint_every: int = 0,
                      resume_from: Optional[str] = None,
                      timeline: Optional[Timeline] = None,
                      use_pallas: bool = True,
                      interpret: Optional[bool] = None) -> SessionResult:
        """The churn-aware session loop: Phase.RENEGOTIATE runs every
        round — contributors leave when they walk out of radio range or
        hit the battery floor, in-range arrivals are signed, and a
        higher-utility arrival displaces the weakest member.  Every
        membership/battery/weight derivation goes through the SAME array
        functions the fleet engine traces (``repro.core.mobility``,
        ``topology.dynamic_round_weights``), so the two engines agree on
        the whole churn trajectory by construction."""
        cfg = self.cfg
        mob = cfg.mobility
        tl = timeline if timeline is not None else Timeline()

        # Phase.HANDSHAKE fixes the candidate POOL (agreeing devices) and
        # exchanges keys with all of them — any candidate may be signed in
        # a later round, when it wanders into range.
        _sp = tl.begin("handshake")
        cands = candidate_pool(self.fleet, cfg.offered_incentive)
        if not cands:
            tl.finish(_sp)
            raise RuntimeError("no nearby device agreed to the incentive (N_d < 1)")
        rng = np.random.default_rng(cfg.seed)
        self.keys = {d.device_id: rng.integers(0, 256, 16).astype(np.uint8)
                     for d in cands}
        self.nonces = {d.device_id: rng.integers(0, 256, 8).astype(np.uint8)
                       for d in cands}
        self._wire = {}
        if self._compress == "int8":
            for d in cands:
                self._wire_pack(d.device_id,
                                self.contributor_states[d.device_id]["params"])
        tl.finish(_sp)
        n_cand = len(cands)
        ids = np.array([d.device_id for d in cands], np.int32)
        max_data = max(d.data_size for d in cands)
        base_util = np.asarray(mobility.static_utility_term(
            np.array([d.model_staleness for d in cands], np.float32),
            np.array([d.data_size for d in cands], np.float32),
            np.float32(max_data)), np.float32)
        level = np.array([d.battery_level for d in cands], np.float32)
        cand_mask = np.ones((n_cand,), bool)

        # The requester owns its model from the start (it cannot rely on a
        # first-round update existing — the neighborhood may be empty).
        params = self.task.init(seed=cfg.seed)
        num_params = tree_size(params)
        model_bytes = update_wire_bytes(num_params, encrypt=cfg.encrypt,
                                        compress=self._compress,
                                        raw_bytes=tree_bytes(params))
        e_tab = np.array(self.cost.round_energy_table(
            max_contrib=n_cand, num_params=num_params, model_bytes=model_bytes,
            num_samples=len(self.own_train[0]), epochs=cfg.epochs,
            n_devices=len(self.fleet), encrypt=cfg.encrypt), np.float32)
        e_tx = np.zeros((n_cand,), np.float32)
        e_ref = np.zeros((n_cand,), np.float32)
        for j, d in enumerate(cands):
            st = self.contributor_states[d.device_id]
            e_tx[j], e_ref[j] = self.cost.contributor_round_energy(
                num_params=num_params, model_bytes=model_bytes,
                num_samples=len(st["data"][0]),
                refresh_epochs=cfg.contributor_refresh_epochs,
                encrypt=cfg.encrypt)

        history = {"accuracy": [], "loss": [], "battery": [],
                   "round_executed": [],
                   "members": [], "member_mask": [], "contracts": []}
        ac = cfg.adversary
        robust = cfg.robust
        gamma = float(cfg.staleness_gamma)
        if ac is not None:
            history["corrupted_mask"] = []
        if robust != "none":
            history["clipped_mask"] = []
        util_rows: List[np.ndarray] = []
        rounds = 0
        stop = protocol.STOP_MAX_ROUNDS
        measured_fit_s = 0.0
        fc = cfg.faults
        retry_windows = 0.0
        e_rx_retry = t_retry = 0.0
        if fc is not None:
            history.update(drops=[], retries=[], stale=[], deliver_mask=[])
            e_rx_retry, _, t_retry = self.cost.retry_energy(
                model_bytes=model_bytes, encrypt=cfg.encrypt)
            self._snap_prev(ids)
        # async cadence (see run()): world state keys on the global event
        # step t, the requester's round clock r advances on its ticks
        cc = cfg.cadence
        total_events = (cadence_mod.events_budget(cc, cfg.max_rounds)
                        if cc is not None else cfg.max_rounds)
        if cc is not None:
            history.update(round_clock=[], idle_steps=[])
        idle_run = 0

        from repro.checkpoint import save_checkpoint

        r_start = t_start = 0
        if resume_from is not None:
            with tl.span("checkpoint_restore"):
                pay = self._restore_state(resume_from, self._state_payload(
                    0, ids, params, history, 0, 0.0, 0.0,
                    util_rows=util_rows, level=level))
            r_start = t_start = int(pay["r"])
            rounds = int(pay["rounds"])
            params = pay["params"]
            measured_fit_s = float(pay["fit_s"])
            retry_windows = float(pay["retry_windows"])
            level = np.asarray(pay["clevel"], np.float32)
            self._refill_history(history, pay, rounds, fc is not None,
                                 cadence=cc is not None,
                                 adversary=ac is not None,
                                 robust=robust != "none")
            if cc is not None:
                t_start = int(pay["t"])
                idle_run = int(pay["idle_run"])
            history["members"] = [float(v) for v in pay["members"][:rounds]]
            history["member_mask"] = [row.copy()
                                      for row in pay["member_h"][:rounds]]
            util_rows = [row.copy() for row in pay["util_h"][:rounds]]
            # contracts are a pure function of (membership, utility) —
            # rebuild the per-round contract history from the restored rows
            history["contracts"] = [
                contracts_from_membership(cands, pay["member_h"][rr] > 0,
                                          pay["util_h"][rr],
                                          cfg.offered_incentive)
                for rr in range(rounds)]

        r = r_start
        for t in range(t_start, total_events):
            if cc is None:
                r = t   # lockstep: the event step IS the round
            elif r >= cfg.max_rounds:
                break
            elif not bool(np.asarray(cadence_mod.tick_mask(
                    cc, t, cc.requester_id,
                    level=np.float32(self.battery.level)))):
                idle_run += 1
                continue
            ctick = (np.asarray(cadence_mod.tick_mask(cc, t, ids), bool)
                     if cc is not None else None)
            # Phase.RENEGOTIATE: release/sign/undercut for this step —
            # under faults, streak-blocked links lose eligibility too.
            blocked = (np.asarray(faults_mod.blocked_mask(
                fc, t, fc.requester_id, ids)) if fc is not None else None)
            member, rank, util = mobility.membership_step(
                mob, t, mob.requester_id, ids, cand_mask, base_util, level,
                cfg.n_max, blocked=blocked)
            member = np.asarray(member, bool)
            util_rows.append(np.asarray(util, np.float32))
            round_w = np.asarray(topology.dynamic_round_weights(
                member, rank, cfg.strategy), np.float32)
            count = int(member.sum())
            history["member_mask"].append(member.astype(np.float32))
            history["members"].append(float(count))
            history["contracts"].append(contracts_from_membership(
                cands, member, util, cfg.offered_incentive))

            # Phase.COLLECT + Phase.DELIVER + Phase.AGGREGATE over the
            # CURRENT members (lane order, zero-weight lanes dropped —
            # fp32-identical to the fleet kernel's full-lane masked
            # reduction).  Under faults only the DELIVERED members feed
            # eq. (14); drops cost the round without contributing.
            # Byzantine weather for this step (pure world state; whether
            # a corrupted link COUNTS is the member/delivered mask below)
            cmask = (np.asarray(adversary_mod.corruption_mask(
                ac, t, ac.requester_id, ids), bool)
                if ac is not None else np.zeros((n_cand,), bool))
            stale = np.zeros((n_cand,), bool)
            if fc is not None:
                delivered, attempts, stale = (
                    np.asarray(v) for v in faults_mod.link_outcomes(
                        fc, t, fc.requester_id, ids))
                delivered = delivered & member
                drops_r = float(np.sum(member & ~delivered))
                retries_r = float(np.sum(np.where(member, attempts - 1, 0)))
                history["drops"].append(drops_r)
                history["retries"].append(retries_r)
                history["stale"].append(float(np.sum(delivered & stale)))
                history["deliver_mask"].append(delivered.astype(np.float32))
                agg_mask = delivered
            else:
                agg_mask = member
            if ac is not None:
                history["corrupted_mask"].append(
                    (cmask & agg_mask).astype(np.float32))
            # staleness-decayed weights (gamma == 1.0: skipped)
            w_eff = round_w
            if gamma < 1.0:
                lag = (np.asarray(cadence_mod.image_lag(cc, t, ids),
                                  np.int64)
                       if cc is not None else np.zeros((n_cand,), np.int64))
                lag = lag + (agg_mask & stale).astype(np.int64)
                w_eff = np.asarray(protocol.decayed_round_weights(
                    round_w, lag, gamma), np.float32)
            dcount = int(agg_mask.sum())
            lanes = np.nonzero(agg_mask)[0]
            updates = []
            if dcount > 0:
                with tl.span("transport", round=r):
                    updates = [self._collect_update(
                        int(ids[j]), stale=bool(stale[j]),
                        corrupt=bool(cmask[j]), step=t)[0]
                        for j in lanes]
            if robust != "none":
                global_params, clipped = self._robust_aggregate_full(
                    updates, lanes, w_eff * agg_mask.astype(np.float32),
                    params, use_pallas, interpret)
                history["clipped_mask"].append(clipped.astype(np.float32))
                if dcount == 0:
                    global_params = params  # alone this round: keep training
            elif dcount > 0:
                global_params = aggregation.masked_fedavg(
                    updates, w_eff[lanes])
            else:
                global_params = params   # alone this round: keep training

            # Phase.FIT + Phase.SCORE
            t0 = time.perf_counter()
            with tl.span("fit", round=r):
                params, losses = self.task.fit(global_params, self.own_train,
                                               cfg.epochs, cfg.batch_size,
                                               seed=cfg.seed + r)
            measured_fit_s += time.perf_counter() - t0
            acc = float(self.task.evaluate(params, self.own_test))
            rounds = r + 1
            history["accuracy"].append(acc)
            history["loss"].append(float(losses[-1]))
            history["round_executed"].append(1.0)
            if cc is not None:
                history["round_clock"].append(t)
                history["idle_steps"].append(idle_run)
                idle_run = 0

            # Phase.ACCOUNT: requester discharge from the member-count
            # energy table (same table the fleet engine stages); under
            # faults the table indexes by DELIVERED count and every
            # drop/retry adds one re-priced receive window.
            if fc is not None:
                extra = drops_r + retries_r
                retry_windows += extra
                e_r = float(e_tab[dcount]) + extra * float(e_rx_retry)
            else:
                e_r = float(e_tab[count])
            self.battery = self.battery.discharge(
                e_r, avg_power_w=self.cost.device.p_train)
            history["battery"].append(self.battery.level)

            if acc >= cfg.desired_accuracy:
                stop = protocol.STOP_ACCURACY
            elif self.battery.below(cfg.battery_threshold):
                stop = protocol.STOP_BATTERY
            # the session "survives" the round (and contributors refresh)
            # unless accuracy/battery stopped it — matching the static
            # engines, the final budget round still refreshes
            continuing = stop == protocol.STOP_MAX_ROUNDS

            # Contributor-side discharge: members paid transmission this
            # round (once per ATTEMPT under faults — the sender's radio
            # burns the same energy whether or not the update lands);
            # the refresh term only while the session survives.
            e_tx_round = (e_tx * attempts.astype(np.float32)
                          if fc is not None else e_tx)
            # under cadence only TICKING members pay the refresh term —
            # a straggler's radio still transmitted, but it skips its fit
            refresh_on = (continuing & ctick if cc is not None
                          else continuing)
            level = np.asarray(mobility.contributor_discharge(
                level, member, e_tx_round, e_ref, refresh_on,
                mob.contributor_capacity_j), np.float32)

            if stop != protocol.STOP_MAX_ROUNDS:
                break

            if fc is not None:
                self._snap_prev(ids)   # next round's stale images
            # Phase.REFRESH for current members only (cadence: only the
            # ticking members — stragglers' wire images stay resident)
            if cfg.contributor_refresh_epochs > 0:
                _sp = tl.begin("refresh", round=r)
                sel = member & ctick if cc is not None else member
                for j in np.nonzero(sel)[0]:
                    did = int(ids[j])
                    st = self.contributor_states[did]
                    base = (self._wire_image(did, st["params"])
                            if self._compress == "int8" else st["params"])
                    fitted, _ = self.task.fit(
                        base, st["data"],
                        cfg.contributor_refresh_epochs, cfg.batch_size,
                        seed=cfg.seed + did)
                    st["params"] = (self._wire_pack(did, fitted)
                                    if self._compress == "int8" else fitted)
                tl.finish(_sp)

            if checkpoint_dir is not None and (r + 1) % checkpoint_every == 0:
                with tl.span("checkpoint_save", round=r):
                    save_checkpoint(checkpoint_dir, r + 1, self._state_payload(
                        r + 1, ids, params, history, rounds, measured_fit_s,
                        retry_windows, util_rows=util_rows, level=level,
                        t_next=t + 1, idle_run=idle_run))
            r += 1   # this lane's round clock (lockstep: rebound from t)

        mean_members = float(np.mean(history["members"])) if rounds else 0.0
        report = self.cost.session(
            rounds=rounds, n_contrib=mean_members, num_params=num_params,
            model_bytes=model_bytes, num_samples=len(self.own_train[0]),
            epochs=cfg.epochs, n_devices=len(self.fleet),
            measured_local_time=measured_fit_s, encrypt=cfg.encrypt)
        if fc is not None and retry_windows:
            report.times.t_com += retry_windows * float(t_retry)
            report.e_comm += retry_windows * float(e_rx_retry)
        if robust != "none" and rounds:
            # one screening pass over the full candidate-lane buffer per
            # executed round (the robust kernels scan every lane, active
            # or not) — priced, never free, never battery-draining
            e_scr, t_scr = self.cost.screening_energy(
                n_contrib=n_cand, num_params=num_params)
            report.times.t_agg += rounds * t_scr
            report.e_comp += rounds * e_scr
        if cc is not None:
            total_idle = int(sum(history["idle_steps"])) + idle_run
            if total_idle:
                e_idle, t_idle = self.cost.idle_energy(
                    idle_steps=total_idle, idle_step_s=cc.idle_step_s)
                report.times.t_com += t_idle
                report.e_comm += e_idle
        return SessionResult(
            accuracy=history["accuracy"][-1], rounds=rounds,
            n_contributors=n_cand, report=report, battery=self.battery,
            history=history, stop_reason=protocol.stop_reason_name(stop),
            params=params, model_bytes=model_bytes)
