"""Architecture registry: ``--arch <id>`` -> ModelConfig.

Two CI-sized debug presets (the only sizes anything in this repo
actually trains or serves on this CPU toolchain) plus the EnFed paper's
own HAR classifiers.  The ten full-size LLM preset modules that used to
live here were dead weight: every engine, test, and driver ran their
``.smoke()`` reductions, never the billion-parameter specs, so the
presets below ARE those reductions, kept honest under their own names.

* ``debug-dense`` — dense GQA decoder with QKV bias: the plain
  attention + SwiGLU path every dense-family code path shares.
* ``debug-moe``  — 4-expert top-2 MoE.  Its vocab (513) is deliberately
  odd so it is never divisible by a model axis — the embedding sharding
  rules must take the d_model-axis fallback (exercised in
  tests/test_distributed.py).
"""

from __future__ import annotations

from typing import Dict

from repro.models.config import ModelConfig, MoEConfig
from repro.models.classifiers import LSTMClassifierConfig, MLPClassifierConfig

DEBUG_DENSE = ModelConfig(
    name="debug-dense",
    family="dense",
    citation="debug preset",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    block_pattern=("attn",),
    qkv_bias=True,
    dtype="float32",
)

DEBUG_MOE = ModelConfig(
    name="debug-moe",
    family="moe",
    citation="debug preset",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=513,  # odd on purpose: forces the embedding-sharding fallback
    block_pattern=("attn",),
    moe=MoEConfig(num_experts=4, num_experts_per_tok=2,
                  num_shared_experts=0, d_ff_expert=128),
    dtype="float32",
)

ARCHS: Dict[str, ModelConfig] = {c.name: c for c in [DEBUG_DENSE, DEBUG_MOE]}

# the EnFed paper's own models (Table III)
PAPER_LSTM = LSTMClassifierConfig(input_dim=6, seq_len=64, hidden=64, num_classes=6)
PAPER_MLP = MLPClassifierConfig(input_dim=8, hidden=(64, 32), num_classes=5)

# input shapes assigned to this paper
INPUT_SHAPES = {
    "train_4k": {"seq_len": 4096, "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32_768, "global_batch": 32, "kind": "prefill"},
    "decode_32k": {"seq_len": 32_768, "global_batch": 128, "kind": "decode"},
    "long_500k": {"seq_len": 524_288, "global_batch": 1, "kind": "decode"},
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)


def shape_supported(cfg: ModelConfig, shape_name: str) -> bool:
    """long_500k only runs for sub-quadratic-decode architectures
    (DESIGN.md §Arch-applicability); everything else runs all shapes."""
    if shape_name == "long_500k":
        return cfg.supports_long_decode
    return True
