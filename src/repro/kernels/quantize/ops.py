"""Public op: int8 update compression for the EnFed transport.

``compress_update`` / ``decompress_update`` wrap a flattened fp32 model
update into (int8 payload, per-tile scales) and back — a 4x cut of the
bytes entering the AES transport and the aggregation collectives.  Since
the ``EnFedConfig.compress="int8"`` protocol knob this is the wire
format of every transported update AND the fleet engine's round state:
the (R, N, P) contributor buffer is carried as int8 payload plus
per-tile fp32 scales, aggregated by the fused dequant->fedavg kernel
(``repro.kernels.fedavg.ops.fedavg_flat_batched_q8``) and refilled by
``quantize_flat_batched`` after each Phase.REFRESH.

``compressed_nbytes`` is the wire-format byte count that feeds the
eq. (4)-(7) cost model (``repro.core.energy.update_wire_bytes``): int8
payload padded to the quantization tile plus 4 bytes of fp32 scale per
tile — AES-CTR preserves length, so it is the same encrypted or not.
"""

from __future__ import annotations

from repro.kernels.quantize.kernel import (TILE, dequantize_pallas,
                                           quantize_batched_pallas,
                                           quantize_pallas)
from repro.kernels.quantize.ref import (dequantize_batched_ref,
                                        dequantize_ref, quantize_batched_ref,
                                        quantize_ref)


def padded_len(orig_len: int) -> int:
    """Wire-format payload length: ``orig_len`` padded up to TILE."""
    return orig_len + (-orig_len) % TILE


def compressed_nbytes(num_params: int) -> int:
    """Bytes of one int8-compressed update on the wire: padded int8
    payload + one fp32 scale per tile."""
    lp = padded_len(num_params)
    return lp + 4 * (lp // TILE)


# ``compress="auto"`` picks int8 only when it actually shrinks the wire
# by at least this factor vs raw fp32.  Below the crossover (small
# models, where TILE padding dominates the payload) int8 is BOTH bigger
# on the wire than the nominal 4x suggests AND slower to simulate —
# quantize/dequantize launches swamp the tiny fedavg (the documented
# small-R regression in BENCH_fleet.json ``results_compress``) — so auto
# falls back to fp32.
AUTO_COMPRESS_MAX_RATIO = 0.5


def resolve_compress(mode, num_params: int):
    """Resolve a ``compress`` protocol knob to a concrete wire format.

    ``None`` and ``"int8"`` are explicit overrides and pass through
    unchanged.  ``"auto"`` returns ``"int8"`` iff the tile-padded int8
    wire image is at most ``AUTO_COMPRESS_MAX_RATIO`` of the raw fp32
    bytes for a ``num_params``-sized update, else ``None``.  Every
    engine and the cost model resolve through this one function so the
    crossover decision is identical everywhere.
    """
    if mode is None or mode == "int8":
        return mode
    if mode == "auto":
        if compressed_nbytes(num_params) <= AUTO_COMPRESS_MAX_RATIO * 4 * num_params:
            return "int8"
        return None
    raise ValueError(f"unknown compress mode {mode!r}; one of None, 'int8', 'auto'")


def compress_update(vec, *, use_pallas: bool = True, interpret=None):
    """vec: (L,) fp32 -> (q, scales, L)."""
    if use_pallas:
        q, s = quantize_pallas(vec, interpret=interpret)
    else:
        import jax.numpy as jnp
        pad = (-vec.shape[0]) % TILE
        q, s = quantize_ref(jnp.pad(vec, (0, pad)))
    return q, s, vec.shape[0]


def decompress_update(q, scales, orig_len, *, use_pallas: bool = True,
                      interpret=None):
    if use_pallas:
        return dequantize_pallas(q, scales, orig_len, interpret=interpret)
    return dequantize_ref(q, scales)[:orig_len]


def quantize_flat_batched(x, *, use_pallas: bool = True, interpret=None):
    """x: (B, Lp) fp32, Lp % TILE == 0 -> (q int8 (B, Lp), scales fp32
    (B, Lp/TILE)).

    The fleet engine's requantize leg: after Phase.REFRESH trains each
    (requester, contributor) lane in fp32, every lane row is snapped
    back onto the int8 wire grid in one launch so the round state never
    persists at full precision.  Matches per-row :func:`compress_update`
    — bit-equal int8 codes, scales within 1 ulp (asserted in
    tests/test_kernels.py) — which is what keeps the two engines'
    quantization points aligned.
    """
    if use_pallas:
        return quantize_batched_pallas(x, interpret=interpret)
    return quantize_batched_ref(x)


def dequantize_flat_batched(q, scales):
    """Elementwise ``q * scale`` over (..., Lp) wire-format rows — the
    exact dequant every path (loop transport, fleet refresh views,
    write-back) runs, kept as plain jnp so XLA fuses it into consumers
    instead of materializing the fp32 block."""
    return dequantize_batched_ref(q, scales)
