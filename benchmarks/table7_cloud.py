"""Paper Table VII + Figs 8-9: EnFed vs cloud-only (no FL).

Prediction accuracy comparison plus response time: the paper reports
EnFed's response ~89-95% faster than shipping raw data to the cloud.
"""

from __future__ import annotations

from benchmarks._harness import build_scenario, run_cloud, run_enfed


def run(verbose: bool = True):
    rows = []
    for ds_id, dataset in (("Dataset1", "calories"), ("Dataset2", "har")):
        for model_kind in ("lstm", "mlp"):
            sc = build_scenario(dataset, model_kind)
            enfed = run_enfed(sc)
            cloud_acc, cloud_resp, _ = run_cloud(sc)
            # EnFed response time = session training time (model is local;
            # inference is on-device and ~free vs the WAN round trip)
            saving = 100 * (1 - enfed.report.t_train / cloud_resp)
            rows += [
                (f"table7/{ds_id}/{model_kind}/EnFed", enfed.accuracy,
                 enfed.report.t_train, saving),
                (f"table7/{ds_id}/{model_kind}/cloud", float(cloud_acc),
                 cloud_resp, 0.0),
            ]
            if verbose:
                print(f"[table7/{ds_id}/{model_kind}] EnFed acc={enfed.accuracy:.3f} "
                      f"resp={enfed.report.t_train:.2f}s | cloud acc={cloud_acc:.3f} "
                      f"resp={cloud_resp:.2f}s | EnFed {saving:.0f}% faster")
    return rows


if __name__ == "__main__":
    run()
