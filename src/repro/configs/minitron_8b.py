"""Minitron-8B [arXiv:2407.14679] — Nemotron-4 15B pruned to 8B
(width-pruned d_ff, depth kept), dense GQA decoder.

Assigned spec: 32L, d_model=4096, 32H (GQA kv=8, head_dim 128),
d_ff=16384, vocab=256000.  Full attention => long_500k skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    citation="arXiv:2407.14679",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=256_000,
    block_pattern=("attn",),
    rope_theta=10000.0,
    dtype="bfloat16",
)
