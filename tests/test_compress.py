"""The ``compress="int8"`` protocol knob: quantize⊕fedavg composition,
wire-format round state, and full two-engine parity.

The knob is the engine's first accuracy-affecting protocol option since
mobility, so it gets the full parity treatment: the loop engine
(``EnFedSession`` + ``_wire_pack``/``_wire_image``) and the fleet engine
(int8 round state + ``fedavg_flat_batched_q8`` + in-program requantize)
must agree bitwise on membership masks and allclose — at an atol tied to
the per-tile quantization scale — on params, in static AND mobility
worlds, encrypted or not.
"""

import copy
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.core import (EnFedConfig, EnFedSession, MobilityConfig,
                        RequesterSpec, SupervisedTask, make_fleet, run_fleet)
from repro.core.energy import CostModel, update_wire_bytes
from repro.data import (CaloriesDatasetConfig, dirichlet_partition,
                        make_calories_tabular)
from repro.models import MLPClassifier, MLPClassifierConfig

RNG = np.random.default_rng(7)
BATCH = 16

# the documented composition bound: each dequantized weight is within
# scale/2 = absmax/254 of its fp32 value per tile, and the masked
# weighted mean is a convex combination, so |q8_fedavg - fp32_fedavg|
# <= max_tile_scale / 2 (+ fp noise)
def _tile_bound(scales):
    return float(jnp.max(scales)) / 2.0 + 1e-6


def _build(n_contrib=3, n_samples=600, seed=0):
    x, y = make_calories_tabular(CaloriesDatasetConfig(num_samples=n_samples))
    task = SupervisedTask(MLPClassifier(MLPClassifierConfig(8, (16,), 5)), lr=3e-3)
    parts = dirichlet_partition(y, num_clients=n_contrib + 1, alpha=100.0, seed=seed)
    shards = [(x[p], y[p]) for p in parts]
    own_x, own_y = shards[0]
    n = int(len(own_x) * 0.8)
    own_train, own_test = (own_x[:n], own_y[:n]), (own_x[n:], own_y[n:])
    fleet = make_fleet(n_contrib, seed=1, p_has_model=1.0)
    states = {}
    for i, dev in enumerate(fleet):
        dev.reservation_price = 0.4
        p = task.init(seed=10 + i)
        p, _ = task.fit(p, shards[i + 1], epochs=1, batch_size=BATCH, seed=i)
        states[dev.device_id] = {"params": p, "data": shards[i + 1]}
    return task, own_train, own_test, fleet, states


@pytest.fixture(scope="module")
def problem():
    return _build()


@pytest.fixture(scope="module")
def problem_big():
    """A model big enough (P=2821 > 2 tiles) that the int8 wire format
    amortizes its tile padding — the regime the knob exists for.  The
    tiny fixture above (P=229 < 1 tile) is padding-limited: int8 can
    cost MORE bytes there, which is honest physics, not a bug."""
    x, y = make_calories_tabular(CaloriesDatasetConfig(num_samples=400))
    task = SupervisedTask(MLPClassifier(MLPClassifierConfig(8, (64, 32), 5)),
                          lr=3e-3)
    parts = dirichlet_partition(y, num_clients=3, alpha=100.0, seed=0)
    shards = [(x[p], y[p]) for p in parts]
    fleet = make_fleet(2, seed=1, p_has_model=1.0)
    states = {}
    for i, dev in enumerate(fleet):
        dev.reservation_price = 0.4
        states[dev.device_id] = {"params": task.init(seed=10 + i),
                                 "data": shards[i + 1]}
    own_x, own_y = shards[0]
    return (task, (own_x[:64], own_y[:64]), (own_x[64:96], own_y[64:96]),
            fleet, states)


# ---------------------------------------------------------------------------
# quantize ⊕ fedavg composition (kernel level)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("r,n,l", [(3, 4, 2048), (2, 1, 1024),   # N=1 lanes
                                   (5, 3, 453)])                  # off-tile P
def test_q8_fedavg_composition_error_bound(r, n, l):
    """Fused dequant->fedavg on quantized updates stays within the
    per-tile scale bound of the fp32 fedavg on the originals."""
    from repro.kernels.fedavg.ops import (fedavg_flat_batched,
                                          fedavg_flat_batched_q8)
    from repro.kernels.quantize.ops import padded_len, quantize_flat_batched

    u = RNG.normal(size=(r, n, l)).astype(np.float32)
    lp = padded_len(l)
    q, s = quantize_flat_batched(
        jnp.pad(jnp.asarray(u), ((0, 0), (0, 0), (0, lp - l)))
        .reshape(r * n, lp))
    q = q.reshape(r, n, lp)
    s = s.reshape(r, n, -1)
    w = jnp.asarray(RNG.random((r, n)).astype(np.float32) + 0.05)
    got = fedavg_flat_batched_q8(q, s, w)[:, :l]
    want = fedavg_flat_batched(jnp.asarray(u), w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=_tile_bound(s))
    # and the pallas path agrees with the jnp oracle exactly
    ref = fedavg_flat_batched_q8(q, s, w, use_pallas=False)[:, :l]
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_q8_fedavg_all_zero_weight_rows():
    """A session whose whole neighborhood churned away: the all-zero
    weight row returns a zero vector, exactly like the fp32 kernel."""
    from repro.kernels.fedavg.ops import fedavg_flat_batched_q8
    from repro.kernels.quantize.ops import quantize_flat_batched

    u = jnp.asarray(RNG.normal(size=(3 * 2, 1024)).astype(np.float32))
    q, s = quantize_flat_batched(u)
    q, s = q.reshape(3, 2, -1), s.reshape(3, 2, -1)
    w = jnp.asarray([[1.0, 0.5], [0.0, 0.0], [0.3, 0.0]], jnp.float32)
    out = np.asarray(fedavg_flat_batched_q8(q, s, w))
    assert np.allclose(out[1], 0.0)
    assert not np.allclose(out[0], 0.0)


def test_batched_quantize_rows_match_compress_update():
    """The fleet's batched requantize matches the loop's per-device
    compress_update — bit-equal int8 codes, scales within 1 ulp (the
    /127 division may codegen differently across shapes) — the property
    that aligns the two engines' quantization points."""
    from repro.kernels.quantize.ops import compress_update, quantize_flat_batched

    x = jnp.asarray(RNG.normal(size=(5, 2048)).astype(np.float32))
    qb, sb = quantize_flat_batched(x)
    for i in range(5):
        qi, si, _ = compress_update(x[i])
        np.testing.assert_array_equal(np.asarray(qb[i]), np.asarray(qi))
        np.testing.assert_allclose(np.asarray(sb[i]), np.asarray(si),
                                   rtol=2e-7)


def test_update_wire_bytes_compression_ratio():
    """The cost model's wire bytes drop ~4x under int8 for models large
    enough that tile padding amortizes."""
    for p in (4096, 10_000, 100_000):
        fp32 = update_wire_bytes(p, encrypt=True, compress=None)
        q8 = update_wire_bytes(p, encrypt=True, compress="int8")
        assert q8 < fp32
        if p >= 10_000:
            assert fp32 / q8 > 3.5
    with pytest.raises(ValueError):
        update_wire_bytes(100, compress="int4")
    with pytest.raises(ValueError):
        EnFedConfig(compress="int4")


# ---------------------------------------------------------------------------
# compress="auto": the padding-overhead crossover
# ---------------------------------------------------------------------------


def test_resolve_compress_crossover():
    """"auto" picks int8 only past the tile-padding crossover; explicit
    modes pass through; junk fails fast."""
    from repro.kernels.quantize.ops import (AUTO_COMPRESS_MAX_RATIO, TILE,
                                            compressed_nbytes, resolve_compress)

    # explicit overrides are never second-guessed
    assert resolve_compress(None, 10) is None
    assert resolve_compress("int8", 10) == "int8"
    # below one tile the padded int8 image beats half of fp32 only for
    # big-enough P: the tiny suite model stays fp32, the big one flips
    assert resolve_compress("auto", 229) is None
    assert resolve_compress("auto", 2821) == "int8"
    # the decision IS the documented ratio, at both sides of the boundary
    for p in (64, 229, 453, 513, 2048, 2821, 100_000):
        want = ("int8" if compressed_nbytes(p) <= AUTO_COMPRESS_MAX_RATIO * 4 * p
                else None)
        assert resolve_compress("auto", p) == want, p
    # a model of exactly half a tile of fp32 bytes sits right at the
    # crossover: padded payload + scale > ratio * raw -> fp32
    assert resolve_compress("auto", TILE // 2) is None
    with pytest.raises(ValueError):
        resolve_compress("int4", 10)


def test_update_wire_bytes_auto_matches_resolved_mode():
    from repro.kernels.quantize.ops import resolve_compress

    for p in (229, 2821, 100_000):
        resolved = resolve_compress("auto", p)
        assert update_wire_bytes(p, compress="auto") == \
            update_wire_bytes(p, compress=resolved), p
    assert EnFedConfig(compress="auto").compress == "auto"  # accepted


def test_auto_resolves_per_model_in_both_engines(problem, problem_big):
    """Under "auto" a sub-crossover model runs EXACTLY the fp32 path and
    a post-crossover model EXACTLY the int8 path — in both engines."""
    def run_pair(prob, mode_a, mode_b, big):
        task, own_train, own_test, fleet, states = prob
        out = {}
        for mode in (mode_a, mode_b):
            cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=1, epochs=1,
                              batch_size=BATCH, encrypt=False,
                              contributor_refresh_epochs=1, compress=mode)
            loop = EnFedSession(task, own_train, own_test, fleet,
                                copy.deepcopy(states), cfg).run()
            fl = run_fleet(task, [RequesterSpec(own_train, own_test, fleet,
                                                copy.deepcopy(states))], cfg)
            out[mode] = (loop, fl)
        (la, fa), (lb, fb) = out[mode_a], out[mode_b]
        for x, y in ((la, lb), (fa.sessions[0], fb.sessions[0])):
            xv, _ = ravel_pytree(x.params)
            yv, _ = ravel_pytree(y.params)
            np.testing.assert_array_equal(np.asarray(xv), np.asarray(yv))
        # identical wire pricing and round-state footprint
        assert la.report.times.t_com == lb.report.times.t_com
        assert fa.staged_param_bytes == fb.staged_param_bytes

    run_pair(problem, "auto", None, big=False)        # tiny: auto == fp32
    run_pair(problem_big, "auto", "int8", big=True)   # big: auto == int8


def _run_both(problem, cfg, battery_kw=None):
    task, own_train, own_test, fleet, states = problem
    from repro.core.battery import BatteryState
    battery_kw = battery_kw or {}
    loop = EnFedSession(task, own_train, own_test, fleet, copy.deepcopy(states),
                        cfg, battery=BatteryState(**battery_kw)).run()
    spec = RequesterSpec(own_train=own_train, own_test=own_test,
                         neighborhood=fleet,
                         contributor_states=copy.deepcopy(states),
                         battery=BatteryState(**battery_kw))
    return loop, run_fleet(task, [spec], cfg).sessions[0]


def _assert_parity(loop, fl, atol=1e-2):
    """allclose at the documented tile-scale atol (<= 1e-2): engine fit
    math differs by ~1e-6, which a quantization boundary can amplify to
    one scale step."""
    assert fl.rounds == loop.rounds
    assert fl.stop_reason == loop.stop_reason
    np.testing.assert_allclose(fl.history_raw["battery"], loop.history_raw["battery"],
                               rtol=1e-5, atol=1e-6)
    lv, _ = ravel_pytree(loop.params)
    fv, _ = ravel_pytree(fl.params)
    np.testing.assert_allclose(np.asarray(fv), np.asarray(lv), atol=atol)


@pytest.mark.parametrize("encrypt", [False, True], ids=["plain", "encrypted"])
def test_compress_parity_static(problem, encrypt):
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=3, epochs=2,
                      batch_size=BATCH, encrypt=encrypt,
                      contributor_refresh_epochs=1, compress="int8")
    loop, fl = _run_both(problem, cfg)
    assert loop.stop_reason == "max_rounds"
    _assert_parity(loop, fl)


def test_compress_parity_mobility(problem):
    """Churn world + compressed transport: masks bit-identical, params
    within the tile bound, battery trajectories exact."""
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=6, epochs=1,
                      batch_size=BATCH, encrypt=False, n_max=2,
                      contributor_refresh_epochs=1, compress="int8",
                      mobility=MobilityConfig(radio_range_m=110.0,
                                              leg_rounds=2, seed=3))
    loop, fl = _run_both(problem, cfg)
    _assert_parity(loop, fl)
    np.testing.assert_array_equal(np.array(loop.history_raw["member_mask"]),
                                  np.array(fl.history_raw["member_mask"]))
    assert loop.history_raw["members"] == fl.history_raw["members"]


def test_compress_writes_back_wire_image(problem):
    """Both engines leave the SAME dequantized-from-wire contributor
    params behind — the compressed analogue of the refresh write-back
    contract."""
    task, own_train, own_test, fleet, states = problem
    cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=2, epochs=1,
                      batch_size=BATCH, encrypt=False,
                      contributor_refresh_epochs=1, compress="int8")
    loop_states = copy.deepcopy(states)
    EnFedSession(task, own_train, own_test, fleet, loop_states, cfg).run()
    fleet_states = copy.deepcopy(states)
    run_fleet(task, [RequesterSpec(own_train, own_test, fleet, fleet_states)], cfg)
    for dev_id in states:
        before, _ = ravel_pytree(states[dev_id]["params"])
        lv, _ = ravel_pytree(loop_states[dev_id]["params"])
        fv, _ = ravel_pytree(fleet_states[dev_id]["params"])
        assert not np.allclose(np.asarray(lv), np.asarray(before)), "refresh ran"
        np.testing.assert_allclose(np.asarray(fv), np.asarray(lv), atol=1e-2)


def test_compress_fleet_bytes_shrink(problem_big):
    """The staged and device-resident param round state drops >= 3.5x
    under int8 once the model amortizes the quantization tile (a model
    under one tile is padding-limited and may not shrink — that edge is
    covered by the ratio helper test above)."""
    task, own_train, own_test, fleet, states = problem_big
    results = {}
    for compress in (None, "int8"):
        cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=1, epochs=1,
                          batch_size=BATCH, encrypt=False,
                          contributor_refresh_epochs=1, compress=compress)
        results[compress] = run_fleet(
            task, [RequesterSpec(own_train, own_test, fleet,
                                 copy.deepcopy(states))], cfg)
    assert (results[None].staged_param_bytes
            / results["int8"].staged_param_bytes) >= 3.5
    assert (results[None].device_round_state_bytes
            / results["int8"].device_round_state_bytes) >= 3.5
    # the refresh gather footprint is reported and beats the old dense form
    for r in results.values():
        assert 0 < r.refresh_gather_bytes < r.refresh_gather_bytes_dense


def test_compress_lowers_transmission_cost(problem_big):
    """eq. (4)-(7) must SEE the compression: same world, same config
    except the knob -> strictly lower t_com and communication energy."""
    task, own_train, own_test, fleet, states = problem_big
    results = {}
    for compress in (None, "int8"):
        cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=2, epochs=1,
                          batch_size=BATCH, encrypt=True,
                          contributor_refresh_epochs=0, compress=compress)
        results[compress] = EnFedSession(
            task, own_train, own_test, fleet, copy.deepcopy(states), cfg).run()
    t_fp32, t_q8 = (results[k].report.times for k in (None, "int8"))
    assert t_q8.t_com < t_fp32.t_com
    assert t_q8.t_dec < t_fp32.t_dec          # crypto runs over fewer bytes
    assert (results["int8"].report.e_comm < results[None].report.e_comm)


def test_compress_knob_through_facade(problem_big):
    """MethodSpec.compress threads to both engines through repro.api and
    ExecutionSpec still cannot change the simulated outcome."""
    from repro.api import Experiment, ExecutionSpec, MethodSpec, WorldSpec

    task, own_train, own_test, fleet, states = problem_big
    world = WorldSpec.single(task, own_train, own_test, fleet,
                             copy.deepcopy(states))
    method = MethodSpec(desired_accuracy=0.99, max_rounds=2, epochs=1,
                        batch_size=BATCH, encrypt=False,
                        contributor_refresh_epochs=1, compress="int8")
    res = {}
    for engine in ("loop", "fleet"):
        res[engine] = Experiment(world, method,
                                 ExecutionSpec(engine=engine)).run()
    assert res["loop"].rounds == res["fleet"].rounds
    lv, _ = ravel_pytree(res["loop"].params)
    fv, _ = ravel_pytree(res["fleet"].params)
    np.testing.assert_allclose(np.asarray(fv), np.asarray(lv), atol=1e-2)
    # the knob reaches the baselines' cost model too
    cmp = Experiment(world, method).compare(
        ["enfed", dataclasses.replace(method, name="dfl", label="dfl")])
    cmp_fp = Experiment(world, dataclasses.replace(method, compress=None)
                        ).compare(["enfed", "dfl"])
    assert (cmp["dfl"].report.times.t_com < cmp_fp["dfl"].report.times.t_com)


def test_compress_changes_results_vs_fp32(problem):
    """compress is a PROTOCOL knob: quantization noise must actually
    reach the trained params (it is not a pure accounting change)."""
    task, own_train, own_test, fleet, states = problem
    runs = {}
    for compress in (None, "int8"):
        cfg = EnFedConfig(desired_accuracy=0.99, max_rounds=1, epochs=1,
                          batch_size=BATCH, encrypt=False,
                          contributor_refresh_epochs=0, compress=compress)
        runs[compress] = EnFedSession(task, own_train, own_test, fleet,
                                      copy.deepcopy(states), cfg).run()
    a, _ = ravel_pytree(runs[None].params)
    b, _ = ravel_pytree(runs["int8"].params)
    diff = float(np.abs(np.asarray(a) - np.asarray(b)).max())
    # nonzero (the noise is real) but small (one fit can amplify the
    # per-weight absmax/254 wire error by a few optimizer steps)
    assert 0.0 < diff < 0.1
