"""Pure-jnp oracle for the fused LSTM cell (identical math to
``repro.models.classifiers.lstm_cell_ref``, re-exported for the kernel
test harness)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lstm_cell_ref(x, h, c, wx, wh, b):
    """x: (B,F); h,c: (B,H); wx: (F,4H); wh: (H,4H); b: (4H,).

    Gate layout [i | f | g | o] along the 4H axis.
    Returns (h_new, c_new).
    """
    gates = x @ wx + h @ wh + b
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new
