"""Pytree checkpointing to .npz (no orbax in this environment).

Layout: ``<dir>/step_<N>.npz`` holding flattened leaves keyed by their
tree path, plus a ``__treedef__`` marker reconstructed from a template
pytree on restore (restore requires a structural template, which the
training loop always has: its freshly-initialized state).
"""

from __future__ import annotations

import os
import re
from typing import Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(directory: str, step: int, state) -> str:
    """Atomically write ``state`` as ``step_<N>.npz``.

    The tmp name carries the ``.npz`` suffix up front — ``np.savez``
    appends one to extension-less names, which used to leave the final
    rename guessing between two candidate tmp paths (a race that could
    orphan ``.tmp.npz`` files on crash).  Deterministic name, one
    ``os.replace``: a reader either sees the complete old file or the
    complete new one, never a torn write.
    """
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"step_{step:08d}.npz")
    tmp = path + ".tmp.npz"
    np.savez(tmp, **_flatten_with_paths(state))
    os.replace(tmp, path)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for f in os.listdir(directory):
        if f.endswith(".tmp.npz"):
            # a crash between savez and replace leaves the tmp file
            # behind; sweep it here (the only other writer path) so
            # stale partial writes never accumulate or get mistaken for
            # checkpoints
            try:
                os.remove(os.path.join(directory, f))
            except OSError:
                pass
            continue
        if m := re.match(r"step_(\d+)\.npz$", f):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template, step: Optional[int] = None):
    """Restore into the structure of ``template``. Returns (state, step)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}.npz")
    data = np.load(path)
    flat_paths = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat_paths[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs template {np.shape(leaf)}")
        want = np.asarray(leaf).dtype
        if arr.dtype != want:
            # no silent downcast: an fp32 checkpoint must not restore
            # into an int8 wire buffer (or vice versa) — the wire-format
            # rule says resumable state checkpoints AS its resident dtype
            raise ValueError(
                f"dtype mismatch for {key}: ckpt {arr.dtype} vs template {want}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat_paths[1], leaves), step
