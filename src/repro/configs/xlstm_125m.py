"""xLSTM-125M [arXiv:2405.04517] — sLSTM + mLSTM recurrent blocks.

Assigned spec: 12L, d_model=768, 4 heads, d_ff=0 (blocks own their
up/down projections, proj factor 2), vocab=50304.
Pattern 3:1 mLSTM:sLSTM (the paper's [7:1]-style mix at 12-layer scale).
O(1)-in-seq recurrent state => long_500k runs.  This is also the family
closest to the EnFed paper's own LSTM classifier.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    citation="arXiv:2405.04517",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    mlstm_proj_factor=2.0,
    tie_embeddings=True,
    dtype="bfloat16",
)
