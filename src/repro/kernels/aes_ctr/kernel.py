"""Pallas TPU kernel: AES-128-CTR keystream generation + payload XOR.

The paper AES-encrypts every model update before transmission; at fleet
scale (R rounds x N_c contributors x w bytes) the cipher is a real
per-byte hot loop.  CTR mode is embarrassingly parallel over 16-byte
blocks, so the kernel computes the keystream for a tile of counter
blocks and XORs the payload in the same VMEM pass — the keystream never
touches HBM.

TPU adaptation note: SubBytes and the GF(2^8) column multiplies are
byte-table lookups.  A TPU has no scalar byte-gather unit, so the lookup
tables are passed into VMEM and indexed with vectorized ``jnp.take``;
this lowers (gather on VMEM) but is not MXU work — on real hardware a
bitsliced formulation would be preferred.  The kernel is validated in
interpret mode against the FIPS-197-checked reference; it exists to
demonstrate the protocol layer can live on-accelerator, per DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret

from repro.core import crypto

BLOCK_TILE = 512  # AES blocks per grid step (512 x 16 B = 8 KiB tile)


def _aes_ctr_kernel(ctr_ref, pay_ref, rk_ref, sbox_ref, mul2_ref, mul3_ref,
                    shift_ref, out_ref):
    """ctr/pay/out: (BT, 16) uint8; rk: (11, 16); tables: (256,) uint8;
    shift: (16,) int32 ShiftRows permutation."""
    sbox = sbox_ref[...]
    mul2 = mul2_ref[...]
    mul3 = mul3_ref[...]
    rk = rk_ref[...]
    shift = shift_ref[...]

    def sub(state):
        return jnp.take(sbox, state.astype(jnp.int32))

    def shift_rows(state):
        return jnp.take(state, shift, axis=1)

    def mix_columns(state):
        s = state.reshape(-1, 4, 4)
        a0, a1, a2, a3 = s[:, :, 0], s[:, :, 1], s[:, :, 2], s[:, :, 3]
        i0, i1, i2, i3 = (a0.astype(jnp.int32), a1.astype(jnp.int32),
                          a2.astype(jnp.int32), a3.astype(jnp.int32))
        b0 = jnp.take(mul2, i0) ^ jnp.take(mul3, i1) ^ a2 ^ a3
        b1 = a0 ^ jnp.take(mul2, i1) ^ jnp.take(mul3, i2) ^ a3
        b2 = a0 ^ a1 ^ jnp.take(mul2, i2) ^ jnp.take(mul3, i3)
        b3 = jnp.take(mul3, i0) ^ a1 ^ a2 ^ jnp.take(mul2, i3)
        return jnp.stack([b0, b1, b2, b3], axis=-1).reshape(-1, 16)

    state = ctr_ref[...] ^ rk[0]
    for rnd in range(1, 10):
        state = mix_columns(shift_rows(sub(state))) ^ rk[rnd]
    keystream = shift_rows(sub(state)) ^ rk[10]
    out_ref[...] = pay_ref[...] ^ keystream


@functools.partial(jax.jit, static_argnames=("interpret",))
def aes_ctr_pallas(payload_u8, round_keys, ctr_blocks, *, interpret=None):
    """payload_u8: (n,) uint8; round_keys: (11,16) uint8;
    ctr_blocks: (ceil(n/16), 16) uint8 CTR input blocks. Returns (n,) uint8."""
    interpret = resolve_interpret(interpret)
    n = payload_u8.shape[0]
    n_blocks = ctr_blocks.shape[0]
    pad = n_blocks * 16 - n
    pay = jnp.pad(payload_u8, (0, pad)).reshape(n_blocks, 16)
    bpad = (-n_blocks) % BLOCK_TILE
    if bpad:
        pay = jnp.pad(pay, ((0, bpad), (0, 0)))
        ctr_blocks = jnp.pad(ctr_blocks, ((0, bpad), (0, 0)))
    nb = n_blocks + bpad
    grid = (nb // BLOCK_TILE,)
    out = pl.pallas_call(
        _aes_ctr_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_TILE, 16), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_TILE, 16), lambda i: (i, 0)),
            pl.BlockSpec((11, 16), lambda i: (0, 0)),
            pl.BlockSpec((256,), lambda i: (0,)),
            pl.BlockSpec((256,), lambda i: (0,)),
            pl.BlockSpec((256,), lambda i: (0,)),
            pl.BlockSpec((16,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_TILE, 16), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, 16), jnp.uint8),
        interpret=interpret,
    )(ctr_blocks, pay, round_keys,
      jnp.asarray(crypto._SBOX), jnp.asarray(crypto._MUL2), jnp.asarray(crypto._MUL3),
      jnp.asarray(crypto._SHIFT_ROWS, dtype=jnp.int32))
    return out.reshape(-1)[:n]
