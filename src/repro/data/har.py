"""Synthetic stand-ins for the paper's two HAR datasets.

The paper uses two Kaggle datasets that are not available offline:

* **Dataset 1** — "Calories burned during exercise and activities":
  tabular features -> calorie-range class in {<0.5, 0.5-1, 1-2, 2-3, >3}
  (5 classes), analysed with the MLP.
* **Dataset 2** — "HARSense": accelerometer + gyroscope streams of 12
  users -> activity in {Running, Walking, Sitting, Standing, Downstairs,
  Upstairs} (6 classes), analysed with the LSTM.

We synthesize both with class-conditional generative signatures chosen so
that (a) the task is learnable to the paper's reported >95% accuracy
bracket with the paper's models, (b) classes overlap enough to be
non-trivial, and (c) per-user style factors exist so a Dirichlet non-IID
split produces genuinely heterogeneous clients (the paper distributes
both datasets non-identically across the requester + 5 supporters).
"""

from __future__ import annotations

import dataclasses

import numpy as np

HAR_ACTIVITIES = ("Running", "Walking", "Sitting", "Standing", "Downstairs", "Upstairs")
CALORIE_CLASSES = ("<0.5", "0.5-1", "1-2", "2-3", ">3")


@dataclasses.dataclass(frozen=True)
class HARDatasetConfig:
    num_samples: int = 6000
    seq_len: int = 64
    num_channels: int = 6       # 3-axis accelerometer + 3-axis gyroscope
    num_users: int = 12         # HARSense has 12 users
    noise: float = 0.35
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class CaloriesDatasetConfig:
    num_samples: int = 5000
    num_features: int = 8       # activity intensity, duration, weight, ...
    noise: float = 0.10         # sensor noise on physiology features
    cal_noise: float = 0.04     # wearable calorie-rate estimate noise
    seed: int = 0


# per-activity signature: (base freq, amplitude, gravity-axis offset, harmonic amp)
_ACT_SIG = {
    0: (2.6, 2.0, 0.4, 0.8),   # Running: high freq, high amp
    1: (1.4, 1.0, 0.4, 0.4),   # Walking
    2: (0.05, 0.05, -0.9, 0.0),  # Sitting: near-static, tilted gravity
    3: (0.05, 0.05, 1.0, 0.0),   # Standing: near-static, upright gravity
    4: (1.7, 1.3, 0.1, 0.6),   # Downstairs: walking-like + impact harmonic
    5: (1.2, 1.5, 0.7, 0.3),   # Upstairs: slower, high vertical effort
}


def make_har_windows(cfg: HARDatasetConfig = HARDatasetConfig()):
    """Returns (x, y, user): x (N, T, C) fp32, y (N,) int32, user (N,) int32."""
    rng = np.random.default_rng(cfg.seed)
    N, T, C = cfg.num_samples, cfg.seq_len, cfg.num_channels
    y = rng.integers(0, len(HAR_ACTIVITIES), size=N)
    user = rng.integers(0, cfg.num_users, size=N)
    # per-user style: gain and frequency scaling (body mass / gait differences)
    user_gain = rng.normal(1.0, 0.12, size=cfg.num_users)
    user_freq = rng.normal(1.0, 0.08, size=cfg.num_users)
    t = np.arange(T)[None, :, None] / 20.0  # 20 Hz sampling
    phase = rng.uniform(0, 2 * np.pi, size=(N, 1, C))
    chan_mix = rng.normal(1.0, 0.2, size=(1, 1, C))

    freq = np.array([_ACT_SIG[c][0] for c in y])[:, None, None]
    amp = np.array([_ACT_SIG[c][1] for c in y])[:, None, None]
    grav = np.array([_ACT_SIG[c][2] for c in y])[:, None, None]
    harm = np.array([_ACT_SIG[c][3] for c in y])[:, None, None]

    freq = freq * user_freq[user][:, None, None]
    amp = amp * user_gain[user][:, None, None]

    x = amp * np.sin(2 * np.pi * freq * t + phase) * chan_mix
    x = x + harm * np.sin(2 * np.pi * 2 * freq * t + 2 * phase)
    # gravity offset on the "vertical" channels (first of each sensor triple)
    x[:, :, 0::3] += grav
    x = x + rng.normal(0, cfg.noise, size=x.shape)
    return x.astype(np.float32), y.astype(np.int32), user.astype(np.int32)


def make_calories_tabular(cfg: CaloriesDatasetConfig = CaloriesDatasetConfig()):
    """Returns (x, y): x (N, F) fp32, y (N,) int32 calorie-range class.

    kcal/min = MET x 3.5 x kg / 200 (the standard MET formula); classes
    are the paper's calorie-rate bins (<0.5, 0.5-1, 1-2, 2-3, >3).  The
    feature set mimics the Kaggle table: noisy physiology readings plus a
    wearable's own (noisy) calorie-rate estimate; with the default noise
    the achievable accuracy sits in the paper's ~96% band for the MLP.
    """
    rng = np.random.default_rng(cfg.seed)
    N, F = cfg.num_samples, cfg.num_features
    # latent physiology: intensity (MET-like), duration, body weight
    intensity = rng.gamma(2.0, 0.8, size=N)           # ~ MET score
    duration = rng.uniform(0.2, 1.5, size=N)          # hours
    weight = rng.normal(75, 12, size=N)               # kg
    cal_per_min = intensity * weight * 3.5 / 200.0    # kcal/min MET formula
    bins = np.array([0.5, 1.0, 2.0, 3.0])
    y = np.digitize(cal_per_min, bins)

    x = np.zeros((N, F), np.float32)
    x[:, 0] = intensity + rng.normal(0, cfg.noise, N)
    x[:, 1] = duration + rng.normal(0, cfg.noise * 0.3, N)
    x[:, 2] = (weight - 75) / 12 + rng.normal(0, cfg.noise, N)
    x[:, 3] = intensity * duration + rng.normal(0, cfg.noise * 2, N)   # effort volume
    x[:, 4] = np.log1p(intensity) + rng.normal(0, cfg.noise, N)
    x[:, 5] = rng.normal(0, 1, N)                                      # nuisance
    x[:, 6] = cal_per_min + rng.normal(0, cfg.cal_noise, N)            # wearable estimate
    x[:, 7] = rng.normal(25, 4, N) / 10                                # BMI-ish nuisance
    return x.astype(np.float32), y.astype(np.int32)
