"""Jit-native EnFed fleet engine: many concurrent requester sessions,
one compiled program, allocation- and transfer-lean.

The loop engine (``repro.core.rounds.EnFedSession``) executes Algorithm 1
as Python control flow — one ``task.fit`` dispatch per contributor per
round — which caps simulations at a handful of sessions.  This module
ports the same protocol onto stacked arrays so an entire fleet of
requesting devices advances together.  Design rules for the hot path at
R=512 and beyond:

* **Flat-parameter round state.**  Contributor params are raveled ONCE
  at setup (``repro.utils.tree.tree_ravel``) into a single (R, N, P)
  fp32 buffer — R requester sessions, N contributor slots, P flat model
  parameters.  That buffer IS the round state: the batched Pallas
  ``fedavg`` kernel (eq. 14 for every session, one launch) reads it
  directly, masked freezes are plain ``jnp.where`` on it, and it is
  donated to XLA (``donate_argnames``) so the round loop updates it in
  place.  Pytrees reappear only inside the per-device ``fit_one`` /
  ``eval_one`` views (``tree_unravel`` on a lane's (P,) slice) and at
  the host boundary when results are unpacked.

* **On-device minibatch scheduling.**  No index tensors are staged:
  batches come from the counter-based derived schedule
  (``repro.core.schedule``), evaluated inside the compiled round loop
  from the traced round number.  The loop engine's ``SupervisedTask.fit``
  evaluates the SAME derivation host-side, so both engines see identical
  batches by construction; prefix-stable per-sample scores make one
  traced program serve requesters with different shard sizes, including
  shards smaller than one batch (single padded step, zero-weight
  padding).

* **Compressed round state (``cfg.compress="int8"``).**  The round
  state is the transported thing, so when the protocol compresses the
  wire it must compress the state: under the knob the (R, N, P) fp32
  buffer is carried instead as a tile-padded int8 payload (R, N, Lp)
  plus per-tile fp32 scales — ~4x less staged host->device traffic and
  ~4x less device-resident round state
  (``FleetResult.device_round_state_bytes``).  Aggregation runs the
  fused dequant->fedavg kernel (``fedavg_flat_batched_q8``) straight on
  the wire-format buffer (the dequantized fp32 block never
  materializes); Phase.REFRESH dequantizes per-lane views for training
  and requantizes the result back into the buffer
  (``quantize_flat_batched``) in the same launch discipline.  fp32
  reappears only in per-lane views and the requester's own params.  The
  loop engine quantizes at the identical protocol points
  (``EnFedSession._wire_pack``), so the knob keeps full two-engine
  parity: bitwise on membership masks, allclose (tile-scale bound) on
  params — see tests/test_compress.py.

* **Deduplicated contributor shards, never re-densified.**  Requesters
  sharing one contributor population used to re-stage the same training
  shards R times as a dense (R, N, n_c, F) block — the dominant
  host->device transfer at R=512.  Shards are now staged once into a
  unique-shard table (U, n_c, F) plus an (R, N) gather index; and the
  program must NEVER undo that dedup in device memory: Phase.REFRESH
  gathers each lane's minibatch straight from the table inside the fit
  scan ((R·N, B, F) per step) instead of materializing the lane-dense
  (R·N, n_c, F) block up front.  ``FleetResult.staged_shard_bytes`` vs
  ``staged_shard_bytes_dense`` records the staging win,
  ``refresh_gather_bytes`` vs ``refresh_gather_bytes_dense`` the
  device-memory one.

* **Early-exit rounds, no dead work.**  The round loop is a chunked
  ``lax.while_loop``: after every ``round_chunk`` rounds the program
  checks whether any lane is still active and stops outright when the
  whole fleet is done, so a fleet that converges by round k executes
  O(k) round bodies, not ``max_rounds``.  Inside a chunk, each round
  body sits under ``lax.cond`` — once every lane has stopped (or the
  chunk runs past ``max_rounds``) the fit/refresh compute is skipped,
  not computed-and-discarded.  Because traces are preallocated
  (max_rounds, ...) buffers written in place, early exit leaves the
  untouched tail at zero — ``history["round_executed"]`` records exactly
  which round bodies ran.

* **Opportunistic world (``cfg.mobility``).**  With a
  ``repro.core.mobility.MobilityConfig`` set, the contract set is no
  longer frozen at handshake: contributor lanes hold the whole agreeing
  *candidate pool*, and every round body re-negotiates membership on
  device — counter-based waypoint positions from the traced round
  number, radio-range proximity, battery-floor releases (contributor
  batteries are traced (R, N) state discharged per participating
  round), and top-``n_max``-by-utility signing so arrivals undercut
  weaker members.  The resulting (R, N) membership mask IS the fedavg
  weight vector of that round's batched kernel launch (via
  ``topology.dynamic_round_weights``), gates Phase.REFRESH to current
  members, and indexes a per-member-count energy table for the
  requester's battery discharge.  ``history["member"]`` traces the mask
  per round.  The loop engine's ``EnFedSession._run_mobility`` derives
  the same world through the same ``repro.core.mobility`` functions with
  concrete round numbers — identical trajectories, masks, params, and
  battery curves by construction.

* **Method as a traced protocol variant.**  ``run_fleet(...,
  method="dfl"|"cfl")`` runs the paper's baselines as lanes of the SAME
  jit program (``_fleet_program``'s ``method`` is a static argument):
  the flat (R, N, P) round state now holds per-client node params, the
  batched Pallas fedavg kernel performs the aggregation step — gossip
  mixing rows for dfl (one launch per mixing-matrix row), the
  server-side data-size-weighted FedAvg for cfl — and the chunked
  ``lax.while_loop`` gives the baselines the same early exit enfed has.
  Which protocol steps trace is decided by the per-method phase mask
  (``protocol.method_phases``): baselines drop RENEGOTIATE / REFRESH /
  battery accounting, and AGGREGATE moves from requester-side to the
  client mixing/server step.  The loop learners
  (``repro.core.federated.CFLLearner`` / ``DFLLearner.run_config``) are
  the parity oracles — same per-client seeds (``seed + 31r + j`` cfl,
  ``seed + 77r + j`` dfl), same mixing matrices
  (``topology.group_mixing_matrix``), same stopping — so
  ``Experiment.compare`` at R=512 measures every method from one
  compiled program instead of extrapolating Python-loop sessions.

Phase mapping (vocabulary in ``repro.core.protocol``): handshake stays
host-side (cheap, deterministic numpy) and emits either the static
(R, N) contract mask + per-round aggregation weights, or — under
mobility — the candidate pool whose per-round RENEGOTIATE step runs on
device; collect+aggregate is the batched fedavg launch on the flat
buffer; fit/score/account are vmapped masked lanes; refresh trains
contributors on their own shards between rounds.

Parity with the loop engine — same aggregated params, round counts, stop
reasons, membership masks, and battery trajectories — is asserted by
``tests/test_fleet_engine.py`` across aggregation strategies, encrypt
on/off, and churn scenarios.  The AES-128-CTR transport is bit-exact
(validated in the loop engine / kernel tests), so the fleet engine
models encryption in the cost domain (byte counts -> eq. (4)-(7) ->
battery) without re-running the cipher per round.  All sessions share
one ``SupervisedTask``.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import adversary as adversary_mod
from repro.core import cadence as cadence_mod
from repro.core import faults as faults_mod
from repro.core import mobility as mobility_mod
from repro.core import protocol, schedule, topology
from repro.core.battery import BatteryState, discharge_level, load_efficiency
from repro.core.energy import CostModel, update_wire_bytes
from repro.core.incentive import (NeighborDevice, candidate_pool,
                                  sign_contracts_fleet)
from repro.core.rounds import EnFedConfig, SessionResult
from repro.kernels.fedavg.ops import (fedavg_flat_batched,
                                      fedavg_flat_batched_q8)
from repro.kernels.robust.ops import robust_aggregate, robust_aggregate_q8
from repro.kernels.quantize.ops import (dequantize_flat_batched, padded_len,
                                        quantize_flat_batched,
                                        resolve_compress)
from repro.models.classifiers import masked_cross_entropy_loss
from repro.optim import apply_updates
from repro.telemetry.profile import jit_hlo_stats, maybe_jax_profiler
from repro.telemetry.spans import Timeline
from repro.utils.tree import (tree_bytes, tree_ravel, tree_size, tree_unravel,
                              tree_where)


@dataclasses.dataclass
class RequesterSpec:
    """One requesting device's inputs, mirroring ``EnFedSession``'s."""

    own_train: tuple                      # (x, y) numpy/array shard
    own_test: tuple
    neighborhood: Sequence[NeighborDevice]
    contributor_states: Dict[int, dict]   # device_id -> {params, data}
    battery: Optional[BatteryState] = None


@dataclasses.dataclass
class FleetResult:
    """Stacked outcome of one fleet program plus per-session views."""

    sessions: List[SessionResult]
    rounds: np.ndarray          # (R,) executed rounds per session
    stop_codes: np.ndarray      # (R,) protocol.STOP_* codes
    accuracy: np.ndarray        # (R,) final accuracy
    battery_level: np.ndarray   # (R,) final battery fraction
    total_energy_j: float       # summed eq. (5) energy across the fleet
    history: Dict[str, np.ndarray]  # (max_rounds, R) traces; "round_executed"
                                    # is (max_rounds,) — 1 where a round body
                                    # ran; "member" is (max_rounds, R, N)
                                    # under mobility (token zeros otherwise:
                                    # the static mask is just round_w > 0)
    staged_host_bytes: int = 0  # host->device bytes staged for the program
    staged_index_bytes: int = 0  # subset that is minibatch-schedule metadata
    staged_shard_bytes: int = 0  # contributor-shard table + gather indices
    staged_shard_bytes_dense: int = 0  # what the dense (R, N, ...) form costs
    staged_param_bytes: int = 0  # contributor-param round state as staged
                                 # (fp32 (R,N,P), or int8 payload + scales)
    device_round_state_bytes: int = 0  # device-RESIDENT round state carried
                                       # through the while_loop (fp32 vs int8)
    refresh_gather_bytes: int = 0  # per-step refresh minibatch gather
                                   # footprint ((R*N, B) rows from the table)
    refresh_gather_bytes_dense: int = 0  # the old re-densified (R*N, n_c, F)
                                         # block the gather replaces
    timeline: Optional[Timeline] = None  # host-side wall-clock spans
                                         # (stage/program/checkpoint/unpack)
    hlo_stats: Optional[dict] = None     # compiled-program flops/bytes
                                         # (TraceConfig.hlo_stats only)

    @property
    def history_raw(self) -> Dict[str, np.ndarray]:
        """Alias for ``history`` — fleet-level traces are not deprecated,
        but the alias keeps call sites uniform with SessionResult/
        RunResult, whose raw access goes through ``history_raw``."""
        return self.history


def _pad_stack(arrays, pad_len: int):
    """Stack ragged leading-axis arrays into (R, pad_len, ...) + mask."""
    shape = arrays[0].shape[1:]
    out = np.zeros((len(arrays), pad_len) + shape, arrays[0].dtype)
    mask = np.zeros((len(arrays), pad_len), np.float32)
    for i, a in enumerate(arrays):
        out[i, :len(a)] = a
        mask[i, :len(a)] = 1.0
    return out, mask


def _stack_trees(trees, template=None):
    """List of pytrees -> pytree with leading stacked axis (None entries
    become zeros_like(template))."""
    template = template if template is not None else next(t for t in trees if t is not None)
    filled = [t if t is not None else jax.tree_util.tree_map(np.zeros_like, template)
              for t in trees]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
                                  *filled)


class FleetCarry(NamedTuple):
    """The fleet loop carry, by name.

    A ``typing.NamedTuple`` is a registered JAX pytree, so it rides
    ``lax.while_loop`` / ``fori_loop`` / donation unchanged — and the
    checkpoint path (``repro.checkpoint`` flattens with key paths)
    serializes each field under its NAME (``state/.contrib`` ...), so a
    restored ``.npz`` stays dtype-strict AND self-describing.  Token
    ``(1, ...)`` buffers stand in for state a variant doesn't carry.

    The per-lane cadence clock fields at the tail are what the
    asynchronous fleet adds: ``clock`` is each requester lane's OWN
    round number (advanced only on its cadence ticks), ``idle`` the
    event steps it has idled since its last executed round, and
    ``clock_h``/``idle_h`` the per-executed-round traces of both
    (which global step each round ran at / how long the lane waited
    for it) — token buffers in lockstep runs.
    """

    contrib: jnp.ndarray      # (R, N, P|Lp) flat round state (int8 wire
                              # payload under compress)
    cscale: jnp.ndarray       # (R, N, T) per-tile scales | token
    live: jnp.ndarray         # (V, P|Lp) dedup'd refresh rows | token
    live_s: jnp.ndarray       # (V, T) their scales | token
    last: jnp.ndarray         # (R, P) requester params
    level: jnp.ndarray        # (R,) requester battery fraction
    active: jnp.ndarray       # (R,) bool — BOTH programs' stop poll
    stop_code: jnp.ndarray    # (R,) protocol.STOP_* codes
    rounds_done: jnp.ndarray  # (R,) executed rounds per lane
    clevel: jnp.ndarray       # (R, N) contributor batteries | token
    acc_h: jnp.ndarray        # (max_rounds, R) accuracy trace
    loss_h: jnp.ndarray       # (max_rounds, R) loss trace
    bat_h: jnp.ndarray        # (max_rounds, R) battery trace
    exec_h: jnp.ndarray       # (max_rounds, R) executed-lane trace
    body_h: jnp.ndarray       # (max_events,) round-body-ran trace
    member_h: jnp.ndarray     # (max_rounds, R, N) membership | token
    prev: jnp.ndarray         # (R, N, P|Lp) stale-delivery wire
                              # snapshot | token
    prev_s: jnp.ndarray       # (R, N, T) its scales | token
    drop_h: jnp.ndarray       # (max_rounds, R) fault drops | token
    retry_h: jnp.ndarray      # (max_rounds, R) fault retries | token
    stale_h: jnp.ndarray      # (max_rounds, R) stale deliveries | token
    deliver_h: jnp.ndarray    # (max_rounds, R, N) deliver mask | token
    clock: jnp.ndarray        # (R,) int32 per-lane round clock | token
    idle: jnp.ndarray         # (R,) int32 idle steps since the lane's
                              # last executed round | token
    clock_h: jnp.ndarray      # (max_rounds, R) int32 global step each
                              # round executed at | token
    idle_h: jnp.ndarray       # (max_rounds, R) int32 idle-steps-before
                              # trace | token
    corrupt_h: jnp.ndarray    # (max_rounds, R, N) corrupted-delivery
                              # mask (adversary worlds) | token
    clip_h: jnp.ndarray       # (max_rounds, R, N) norm-clipped mask
                              # (robust != "none") | token


def _make_round_fn(task, use_pallas, interpret, do_refresh, max_rounds,
                   max_events, epochs, batch, steps_max, ref_epochs,
                   ref_steps, spec, mob, n_max, strategy, compress, n_params,
                   method, fc, cc, ac, robust, gamma, n_req, n_lanes, arrays):
    """Build the traced per-round body shared by BOTH fleet programs.

    :func:`_fleet_program` (the compiled chunked ``while_loop``) and
    :func:`_fleet_chunk_program` (one chunk per call, for host-driven
    checkpoint/resume) trace the SAME ``maybe_round`` returned here, so
    the two execution paths cannot drift apart — which is what makes
    killed-at-round-k-and-resumed bit-identical to uninterrupted.

    ``fc`` is the static :class:`repro.core.faults.FaultConfig` (None =
    perfect links); under faults every round derives the per-link
    (delivered, attempts, stale) outcomes from the counter-based fault
    world (``Phase.DELIVER``), masks undelivered links out of the fedavg
    weights, aggregates round-(r-1) wire images for stale links (the
    ``prev`` carry), and re-prices every extra receive window through
    the staged ``e_retry`` term.

    ``cc`` is the static :class:`repro.core.cadence.CadenceConfig` (None
    = lockstep).  Under cadence ``maybe_round`` iterates GLOBAL EVENT
    STEPS, not rounds: world state (mobility kinematics, fault weather)
    is keyed on the step counter ``rr``, while each requester lane
    carries its own round ``clock`` that advances only on the lane's
    cadence ticks — a step where no lane ticks costs one idle increment
    and no compute (``lax.cond``, the early-exit skip machinery), and a
    lane that doesn't tick while others execute keeps its wire image
    resident for them to aggregate as-is (the straggler path).  With
    ``cc=None``, ``max_events == max_rounds`` and every lane ticks every
    step, so the traced program is today's lockstep loop bit for bit.

    ``ac`` is the static :class:`repro.core.adversary.AdversaryConfig`
    (None = honest world): per-link corruption outcomes derive from the
    same counter-based fold_in discipline as faults/cadence, keyed on
    the event step, and corrupt the WIRE image at the transport point —
    after the stale-delivery substitution, per the Phase.DELIVER
    ordering pin in ``repro.core.protocol``.  ``robust`` selects the
    Phase.AGGREGATE statistic (``repro.kernels.robust``) and ``gamma``
    the staleness decay on the aggregation weights
    (``protocol.decayed_round_weights``); both default to the plain
    fedavg path bit for bit.
    """
    model, opt = task.model, task._opt
    R, N = n_req, n_lanes
    P = n_params
    phases = protocol.method_phases(method)
    if method == "enfed":
        n_pad = arrays["own_x"].shape[1]
    mobility_on = (mob is not None) and (protocol.Phase.RENEGOTIATE in phases)
    faults_on = (fc is not None) and (protocol.Phase.DELIVER in phases)
    compress_on = compress == "int8"
    cadence_on = cc is not None
    adversary_on = (ac is not None) and (protocol.Phase.DELIVER in phases)
    robust_on = robust != "none"
    decay_on = float(gamma) != 1.0

    def _fit_lane(flat_p, get_xy, idx, w):
        """Identical math to SupervisedTask.fit for one device's shard,
        on a flat (P,) parameter view; ``get_xy`` maps a (B,) index row
        to that step's minibatch (direct shard slice for requesters,
        unique-table gather for contributor refresh)."""
        E, S, B = idx.shape
        params = tree_unravel(spec, flat_p)

        def one_step(carry, sv):
            p, s = carry
            ib, wb = sv
            xb, yb = get_xy(ib)
            loss, grads = jax.value_and_grad(
                lambda pp: masked_cross_entropy_loss(
                    model.forward(pp, xb), yb, wb))(p)
            upd, s2 = opt.update(grads, s, p)
            p2 = apply_updates(p, upd)
            take = jnp.sum(wb) > 0
            return ((tree_where(take, p2, p), tree_where(take, s2, s)),
                    jnp.where(take, loss, 0.0))

        (params, _), losses = jax.lax.scan(
            one_step, (params, opt.init(params)),
            (idx.reshape(E * S, B), w.reshape(E * S, B)))
        valid_steps = (w.sum(-1) > 0).astype(jnp.float32).reshape(E, S).sum(1)
        per_epoch = losses.reshape(E, S).sum(1) / jnp.maximum(valid_steps, 1.0)
        flat_out, _ = tree_ravel(params)
        return flat_out, per_epoch[-1]

    def fit_one(flat_p, x, y, idx, w):
        return _fit_lane(flat_p, lambda ib: (x[ib], y[ib]), idx, w)

    def eval_one(flat_p, x, y, mask):
        logits = model.forward(tree_unravel(spec, flat_p), x)
        correct = (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)
        return jnp.sum(correct * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    # Static worlds dedup the refresh COMPUTE itself: every active lane
    # subscribed to the same (device, shard, params) follows the
    # identical refresh trajectory (refresh is the only mutation of a
    # contributor lane, and it hits exactly the active lanes each
    # round), so one "live" row per unique subscription is trained and
    # scattered to lanes.  Under mobility membership gaps make lanes
    # diverge (a lane skips refresh in non-member rounds), so the
    # per-lane path remains — and cadence gaps (a contributor that
    # doesn't tick skips its refresh) desynchronize lanes the same way.
    refresh_dedup = do_refresh and not mobility_on and not cadence_on
    if do_refresh:
        # Phase.REFRESH schedule is round-invariant (seed = cfg.seed +
        # device_id), so its indices are derived once per program, on
        # device, and reused every round.  Training minibatches come
        # straight from the deduplicated unique-shard table, gathered
        # per step INSIDE the fit scan — the dedup is never undone into
        # an (R*N, n_c, F) lane-dense block in device memory.
        nc_pad = arrays["cx_tab"].shape[1]
        if refresh_dedup:
            ref_scores = jax.vmap(
                lambda s: schedule.epoch_scores(s, ref_epochs, nc_pad))(
                arrays["u_seed"])
            ref_idx, ref_w = jax.vmap(
                lambda sc, n: schedule.plan_from_scores(sc, n, batch,
                                                        ref_steps))(
                ref_scores, arrays["u_n"])
            ref_rows = arrays["u_cidx"]
            uidx_flat = arrays["ref_uidx"].reshape(R * N)
            # padded contributor slots subscribe to no live row; their
            # old no-op-refresh contents must survive the scatter
            lane_valid = arrays["lane_valid"].reshape(R * N, 1)
        else:
            ref_scores = jax.vmap(jax.vmap(
                lambda s: schedule.epoch_scores(s, ref_epochs, nc_pad)))(
                arrays["ref_seeds"])
            ref_idx, ref_w = jax.vmap(jax.vmap(
                lambda sc, n: schedule.plan_from_scores(sc, n, batch,
                                                        ref_steps)))(
                ref_scores, arrays["ref_n"])
            ref_rows = arrays["cidx"].reshape(R * N)
            ref_idx = ref_idx.reshape(R * N, ref_epochs, ref_steps, batch)
            ref_w = ref_w.reshape(R * N, ref_epochs, ref_steps, batch)

        def fit_refresh(flat_p, u, idx, w):
            """One refresh row: minibatch (B, F) rows are gathered from
            the shard table by (row u, index ib)."""
            return _fit_lane(
                flat_p,
                lambda ib: (arrays["cx_tab"][u, ib], arrays["cy_tab"][u, ib]),
                idx, w)

    def run_round(state, rr, tick=None):
        """One live round body.  Entered only via lax.cond when at least
        one lane is active and rr < max_events (so ``active`` needs no
        extra validity masking inside).  Under cadence ``tick`` is the
        (R,) bool of lanes executing THIS event step (already masked by
        ``active``); lockstep passes None and every active lane
        executes."""
        (contrib, cscale, live, live_s, last, level, active, stop_code,
         rounds_done, clevel, acc_h, loss_h, bat_h, exec_h, body_h,
         member_h, prev, prev_s, drop_h, retry_h, stale_h, deliver_h,
         clock, idle, clock_h, idle_h, corrupt_h, clip_h) = state
        # which lanes execute a protocol round at this event step; under
        # cadence the rest idle in place (their whole ACCOUNT/history
        # update is masked out below)
        exec_mask = tick if cadence_on else active
        if cadence_on:
            # contributor ticks gate Phase.REFRESH only — a straggler's
            # wire image stays resident and is aggregated as-is by the
            # lanes that did tick
            ctick = cadence_mod.tick_mask(cc, rr, arrays["cad_cand_ids"])
            # each executing lane writes history at ITS OWN round row
            row = jnp.clip(clock, 0, max_rounds - 1)
            lanes = jnp.arange(R)

            def put_lane(buf, vals):
                cur = buf[row, lanes]
                if vals.ndim == 2:      # (R, N) membership-shaped rows
                    return buf.at[row, lanes].set(
                        jnp.where(exec_mask[:, None], vals, cur))
                return buf.at[row, lanes].set(
                    jnp.where(exec_mask, vals, cur))

        # Phase.RENEGOTIATE (mobility): release members that walked out
        # of radio range or hit the battery floor, sign in-range
        # arrivals, let higher-utility arrivals displace weaker members
        # — all on device, from the traced round number.  Under faults,
        # streak-blocked links lose eligibility here too.
        if mobility_on:
            blocked = (faults_mod.blocked_mask(
                fc, rr, arrays["freq_ids"], arrays["cand_ids"])
                if faults_on else None)
            member, rank, _util = mobility_mod.membership_step(
                mob, rr, arrays["req_ids"], arrays["cand_ids"],
                arrays["cand_mask"], arrays["base_util"], clevel, n_max,
                blocked=blocked)
            round_w = topology.dynamic_round_weights(member, rank, strategy)
            count = jnp.sum(member, axis=1).astype(jnp.int32)
        else:
            round_w = arrays["round_w"]

        # Phase.DELIVER (faults): which attempting links actually landed
        # an update this round, how many transmissions each burned, and
        # which delivered the round-(r-1) wire image instead.  The
        # delivered mask multiplies straight into the fedavg weights —
        # the kernel's normalized masked mean IS the graceful
        # degradation.
        if faults_on:
            delivered, attempts, stale = faults_mod.link_outcomes(
                fc, rr, arrays["freq_ids"], arrays["fcand_ids"])
            if mobility_on:
                att_mask = member           # members attempt; blocked
                #   links were already released at RENEGOTIATE
            else:
                att_mask = arrays["fsigned"] & ~faults_mod.blocked_mask(
                    fc, rr, arrays["freq_ids"], arrays["fcand_ids"])
            delivered = delivered & att_mask
            dcount = jnp.sum(delivered, axis=1).astype(jnp.int32)
            round_w = round_w * delivered.astype(round_w.dtype)
            drops_r = jnp.sum(att_mask & ~delivered, axis=1).astype(
                jnp.float32)
            retries_r = jnp.sum(jnp.where(att_mask, attempts - 1, 0),
                                axis=1).astype(jnp.float32)
            stale_r = jnp.sum(delivered & stale, axis=1).astype(jnp.float32)
            stale_sel = (delivered & stale)[:, :, None]

        # Phase.COLLECT + Phase.AGGREGATE: one batched kernel launch,
        # directly on the flat round state; under mobility the
        # membership mask IS the kernel's weight vector, and a lane whose
        # whole neighborhood churned away keeps training on its own
        # previous params.  Compressed state runs the fused
        # dequant->fedavg kernel on the wire-format buffer (the padding
        # tail dequantizes to zero and is sliced off).  Stale links
        # substitute the second wire-format-resident buffer (``prev``) —
        # the fp32 image never materializes either way.
        src = jnp.where(stale_sel, prev, contrib) if faults_on else contrib
        if compress_on:
            src_s = (jnp.where(stale_sel, prev_s, cscale) if faults_on
                     else cscale)
        if adversary_on:
            # Byzantine corruption at the transport point: AFTER the
            # stale substitution (ordering pin, protocol.Phase.DELIVER),
            # keyed on the delivering event step, applied to the wire
            # image itself (int8 codes/scales under compress — never
            # re-densified).  The mask derivation is the shared
            # counter-based closed form, so the loop oracle's per-link
            # draws match bit for bit.
            cmask = adversary_mod.corruption_mask(
                ac, rr, arrays["areq_ids"], arrays["acand_ids"])
            if compress_on:
                src, src_s = adversary_mod.corrupt_wire_batched(
                    ac, src, src_s, cmask, rr, arrays["areq_ids"],
                    arrays["acand_ids"])
                # the quantization padding tail is not part of the model
                # update: the loop oracle's dense view slices to P before
                # any robust statistic, so a noise payload's tail codes
                # must not leak into the fused q8 clip norms.  Honest
                # tails are already exact zero codes — this multiply is
                # the identity for them.
                if P < src.shape[-1]:
                    src = src * (jnp.arange(src.shape[-1])
                                 < P).astype(src.dtype)
            else:
                src = adversary_mod.corrupt_dense_batched(
                    ac, src, cmask, rr, arrays["areq_ids"],
                    arrays["acand_ids"])
        if decay_on:
            # staleness-decayed weights (gamma**lag): the stride lag of
            # each resident image under cadence, +1 for a fault-stale
            # delivery — closed form, no new carried state; masks are
            # exact 0/1 factors so applying decay after them is bitwise
            # identical to the loop engine's decay-then-mask order
            lag = (cadence_mod.image_lag(cc, rr, arrays["cad_cand_ids"])
                   if cadence_on else jnp.zeros((R, N), jnp.int32))
            if faults_on:
                lag = lag + (delivered & stale).astype(jnp.int32)
            round_w = protocol.decayed_round_weights(round_w, lag, gamma)
        if robust_on:
            # Phase.AGGREGATE hardened: the robust statistic runs on the
            # SAME masked lane buffer the fedavg kernel would see —
            # both engines call the one repro.kernels.robust entry, so
            # the clipped masks are bitwise identical by construction
            if compress_on:
                glob, clipped = robust_aggregate_q8(
                    src, src_s, round_w, method=robust,
                    use_pallas=use_pallas, interpret=interpret)
                glob = glob[:, :P]
            else:
                glob, clipped = robust_aggregate(
                    src, round_w, method=robust,
                    use_pallas=use_pallas, interpret=interpret)
        elif compress_on:
            glob = fedavg_flat_batched_q8(
                src, src_s, round_w,
                use_pallas=use_pallas, interpret=interpret)[:, :P]
        else:
            glob = fedavg_flat_batched(src, round_w,
                                       use_pallas=use_pallas,
                                       interpret=interpret)
        if adversary_on:
            # the delivered-and-corrupted trace row: a corruption draw
            # only counts when that link actually fed eq. (14)'s buffer
            agg_mask = (delivered if faults_on
                        else (member if mobility_on else arrays["asigned"]))
            corrupted_r = cmask & agg_mask
        if mobility_on or faults_on:
            # nothing fed eq. (14) this round: fall back to own params,
            # exactly like the loop engine's empty-neighborhood case
            fed_count = dcount if faults_on else count
            glob = jnp.where((fed_count > 0)[:, None], glob, last)

        # Phase.FIT (requesters personalize) + Phase.SCORE.  The round's
        # minibatch indices are derived here, on device, from the traced
        # round number — nothing was staged from the host.  Under
        # cadence the fit seed is the LANE'S OWN round clock, not the
        # global step, so a straggler lane draws the same minibatches
        # the loop oracle draws for its r-th round.
        if cadence_on:
            lane_scores = jax.vmap(
                lambda c: schedule.epoch_scores(arrays["seed0"] + c, epochs,
                                                n_pad))(clock)
            idx, w = jax.vmap(
                lambda sc, n: schedule.plan_from_scores(sc, n, batch,
                                                        steps_max))(
                lane_scores, arrays["n_own"])
        else:
            scores = schedule.epoch_scores(arrays["seed0"] + rr, epochs,
                                           n_pad)
            idx, w = jax.vmap(
                lambda n: schedule.plan_from_scores(scores, n, batch,
                                                    steps_max))(
                arrays["n_own"])
        new_flat, last_loss = jax.vmap(fit_one)(
            glob, arrays["own_x"], arrays["own_y"], idx, w)
        acc = jax.vmap(eval_one)(new_flat, arrays["test_x"], arrays["test_y"],
                                 arrays["test_mask"])

        # Phase.ACCOUNT: traced battery discharge for executed rounds;
        # under mobility (or faults) the round energy depends on how many
        # updates actually fed eq. (14) — a host-precomputed per-count
        # table, gathered with the traced count — and every fault-world
        # drop or retry burns one MORE receive window (``e_retry``).
        if mobility_on or faults_on:
            e_round = jnp.take_along_axis(
                arrays["e_tab"],
                (dcount if faults_on else count)[:, None], axis=1)[:, 0]
        else:
            e_round = arrays["e_round"]
        if faults_on:
            e_round = e_round + (drops_r + retries_r) * arrays["e_retry"]
        level_new = discharge_level(level, e_round,
                                    arrays["capacity"], arrays["eff"])
        reached = acc >= arrays["desired_accuracy"]
        low = level_new < arrays["battery_threshold"]
        if cadence_on:
            # only executing lanes pay the round, advance their clocks,
            # or may stop; ``cont`` (survives the round) still gates the
            # final-round refresh even when the clock hits the budget —
            # matching the loop oracle, whose last executed round still
            # refreshes before the budget break
            stop_code = jnp.where(exec_mask & reached,
                                  protocol.STOP_ACCURACY,
                                  jnp.where(exec_mask & ~reached & low,
                                            protocol.STOP_BATTERY,
                                            stop_code))
            level = jnp.where(exec_mask, level_new, level)
            rounds_done = rounds_done + exec_mask.astype(jnp.int32)
            last = jnp.where(exec_mask[:, None], new_flat, last)
            cont = active & ~(exec_mask & (reached | low))
            clock_new = clock + exec_mask.astype(jnp.int32)
            next_active = cont & (clock_new < max_rounds)
        else:
            stop_code = jnp.where(active & reached, protocol.STOP_ACCURACY,
                                  jnp.where(active & ~reached & low,
                                            protocol.STOP_BATTERY,
                                            stop_code))
            level = jnp.where(active, level_new, level)
            rounds_done = rounds_done + active.astype(jnp.int32)
            last = jnp.where(active[:, None], new_flat, last)
            cont = next_active = active & ~reached & ~low
            clock_new = clock

        # Contributor-side discharge (mobility): members paid the
        # transmission term this round — once per ATTEMPT under faults,
        # the sender's radio burns the same energy whether or not the
        # update lands; the refresh term only while their requester's
        # session survives.  Releases at the battery floor feed back
        # into the NEXT round's membership_step.
        if mobility_on:
            e_tx_round = (arrays["e_tx"] * attempts.astype(jnp.float32)
                          if faults_on else arrays["e_tx"])
            # under cadence only members of EXECUTING lanes paid a
            # transmission this step, and the refresh term additionally
            # requires the contributor's own tick
            refresh_on = (cont[:, None] & exec_mask[:, None] & ctick
                          if cadence_on else next_active[:, None])
            clevel = mobility_mod.contributor_discharge(
                clevel, member & exec_mask[:, None], e_tx_round,
                arrays["e_ref"], refresh_on,
                mob.contributor_capacity_j)

        # the round-(r-1) image next round's stale links will deliver:
        # snapshot the PRE-refresh round state (what this round
        # aggregated), still wire-format resident; under cadence only
        # the lanes that executed re-snapshot — a straggler's "previous
        # round" stays whatever its own last round aggregated
        if faults_on:
            if cadence_on:
                prev = jnp.where(exec_mask[:, None, None], contrib, prev)
                if compress_on:
                    prev_s = jnp.where(exec_mask[:, None, None], cscale,
                                       prev_s)
            else:
                prev, prev_s = contrib, cscale

        # Phase.REFRESH: contributors keep training (frozen once their
        # requester stops; under mobility, only CURRENT members train);
        # skipped entirely — not computed-and-masked — when no lane
        # survives into the next round.  Under compress, each lane's
        # wire payload is dequantized into its fp32 training view and
        # the result requantized back — the round state never persists
        # at full precision.
        if do_refresh:
            if cadence_on:
                # a contributor refreshes when its requester's lane
                # executed AND survives AND the contributor itself
                # ticked this step; signed-lane validity replaces the
                # dedup path's lane_valid in static worlds
                rmask = cont[:, None] & exec_mask[:, None] & ctick
                rmask = rmask & (member if mobility_on
                                 else arrays["cad_signed"])
            else:
                rmask = (next_active[:, None] & member) if mobility_on \
                    else next_active[:, None]

            def refresh(args):
                lv, lvs, c, sc = args
                # the training source: the live unique rows (dedup) or
                # every lane (mobility); compressed state is dequantized
                # into its fp32 training view here and requantized below
                if refresh_dedup:
                    src = (dequantize_flat_batched(lv, lvs)[:, :P]
                           if compress_on else lv)
                else:
                    src = (dequantize_flat_batched(
                        c.reshape(R * N, -1), sc.reshape(R * N, -1))[:, :P]
                        if compress_on else c.reshape(R * N, P))
                refreshed, _ = jax.vmap(fit_refresh)(
                    src, ref_rows, ref_idx, ref_w)
                take = jnp.broadcast_to(rmask, (R, N)).reshape(R * N, 1)
                if refresh_dedup:
                    take = take & lane_valid
                if compress_on:
                    lp = c.shape[-1]
                    q2, s2 = quantize_flat_batched(
                        jnp.pad(refreshed, ((0, 0), (0, lp - P))),
                        use_pallas=use_pallas, interpret=interpret)
                    q_lane = q2[uidx_flat] if refresh_dedup else q2
                    s_lane = s2[uidx_flat] if refresh_dedup else s2
                    return ((q2, s2) if refresh_dedup else (lv, lvs)) + (
                        jnp.where(take, q_lane, c.reshape(R * N, lp))
                        .reshape(c.shape),
                        jnp.where(take, s_lane, sc.reshape(R * N, -1))
                        .reshape(sc.shape))
                p_lane = refreshed[uidx_flat] if refresh_dedup else refreshed
                return ((refreshed if refresh_dedup else lv), lvs,
                        jnp.where(take[..., None].reshape(R, N, 1),
                                  p_lane.reshape(R, N, P), c), sc)

            live, live_s, contrib, cscale = jax.lax.cond(
                jnp.any(rmask) if cadence_on else jnp.any(next_active),
                refresh, lambda a: a, (live, live_s, contrib, cscale))

        def put(buf, row):
            return jax.lax.dynamic_update_slice_in_dim(buf, row[None], rr, 0)

        if cadence_on:
            # each executing lane lands at its OWN round row (masked
            # scatter); the (max_events,) body trace still records this
            # global step's body running
            acc_h = put_lane(acc_h, acc)
            loss_h = put_lane(loss_h, last_loss)
            bat_h = put_lane(bat_h, level)
            exec_h = put_lane(exec_h, exec_mask.astype(jnp.float32))
            clock_h = put_lane(clock_h,
                               jnp.broadcast_to(jnp.asarray(rr, jnp.int32),
                                                (R,)))
            idle_h = put_lane(idle_h, idle)
            idle = jnp.where(exec_mask, 0,
                             idle + (active & ~exec_mask).astype(jnp.int32))
            clock = clock_new
            body_h = put(body_h, jnp.float32(1.0))
            if mobility_on:
                member_h = put_lane(
                    member_h,
                    (member & exec_mask[:, None]).astype(jnp.float32))
            if faults_on:
                af = exec_mask.astype(jnp.float32)
                drop_h = put_lane(drop_h, drops_r * af)
                retry_h = put_lane(retry_h, retries_r * af)
                stale_h = put_lane(stale_h, stale_r * af)
                deliver_h = put_lane(
                    deliver_h,
                    (delivered & exec_mask[:, None]).astype(jnp.float32))
            if adversary_on:
                corrupt_h = put_lane(
                    corrupt_h,
                    (corrupted_r & exec_mask[:, None]).astype(jnp.float32))
            if robust_on:
                clip_h = put_lane(
                    clip_h,
                    (clipped & exec_mask[:, None]).astype(jnp.float32))
        else:
            acc_h = put(acc_h, acc)
            loss_h = put(loss_h, last_loss)
            bat_h = put(bat_h, level)
            exec_h = put(exec_h, active.astype(jnp.float32))
            body_h = put(body_h, jnp.float32(1.0))
            if mobility_on:
                member_h = put(member_h,
                               (member & active[:, None]).astype(jnp.float32))
            if faults_on:
                af = active.astype(jnp.float32)
                drop_h = put(drop_h, drops_r * af)
                retry_h = put(retry_h, retries_r * af)
                stale_h = put(stale_h, stale_r * af)
                deliver_h = put(deliver_h,
                                (delivered
                                 & active[:, None]).astype(jnp.float32))
            if adversary_on:
                corrupt_h = put(
                    corrupt_h,
                    (corrupted_r & active[:, None]).astype(jnp.float32))
            if robust_on:
                clip_h = put(clip_h,
                             (clipped & active[:, None]).astype(jnp.float32))
        return FleetCarry(contrib, cscale, live, live_s, last, level,
                          next_active, stop_code, rounds_done, clevel, acc_h,
                          loss_h, bat_h, exec_h, body_h, member_h, prev,
                          prev_s, drop_h, retry_h, stale_h, deliver_h,
                          clock, idle, clock_h, idle_h, corrupt_h, clip_h)

    # ---- baseline method variants (dfl / cfl) ------------------------------
    # Same scaffolding — flat (R, N, P) state, batched fedavg kernels,
    # chunked early-exit while_loop — with the phase mask deciding what
    # traces: no RENEGOTIATE, no REFRESH, no battery term in ACCOUNT,
    # and AGGREGATE moves to the client side (dfl gossip mixing) or the
    # virtual server (cfl data-size FedAvg).  Lane j of requester i is
    # client j of the loop learners' client_data list (client 0 = the
    # requester's own shard), so seeds, schedules, mixing weights, and
    # stopping reproduce CFLLearner/DFLLearner.run_config exactly.
    if method in ("dfl", "cfl"):
        assert protocol.Phase.REFRESH not in phases
        nc_pad = arrays["cx_tab"].shape[1]
        seed_stride = 31 if method == "cfl" else 77
        cidx_flat = arrays["cidx"].reshape(R * N)
        cli_n_flat = arrays["cli_n"].reshape(R * N)
        lane_j = jnp.arange(R * N, dtype=jnp.int32) % N

        def fit_client(flat_p, u, idx, w):
            """One client lane: minibatches gathered straight from the
            deduplicated shard table (never re-densified)."""
            return _fit_lane(
                flat_p,
                lambda ib: (arrays["cx_tab"][u, ib], arrays["cy_tab"][u, ib]),
                idx, w)

        def run_round(state, rr, tick=None):
            (contrib, cscale, live, live_s, last, level, active, stop_code,
             rounds_done, clevel, acc_h, loss_h, bat_h, exec_h, body_h,
             member_h, prev, prev_s, drop_h, retry_h, stale_h, deliver_h,
             clock, idle, clock_h, idle_h, corrupt_h, clip_h) = state

            # Phase.FIT at every client lane.  The loop oracles seed each
            # client fit with cfg.seed + stride*r + client_index; the
            # prefix-stable derived schedule reproduces
            # SupervisedTask.fit's minibatches bit for bit, with padded
            # lanes (n=0) collapsing to zero-weight no-op steps.
            scores = jax.vmap(
                lambda j: schedule.epoch_scores(
                    arrays["seed0"] + seed_stride * rr + j, epochs, nc_pad))(
                jnp.arange(N, dtype=jnp.int32))
            idx, w = jax.vmap(
                lambda j, n: schedule.plan_from_scores(
                    scores[j], n, batch, steps_max))(lane_j, cli_n_flat)
            if method == "cfl":
                # every client trains FROM THE SHARED GLOBAL (in `last`)
                src = jnp.broadcast_to(last[:, None], (R, N, P)).reshape(R * N, P)
            else:
                # dfl: every node trains from its own params
                src = contrib.reshape(R * N, P)
            fitted, fit_loss = jax.vmap(fit_client)(src, cidx_flat, idx, w)
            fitted = fitted.reshape(R, N, P)

            # Phase.COLLECT + Phase.AGGREGATE on the flat round state:
            # cfl is one server-side data-size-weighted kernel launch;
            # dfl applies the row-stochastic mixing matrix as one launch
            # per output row (rows sum to 1, so the kernel's normalized
            # weighted mean IS the gossip mix of apply_mixing).
            if method == "cfl":
                glob = fedavg_flat_batched(fitted, arrays["cli_w"],
                                           use_pallas=use_pallas,
                                           interpret=interpret)
                new_contrib, new_last = fitted, glob
            else:
                mixed = jnp.stack(
                    [fedavg_flat_batched(fitted, arrays["mix_w"][:, k, :],
                                         use_pallas=use_pallas,
                                         interpret=interpret)
                     for k in range(N)], axis=1)
                new_contrib, new_last = mixed, mixed[:, 0]

            # Phase.SCORE: the loop oracles evaluate the aggregated
            # global (cfl) / node 0 after mixing (dfl) on requester_test
            acc = jax.vmap(eval_one)(new_last, arrays["test_x"],
                                     arrays["test_y"], arrays["test_mask"])

            # Phase.ACCOUNT without the battery term: the baselines
            # carry no battery (energy is priced host-side per session
            # via cfl_session/dfl_session), so stopping is accuracy or
            # the round budget only.
            reached = acc >= arrays["desired_accuracy"]
            stop_code = jnp.where(active & reached, protocol.STOP_ACCURACY,
                                  stop_code)
            rounds_done = rounds_done + active.astype(jnp.int32)
            last = jnp.where(active[:, None], new_last, last)
            contrib = jnp.where(active[:, None, None], new_contrib, contrib)
            next_active = active & ~reached

            def put(buf, row):
                return jax.lax.dynamic_update_slice_in_dim(buf, row[None], rr, 0)

            acc_h = put(acc_h, acc)
            # requester-lane (client 0) last-epoch fit loss per round
            loss_h = put(loss_h, fit_loss.reshape(R, N)[:, 0])
            bat_h = put(bat_h, level)
            exec_h = put(exec_h, active.astype(jnp.float32))
            body_h = put(body_h, jnp.float32(1.0))
            return FleetCarry(contrib, cscale, live, live_s, last, level,
                              next_active, stop_code, rounds_done, clevel,
                              acc_h, loss_h, bat_h, exec_h, body_h, member_h,
                              prev, prev_s, drop_h, retry_h, stale_h,
                              deliver_h, clock, idle, clock_h, idle_h,
                              corrupt_h, clip_h)

    def maybe_round(i, carry):
        r0, state = carry
        rr = r0 + i
        if not cadence_on:
            state = jax.lax.cond((rr < max_rounds) & jnp.any(state.active),
                                 lambda s: run_round(s, rr), lambda s: s,
                                 state)
            return r0, state
        # cadence: rr is a GLOBAL EVENT STEP.  Which lanes tick is the
        # shared counter-based derivation (battery-paced on the carried
        # levels); a step where nobody ticks only advances the idle
        # counters — the fit/aggregate compute is skipped, not
        # computed-and-discarded, same as the early-exit machinery.
        tick = cadence_mod.tick_mask(cc, rr, arrays["cad_req_ids"],
                                     level=state.level) & state.active

        def step(s):
            return jax.lax.cond(
                jnp.any(tick),
                lambda t: run_round(t, rr, tick),
                lambda t: t._replace(
                    idle=t.idle + (t.active & ~tick).astype(jnp.int32)),
                s)

        state = jax.lax.cond((rr < max_events) & jnp.any(state.active),
                             step, lambda s: s, state)
        return r0, state

    return maybe_round


def _init_state(method, mob, do_refresh, compress, max_rounds, max_events,
                n_params, fc, cc, ac, robust, contrib_flat, arrays):
    """The :class:`FleetCarry` at round 0 — built HOST-SIDE (eagerly) so
    the checkpoint path can serialize/restore exactly this pytree at
    chunk boundaries (field-named ``.npz`` keys, dtype-strict); the
    compiled programs receive it donated.

    Token (1, ...) buffers stand in for state a variant doesn't carry —
    including the per-lane cadence clock fields when ``cc`` is None.
    """
    R, N = contrib_flat.shape[:2]
    P = n_params
    phases = protocol.method_phases(method)
    mobility_on = (mob is not None) and (protocol.Phase.RENEGOTIATE in phases)
    faults_on = (fc is not None) and (protocol.Phase.DELIVER in phases)
    compress_on = compress == "int8"
    cadence_on = cc is not None
    refresh_dedup = do_refresh and not mobility_on and not cadence_on
    if method == "cfl":
        # the shared global model every client fits from each round
        last0 = jnp.broadcast_to(arrays["init_flat"], (R, P)) + 0.0
    elif method == "dfl":
        # node 0's (the requester's) initial params
        last0 = contrib_flat[:, 0]
    else:
        # mobility and fault worlds can aggregate NOTHING in a round
        # (empty neighborhood / all links failed) — the fallback chain
        # must bottom out at the requester's own init, like the loop
        last0 = (jnp.broadcast_to(arrays["init_flat"], (R, P)) + 0.0
                 if (mobility_on or faults_on)
                 else jnp.zeros((R, P), jnp.float32))
    # the carry is DONATED to the programs while ``arrays`` is not — every
    # staged buffer that seeds a carry element is copied (`+ 0`) so no
    # donated input aliases a live one
    clevel0 = (arrays["clevel0"] + 0.0 if mobility_on
               else jnp.zeros((R, N), jnp.float32))
    # per-tile scales travel in the carried state (refresh rewrites
    # them); fp32 runs carry a token buffer
    cscale0 = (arrays["c_scales"] + 0.0 if compress_on
               else jnp.zeros((1, 1, 1), jnp.float32))
    # the dedup'd refresh trajectories (V unique rows), wire-format under
    # compress; token buffers when per-lane refresh (mobility) runs
    if refresh_dedup:
        live0 = (arrays["live_q0"] + 0 if compress_on
                 else arrays["live0"] + 0.0)
        live_s0 = (arrays["live_s0"] + 0.0 if compress_on
                   else jnp.zeros((1, 1), jnp.float32))
    else:
        live0 = jnp.zeros((1, 1), jnp.float32)
        live_s0 = jnp.zeros((1, 1), jnp.float32)
    # the stale-delivery snapshot starts as the handshake staging itself
    # (a round-0 stale hit is a no-op by construction, in both engines)
    if faults_on:
        prev0 = contrib_flat + 0
        prev_s0 = cscale0 + 0.0 if compress_on else jnp.zeros(
            (1, 1, 1), jnp.float32)
    else:
        prev0 = jnp.zeros((1, 1, 1), jnp.float32)
        prev_s0 = jnp.zeros((1, 1, 1), jnp.float32)
    return FleetCarry(
        contrib=contrib_flat,
        cscale=cscale0,
        live=live0,
        live_s=live_s0,
        last=last0,
        level=arrays["level0"] + 0.0,
        active=jnp.ones((R,), bool),
        stop_code=jnp.full((R,), protocol.STOP_MAX_ROUNDS, jnp.int32),
        rounds_done=jnp.zeros((R,), jnp.int32),
        clevel=clevel0,
        acc_h=jnp.zeros((max_rounds, R), jnp.float32),
        loss_h=jnp.zeros((max_rounds, R), jnp.float32),
        bat_h=jnp.zeros((max_rounds, R), jnp.float32),
        exec_h=jnp.zeros((max_rounds, R), jnp.float32),
        # the body trace is per EVENT STEP (== per round in lockstep)
        body_h=jnp.zeros((max_events,), jnp.float32),
        # membership trace; static-world runs carry a token buffer
        # (the mask would just be round_w > 0 replicated per round)
        member_h=jnp.zeros((max_rounds, R, N) if mobility_on else (1, 1, 1),
                           jnp.float32),
        prev=prev0,
        prev_s=prev_s0,
        drop_h=jnp.zeros((max_rounds, R) if faults_on else (1, 1),
                         jnp.float32),
        retry_h=jnp.zeros((max_rounds, R) if faults_on else (1, 1),
                          jnp.float32),
        stale_h=jnp.zeros((max_rounds, R) if faults_on else (1, 1),
                          jnp.float32),
        deliver_h=jnp.zeros((max_rounds, R, N) if faults_on else (1, 1, 1),
                            jnp.float32),
        clock=jnp.zeros((R,) if cadence_on else (1,), jnp.int32),
        idle=jnp.zeros((R,) if cadence_on else (1,), jnp.int32),
        clock_h=jnp.zeros((max_rounds, R) if cadence_on else (1, 1),
                          jnp.int32),
        idle_h=jnp.zeros((max_rounds, R) if cadence_on else (1, 1),
                         jnp.int32),
        corrupt_h=jnp.zeros((max_rounds, R, N) if ac is not None
                            else (1, 1, 1), jnp.float32),
        clip_h=jnp.zeros((max_rounds, R, N) if robust != "none"
                         else (1, 1, 1), jnp.float32))


_FLEET_STATICS = ("task", "use_pallas", "interpret", "do_refresh", "chunk",
                  "max_rounds", "max_events", "epochs", "batch", "steps_max",
                  "ref_epochs", "ref_steps", "spec", "mob", "n_max",
                  "strategy", "compress", "n_params", "method", "fc", "cc",
                  "ac", "robust", "gamma", "n_req", "n_lanes")


@functools.partial(jax.jit, static_argnames=_FLEET_STATICS,
                   donate_argnames=("state",))
def _fleet_program(task, use_pallas, interpret, do_refresh, chunk, max_rounds,
                   max_events, epochs, batch, steps_max, ref_epochs,
                   ref_steps, spec, mob, n_max, strategy, compress, n_params,
                   method, fc, cc, ac, robust, gamma, n_req, n_lanes, state,
                   arrays):
    """The whole fleet's Algorithm 1 as one compiled program.

    Module-level so the jit cache is shared across ``run_fleet`` calls:
    re-running with the same ``task`` (id-hashed static) and the same
    array shapes — e.g. parametrized parity tests sweeping strategies,
    encryption, or stopping thresholds, all of which are traced inputs
    (``round_w``, ``e_round``, ``desired_accuracy``...) — reuses the
    compiled executable instead of re-tracing per call.

    ``state`` is the donated :class:`FleetCarry` from
    :func:`_init_state`; its ``contrib`` field is the flat round state:
    (R, N, P) fp32, or — under ``compress="int8"`` — the (R, N, Lp) int8
    wire payload whose per-tile fp32 scales travel as ``cscale``.
    ``n_params`` is the true flat parameter count P (<= Lp, the
    tile-padded payload length).  ``spec`` is the static
    :func:`repro.utils.tree.tree_ravel` spec that recovers per-device
    parameter pytrees from (P,) lane views.  ``mob`` is the static
    :class:`repro.core.mobility.MobilityConfig` (None = static
    neighborhood); ``fc`` the static
    :class:`repro.core.faults.FaultConfig` (None = perfect links).

    ``method`` selects the traced protocol variant ("enfed", "dfl",
    "cfl" — vocabulary in :func:`repro.core.protocol.method_phases`):
    the per-method phase mask decides at trace time which protocol
    steps are live.  The baseline variants share this program's flat
    round state, batched fedavg kernels, and chunked early-exit loop;
    their round bodies are the loop learners' algorithms phase for
    phase.
    """
    maybe_round = _make_round_fn(
        task, use_pallas, interpret, do_refresh, max_rounds, max_events,
        epochs, batch, steps_max, ref_epochs, ref_steps, spec, mob, n_max,
        strategy, compress, n_params, method, fc, cc, ac, robust, gamma,
        n_req, n_lanes, arrays)

    def while_cond(carry):
        r0, state = carry
        return (r0 < max_events) & jnp.any(state.active)

    def while_body(carry):
        r0, state = carry
        _, state = jax.lax.fori_loop(0, chunk, maybe_round, (r0, state))
        return r0 + chunk, state

    _, state = jax.lax.while_loop(while_cond, while_body,
                                  (jnp.int32(0), state))
    return state


@functools.partial(jax.jit, static_argnames=_FLEET_STATICS,
                   donate_argnames=("state",))
def _fleet_chunk_program(task, use_pallas, interpret, do_refresh, chunk,
                         max_rounds, max_events, epochs, batch, steps_max,
                         ref_epochs, ref_steps, spec, mob, n_max, strategy,
                         compress, n_params, method, fc, cc, ac, robust,
                         gamma, n_req, n_lanes, r0, state, arrays):
    """ONE ``chunk`` of fleet rounds (event steps under cadence), for
    the host-driven checkpoint loop: ``run_fleet(checkpoint_dir=...)``
    calls this per chunk, serializing the returned carry at checkpoint
    boundaries (``repro.checkpoint``).  Traces the SAME ``maybe_round``
    as :func:`_fleet_program` — only the outer while_loop moves to the
    host, so a resumed run replays bit-identical round bodies."""
    maybe_round = _make_round_fn(
        task, use_pallas, interpret, do_refresh, max_rounds, max_events,
        epochs, batch, steps_max, ref_epochs, ref_steps, spec, mob, n_max,
        strategy, compress, n_params, method, fc, cc, ac, robust, gamma,
        n_req, n_lanes, arrays)
    _, state = jax.lax.fori_loop(0, chunk, maybe_round, (r0, state))
    return state


def _jit_cache_size(jit_fn) -> Optional[int]:
    """Compiled-executable count of a jit wrapper, or None where the
    (private, version-dependent) introspection is unavailable."""
    try:
        return int(jit_fn._cache_size())
    except Exception:
        return None


def _note_cache_miss(span, jit_fn, before: Optional[int]) -> None:
    """Annotate a program/chunk span with whether its call compiled
    (cache grew) or reused a warm executable — the compile-vs-warm split
    the bench's wall-clock breakdown is built from."""
    after = _jit_cache_size(jit_fn)
    if before is not None and after is not None:
        span.attrs["cache_miss"] = bool(after > before)


def run_fleet(task, requesters: Sequence[RequesterSpec],
              cfg: Optional[EnFedConfig] = None,
              cost_model: Optional[CostModel] = None,
              use_pallas: bool = True,
              interpret: Optional[bool] = None,
              round_chunk: int = 4,
              method: str = "enfed",
              dfl_topology: str = "mesh",
              checkpoint_dir: Optional[str] = None,
              checkpoint_every: int = 0,
              resume_from: Optional[str] = None,
              timeline: Optional[Timeline] = None,
              trace=None) -> FleetResult:
    """Run ``len(requesters)`` concurrent EnFed sessions as one jit program.

    Note: prefer the :mod:`repro.api` facade
    (``ExecutionSpec(engine="fleet", ...)``) — this function remains the
    engine entrypoint it delegates to.  ``cfg=None`` constructs a fresh
    default config per call (a ``cfg=EnFedConfig()`` default would be one
    import-time mutable instance shared by every caller).

    ``interpret`` selects Pallas interpret mode for the aggregation
    kernel (``None`` = compiled on TPU, interpreted on CPU — see
    ``repro.kernels.common.resolve_interpret``).  ``round_chunk`` is the
    early-exit granularity: the compiled round loop re-checks "is any
    session still active?" every ``round_chunk`` rounds.

    With ``cfg.mobility`` set, contributor lanes hold each requester's
    candidate pool and membership churns on device — requester lane i
    moves as device ``cfg.mobility.requester_id + i`` in the shared
    kinematics space, so a 1-lane fleet reproduces
    ``EnFedSession.run()`` under the same :class:`MobilityConfig`
    exactly.

    With ``cfg.compress="int8"`` the contributor round state is staged,
    carried, aggregated (fused dequant->fedavg kernel), and refreshed
    entirely in wire format — int8 payload + per-tile fp32 scales — so
    ``staged_param_bytes`` and ``device_round_state_bytes`` drop ~4x on
    tile-amortizing models, and ``CostModel`` prices the compressed
    ``model_bytes`` in every eq. (4)-(7) term.  ``compress="auto"``
    resolves to int8 or fp32 at the tile-padding crossover
    (:func:`repro.kernels.quantize.ops.resolve_compress`) before any of
    that staging happens.

    ``method`` selects the traced protocol variant: ``"enfed"``
    (default, the full Algorithm 1) or the paper's baselines ``"dfl"``
    (gossip mixing over ``dfl_topology`` — "mesh" or "ring") and
    ``"cfl"`` (server-side FedAvg), which run as lanes of the same
    compiled program with the per-method phase mask
    (``protocol.method_phases``) deciding which steps trace.  Baseline
    lanes are the loop learners' client lists (client 0 = the
    requester's own shard, then every in-range neighbor with data);
    their ``SessionResult`` views carry ``battery=None`` and
    ``cfl_session``/``dfl_session`` energy reports, exactly like
    ``repro.api``'s loop-engine baselines.

    With ``cfg.faults`` set, ``Phase.DELIVER`` runs inside the program:
    per-link drop/retry/stale outcomes are derived from the traced round
    number (``repro.core.faults`` — the exact hash chain the loop engine
    evaluates host-side), undelivered links are zeroed out of the fedavg
    weight mask, stale links aggregate the carried round-(r-1) wire
    image, and every drop or retry prices one extra receive window
    through ``CostModel.retry_energy``.

    ``checkpoint_dir`` switches the round loop to a host-driven chunk
    loop that serializes the FULL flat loop carry — wire-format round
    state, batteries, masks, round clocks — via :mod:`repro.checkpoint`
    every ``checkpoint_every`` rounds (default: every ``round_chunk``;
    rounded up to a chunk multiple).  ``resume_from`` restores the
    latest checkpoint in a directory and continues: a run killed at a
    checkpoint boundary and resumed is bit-identical to the
    uninterrupted chunked run (same traced round bodies — only the
    outer while_loop moves to the host).  Checkpointing is an
    enfed-only knob (the baselines' loop oracles have no resumable
    state contract); passing it with ``method != "enfed"`` raises.
    """
    from repro.kernels.common import resolve_interpret

    cfg = cfg if cfg is not None else EnFedConfig()
    cost = cost_model or CostModel()
    protocol.method_phases(method)     # validate the variant name
    R = len(requesters)
    if R == 0:
        raise ValueError("empty fleet")
    if round_chunk < 1:
        raise ValueError(f"round_chunk must be >= 1 (got {round_chunk})")
    if checkpoint_every < 0:
        raise ValueError(
            f"checkpoint_every must be >= 0 (got {checkpoint_every})")
    if (checkpoint_dir or resume_from) and method != "enfed":
        raise ValueError(
            f"checkpointing is enfed-only (got method={method!r})")
    if getattr(cfg, "cadence", None) is not None and method != "enfed":
        raise ValueError(
            f"cadence is enfed-only (got method={method!r}) — the "
            "baselines' loop oracles tick on one global round clock")
    if method != "enfed" and (
            getattr(cfg, "adversary", None) is not None
            or getattr(cfg, "robust", "none") != "none"
            or float(getattr(cfg, "staleness_gamma", 1.0)) != 1.0):
        raise ValueError(
            f"adversary/robust/staleness_gamma are enfed-only (got "
            f"method={method!r}) — the baselines' loop oracles define "
            "their aggregation semantics without Phase.DELIVER")
    # observability: spans are host-side wall clocks only and never feed
    # back into the program (the telemetry house rule); ``trace`` is the
    # opt-in TraceConfig selecting the profiler hook / hlo_stats
    tl = timeline if timeline is not None else Timeline()
    if method != "enfed":
        return _run_fleet_baseline(task, requesters, cfg, cost, method,
                                   dfl_topology, use_pallas, interpret,
                                   round_chunk, timeline=tl, trace=trace)
    mob = cfg.mobility
    fc = cfg.faults
    cc = getattr(cfg, "cadence", None)
    # the global event-step budget the program loops over; lockstep is
    # the special case max_events == max_rounds (one step per round)
    max_events = (cadence_mod.events_budget(cc, cfg.max_rounds)
                  if cc is not None else cfg.max_rounds)
    _sp_stage = tl.begin("stage")

    # ---- Phase.HANDSHAKE (host-side, static) ------------------------------
    # Static world: sign utility-ranked contracts once.  Mobility: fix the
    # candidate POOL (agreeing devices, stable device order — the lane
    # order of both engines); membership is re-negotiated per round on
    # device by mobility.membership_step.
    if mob is None:
        contracts, contract_mask = sign_contracts_fleet(
            [spec.neighborhood for spec in requesters],
            cfg.offered_incentive, cfg.n_max)
        lane_devs = contracts
    else:
        lane_devs = [candidate_pool(spec.neighborhood, cfg.offered_incentive)
                     for spec in requesters]
    for i, cs in enumerate(lane_devs):
        if not cs:
            raise RuntimeError(
                f"requester {i}: no nearby device agreed to the incentive (N_d < 1)")
    N = (contract_mask.shape[1] if mob is None
         else max(len(cs) for cs in lane_devs))

    if mob is None:
        # per-round aggregation weights = contract mask x strategy round mask
        round_w = np.zeros((R, N), np.float32)
        for i, cs in enumerate(lane_devs):
            round_w[i, :len(cs)] = protocol.round_weights(len(cs), cfg.strategy)
    else:
        # membership (and therefore the weight vector) is traced; stage
        # the static candidate descriptors instead
        req_ids = np.array([mob.requester_id + i for i in range(R)], np.int32)
        cand_ids = np.zeros((R, N), np.int32)
        cand_mask = np.zeros((R, N), bool)
        base_util = np.zeros((R, N), np.float32)
        clevel0 = np.zeros((R, N), np.float32)
        for i, cs in enumerate(lane_devs):
            n_i = len(cs)
            max_data = max(d.data_size for d in cs)
            cand_ids[i, :n_i] = [d.device_id for d in cs]
            cand_mask[i, :n_i] = True
            clevel0[i, :n_i] = [d.battery_level for d in cs]
            # one vectorized call per requester, the same arithmetic the
            # loop engine's _run_mobility stages
            base_util[i, :n_i] = np.asarray(mobility_mod.static_utility_term(
                np.array([d.model_staleness for d in cs], np.float32),
                np.array([d.data_size for d in cs], np.float32),
                np.float32(max_data)), np.float32)

    # ---- contributor state / data stacks ----------------------------------
    # Shared shards are deduplicated: each unique (device, shard) pair is
    # staged once into a table, lanes carry gather indices.  At R=512
    # with one shared contributor population this removes the dominant
    # host->device transfer (the ROADMAP's cx item).
    template = requesters[0].contributor_states[
        lane_devs[0][0].device_id]["params"]
    contrib_params = []
    shard_rows: dict = {}
    shard_x, shard_y = [], []
    cidx = np.zeros((R, N), np.int32)
    shard_len = np.zeros((R, N), np.int32)
    for i, (spec, cs) in enumerate(zip(requesters, lane_devs)):
        row_p = []
        for j, c in enumerate(cs):
            st = spec.contributor_states[c.device_id]
            row_p.append(st["params"])
            xa = np.ascontiguousarray(st["data"][0], np.float32)
            ya = np.ascontiguousarray(st["data"][1], np.int32)
            # content identity, not object identity: deep-copied
            # contributor_states (the common RequesterSpec pattern) must
            # still collapse to one staged shard per device.  Full
            # 128-bit digests, not Python hash(): a 64-bit hash over a
            # long-lived population could silently alias two distinct
            # shards onto one staged row
            key = (c.device_id, xa.shape,
                   hashlib.blake2b(xa.tobytes(), digest_size=16).digest(),
                   hashlib.blake2b(ya.tobytes(), digest_size=16).digest())
            row = shard_rows.get(key)
            if row is None:
                row = len(shard_x)
                shard_rows[key] = row
                shard_x.append(xa)
                shard_y.append(ya)
            cidx[i, j] = row
            shard_len[i, j] = len(shard_x[row])
        contrib_params.append(row_p)

    n_c_max = max(len(x) for x in shard_x)
    U = len(shard_x)
    cx_tab = np.zeros((U, n_c_max) + shard_x[0].shape[1:], np.float32)
    cy_tab = np.zeros((U, n_c_max), np.int32)
    for u, (x, y) in enumerate(zip(shard_x, shard_y)):
        cx_tab[u, :len(x)] = x
        cy_tab[u, :len(y)] = y
    padded_rows = [row + [None] * (N - len(row)) for row in contrib_params]
    contrib_stack = _stack_trees(
        [_stack_trees(row, template) for row in padded_rows])
    # the flat-parameter round state: raveled ONCE here, donated to the
    # program, carried flat through every round.  Under compress="int8"
    # it is quantized ONCE here too — the program is staged (and runs)
    # entirely on the wire-format payload + per-tile scales.
    contrib_flat, ravel_spec = tree_ravel(contrib_stack, batch_ndim=2)
    P = contrib_flat.shape[-1]
    # "auto" resolves to a concrete wire format here, from the flat
    # model size — the same resolution EnFedSession and the cost model
    # apply, so all paths land on one side of the crossover together
    wire_compress = resolve_compress(cfg.compress, P)
    # fp32 lane rows, kept host-side for the refresh-dedup key/live rows
    # (the donated buffer below may be quantized); cadence runs keep the
    # per-lane refresh path — contributor ticks desynchronize lanes
    contrib_np = (np.asarray(contrib_flat)
                  if (cfg.contributor_refresh_epochs > 0 and mob is None
                      and cc is None)
                  else None)
    c_scales = None
    if wire_compress == "int8":
        lp = padded_len(P)
        with tl.span("quantize_pack", what="round_state"):
            q0, s0 = quantize_flat_batched(
                jnp.pad(contrib_flat, ((0, 0), (0, 0), (0, lp - P)))
                .reshape(R * N, lp),
                use_pallas=use_pallas, interpret=interpret)
            jax.block_until_ready(q0)
        contrib_flat = q0.reshape(R, N, lp)
        c_scales = s0.reshape(R, N, -1)
        staged_param_bytes = int(contrib_flat.nbytes + c_scales.nbytes)
    else:
        staged_param_bytes = int(contrib_flat.nbytes)
    device_round_state_bytes = staged_param_bytes

    # ---- requester data + derived-schedule metadata -----------------------
    own_x, _ = _pad_stack([np.asarray(s.own_train[0], np.float32) for s in requesters],
                          max(len(s.own_train[0]) for s in requesters))
    own_y, _ = _pad_stack([np.asarray(s.own_train[1], np.int32) for s in requesters],
                          own_x.shape[1])
    test_x, test_mask = _pad_stack([np.asarray(s.own_test[0], np.float32) for s in requesters],
                                   max(len(s.own_test[0]) for s in requesters))
    test_y, _ = _pad_stack([np.asarray(s.own_test[1], np.int32) for s in requesters],
                           test_x.shape[1])

    n_own = np.array([len(s.own_train[0]) for s in requesters], np.int32)
    steps_max = max(schedule.fit_steps(int(n), cfg.batch_size) for n in n_own)

    ref_epochs = max(cfg.contributor_refresh_epochs, 0)
    ref_steps = max((schedule.fit_steps(int(n), cfg.batch_size)
                     for n in shard_len[shard_len > 0]), default=1)
    ref_seeds = np.zeros((R, N), np.int32)
    for i, cs in enumerate(lane_devs):
        for j, c in enumerate(cs):
            ref_seeds[i, j] = cfg.seed + c.device_id

    # ---- Phase.ACCOUNT constants (static per requester) -------------------
    num_params = tree_size(template)
    model_bytes = update_wire_bytes(num_params, encrypt=cfg.encrypt,
                                    compress=wire_compress,
                                    raw_bytes=tree_bytes(template))
    batteries = [s.battery or BatteryState() for s in requesters]
    if mob is None and fc is None:
        e_round = np.array([cost.round_energy(
            n_contrib=len(cs), num_params=num_params, model_bytes=model_bytes,
            num_samples=len(spec.own_train[0]), epochs=cfg.epochs,
            n_devices=len(spec.neighborhood), encrypt=cfg.encrypt)
            for spec, cs in zip(requesters, lane_devs)], np.float32)
    elif mob is None:
        # static world + faults: the DELIVERED count is traced, so the
        # round energy becomes the same per-count lookup mobility uses
        # (table entries are round_energy(n_contrib=j) — independent of
        # the table width, so they match the loop engine's per-requester
        # tables entry for entry)
        e_tab = np.array([cost.round_energy_table(
            max_contrib=N, num_params=num_params, model_bytes=model_bytes,
            num_samples=len(spec.own_train[0]), epochs=cfg.epochs,
            n_devices=len(spec.neighborhood), encrypt=cfg.encrypt)
            for spec in requesters], np.float32)
        init_params = task.init(seed=cfg.seed)
        init_flat, _ = tree_ravel(init_params)
    else:
        # member count is traced -> per-count lookup table, plus the
        # contributor-side per-round energy split (tx / refresh)
        e_tab = np.array([cost.round_energy_table(
            max_contrib=N, num_params=num_params, model_bytes=model_bytes,
            num_samples=len(spec.own_train[0]), epochs=cfg.epochs,
            n_devices=len(spec.neighborhood), encrypt=cfg.encrypt)
            for spec in requesters], np.float32)
        e_tx = np.zeros((R, N), np.float32)
        e_ref = np.zeros((R, N), np.float32)
        for i, cs in enumerate(lane_devs):
            for j in range(len(cs)):
                e_tx[i, j], e_ref[i, j] = cost.contributor_round_energy(
                    num_params=num_params, model_bytes=model_bytes,
                    num_samples=int(shard_len[i, j]),
                    refresh_epochs=cfg.contributor_refresh_epochs,
                    encrypt=cfg.encrypt)
        init_params = task.init(seed=cfg.seed)
        init_flat, _ = tree_ravel(init_params)
    capacity = np.array([b.capacity_j for b in batteries], np.float32)
    level0 = np.array([b.level for b in batteries], np.float32)
    eff = np.array([load_efficiency(cost.device.p_train, b.high_load_penalty,
                                    b.high_load_threshold_w) for b in batteries],
                   np.float32)

    # ---- the compiled program ---------------------------------------------
    arrays = dict(
        level0=jnp.asarray(level0), own_x=jnp.asarray(own_x),
        own_y=jnp.asarray(own_y), test_x=jnp.asarray(test_x),
        test_y=jnp.asarray(test_y), test_mask=jnp.asarray(test_mask),
        n_own=jnp.asarray(n_own), seed0=jnp.int32(cfg.seed),
        capacity=jnp.asarray(capacity), eff=jnp.asarray(eff),
        desired_accuracy=jnp.float32(cfg.desired_accuracy),
        battery_threshold=jnp.float32(cfg.battery_threshold))
    if mob is None:
        arrays.update(round_w=jnp.asarray(round_w))
        if fc is None:
            arrays.update(e_round=jnp.asarray(e_round))
        else:
            arrays.update(e_tab=jnp.asarray(e_tab),
                          init_flat=jnp.asarray(init_flat))
    else:
        arrays.update(req_ids=jnp.asarray(req_ids),
                      cand_ids=jnp.asarray(cand_ids),
                      cand_mask=jnp.asarray(cand_mask),
                      base_util=jnp.asarray(base_util),
                      clevel0=jnp.asarray(clevel0),
                      e_tab=jnp.asarray(e_tab), e_tx=jnp.asarray(e_tx),
                      e_ref=jnp.asarray(e_ref),
                      init_flat=jnp.asarray(init_flat))
    if c_scales is not None:
        arrays.update(c_scales=c_scales)
    if fc is not None:
        # Phase.DELIVER staging: lane i rolls fault dice as requester
        # ``fc.requester_id + i`` (the api loop path hands requester i a
        # config with exactly that id, so engines agree per requester);
        # links are the signed lanes (static) or the candidate pool
        # (mobility — membership already masks attempts per round).
        freq_ids = np.array([fc.requester_id + i for i in range(R)], np.int32)
        fcand_ids = np.zeros((R, N), np.int32)
        fsigned = np.zeros((R, N), bool)
        for i, cs in enumerate(lane_devs):
            fcand_ids[i, :len(cs)] = [d.device_id for d in cs]
            fsigned[i, :len(cs)] = True
        e_rx_retry, _, t_retry = cost.retry_energy(
            model_bytes=model_bytes, encrypt=cfg.encrypt)
        arrays.update(freq_ids=jnp.asarray(freq_ids),
                      fcand_ids=jnp.asarray(fcand_ids),
                      e_retry=jnp.float32(e_rx_retry))
        if mob is None:
            arrays.update(fsigned=jnp.asarray(fsigned))
    if cc is not None:
        # cadence staging: lane i's requester ticks as device
        # ``cc.requester_id + i`` (the api loop path hands requester i a
        # config with exactly that id); contributors tick by their REAL
        # device ids — a device's cadence is a property of the device,
        # not of the session observing it.  ``cad_signed`` masks padded
        # contributor slots out of the refresh gate in static worlds.
        cad_req_ids = np.array([cc.requester_id + i for i in range(R)],
                               np.int32)
        cad_cand_ids = np.zeros((R, N), np.int32)
        cad_signed = np.zeros((R, N), bool)
        for i, cs in enumerate(lane_devs):
            cad_cand_ids[i, :len(cs)] = [d.device_id for d in cs]
            cad_signed[i, :len(cs)] = True
        arrays.update(cad_req_ids=jnp.asarray(cad_req_ids),
                      cad_cand_ids=jnp.asarray(cad_cand_ids),
                      cad_signed=jnp.asarray(cad_signed))
    ac = getattr(cfg, "adversary", None)
    if ac is not None:
        # adversary staging: lane i rolls corruption dice as requester
        # ``ac.requester_id + i`` (the api loop path hands requester i a
        # config with exactly that id); links key on the contributors'
        # REAL device ids — which devices are Byzantine is a property of
        # the world, observed identically by every session.  ``asigned``
        # masks padded lanes out of the corrupted trace rows.
        areq_ids = np.array([ac.requester_id + i for i in range(R)], np.int32)
        acand_ids = np.zeros((R, N), np.int32)
        asigned = np.zeros((R, N), bool)
        for i, cs in enumerate(lane_devs):
            acand_ids[i, :len(cs)] = [d.device_id for d in cs]
            asigned[i, :len(cs)] = True
        arrays.update(areq_ids=jnp.asarray(areq_ids),
                      acand_ids=jnp.asarray(acand_ids),
                      asigned=jnp.asarray(asigned))
    shard_bytes = shard_bytes_dense = 0
    gather_bytes = gather_bytes_dense = 0
    index_bytes = int(n_own.nbytes + 4)
    if ref_epochs > 0:
        arrays.update(cx_tab=jnp.asarray(cx_tab), cy_tab=jnp.asarray(cy_tab))
        if mob is None and cc is None:
            # refresh-COMPUTE dedup: lanes subscribed to the same
            # (device, shard content, staged params) follow identical
            # trajectories in a static world, so one live row per unique
            # subscription is trained and scattered to its lanes
            ref_map: dict = {}
            ref_uidx = np.zeros((R, N), np.int32)
            lane_valid = np.zeros((R, N), bool)
            u_cidx, u_n, u_seed, rep_i, rep_j = [], [], [], [], []
            for i, cs in enumerate(lane_devs):
                for j, c in enumerate(cs):
                    key = (c.device_id, int(cidx[i, j]),
                           hashlib.blake2b(contrib_np[i, j].tobytes(),
                                           digest_size=16).digest())
                    v = ref_map.get(key)
                    if v is None:
                        v = len(u_cidx)
                        ref_map[key] = v
                        u_cidx.append(int(cidx[i, j]))
                        u_n.append(int(shard_len[i, j]))
                        u_seed.append(cfg.seed + c.device_id)
                        rep_i.append(i)
                        rep_j.append(j)
                    ref_uidx[i, j] = v
                    lane_valid[i, j] = True
            V = len(u_cidx)
            live0 = jnp.asarray(contrib_np[rep_i, rep_j])   # (V, P) fp32
            arrays.update(u_cidx=jnp.asarray(np.array(u_cidx, np.int32)),
                          u_n=jnp.asarray(np.array(u_n, np.int32)),
                          u_seed=jnp.asarray(np.array(u_seed, np.int32)),
                          ref_uidx=jnp.asarray(ref_uidx),
                          lane_valid=jnp.asarray(lane_valid))
            if wire_compress == "int8":
                lp = padded_len(P)
                with tl.span("quantize_pack", what="live_rows"):
                    lq, ls = quantize_flat_batched(
                        jnp.pad(live0, ((0, 0), (0, lp - P))),
                        use_pallas=use_pallas, interpret=interpret)
                    jax.block_until_ready(lq)
                arrays.update(live_q0=lq, live_s0=ls)
            else:
                arrays.update(live0=live0)
            ref_lanes = V
            idx_meta = int(ref_uidx.nbytes + 4 * 3 * V)
        else:
            arrays.update(cidx=jnp.asarray(cidx),
                          ref_seeds=jnp.asarray(ref_seeds),
                          ref_n=jnp.asarray(shard_len))
            ref_lanes = R * N
            idx_meta = int(ref_seeds.nbytes + shard_len.nbytes)
        # shard-table accounting: gather indices live with the shards
        # (cidx/ref_uidx only count here); schedule metadata is separate
        shard_bytes = int(cx_tab.nbytes + cy_tab.nbytes + cidx.nbytes)
        shard_bytes_dense = int(R * N * (cx_tab.nbytes + cy_tab.nbytes)
                                / max(U, 1))
        index_bytes += idx_meta
        # refresh device-memory accounting: the per-step (ref_lanes, B)
        # table gather vs the old lane-dense (R*N, n_c, F) block
        sample_bytes = int((cx_tab.nbytes + cy_tab.nbytes)
                           // max(U * n_c_max, 1))
        gather_bytes = int(ref_lanes * cfg.batch_size * sample_bytes)
        gather_bytes_dense = shard_bytes_dense
    staged = [contrib_flat] + [v for v in arrays.values() if hasattr(v, "nbytes")]
    staged_bytes = int(sum(int(v.nbytes) for v in staged))

    robust = getattr(cfg, "robust", "none")
    gamma = float(getattr(cfg, "staleness_gamma", 1.0))
    statics = (task, use_pallas, resolve_interpret(interpret), ref_epochs > 0,
               int(round_chunk), cfg.max_rounds, max_events, cfg.epochs,
               cfg.batch_size, steps_max, ref_epochs, ref_steps, ravel_spec,
               mob, cfg.n_max, cfg.strategy if mob is not None else None,
               wire_compress, P, "enfed", fc, cc, ac, robust, gamma, R, N)
    state = _init_state("enfed", mob, ref_epochs > 0, wire_compress,
                        cfg.max_rounds, max_events, P, fc, cc, ac, robust,
                        contrib_flat, arrays)
    tl.finish(_sp_stage)
    hlo = None
    if trace is not None and getattr(trace, "hlo_stats", False):
        # AOT lower+compile BEFORE the donating call: lowering only reads
        # abstract shapes, so the donated carry buffers stay intact
        with tl.span("hlo_stats"):
            hlo = jit_hlo_stats(_fleet_program, *statics, state, arrays) or None
    profiler_dir = getattr(trace, "jax_profiler_dir", None) if trace else None
    if checkpoint_dir or resume_from:
        # host-driven chunk loop: same traced round bodies, the outer
        # while moves to the host so the carry can be serialized (and a
        # killed run restarted) at chunk boundaries
        from repro import checkpoint as ckpt_mod
        chunk = int(round_chunk)
        every = checkpoint_every if checkpoint_every > 0 else chunk
        every = ((every + chunk - 1) // chunk) * chunk   # chunk multiple
        r0 = 0
        if resume_from:
            with tl.span("checkpoint_restore"):
                template = {"r0": np.int64(0),
                            "state": jax.tree_util.tree_map(np.asarray, state)}
                pay, _step = ckpt_mod.restore_checkpoint(resume_from, template)
            r0 = int(pay["r0"])
            state = jax.tree_util.tree_map(jnp.asarray, pay["state"])
        with maybe_jax_profiler(profiler_dir):
            while r0 < max_events and bool(np.any(np.asarray(state.active))):
                before = _jit_cache_size(_fleet_chunk_program)
                _sp = tl.begin("chunk", r0=r0)
                state = _fleet_chunk_program(*statics, jnp.int32(r0), state,
                                             arrays)
                jax.block_until_ready(state)
                _note_cache_miss(tl.spans[_sp], _fleet_chunk_program, before)
                tl.finish(_sp)
                r0 += chunk
                if checkpoint_dir and r0 % every == 0:
                    with tl.span("checkpoint_save", r0=r0):
                        ckpt_mod.save_checkpoint(
                            checkpoint_dir, r0,
                            {"r0": np.int64(r0),
                             "state": jax.tree_util.tree_map(np.asarray,
                                                             state)})
    else:
        before = _jit_cache_size(_fleet_program)
        _sp = tl.begin("program")
        with maybe_jax_profiler(profiler_dir):
            state = _fleet_program(*statics, state, arrays)
            jax.block_until_ready(state)
        _note_cache_miss(tl.spans[_sp], _fleet_program, before)
        tl.finish(_sp)
    _sp_unpack = tl.begin("unpack")
    contrib_final, cscale_final = state.contrib, state.cscale
    last_flat = state.last
    acc_h, loss_h, bat_h, exec_h, body_h, member_h = (
        np.asarray(t) for t in (state.acc_h, state.loss_h, state.bat_h,
                                state.exec_h, state.body_h, state.member_h))
    if fc is not None:
        drop_h, retry_h, stale_h, deliver_h = (
            np.asarray(t) for t in (state.drop_h, state.retry_h,
                                    state.stale_h, state.deliver_h))
    if cc is not None:
        clock_h = np.asarray(state.clock_h)
        idle_h = np.asarray(state.idle_h)
        idle_fin = np.asarray(state.idle)
    if ac is not None:
        corrupt_h = np.asarray(state.corrupt_h)
    if robust != "none":
        clip_h = np.asarray(state.clip_h)
    rounds_np = np.asarray(state.rounds_done)
    codes_np = np.asarray(state.stop_code)
    level_np = np.asarray(state.level)

    # contributor write-back: like the loop engine's in-place refresh,
    # each requester's contributor_states end up holding that session's
    # final (refresh-trained, frozen-once-stopped) contributor params.
    # Requesters sharing one states dict see the last writer's lanes.
    # Under compress the final state is wire format — the write-back is
    # its dequantized image, exactly what the loop engine leaves behind.
    if ref_epochs > 0:
        if wire_compress == "int8":
            with tl.span("dequant_unpack"):
                contrib_final = dequantize_flat_batched(
                    contrib_final, cscale_final)[..., :P]
                jax.block_until_ready(contrib_final)
        contrib_tree = tree_unravel(ravel_spec, contrib_final)
        for i, (spec, cs) in enumerate(zip(requesters, lane_devs)):
            for j, c in enumerate(cs):
                spec.contributor_states[c.device_id]["params"] = (
                    jax.tree_util.tree_map(lambda l: l[i, j], contrib_tree))

    # ---- per-session views (loop-engine-compatible SessionResults) --------
    last_p = tree_unravel(ravel_spec, last_flat)
    tl.finish(_sp_unpack)
    sessions = []
    total_e = 0.0
    for i, (spec, cs, b0) in enumerate(zip(requesters, lane_devs, batteries)):
        r_i = int(rounds_np[i])
        if mob is None:
            n_contrib_i = float(len(cs))
        else:
            # mobility: energy roll-up over the MEAN membership, matching
            # EnFedSession._run_mobility's report
            n_contrib_i = (float(member_h[:r_i, i].sum(-1).mean())
                           if r_i else 0.0)
        report = cost.session(
            rounds=r_i, n_contrib=n_contrib_i, num_params=num_params,
            model_bytes=model_bytes, num_samples=len(spec.own_train[0]),
            epochs=cfg.epochs, n_devices=len(spec.neighborhood),
            encrypt=cfg.encrypt)
        if fc is not None:
            # the traces alone reconstruct the fault transport overhead:
            # every drop or retry burned one extra receive window
            extra_i = float(drop_h[:r_i, i].sum() + retry_h[:r_i, i].sum())
            if extra_i:
                report.times.t_com += extra_i * t_retry
                report.e_comm += extra_i * e_rx_retry
        if cc is not None:
            # idle/duty-cycle windows priced through the one shared
            # helper, post-hoc like the retry windows: per-round waits
            # from the trace plus the trailing idle of a lane that never
            # finished.  Idle never drains the simulated battery.
            total_idle_i = int(idle_h[:r_i, i].sum()) + int(idle_fin[i])
            if total_idle_i:
                e_idle, t_idle = cost.idle_energy(
                    idle_steps=total_idle_i, idle_step_s=cc.idle_step_s)
                report.times.t_com += t_idle
                report.e_comm += e_idle
        if robust != "none" and r_i:
            # robust-screening compute priced post-hoc like retry/idle
            # windows: one scan of the session's lane buffer per
            # executed round, into the aggregation time/energy terms —
            # never the simulated battery (so defended and undefended
            # runs of the same world keep bitwise-equal battery traces)
            e_scr, t_scr = cost.screening_energy(
                n_contrib=len(cs), num_params=num_params)
            report.times.t_agg += r_i * t_scr
            report.e_comp += r_i * e_scr
        total_e += report.e_tot
        battery = dataclasses.replace(b0, level=float(level_np[i]))
        history = {"accuracy": [float(a) for a in acc_h[:r_i, i]],
                   "loss": [float(l) for l in loss_h[:r_i, i]],
                   "battery": [float(l) for l in bat_h[:r_i, i]],
                   "round_executed": [float(x) for x in exec_h[:r_i, i]]}
        if mob is not None:
            history["member_mask"] = [member_h[r, i].copy()
                                      for r in range(r_i)]
            history["members"] = [float(member_h[r, i].sum())
                                  for r in range(r_i)]
        if fc is not None:
            history["drops"] = [float(x) for x in drop_h[:r_i, i]]
            history["retries"] = [float(x) for x in retry_h[:r_i, i]]
            history["stale"] = [float(x) for x in stale_h[:r_i, i]]
            history["deliver_mask"] = [deliver_h[r, i].copy()
                                       for r in range(r_i)]
        if cc is not None:
            history["round_clock"] = [int(x) for x in clock_h[:r_i, i]]
            history["idle_steps"] = [int(x) for x in idle_h[:r_i, i]]
        if ac is not None:
            history["corrupted_mask"] = [corrupt_h[r, i].copy()
                                         for r in range(r_i)]
        if robust != "none":
            history["clipped_mask"] = [clip_h[r, i].copy()
                                       for r in range(r_i)]
        sessions.append(SessionResult(
            accuracy=history["accuracy"][-1] if history["accuracy"] else 0.0,
            rounds=r_i, n_contributors=len(cs), report=report, battery=battery,
            history=history, stop_reason=protocol.stop_reason_name(codes_np[i]),
            params=jax.tree_util.tree_map(lambda l: l[i], last_p),
            model_bytes=model_bytes))
    fleet_hist = {"accuracy": acc_h, "loss": loss_h, "battery": bat_h,
                  "executed": exec_h, "round_executed": body_h,
                  "member": member_h}
    if fc is not None:
        fleet_hist.update(drops=drop_h, retries=retry_h, stale=stale_h,
                          deliver=deliver_h)
    if cc is not None:
        fleet_hist.update(round_clock=clock_h, idle_steps=idle_h)
    if ac is not None:
        fleet_hist.update(corrupted=corrupt_h)
    if robust != "none":
        fleet_hist.update(clipped=clip_h)
    return FleetResult(
        sessions=sessions, rounds=rounds_np, stop_codes=codes_np,
        accuracy=np.array([s.accuracy for s in sessions], np.float32),
        battery_level=level_np, total_energy_j=float(total_e),
        history=fleet_hist,
        staged_host_bytes=staged_bytes, staged_index_bytes=index_bytes,
        staged_shard_bytes=shard_bytes,
        staged_shard_bytes_dense=shard_bytes_dense,
        staged_param_bytes=staged_param_bytes,
        device_round_state_bytes=device_round_state_bytes,
        refresh_gather_bytes=gather_bytes,
        refresh_gather_bytes_dense=gather_bytes_dense,
        timeline=tl, hlo_stats=hlo)


def _run_fleet_baseline(task, requesters: Sequence[RequesterSpec], cfg, cost,
                        method: str, dfl_topology: str, use_pallas: bool,
                        interpret, round_chunk: int,
                        timeline: Optional[Timeline] = None,
                        trace=None) -> FleetResult:
    """Stage and run the dfl/cfl traced protocol variants.

    Client roster of requester i = [own shard] + every in-range neighbor
    with data, in neighborhood order — exactly ``WorldSpec.client_data``
    and therefore the loop learners' ``client_data`` list.  Shards are
    content-deduplicated into the same unique-table + gather-index form
    the enfed path stages; node params are the flat (R, N, P) round
    state.  Mobility, refresh, compression-of-state, and battery do not
    exist for the baselines (their loop oracles have none), so those
    knobs are stripped before tracing; ``cfg.compress`` still prices the
    wire in the cost domain, matching the loop learners.
    """
    from repro.kernels.common import resolve_interpret

    if dfl_topology not in ("mesh", "ring"):
        raise ValueError(f"unknown dfl topology {dfl_topology!r} (mesh|ring)")
    tl = timeline if timeline is not None else Timeline()
    _sp_stage = tl.begin("stage")
    R = len(requesters)

    # ---- client rosters (the loop learners' client_data lists) ------------
    rosters = []
    for spec in requesters:
        shards = [spec.own_train]
        for dev in spec.neighborhood:
            st = spec.contributor_states.get(dev.device_id)
            if st is not None:
                shards.append(st["data"])
        rosters.append(shards)
    N = max(len(s) for s in rosters)

    # ---- deduplicated shard table + per-lane gather indices ---------------
    shard_rows: dict = {}
    shard_x, shard_y = [], []
    cidx = np.zeros((R, N), np.int32)
    cli_n = np.zeros((R, N), np.int32)
    for i, shards in enumerate(rosters):
        for j, (xs, ys) in enumerate(shards):
            xa = np.ascontiguousarray(xs, np.float32)
            ya = np.ascontiguousarray(ys, np.int32)
            key = (xa.shape,
                   hashlib.blake2b(xa.tobytes(), digest_size=16).digest(),
                   hashlib.blake2b(ya.tobytes(), digest_size=16).digest())
            row = shard_rows.get(key)
            if row is None:
                row = len(shard_x)
                shard_rows[key] = row
                shard_x.append(xa)
                shard_y.append(ya)
            cidx[i, j] = row
            cli_n[i, j] = len(xa)
    U = len(shard_x)
    n_c_max = max(len(x) for x in shard_x)
    cx_tab = np.zeros((U, n_c_max) + shard_x[0].shape[1:], np.float32)
    cy_tab = np.zeros((U, n_c_max), np.int32)
    for u, (x, y) in enumerate(zip(shard_x, shard_y)):
        cx_tab[u, :len(x)] = x
        cy_tab[u, :len(y)] = y

    # ---- node params: the flat (R, N, P) round state -----------------------
    template = task.init(seed=cfg.seed)
    init_flat, ravel_spec = tree_ravel(template)
    P = int(init_flat.shape[-1])
    if method == "dfl":
        # DFLLearner: node j of every requester inits from seed + j
        node_inits = jnp.stack(
            [init_flat] + [tree_ravel(task.init(seed=cfg.seed + j))[0]
                           for j in range(1, N)])
        contrib_flat = jnp.broadcast_to(node_inits[None], (R, N, P)) + 0.0
    else:
        # CFL carries ONE global (in `last`); the lane buffer holds the
        # current round's fitted client updates
        contrib_flat = jnp.zeros((R, N, P), jnp.float32)

    # ---- aggregation weights ----------------------------------------------
    if method == "cfl":
        # CFLLearner weights clients by shard size; padded lanes weigh 0
        cli_w = cli_n.astype(np.float32)
    else:
        strategy = topology.AggregationStrategy(
            kind="dfl_mesh" if dfl_topology == "mesh" else "dfl_ring")
        mix_w = np.zeros((R, N, N), np.float32)
        for i, shards in enumerate(rosters):
            n_i = len(shards)
            mix_w[i, :n_i, :n_i] = topology.group_mixing_matrix(n_i, strategy)
            for k in range(n_i, N):
                mix_w[i, k, k] = 1.0    # padded lanes mix with themselves

    # ---- requester test stacks + schedule bounds --------------------------
    test_x, test_mask = _pad_stack(
        [np.asarray(s.own_test[0], np.float32) for s in requesters],
        max(len(s.own_test[0]) for s in requesters))
    test_y, _ = _pad_stack(
        [np.asarray(s.own_test[1], np.int32) for s in requesters],
        test_x.shape[1])
    steps_max = max(schedule.fit_steps(int(n), cfg.batch_size)
                    for n in cli_n[cli_n > 0])

    arrays = dict(
        cx_tab=jnp.asarray(cx_tab), cy_tab=jnp.asarray(cy_tab),
        cidx=jnp.asarray(cidx), cli_n=jnp.asarray(cli_n),
        test_x=jnp.asarray(test_x), test_y=jnp.asarray(test_y),
        test_mask=jnp.asarray(test_mask), seed0=jnp.int32(cfg.seed),
        desired_accuracy=jnp.float32(cfg.desired_accuracy),
        level0=jnp.ones((R,), jnp.float32))
    if method == "cfl":
        arrays.update(cli_w=jnp.asarray(cli_w), init_flat=init_flat)
    else:
        arrays.update(mix_w=jnp.asarray(mix_w))
    staged_param_bytes = int(contrib_flat.nbytes)
    shard_bytes = int(cx_tab.nbytes + cy_tab.nbytes + cidx.nbytes)
    shard_bytes_dense = int(R * N * (cx_tab.nbytes + cy_tab.nbytes)
                            / max(U, 1))
    index_bytes = int(cli_n.nbytes + cidx.nbytes + 4)
    staged = [contrib_flat] + [v for v in arrays.values()
                               if hasattr(v, "nbytes")]
    staged_bytes = int(sum(int(v.nbytes) for v in staged))

    state0 = _init_state(method, None, False, None, cfg.max_rounds,
                         cfg.max_rounds, P, None, None, None, "none",
                         contrib_flat, arrays)
    statics = (task, use_pallas, resolve_interpret(interpret), False,
               int(round_chunk), cfg.max_rounds, cfg.max_rounds, cfg.epochs,
               cfg.batch_size, steps_max, 0, 1, ravel_spec, None, cfg.n_max,
               None, None, P, method, None, None, None, "none", 1.0, R, N)
    tl.finish(_sp_stage)
    hlo = None
    if trace is not None and getattr(trace, "hlo_stats", False):
        with tl.span("hlo_stats"):
            hlo = jit_hlo_stats(_fleet_program, *statics, state0, arrays) or None
    before = _jit_cache_size(_fleet_program)
    _sp = tl.begin("program")
    with maybe_jax_profiler(getattr(trace, "jax_profiler_dir", None)
                            if trace else None):
        state = _fleet_program(*statics, state0, arrays)
        jax.block_until_ready(state)
    _note_cache_miss(tl.spans[_sp], _fleet_program, before)
    tl.finish(_sp)
    _sp_unpack = tl.begin("unpack")
    last_flat, level = state.last, state.level
    acc_h, loss_h, bat_h, exec_h, body_h, member_h = (
        np.asarray(t) for t in (state.acc_h, state.loss_h, state.bat_h,
                                state.exec_h, state.body_h, state.member_h))
    rounds_np = np.asarray(state.rounds_done)
    codes_np = np.asarray(state.stop_code)

    # ---- per-session views (loop-baseline-compatible) ----------------------
    # Identical pricing to CFLLearner/DFLLearner.run_config, with the
    # analytic t_local_fit fallback (a compiled fleet has no per-node
    # host wall clock to measure); battery=None like the loop baselines.
    num_params = tree_size(template)
    model_bytes = update_wire_bytes(num_params, encrypt=False,
                                    compress=getattr(cfg, "compress", None),
                                    raw_bytes=tree_bytes(template))
    last_p = tree_unravel(ravel_spec, last_flat)
    tl.finish(_sp_unpack)
    fc = getattr(cfg, "faults", None)
    sessions = []
    total_e = 0.0
    for i, spec in enumerate(requesters):
        r_i = int(rounds_np[i])
        n_cli = len(rosters[i])
        if method == "cfl":
            report = cost.cfl_session(
                rounds=r_i, num_params=num_params, model_bytes=model_bytes,
                num_samples=len(spec.own_train[0]), epochs=cfg.epochs)
            history = {"accuracy": [float(a) for a in acc_h[:r_i, i]],
                       "loss": []}
        else:
            report = cost.dfl_session(
                rounds=r_i, n_peers=n_cli - 1, num_params=num_params,
                model_bytes=model_bytes,
                num_samples=len(spec.own_train[0]), epochs=cfg.epochs,
                topology=dfl_topology)
            history = {"accuracy": [float(a) for a in acc_h[:r_i, i]]}
        if fc is not None and r_i:
            # the baselines' loop oracles define convergence, so link
            # faults price in the COST domain only: the same fault world
            # (requester fc.requester_id + i), rolled over this method's
            # wire links — the one server uplink (cfl, WAN-rated) or the
            # gossip fan (dfl) — and every extra transmission re-priced
            # through the one CostModel, same as the enfed engines
            if method == "cfl":
                link_ids = np.array([0], np.int32)
                _, e_tx_r, t_xfer = cost.retry_energy(
                    model_bytes=model_bytes, encrypt=False,
                    rate_bps=cost.link.wan_rate_bps)
            else:
                fan = (n_cli - 1 if dfl_topology == "mesh"
                       else min(2, n_cli - 1))
                link_ids = np.arange(1, fan + 1, dtype=np.int32)
                _, e_tx_r, t_xfer = cost.retry_energy(
                    model_bytes=model_bytes, encrypt=True)
            extra = 0.0
            for r in range(r_i):
                delivered, attempts, _ = faults_mod.link_outcomes(
                    fc, r, fc.requester_id + i, link_ids)
                extra += float(np.sum(np.asarray(attempts))
                               - np.sum(np.asarray(delivered)))
            report.times.t_com += extra * t_xfer
            report.e_comm += extra * e_tx_r
            history["fault_extra_tx"] = extra
        total_e += report.e_tot
        sessions.append(SessionResult(
            accuracy=history["accuracy"][-1] if history["accuracy"] else 0.0,
            rounds=r_i, n_contributors=n_cli - 1, report=report,
            battery=None, history=history,
            stop_reason=protocol.stop_reason_name(codes_np[i]),
            params=jax.tree_util.tree_map(lambda l: l[i], last_p),
            model_bytes=model_bytes))
    return FleetResult(
        sessions=sessions, rounds=rounds_np, stop_codes=codes_np,
        accuracy=np.array([s.accuracy for s in sessions], np.float32),
        battery_level=np.asarray(level), total_energy_j=float(total_e),
        history={"accuracy": acc_h, "loss": loss_h, "battery": bat_h,
                 "executed": exec_h, "round_executed": body_h,
                 "member": member_h},
        staged_host_bytes=staged_bytes, staged_index_bytes=index_bytes,
        staged_shard_bytes=shard_bytes,
        staged_shard_bytes_dense=shard_bytes_dense,
        staged_param_bytes=staged_param_bytes,
        device_round_state_bytes=staged_param_bytes,
        refresh_gather_bytes=0, refresh_gather_bytes_dense=0,
        timeline=tl, hlo_stats=hlo)
